#!/usr/bin/env python
"""Check that intra-repo markdown links resolve.

Scans every ``*.md`` file in the repository (skipping dot-directories) for
inline links/images ``[text](target)`` and reference definitions
``[ref]: target``, and verifies that each relative target exists on disk
(anchors and ``http(s)``/``mailto`` links are skipped).  Exits non-zero
listing every broken link — the docs job in ``.github/workflows/ci.yml``
runs this on every push.

    python scripts/check_md_links.py [root]
"""

from __future__ import annotations

import pathlib
import re
import sys

# inline [text](target) and image ![alt](target); stop at whitespace or ')'
_INLINE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
# reference-style definitions: [ref]: target
_REFDEF = re.compile(r"^\s*\[[^\]]+\]:\s+(\S+)", re.MULTILINE)


def _targets(text: str) -> list[str]:
    return _INLINE.findall(text) + _REFDEF.findall(text)


def check(root: pathlib.Path) -> list[str]:
    errors: list[str] = []
    md_files = [p for p in root.rglob("*.md")
                if not any(part.startswith(".") for part in p.parts)]
    for md in sorted(md_files):
        # fenced code blocks may contain [x](y)-looking text — drop them
        text = re.sub(r"```.*?```", "", md.read_text(), flags=re.DOTALL)
        for target in _targets(text):
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            path = target.split("#", 1)[0]
            if not path:
                continue
            resolved = (md.parent / path).resolve()
            if not resolved.exists():
                errors.append(f"{md.relative_to(root)}: broken link "
                              f"-> {target}")
    return errors


def main() -> int:
    root = pathlib.Path(sys.argv[1] if len(sys.argv) > 1 else ".").resolve()
    errors = check(root)
    for e in errors:
        print(e)
    checked = len([p for p in root.rglob('*.md')
                   if not any(part.startswith('.') for part in p.parts)])
    print(f"{'FAIL' if errors else 'OK'}: {checked} markdown files, "
          f"{len(errors)} broken links")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main())
