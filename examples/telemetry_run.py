"""Fleet observability end-to-end: a seeded churn run recorded into a
durable ``repro.telemetry.RunStore``, queried back, and rendered as a
report (docs/observability.md).

The same churn lifecycle as ``examples/churn_serving.py`` — a crash
mid-request, a graceful leave, a joint return — but with a
``TelemetryRecorder`` threaded through every instrumented layer: the
simulator (request/attempt spans, retries, migrations, SLO violations,
joules), the membership-keyed ``PlanCache`` (per-tenant hits/misses,
DP frontier-pass spans), and the ``FleetController`` (membership gauges,
leader fail-overs).  The run lands as an append-only JSONL event log plus
an atomic manifest; the gates below hold the log to its contract:

  1. **sufficiency** — ``sim_aggregates`` rebuilds the in-memory
     ``SimReport`` totals exactly from the log;
  2. **durability** — a fresh ``RunStore`` handle (a "process restart")
     reads the same events back;
  3. **reportability** — ``repro.telemetry.report`` renders a non-empty
     summary (the CLI exits nonzero on an empty run).

    PYTHONPATH=src python examples/telemetry_run.py
"""

import tempfile

from repro.core import (EdgeSimulator, HiDPPlanner, Objective,
                        PlannerConfig, SimRequest)
from repro.core.edge_models import EDGE_MODELS, MODEL_DELTA, paper_cluster
from repro.fleet import ChurnTrace, FleetController
from repro.serving import PlanCache
from repro.telemetry import RunStore, TelemetryRecorder, sim_aggregates
from repro.telemetry.report import generate

cluster = paper_cluster()
dag, delta = EDGE_MODELS["resnet152"](), MODEL_DELTA["resnet152"]

workdir = tempfile.mkdtemp(prefix="telemetry_run_")
store = RunStore(workdir)
rec = TelemetryRecorder(store.new_run("churn"), store=store)

trace = ChurnTrace.scripted([
    (0.35, "tx2", "crash"),
    (4.00, "nano", "leave"),
    (8.00, "tx2", "join"),
    (8.00, "nano", "join"),
])
fleet = FleetController(cluster, trace, telemetry=rec)
cache = PlanCache(
    HiDPPlanner(PlannerConfig(objective=Objective("energy",
                                                  radio_power=4.0))),
    cluster, membership_source=fleet, telemetry=rec)
sim = EdgeSimulator(cluster, "hidp", plan_cache=cache, fleet=fleet,
                    telemetry=rec)

requests = [SimRequest(i, dag, 2.5 * i, delta, slo=2.0) for i in range(5)]
report = sim.run(requests)
rec.close(example="telemetry_run", nodes=len(cluster.nodes))

# gate 1: the log is a sufficient statistic for the run
agg = sim_aggregates(store, rec.run)
assert agg["requests"] == len(report.records)
assert agg["total_retries"] == report.total_retries() == 1
assert agg["total_migrations"] == report.total_migrations()
assert agg["slo_violations"] == report.slo_violations()
assert agg["total_active_joules"] == sum(r.active_energy
                                         for r in report.records)
assert sum(agg["cache_hits_by_tenant"].values()) == cache.hits
assert sum(agg["cache_misses_by_tenant"].values()) == cache.misses

# gate 2: a fresh handle (a restarted process) reads the same run back
reopened = RunStore(workdir)
assert reopened.latest() == rec.run
assert len(reopened.events(rec.run)) == len(store.events(rec.run)) > 0
assert reopened.manifest(rec.run)["counts"]["span"] > 0

# gate 3: the report renders, and the queries slice
epochs = store.events(rec.run, kind="gauge", name="fleet.membership")
passes = store.events(rec.run, kind="span", name="plan.frontier_pass")
assert len(epochs) == fleet.epoch == 3
assert len(passes) == cache.misses == 3
print(generate(store, rec.run))
print(f"\nrun store: {store.run_dir(rec.run)}")
print("telemetry lifecycle: record -> persist -> restart -> query -> "
      "report, log == SimReport: OK")
