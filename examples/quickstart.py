"""Quickstart: plan a DNN inference request with HiDP and compare against the
SoA baselines — the paper's core loop in ~50 lines.

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import (EdgeSimulator, Objective, STRATEGIES, PlannerConfig,
                        plan, simulate)
from repro.core.edge_models import (MODEL_DELTA, battery_cluster,
                                    paper_cluster, resnet152)

cluster = paper_cluster()          # Orin NX + TX2 + Nano + RPi5 + RPi4
dag = resnet152()                  # the DNN as a partitionable block DAG
delta = MODEL_DELTA["resnet152"]   # measured compute intensity [cycles/flop]

# --- two-tier HiDP planning (Alg. 1) --------------------------------------
p = plan(dag, cluster, PlannerConfig(delta=delta))
print(f"HiDP chose GLOBAL {p.mode} partitioning across "
      f"{len(p.global_plan.assignments)} nodes "
      f"(predicted latency {p.predicted_latency * 1e3:.0f} ms):")
for a, lp in zip(p.global_plan.assignments, p.local_plans):
    share = (f"blocks[{a.block_range[0]}:{a.block_range[1]}]"
             if a.block_range else f"{a.fraction:.1%} of the input")
    print(f"  {a.node.name:8s} ← {share:22s} "
          f"local tier: {lp.mode}-partitioned "
          f"(latency {lp.predicted_latency * 1e3:.0f} ms)")
print(f"planning overhead: {p.planning_seconds * 1e3:.1f} ms "
      f"(paper: ~15 ms)\n")

# --- simulate one request under every strategy -----------------------------
for name in STRATEGIES:
    rep = simulate(cluster, name, [(0.0, dag, delta)])
    r = rep.records[0]
    print(f"{name:10s} latency={r.latency * 1e3:7.0f} ms   "
          f"energy={rep.energies()['resnet152']:6.1f} J   mode={r.mode}")

# --- energy-aware planning (docs/energy.md) ---------------------------------
# On a duty-cycled (battery) fleet, minimize energy under a latency budget.
battery = battery_cluster()
base = plan(dag, battery, PlannerConfig(delta=delta))
obj = Objective("energy", latency_budget=base.predicted_latency * 1.35,
                radio_power=EdgeSimulator.RADIO_POWER)
frugal = plan(dag, battery, PlannerConfig(delta=delta, objective=obj))
print(f"\nbattery fleet: latency-optimal {base.predicted_latency * 1e3:.0f} ms"
      f" / {base.predicted_energy:.1f} J  →  energy-optimal "
      f"{frugal.predicted_latency * 1e3:.0f} ms / "
      f"{frugal.predicted_energy:.1f} J "
      f"(budget {obj.latency_budget * 1e3:.0f} ms, "
      f"{len(frugal.global_plan.assignments)} of "
      f"{len(battery.nodes)} nodes)")
