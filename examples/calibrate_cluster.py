"""Calibrate a cluster: profile, fit, persist, and re-plan — the paper's
DNN Model Analyzer loop on a synthetic 3-node fleet whose true performance
diverges from its datasheet.

    PYTHONPATH=src python examples/calibrate_cluster.py
"""

import tempfile

from repro.core import (Cluster, Node, PlannerConfig, Processor, plan,
                        simulate)
from repro.core.dag import Block, chain
from repro.profiling import (CalibratedCostProvider, CalibrationStore,
                             LearnedCostModel, Profiler, SyntheticGroundTruth)


# --- a 3-node cluster, declared identical ----------------------------------
def make_node(name: str) -> Node:
    return Node(name=name, processors=(
        Processor(name="cpu", kind="cpu", peak_flops=5e10, local_bw=1e10,
                  active_power=2.0, idle_power=0.5),
        Processor(name="gpu", kind="gpu", peak_flops=2e11, local_bw=1e10,
                  active_power=5.0, idle_power=1.0),
    ), net_bw=1e8, default_processor="gpu")


cluster = Cluster(nodes=(make_node("alpha"), make_node("beta"),
                         make_node("gamma")))

# ... but beta secretly sustains 30% of its datasheet (thermal throttling)
truth = SyntheticGroundTruth(cluster, rate_scale={"beta": 0.3}, noise=0.02)

# --- a simple conv workload ------------------------------------------------
blocks = [Block(name=f"b{i}", kind="conv", flops=2e9, param_bytes=1e5,
                bytes_in=4e4, bytes_out=4e4, halo_fraction=0.02)
          for i in range(12)]
dag = chain("toy_cnn", blocks, 4e4, 4e4)

# --- 1. plan with the datasheet (what every node claims) -------------------
before = plan(dag, cluster, PlannerConfig(delta=1.0))
print("datasheet plan  :", ", ".join(
    f"{a.node.name}={a.fraction:.1%}" for a in before.global_plan.assignments),
    f"→ predicted {before.predicted_latency * 1e3:.1f} ms")

# --- 2. profile the fleet and fit the learned cost model -------------------
samples = Profiler(seed=0).profile_cluster(cluster, {"toy_cnn": dag},
                                           {"toy_cnn": 1.0},
                                           ground_truth=truth)
model = LearnedCostModel.fit(samples)
for node in cluster.nodes:
    learned = model.rate(f"{node.name}/gpu", "conv")
    print(f"  measured {node.name}/gpu rate: {learned / 1e9:6.1f} GFLOP/s "
          f"(datasheet {node.processors[1].peak_flops / 1e9:.0f})")

# --- 3. persist it, versioned by cluster fingerprint -----------------------
store = CalibrationStore(tempfile.mkdtemp(prefix="calibrations_"))
version = store.save(cluster, model, note="initial profiling run")
print(f"saved calibration v{version} for fingerprint "
      f"{CalibrationStore.fingerprint(cluster)} under {store.root}")

# --- 4. re-plan with measured rates ----------------------------------------
provider = CalibratedCostProvider(store.load(cluster))
after = plan(dag, cluster, PlannerConfig(delta=1.0, provider=provider))
print("calibrated plan :", ", ".join(
    f"{a.node.name}={a.fraction:.1%}" for a in after.global_plan.assignments),
    f"→ predicted {after.predicted_latency * 1e3:.1f} ms")

# --- 5. both plans on the *true* hardware ----------------------------------
for label, prov in (("datasheet", None), ("calibrated", provider)):
    rep = simulate(cluster, "hidp", [(0.0, dag, 1.0)], provider=prov,
                   ground_truth=truth)
    print(f"simulated latency with {label:10s} plan: "
          f"{rep.records[0].latency * 1e3:6.1f} ms")
