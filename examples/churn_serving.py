"""Churn-aware elastic serving: nodes crash mid-request, leave, and return
while a membership-keyed PlanCache keeps planning off the hot path
(docs/fleet.md).

A scripted ``ChurnTrace`` drives the fleet through three membership epochs
while a mixed request stream is served:

  1. **crash mid-request** — tx2 dies while executing a shard; the leader
     consumes the failure, re-plans the request on the survivors (one
     frontier pass for the never-seen membership), and retries it to
     completion — ``SimReport`` counts the retry and the migrated shards;
  2. **graceful leave** — nano departs between requests; the next request
     simply plans around it (another membership, another single pass);
  3. **return** — both nodes come back: the membership fingerprint flips
     back to its original value and the warm front built in step 0 serves
     again with **zero DP work** — asserted, not hoped.

    PYTHONPATH=src python examples/churn_serving.py
"""

from repro.core import (EdgeSimulator, HiDPPlanner, Objective,
                        PlannerConfig, SimRequest)
from repro.core.edge_models import EDGE_MODELS, MODEL_DELTA, paper_cluster
from repro.fleet import ChurnTrace, FleetController
from repro.serving import PlanCache

cluster = paper_cluster()
dag, delta = EDGE_MODELS["resnet152"](), MODEL_DELTA["resnet152"]

# one crash inside request 0's execution window, one leave/return cycle
trace = ChurnTrace.scripted([
    (0.35, "tx2", "crash"),
    (4.00, "nano", "leave"),
    (8.00, "tx2", "join"),
    (8.00, "nano", "join"),
])
fleet = FleetController(cluster, trace)
cache = PlanCache(
    HiDPPlanner(PlannerConfig(objective=Objective("energy",
                                                  radio_power=4.0))),
    cluster, membership_source=fleet)
sim = EdgeSimulator(cluster, "hidp", plan_cache=cache, fleet=fleet)

requests = [SimRequest(i, dag, 2.5 * i, delta, slo=2.0) for i in range(5)]
report = sim.run(requests)

print("request  arrival  latency  retries  migrations  slo")
for r in report.records:
    print(f"{r.request_id:7d}  {r.arrival:7.2f}  {r.latency * 1e3:6.0f}ms"
          f"  {r.retries:7d}  {r.migrations:10d}"
          f"  {'VIOLATED' if r.slo_violated else 'ok':>8s}")
s = cache.stats()
print(f"\nepochs {fleet.epoch}, leader elections {fleet.leader_elections}, "
      f"retries {report.total_retries()}, "
      f"migrations {report.total_migrations()}, "
      f"SLO violations {report.slo_violations()}")
print(f"cache: {s['misses']} frontier passes for "
      f"{1 + fleet.epoch} memberships x 1 tenant, {s['hits']} warm hits")

# the gates this example exists to demonstrate
assert len(report.records) == len(requests), "a request was lost to churn"
assert report.total_retries() == 1, "the crash must retry exactly once"
assert report.total_migrations() >= 1
assert fleet.epoch == 3                      # crash, leave, joint return
# memberships: full, minus-tx2, minus-both — the final epoch *returns* to
# full, so 3 frontier passes cover all 4 epochs: zero DP on warm return
assert cache.misses == 3, f"expected 3 frontier passes, got {cache.misses}"
final = cache.misses
cache.get(dag, "latency", delta=delta)       # post-return lookup
assert cache.misses == final, "warm return must cost zero DP work"
print("\nchurn lifecycle: crash -> retry, leave -> re-plan, "
      "return -> warm front, zero DP: OK")
