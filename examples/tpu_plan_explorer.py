"""TPU-tier HiDP planning: show the tier-2 DSE (the P1–P9 analogue) for any
(arch × shape × mesh) cell — which layouts were considered, their predicted
three-term roofline costs, and what the planner picked.

    PYTHONPATH=src python examples/tpu_plan_explorer.py --arch qwen3-moe-30b-a3b --shape train_4k
"""

import argparse

from repro.configs import ARCH_IDS, get_config
from repro.models import SHAPES, build_model
from repro.sharding.plan import (MULTI_POD, SINGLE_POD, _candidate_cost,
                                 _enumerate_candidates, plan_tpu)

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="qwen3-moe-30b-a3b", choices=ARCH_IDS)
ap.add_argument("--shape", default="train_4k", choices=list(SHAPES))
ap.add_argument("--multi-pod", action="store_true")
args = ap.parse_args()

mesh = MULTI_POD if args.multi_pod else SINGLE_POD
cfg = get_config(args.arch)
model = build_model(cfg)
shape = SHAPES[args.shape]

cands = _enumerate_candidates(cfg, shape, mesh, "data")
rows = []
for c in cands:
    cost = _candidate_cost(model, shape, c, mesh)
    rows.append((c, cost))
rows.sort(key=lambda rc: rc[1]["total"])

print(f"{args.arch} × {args.shape} on {mesh.shape} — tier-2 DSE "
      f"({len(rows)} candidates, top 12 by predicted step time):\n")
print(f"{'layout':22s}{'micro':>6s}{'rg':>4s}{'opt':>5s}{'par':>5s}"
      f"{'compute':>9s}{'memory':>9s}{'coll':>9s}{'resident':>10s}{'fits':>6s}")
seen = set()
shown = 0
for c, cost in rows:
    key = (c["name"], c["moe_impl"])
    if key in seen or shown >= 12:
        continue
    seen.add(key)
    shown += 1
    print(f"{c['name']:22s}{c['microbatches']:6d}{c.get('remat_group', 1):4d}"
          f"{c.get('opt_dtype', 'f32')[:4]:>5s}"
          f"{c.get('param_dtype', 'f32')[:4]:>5s}"
          f"{cost['compute']:9.3g}{cost['memory']:9.3g}"
          f"{cost['collective']:9.3g}{cost['resident'] / 1e9:9.1f}G"
          f"{'  ✓' if cost['fits'] else '  ✗'}")

plan = plan_tpu(model, shape, mesh)
print(f"\nHiDP picked: {plan.local_layout} (global {plan.global_mode} mode, "
      f"micro={plan.microbatches}, remat_group={plan.remat_group}, "
      f"opt={plan.opt_dtype}, params={plan.param_dtype}, "
      f"moe={plan.moe_impl})")
print(f"planning took {plan.planning_seconds * 1e3:.1f} ms")
