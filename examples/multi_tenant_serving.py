"""Multi-tenant serving: two DNN workloads share one edge cluster through
a single persistent, evicting PlanCache (docs/serving.md).

Phase 1 (the cold process) serves a mixed EfficientNet-B0 + VGG-19 request
stream from one shared cache — each tenant pays exactly one frontier pass —
prints the cache stats, and persists the warm fronts next to the
calibrations in a ``CalibrationStore``.

Phase 2 (the restart) re-executes this script in a **fresh interpreter**
(``--restart``): the new process builds its PlanCache straight from the
store and serves the same mixed stream with *zero* DP work — no tenant
ever re-pays the cold pass.

    PYTHONPATH=src python examples/multi_tenant_serving.py
"""

import subprocess
import sys
import tempfile

from repro.core import HiDPPlanner, Objective, PlannerConfig, simulate
from repro.core.edge_models import EDGE_MODELS, MODEL_DELTA, battery_cluster
from repro.profiling import CalibrationStore
from repro.serving import LRUEviction, PlanCache

TENANTS = ("efficientnet_b0", "vgg19")


def build_cache(store: CalibrationStore | None = None) -> PlanCache:
    """One cache per cluster: an energy-aware planner, an LRU budget big
    enough for both tenants, and (optionally) a store to warm from."""
    planner = HiDPPlanner(PlannerConfig(
        objective=Objective("energy", radio_power=4.0)))
    return PlanCache(planner, battery_cluster(),
                     eviction=LRUEviction(max_entries=8), store=store)


def serve_mixed_stream(cache: PlanCache, label: str) -> None:
    """12 requests alternating between the two tenants, mixed objectives,
    all resolved from the one shared cache."""
    for name in TENANTS:
        dag, delta = EDGE_MODELS[name](), MODEL_DELTA[name]
        for metric in ("latency", "energy", "edp"):
            p = cache.get(dag, metric, delta=delta)
        p = cache.get(dag, "energy", delta=delta)
        print(f"  {name:18s} energy-optimal "
              f"{p.predicted_latency * 1e3:6.0f} ms / "
              f"{p.predicted_energy:5.1f} J  mode={p.mode}")
    wl = [(0.3 * i, EDGE_MODELS[TENANTS[i % 2]](),
           MODEL_DELTA[TENANTS[i % 2]]) for i in range(12)]
    rep = simulate(battery_cluster(), "hidp", wl, plan_cache=cache)
    s = cache.stats()
    print(f"  [{label}] served {len(rep.records)} simulated requests — "
          f"cache: {s['misses']} frontier passes, {s['hits']} hits "
          f"(hit rate {s['hit_rate']:.3f}), {s['entries']} tenants "
          f"resident, {s['nbytes']} bytes, {s['evictions']} evictions")


def cold_process() -> None:
    store_dir = tempfile.mkdtemp(prefix="hidp_store_")
    store = CalibrationStore(store_dir)
    cache = build_cache()
    print("cold process: every tenant pays one frontier pass")
    serve_mixed_stream(cache, "cold")
    n = cache.persist(store)
    print(f"persisted {n} warm fronts → "
          f"{store.fronts_path(cache.cluster)}\n")
    print("restarting in a fresh interpreter ...")
    ret = subprocess.run([sys.executable, __file__, "--restart", store_dir])
    raise SystemExit(ret.returncode)


def restarted_process(store_dir: str) -> None:
    cache = build_cache(store=CalibrationStore(store_dir))
    print(f"restarted process: {cache.loaded} fronts loaded warm from "
          f"the store")
    serve_mixed_stream(cache, "restarted")
    assert cache.misses == 0, "restart paid a DP pass it should have skipped"
    print("restart served every tenant with zero DP/frontier work — the "
          "cold pass ran once, ever")


if __name__ == "__main__":
    if len(sys.argv) > 2 and sys.argv[1] == "--restart":
        restarted_process(sys.argv[2])
    else:
        cold_process()
