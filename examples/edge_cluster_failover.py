"""Fault tolerance in action: nodes drop out mid-stream and the HiDP leader
re-plans around them (availability vector A(N_φ), Eq. 4) — requests keep
completing, at reduced throughput, with zero manual intervention.

    PYTHONPATH=src python examples/edge_cluster_failover.py
"""

from repro.core import ClusterManager, EdgeSimulator, SimRequest
from repro.core.edge_models import MODEL_DELTA, paper_cluster, inceptionv3

cluster5 = paper_cluster()
mgr = ClusterManager(cluster5)
mgr.elect_leader("orin_nx")
dag = inceptionv3()
delta = MODEL_DELTA["inceptionv3"]

print("phase 1: all 5 nodes up")
sim = EdgeSimulator(mgr.cluster, "hidp")
rep = sim.run([SimRequest(0, dag, 0.0, delta)])
print(f"  latency {rep.records[0].latency * 1e3:.0f} ms using "
      f"{len({s.node for s in rep.spans})} nodes")

print("phase 2: tx2 and nano fail (heartbeats stop)")
mgr.set_available("tx2", False)
mgr.set_available("nano", False)
sim = EdgeSimulator(mgr.cluster, "hidp")
rep = sim.run([SimRequest(1, dag, 0.0, delta)])
used = {s.node for s in rep.spans}
print(f"  latency {rep.records[0].latency * 1e3:.0f} ms using {used}")
assert "tx2" not in used and "nano" not in used

print("phase 3: tx2 recovers")
mgr.set_available("tx2", True)
sim = EdgeSimulator(mgr.cluster, "hidp")
rep = sim.run([SimRequest(2, dag, 0.0, delta)])
print(f"  latency {rep.records[0].latency * 1e3:.0f} ms using "
      f"{ {s.node for s in rep.spans} }")
print("re-planning around failures: OK")
