"""Fig. 8 — inference latency with 2–5 worker nodes.  Paper: HiDP lowest
everywhere and its advantage GROWS as the cluster shrinks (the local tier
matters most when there are few nodes); averages 30/46/38 % vs
DisNet/OmniBoost/MoDNN."""

from __future__ import annotations

import numpy as np

from repro.core import simulate
from repro.core.edge_models import EDGE_MODELS, MODEL_DELTA, paper_cluster

from .common import MODELS, STRATS, emit


def main() -> dict:
    out: dict[int, dict[str, float]] = {}
    print("\n== Fig 8: mean latency (ms) vs cluster size ==")
    print("nodes".ljust(8) + "".join(f"{s:>11}" for s in STRATS))
    for n in (2, 3, 4, 5):
        row = {}
        for s in STRATS:
            lats = []
            for m in MODELS:
                rep = simulate(paper_cluster(n), s,
                               [(0.0, EDGE_MODELS[m](), MODEL_DELTA[m])])
                lats.append(rep.records[0].latency)
            row[s] = float(np.mean(lats))
            emit(f"fig8/{n}nodes/{s}", row[s] * 1e6)
        out[n] = row
        print(f"{n}".ljust(8) + "".join(f"{row[s] * 1e3:11.0f}"
                                        for s in STRATS))
    # HiDP lowest at every cluster size (the paper's core Fig. 8 claim)
    for n, row in out.items():
        assert min(row, key=row.get) == "hidp", (n, row)
    adv = {n: 1 - row["hidp"] / min(row[s] for s in STRATS[1:])
           for n, row in out.items()}
    print("\nHiDP advantage vs best baseline:",
          {n: f"{a * 100:.0f}%" for n, a in sorted(adv.items())},
          "(paper: gap grows as the cluster shrinks; here it is ~flat — "
          "our wireless medium saturates later than theirs, see "
          "EXPERIMENTS.md)")
    return out


if __name__ == "__main__":
    main()
