"""Fig. 8 — inference latency with 2–5 worker nodes.  Paper: HiDP lowest
everywhere and its advantage GROWS as the cluster shrinks (the local tier
matters most when there are few nodes); averages 30/46/38 % vs
DisNet/OmniBoost/MoDNN.

Plus the **churn variant** (exit-code gated): the same 5-node cluster
serving the same request stream while a scripted ``repro.fleet``
ChurnTrace crashes one node mid-request and walks another through a
leave/return cycle.  Every request must still complete (retried where a
crash killed its shards), and throughput under churn must stay >= 0.8x
the static run at the same node count — the elasticity tax is bounded.

Plus the **trace gate** (exit-code gated): two seeded replays of the
churn scenario, recorded through ``repro.telemetry``, must reconstruct
**byte-identical** span trees (``tree_lines`` — ids, parentage, children
order, canonical JSON), and every request's critical-path categories
must sum to its recorded latency, with the scripted crash surfacing as
nonzero retry-waste.  This is the determinism contract the trace layer
adds on top of the event log."""

from __future__ import annotations

import tempfile

import numpy as np

from repro.core import SimRequest, EdgeSimulator, simulate
from repro.core.edge_models import EDGE_MODELS, MODEL_DELTA, paper_cluster
from repro.fleet import ChurnTrace, FleetController

from .common import MODELS, STRATS, emit


def main() -> dict:
    out: dict[int, dict[str, float]] = {}
    print("\n== Fig 8: mean latency (ms) vs cluster size ==")
    print("nodes".ljust(8) + "".join(f"{s:>11}" for s in STRATS))
    for n in (2, 3, 4, 5):
        row = {}
        for s in STRATS:
            lats = []
            for m in MODELS:
                rep = simulate(paper_cluster(n), s,
                               [(0.0, EDGE_MODELS[m](), MODEL_DELTA[m])])
                lats.append(rep.records[0].latency)
            row[s] = float(np.mean(lats))
            # simulated latency: deterministic domain time, so the
            # regression diff gates it (unlike wall-clock "us" metrics)
            emit(f"fig8/{n}nodes/{s}", row[s] * 1e6, unit="sim_us")
        out[n] = row
        print(f"{n}".ljust(8) + "".join(f"{row[s] * 1e3:11.0f}"
                                        for s in STRATS))
    # HiDP lowest at every cluster size (the paper's core Fig. 8 claim)
    for n, row in out.items():
        assert min(row, key=row.get) == "hidp", (n, row)
    adv = {n: 1 - row["hidp"] / min(row[s] for s in STRATS[1:])
           for n, row in out.items()}
    print("\nHiDP advantage vs best baseline:",
          {n: f"{a * 100:.0f}%" for n, a in sorted(adv.items())},
          "(paper: gap grows as the cluster shrinks; here it is ~flat — "
          "our wireless medium saturates later than theirs, see "
          "EXPERIMENTS.md)")
    churn_gate()
    trace_gate()
    return out


def churn_gate(n_requests: int = 12, floor: float = 0.8) -> dict:
    """Throughput under churn >= ``floor`` x static, same node count.

    The stream alternates two workloads; the trace crashes tx2 inside the
    first request's execution window (its shards fail, the request
    re-plans on survivors and retries) and duty-cycles nano through a
    graceful leave/return.  Gated (assert -> non-zero exit in CI): every
    request completes, at least one retry actually happened, and the
    completed-per-second ratio holds the floor."""
    names = ["resnet152", "vgg19"]
    wl = [SimRequest(i, EDGE_MODELS[names[i % 2]](), 0.8 * i,
                     MODEL_DELTA[names[i % 2]])
          for i in range(n_requests)]

    static = EdgeSimulator(paper_cluster(), "hidp").run(
        [SimRequest(r.request_id, r.dag, r.arrival, r.delta) for r in wl])
    static_tp = len(static.records) / static.makespan()

    trace = ChurnTrace.scripted([
        (static.records[0].latency * 0.5, "tx2", "crash"),
        (3.0, "tx2", "join"),
        (4.0, "nano", "leave"),
        (6.0, "nano", "join"),
    ])
    fleet = FleetController(paper_cluster(), trace)
    churn = EdgeSimulator(paper_cluster(), "hidp", fleet=fleet).run(wl)
    churn_tp = len(churn.records) / churn.makespan()
    ratio = churn_tp / static_tp

    print(f"\n== Fig 8 churn gate: throughput under churn, 5 nodes ==")
    print(f"static {static_tp:.3f} req/s | churn {churn_tp:.3f} req/s "
          f"(ratio {ratio:.3f}, floor {floor}) — "
          f"{churn.total_retries()} retries, "
          f"{churn.total_migrations()} migrations, "
          f"{fleet.epoch} membership epochs")
    emit("fig8/churn/throughput_ratio_x1000", ratio * 1e3,
         unit="ratio", direction="higher")
    assert len(churn.records) == n_requests, \
        "churn lost a request — every mid-request failure must retry"
    assert churn.total_retries() >= 1, \
        "the scripted crash should have forced at least one retry"
    assert ratio >= floor, (
        f"throughput under churn degraded {ratio:.3f}x < {floor}x static")
    print(f"PASS: churn throughput >= {floor}x static with every "
          "failure retried to completion")
    return {"static": static_tp, "churn": churn_tp, "ratio": ratio}


def trace_gate(n_requests: int = 12, eps: float = 1e-6) -> dict:
    """Span-tree determinism + critical-path exactness over the fig8
    churn scenario.

    Two independent seeded replays (``planning_time=0.0`` — the
    documented replay mode that keeps wall-clock DP overhead out of
    simulated time) are recorded into separate stores; their
    reconstructed trees, rendered as canonical ``tree_lines``, must be
    byte-identical, every request's critical-path categories must sum
    to its recorded latency within ``eps``, and the scripted crash must
    surface as nonzero retry-waste.  Gated (assert -> non-zero exit in
    CI)."""
    from repro.telemetry import (RunStore, TelemetryRecorder,
                                 request_critical_paths, span_trees,
                                 tree_lines)

    names = ["resnet152", "vgg19"]

    def one_replay(root):
        wl = [SimRequest(i, EDGE_MODELS[names[i % 2]](), 0.8 * i,
                         MODEL_DELTA[names[i % 2]])
              for i in range(n_requests)]
        trace = ChurnTrace.scripted([
            (0.4, "tx2", "crash"), (3.0, "tx2", "join"),
            (4.0, "nano", "leave"), (6.0, "nano", "join")])
        store = RunStore(root)
        rec = TelemetryRecorder(store.new_run("fig8trace"), store=store)
        fleet = FleetController(paper_cluster(), trace, telemetry=rec)
        rep = EdgeSimulator(paper_cluster(), "hidp", fleet=fleet,
                            telemetry=rec, planning_time=0.0).run(wl)
        rec.close()
        return store, rec.run, rep

    with tempfile.TemporaryDirectory() as td_a, \
            tempfile.TemporaryDirectory() as td_b:
        store_a, run_a, rep_a = one_replay(td_a)
        store_b, run_b, rep_b = one_replay(td_b)
        lines_a = tree_lines(span_trees(store_a.events(run_a)))
        lines_b = tree_lines(span_trees(store_b.events(run_b)))
        paths = request_critical_paths(store_a, run_a)

    print("\n== Fig 8 trace gate: span-tree determinism + "
          "critical-path exactness ==")
    assert lines_a == lines_b, (
        "two seeded replays reconstructed different span trees — "
        "trace identity leaked nondeterminism")
    assert len(paths) == n_requests, (len(paths), n_requests)
    max_resid = max(abs(p.residual) for p in paths)
    assert max_resid <= eps, (
        f"critical-path categories do not sum to recorded latency "
        f"(max residual {max_resid:.3e} s > {eps:.0e})")
    waste = sum(p.categories["retry_waste"] for p in paths)
    assert waste > 0, (
        "the scripted crash produced no retry-waste in any critical "
        "path — attempt parentage is broken")
    print(f"{len(lines_a)} tree lines byte-identical across replays | "
          f"{len(paths)} requests, max residual {max_resid:.2e} s, "
          f"retry waste {waste * 1e3:.1f} ms")
    emit("fig8/trace/lines", float(len(lines_a)), unit="count")
    emit("fig8/trace/retry_waste", waste * 1e6, unit="sim_us")
    print("PASS: trace trees replay byte-identical and critical paths "
          "sum exactly")
    return {"lines": len(lines_a), "max_residual": max_resid,
            "retry_waste_s": waste}


if __name__ == "__main__":
    main()
