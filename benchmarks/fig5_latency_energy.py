"""Fig. 5 — per-strategy inference latency (a) and energy (b) for the four
workloads on the 5-node cluster.  Paper claims (averages across Figs 5-8):
HiDP 37/44/56 % lower latency and 33/48/58 % lower energy than DisNet /
OmniBoost / MoDNN.

Beyond the seed's strategy comparison, this benchmark also covers the two
energy-planning additions:

* ``--objective energy|edp [--latency-slack S]`` — the objective sweep: plan
  every workload latency-optimal, set a latency budget of S × that latency,
  re-plan under the requested objective, and simulate both plans on the
  duty-cycled ``battery_cluster`` (where active joules dominate and the
  trade-off is real; on the wall-powered paper cluster energy simply tracks
  latency, which the default table shows).  Passes when the energy-aware
  plans measure lower ground-truth energy within the budget on ≥ 2 models.

* the calibration comparison (always printed): predicted energy from the
  analytic datasheet algebra vs. from fitted energy predictors, side by
  side against the simulator's ground-truth metering on hardware whose
  true rates/powers diverge from the datasheet.

* the frontier table (always printed): the *full* latency–energy Pareto
  front per workload on the battery cluster — not just the three
  scalarizations — with a gate asserting the PR-2 energy/edp scalarized
  picks lie on it (selection can never leave the frontier it selects
  from).
"""

from __future__ import annotations

import argparse
import sys

import numpy as np

from repro.core import (EdgeSimulator, Objective, PlannerConfig, plan,
                        plan_front, simulate)
from repro.core.edge_models import (EDGE_MODELS, MODEL_DELTA, battery_cluster,
                                    paper_cluster)
from repro.profiling import SyntheticGroundTruth, calibrate

from .common import MODELS, STRATS, emit, single_request_report

# plan with exactly the radio wattage the simulator meters
RADIO_W = EdgeSimulator.RADIO_POWER


# --------------------------------------------------------------------------
# Seed tables: Fig 5a/5b strategy comparison (latency objective)
# --------------------------------------------------------------------------

def strategy_tables() -> dict:
    lat: dict[str, dict[str, float]] = {m: {} for m in MODELS}
    en: dict[str, dict[str, float]] = {m: {} for m in MODELS}
    for m in MODELS:
        for s in STRATS:
            rep = single_request_report(s, m)
            lat[m][s] = rep.records[0].latency
            en[m][s] = rep.energies()[m]
            emit(f"fig5/{m}/{s}", lat[m][s] * 1e6,
                 f"energy_J={en[m][s]:.2f};mode={rep.records[0].mode}")

    print("\n== Fig 5a: latency (ms) ==")
    print("model".ljust(18) + "".join(f"{s:>11}" for s in STRATS))
    for m in MODELS:
        print(m.ljust(18) + "".join(f"{lat[m][s] * 1e3:11.0f}"
                                    for s in STRATS))
    print("\n== Fig 5b: energy (J) ==")
    print("model".ljust(18) + "".join(f"{s:>11}" for s in STRATS))
    for m in MODELS:
        print(m.ljust(18) + "".join(f"{en[m][s]:11.1f}" for s in STRATS))

    print("\n== averages vs paper ==")
    for s, p_lat, p_en in (("disnet", 37, 33), ("omniboost", 44, 48),
                           ("modnn", 56, 58)):
        dl = np.mean([1 - lat[m]["hidp"] / lat[m][s] for m in MODELS]) * 100
        de = np.mean([1 - en[m]["hidp"] / en[m][s] for m in MODELS]) * 100
        print(f"HiDP vs {s:10s}: latency -{dl:4.0f}% (paper {p_lat}%)   "
              f"energy -{de:4.0f}% (paper {p_en}%)")
    return {"latency": lat, "energy": en}


# --------------------------------------------------------------------------
# Energy prediction: analytic vs calibrated, against ground truth
# --------------------------------------------------------------------------

def calibration_comparison() -> dict:
    """Side-by-side energy predictions on hardware that diverges from the
    datasheet: the analytic algebra cannot see the divergence, the fitted
    energy predictors (profiled against the same ground truth) can."""
    cluster = paper_cluster()
    dags = {k: f() for k, f in EDGE_MODELS.items()}
    gt = SyntheticGroundTruth(cluster,
                              rate_scale={("orin_nx", "gpu"): 0.6},
                              power_scale={("orin_nx", "gpu"): 2.0,
                                           ("tx2", "gpu"): 1.6})
    prov = calibrate(cluster, dags, MODEL_DELTA, ground_truth=gt)

    print("\n== predicted energy (J): analytic vs calibrated vs measured ==")
    print("model".ljust(18) + f"{'analytic':>11}{'calibrated':>12}"
          f"{'measured':>11}{'ana err':>9}{'cal err':>9}")
    out = {}
    for m in MODELS:
        rep_a = simulate(cluster, "hidp", [(0.0, dags[m], MODEL_DELTA[m])],
                         ground_truth=gt)
        rep_c = simulate(cluster, "hidp", [(0.0, dags[m], MODEL_DELTA[m])],
                         provider=prov, ground_truth=gt)
        pred_a = rep_a.predicted_energies()[m]
        pred_c = rep_c.predicted_energies()[m]
        meas = rep_c.energies()[m]
        err_a = rep_a.prediction_error()["energy"]
        err_c = rep_c.prediction_error()["energy"]
        print(m.ljust(18) + f"{pred_a:11.1f}{pred_c:12.1f}{meas:11.1f}"
              f"{err_a:9.1%}{err_c:9.1%}")
        emit(f"fig5/calibration/{m}", meas * 1e6,
             f"analytic_err={err_a:.3f};calibrated_err={err_c:.3f}")
        out[m] = {"analytic": pred_a, "calibrated": pred_c, "measured": meas,
                  "analytic_err": err_a, "calibrated_err": err_c}
    return out


# --------------------------------------------------------------------------
# Frontier table: the whole trade-off curve, not three scalarizations
# --------------------------------------------------------------------------

def frontier_table(slack: float = 1.35) -> dict:
    """Plot (textually) the full latency–energy front per workload on the
    duty-cycled cluster and verify the scalarized energy/edp picks under
    the PR-2 budget lie *on* it — the structural guarantee behind the
    objective sweep below."""
    cluster = battery_cluster()
    print("\n== latency-energy Pareto front per workload (battery cluster) ==")
    out = {}
    ok_all = True
    for m in MODELS:
        dag = EDGE_MODELS[m]()
        delta = MODEL_DELTA[m]
        front = plan_front(dag, cluster, PlannerConfig(
            delta=delta, objective=Objective("energy", radio_power=RADIO_W)))
        curve = [(p.latency, p.energy) for p in front]
        print(f"{m} ({len(front)} points):")
        print("   " + "  ".join(f"({lat * 1e3:.0f}ms, {en:.1f}J)"
                                for lat, en in curve))
        emit(f"fig5/front/{m}", front.latency_optimal.latency * 1e6,
             f"points={len(front)};"
             f"lat_span={front.points[-1].latency / front.points[0].latency:.2f};"
             f"en_span={front.points[0].energy / front.points[-1].energy:.2f}")
        budget = front.latency_optimal.latency * slack
        picks = {}
        for metric in ("energy", "edp"):
            obj = Objective(metric, latency_budget=budget,
                            radio_power=RADIO_W)
            picked = plan(dag, cluster, PlannerConfig(delta=delta,
                                                      objective=obj))
            on_front = not front.dominated(picked.predicted_latency,
                                           picked.predicted_energy)
            ok_all &= on_front
            picks[metric] = (picked.predicted_latency,
                             picked.predicted_energy, on_front)
            print(f"   {metric:6s} pick: {picked.predicted_latency * 1e3:.0f}"
                  f" ms / {picked.predicted_energy:.1f} J  "
                  f"{'on front' if on_front else 'OFF FRONT'}")
        out[m] = {"front": curve, "picks": picks}
    print(f"\n{'PASS' if ok_all else 'FAIL'}: energy/edp scalarized picks "
          f"lie on the planned frontier for every workload")
    out["pass"] = ok_all
    return out


# --------------------------------------------------------------------------
# Objective sweep: latency vs energy/edp planning under a latency budget
# --------------------------------------------------------------------------

def objective_sweep(metric: str, slack: float) -> dict:
    from repro.core import HiDPPlanner
    from repro.serving import PlanCache

    cluster = battery_cluster()
    # steady-state serving: the frontier is planned once per (cluster, dag)
    # and every objective variation selects from the warm cache — requests
    # pay lookup microseconds, not the cold DP pass, exactly as the
    # ServingEngine does
    cache = PlanCache(HiDPPlanner(PlannerConfig(
        objective=Objective("energy", radio_power=RADIO_W))), cluster)
    print(f"\n== objective sweep: latency vs {metric} "
          f"(budget = {slack:.2f} x latency-optimal; duty-cycled cluster; "
          f"warm plan cache) ==")
    print("model".ljust(18) + f"{'lat-obj ms':>11}{'lat-obj J':>10}"
          f"{metric + ' ms':>11}{metric + ' J':>10}{'budget ms':>10}"
          f"{'saved':>7}{'ok':>4}")
    out = {}
    improved = 0
    for m in MODELS:
        dag = EDGE_MODELS[m]()
        delta = MODEL_DELTA[m]
        cache.front(dag, delta=delta)            # the one cold pass
        rep_l = simulate(cluster, "hidp", [(0.0, dag, delta)],
                         plan_cache=cache)
        budget = rep_l.records[0].predicted_latency * slack
        obj = Objective(metric, latency_budget=budget, radio_power=RADIO_W)
        rep_e = simulate(cluster, "hidp", [(0.0, dag, delta)], objective=obj,
                         plan_cache=cache)
        lat_l, en_l = rep_l.records[0].latency, rep_l.energies()[m]
        lat_e, en_e = rep_e.records[0].latency, rep_e.energies()[m]
        saved = 1.0 - en_e / en_l
        # the budget binds the *predicted* latency (exposed on the record);
        # the simulated one adds planning overhead and shared-medium
        # contention on top
        ok = (rep_e.records[0].predicted_latency <= budget * (1 + 1e-9)
              and lat_e <= budget * 1.10)
        improved += saved > 0 and ok
        print(m.ljust(18) + f"{lat_l * 1e3:11.0f}{en_l:10.2f}"
              f"{lat_e * 1e3:11.0f}{en_e:10.2f}{budget * 1e3:10.0f}"
              f"{saved:7.1%}{'y' if ok else 'N':>4}")
        emit(f"fig5/objective/{metric}/{m}", lat_e * 1e6,
             f"energy_J={en_e:.2f};latency_J_base={en_l:.2f};"
             f"budget_ms={budget * 1e3:.0f};within_budget={ok}")
        out[m] = {"latency_obj": (lat_l, en_l),
                  f"{metric}_obj": (lat_e, en_e),
                  "budget": budget, "within_budget": ok, "saved": saved}
    verdict = "PASS" if improved >= 2 else "FAIL"
    print(f"\n{verdict}: {metric}-objective plans measure lower ground-truth "
          f"energy within budget on {improved}/{len(MODELS)} models "
          f"(need >= 2); plan cache: {cache.misses} DP passes, "
          f"hit rate {cache.hit_rate():.2f}")
    out["improved"] = improved
    out["cache_hit_rate"] = cache.hit_rate()
    return out


def main(argv: tuple[str, ...] | list[str] = ()) -> dict:
    # called with no args from benchmarks.run — only the CLI passes argv
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--objective", choices=("latency", "energy", "edp"),
                    default="latency",
                    help="latency reproduces the seed tables; energy/edp "
                         "additionally sweep the objective against "
                         "latency-only planning")
    ap.add_argument("--latency-slack", type=float, default=1.35,
                    help="latency budget as a multiple of the "
                         "latency-optimal prediction (default 1.35)")
    args = ap.parse_args(list(argv))

    results = {"strategies": strategy_tables(),
               "calibration": calibration_comparison(),
               "frontier": frontier_table(args.latency_slack)}
    if not results["frontier"]["pass"]:
        sys.exit(1)
    if args.objective != "latency":
        results["sweep"] = objective_sweep(args.objective, args.latency_slack)
        if results["sweep"]["improved"] < 2:
            sys.exit(1)
    return results


if __name__ == "__main__":
    main(sys.argv[1:])
