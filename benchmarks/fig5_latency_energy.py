"""Fig. 5 — per-strategy inference latency (a) and energy (b) for the four
workloads on the 5-node cluster.  Paper claims (averages across Figs 5-8):
HiDP 37/44/56 % lower latency and 33/48/58 % lower energy than DisNet /
OmniBoost / MoDNN."""

from __future__ import annotations

import numpy as np

from .common import MODELS, STRATS, emit, single_request_report


def main() -> dict:
    lat: dict[str, dict[str, float]] = {m: {} for m in MODELS}
    en: dict[str, dict[str, float]] = {m: {} for m in MODELS}
    for m in MODELS:
        for s in STRATS:
            rep = single_request_report(s, m)
            lat[m][s] = rep.records[0].latency
            en[m][s] = rep.energies()[m]
            emit(f"fig5/{m}/{s}", lat[m][s] * 1e6,
                 f"energy_J={en[m][s]:.2f};mode={rep.records[0].mode}")

    print("\n== Fig 5a: latency (ms) ==")
    print("model".ljust(18) + "".join(f"{s:>11}" for s in STRATS))
    for m in MODELS:
        print(m.ljust(18) + "".join(f"{lat[m][s] * 1e3:11.0f}"
                                    for s in STRATS))
    print("\n== Fig 5b: energy (J) ==")
    print("model".ljust(18) + "".join(f"{s:>11}" for s in STRATS))
    for m in MODELS:
        print(m.ljust(18) + "".join(f"{en[m][s]:11.1f}" for s in STRATS))

    print("\n== averages vs paper ==")
    for s, p_lat, p_en in (("disnet", 37, 33), ("omniboost", 44, 48),
                           ("modnn", 56, 58)):
        dl = np.mean([1 - lat[m]["hidp"] / lat[m][s] for m in MODELS]) * 100
        de = np.mean([1 - en[m]["hidp"] / en[m][s] for m in MODELS]) * 100
        print(f"HiDP vs {s:10s}: latency -{dl:4.0f}% (paper {p_lat}%)   "
              f"energy -{de:4.0f}% (paper {p_en}%)")
    return {"latency": lat, "energy": en}


if __name__ == "__main__":
    main()
