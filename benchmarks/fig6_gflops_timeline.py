"""Fig. 6 — cluster performance (GFLOP/s) over time while the four DNNs
arrive every 0.5 s (all four concurrent from t = 1.5 s).  Paper: HiDP
completes all inferences within 5 s and sustains the highest throughput."""

from __future__ import annotations

from repro.core import simulate
from repro.core.edge_models import EDGE_MODELS, MODEL_DELTA, paper_cluster

from .common import STRATS, emit

ORDER = ("efficientnet_b0", "inceptionv3", "resnet152", "vgg19")


def main() -> dict:
    out = {}
    print("\n== Fig 6: dynamic burst (requests every 0.5 s) ==")
    for s in STRATS:
        wl = [(0.5 * i, EDGE_MODELS[n](), MODEL_DELTA[n])
              for i, n in enumerate(ORDER)]
        rep = simulate(paper_cluster(), s, wl)
        makespan = rep.makespan()
        tl = rep.gflops_timeline(dt=0.25)
        peak = max(g for _, g in tl)
        mean = sum(g for _, g in tl if g > 0) / max(
            sum(1 for _, g in tl if g > 0), 1)
        out[s] = dict(makespan=makespan, peak_gflops=peak, mean_gflops=mean)
        emit(f"fig6/{s}", makespan * 1e6,
             f"peak_gflops={peak:.0f};mean_gflops={mean:.0f}")
        bars = "".join("▁▂▃▄▅▆▇█"[min(int(g / max(peak, 1) * 7.99), 7)]
                       for _, g in tl)
        print(f"{s:10s} all-done={makespan:5.2f}s  mean={mean:6.0f} "
              f"GF/s  |{bars}|")
    assert out["hidp"]["makespan"] < 5.0, "HiDP must finish within 5 s"
    assert out["hidp"]["makespan"] == min(v["makespan"] for v in out.values())
    return out


if __name__ == "__main__":
    main()
