"""Fig. 1 — inference latency of the four DNNs on a single Jetson TX2 under
partitioning configurations P1–P9 (number of data partitions × CPU/GPU split).

P1 is the SoA/framework default (all-GPU, no partitioning).  The reproduction
claim: P1 is never optimal; per-model optima differ (ResNet/VGG near 80/20
GPU-heavy splits, EfficientNet's depthwise convs push toward 50/50)."""

from __future__ import annotations

from repro.core.cost_model import comm_time, compute_time, \
    processors_as_resources
from repro.core.edge_models import EDGE_MODELS, MODEL_DELTA, jetson_tx2
from repro.core.local_partitioner import dominant_kind

from .common import emit

# (label, n_partitions, gpu_fraction)
CONFIGS = [("P1", 1, 1.00), ("P2", 1, 0.90), ("P3", 2, 0.90),
           ("P4", 2, 0.80), ("P5", 4, 0.90), ("P6", 2, 0.85),
           ("P7", 4, 0.80), ("P8", 4, 0.65), ("P9", 4, 0.50)]
PARTITION_OVERHEAD = 0.004      # s per extra partition (merge/launch cost)


def latency(dag, delta: float, n_parts: int, gpu_frac: float) -> float:
    node = jetson_tx2()
    kind = dominant_kind(dag)
    cpu, gpu = processors_as_resources(node, delta, kind)
    per_part = []
    for frac, r in ((1 - gpu_frac, cpu), (gpu_frac, gpu)):
        if frac <= 0:
            continue
        t = (compute_time(dag.total_flops * frac, r.rate)
             + comm_time((dag.input_bytes + dag.output_bytes) * frac, r.bw,
                         r.rtt))
        per_part.append(t)
    base = max(per_part)
    halo = sum(b.bytes_out * b.halo_fraction for b in dag.blocks)
    return base + (n_parts - 1) * (PARTITION_OVERHEAD
                                   + halo / cpu.bw / max(n_parts, 1))


def main() -> dict:
    out: dict[str, dict[str, float]] = {}
    print("\n== Fig 1: P1–P9 partitioning sweep on Jetson TX2 "
          "(normalised latency) ==")
    header = "model".ljust(18) + "".join(f"{c[0]:>7}" for c in CONFIGS)
    print(header)
    for name, fn in EDGE_MODELS.items():
        dag = fn()
        lats = {label: latency(dag, MODEL_DELTA[name], n, g)
                for label, n, g in CONFIGS}
        p1 = lats["P1"]
        out[name] = lats
        row = name.ljust(18) + "".join(f"{lats[l] / p1:7.2f}"
                                       for l, _, _ in CONFIGS)
        best = min(lats, key=lats.get)
        print(row + f"   best={best} ({(1 - lats[best] / p1) * 100:.0f}% "
              f"under P1)")
        emit(f"fig1/{name}", lats[best] * 1e6,
             f"best={best};p1_us={p1 * 1e6:.0f}")
        assert best != "P1", f"P1 unexpectedly optimal for {name}"
    return out


if __name__ == "__main__":
    main()
