"""§Roofline — three-term roofline per (arch × shape × mesh) from the dry-run
records.

    compute    = EXEC_FLOPS / (chips × 197 TF/s)
    memory     = HBM_BYTES_per_chip / 819 GB/s
    collective = COLL_BYTES_per_chip / 50 GB/s (ICI) [+ DCN share when the
                 plan crosses pods]

Methodology notes (full discussion in EXPERIMENTS.md §Roofline):

* ``compiled.cost_analysis()`` on XLA:CPU counts each while-loop body ONCE
  (verified: a 5-iteration scan of a matmul reports 1× the matmul FLOPs), so
  the raw HLO numbers undercount depth-L scans by ~L×.  The roofline
  therefore uses an analytic EXECUTED-FLOPs model — useful MODEL_FLOPS plus
  the implementation overheads that are visible in the HLO (remat recompute,
  dense-MoE all-expert waste, blocked-attention full-mask compute, MoE
  capacity padding) — and cross-checks it against raw cost_analysis × L.
* Collective bytes are parsed from the post-SPMD per-device HLO (result
  shapes of all-gather/all-reduce/reduce-scatter/all-to-all/collective-
  permute); in-scan collectives get the same ×L correction via the
  plan's ring model, and the larger of (parsed, ring-model) is reported.
* roofline_fraction = MODEL_FLOPS / (chips × peak × max(term)) — the score:
  fraction of the cluster's peak sustained on USEFUL flops at the modelled
  bottleneck.
"""

from __future__ import annotations

import glob
import json
import os

from repro.configs import get_config
from repro.core import cost_model as cm
from repro.models import SHAPES, build_model
from repro.models.model import (_attn_ctx_flops, _eff_ctx,
                                _per_layer_windows)
from repro.sharding.plan import _moe_ffn_share

PEAK = cm.TPU_V5E_PEAK_FLOPS
HBM = cm.TPU_V5E_HBM_BW
ICI = cm.TPU_V5E_ICI_BW
TDP = cm.TPU_V5E_TDP


def executed_flops(model, shape, plan: dict) -> float:
    """Useful FLOPs + implementation overheads visible in the lowered HLO."""
    cfg = model.cfg
    f = model.step_flops(shape)
    train = shape.kind == "train"
    if train:
        f *= 4.0 / 3.0                      # remat: one extra forward
    # blocked attention computes every (q, kv) block pair (masking, not
    # skipping, in the jnp lowering): charge full-context attention
    if cfg.family != "ssm" and shape.kind != "decode":
        B, S = shape.global_batch, shape.seq_len
        extra = 0.0
        for w in _per_layer_windows(cfg):
            eff = _eff_ctx(S, w)
            extra += B * S * _attn_ctx_flops(cfg, S - eff)
        f += extra * (3.0 if train else 1.0)
    if cfg.moe is not None:
        share = _moe_ffn_share(cfg, shape)
        if plan.get("moe_impl", "dense") == "dense":
            f += (cfg.moe.num_experts / cfg.moe.top_k - 1.0) * share
        else:
            f += (cfg.moe.capacity_factor - 1.0) * share
    return f


def hbm_bytes_per_chip(model, shape, plan: dict, chips: int) -> float:
    """Per-chip HBM traffic per step (reads + writes of resident state and
    activation streams)."""
    cfg = model.cfg
    shards = plan.get("param_shards", None)
    if shards is None:
        shards = 1
        sizes = {"pod": 2 if chips == 512 else 1, "data": 16, "model": 16}
        for a in set(plan["tp_axes"]) | set(plan["fsdp_axes"]):
            shards *= sizes.get(a, 1)
        shards = max(shards, 1)
    p_total = cfg.params_total()
    tokens = shape.global_batch * (1 if shape.kind == "decode"
                                   else shape.seq_len)
    dp = 1
    sizes = {"pod": 2 if chips == 512 else 1, "data": 16, "model": 16}
    for a in tuple(plan["batch_axes"]) + tuple(plan["seq_axes"]):
        dp *= sizes.get(a, 1)
    tok_local = tokens / max(dp, 1)
    if shape.kind == "train":
        sd = 2 if plan.get("opt_dtype") == "bfloat16" else 4
        state = p_total * (4 + sd + sd + 4) / shards
        traffic = 2.0 * state                       # read + write per step
        micro = max(plan.get("microbatches", 1), 1)
        traffic += micro * p_total * 4 / shards * 2  # per-micro param reads
        traffic += 6.0 * tok_local * cfg.d_model * 2 * cfg.n_layers
        return traffic
    params = p_total * 2.0 / shards
    cache = 0.0
    if cfg.family != "ssm":
        cache = (cfg.n_layers * shape.global_batch * shape.seq_len
                 * cfg.n_kv_heads * cfg.hd * 2 * 2) / max(dp * (
                     16 if "model" not in plan["tp_axes"] else 16), 1)
        cache = cache / max(chips / max(dp, 1), 1) * (
            1 if shape.kind == "decode" else 1)
    act = tok_local * cfg.d_model * 2 * cfg.n_layers * 4
    rw = 2.0 if shape.kind == "prefill" else 1.0
    return params + rw * cache + act


def analyze_record(rec: dict) -> dict | None:
    if rec.get("status") != "ok":
        return None
    cfg = get_config(rec["arch"])
    model = build_model(cfg)
    shape = SHAPES[rec["shape"]]
    chips = 512 if rec["multi_pod"] else 256
    plan = rec["plan"]

    model_flops = rec["model_flops"]
    exec_flops = executed_flops(model, shape, plan)
    compute = exec_flops / (chips * PEAK)

    hbm = hbm_bytes_per_chip(model, shape, plan, chips)
    memory = hbm / HBM

    parsed_coll = rec["collectives"].get("total", 0.0)    # per-device, 1×scan
    ring = plan.get("predicted", {}).get("collective", 0.0)
    collective = max(parsed_coll / ICI, ring)

    terms = {"compute": compute, "memory": memory, "collective": collective}
    dom = max(terms, key=terms.get)
    bound = max(terms.values())
    frac = model_flops / (chips * PEAK * bound) if bound > 0 else 0.0
    energy_j = chips * TDP * bound
    return dict(
        arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
        layout=plan["layout"], moe_impl=plan.get("moe_impl", "-"),
        compute_s=compute, memory_s=memory, collective_s=collective,
        dominant=dom, roofline_fraction=frac,
        model_flops=model_flops, exec_flops=exec_flops,
        useful_ratio=model_flops / exec_flops,
        hlo_flops_raw=rec["cost"]["flops"],
        hlo_coll_bytes=parsed_coll,
        peak_mem_gb=rec["memory"]["peak_per_device"] / 1e9,
        fits=rec["memory"]["peak_per_device"] <= 16e9,
        energy_j=energy_j,
    )


def _load_rows(dryrun_dir: str) -> list[dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        # skip forced-layout/impl variants (suffix-tagged)
        base = os.path.basename(path)
        if base.count("_") > 2 and not base.endswith(("_sp.json",
                                                      "_mp.json")):
            continue
        row = analyze_record(rec)
        if row:
            rows.append(row)
    rows.sort(key=lambda r: (r["mesh"], r["arch"], r["shape"]))
    return rows


def main(dryrun_dir: str = "experiments/dryrun",
         out_path: str = "experiments/roofline.json") -> list[dict]:
    rows = _print_table(dryrun_dir, "paper-faithful baseline planner")
    if os.path.isdir("experiments/dryrun_v2"):
        v2 = _print_table("experiments/dryrun_v2",
                          "final planner (post-§Perf hillclimbs)")
        base_map = {(r["arch"], r["shape"], r["mesh"]):
                    r["roofline_fraction"] for r in rows}
        gains = [(k := (r["arch"], r["shape"], r["mesh"]),
                  base_map.get(k, 0), r["roofline_fraction"])
                 for r in v2]
        improved = [(k, b, n) for k, b, n in gains if n > b + 0.01]
        print(f"\n{len(improved)} cells improved by the final planner "
              f"(details in EXPERIMENTS.md §Perf)")
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(rows, f, indent=2)
    return rows


def _print_table(dryrun_dir: str, title: str) -> list[dict]:
    rows = _load_rows(dryrun_dir)
    print(f"\n== §Roofline: three-term table — {title} ==")
    hdr = (f"{'arch':22s}{'shape':12s}{'mesh':9s}{'layout':15s}"
           f"{'compute':>9s}{'memory':>9s}{'coll':>9s}{'dom':>6s}"
           f"{'frac':>7s}{'useful':>7s}{'mem(GB)':>8s}")
    print(hdr)
    for r in rows:
        print(f"{r['arch']:22s}{r['shape']:12s}{r['mesh']:9s}"
              f"{r['layout']:15s}"
              f"{r['compute_s']:9.3g}{r['memory_s']:9.3g}"
              f"{r['collective_s']:9.3g}{r['dominant'][:4]:>6s}"
              f"{r['roofline_fraction']:7.2%}{r['useful_ratio']:7.2f}"
              f"{r['peak_mem_gb']:8.1f}")
        print(f"roofline/{r['arch']}/{r['shape']}/{r['mesh']},"
              f"{max(r['compute_s'], r['memory_s'], r['collective_s']) * 1e6:.1f},"
              f"frac={r['roofline_fraction']:.3f};dom={r['dominant']}")
    return rows


if __name__ == "__main__":
    main()
