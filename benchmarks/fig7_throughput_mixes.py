"""Fig. 7 — throughput (inferences per 100 s) over 8 workload mixes:
Mix 1–4 pair two DNNs, Mix 5–8 combine three.  Paper: HiDP up to 150 %
higher (Mix-2), 56 % higher on average.

Plus the multi-tenant serving table behind those mixes: all 8 mixes
replayed through **one shared, persistent PlanCache** — every mix's
request stream resolves plans per-request from the same cache, so a
tenant warmed by an earlier mix serves later mixes with zero DP work.
Gated: per mix, cold frontier passes ≤ new tenants and cached throughput
≥ the per-request-planning throughput; across all mixes, exactly one DP
pass per distinct tenant.  A bounded cache (``LRUEviction``) is replayed
too, showing eviction churn instead of unbounded growth.
"""

from __future__ import annotations

import itertools

import numpy as np

from repro.core import HiDPPlanner, simulate
from repro.core.edge_models import EDGE_MODELS, MODEL_DELTA, paper_cluster
from repro.serving import LRUEviction, PlanCache

from .common import STRATS, emit

M = ("efficientnet_b0", "inceptionv3", "resnet152", "vgg19")
MIXES = {
    "mix1": (M[0], M[1]), "mix2": (M[0], M[3]), "mix3": (M[1], M[2]),
    "mix4": (M[2], M[3]), "mix5": (M[0], M[1], M[2]),
    "mix6": (M[0], M[1], M[3]), "mix7": (M[0], M[2], M[3]),
    "mix8": (M[1], M[2], M[3]),
}
HORIZON = 100.0


def _workload(mix: tuple[str, ...]) -> list[tuple]:
    """Saturating open-loop stream: round-robin requests of the mix,
    arrival spacing well under service time."""
    names = list(itertools.islice(itertools.cycle(mix), 400))
    return [(0.2 * i, EDGE_MODELS[n](), MODEL_DELTA[n])
            for i, n in enumerate(names)]


def throughput(strategy: str, mix: tuple[str, ...]) -> int:
    """Completions before HORIZON with per-request planning."""
    rep = simulate(paper_cluster(), strategy, _workload(mix))
    return rep.completed_by(HORIZON)


def shared_cache_table(plain: dict[str, dict[str, int]]) -> dict:
    """All 8 mixes through one shared multi-tenant PlanCache."""
    cluster = paper_cluster()
    cache = PlanCache(HiDPPlanner(), cluster)
    print("\n== multi-tenant serving: all mixes, one shared plan cache ==")
    print(f"{'mix':8s}{'tenants':>8}{'done':>6}{'plain':>7}{'cold':>6}"
          f"{'hits':>7}{'hit rate':>10}")
    out, ok = {}, True
    seen: set[str] = set()
    for mix, members in MIXES.items():
        new = [m for m in members if m not in seen]
        seen.update(members)
        h0, m0 = cache.hits, cache.misses
        rep = simulate(cluster, "hidp", _workload(members),
                       plan_cache=cache)
        done = rep.completed_by(HORIZON)
        cold, hits = cache.misses - m0, cache.hits - h0
        rate = hits / max(hits + cold, 1)
        print(f"{mix:8s}{len(members):8d}{done:6d}"
              f"{plain[mix]['hidp']:7d}{cold:6d}{hits:7d}{rate:10.3f}")
        emit(f"fig7/cache/{mix}", 1e8 / max(done, 1),
             f"completions={done};cold={cold};hits={hits}")
        # a tenant warmed by an earlier mix never re-plans; amortizing the
        # DP can only help throughput
        mix_ok = cold <= len(new) and done >= plain[mix]["hidp"]
        ok &= mix_ok
        out[mix] = {"completions": done, "cold": cold, "hits": hits,
                    "pass": mix_ok}
    ok &= cache.misses == len(M)        # one frontier pass per tenant, ever
    print(f"\n{'PASS' if ok else 'FAIL'}: {cache.misses} frontier passes "
          f"served {cache.hits + cache.misses} requests across "
          f"{len(MIXES)} mixes ({len(M)} tenants, hit rate "
          f"{cache.hit_rate():.4f})")

    # bounded variant: a 2-entry budget on 3-tenant mixes must evict and
    # re-plan instead of growing — correctness is unaffected
    bounded = PlanCache(HiDPPlanner(), cluster,
                        eviction=LRUEviction(max_entries=2))
    rep = simulate(cluster, "hidp", _workload(MIXES["mix5"]),
                   plan_cache=bounded)
    done_bounded = rep.completed_by(HORIZON)
    print(f"bounded (LRU, max_entries=2) on mix5: {done_bounded} done, "
          f"{bounded.evictions} evictions, {bounded.misses} re-plans, "
          f"{len(bounded)} entries resident ({bounded.nbytes()} bytes)")
    assert len(bounded) <= 2 and bounded.evictions > 0
    out["bounded_mix5"] = {"completions": done_bounded,
                           "evictions": bounded.evictions}
    out["pass"] = ok
    assert ok, "shared-cache multi-tenant gate failed"
    return out


def main() -> dict:
    out: dict[str, dict] = {}
    print("\n== Fig 7: inferences per 100 s over 8 mixes ==")
    print("mix".ljust(8) + "".join(f"{s:>11}" for s in STRATS))
    for mix, members in MIXES.items():
        out[mix] = {s: throughput(s, members) for s in STRATS}
        print(mix.ljust(8) + "".join(f"{out[mix][s]:11d}" for s in STRATS))
        for s in STRATS:
            emit(f"fig7/{mix}/{s}", 1e8 / max(out[mix][s], 1),
                 f"completions={out[mix][s]}")
    gains = [out[m]["hidp"] / max(max(out[m][s] for s in STRATS[1:]), 1) - 1
             for m in MIXES]
    avg_all = np.mean([out[m]["hidp"] / max(out[m][s], 1) - 1
                       for m in MIXES for s in STRATS[1:]]) * 100
    print(f"\nHiDP vs best-other per mix: up to {max(gains) * 100:.0f}% "
          f"higher; vs all others avg +{avg_all:.0f}% (paper: up to 150%, "
          f"avg 56%)")
    for m in MIXES:
        assert out[m]["hidp"] >= max(out[m][s] for s in STRATS[1:]), m
    out["shared_cache"] = shared_cache_table(out)
    return out


if __name__ == "__main__":
    main()
