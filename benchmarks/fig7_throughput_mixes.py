"""Fig. 7 — throughput (inferences per 100 s) over 8 workload mixes:
Mix 1–4 pair two DNNs, Mix 5–8 combine three.  Paper: HiDP up to 150 %
higher (Mix-2), 56 % higher on average."""

from __future__ import annotations

import itertools

import numpy as np

from repro.core import simulate
from repro.core.edge_models import EDGE_MODELS, MODEL_DELTA, paper_cluster

from .common import STRATS, emit

M = ("efficientnet_b0", "inceptionv3", "resnet152", "vgg19")
MIXES = {
    "mix1": (M[0], M[1]), "mix2": (M[0], M[3]), "mix3": (M[1], M[2]),
    "mix4": (M[2], M[3]), "mix5": (M[0], M[1], M[2]),
    "mix6": (M[0], M[1], M[3]), "mix7": (M[0], M[2], M[3]),
    "mix8": (M[1], M[2], M[3]),
}
HORIZON = 100.0


def throughput(strategy: str, mix: tuple[str, ...]) -> int:
    """Saturating open-loop stream: round-robin requests of the mix, arrival
    spacing well under service time, count completions before HORIZON."""
    names = list(itertools.islice(itertools.cycle(mix), 400))
    wl = [(0.2 * i, EDGE_MODELS[n](), MODEL_DELTA[n])
          for i, n in enumerate(names)]
    rep = simulate(paper_cluster(), strategy, wl)
    return rep.completed_by(HORIZON)


def main() -> dict:
    out: dict[str, dict[str, int]] = {}
    print("\n== Fig 7: inferences per 100 s over 8 mixes ==")
    print("mix".ljust(8) + "".join(f"{s:>11}" for s in STRATS))
    for mix, members in MIXES.items():
        out[mix] = {s: throughput(s, members) for s in STRATS}
        print(mix.ljust(8) + "".join(f"{out[mix][s]:11d}" for s in STRATS))
        for s in STRATS:
            emit(f"fig7/{mix}/{s}", 1e8 / max(out[mix][s], 1),
                 f"completions={out[mix][s]}")
    gains = [out[m]["hidp"] / max(max(out[m][s] for s in STRATS[1:]), 1) - 1
             for m in MIXES]
    avg_all = np.mean([out[m]["hidp"] / max(out[m][s], 1) - 1
                       for m in MIXES for s in STRATS[1:]]) * 100
    print(f"\nHiDP vs best-other per mix: up to {max(gains) * 100:.0f}% "
          f"higher; vs all others avg +{avg_all:.0f}% (paper: up to 150%, "
          f"avg 56%)")
    for m in MIXES:
        assert out[m]["hidp"] >= max(out[m][s] for s in STRATS[1:]), m
    return out


if __name__ == "__main__":
    main()
