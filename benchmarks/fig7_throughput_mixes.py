"""Fig. 7 — throughput (inferences per 100 s) over 8 workload mixes:
Mix 1–4 pair two DNNs, Mix 5–8 combine three.  Paper: HiDP up to 150 %
higher (Mix-2), 56 % higher on average.

Plus the multi-tenant serving table behind those mixes: all 8 mixes
replayed through **one shared, persistent PlanCache** — every mix's
request stream resolves plans per-request from the same cache, so a
tenant warmed by an earlier mix serves later mixes with zero DP work.
Gated: per mix, cold frontier passes ≤ new tenants and cached throughput
≥ the per-request-planning throughput; across all mixes, exactly one DP
pass per distinct tenant.  A bounded cache (``LRUEviction``) is replayed
too, showing eviction churn instead of unbounded growth.

Plus the two ``repro.telemetry`` acceptance gates (exit-code enforced):

* **overhead** — a *disabled* recorder threaded through the simulator
  must cost ≤ 2 % wall time against no recorder at all (``active()``
  normalizes it away, so the hot path is identical);
* **reconstruction** — a seeded churn run (crash + leave/join, SLOs,
  membership-keyed cache) recorded into a ``RunStore`` must let
  ``repro.telemetry.report.sim_aggregates`` rebuild the in-memory
  ``SimReport`` totals (retries, migrations, SLO violations, joules,
  cache hit/miss counts) EXACTLY from the event log.
"""

from __future__ import annotations

import itertools
import tempfile
import time

import numpy as np

from repro.core import HiDPPlanner, simulate
from repro.core.edge_models import EDGE_MODELS, MODEL_DELTA, paper_cluster
from repro.serving import LRUEviction, PlanCache

from .common import STRATS, emit

M = ("efficientnet_b0", "inceptionv3", "resnet152", "vgg19")
MIXES = {
    "mix1": (M[0], M[1]), "mix2": (M[0], M[3]), "mix3": (M[1], M[2]),
    "mix4": (M[2], M[3]), "mix5": (M[0], M[1], M[2]),
    "mix6": (M[0], M[1], M[3]), "mix7": (M[0], M[2], M[3]),
    "mix8": (M[1], M[2], M[3]),
}
HORIZON = 100.0


def _workload(mix: tuple[str, ...]) -> list[tuple]:
    """Closed 400-request replay: round-robin over the mix at fixed 0.2 s
    spacing (well under service time, so the backlog saturates the
    cluster).  This is the paper's fig7 protocol — a finite request list
    measured to completion.  True *open-loop* arrivals (unbounded streams,
    admission control, shedding) are fig9's job: ``repro.load``."""
    names = list(itertools.islice(itertools.cycle(mix), 400))
    return [(0.2 * i, EDGE_MODELS[n](), MODEL_DELTA[n])
            for i, n in enumerate(names)]


def throughput(strategy: str, mix: tuple[str, ...]) -> int:
    """Completions before HORIZON with per-request planning."""
    rep = simulate(paper_cluster(), strategy, _workload(mix))
    return rep.completed_by(HORIZON)


def shared_cache_table(plain: dict[str, dict[str, int]]) -> dict:
    """All 8 mixes through one shared multi-tenant PlanCache."""
    cluster = paper_cluster()
    cache = PlanCache(HiDPPlanner(), cluster)
    print("\n== multi-tenant serving: all mixes, one shared plan cache ==")
    print(f"{'mix':8s}{'tenants':>8}{'done':>6}{'plain':>7}{'cold':>6}"
          f"{'hits':>7}{'hit rate':>10}")
    out, ok = {}, True
    seen: set[str] = set()
    for mix, members in MIXES.items():
        new = [m for m in members if m not in seen]
        seen.update(members)
        h0, m0 = cache.hits, cache.misses
        rep = simulate(cluster, "hidp", _workload(members),
                       plan_cache=cache)
        done = rep.completed_by(HORIZON)
        cold, hits = cache.misses - m0, cache.hits - h0
        rate = hits / max(hits + cold, 1)
        print(f"{mix:8s}{len(members):8d}{done:6d}"
              f"{plain[mix]['hidp']:7d}{cold:6d}{hits:7d}{rate:10.3f}")
        emit(f"fig7/cache/{mix}", 1e8 / max(done, 1),
             f"completions={done};cold={cold};hits={hits}")
        # a tenant warmed by an earlier mix never re-plans; amortizing the
        # DP can only help throughput
        mix_ok = cold <= len(new) and done >= plain[mix]["hidp"]
        ok &= mix_ok
        out[mix] = {"completions": done, "cold": cold, "hits": hits,
                    "pass": mix_ok}
    ok &= cache.misses == len(M)        # one frontier pass per tenant, ever
    print(f"\n{'PASS' if ok else 'FAIL'}: {cache.misses} frontier passes "
          f"served {cache.hits + cache.misses} requests across "
          f"{len(MIXES)} mixes ({len(M)} tenants, hit rate "
          f"{cache.hit_rate():.4f})")

    # bounded variant: a 2-entry budget on 3-tenant mixes must evict and
    # re-plan instead of growing — correctness is unaffected
    bounded = PlanCache(HiDPPlanner(), cluster,
                        eviction=LRUEviction(max_entries=2))
    rep = simulate(cluster, "hidp", _workload(MIXES["mix5"]),
                   plan_cache=bounded)
    done_bounded = rep.completed_by(HORIZON)
    print(f"bounded (LRU, max_entries=2) on mix5: {done_bounded} done, "
          f"{bounded.evictions} evictions, {bounded.misses} re-plans, "
          f"{len(bounded)} entries resident ({bounded.nbytes()} bytes)")
    assert len(bounded) <= 2 and bounded.evictions > 0
    out["bounded_mix5"] = {"completions": done_bounded,
                           "evictions": bounded.evictions}
    out["pass"] = ok
    assert ok, "shared-cache multi-tenant gate failed"
    return out


def telemetry_overhead_gate(repeat: int = 5) -> dict:
    """A disabled recorder must be free: ``active()`` normalizes it to no
    recorder at construction, so both timings exercise the identical code
    path — the gate holds the min-of-N ratio to ≤ 1.02 (ISSUE gate)."""
    from repro.telemetry import TelemetryRecorder

    wl = _workload(MIXES["mix1"])[:120]
    cluster = paper_cluster()

    def bench(telemetry):
        t0 = time.perf_counter()
        simulate(cluster, "hidp", wl, telemetry=telemetry)
        return time.perf_counter() - t0

    off = TelemetryRecorder("overhead", enabled=False)
    bench(None)                                 # warm caches/JIT once
    base = disabled = float("inf")
    for i in range(repeat):
        # interleave the arms, alternating order each round, so ambient
        # machine load lands on both and cancels out of the min-of-N
        arms = [(True, off), (False, None)] if i % 2 \
            else [(False, None), (True, off)]
        for is_disabled, tel in arms:
            dt = bench(tel)
            if is_disabled:
                disabled = min(disabled, dt)
            else:
                base = min(base, dt)
    ratio = disabled / base
    print(f"\n== telemetry overhead (disabled recorder vs none) ==\n"
          f"no recorder {base * 1e3:8.1f} ms   disabled "
          f"{disabled * 1e3:8.1f} ms   ratio {ratio:.4f} (gate <= 1.02)")
    emit("fig7/telemetry/overhead", disabled * 1e6, f"ratio={ratio:.4f}")
    assert ratio <= 1.02, f"disabled-recorder overhead {ratio:.4f} > 1.02"
    return {"base_s": base, "disabled_s": disabled, "ratio": ratio}


def telemetry_reconstruction_gate() -> dict:
    """Record a seeded churn run (crash mid-request + leave/join, SLOs,
    membership-keyed cache) into a RunStore, then rebuild the SimReport
    aggregates from the log alone — every total must match EXACTLY."""
    from repro.core.simulator import EdgeSimulator, SimRequest
    from repro.fleet import ChurnTrace, FleetController
    from repro.telemetry import RunStore, TelemetryRecorder, sim_aggregates

    names = ("resnet152", "vgg19")
    dags = {n: EDGE_MODELS[n]() for n in names}
    cluster = paper_cluster()
    solo = simulate(cluster, "hidp",
                    [(0.0, dags[names[0]], MODEL_DELTA[names[0]])])
    slo = solo.records[0].latency * 1.2
    trace = ChurnTrace.scripted([(slo * 0.5, "tx2", "crash"),
                                 (6.0, "nano", "leave"),
                                 (12.0, "nano", "join"),
                                 (30.0, "tx2", "join")])

    with tempfile.TemporaryDirectory() as d:
        store = RunStore(d)
        rec = TelemetryRecorder(store.new_run("churn"), store=store)
        fleet = FleetController(cluster, trace, telemetry=rec)
        cache = PlanCache(HiDPPlanner(), cluster, membership_source=fleet,
                          telemetry=rec)
        sim = EdgeSimulator(cluster, "hidp", plan_cache=cache, fleet=fleet,
                            telemetry=rec)
        wl = [SimRequest(i, dags[names[i % 2]], 2.0 * i,
                         MODEL_DELTA[names[i % 2]], slo=slo)
              for i in range(10)]
        rep = sim.run(wl)
        rec.close(kind="fig7-reconstruction")
        agg = sim_aggregates(store, rec.run)

        expected = {
            "requests": len(rep.records),
            "total_retries": rep.total_retries(),
            "total_migrations": rep.total_migrations(),
            "slo_violations": rep.slo_violations(),
            "total_active_joules": sum(r.active_energy
                                       for r in rep.records),
            "cache_hits": cache.hits,
            "cache_misses": cache.misses,
        }
        got = {k: agg[k] for k in ("requests", "total_retries",
                                   "total_migrations", "slo_violations",
                                   "total_active_joules")}
        got["cache_hits"] = sum(agg["cache_hits_by_tenant"].values())
        got["cache_misses"] = sum(agg["cache_misses_by_tenant"].values())

        print("\n== telemetry reconstruction (event log vs SimReport) ==")
        ok = True
        for k in expected:
            match = got[k] == expected[k]
            ok &= match
            print(f"{k:22s} log={got[k]!r:>12} report={expected[k]!r:>12} "
                  f"{'ok' if match else 'MISMATCH'}")
        emit("fig7/telemetry/reconstruction", 0.0,
             f"events={agg['requests']};retries={got['total_retries']};"
             f"pass={ok}")
        assert ok, "telemetry log does not reconstruct SimReport aggregates"
        assert expected["total_retries"] >= 1, "churn run recorded no retry"
        return {"expected": expected, "reconstructed": got, "pass": ok}


def main() -> dict:
    out: dict[str, dict] = {}
    print("\n== Fig 7: inferences per 100 s over 8 mixes ==")
    print("mix".ljust(8) + "".join(f"{s:>11}" for s in STRATS))
    for mix, members in MIXES.items():
        out[mix] = {s: throughput(s, members) for s in STRATS}
        print(mix.ljust(8) + "".join(f"{out[mix][s]:11d}" for s in STRATS))
        for s in STRATS:
            emit(f"fig7/{mix}/{s}", 1e8 / max(out[mix][s], 1),
                 f"completions={out[mix][s]}")
    gains = [out[m]["hidp"] / max(max(out[m][s] for s in STRATS[1:]), 1) - 1
             for m in MIXES]
    avg_all = np.mean([out[m]["hidp"] / max(out[m][s], 1) - 1
                       for m in MIXES for s in STRATS[1:]]) * 100
    print(f"\nHiDP vs best-other per mix: up to {max(gains) * 100:.0f}% "
          f"higher; vs all others avg +{avg_all:.0f}% (paper: up to 150%, "
          f"avg 56%)")
    for m in MIXES:
        assert out[m]["hidp"] >= max(out[m][s] for s in STRATS[1:]), m
    out["shared_cache"] = shared_cache_table(out)
    out["telemetry_overhead"] = telemetry_overhead_gate()
    out["telemetry_reconstruction"] = telemetry_reconstruction_gate()
    return out


if __name__ == "__main__":
    main()
