"""Shared helpers for the benchmark suite.

Every benchmark prints ``name,us_per_call,derived`` CSV rows (per the harness
contract) plus a human-readable table, and returns its raw numbers so
``benchmarks/run.py`` can aggregate everything into bench_output.txt.

When ``benchmarks/run.py`` is launched with ``--telemetry-dir`` it installs a
:class:`repro.telemetry.TelemetryRecorder` as the module-level ``RECORDER``;
every :func:`emit` then also lands as a ``benchmark.metric`` gauge in the
run's event log, so the CSV surface and the durable log carry the same
numbers.
"""

from __future__ import annotations

import time
from typing import Callable

from repro.core import simulate
from repro.core.edge_models import EDGE_MODELS, MODEL_DELTA, paper_cluster

STRATS = ("hidp", "disnet", "omniboost", "modnn")
MODELS = tuple(EDGE_MODELS)

# Set by benchmarks/run.py when --telemetry-dir is given (a
# repro.telemetry.TelemetryRecorder); None keeps emit() print-only.
RECORDER = None

# Every emit() of the current process accumulates here (last write per
# name wins): ``{name: {"value", "unit", "direction"}}`` — the rows
# ``benchmarks/run.py --bench-json`` snapshots via
# ``repro.telemetry.regress``.  Unit "us" marks machine-dependent wall
# time (reported, never gated); "sim_us"/"ratio"/"count" mark
# deterministic domain quantities the regression diff gates on.
METRICS: dict[str, dict] = {}


def timed(fn: Callable, *args, repeat: int = 3) -> tuple[float, object]:
    best, out = float("inf"), None
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn(*args)
        best = min(best, time.perf_counter() - t0)
    return best * 1e6, out


def emit(name: str, us: float, derived: str = "", *, unit: str = "us",
         direction: str = "lower") -> None:
    print(f"{name},{us:.1f},{derived}")
    METRICS[name] = {"value": float(us), "unit": unit,
                     "direction": direction}
    if RECORDER is not None:
        RECORDER.gauge("benchmark.metric", us, metric=name, derived=derived)


def single_request_report(strategy: str, model: str):
    dag = EDGE_MODELS[model]()
    return simulate(paper_cluster(), strategy,
                    [(0.0, dag, MODEL_DELTA[model])])
