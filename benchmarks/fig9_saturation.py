"""Fig. 9 — open-loop saturation curves (the fig7/fig8 companion).

Fig7 replays a *closed* request list; fig8 replays it under churn.  Fig9
asks the open-loop question both dodge: what happens when arrivals keep
coming whether or not the cluster keeps up?  One seeded Poisson trace is
replayed at a ladder of offered-load factors through ``repro.load`` —
plan-priced service (the planner's own ``predicted_latency`` via the
membership-keyed ``PlanCache``), bounded queues, SLO-aware priorities,
WDRR fairness, and shedding.

Exit-code gates (each ``assert`` fails the CI step):

* **static sweep** (fig7 variant) — below the knee, throughput tracks
  offered load and nothing is turned away; above it, lane utilization
  pins near 1 (and never exceeds it — no scheduler outruns physics),
  throughput plateaus at or below the cluster's service capacity, the
  excess shows up as rejects/sheds, and every *served* request still
  meets its SLO (doomed-shedding), keeping p99 bounded;
* **churn composition** (fig8 variant) — an arrival trace composed with
  a ``FleetController`` churn trace re-prices service exactly once per
  tenant per membership epoch (``PlanCache.stats()``-verified), engages
  backpressure instead of deadlocking when capacity drops, and two
  seeded replays emit byte-identical canonical telemetry;
* **scale** — one seeded run pushes ≥ 10⁵ requests through the
  vectorized event loop with full per-decision telemetry, replays
  byte-identically, and the ``RunStore``-style counters reconstruct the
  run's own conservation terms from the event log alone.
"""

from __future__ import annotations

import time

from repro.core import HiDPPlanner
from repro.core.edge_models import EDGE_MODELS, MODEL_DELTA, paper_cluster
from repro.fleet import ChurnTrace, FleetController
from repro.load import (ArrivalTrace, FixedServiceModel, LoadConfig,
                        OpenLoopHarness, PlanServiceModel, TenantSpec,
                        saturation_sweep)
from repro.serving import PlanCache
from repro.telemetry import TelemetryRecorder

from .common import emit

TENANTS = ("resnet152", "vgg19")
FACTORS = (0.5, 1.0, 1.5, 2.0, 4.0, 8.0)
TARGET_RHO = 0.3          # per-tenant utilization at factor 1.0


def _plan_priced(telemetry=None, fleet=None):
    """Specs + service model priced by the planner's own predictions."""
    cluster = paper_cluster()
    cache = PlanCache(HiDPPlanner(), cluster, membership_source=fleet,
                      telemetry=telemetry)
    specs = {}
    for i, name in enumerate(TENANTS):
        specs[name] = TenantSpec(name, weight=2.0 if i == 0 else 1.0,
                                 dag=EDGE_MODELS[name](),
                                 delta=MODEL_DELTA[name])
    model = PlanServiceModel(cache, specs)
    svc = {n: model.service_time(n) for n in TENANTS}
    # SLO = 4x solo service; doomed-shedding then guarantees served
    # requests meet it
    specs = {n: TenantSpec(n, slo=4.0 * svc[n], weight=s.weight,
                           dag=s.dag, delta=s.delta)
             for n, s in specs.items()}
    model = PlanServiceModel(cache, specs)
    return specs, model, svc, cache


def static_sweep() -> dict:
    """The fig7 variant: one seeded trace, six offered-load levels, a
    static full cluster."""
    specs, model, svc, cache = _plan_priced()
    # rate_i = ρ/s_i puts each tenant at utilization ρ when factor=1
    rates = {n: TARGET_RHO / svc[n] for n in TENANTS}
    horizon = 400.0 * max(svc.values())
    trace = ArrivalTrace.poisson(rates, horizon, seed=42)
    cfg = LoadConfig(queue_capacity=64)
    capacity = 1.0 / min(svc.values())     # requests/s if only cheap work
    print("== fig9a: open-loop saturation, static cluster ==")
    print(f"service: " + ", ".join(f"{n}={svc[n]:.3f}s" for n in TENANTS)
          + f"; base offered {trace.offered_rate():.4f}/s over "
            f"{horizon:.0f}s ({len(trace)} arrivals)")
    print(f"{'factor':>7}{'offered/s':>11}{'thr/s':>9}{'util':>7}"
          f"{'p50':>8}{'p99':>8}{'loss':>7}{'viol':>6}")
    points = saturation_sweep(trace, specs, model, FACTORS, cfg)
    rows = []
    for p in points:
        r = p.report
        util = r.utilization()
        viol = r.slo_violations()
        print(f"{p.factor:7.2g}{p.offered:11.4f}{p.throughput:9.4f}"
              f"{util:7.3f}{p.p50:8.3f}{p.p99:8.3f}{p.loss_rate:7.3f}"
              f"{viol:6d}")
        emit(f"fig9/static/x{p.factor:g}", 1e6 * p.p99,
             f"offered={p.offered:.4f};thr={p.throughput:.4f};"
             f"util={util:.3f};loss={p.loss_rate:.3f};viol={viol}")
        rows.append(p.row() | {"utilization": util})
        # physics: no point may deliver more service than the lanes hold,
        # and served throughput is bounded by the cheapest-work capacity
        assert util <= 1.0 + 1e-9, f"utilization {util} > 1 at x{p.factor}"
        assert p.throughput <= capacity * 1.01
        # doomed-shedding: every *served* request meets its SLO, which
        # also bounds p99 of the served traffic below the loosest SLO
        assert viol == 0, f"{viol} served-SLO violations at x{p.factor}"
        assert p.p99 <= max(s.slo for s in specs.values()) + 1e-9
        assert r.conservation_ok()

    below, above = points[0], points[-1]
    # below the knee: the queue never fills (no rejects) and at most a
    # stray burst-tail shed; throughput tracks offered load
    assert below.report.rejected == 0
    assert below.loss_rate <= 0.02
    assert below.throughput >= 0.97 * below.offered
    # above it: lanes saturate and the excess is turned away, accounted
    assert above.report.utilization() > 0.9
    assert above.loss_rate > 0.2
    assert above.report.rejected + above.report.shed > 0
    # the plateau: doubling offered load past saturation barely moves
    # delivered service
    u4 = points[-2].report.utilization()
    u8 = above.report.utilization()
    assert abs(u8 - u4) < 0.05, f"no plateau: util {u4} -> {u8}"
    # the static membership is planned once per tenant, ever: every load
    # level re-reads the same cached frontier pass
    assert cache.stats()["misses"] == len(TENANTS), \
        "static sweep must run one frontier pass per tenant, total"
    print("PASS: saturation knee, plateau, and served-SLO gates hold")
    return {"rows": rows, "capacity": capacity}


def churn_composition() -> dict:
    """The fig8 variant: the same open-loop trace composed with a churn
    trace — membership epochs re-price service mid-run."""
    def one_run(tag):
        rec = TelemetryRecorder(tag)
        cluster = paper_cluster()
        # price the full cluster once (untelemetered) to scale the churn
        # timeline in service-time units
        _, _, svc, _ = _plan_priced()
        s = max(svc.values())
        churn = ChurnTrace.scripted([(1.0 * s, "tx2", "crash"),
                                     (3.0 * s, "nano", "leave"),
                                     (6.0 * s, "tx2", "join")])
        fleet = FleetController(cluster, churn, telemetry=rec)
        # rebuild the cache/model membership-keyed to this fleet
        specs, model, svc, cache = _plan_priced(telemetry=rec, fleet=fleet)
        # 4x the per-tenant target utilization: saturated by design
        rates = {n: 4.0 * TARGET_RHO / svc[n] for n in TENANTS}
        trace = ArrivalTrace.poisson(rates, 10.0 * s, seed=7)
        h = OpenLoopHarness(trace, specs, model,
                            LoadConfig(queue_capacity=8),
                            fleet=fleet, telemetry=rec)
        rep = h.run()
        return rep, h, model, cache, fleet, rec

    rep, h, model, cache, fleet, rec = one_run("fig9b-a")
    stats = cache.stats()
    print("\n== fig9b: saturation under churn (crash + leave + return) ==")
    print(f"{rep!r}; epochs={h.epochs_seen}; resolutions="
          f"{model.resolutions}; cache={{hits: {stats['hits']}, "
          f"misses: {stats['misses']}}}")
    emit("fig9/churn/run", 1e6 * rep.percentile(99),
         f"completed={rep.completed};rejected={rep.rejected};"
         f"shed={rep.shed};epochs={h.epochs_seen};"
         f"resolutions={model.resolutions}")
    assert rep.conservation_ok()
    assert rep.queued == rep.in_flight == 0, "drained — no deadlock"
    assert h.epochs_seen >= 2, "churn events must land mid-run"
    # one plan resolution per tenant per membership epoch, never more
    # (+ len(TENANTS) gets from the setup pricing pass, warm by then)
    assert model.resolutions == len(TENANTS) * (1 + h.epochs_seen)
    assert stats["hits"] + stats["misses"] \
        == model.resolutions + len(TENANTS)
    # frontier passes only for never-seen memberships: full, crash,
    # crash+leave, and the post-join mask (nano still out) = 4 distinct
    assert stats["misses"] == len(TENANTS) * 4
    # the degraded membership forces backpressure: losses while degraded
    assert rep.rejected + rep.shed > 0, "backpressure never engaged"

    rep2, h2, model2, cache2, fleet2, rec2 = one_run("fig9b-b")
    lines = [e.canonical() for e in rec.events]
    lines2 = [e.canonical() for e in rec2.events]
    assert lines and lines == lines2, \
        "churn-composed replays are not byte-identical"
    print(f"PASS: one pass/tenant/epoch, backpressure engaged, "
          f"{len(lines)} canonical events byte-identical across replays")
    return {"epochs": h.epochs_seen, "resolutions": model.resolutions,
            "events": len(lines)}


def scale_gate(n_target: int = 100_000) -> dict:
    """≥ 1e5 requests through the vectorized event loop, twice, with full
    per-decision telemetry — byte-identical, and the event log alone
    reconstructs the conservation terms."""
    rates = {"interactive": 1500.0, "batch": 800.0}
    horizon = (n_target * 1.05) / sum(rates.values())
    svc = FixedServiceModel({"interactive": 0.0004, "batch": 0.0006})
    specs = [TenantSpec("interactive", slo=0.2, weight=2.0),
             TenantSpec("batch", slo=0.5)]
    cfg = LoadConfig(queue_capacity=256, max_wait=0.25)

    def one_run(tag):
        rec = TelemetryRecorder(tag)
        trace = ArrivalTrace.poisson(rates, horizon, seed=1)
        t0 = time.perf_counter()
        rep = OpenLoopHarness(trace, specs, svc, cfg,
                              telemetry=rec).run()
        return rep, rec, time.perf_counter() - t0

    rep, rec, dt = one_run("fig9c-a")
    print(f"\n== fig9c: scale gate ==\n{rep.arrived} arrivals simulated in "
          f"{dt:.2f}s wall ({rep.arrived / dt:,.0f} req/s); {rep!r}")
    emit("fig9/scale/run", 1e6 * dt / max(rep.arrived, 1),
         f"arrived={rep.arrived};completed={rep.completed};"
         f"wall_s={dt:.2f}")
    assert rep.arrived >= n_target, \
        f"scale gate needs >= {n_target} requests, got {rep.arrived}"
    assert rep.conservation_ok()
    assert rep.utilization() <= 1.0 + 1e-9

    # the event log alone reconstructs the conservation story
    totals = {"load.admit": 0, "load.reject": 0, "load.shed": 0}
    for e in rec.events:
        if e.name in totals:
            totals[e.name] += 1
    assert totals["load.admit"] == rep.admitted
    assert totals["load.reject"] == rep.rejected
    assert totals["load.shed"] == rep.shed
    assert sum(totals.values()) == rep.arrived

    rep2, rec2, _ = one_run("fig9c-b")
    assert [e.canonical() for e in rec.events] \
        == [e.canonical() for e in rec2.events], \
        "1e5-request replays are not byte-identical"
    print(f"PASS: {rep.arrived} requests, {len(rec.events)} events "
          f"reconstruct conservation and replay byte-identically")
    return {"arrived": rep.arrived, "wall_s": dt,
            "events": len(rec.events)}


def main() -> dict:
    out = {"static": static_sweep(),
           "churn": churn_composition(),
           "scale": scale_gate()}
    print("\nfig9: all saturation gates PASS")
    return out


if __name__ == "__main__":
    main()
