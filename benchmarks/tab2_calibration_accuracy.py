"""Table II (ours): calibration accuracy and its effect on HiDP plans.

Scenario: the cluster's *true* per-processor rates diverge from the Table II
datasheet the analytic cost model plans with (Orin's GPU thermally throttled
to 35%, TX2's CPU contended to 40% — ≥2× divergence, the regime CoEdge-style
measurement-driven models target).  We compare:

* **prediction MAPE** of the analytic vs. the calibrated cost model against
  ground-truth per-block latencies, per (model × processor class);
* **plan quality**: simulated end-to-end latency on the true hardware when
  HiDP plans with each cost model.

Rows: ``tab2/<model>/{analytic|calibrated}`` with simulated latency in µs
and the MAPE in the derived column.
"""

from __future__ import annotations

import numpy as np

from repro.core import PlannerConfig, plan
from repro.core.edge_models import EDGE_MODELS, MODEL_DELTA, paper_cluster
from repro.core.simulator import EdgeSimulator, SimRequest
from repro.profiling import (CalibratedCostProvider, LearnedCostModel,
                             Profiler, SyntheticGroundTruth)

from .common import emit

DIVERGENCE = {("orin_nx", "gpu"): 0.35, ("tx2", "cpu"): 0.40}


def _mape_against_truth(cluster, dag, delta, gt, provider) -> float:
    """Per-block prediction error of a provider vs. the noise-free measured
    block latency (compute + memory traffic + launch overhead), over every
    processor in the cluster."""
    from repro.core.cost_model import processors_as_resources
    errs = []
    for node in cluster.nodes:
        for block in dag.blocks:
            for proc, res in zip(node.processors,
                                 processors_as_resources(node, delta,
                                                         block.kind)):
                truth = gt.block_seconds(node.name, proc.name, block, delta)
                pred = provider.at_delta(delta).block_time(res, block) \
                    if isinstance(provider, CalibratedCostProvider) \
                    else provider.compute_time(block.flops, res, block.kind)
                errs.append(abs(pred - truth) / max(truth, 1e-12))
    return float(np.mean(errs))


def _simulated_latency(cluster, dag, delta, gt, provider) -> float:
    fixed = plan(dag, cluster, PlannerConfig(delta=delta, provider=provider))
    sim = EdgeSimulator(cluster,
                        lambda *_a, **_k: fixed, ground_truth=gt)
    rep = sim.run([SimRequest(0, dag, 0.0, delta)])
    return rep.records[0].latency - fixed.planning_seconds


def main() -> dict:
    cluster = paper_cluster()
    dags = {k: f() for k, f in EDGE_MODELS.items()}
    gt = SyntheticGroundTruth(cluster, rate_scale=DIVERGENCE, noise=0.02)

    samples = Profiler(seed=0).profile_cluster(cluster, dags, MODEL_DELTA,
                                               ground_truth=gt)
    calibrated = CalibratedCostProvider(LearnedCostModel.fit(samples))
    from repro.core.cost_model import ANALYTIC

    print("\n== Table II: cost-model calibration ==")
    print(f"true rates diverge from datasheet: "
          f"{', '.join(f'{n}/{p}×{s}' for (n, p), s in DIVERGENCE.items())}")
    print(f"{'model':18s}{'MAPE analytic':>14s}{'MAPE calib':>12s}"
          f"{'sim lat analytic':>18s}{'sim lat calib':>15s}")
    out = {}
    for name, dag in dags.items():
        delta = MODEL_DELTA[name]
        mape_a = _mape_against_truth(cluster, dag, delta, gt, ANALYTIC)
        mape_c = _mape_against_truth(cluster, dag, delta, gt, calibrated)
        lat_a = _simulated_latency(cluster, dag, delta, gt, None)
        lat_c = _simulated_latency(cluster, dag, delta, gt, calibrated)
        print(f"{name:18s}{mape_a:>13.1%}{mape_c:>11.1%}"
              f"{lat_a * 1e3:>15.1f} ms{lat_c * 1e3:>12.1f} ms")
        emit(f"tab2/{name}/analytic", lat_a * 1e6, f"mape={mape_a:.3f}")
        emit(f"tab2/{name}/calibrated", lat_c * 1e6, f"mape={mape_c:.3f}")
        out[name] = {"mape_analytic": mape_a, "mape_calibrated": mape_c,
                     "lat_analytic_s": lat_a, "lat_calibrated_s": lat_c}
        assert mape_c < mape_a, f"calibration must reduce MAPE ({name})"
    wins = sum(v["lat_calibrated_s"] < v["lat_analytic_s"]
               for v in out.values())
    print(f"\ncalibrated plan faster on true hardware for {wins}/{len(out)} "
          f"models")
    return out


if __name__ == "__main__":
    main()
