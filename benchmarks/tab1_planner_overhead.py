"""Table I feature matrix + §IV-A planner overhead (paper: DP exploration
including both tiers ≈ 15 ms per request on average) + the plan-cache
amortization table: a cold frontier pass per (cluster, calibration, dag)
vs. warm cached lookups serving any objective — the CoEdge/DEFER-style
amortization that takes the ~15 ms DP off the serving hot path.  Two gates
(run as a script the exit code reports both, so CI can smoke them):

* warm cached lookups must be ≥ 100× faster than cold planning on every
  model;
* **restart-warm**: after persisting warm fronts to a
  ``CalibrationStore`` and constructing a fresh ``PlanCache`` from it,
  every tenant's first request must be served with **zero DP/frontier
  work**, and every selection off a loaded front must be bit-identical to
  the selection off the freshly built one.
"""

from __future__ import annotations

import sys
import tempfile
import time

import numpy as np

from repro.core import (HiDPPlanner, Objective, PlannerConfig, plan)
from repro.core.edge_models import EDGE_MODELS, MODEL_DELTA, paper_cluster
from repro.core.objective import METRICS
from repro.profiling import CalibrationStore
from repro.serving import PlanCache

from .common import emit


FEATURES = [
    # strategy, partition type, global, local, heterogeneous block size
    ("modnn", "data", True, False, False),
    ("omniboost", "model", True, False, True),
    ("disnet", "hybrid", True, False, True),
    ("hidp", "hybrid", True, True, True),
]


def main() -> dict:
    print("\n== Table I: strategy feature matrix ==")
    print(f"{'strategy':12s}{'type':8s}{'global':>8s}{'local':>7s}"
          f"{'het.block':>10s}")
    for s, t, g, l, h in FEATURES:
        print(f"{s:12s}{t:8s}{'✓' if g else '×':>8s}{'✓' if l else '×':>7s}"
              f"{'✓' if h else '×':>10s}")

    cluster = paper_cluster()
    times = []
    for name, fn in EDGE_MODELS.items():
        dag = fn()
        for _ in range(5):
            t0 = time.perf_counter()
            plan(dag, cluster, PlannerConfig(delta=MODEL_DELTA[name]))
            times.append(time.perf_counter() - t0)
    mean_ms = float(np.mean(times)) * 1e3
    p95_ms = float(np.percentile(times, 95)) * 1e3
    emit("planner/overhead", mean_ms * 1e3, f"p95_ms={p95_ms:.1f}")
    print(f"\nHiDP two-tier planning overhead: mean {mean_ms:.1f} ms, "
          f"p95 {p95_ms:.1f} ms (paper: ~15 ms)")

    cache_stats = plan_cache_table(cluster)
    restart_stats = restart_warm_table(cluster)
    return {"mean_ms": mean_ms, "p95_ms": p95_ms, "cache": cache_stats,
            "restart": restart_stats}


# --------------------------------------------------------------------------
# PlanCache amortization: cold frontier pass vs warm cached lookup
# --------------------------------------------------------------------------

WARM_LOOKUPS = 10       # per batch: METRICS cycled, all hits after the miss
WARM_BATCHES = 3        # best batch counts — robust to GC/scheduler jitter
SPEEDUP_TARGET = 100.0


def plan_cache_table(cluster) -> dict:
    cache = PlanCache(HiDPPlanner(PlannerConfig(
        objective=Objective("energy", radio_power=4.0))), cluster)
    print("\n== plan cache: cold frontier pass vs warm lookup ==")
    print(f"{'model':18s}{'cold ms':>9}{'warm us':>9}{'speedup':>10}"
          f"{'front':>7}{'hit rate':>10}")
    out, worst = {}, float("inf")
    for name, fn in EDGE_MODELS.items():
        dag = fn()
        delta = MODEL_DELTA[name]
        hits0, misses0 = cache.hits, cache.misses
        cold = cache.get(dag, "latency", delta=delta)   # the one DP pass
        warm_s = float("inf")
        for _ in range(WARM_BATCHES):
            t0 = time.perf_counter()
            for i in range(WARM_LOOKUPS):
                cache.get(dag, METRICS[i % len(METRICS)], delta=delta)
            warm_s = min(warm_s,
                         (time.perf_counter() - t0) / WARM_LOOKUPS)
        speedup = cold.planning_seconds / warm_s
        worst = min(worst, speedup)
        hit_rate = (cache.hits - hits0) / (cache.hits - hits0
                                           + cache.misses - misses0)
        front_n = len(cache.front(dag, delta=delta))
        print(f"{name:18s}{cold.planning_seconds * 1e3:9.1f}"
              f"{warm_s * 1e6:9.1f}{speedup:9.0f}x{front_n:7d}"
              f"{hit_rate:10.3f}")
        emit(f"tab1/cache/{name}", warm_s * 1e6,
             f"cold_ms={cold.planning_seconds * 1e3:.1f};"
             f"speedup={speedup:.0f};hit_rate={hit_rate:.3f}")
        out[name] = {"cold_s": cold.planning_seconds, "warm_s": warm_s,
                     "speedup": speedup, "hit_rate": hit_rate}
    # the deterministic half of the gate: exactly one DP pass per model,
    # everything else a hit — independent of wall-clock jitter
    ok = worst >= SPEEDUP_TARGET and cache.misses == len(EDGE_MODELS)
    print(f"\n{'PASS' if ok else 'FAIL'}: warm cached lookups are >= "
          f"{worst:.0f}x faster than cold frontier planning on every model "
          f"(target >= {SPEEDUP_TARGET:.0f}x); "
          f"overall hit rate {cache.hit_rate():.3f}, "
          f"{cache.misses} DP passes for "
          f"{cache.hits + cache.misses} plan requests "
          f"(expected {len(EDGE_MODELS)} passes)")
    out["min_speedup"] = worst
    out["hit_rate"] = cache.hit_rate()
    out["pass"] = ok
    return out


# --------------------------------------------------------------------------
# Restart-warm serving: persisted fronts skip the cold pass entirely
# --------------------------------------------------------------------------

def restart_warm_table(cluster) -> dict:
    """Warm a cache over every paper workload, persist its fronts next to
    the calibrations, construct a *fresh* ``PlanCache`` from the store
    (the restart), and serve every tenant × objective again.  Gated on:
    zero DP/frontier work after the restart, and bit-identical selections
    off the loaded fronts."""
    planner = HiDPPlanner(PlannerConfig(
        objective=Objective("energy", radio_power=4.0)))
    store = CalibrationStore(tempfile.mkdtemp(prefix="hidp_fronts_"))
    warm = PlanCache(planner, cluster)
    built = {}
    for name, fn in EDGE_MODELS.items():
        dag = fn()
        for metric in METRICS:
            built[(name, metric)] = warm.get(dag, metric,
                                             delta=MODEL_DELTA[name])
    persisted = warm.persist(store)

    fresh = PlanCache(planner, cluster, store=store)    # the restart
    print("\n== restart-warm: fresh PlanCache from CalibrationStore ==")
    print(f"{'model':18s}{'first-request us':>17}{'DP passes':>11}"
          f"{'identical':>11}")
    identical_all = True
    for name, fn in EDGE_MODELS.items():
        dag = fn()
        misses0 = fresh.misses
        t0 = time.perf_counter()
        served = {m: fresh.get(dag, m, delta=MODEL_DELTA[name])
                  for m in METRICS}
        first_us = (time.perf_counter() - t0) / len(METRICS) * 1e6
        identical = all(
            p.predicted_latency == built[(name, m)].predicted_latency
            and p.predicted_energy == built[(name, m)].predicted_energy
            and p.global_plan.partition ==
            built[(name, m)].global_plan.partition
            and p.local_plans == built[(name, m)].local_plans
            for m, p in served.items())
        identical_all &= identical
        dp = fresh.misses - misses0
        print(f"{name:18s}{first_us:17.1f}{dp:11d}"
              f"{'yes' if identical else 'NO':>11}")
        emit(f"tab1/restart/{name}", first_us,
             f"dp_passes={dp};identical={int(identical)}")
    ok = (fresh.misses == 0 and identical_all
          and fresh.loaded == persisted == len(EDGE_MODELS))
    print(f"\n{'PASS' if ok else 'FAIL'}: restart served every tenant with "
          f"{fresh.misses} DP passes ({fresh.loaded} fronts loaded warm, "
          f"{persisted} persisted); selections "
          f"{'bit-identical' if identical_all else 'DIVERGED'} vs the "
          f"freshly built fronts")
    return {"persisted": persisted, "loaded": fresh.loaded,
            "misses": fresh.misses, "identical": identical_all, "pass": ok}


if __name__ == "__main__":
    result = main()
    sys.exit(0 if result["cache"]["pass"] and result["restart"]["pass"]
             else 1)
