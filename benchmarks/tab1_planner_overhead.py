"""Table I feature matrix + §IV-A planner overhead (paper: DP exploration
including both tiers ≈ 15 ms per request on average) + the plan-cache
amortization table: a cold frontier pass per (cluster, calibration, dag)
vs. warm cached lookups serving any objective — the CoEdge/DEFER-style
amortization that takes the ~15 ms DP off the serving hot path.  The warm
path must be ≥ 100× faster than cold planning (gated; run as a script the
exit code reports it, so CI can smoke it)."""

from __future__ import annotations

import sys
import time

import numpy as np

from repro.core import (HiDPPlanner, Objective, PlannerConfig, plan)
from repro.core.edge_models import EDGE_MODELS, MODEL_DELTA, paper_cluster
from repro.core.objective import METRICS
from repro.serving import PlanCache

from .common import emit


FEATURES = [
    # strategy, partition type, global, local, heterogeneous block size
    ("modnn", "data", True, False, False),
    ("omniboost", "model", True, False, True),
    ("disnet", "hybrid", True, False, True),
    ("hidp", "hybrid", True, True, True),
]


def main() -> dict:
    print("\n== Table I: strategy feature matrix ==")
    print(f"{'strategy':12s}{'type':8s}{'global':>8s}{'local':>7s}"
          f"{'het.block':>10s}")
    for s, t, g, l, h in FEATURES:
        print(f"{s:12s}{t:8s}{'✓' if g else '×':>8s}{'✓' if l else '×':>7s}"
              f"{'✓' if h else '×':>10s}")

    cluster = paper_cluster()
    times = []
    for name, fn in EDGE_MODELS.items():
        dag = fn()
        for _ in range(5):
            t0 = time.perf_counter()
            plan(dag, cluster, PlannerConfig(delta=MODEL_DELTA[name]))
            times.append(time.perf_counter() - t0)
    mean_ms = float(np.mean(times)) * 1e3
    p95_ms = float(np.percentile(times, 95)) * 1e3
    emit("planner/overhead", mean_ms * 1e3, f"p95_ms={p95_ms:.1f}")
    print(f"\nHiDP two-tier planning overhead: mean {mean_ms:.1f} ms, "
          f"p95 {p95_ms:.1f} ms (paper: ~15 ms)")

    cache_stats = plan_cache_table(cluster)
    return {"mean_ms": mean_ms, "p95_ms": p95_ms, "cache": cache_stats}


# --------------------------------------------------------------------------
# PlanCache amortization: cold frontier pass vs warm cached lookup
# --------------------------------------------------------------------------

WARM_LOOKUPS = 10       # per batch: METRICS cycled, all hits after the miss
WARM_BATCHES = 3        # best batch counts — robust to GC/scheduler jitter
SPEEDUP_TARGET = 100.0


def plan_cache_table(cluster) -> dict:
    cache = PlanCache(HiDPPlanner(PlannerConfig(
        objective=Objective("energy", radio_power=4.0))), cluster)
    print("\n== plan cache: cold frontier pass vs warm lookup ==")
    print(f"{'model':18s}{'cold ms':>9}{'warm us':>9}{'speedup':>10}"
          f"{'front':>7}{'hit rate':>10}")
    out, worst = {}, float("inf")
    for name, fn in EDGE_MODELS.items():
        dag = fn()
        delta = MODEL_DELTA[name]
        hits0, misses0 = cache.hits, cache.misses
        cold = cache.get(dag, "latency", delta=delta)   # the one DP pass
        warm_s = float("inf")
        for _ in range(WARM_BATCHES):
            t0 = time.perf_counter()
            for i in range(WARM_LOOKUPS):
                cache.get(dag, METRICS[i % len(METRICS)], delta=delta)
            warm_s = min(warm_s,
                         (time.perf_counter() - t0) / WARM_LOOKUPS)
        speedup = cold.planning_seconds / warm_s
        worst = min(worst, speedup)
        hit_rate = (cache.hits - hits0) / (cache.hits - hits0
                                           + cache.misses - misses0)
        front_n = len(cache.front(dag, delta=delta))
        print(f"{name:18s}{cold.planning_seconds * 1e3:9.1f}"
              f"{warm_s * 1e6:9.1f}{speedup:9.0f}x{front_n:7d}"
              f"{hit_rate:10.3f}")
        emit(f"tab1/cache/{name}", warm_s * 1e6,
             f"cold_ms={cold.planning_seconds * 1e3:.1f};"
             f"speedup={speedup:.0f};hit_rate={hit_rate:.3f}")
        out[name] = {"cold_s": cold.planning_seconds, "warm_s": warm_s,
                     "speedup": speedup, "hit_rate": hit_rate}
    # the deterministic half of the gate: exactly one DP pass per model,
    # everything else a hit — independent of wall-clock jitter
    ok = worst >= SPEEDUP_TARGET and cache.misses == len(EDGE_MODELS)
    print(f"\n{'PASS' if ok else 'FAIL'}: warm cached lookups are >= "
          f"{worst:.0f}x faster than cold frontier planning on every model "
          f"(target >= {SPEEDUP_TARGET:.0f}x); "
          f"overall hit rate {cache.hit_rate():.3f}, "
          f"{cache.misses} DP passes for "
          f"{cache.hits + cache.misses} plan requests "
          f"(expected {len(EDGE_MODELS)} passes)")
    out["min_speedup"] = worst
    out["hit_rate"] = cache.hit_rate()
    out["pass"] = ok
    return out


if __name__ == "__main__":
    result = main()
    sys.exit(0 if result["cache"]["pass"] else 1)
