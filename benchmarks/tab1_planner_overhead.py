"""Table I feature matrix + §IV-A planner overhead (paper: DP exploration
including both tiers ≈ 15 ms per request on average) + the plan-cache
amortization table: a cold frontier pass per (cluster, calibration, dag)
vs. warm cached lookups serving any objective — the CoEdge/DEFER-style
amortization that takes the ~15 ms DP off the serving hot path.  Four
gates (run as a script the exit code reports all of them, so CI can smoke
them):

* warm cached lookups must be ≥ 100× faster than cold planning on every
  model;
* **restart-warm**: after persisting warm fronts to a
  ``CalibrationStore`` and constructing a fresh ``PlanCache`` from it,
  every tenant's first request must be served with **zero DP/frontier
  work**, and every selection off a loaded front must be bit-identical to
  the selection off the freshly built one;
* **vectorized engine**: the fast DP engine's cold frontier passes must be
  ≥ 10× faster in aggregate than the pure-Python reference over the paper
  workloads plus a layer-granular ResNet-152 (where the O(n²·k) inner
  loop dominates) — with **bit-identical** fronts on every workload;
* **epoch re-plan**: with speculative pre-warming wired to a
  ``FleetController``, a single-departure membership epoch must be served
  with **zero** demand frontier passes (counter-verified:
  ``prewarm_hits`` covers every tenant, ``misses`` unchanged), and the
  speculation sweep must reuse cached DP rows (``rows_reused > 0``) —
  the incremental re-planning that keeps per-epoch cost sublinear in
  cluster size.
"""

from __future__ import annotations

import dataclasses
import sys
import tempfile
import time

import numpy as np

from repro.core import (HiDPPlanner, Objective, PlannerConfig, plan)
from repro.core import dp_partitioner
from repro.core.cost_model import node_as_resource
from repro.core.dag import ModelDAG
from repro.core.dp_cache import reset_workspaces, workspace_for
from repro.core.edge_models import EDGE_MODELS, MODEL_DELTA, paper_cluster
from repro.core.objective import METRICS
from repro.profiling import CalibrationStore
from repro.serving import PlanCache, SpeculativePrewarmer

from . import common
from .common import emit


FEATURES = [
    # strategy, partition type, global, local, heterogeneous block size
    ("modnn", "data", True, False, False),
    ("omniboost", "model", True, False, True),
    ("disnet", "hybrid", True, False, True),
    ("hidp", "hybrid", True, True, True),
]


def main() -> dict:
    print("\n== Table I: strategy feature matrix ==")
    print(f"{'strategy':12s}{'type':8s}{'global':>8s}{'local':>7s}"
          f"{'het.block':>10s}")
    for s, t, g, l, h in FEATURES:
        print(f"{s:12s}{t:8s}{'✓' if g else '×':>8s}{'✓' if l else '×':>7s}"
              f"{'✓' if h else '×':>10s}")

    cluster = paper_cluster()
    times = []
    for name, fn in EDGE_MODELS.items():
        dag = fn()
        for _ in range(5):
            t0 = time.perf_counter()
            plan(dag, cluster, PlannerConfig(delta=MODEL_DELTA[name]))
            times.append(time.perf_counter() - t0)
    mean_ms = float(np.mean(times)) * 1e3
    p95_ms = float(np.percentile(times, 95)) * 1e3
    emit("planner/overhead", mean_ms * 1e3, f"p95_ms={p95_ms:.1f}")
    print(f"\nHiDP two-tier planning overhead: mean {mean_ms:.1f} ms, "
          f"p95 {p95_ms:.1f} ms (paper: ~15 ms)")

    cache_stats = plan_cache_table(cluster)
    restart_stats = restart_warm_table(cluster)
    fast_stats = fast_planner_table(cluster)
    replan_stats = epoch_replan_table(cluster)
    return {"mean_ms": mean_ms, "p95_ms": p95_ms, "cache": cache_stats,
            "restart": restart_stats, "fast": fast_stats,
            "replan": replan_stats}


# --------------------------------------------------------------------------
# PlanCache amortization: cold frontier pass vs warm cached lookup
# --------------------------------------------------------------------------

WARM_LOOKUPS = 10       # per batch: METRICS cycled, all hits after the miss
WARM_BATCHES = 3        # best batch counts — robust to GC/scheduler jitter
SPEEDUP_TARGET = 100.0


def plan_cache_table(cluster) -> dict:
    cache = PlanCache(HiDPPlanner(PlannerConfig(
        objective=Objective("energy", radio_power=4.0))), cluster,
        telemetry=common.RECORDER)
    print("\n== plan cache: cold frontier pass vs warm lookup ==")
    print(f"{'model':18s}{'cold ms':>9}{'warm us':>9}{'speedup':>10}"
          f"{'front':>7}{'hit rate':>10}")
    out, worst = {}, float("inf")
    for name, fn in EDGE_MODELS.items():
        dag = fn()
        delta = MODEL_DELTA[name]
        hits0, misses0 = cache.hits, cache.misses
        cold = cache.get(dag, "latency", delta=delta)   # the one DP pass
        warm_s = float("inf")
        for _ in range(WARM_BATCHES):
            t0 = time.perf_counter()
            for i in range(WARM_LOOKUPS):
                cache.get(dag, METRICS[i % len(METRICS)], delta=delta)
            warm_s = min(warm_s,
                         (time.perf_counter() - t0) / WARM_LOOKUPS)
        speedup = cold.planning_seconds / warm_s
        worst = min(worst, speedup)
        hit_rate = (cache.hits - hits0) / (cache.hits - hits0
                                           + cache.misses - misses0)
        front_n = len(cache.front(dag, delta=delta))
        print(f"{name:18s}{cold.planning_seconds * 1e3:9.1f}"
              f"{warm_s * 1e6:9.1f}{speedup:9.0f}x{front_n:7d}"
              f"{hit_rate:10.3f}")
        emit(f"tab1/cache/{name}", warm_s * 1e6,
             f"cold_ms={cold.planning_seconds * 1e3:.1f};"
             f"speedup={speedup:.0f};hit_rate={hit_rate:.3f}")
        out[name] = {"cold_s": cold.planning_seconds, "warm_s": warm_s,
                     "speedup": speedup, "hit_rate": hit_rate}
    # the deterministic half of the gate: exactly one DP pass per model,
    # everything else a hit — independent of wall-clock jitter
    ok = worst >= SPEEDUP_TARGET and cache.misses == len(EDGE_MODELS)
    print(f"\n{'PASS' if ok else 'FAIL'}: warm cached lookups are >= "
          f"{worst:.0f}x faster than cold frontier planning on every model "
          f"(target >= {SPEEDUP_TARGET:.0f}x); "
          f"overall hit rate {cache.hit_rate():.3f}, "
          f"{cache.misses} DP passes for "
          f"{cache.hits + cache.misses} plan requests "
          f"(expected {len(EDGE_MODELS)} passes)")
    out["min_speedup"] = worst
    out["hit_rate"] = cache.hit_rate()
    out["pass"] = ok
    return out


# --------------------------------------------------------------------------
# Restart-warm serving: persisted fronts skip the cold pass entirely
# --------------------------------------------------------------------------

def restart_warm_table(cluster) -> dict:
    """Warm a cache over every paper workload, persist its fronts next to
    the calibrations, construct a *fresh* ``PlanCache`` from the store
    (the restart), and serve every tenant × objective again.  Gated on:
    zero DP/frontier work after the restart, and bit-identical selections
    off the loaded fronts."""
    planner = HiDPPlanner(PlannerConfig(
        objective=Objective("energy", radio_power=4.0)))
    store = CalibrationStore(tempfile.mkdtemp(prefix="hidp_fronts_"))
    warm = PlanCache(planner, cluster)
    built = {}
    for name, fn in EDGE_MODELS.items():
        dag = fn()
        for metric in METRICS:
            built[(name, metric)] = warm.get(dag, metric,
                                             delta=MODEL_DELTA[name])
    persisted = warm.persist(store)

    fresh = PlanCache(planner, cluster, store=store)    # the restart
    print("\n== restart-warm: fresh PlanCache from CalibrationStore ==")
    print(f"{'model':18s}{'first-request us':>17}{'DP passes':>11}"
          f"{'identical':>11}")
    identical_all = True
    for name, fn in EDGE_MODELS.items():
        dag = fn()
        misses0 = fresh.misses
        t0 = time.perf_counter()
        served = {m: fresh.get(dag, m, delta=MODEL_DELTA[name])
                  for m in METRICS}
        first_us = (time.perf_counter() - t0) / len(METRICS) * 1e6
        identical = all(
            p.predicted_latency == built[(name, m)].predicted_latency
            and p.predicted_energy == built[(name, m)].predicted_energy
            and p.global_plan.partition ==
            built[(name, m)].global_plan.partition
            and p.local_plans == built[(name, m)].local_plans
            for m, p in served.items())
        identical_all &= identical
        dp = fresh.misses - misses0
        print(f"{name:18s}{first_us:17.1f}{dp:11d}"
              f"{'yes' if identical else 'NO':>11}")
        emit(f"tab1/restart/{name}", first_us,
             f"dp_passes={dp};identical={int(identical)}")
    ok = (fresh.misses == 0 and identical_all
          and fresh.loaded == persisted == len(EDGE_MODELS))
    print(f"\n{'PASS' if ok else 'FAIL'}: restart served every tenant with "
          f"{fresh.misses} DP passes ({fresh.loaded} fronts loaded warm, "
          f"{persisted} persisted); selections "
          f"{'bit-identical' if identical_all else 'DIVERGED'} vs the "
          f"freshly built fronts")
    return {"persisted": persisted, "loaded": fresh.loaded,
            "misses": fresh.misses, "identical": identical_all, "pass": ok}


# --------------------------------------------------------------------------
# Vectorized DP engine: fast vs reference, bit-identical and >= 10x
# --------------------------------------------------------------------------

FAST_SPEEDUP_TARGET = 10.0
FAST_REPEATS = 3


def layer_granular(dag: ModelDAG, splits: int = 3) -> ModelDAG:
    """A layer-granularity variant: each fused block split into ``splits``
    equal-FLOPs partition points (the regime the paper's per-layer DP
    actually runs in — n grows ~3×, and the O(n²·k) frontier inner loop
    dominates planning time)."""
    blocks = []
    for b in dag.blocks:
        for t in range(splits):
            blocks.append(dataclasses.replace(
                b, name=f"{b.name}.{t}", flops=b.flops / splits,
                param_bytes=b.param_bytes / splits,
                bytes_in=b.bytes_in if t == 0 else b.bytes_out))
    return ModelDAG(name=f"{dag.name}-layers", blocks=tuple(blocks),
                    input_bytes=dag.input_bytes,
                    output_bytes=dag.output_bytes)


def _front_snapshot(front) -> list[tuple]:
    return [(p.latency, p.energy, p.plan) for p in front]


def fast_planner_table(cluster) -> dict:
    """Cold (lat, energy)-frontier passes, reference vs vectorized engine,
    on the paper workloads plus layer-granular ResNet-152.  Gated on the
    aggregate speedup (≥ 10×) *and* bit-identical fronts everywhere —
    the fast engine is an optimization, never an approximation."""
    workloads = [(name, fn()) for name, fn in EDGE_MODELS.items()]
    workloads.append(("resnet152-layers",
                      layer_granular(EDGE_MODELS["resnet152"]())))
    deltas = dict(MODEL_DELTA)
    deltas["resnet152-layers"] = MODEL_DELTA["resnet152"]

    print("\n== vectorized DP engine: cold frontier pass, fast vs "
          "reference ==")
    print(f"{'workload':20s}{'blocks':>7}{'ref ms':>9}{'fast ms':>9}"
          f"{'speedup':>9}{'identical':>11}")
    out, ref_total, fast_total, identical_all = {}, 0.0, 0.0, True
    for name, dag in workloads:
        resources = [node_as_resource(n, deltas[name])
                     for n in cluster.nodes]
        with dp_partitioner.planner_engine("reference"):
            t0 = time.perf_counter()
            ref_front = dp_partitioner.partition_front(dag, resources)
            ref_s = time.perf_counter() - t0
        fast_s = float("inf")
        with dp_partitioner.planner_engine("fast"):
            for _ in range(FAST_REPEATS):
                reset_workspaces()               # genuinely cold each time
                t0 = time.perf_counter()
                fast_front = dp_partitioner.partition_front(dag, resources)
                fast_s = min(fast_s, time.perf_counter() - t0)
        identical = _front_snapshot(ref_front) == _front_snapshot(fast_front)
        identical_all &= identical
        ref_total += ref_s
        fast_total += fast_s
        speedup = ref_s / fast_s
        print(f"{name:20s}{len(dag.blocks):7d}{ref_s * 1e3:9.2f}"
              f"{fast_s * 1e3:9.2f}{speedup:8.1f}x"
              f"{'yes' if identical else 'NO':>11}")
        emit(f"tab1/fast/{name}", fast_s * 1e6,
             f"ref_ms={ref_s * 1e3:.2f};speedup={speedup:.1f};"
             f"identical={int(identical)}")
        out[name] = {"ref_s": ref_s, "fast_s": fast_s, "speedup": speedup,
                     "identical": identical}
    total_speedup = ref_total / fast_total
    ok = total_speedup >= FAST_SPEEDUP_TARGET and identical_all
    print(f"\n{'PASS' if ok else 'FAIL'}: vectorized engine is "
          f"{total_speedup:.1f}x faster in aggregate "
          f"(target >= {FAST_SPEEDUP_TARGET:.0f}x) with "
          f"{'bit-identical' if identical_all else 'DIVERGED'} fronts")
    emit("tab1/fast/speedup", total_speedup,
         f"target={FAST_SPEEDUP_TARGET:.0f};identical={int(identical_all)}")
    out["total_speedup"] = total_speedup
    out["identical"] = identical_all
    out["pass"] = ok
    return out


# --------------------------------------------------------------------------
# Epoch re-plan: speculative pre-warming serves departures with zero DP
# --------------------------------------------------------------------------

def epoch_replan_table(cluster) -> dict:
    """Serve membership epochs through a pre-warmed cache: a
    ``SpeculativePrewarmer`` builds fronts for every single-departure
    neighbour ahead of time, so the epoch that realizes one costs zero
    demand frontier passes.  Gated on the counters (``prewarm_hits``
    covers every tenant, ``misses`` stays flat) and on DP row reuse
    (``rows_reused > 0``) — the sweep re-solves only the rows the
    departed node participated in."""
    from repro.fleet import FleetController
    from repro.fleet.traces import ChurnEvent, ChurnTrace

    with dp_partitioner.planner_engine("fast"):
        reset_workspaces()
        names = [n.name for n in cluster.nodes]
        trace = ChurnTrace([
            ChurnEvent(time=10.0, node=names[2], kind="leave"),
            ChurnEvent(time=20.0, node=names[2], kind="join"),
            ChurnEvent(time=30.0, node=names[-1], kind="crash"),
        ])
        # threading the run's recorder means a --telemetry-dir invocation
        # captures the speculation economy itself: every plan.prewarm span,
        # every plan_cache.prewarm_hit/prewarm_miss counter, every epoch
        ctrl = FleetController(cluster, trace, telemetry=common.RECORDER)
        cache = PlanCache(HiDPPlanner(), cluster, membership_source=ctrl,
                          telemetry=common.RECORDER)
        pw = SpeculativePrewarmer(cache, ctrl)
        tenants = [(fn(), MODEL_DELTA[name])
                   for name, fn in EDGE_MODELS.items()]

        for dag, delta in tenants:               # demand: full membership
            cache.front(dag, delta=delta)
        cold_misses = cache.misses
        t0 = time.perf_counter()
        primed = pw.prime()                      # idle-time speculation
        prime_s = time.perf_counter() - t0
        ws = workspace_for(None)
        rows_reused = ws.rows_reused if ws is not None else 0

        print("\n== epoch re-plan: speculative pre-warming vs demand DP ==")
        print(f"{'epoch':28s}{'replan ms':>11}{'demand DP':>11}"
              f"{'prewarm hits':>14}")
        print(f"{'prime (idle, %d fronts)' % primed:28s}"
              f"{prime_s * 1e3:11.1f}{'-':>11}{'-':>14}")
        rows, epoch_ok = [], True
        for when, label in ((10.0, "leave " + names[2]),
                            (20.0, "return " + names[2]),
                            (30.0, "crash " + names[-1])):
            misses0, phits0 = cache.misses, cache.prewarm_hits
            t0 = time.perf_counter()
            ctrl.advance(when)                   # epoch hook re-speculates
            for dag, delta in tenants:
                cache.front(dag, delta=delta)
            dt = time.perf_counter() - t0
            demand = cache.misses - misses0
            phits = cache.prewarm_hits - phits0
            epoch_ok &= demand == 0
            print(f"{label:28s}{dt * 1e3:11.1f}{demand:11d}{phits:14d}")
            emit(f"tab1/replan/{label.split()[0]}", dt * 1e3 * 1e3,
                 f"demand_misses={demand};prewarm_hits={phits}")
            rows.append({"label": label, "seconds": dt,
                         "demand_misses": demand, "prewarm_hits": phits})

        s = cache.stats()
        ok = (epoch_ok and cache.misses == cold_misses
              and s["prewarm_hits"] >= len(tenants) and rows_reused > 0)
        print(f"\n{'PASS' if ok else 'FAIL'}: every epoch served with zero "
              f"demand frontier passes ({cache.misses} total for "
              f"{len(tenants)} tenants x {len(rows) + 1} memberships); "
              f"{s['prewarm_hits']} speculative fronts promoted, "
              f"{rows_reused} DP rows reused across the sweep")
        per_epoch_ms = float(np.mean([r["seconds"] for r in rows])) * 1e3
        emit("tab1/replan/epoch_cost", per_epoch_ms * 1e3,
             f"demand_misses={cache.misses - cold_misses};"
             f"prewarm_hits={s['prewarm_hits']};rows_reused={rows_reused}")
        return {"epochs": rows, "prime_s": prime_s, "primed": primed,
                "per_epoch_ms": per_epoch_ms, "rows_reused": rows_reused,
                "demand_misses": cache.misses - cold_misses,
                "prewarm_hits": s["prewarm_hits"], "pass": ok}


if __name__ == "__main__":
    result = main()
    sys.exit(0 if (result["cache"]["pass"] and result["restart"]["pass"]
                   and result["fast"]["pass"] and result["replan"]["pass"])
             else 1)
