"""Table I feature matrix + §IV-A planner overhead (paper: DP exploration
including both tiers ≈ 15 ms per request on average)."""

from __future__ import annotations

import time

import numpy as np

from repro.core import PlannerConfig, plan
from repro.core.edge_models import EDGE_MODELS, MODEL_DELTA, paper_cluster

from .common import emit


FEATURES = [
    # strategy, partition type, global, local, heterogeneous block size
    ("modnn", "data", True, False, False),
    ("omniboost", "model", True, False, True),
    ("disnet", "hybrid", True, False, True),
    ("hidp", "hybrid", True, True, True),
]


def main() -> dict:
    print("\n== Table I: strategy feature matrix ==")
    print(f"{'strategy':12s}{'type':8s}{'global':>8s}{'local':>7s}"
          f"{'het.block':>10s}")
    for s, t, g, l, h in FEATURES:
        print(f"{s:12s}{t:8s}{'✓' if g else '×':>8s}{'✓' if l else '×':>7s}"
              f"{'✓' if h else '×':>10s}")

    cluster = paper_cluster()
    times = []
    for name, fn in EDGE_MODELS.items():
        dag = fn()
        for _ in range(5):
            t0 = time.perf_counter()
            plan(dag, cluster, PlannerConfig(delta=MODEL_DELTA[name]))
            times.append(time.perf_counter() - t0)
    mean_ms = float(np.mean(times)) * 1e3
    p95_ms = float(np.percentile(times, 95)) * 1e3
    emit("planner/overhead", mean_ms * 1e3, f"p95_ms={p95_ms:.1f}")
    print(f"\nHiDP two-tier planning overhead: mean {mean_ms:.1f} ms, "
          f"p95 {p95_ms:.1f} ms (paper: ~15 ms)")
    return {"mean_ms": mean_ms, "p95_ms": p95_ms}


if __name__ == "__main__":
    main()
