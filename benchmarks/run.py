"""Benchmark entry point: one function per paper table/figure + the TPU
roofline.  Prints ``name,us_per_call,derived`` CSV rows interleaved with
human-readable tables.

    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run fig5 fig7  # subset

With ``--telemetry-dir DIR`` the whole run records into a
``repro.telemetry.RunStore`` under DIR: every ``emit()`` CSV row doubles
as a ``benchmark.metric`` gauge, every suite gets a ``benchmark.suite``
wall-clock span, and the run closes with a manifest plus a rendered
``repro.telemetry.report`` summary.  A telemetry run that records no
events exits nonzero — the CI smoke gates on that.

With ``--bench-json PATH`` the run's emitted metrics are written as a
``repro.telemetry.regress`` snapshot (the ``BENCH_<n>.json`` series);
CI diffs its snapshot against the committed baseline and fails on >25 %
drift in any gated (non-wall) metric.
"""

from __future__ import annotations

import argparse
import sys
import time


def main(argv: list[str] | None = None) -> int:
    from . import (common, fig1_partition_sweep, fig5_latency_energy,
                   fig6_gflops_timeline, fig7_throughput_mixes,
                   fig8_node_scaling, fig9_saturation, roofline,
                   tab1_planner_overhead, tab2_calibration_accuracy)

    suites = {
        "fig1": fig1_partition_sweep.main,
        "fig5": fig5_latency_energy.main,
        "fig6": fig6_gflops_timeline.main,
        "fig7": fig7_throughput_mixes.main,
        "fig8": fig8_node_scaling.main,
        "fig9": fig9_saturation.main,
        "tab1": tab1_planner_overhead.main,
        "tab2": tab2_calibration_accuracy.main,
        "roofline": roofline.main,
    }
    parser = argparse.ArgumentParser(
        prog="benchmarks.run", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    parser.add_argument("suites", nargs="*", choices=[[], *suites],
                        metavar="suite",
                        help=f"suites to run (default: all) — "
                             f"{', '.join(suites)}")
    parser.add_argument("--telemetry-dir", default=None, metavar="DIR",
                        help="record the run into a repro.telemetry "
                             "RunStore under DIR and print its report")
    parser.add_argument("--bench-json", default=None, metavar="PATH",
                        help="write a repro.telemetry.regress metric "
                             "snapshot (e.g. BENCH_1.json) after the "
                             "run — the file CI diffs against the "
                             "committed baseline")
    args = parser.parse_args(argv)
    picks = args.suites or list(suites)

    recorder = store = None
    if args.telemetry_dir:
        from repro.telemetry import RunStore, TelemetryRecorder
        from repro.telemetry.report import generate
        store = RunStore(args.telemetry_dir)
        recorder = TelemetryRecorder(store.new_run("bench"), store=store)
        common.RECORDER = recorder
        print(f"telemetry: recording run {recorder.run} under {store.root}")

    t0 = time.time()
    for name in picks:
        print(f"\n{'=' * 72}\n# {name}\n{'=' * 72}")
        if recorder is not None:
            with recorder.timed("benchmark.suite", suite=name):
                suites[name]()
        else:
            suites[name]()
    print(f"\nall benchmarks done in {time.time() - t0:.1f}s")

    if recorder is not None:
        common.RECORDER = None
        recorder.close(suites=",".join(picks))
        try:
            print(f"\n{generate(store, recorder.run)}")
        except ValueError as e:
            print(f"telemetry report failed: {e}", file=sys.stderr)
            return 1
    if args.bench_json:
        from repro.telemetry.regress import write_snapshot
        if not common.METRICS:
            print("bench-json: the run emitted no metrics — nothing to "
                  "snapshot", file=sys.stderr)
            return 1
        path = write_snapshot(args.bench_json, common.METRICS, picks)
        print(f"bench snapshot: {len(common.METRICS)} metrics -> {path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
