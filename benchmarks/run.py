"""Benchmark entry point: one function per paper table/figure + the TPU
roofline.  Prints ``name,us_per_call,derived`` CSV rows interleaved with
human-readable tables.

    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run fig5 fig7  # subset
"""

from __future__ import annotations

import sys
import time


def main() -> None:
    from . import (fig1_partition_sweep, fig5_latency_energy,
                   fig6_gflops_timeline, fig7_throughput_mixes,
                   fig8_node_scaling, roofline, tab1_planner_overhead,
                   tab2_calibration_accuracy)

    suites = {
        "fig1": fig1_partition_sweep.main,
        "fig5": fig5_latency_energy.main,
        "fig6": fig6_gflops_timeline.main,
        "fig7": fig7_throughput_mixes.main,
        "fig8": fig8_node_scaling.main,
        "tab1": tab1_planner_overhead.main,
        "tab2": tab2_calibration_accuracy.main,
        "roofline": roofline.main,
    }
    picks = sys.argv[1:] or list(suites)
    t0 = time.time()
    for name in picks:
        print(f"\n{'=' * 72}\n# {name}\n{'=' * 72}")
        suites[name]()
    print(f"\nall benchmarks done in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
