"""repro.telemetry — the observability contracts (docs/observability.md):

* typed events with kind validation; wall-clock facts confined to the
  designated ``WALL_FIELDS`` and stripped by the canonical projection;
* a disabled recorder normalizes to no recorder at all (``active()``);
* two seeded churn runs produce **byte-identical** canonical event logs;
* the ``RunStore`` round-trips across a process restart, filters, and
  window-aggregates;
* the event log is a *sufficient statistic*: ``sim_aggregates`` rebuilds
  the in-memory ``SimReport`` totals exactly (the ISSUE acceptance gate);
* drift, kernel-profiling, and the real-hardware calibration loop all
  emit; the report CLI is exit-code gated.
"""

import json
import subprocess
import sys

import pytest

from repro.core import (EdgeSimulator, HiDPPlanner, Objective,
                        PlannerConfig, SimRequest, simulate)
from repro.core.edge_models import EDGE_MODELS, MODEL_DELTA, paper_cluster
from repro.fleet import ChurnTrace, FleetController
from repro.serving import PlanCache
from repro.telemetry import (KINDS, WALL_FIELDS, RunStore, TelemetryEvent,
                             TelemetryRecorder, active, sim_aggregates)
from repro.telemetry.report import generate, percentile, run_summary


# --------------------------------------------------------------------------
# events + recorder
# --------------------------------------------------------------------------

def test_event_schema_and_canonical_projection():
    e = TelemetryEvent(seq=3, kind="span", name="sim.request", value=1.5,
                       t=2.0, tenant="vgg19", epoch=1,
                       attrs={"retries": 1}, wall=123.4, wall_s=0.01)
    d = json.loads(e.to_json())
    assert d["wall"] == 123.4 and d["wall_s"] == 0.01
    c = json.loads(e.canonical())
    assert not any(f in c for f in WALL_FIELDS)
    # round-trip through JSON is lossless
    assert TelemetryEvent.from_json(e.to_json()) == e
    with pytest.raises(ValueError, match="unknown event kind"):
        TelemetryEvent(seq=0, kind="metric", name="x", value=1.0)
    assert set(KINDS) == {"span", "counter", "gauge"}


def test_recorder_seq_clock_and_counts():
    rec = TelemetryRecorder("r")
    rec.counter("a.hit", tenant="t1")
    rec.advance(5.0)
    rec.gauge("b.level", 3.0)
    rec.advance(2.0)                       # clock never goes backward
    rec.span("c.req", 1.25, t=4.0, epoch=2)
    assert [e.seq for e in rec.events] == [0, 1, 2]
    assert rec.events[0].t == 0.0 and rec.events[1].t == 5.0
    assert rec.clock == 5.0
    assert rec.events[2].value == 1.25 and rec.events[2].epoch == 2
    with rec.timed("d.pass", tenant="t1"):
        pass
    timed = rec.events[-1]
    assert timed.kind == "span" and timed.value == 0.0
    assert timed.wall_s is not None and timed.wall_s >= 0.0


def test_disabled_recorder_normalizes_away_and_emits_nothing():
    off = TelemetryRecorder("off", enabled=False)
    assert active(off) is None and active(None) is None
    assert active(TelemetryRecorder("on")) is not None
    off.counter("x")
    off.gauge("y", 1.0)
    assert off.events == []
    # instrumented classes accept a disabled recorder and drop it
    sim = EdgeSimulator(paper_cluster(), "hidp", telemetry=off)
    assert sim.telemetry is None


def test_recorder_flush_every_and_close(tmp_path):
    store = RunStore(tmp_path)
    rec = TelemetryRecorder("run-0001", store=store, flush_every=2)
    rec.counter("a")
    assert not store.events_path("run-0001").is_file()   # buffer below limit
    rec.counter("b")                                     # triggers flush
    assert len(store.events(rec.run)) == 2
    rec.gauge("c", 1.0)
    rec.close(extra="meta")
    assert len(store.events(rec.run)) == 3
    man = store.manifest(rec.run)
    assert man["events"] == 3 and man["extra"] == "meta"
    assert man["counts"] == {"span": 0, "counter": 2, "gauge": 1}
    with pytest.raises(ValueError):
        TelemetryRecorder("x", flush_every=0)
    with pytest.raises(ValueError):
        TelemetryRecorder("x", flush_every=2)            # no store to flush to


# --------------------------------------------------------------------------
# run store
# --------------------------------------------------------------------------

def _recorded_churn_run(root, seed_trace=None, planning_time="wall"):
    """One seeded churn run (crash + leave/join, SLOs, membership-keyed
    cache) recorded into a fresh run under ``root``.  Determinism tests
    pass ``planning_time=0.0`` — the documented seeded-replay mode that
    keeps wall-clock DP overhead out of simulated time."""
    cluster = paper_cluster()
    dag, delta = EDGE_MODELS["resnet152"](), MODEL_DELTA["resnet152"]
    trace = seed_trace or ChurnTrace.scripted([
        (0.35, "tx2", "crash"), (4.0, "nano", "leave"),
        (8.0, "tx2", "join"), (8.0, "nano", "join")])
    store = RunStore(root)
    rec = TelemetryRecorder(store.new_run("churn"), store=store)
    fleet = FleetController(cluster, trace, telemetry=rec)
    cache = PlanCache(HiDPPlanner(PlannerConfig(
        objective=Objective("energy", radio_power=4.0))), cluster,
        membership_source=fleet, telemetry=rec)
    sim = EdgeSimulator(cluster, "hidp", plan_cache=cache, fleet=fleet,
                        telemetry=rec, planning_time=planning_time)
    rep = sim.run([SimRequest(i, dag, 2.5 * i, delta, slo=2.0)
                   for i in range(5)])
    rec.close()
    return store, rec, rep, cache, fleet


def test_run_store_new_run_numbering_and_latest(tmp_path):
    store = RunStore(tmp_path)
    a, b = store.new_run("x"), store.new_run("x")
    assert (a, b) == ("x-0001", "x-0002")
    store.append(a, [TelemetryEvent(0, "counter", "n", 1.0)])
    store.write_manifest(b, {})
    assert store.runs() == [a, b]
    assert store.latest() == b                 # manifest created_unix wins
    assert RunStore(tmp_path / "empty").latest() is None


def test_run_store_restart_round_trip(tmp_path):
    store, rec, rep, cache, fleet = _recorded_churn_run(tmp_path)
    reopened = RunStore(tmp_path)              # a fresh process would do this
    assert reopened.runs() == store.runs()
    assert [e.to_json() for e in reopened.events(rec.run)] == \
        [e.to_json() for e in store.events(rec.run)]
    assert reopened.canonical_lines(rec.run) == store.canonical_lines(rec.run)
    assert reopened.manifest(rec.run)["events"] == len(rec.events)


def test_run_store_query_filters(tmp_path):
    store, rec, rep, cache, fleet = _recorded_churn_run(tmp_path)
    run = rec.run
    evs = store.events(run)
    assert [e.seq for e in evs] == sorted(e.seq for e in evs)
    # kind + name (exact and prefix-*)
    assert all(e.kind == "span" for e in store.events(run, kind="span"))
    hits = store.events(run, name="plan_cache.hit")
    assert len(hits) == cache.hits
    assert len(store.events(run, name="plan_cache.*")) >= \
        cache.hits + cache.misses
    # tenant + epoch + time range
    assert all(e.tenant == "resnet152"
               for e in store.events(run, tenant="resnet152"))
    ep1 = store.events(run, epoch=1)
    assert ep1 and all(e.epoch == 1 for e in ep1)
    windowed = store.events(run, t_range=(0.0, 2.5))
    assert windowed and all(0.0 <= e.t < 2.5 for e in windowed)


def test_run_store_windowed_aggregation(tmp_path):
    store = RunStore(tmp_path)
    rec = TelemetryRecorder(store.new_run("agg"), store=store)
    for i, v in enumerate((1.0, 2.0, 3.0, 4.0)):
        rec.counter("x", v, t=float(i))        # t = 0, 1, 2, 3
    rec.close()
    assert store.aggregate(rec.run, "x", window=2.0) == \
        [(0.0, 3.0), (2.0, 7.0)]
    assert store.aggregate(rec.run, "x", window=2.0, reduce="count") == \
        [(0.0, 2.0), (2.0, 2.0)]
    assert store.aggregate(rec.run, "x", window=4.0, reduce="mean") == \
        [(0.0, 2.5)]
    assert store.aggregate(rec.run, "x", window=1.0, reduce="max")[-1] == \
        (3.0, 4.0)
    with pytest.raises(ValueError, match="window"):
        store.aggregate(rec.run, "x", window=0.0)
    with pytest.raises(ValueError, match="reducer"):
        store.aggregate(rec.run, "x", reduce="median")


# --------------------------------------------------------------------------
# determinism — the headline contract
# --------------------------------------------------------------------------

def test_two_seeded_runs_are_byte_identical_modulo_wall(tmp_path):
    """Seeded replay determinism: the canonical (wall-stripped) event logs
    of two identical churn runs are byte-identical.  ``planning_time=0.0``
    is the replay mode — with wall-clock DP overhead charged into domain
    time (the default), completion times inherit timer jitter."""
    s0, r0, *_ = _recorded_churn_run(tmp_path / "a", planning_time=0.0)
    s1, r1, *_ = _recorded_churn_run(tmp_path / "b", planning_time=0.0)
    l0, l1 = s0.canonical_lines(r0.run), s1.canonical_lines(r1.run)
    assert l0 and l0 == l1
    # and the raw logs differ ONLY in the designated wall fields
    for e0, e1 in zip(s0.events(r0.run), s1.events(r1.run)):
        d0, d1 = e0.to_dict(), e1.to_dict()
        for f in WALL_FIELDS:
            d0.pop(f, None), d1.pop(f, None)
        assert d0 == d1


def test_poisson_churn_run_deterministic_under_seed(tmp_path):
    names = [n.name for n in paper_cluster().nodes]
    t0 = ChurnTrace.poisson(names, rate=0.3, horizon=20.0, seed=11)
    t1 = ChurnTrace.poisson(names, rate=0.3, horizon=20.0, seed=11)
    s0, r0, *_ = _recorded_churn_run(tmp_path / "a", seed_trace=t0,
                                     planning_time=0.0)
    s1, r1, *_ = _recorded_churn_run(tmp_path / "b", seed_trace=t1,
                                     planning_time=0.0)
    assert s0.canonical_lines(r0.run) == s1.canonical_lines(r1.run)


# --------------------------------------------------------------------------
# reconstruction — the ISSUE acceptance gate
# --------------------------------------------------------------------------

def test_log_reconstructs_sim_report_aggregates_exactly(tmp_path):
    """The durable event log is a sufficient statistic for the run: the
    report's ``sim_aggregates`` equals the in-memory ``SimReport`` totals
    — retries, migrations, SLO violations, joules, per-tenant cache
    hits/misses — exactly, not approximately."""
    store, rec, rep, cache, fleet = _recorded_churn_run(tmp_path)
    agg = sim_aggregates(store, rec.run)
    assert agg["requests"] == len(rep.records) == 5
    assert agg["total_retries"] == rep.total_retries() == 1
    assert agg["total_migrations"] == rep.total_migrations()
    assert agg["slo_violations"] == rep.slo_violations()
    assert agg["total_active_joules"] == \
        sum(r.active_energy for r in rep.records)
    assert sum(agg["cache_hits_by_tenant"].values()) == cache.hits
    assert sum(agg["cache_misses_by_tenant"].values()) == cache.misses
    assert agg["cache_hits_by_tenant"] == {"resnet152": cache.hits}
    # per-request latencies reconstruct too
    assert agg["latencies"] == [r.latency for r in rep.records]
    # the crash's retry lands in the epoch that crash created
    assert sum(agg["retries_by_epoch"].values()) == rep.total_retries()
    # fleet history: one membership gauge per epoch, stamped at epoch time
    gauges = store.events(rec.run, kind="gauge", name="fleet.membership")
    assert [(e.epoch, e.t) for e in gauges] == \
        [(ep.epoch, ep.time) for ep in fleet.epochs[1:]]
    # frontier passes carry wall timings; their count equals cache misses
    passes = store.events(rec.run, kind="span", name="plan.frontier_pass")
    assert len(passes) == cache.misses
    assert all(p.wall_s is not None and p.wall_s > 0 for p in passes)


def test_run_summary_and_report_render(tmp_path):
    store, rec, rep, cache, fleet = _recorded_churn_run(tmp_path)
    summary = run_summary(store, rec.run)
    lats = sorted(r.latency for r in rep.records)
    assert summary["p50_latency_s"] == percentile([r.latency
                                                   for r in rep.records], 50)
    assert lats[0] <= summary["p50_latency_s"] <= lats[-1]
    assert summary["cache_hit_rate"] == pytest.approx(
        cache.hits / (cache.hits + cache.misses))
    assert summary["epochs"] == fleet.epoch
    text = generate(store, rec.run)
    assert f"run {rec.run}" in text and "tenant resnet152" in text


def test_report_cli_exit_codes(tmp_path):
    store, rec, *_ = _recorded_churn_run(tmp_path / "full")
    env_root = str(tmp_path / "full")
    ok = subprocess.run(
        [sys.executable, "-m", "repro.telemetry.report", env_root],
        capture_output=True, text=True)
    assert ok.returncode == 0 and f"run {rec.run}" in ok.stdout
    empty = subprocess.run(
        [sys.executable, "-m", "repro.telemetry.report",
         str(tmp_path / "nothing")],
        capture_output=True, text=True)
    assert empty.returncode == 1 and "failed" in empty.stderr
    with pytest.raises(ValueError):
        generate(RunStore(tmp_path / "still-nothing"))


# --------------------------------------------------------------------------
# the other instrumented layers
# --------------------------------------------------------------------------

def test_feedback_drift_emits_gauge():
    from repro.profiling import FeedbackLoop, LearnedCostModel, Sample

    model = LearnedCostModel.fit(
        [Sample("n/gpu", "conv", w, 0.0, w / 1e9)
         for w in (1e8, 2e8, 4e8, 8e8)])
    rec = TelemetryRecorder("drift")
    fb = FeedbackLoop(model, threshold=0.3, telemetry=rec,
                      calibration_version=7)
    for i in range(40):
        work = 1e8 * (1 + i % 5)
        fb.observe("n/gpu", "conv", work, 0.0, 3.0 * work / 1e9)
    assert fb.replans == 1
    drifts = [e for e in rec.events if e.name == "feedback.drift"]
    assert len(drifts) == 1
    (d,) = drifts
    assert d.kind == "gauge" and d.value == fb.events[0].mean_error > 0.3
    assert d.attrs["metric"] == "latency"
    assert d.attrs["resource"] == "n/gpu"
    assert d.attrs["calibration_version"] == 8   # bumped at the trip


def test_kernel_sweep_and_calibration_loop_emit(tmp_path):
    import jax

    from repro.profiling import (CalibrationStore, Profiler,
                                 calibrate_kernels)

    store = RunStore(tmp_path / "telemetry")
    rec = TelemetryRecorder(store.new_run("calib"), store=store)
    calib = CalibrationStore(tmp_path / "calibrations")
    cluster = paper_cluster()
    prof = Profiler(warmup=0, repeats=1, trim=0)
    # pin devices: earlier tests may have initialized jax with a forced
    # host device count, and the default sweeps every visible device
    model, version = calibrate_kernels(
        calib, cluster, profiler=prof, telemetry=rec,
        devices=jax.devices()[:1],
        shapes={"attn": ((1, 32, 2, 16),), "decode": ((1, 32, 2, 16),),
                "ssd": ((1, 32, 2, 16, 8),)})
    rec.close()
    assert version == 1
    # persisted through the CalibrationStore and loadable again
    assert set(calib.load(cluster).entries) == set(model.entries)
    spans = store.events(rec.run, kind="span", name="profile.kernel")
    assert {e.attrs["kind"] for e in spans} == {"attn", "decode", "ssd"}
    assert all(e.wall_s is not None and e.wall_s > 0 for e in spans)
    done = store.events(rec.run, name="profile.calibration")
    assert len(done) == 1 and done[0].attrs["version"] == 1
    assert done[0].attrs["samples"] == len(spans) == 3


def test_engine_submit_and_replan_counters():
    """ServingEngine cache-resolution counters (the membership/drift
    re-plan paths are covered end-to-end in test_serving): a submit with
    no plan cache records resolution='none'."""
    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.models import build_model
    from repro.serving import ServingEngine

    cfg = get_config("gemma-2b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(3))
    rec = TelemetryRecorder("engine")
    eng = ServingEngine(model, params, max_batch=1, max_len=16,
                        telemetry=rec)
    eng.submit(np.asarray([1, 2], np.int32), max_new_tokens=1)
    subs = [e for e in rec.events if e.name == "engine.submit"]
    assert len(subs) == 1 and subs[0].attrs["resolved"] == "none"


# --------------------------------------------------------------------------
# satellite: SimReport empty-report guards
# --------------------------------------------------------------------------

def test_sim_report_empty_guards():
    sim = EdgeSimulator(paper_cluster(), "hidp")
    rep = sim.run([])
    assert rep.records == []
    assert rep.predicted_energies() == {}
    assert rep.prediction_error() == {}
    # simulate() with an empty workload goes through the same guards
    rep2 = simulate(paper_cluster(), "hidp", [])
    assert rep2.prediction_error() == {}
