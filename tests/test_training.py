"""Training substrate: optimizer schedules, checkpoint round-trip, fault
tolerance (restart, stragglers), data pipeline determinism, microbatch
equivalence."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.runtime.fault_tolerance import (CheckpointPolicy,
                                           FaultTolerantRunner,
                                           StragglerPolicy)
from repro.runtime.elastic import ElasticController
from repro.models.config import SHAPES
from repro.sharding.plan import MULTI_POD, SINGLE_POD, ShardingPlan, plan_tpu
from repro.training import checkpoint as ckpt
from repro.training import optimizer as optim
from repro.training.data import SyntheticDataset
from repro.training.train_loop import make_train_step


def test_lr_schedules():
    cfg = optim.OptConfig(lr=1.0, warmup_steps=10, total_steps=100,
                          schedule="cosine")
    assert float(optim.lr_at(cfg, jnp.asarray(0))) == 0.0
    assert float(optim.lr_at(cfg, jnp.asarray(10))) == pytest.approx(1.0,
                                                                     abs=0.03)
    assert float(optim.lr_at(cfg, jnp.asarray(100))) < 0.01
    wsd = optim.OptConfig(lr=1.0, warmup_steps=10, total_steps=100,
                          schedule="wsd")
    # stable phase holds peak LR; decay phase drops toward 10%
    assert float(optim.lr_at(wsd, jnp.asarray(50))) == pytest.approx(1.0)
    assert float(optim.lr_at(wsd, jnp.asarray(89))) == pytest.approx(1.0)
    assert float(optim.lr_at(wsd, jnp.asarray(100))) == pytest.approx(0.1,
                                                                      abs=.02)


def test_adamw_converges_quadratic():
    target = jnp.asarray([1.5, -2.0, 0.5])
    params = {"w": jnp.zeros(3)}
    cfg = optim.OptConfig(lr=0.1, warmup_steps=1, total_steps=200,
                          weight_decay=0.0)
    state = optim.init(params)
    for _ in range(200):
        g = {"w": 2 * (params["w"] - target)}
        params, state, _ = optim.apply_updates(cfg, params, g, state)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=1e-2)


def test_bf16_opt_state_still_converges():
    target = jnp.asarray([1.0, -1.0])
    params = {"w": jnp.zeros(2)}
    cfg = optim.OptConfig(lr=0.1, warmup_steps=1, total_steps=300,
                          weight_decay=0.0, state_dtype="bfloat16")
    state = optim.init(params, jnp.bfloat16)
    for _ in range(300):
        g = {"w": 2 * (params["w"] - target)}
        params, state, _ = optim.apply_updates(cfg, params, g, state)
    assert state.m["w"].dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=5e-2)


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16),
                  "d": jnp.asarray(3, jnp.int32)}}
    path = ckpt.save(str(tmp_path / "x.msgpack"), tree, step=17)
    restored, step = ckpt.restore(path, tree)
    assert step == 17
    for l0, l1 in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        assert l0.dtype == l1.dtype
        np.testing.assert_array_equal(np.asarray(l0, np.float32),
                                      np.asarray(l1, np.float32))


def test_checkpoint_latest_and_gc(tmp_path):
    pol = CheckpointPolicy(str(tmp_path), every_steps=1, keep=2)
    tree = {"w": jnp.zeros(2)}
    for s in range(1, 6):
        pol.maybe_save(s, tree)
    files = sorted(os.listdir(tmp_path))
    assert len(files) == 2
    assert ckpt.latest(str(tmp_path)).endswith("00000005.msgpack")


def test_fault_tolerant_runner_restarts(tmp_path):
    """A step that crashes twice resumes from the checkpoint and finishes."""
    pol = CheckpointPolicy(str(tmp_path), every_steps=1, keep=3)
    crashes = {"left": 2}

    def step_fn(state, batch):
        if batch == "boom" and crashes["left"]:
            crashes["left"] -= 1
            raise RuntimeError("node failure")
        return {"w": state["w"] + 1}, {"loss": float(state["w"][0])}

    runner = FaultTolerantRunner(step_fn=step_fn, ckpt_policy=pol)
    state, step, log = runner.run({"w": jnp.zeros(1)},
                                  ["a", "b", "boom", "boom", "c"])
    assert runner.restarts == 2
    assert step == 3                   # a, b, c applied
    assert len(log) == 3


def test_straggler_detection():
    pol = StragglerPolicy(slack=1.5, window=10)
    for _ in range(10):
        for p in ("pod0", "pod1", "pod2", "pod3"):
            pol.record(p, 1.0)
        pol.record("pod4", 2.5)
    assert pol.stragglers() == ["pod4"]


def test_elastic_replan_shrinks_and_is_stable():
    model = build_model(get_config("gemma-2b"))
    ctl = ElasticController(model, SHAPES["train_4k"], MULTI_POD)
    p0 = ctl.initial_plan()
    assert p0.mesh.n_pods == 2
    p1 = ctl.on_availability_change(1)        # lose a pod
    assert p1.mesh.n_pods == 1
    assert ctl.replans == 1
    p2 = ctl.on_availability_change(1)        # nothing changed → no replan
    assert p2 is p1
    assert ctl.replans == 1


def test_synthetic_data_deterministic():
    cfg = get_config("gemma-2b").reduced()
    a = next(iter(SyntheticDataset(cfg, batch=2, seq_len=16, seed=7)))
    b = next(iter(SyntheticDataset(cfg, batch=2, seq_len=16, seed=7)))
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert a["tokens"].min() >= 0 and a["tokens"].max() < cfg.vocab


@pytest.mark.slow
def test_microbatch_equivalence(rng):
    """micro=2 grad-accumulated step == micro=1 step (same loss & params)."""
    cfg = get_config("gemma-2b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    batch = {"tokens": jax.random.randint(rng, (4, 16), 0, cfg.vocab),
             "targets": jax.random.randint(rng, (4, 16), 0, cfg.vocab)}
    outs = {}
    for m in (1, 2):
        plan = ShardingPlan(arch="t", shape="s", mesh=SINGLE_POD,
                            global_mode="data", local_layout="x",
                            batch_axes=(), microbatches=m, remat=False)
        step = make_train_step(model, optim.OptConfig(lr=1e-3,
                                                      warmup_steps=1), plan)
        p, o, metrics = step(params, optim.init(params), batch)
        outs[m] = (metrics["loss"], p)
    np.testing.assert_allclose(float(outs[1][0]), float(outs[2][0]),
                               rtol=1e-3)
    # bf16 forward → different reduction order across microbatch shapes;
    # AdamW's rsqrt amplifies tiny grad deltas, so tolerance is loose-ish
    for l1, l2 in zip(jax.tree.leaves(outs[1][1]),
                      jax.tree.leaves(outs[2][1])):
        np.testing.assert_allclose(np.asarray(l1, np.float32),
                                   np.asarray(l2, np.float32), atol=3e-3)


def test_planner_emits_valid_plans_for_all_cells():
    """plan_tpu returns structurally valid plans for every runnable cell
    (pure planning, no lowering — fast)."""
    from repro.configs import ARCH_IDS
    from repro.models import shape_applicable
    for aid in ARCH_IDS:
        cfg = get_config(aid)
        model = build_model(cfg)
        for sname, shape in SHAPES.items():
            ok, _ = shape_applicable(cfg, shape)
            if not ok:
                continue
            for mesh in (SINGLE_POD, MULTI_POD):
                plan = plan_tpu(model, shape, mesh)
                assert plan.predicted["total"] >= 0
                assert plan.local_layout
                B = shape.global_batch
                dp = plan.dp_size
                assert dp <= max(B, 1) or plan.seq_axes, (aid, sname)
