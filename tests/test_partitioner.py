"""Unit + property tests for the HiDP core: DP partitioner invariants, cost
model algebra, mode selection, hierarchical refinement."""

import math

import pytest  # noqa: F401

from _hypothesis_compat import given, settings, st

from repro.core import (Block, Cluster, ModelDAG, Node, Processor, chain,
                        partition, partition_data, partition_model, plan,
                        PlannerConfig)
from repro.core.cost_model import (Resource, node_as_resource,
                                   processors_as_resources)
from repro.core.dag import DataPartition, ModelPartition
from repro.core.edge_models import (EDGE_MODELS, MODEL_DELTA, paper_cluster,
                                    resnet152)


# --------------------------------------------------------------------------
# strategies
# --------------------------------------------------------------------------

@st.composite
def dags(draw):
    n = draw(st.integers(2, 24))
    blocks = []
    bytes_in = draw(st.floats(1e3, 1e7))
    for i in range(n):
        bytes_out = draw(st.floats(1e3, 1e7))
        blocks.append(Block(
            name=f"b{i}",
            flops=draw(st.floats(1e6, 1e12)),
            param_bytes=draw(st.floats(1e3, 1e8)),
            bytes_in=bytes_in, bytes_out=bytes_out,
            halo_fraction=draw(st.floats(0, 0.2))))
        bytes_in = bytes_out
    return ModelDAG(name="h", blocks=tuple(blocks), input_bytes=blocks[0].bytes_in,
                    output_bytes=blocks[-1].bytes_out)


@st.composite
def resource_lists(draw):
    m = draw(st.integers(1, 6))
    return [Resource(name=f"r{i}",
                     rate=draw(st.floats(1e8, 1e13)),
                     bw=draw(st.floats(1e6, 1e10)),
                     rtt=draw(st.floats(0, 1e-2)),
                     active_power=draw(st.floats(1, 20)),
                     idle_power=draw(st.floats(0.1, 5)))
            for i in range(m)]


# --------------------------------------------------------------------------
# model-partition DP invariants
# --------------------------------------------------------------------------

@settings(max_examples=60, deadline=None)
@given(dags(), resource_lists())
def test_model_partition_covers_all_blocks(dag, resources):
    p = partition_model(dag, resources)
    assert p.boundaries[0] == 0
    assert p.boundaries[-1] == len(dag.blocks)
    # contiguous, strictly increasing cuts; one resource per stage
    assert list(p.boundaries) == sorted(set(p.boundaries))
    assert len(p.assignment) == p.num_stages
    assert p.num_stages <= len(resources)
    # no resource used twice (stages map to distinct resources)
    assert len(set(p.assignment)) == len(p.assignment)
    assert p.predicted_latency > 0 and math.isfinite(p.predicted_latency)


@settings(max_examples=60, deadline=None)
@given(dags(), resource_lists())
def test_data_partition_fractions_valid(dag, resources):
    p = partition_data(dag, resources)
    assert abs(sum(p.fractions) - 1.0) < 1e-6
    assert all(f > 0 for f in p.fractions)
    assert len(set(p.assignment)) == len(p.assignment)
    assert p.predicted_latency > 0 and math.isfinite(p.predicted_latency)


@settings(max_examples=40, deadline=None)
@given(dags(), resource_lists())
def test_mode_selection_is_min(dag, resources):
    w = partition_model(dag, resources)
    s = partition_data(dag, resources)
    best = partition(dag, resources)
    assert best.predicted_latency == min(w.predicted_latency,
                                         s.predicted_latency)


@settings(max_examples=30, deadline=None)
@given(dags(), resource_lists(), st.floats(1.5, 4.0))
def test_more_compute_never_hurts(dag, resources, boost):
    """Monotonicity: uniformly faster resources can't increase latency."""
    base = partition(dag, resources).predicted_latency
    faster = [Resource(r.name, r.rate * boost, r.bw, r.rtt,
                       r.active_power, r.idle_power) for r in resources]
    assert partition(dag, faster).predicted_latency <= base + 1e-9


@settings(max_examples=30, deadline=None)
@given(dags(), resource_lists())
def test_single_resource_latency_is_serial(dag, resources):
    r = resources[:1]
    p = partition(dag, r)
    serial = (dag.total_flops / r[0].rate
              + (dag.input_bytes + dag.output_bytes) / r[0].bw)
    # plan can't beat physics on one resource (up to rtt bookkeeping)
    assert p.predicted_latency >= serial * 0.5


# --------------------------------------------------------------------------
# hierarchical planner on the paper's cluster
# --------------------------------------------------------------------------

def test_hidp_beats_p1_on_every_paper_model():
    cluster = paper_cluster()
    for name, fn in EDGE_MODELS.items():
        dag = fn()
        full = plan(dag, cluster, PlannerConfig(delta=MODEL_DELTA[name]))
        p1 = plan(dag, cluster, PlannerConfig(delta=MODEL_DELTA[name],
                                              p1_local=True,
                                              node_capacity="default"))
        assert full.predicted_latency < p1.predicted_latency, name


def test_local_tier_refines_global_estimate():
    cluster = paper_cluster()
    dag = resnet152()
    res = plan(dag, cluster, PlannerConfig(delta=MODEL_DELTA["resnet152"]))
    assert res.mode in ("data", "model")
    assert len(res.local_plans) == len(res.global_plan.assignments)
    for lp in res.local_plans:
        assert lp.predicted_latency > 0


def test_availability_vector_masks_nodes():
    cluster = paper_cluster().with_availability([True, True, False, False,
                                                 False])
    dag = resnet152()
    res = plan(dag, cluster, PlannerConfig(delta=MODEL_DELTA["resnet152"]))
    used = {a.node.name for a in res.global_plan.assignments}
    assert used <= {"orin_nx", "tx2"}


def test_planning_overhead_under_paper_budget():
    """Paper §IV-A: DP exploration overhead ≈ 15 ms on average."""
    import time
    cluster = paper_cluster()
    t, n = 0.0, 0
    for name, fn in EDGE_MODELS.items():
        dag = fn()
        t0 = time.perf_counter()
        plan(dag, cluster, PlannerConfig(delta=MODEL_DELTA[name]))
        t += time.perf_counter() - t0
        n += 1
    assert t / n < 0.2       # generous CI bound; benchmark reports the real #


def test_edge_dag_consistency():
    for name, fn in EDGE_MODELS.items():
        dag = fn()
        assert dag.total_flops > 1e8
        assert len(dag) >= 8
        if name != "inceptionv3":      # approximated byte edges documented
            dag.validate()
