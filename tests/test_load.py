"""repro.load — the open-loop queueing contracts (docs/load.md):

* arrival traces are seeded and replayable, per-tenant streams are
  independent, and the operators (merge / scaled / window) preserve the
  arrival sequence;
* queueing invariants: **request conservation** (arrived = admitted +
  rejected + shed; admitted = completed + in-flight), per-tenant FIFO (no
  reordering within a priority class's tenant stream), strict priority
  across classes, WDRR fairness bounds within a class, utilization ≤ 1;
* admission control rejects at the bounded queue, shedding bounds both
  queue age (``max_wait``) and doomed-SLO dispatches;
* two seeded replays emit **byte-identical** canonical telemetry, and the
  ``RunStore`` reconstructs the harness's own counts from the event log;
* composing an arrival trace with a churn trace keeps the
  one-frontier-pass-per-tenant-per-epoch invariant (counter-verified via
  ``PlanCache.stats()``) and engages backpressure instead of deadlocking
  when capacity drops below offered load.

Property-based tests run under hypothesis when installed and are paired
with seeded ``random.Random`` fallback loops that always run.
"""

import math
import random

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.load import (ArrivalTrace, FixedServiceModel, LoadConfig,
                        OpenLoopHarness, TenantSpec, mix_capacity,
                        saturation_sweep)
from repro.load.harness import derive_priorities


# --------------------------------------------------------------------------
# arrival traces
# --------------------------------------------------------------------------

RATES = {"chat": 20.0, "batch": 10.0}


def test_poisson_trace_is_seeded_and_replayable():
    a = ArrivalTrace.poisson(RATES, horizon=20.0, seed=3)
    b = ArrivalTrace.poisson(RATES, horizon=20.0, seed=3)
    assert np.array_equal(a.times, b.times)
    assert np.array_equal(a.tenant_ids, b.tenant_ids)
    c = ArrivalTrace.poisson(RATES, horizon=20.0, seed=4)
    assert not np.array_equal(a.times, c.times)
    # sorted, windowed, frozen
    assert np.all(np.diff(a.times) >= 0)
    assert a.times[-1] < 20.0
    with pytest.raises(ValueError):
        a.times[0] = -1.0


def test_poisson_offered_rates_match_requested():
    tr = ArrivalTrace.poisson(RATES, horizon=200.0, seed=0)
    got = tr.offered_rates()
    for name, rate in RATES.items():
        assert got[name] == pytest.approx(rate, rel=0.15)
    assert tr.offered_rate() == pytest.approx(sum(RATES.values()), rel=0.1)


def test_per_tenant_streams_are_independent():
    """Adding a tenant must not perturb another tenant's arrivals."""
    a = ArrivalTrace.poisson({"chat": 20.0}, horizon=20.0, seed=3)
    b = ArrivalTrace.poisson({"chat": 20.0, "extra": 5.0}, horizon=20.0,
                             seed=3)
    chat_b = b.times[b.tenant_ids == b.tenants.index("chat")]
    assert np.array_equal(a.times, chat_b)


def test_diurnal_trace_swings_between_trough_and_peak():
    tr = ArrivalTrace.diurnal({"t": 10.0}, horizon=100.0, seed=1,
                              peak_factor=5.0, period=100.0, phase=0.0)
    # λ(t) ∝ 1 − cos(2πt/period): trough at t=0, peak at t=period/2
    trough = len(tr.window(0.0, 20.0))
    peak = len(tr.window(40.0, 60.0))
    assert peak > 2 * trough
    with pytest.raises(ValueError, match="peak_factor"):
        ArrivalTrace.diurnal({"t": 1.0}, 10.0, peak_factor=0.5)


def test_burst_trace_is_overdispersed():
    """An MMPP's per-second counts have a variance/mean ratio well above
    the Poisson process's 1."""
    horizon = 400.0
    burst = ArrivalTrace.burst({"t": 10.0}, horizon, seed=2,
                               burst_factor=8.0)
    plain = ArrivalTrace.poisson({"t": burst.offered_rate()}, horizon,
                                 seed=2)

    def dispersion(tr):
        counts = np.bincount(tr.times.astype(np.int64),
                             minlength=int(horizon))
        return counts.var() / counts.mean()

    assert dispersion(plain) < 1.5
    assert dispersion(burst) > 2.0
    with pytest.raises(ValueError, match="rate states"):
        ArrivalTrace.mmpp({"t": 1.0}, 10.0, state_factors=(1.0,))


def test_merge_pools_same_named_tenants_and_stays_sorted():
    a = ArrivalTrace.poisson({"x": 5.0, "y": 2.0}, horizon=10.0, seed=0)
    b = ArrivalTrace.poisson({"y": 3.0, "z": 1.0}, horizon=15.0, seed=9)
    m = a.merge(b)
    assert m.tenants == ("x", "y", "z")
    assert m.horizon == 15.0
    assert len(m) == len(a) + len(b)
    assert np.all(np.diff(m.times) >= 0)
    counts = m.counts()
    assert counts["y"] == a.counts()["y"] + b.counts()["y"]


def test_scaled_compresses_time_and_multiplies_offered_load():
    tr = ArrivalTrace.poisson(RATES, horizon=20.0, seed=3)
    s = tr.scaled(4.0)
    assert np.allclose(s.times, tr.times / 4.0)
    assert np.array_equal(s.tenant_ids, tr.tenant_ids)
    assert s.horizon == tr.horizon / 4.0
    assert s.offered_rate() == pytest.approx(4.0 * tr.offered_rate())
    with pytest.raises(ValueError):
        tr.scaled(0.0)


def test_window_reanchors_at_zero():
    tr = ArrivalTrace.poisson({"t": 10.0}, horizon=20.0, seed=1)
    w = tr.window(5.0, 8.0)
    assert w.horizon == 3.0
    assert len(w) and w.times.min() >= 0.0 and w.times.max() < 3.0


def test_trace_validation():
    with pytest.raises(ValueError, match="1-D"):
        ArrivalTrace(np.zeros((2, 2)), np.zeros((2, 2), np.int32),
                     ("a",), 1.0)
    with pytest.raises(ValueError, match="outside tenants"):
        ArrivalTrace(np.array([0.5]), np.array([3], np.int32), ("a",), 1.0)
    # unsorted input is stably sorted, not rejected
    tr = ArrivalTrace(np.array([2.0, 1.0]), np.array([0, 0], np.int32),
                      ("a",), 3.0)
    assert list(tr.times) == [1.0, 2.0]


# --------------------------------------------------------------------------
# queueing harness — deterministic unit tests
# --------------------------------------------------------------------------

def _scripted(times, ids, tenants, horizon):
    return ArrivalTrace(np.asarray(times, float),
                        np.asarray(ids, np.int32), tenants, horizon)


def test_underload_completes_everything_within_slo():
    tr = ArrivalTrace.poisson(RATES, horizon=30.0, seed=7)
    svc = FixedServiceModel({"chat": 0.010, "batch": 0.030})
    specs = [TenantSpec("chat", slo=0.25, weight=2.0),
             TenantSpec("batch", slo=0.5)]
    rep = OpenLoopHarness(tr, specs, svc).run()
    assert rep.conservation_ok()
    assert rep.completed == rep.arrived
    assert rep.rejected == rep.shed == 0
    assert rep.slo_violations() == 0
    assert 0.0 < rep.utilization() < 1.0
    pt = rep.per_tenant()
    assert pt["chat"]["completed"] == tr.counts()["chat"]
    assert pt["chat"]["p99"] <= 0.25


def test_admission_control_rejects_when_queue_full():
    tr = ArrivalTrace.poisson(RATES, horizon=10.0, seed=7).scaled(20.0)
    svc = FixedServiceModel({"chat": 0.010, "batch": 0.030})
    specs = [TenantSpec("chat", slo=1.0), TenantSpec("batch", slo=1.0)]
    rep = OpenLoopHarness(tr, specs, svc,
                          LoadConfig(queue_capacity=16,
                                     shed_doomed=False)).run()
    assert rep.conservation_ok()
    assert rep.rejected > 0
    assert rep.utilization() <= 1.0 + 1e-9


def test_free_lane_is_never_rejected_even_with_zero_waiting_room():
    tr = _scripted([0.0, 10.0], [0, 0], ("t",), 20.0)
    rep = OpenLoopHarness(tr, [TenantSpec("t")],
                          FixedServiceModel({"t": 1.0}),
                          LoadConfig(queue_capacity=0)).run()
    assert rep.completed == 2 and rep.rejected == 0


def test_max_wait_bounds_every_admitted_requests_queue_age():
    tr = ArrivalTrace.poisson(RATES, horizon=10.0, seed=7).scaled(10.0)
    svc = FixedServiceModel({"chat": 0.010, "batch": 0.030})
    specs = [TenantSpec("chat"), TenantSpec("batch")]
    rep = OpenLoopHarness(tr, specs, svc,
                          LoadConfig(max_wait=0.2)).run()
    assert rep.conservation_ok()
    assert rep.shed > 0
    assert rep.waits().max() <= 0.2 + 1e-9


def test_doomed_shedding_makes_served_traffic_meet_slo():
    """With shed_doomed on, a dispatched request satisfies
    wait + service <= slo, so no completed request violates."""
    tr = ArrivalTrace.poisson(RATES, horizon=10.0, seed=7).scaled(10.0)
    svc = FixedServiceModel({"chat": 0.010, "batch": 0.030})
    specs = [TenantSpec("chat", slo=0.1), TenantSpec("batch", slo=0.3)]
    rep = OpenLoopHarness(tr, specs, svc,
                          LoadConfig(queue_capacity=128)).run()
    assert rep.conservation_ok()
    assert rep.shed > 0
    assert rep.slo_violations() == 0


def test_drain_false_leaves_backlog_accounted():
    tr = _scripted([0.0, 0.0, 0.0, 0.0], [0] * 4, ("t",), 1.0)
    rep = OpenLoopHarness(tr, [TenantSpec("t")],
                          FixedServiceModel({"t": 10.0}),
                          LoadConfig(drain=False)).run()
    assert rep.conservation_ok()
    assert rep.completed == 0 and rep.in_flight == 1 and rep.queued == 3
    assert rep.admitted == 1


def test_per_tenant_fifo_no_reordering():
    """Within one tenant (hence within its priority class's stream),
    dispatch order equals arrival order."""
    tr = ArrivalTrace.poisson(RATES, horizon=10.0, seed=5).scaled(5.0)
    svc = FixedServiceModel({"chat": 0.010, "batch": 0.030})
    specs = [TenantSpec("chat", slo=0.5, weight=2.0),
             TenantSpec("batch", slo=1.0)]
    rep = OpenLoopHarness(tr, specs, svc,
                          LoadConfig(queue_capacity=64)).run()
    for ti in range(len(tr.tenants)):
        starts = rep.start[(tr.tenant_ids == ti)
                           & ~np.isnan(rep.start)]
        assert np.all(np.diff(starts) >= 0)


def test_strict_priority_across_classes():
    """All tight-class requests dispatch before any loose-class one when
    both are backlogged from t=0."""
    n = 6
    tr = _scripted([0.0] * (2 * n), [0] * n + [1] * n, ("hi", "lo"), 1.0)
    specs = [TenantSpec("hi", priority=0), TenantSpec("lo", priority=1)]
    rep = OpenLoopHarness(tr, specs, FixedServiceModel({"hi": 0.1,
                                                        "lo": 0.1})).run()
    hi_starts = rep.start[:n]
    lo_starts = rep.start[n:]
    assert hi_starts.max() < lo_starts.min()


def test_slo_derived_priorities_and_explicit_override():
    specs = [TenantSpec("a", slo=0.1), TenantSpec("b", slo=0.5),
             TenantSpec("c"), TenantSpec("d", slo=9.0, priority=0)]
    prio = derive_priorities(specs)
    assert prio == {"a": 0, "b": 1, "c": 2, "d": 0}


def test_wdrr_shares_service_by_weight_under_backlog():
    """Two equally-priced tenants, weights 3:1, permanently backlogged:
    completions interleave ~3:1 (within a quantum per round)."""
    n = 400
    tr = _scripted([0.0] * (2 * n), [0] * n + [1] * n, ("big", "small"),
                   1.0)
    specs = [TenantSpec("big", priority=0, weight=3.0),
             TenantSpec("small", priority=0, weight=1.0)]
    rep = OpenLoopHarness(tr, specs,
                          FixedServiceModel({"big": 0.01,
                                             "small": 0.01})).run()
    # look at the first half of completions — both tenants still backlogged
    order = np.argsort(rep.finish)
    first = order[: n]
    big = int(np.count_nonzero(tr.tenant_ids[first] == 0))
    small = len(first) - big
    assert small > 0
    assert big / small == pytest.approx(3.0, rel=0.15)


def test_wdrr_weights_do_not_starve_light_tenants():
    tr = _scripted([0.0] * 40, [0] * 39 + [1], ("flood", "droplet"), 1.0)
    specs = [TenantSpec("flood", priority=0, weight=1.0),
             TenantSpec("droplet", priority=0, weight=1.0)]
    rep = OpenLoopHarness(tr, specs,
                          FixedServiceModel({"flood": 0.01,
                                             "droplet": 0.01})).run()
    # the droplet is served within its first DRR visit, not after the flood
    droplet_start = rep.start[-1]
    assert droplet_start <= 0.01 * 3 + 1e-9


def test_mix_capacity_and_saturation_sweep_shape():
    svc_times = {"chat": 0.010, "batch": 0.030}
    cap = mix_capacity(svc_times, RATES)
    assert cap == pytest.approx(60.0)
    tr = ArrivalTrace.poisson(RATES, horizon=30.0, seed=7)
    specs = [TenantSpec("chat", slo=0.15, weight=2.0),
             TenantSpec("batch", slo=0.5)]
    pts = saturation_sweep(tr, specs, FixedServiceModel(svc_times),
                           [0.5, 1.0, 4.0],
                           LoadConfig(queue_capacity=64, max_wait=1.0))
    below, at, above = pts
    # below the knee: throughput tracks offered load, nothing turned away
    assert below.throughput == pytest.approx(below.offered, rel=0.02)
    assert below.loss_rate == 0.0
    # above it: lanes saturate, the excess is rejected/shed
    assert above.report.utilization() > 0.95
    assert above.loss_rate > 0.1
    assert above.report.utilization() <= 1.0 + 1e-9
    assert above.p99 >= below.p99
    row = above.row()
    assert row["arrived"] == float(above.report.arrived)


def test_spec_and_config_validation():
    with pytest.raises(ValueError, match="weight"):
        TenantSpec("t", weight=0.0)
    with pytest.raises(ValueError, match="slo"):
        TenantSpec("t", slo=-1.0)
    with pytest.raises(ValueError, match="servers"):
        LoadConfig(servers=0)
    with pytest.raises(ValueError, match="queue_capacity"):
        LoadConfig(queue_capacity=-1)
    tr = ArrivalTrace.poisson({"t": 1.0}, 5.0, seed=0)
    with pytest.raises(ValueError, match="no TenantSpec"):
        OpenLoopHarness(tr, [], FixedServiceModel({"t": 1.0}))
    with pytest.raises(ValueError, match="positive"):
        FixedServiceModel({"t": 0.0})


def test_multi_server_utilization_and_speedup():
    tr = ArrivalTrace.poisson({"t": 50.0}, horizon=20.0, seed=2)
    svc = FixedServiceModel({"t": 0.05})          # offered ρ≈2.5 on 1 lane
    one = OpenLoopHarness(tr, [TenantSpec("t")], svc,
                          LoadConfig(servers=1, queue_capacity=32,
                                     shed_doomed=False)).run()
    four = OpenLoopHarness(tr, [TenantSpec("t")], svc,
                           LoadConfig(servers=4, queue_capacity=32,
                                      shed_doomed=False)).run()
    assert four.completed > one.completed
    assert four.utilization() <= 1.0 + 1e-9
    assert four.throughput() <= 4.0 / 0.05 * 1.01


# --------------------------------------------------------------------------
# property-based invariants (hypothesis + seeded fallbacks)
# --------------------------------------------------------------------------

def _check_queueing_invariants(seed, rate_a, rate_b, factor, cap,
                               max_wait, servers):
    """The core property: for any load level and queue knobs, the harness
    conserves requests, respects capacity physics, bounds admitted queue
    age, and never reorders within a tenant."""
    tr = ArrivalTrace.poisson({"a": rate_a, "b": rate_b}, horizon=5.0,
                              seed=seed).scaled(factor)
    svc = FixedServiceModel({"a": 0.004, "b": 0.011})
    specs = [TenantSpec("a", slo=0.2, weight=2.0),
             TenantSpec("b", slo=0.6)]
    cfg = LoadConfig(servers=servers, queue_capacity=cap,
                     max_wait=max_wait)
    rep = OpenLoopHarness(tr, specs, svc, cfg).run()
    assert rep.conservation_ok()
    assert rep.queued == rep.in_flight == 0          # drained
    assert rep.admitted == rep.completed
    assert rep.utilization() <= 1.0 + 1e-9
    if max_wait is not None and rep.admitted:
        assert rep.waits().max() <= max_wait + 1e-9
    assert rep.slo_violations() == 0                 # shed_doomed default
    for ti in range(2):
        starts = rep.start[(tr.tenant_ids == ti) & ~np.isnan(rep.start)]
        assert np.all(np.diff(starts) >= 0)
    return rep


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2 ** 16), st.floats(1.0, 60.0), st.floats(0.0, 40.0),
       st.floats(0.25, 8.0), st.integers(0, 64),
       st.one_of(st.none(), st.floats(0.05, 1.0)), st.integers(1, 4))
def test_queueing_invariants_property(seed, rate_a, rate_b, factor, cap,
                                      max_wait, servers):
    _check_queueing_invariants(seed, rate_a, rate_b, factor, cap,
                               max_wait, servers)


def test_queueing_invariants_seeded_fallback():
    """The same property as a seeded loop, exercised whether or not
    hypothesis is installed."""
    rng = random.Random(0xC0FFEE)
    for _ in range(25):
        _check_queueing_invariants(
            seed=rng.randrange(2 ** 16),
            rate_a=rng.uniform(1.0, 60.0),
            rate_b=rng.uniform(0.0, 40.0),
            factor=rng.uniform(0.25, 8.0),
            cap=rng.randrange(0, 64),
            max_wait=rng.choice([None, rng.uniform(0.05, 1.0)]),
            servers=rng.randrange(1, 5))


def _check_trace_identity(seed, rate, factor):
    a = ArrivalTrace.poisson({"t": rate}, 5.0, seed=seed).scaled(factor)
    b = ArrivalTrace.poisson({"t": rate}, 5.0, seed=seed).scaled(factor)
    assert np.array_equal(a.times, b.times)
    assert a.offered_rate() == b.offered_rate()


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2 ** 16), st.floats(0.5, 80.0), st.floats(0.25, 8.0))
def test_trace_identity_property(seed, rate, factor):
    _check_trace_identity(seed, rate, factor)


def test_trace_identity_seeded_fallback():
    rng = random.Random(7)
    for _ in range(25):
        _check_trace_identity(rng.randrange(2 ** 16),
                              rng.uniform(0.5, 80.0),
                              rng.uniform(0.25, 8.0))


def _check_wdrr_fairness_bound(w_big):
    """Under permanent backlog of equally-priced tenants, the completion
    split tracks the weight split to within one quantum per round."""
    n = 300
    tr = _scripted([0.0] * (2 * n), [0] * n + [1] * n, ("big", "small"),
                   1.0)
    specs = [TenantSpec("big", priority=0, weight=w_big),
             TenantSpec("small", priority=0, weight=1.0)]
    rep = OpenLoopHarness(tr, specs,
                          FixedServiceModel({"big": 0.01,
                                             "small": 0.01})).run()
    order = np.argsort(rep.finish)[: n]
    big = int(np.count_nonzero(tr.tenant_ids[order] == 0))
    small = len(order) - big
    assert small > 0
    assert big / small == pytest.approx(w_big, rel=0.25)


@settings(max_examples=10, deadline=None)
@given(st.floats(1.0, 6.0))
def test_wdrr_fairness_property(w_big):
    _check_wdrr_fairness_bound(w_big)


def test_wdrr_fairness_seeded_fallback():
    rng = random.Random(11)
    for _ in range(6):
        _check_wdrr_fairness_bound(rng.uniform(1.0, 6.0))



# --------------------------------------------------------------------------
# telemetry determinism + reconstruction
# --------------------------------------------------------------------------

def _telemetry_run(tmp_path, tag):
    from repro.telemetry import RunStore, TelemetryRecorder
    store = RunStore(tmp_path / tag)
    rec = TelemetryRecorder(store.new_run("load"), store=store)
    tr = ArrivalTrace.poisson(RATES, horizon=10.0, seed=13).scaled(8.0)
    svc = FixedServiceModel({"chat": 0.010, "batch": 0.030})
    specs = [TenantSpec("chat", slo=0.2, weight=2.0),
             TenantSpec("batch", slo=0.6)]
    rep = OpenLoopHarness(tr, specs, svc,
                          LoadConfig(queue_capacity=32, max_wait=0.5),
                          telemetry=rec).run()
    rec.close()
    return store, rec.run, rep


def test_two_seeded_replays_emit_byte_identical_canonical_logs(tmp_path):
    s1, run1, rep1 = _telemetry_run(tmp_path, "a")
    s2, run2, rep2 = _telemetry_run(tmp_path, "b")
    lines1 = s1.canonical_lines(run1)
    lines2 = s2.canonical_lines(run2)
    assert lines1 and lines1 == lines2
    assert rep1.completed == rep2.completed
    assert rep1.rejected == rep2.rejected and rep1.shed == rep2.shed


def test_run_store_reconstructs_the_saturation_story(tmp_path):
    """`RunStore` alone — no LoadReport — recovers every queue decision:
    the load.admit/reject/shed counters match the report's conservation
    terms, and queue_wait spans bound the admitted wait."""
    store, run, rep = _telemetry_run(tmp_path, "solo")
    assert store.counter_total(run, "load.admit") == rep.admitted
    assert store.counter_total(run, "load.reject") == rep.rejected
    assert store.counter_total(run, "load.shed") == rep.shed
    total = (store.counter_total(run, "load.admit")
             + store.counter_total(run, "load.reject")
             + store.counter_total(run, "load.shed"))
    assert total == rep.arrived                     # conservation, replayed
    waits = [e.value for e in store.events(run, kind="span",
                                           name="load.queue_wait")]
    assert len(waits) == rep.admitted
    assert max(waits) <= 0.5 + 1e-9                 # max_wait bound
    by_tenant = store.by_tenant(run, "load.admit")
    pt = rep.per_tenant()
    for name, stats in pt.items():
        assert by_tenant.get(name, 0.0) == stats["completed"]
    # completion spans carry slo_violated for the SLO-rate reconstruction
    reqs = store.events(run, kind="span", name="load.request")
    assert len(reqs) == rep.completed
    viol = sum(1 for e in reqs if e.attrs.get("slo_violated"))
    assert viol == rep.slo_violations()


# --------------------------------------------------------------------------
# churn composition (arrival trace × churn trace)
# --------------------------------------------------------------------------

def _plan_priced_setup(churn_events, *, rates=None, horizon=8.0,
                       factor=1.0, cap=32, telemetry=None):
    from repro.core import HiDPPlanner
    from repro.core.edge_models import (EDGE_MODELS, MODEL_DELTA,
                                        paper_cluster)
    from repro.fleet import ChurnTrace, FleetController
    from repro.load import PlanServiceModel
    from repro.serving import PlanCache

    cluster = paper_cluster()
    fleet = FleetController(cluster, ChurnTrace.scripted(churn_events),
                            telemetry=telemetry)
    cache = PlanCache(HiDPPlanner(), cluster, membership_source=fleet,
                      telemetry=telemetry)
    specs = {
        "resnet": TenantSpec("resnet", slo=60.0, weight=2.0,
                             dag=EDGE_MODELS["resnet152"](),
                             delta=MODEL_DELTA["resnet152"]),
        "vgg": TenantSpec("vgg", slo=90.0,
                          dag=EDGE_MODELS["vgg19"](),
                          delta=MODEL_DELTA["vgg19"]),
    }
    model = PlanServiceModel(cache, specs)
    tr = ArrivalTrace.poisson(rates or {"resnet": 2.0, "vgg": 1.0},
                              horizon=horizon, seed=5).scaled(factor)
    h = OpenLoopHarness(tr, specs, model,
                        LoadConfig(queue_capacity=cap, max_wait=200.0,
                                   shed_doomed=False),
                        fleet=fleet, telemetry=telemetry)
    return h, model, cache, fleet


def test_churn_composition_one_frontier_pass_per_tenant_per_epoch():
    """A mid-run departure + return: the plan cache sees exactly one
    resolution per tenant per membership epoch, frontier passes only for
    never-seen memberships, warm hits for the returning one."""
    h, model, cache, fleet = _plan_priced_setup(
        [(2.0, "tx2", "crash"), (5.0, "tx2", "join")])
    rep = h.run()
    assert rep.conservation_ok()
    assert h.epochs_seen == 2 and fleet.epoch == 2
    # one cache.get per tenant per epoch (incl. epoch 0)
    assert model.resolutions == 2 * (1 + h.epochs_seen)
    stats = cache.stats()
    assert stats["hits"] + stats["misses"] == model.resolutions
    # 2 distinct memberships × 2 tenants planned; the return is warm
    assert stats["misses"] == 4
    assert stats["hits"] == 2


def test_backpressure_engages_instead_of_deadlocking_under_capacity_drop():
    """Drop most of the cluster mid-run while offered load is near the
    full-cluster capacity: service re-prices upward, the bounded queue
    must overflow into rejects (not hang), and the run must terminate
    with conservation intact."""
    from repro.core.edge_models import paper_cluster
    names = [n.name for n in paper_cluster().nodes]
    # keep only the first node after t=1.0
    events = [(1.0, n, "leave") for n in names[1:]]
    h, model, cache, fleet = _plan_priced_setup(
        events, rates={"resnet": 4.0, "vgg": 2.0}, horizon=6.0,
        cap=8)
    rep = h.run()
    assert rep.conservation_ok()
    assert rep.queued == rep.in_flight == 0        # drained — no deadlock
    assert rep.rejected > 0                        # backpressure engaged
    assert rep.utilization() <= 1.0 + 1e-9
    assert h.epochs_seen >= 1
    # degraded membership re-priced service upward
    assert model.resolutions >= 4


def test_churn_composed_replays_are_byte_identical():
    from repro.telemetry import TelemetryRecorder

    def one(run):
        rec = TelemetryRecorder(run)
        h, model, cache, fleet = _plan_priced_setup(
            [(2.0, "tx2", "crash"), (5.0, "tx2", "join")], telemetry=rec)
        rep = h.run()
        return [e.canonical() for e in rec.events], rep

    l1, r1 = one("c1")
    l2, r2 = one("c2")
    assert l1 and l1 == l2
    assert r1.completed == r2.completed


# --------------------------------------------------------------------------
# scale (the 1e5-request acceptance floor)
# --------------------------------------------------------------------------

@pytest.mark.slow
def test_hundred_thousand_requests_through_the_event_loop():
    tr = ArrivalTrace.poisson({"a": 1500.0, "b": 800.0}, horizon=50.0,
                              seed=1)
    assert len(tr) >= 100_000
    rep = OpenLoopHarness(
        tr, [TenantSpec("a", slo=0.2, weight=2.0),
             TenantSpec("b", slo=0.4)],
        FixedServiceModel({"a": 0.0004, "b": 0.0006}),
        LoadConfig(queue_capacity=256, max_wait=0.5)).run()
    assert rep.conservation_ok()
    assert rep.completed >= 90_000
    assert rep.utilization() <= 1.0 + 1e-9
    assert math.isfinite(rep.percentile(99))
