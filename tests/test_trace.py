"""repro.telemetry.trace — span-tree reconstruction, critical paths,
utilization, and the report's causal section.

The determinism surface under test is ``tree_lines``: two seeded replays
(``planning_time=0.0``) must render byte-identical forests — ids,
parentage, children order, canonical JSON.  The accounting surface is
``critical_path``: plan/queue/compute/comm/retry-waste/other must sum to
each request's recorded latency to float precision, under churn retries
and mixed-tenant interleaving alike.
"""

import pytest

from repro.core import EdgeSimulator, SimRequest
from repro.core.edge_models import EDGE_MODELS, MODEL_DELTA, paper_cluster
from repro.fleet import ChurnTrace, FleetController
from repro.load import (ArrivalTrace, FixedServiceModel, LoadConfig,
                        OpenLoopHarness, TenantSpec)
from repro.telemetry import (RunStore, TelemetryEvent, TelemetryRecorder,
                             critical_path, node_utilization,
                             overlap_headroom, request_critical_paths,
                             span_trees, tree_lines)
from repro.telemetry.events import WALL_FIELDS
from repro.telemetry.report import generate
from repro.telemetry.trace import (CATEGORIES, REQUEST_ROOTS,
                                   category_totals, forest, trace_summary)

CHURN = [(0.4, "tx2", "crash"), (3.0, "tx2", "join"),
         (4.0, "nano", "leave"), (6.0, "nano", "join")]


def _churn_run(root, n_requests=6):
    """A mixed-tenant churn run in replay mode (``planning_time=0.0``)
    recorded under ``root``: resnet152/vgg19 interleaved, one scripted
    mid-request crash (forces a retry), a leave/return cycle."""
    names = ["resnet152", "vgg19"]
    wl = [SimRequest(i, EDGE_MODELS[names[i % 2]](), 0.8 * i,
                     MODEL_DELTA[names[i % 2]], slo=2.0)
          for i in range(n_requests)]
    store = RunStore(root)
    rec = TelemetryRecorder(store.new_run("trace"), store=store)
    fleet = FleetController(paper_cluster(), ChurnTrace.scripted(CHURN),
                            telemetry=rec)
    rep = EdgeSimulator(paper_cluster(), "hidp", fleet=fleet,
                        telemetry=rec, planning_time=0.0).run(wl)
    rec.close()
    return store, rec.run, rep


# --------------------------------------------------------------------------
# tree reconstruction
# --------------------------------------------------------------------------

def test_span_trees_synthetic_parentage_and_orphans():
    ev = [
        TelemetryEvent(0, "span", "root", 1.0, span_id=0),
        TelemetryEvent(1, "span", "child", 0.5, span_id=1, parent_id=0),
        TelemetryEvent(2, "span", "leaf", 0.1, parent_id=1),
        TelemetryEvent(3, "counter", "tick", 1.0, parent_id=0),
        TelemetryEvent(4, "span", "orphan", 0.2, span_id=9, parent_id=77),
        TelemetryEvent(5, "counter", "lost", 1.0, parent_id=77),
    ]
    roots = span_trees(ev)
    # orphan (parent id nobody claims) is surfaced as a root, not dropped
    assert [r.name for r in roots] == ["root", "orphan"]
    root = roots[0]
    assert [c.name for c in root.children] == ["child"]
    assert [c.name for c in root.children[0].children] == ["leaf"]
    # non-span events attach to their parent; unknown parent → dropped
    assert [e.name for e in root.events] == ["tick"]
    assert all(e.name != "lost" for n in roots for x in n.walk()
               for e in x.events)
    # walk() is depth-first
    assert [n.name for n in root.walk()] == ["root", "child", "leaf"]


def test_churn_run_tree_shape(tmp_path):
    store, run, rep = _churn_run(tmp_path)
    roots = forest(store, run)
    req_roots = [r for r in roots if r.name in REQUEST_ROOTS]
    assert len(req_roots) == len(rep.records)
    crashed = [r for r in req_roots
               if any(not a.event.attrs.get("ok", True)
                      for a in r.children if a.name == "sim.attempt")]
    assert crashed, "the scripted crash should fail at least one attempt"
    for r in req_roots:
        attempts = [c for c in r.children if c.name == "sim.attempt"]
        assert attempts, "every request runs at least one attempt"
        assert attempts[-1].event.attrs["ok"] is True
        # per-stage shards hang under their attempt, tagged with the
        # owning request id
        stage_names = {c.name for a in attempts for c in a.children}
        assert "sim.compute" in stage_names
        rid = r.event.attrs["request"]
        for a in attempts:
            for c in a.children:
                if c.name == "sim.compute":
                    assert c.event.attrs["request"] == rid
    # retry accounting parents under the *request*, not the dead attempt
    retried = crashed[0]
    assert any(e.name == "sim.retry" for e in retried.events)


def test_tree_lines_byte_identical_across_seeded_replays(tmp_path):
    store_a, run_a, _ = _churn_run(tmp_path / "a")
    store_b, run_b, _ = _churn_run(tmp_path / "b")
    lines_a = tree_lines(span_trees(store_a.events(run_a)))
    lines_b = tree_lines(span_trees(store_b.events(run_b)))
    assert lines_a == lines_b
    assert len(lines_a) > 50
    # and the canonical surface really strips only the wall fields
    for f in WALL_FIELDS:
        assert all(f'"{f}"' not in ln for ln in lines_a)


# --------------------------------------------------------------------------
# critical paths
# --------------------------------------------------------------------------

def test_critical_path_sums_to_latency_under_churn(tmp_path):
    store, run, rep = _churn_run(tmp_path)
    paths = request_critical_paths(store, run)
    assert len(paths) == len(rep.records)
    by_rid = {p.request: p for p in paths}
    for r in rep.records:
        p = by_rid[r.request_id]
        assert p.latency == pytest.approx(r.latency, abs=1e-12)
        assert abs(p.residual) < 1e-9
        assert set(p.categories) == set(CATEGORIES)
        assert all(v >= 0.0 for v in p.categories.values())
    # the crashed request's doomed attempt is retry-waste wholesale
    retried = [r for r in rep.records if r.retries][0]
    assert by_rid[retried.request_id].categories["retry_waste"] > 0
    clean = [r for r in rep.records if not r.retries][0]
    assert by_rid[clean.request_id].categories["retry_waste"] == 0.0
    totals = category_totals(paths)
    assert sum(totals.values()) == pytest.approx(
        sum(r.latency for r in rep.records), rel=1e-9)


def test_critical_path_rejects_non_request_roots():
    node = span_trees([TelemetryEvent(0, "span", "sim.attempt", 1.0,
                                      span_id=0)])[0]
    with pytest.raises(ValueError, match="not a request root"):
        critical_path(node)


def test_mixed_tenant_interleaving_keeps_trees_disjoint(tmp_path):
    """Two tenants' requests interleave in one store; every stage shard
    must land under its own request's tree, never a neighbour's."""
    store, run, _ = _churn_run(tmp_path, n_requests=8)
    for r in forest(store, run):
        if r.name not in REQUEST_ROOTS:
            continue
        rid, tenant = r.event.attrs["request"], r.event.tenant
        for node in r.walk():
            got = node.event.attrs.get("request")
            if got is not None:
                assert got == rid, (node.name, got, rid)
            if node.event.tenant:
                assert node.event.tenant == tenant


# --------------------------------------------------------------------------
# load-harness trees
# --------------------------------------------------------------------------

def _load_run(root):
    tr = ArrivalTrace.poisson({"chat": 30.0, "batch": 10.0},
                              horizon=10.0, seed=5)
    svc = FixedServiceModel({"chat": 0.012, "batch": 0.040})
    specs = [TenantSpec("chat", slo=0.25, weight=2.0),
             TenantSpec("batch", slo=1.0)]
    store = RunStore(root)
    rec = TelemetryRecorder(store.new_run("load"), store=store)
    rep = OpenLoopHarness(tr, specs, svc,
                          LoadConfig(servers=1, queue_capacity=16,
                                     max_wait=0.5),
                          telemetry=rec).run()
    rec.close()
    return store, rec.run, rep


def test_load_request_trees_and_critical_paths(tmp_path):
    store, run, rep = _load_run(tmp_path)
    roots = [r for r in forest(store, run) if r.name == "load.request"]
    assert len(roots) == rep.completed
    for r in roots:
        names = [c.name for c in r.children]
        assert names.count("load.service") == 1
        assert names.count("load.queue_wait") == 1
        p = critical_path(r)
        assert abs(p.residual) < 1e-9
        assert p.categories["compute"] > 0
    # shed requests never grow a tree, but their counters cite the
    # pre-allocated span id of a root that was never emitted — dropped,
    # not mis-attached
    shed = store.events(run, kind="counter", name="load.shed")
    if shed:
        claimed = {r.event.span_id for r in roots}
        assert all(e.parent_id not in claimed for e in shed)


def test_load_trees_byte_identical_across_replays(tmp_path):
    store_a, run_a, _ = _load_run(tmp_path / "a")
    store_b, run_b, _ = _load_run(tmp_path / "b")
    assert (tree_lines(span_trees(store_a.events(run_a)))
            == tree_lines(span_trees(store_b.events(run_b))))


# --------------------------------------------------------------------------
# utilization / headroom / report surface
# --------------------------------------------------------------------------

def test_node_utilization_and_overlap_headroom(tmp_path):
    store, run, rep = _churn_run(tmp_path)
    util = node_utilization(store, run)
    nodes = [k for k in util if k != "medium" and "/" not in k]
    assert nodes, "compute nodes should have busy intervals"
    for k, u in util.items():
        assert u["busy_s"] >= 0 and 0.0 <= u["utilization"] <= 1.0
        assert u["busy_s"] == pytest.approx(
            sum(e - s for s, e in u["intervals"]))
    head = overlap_headroom(store, run)
    assert 0.0 <= head["total"]["fraction"] <= 1.0
    # with >1 nodes computing disjointly there must be *some* headroom
    assert head["total"]["idle_while_peer_busy_s"] > 0
    summ = trace_summary(store, run)
    assert summ["requests"] == len(rep.records)
    assert summ["max_residual_s"] < 1e-9
    assert sum(summ["category_fractions"].values()) == pytest.approx(
        1.0, abs=1e-6)


def test_report_includes_trace_section_and_timelines(tmp_path):
    store, run, _ = _churn_run(tmp_path)
    out = generate(store, run, window=2.0)
    assert "critical path" in out
    assert "retry_waste" in out
    assert "overlap headroom" in out
    assert "sim.request per 2 s" in out
    assert "sim.energy per 2 s" in out


def test_report_fails_readably_on_zero_span_runs(tmp_path):
    store = RunStore(tmp_path)
    rec = TelemetryRecorder(store.new_run("empty"), store=store)
    rec.counter("something.happened")
    rec.close()
    with pytest.raises(ValueError, match="zero span events"):
        generate(store, rec.run)
    from repro.telemetry.report import main
    assert main([str(tmp_path), rec.run]) == 1


def test_disabled_recorder_allocates_nothing(tmp_path):
    rec = TelemetryRecorder("r", enabled=False)
    with rec.trace("outer") as h:
        assert h.span_id is None
        assert rec.child_span("inner", 0.1) is None
        assert rec.current_span() is None
    assert rec.events == []
