"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests and benches must
see 1 device; only launch/dryrun.py forces 512 placeholder devices."""
import jax
import pytest

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
