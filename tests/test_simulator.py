"""Edge-cluster simulator + scheduler FSM: reproduction-level invariants
(HiDP wins, FSM traces, queueing behaviour, node-count scaling)."""

import pytest

from repro.core import (ClusterManager, EdgeSimulator, HeartbeatMonitor,
                        InferenceRequest, LeaderFSM, simulate)
from repro.core.edge_models import (EDGE_MODELS, MODEL_DELTA, paper_cluster,
                                    efficientnet_b0, resnet152)
from repro.core.scheduler import ShardResult, State


STRATS = ("hidp", "disnet", "omniboost", "modnn")


def _single(strategy, name):
    rep = simulate(paper_cluster(), strategy,
                   [(0.0, EDGE_MODELS[name](), MODEL_DELTA[name])])
    return rep


def test_hidp_lowest_latency_all_models():
    for name in EDGE_MODELS:
        lats = {s: _single(s, name).records[0].latency for s in STRATS}
        assert min(lats, key=lats.get) == "hidp", (name, lats)


def test_hidp_lowest_energy_all_models():
    for name in EDGE_MODELS:
        ens = {s: _single(s, name).energies()[name] for s in STRATS}
        assert min(ens, key=ens.get) == "hidp", (name, ens)


def test_queueing_increases_latency_under_load():
    dag = resnet152()
    d = MODEL_DELTA["resnet152"]
    solo = simulate(paper_cluster(), "hidp", [(0.0, dag, d)])
    burst = simulate(paper_cluster(), "hidp",
                     [(0.0, dag, d), (0.01, dag, d), (0.02, dag, d)])
    l_solo = solo.records[0].latency
    l_last = max(r.latency for r in burst.records)
    assert l_last > l_solo * 1.5


def test_node_scaling_monotone_for_hidp():
    """Fig. 8: more nodes → lower (or equal) latency."""
    dag = resnet152()
    d = MODEL_DELTA["resnet152"]
    lats = []
    for n in (2, 3, 4, 5):
        rep = simulate(paper_cluster(n), "hidp", [(0.0, dag, d)])
        lats.append(rep.records[0].latency)
    assert all(b <= a * 1.05 for a, b in zip(lats, lats[1:])), lats


def test_gflops_timeline_integrates_to_total_work():
    dag = efficientnet_b0()
    rep = simulate(paper_cluster(), "hidp",
                   [(0.0, dag, MODEL_DELTA["efficientnet_b0"])])
    total = sum(s.flops for s in rep.spans)
    assert total == pytest.approx(dag.total_flops, rel=0.02)


# --------------------------------------------------------------------------
# FSM
# --------------------------------------------------------------------------

class _InstantTransport:
    def send(self, src, dst, nbytes, payload, now):
        return now + nbytes / 80e6


def test_leader_fsm_full_cycle():
    mgr = ClusterManager(paper_cluster())
    mgr.elect_leader("orin_nx")
    now = 0.0
    for n in mgr.nodes():
        mgr.monitor.beat(n.name, now)
    fsm = LeaderFSM(manager=mgr, transport=_InstantTransport())
    req = InferenceRequest(0, resnet152(), arrival_time=now,
                           delta=MODEL_DELTA["resnet152"])
    plan = fsm.on_request(req, now)
    assert fsm.state == State.GLOBAL_OFFLOAD
    assert plan.predicted_latency > 0
    sent = fsm.offload(now)
    assert fsm.state == State.LOCAL_MAP
    lp = fsm.local_map(now)
    assert fsm.state == State.EXECUTE
    # all shards report → merge → back to ANALYZE
    n_shards = len(plan.global_plan.assignments)
    for i, a in enumerate(plan.global_plan.assignments):
        done = fsm.on_shard_result(
            ShardResult(0, a.node.name, a.stage_index, None, now + 1.0), now)
        assert done == (i == n_shards - 1)
    assert fsm.state == State.ANALYZE
    states = [s for _, s in fsm.trace]
    assert states[:4] == [State.ANALYZE, State.EXPLORE, State.GLOBAL_OFFLOAD,
                          State.LOCAL_MAP]


def test_heartbeat_availability():
    mon = HeartbeatMonitor(interval=0.5, miss_threshold=3)
    mon.beat("a", 0.0)
    assert mon.alive("a", 1.0)
    assert not mon.alive("a", 2.0)          # 4 intervals missed
    assert not mon.alive("never-seen", 0.0)


def test_manager_failure_masks_node():
    mgr = ClusterManager(paper_cluster())
    mgr.elect_leader("orin_nx")
    now = 10.0
    for n in mgr.nodes():
        if n.name != "rpi4":
            mgr.monitor.beat(n.name, now)
    cluster = mgr.refresh_availability(now)
    av = dict(zip((n.name for n in cluster.nodes), cluster.availability()))
    assert av["rpi4"] == 0
    assert av["orin_nx"] == 1 and av["tx2"] == 1
