"""Fast DP engine guarantees, as tests.

The vectorized planner is an *optimization*, never an approximation — so
every test here is an equality test, not a tolerance test:

* fast == reference **bit-identically** — scalar DP, (lat, energy)
  frontier DP, and the full hierarchical ``plan_front`` — over the paper
  workloads and randomized DAG/cluster instances (property-tested via
  hypothesis when installed, seeded fallback regardless);
* incremental epoch re-planning: a departure + return replayed through a
  warm :class:`~repro.core.dp_cache.PlannerWorkspace` yields plans
  byte-identical to a cold pass, while reusing the DP rows the departed
  node never touched (``rows_reused`` counts it);
* speculative pre-warming: with a ``SpeculativePrewarmer`` wired to a
  ``FleetController``, a single-departure epoch is served with **zero**
  demand frontier passes, counter-verified;
* the engine flag (``set_engine`` / ``planner_engine`` /
  ``REPRO_PLANNER_ENGINE``) actually switches engines and validates;
* a refit calibration (model ``revision`` bump) orphans every cached DP
  row — stale rows can never price a plan.
"""

import random

import pytest

from _hypothesis_compat import given, settings, st

from repro.core import Block, HiDPPlanner, ModelDAG, PlannerConfig
from repro.core import dp_partitioner as dp
from repro.core.cost_model import Resource
from repro.core.dp_cache import (PlannerWorkspace, reset_workspaces,
                                 single_departure_masks, workspace_for)
from repro.core.edge_models import (EDGE_MODELS, MODEL_DELTA, battery_cluster,
                                    paper_cluster)
from repro.core.hidp import plan_front, plan_to_dict
from repro.fleet import FleetController
from repro.fleet.traces import ChurnEvent, ChurnTrace
from repro.profiling import CalibratedCostProvider, LearnedCostModel
from repro.serving import PlanCache, SpeculativePrewarmer


@pytest.fixture(autouse=True)
def _fast_engine_and_cold_workspaces():
    """Each test starts on the fast engine with cold workspaces and
    restores whatever engine the session default was."""
    prev = dp.get_engine()
    dp.set_engine("fast")
    reset_workspaces()
    yield
    dp.set_engine(prev)
    reset_workspaces()


# --------------------------------------------------------------------------
# instance generators — trade-off-rich: rate and power anti-correlate, so
# frontier cells genuinely grow and the event/general DP lanes execute
# --------------------------------------------------------------------------

def _tradeoff_resources(rng: random.Random, m: int) -> list[Resource]:
    out = []
    for i in range(m):
        speed = rng.uniform(0.1, 1.0)
        out.append(Resource(
            name=f"r{i}", rate=speed * rng.uniform(1e10, 1e12),
            bw=rng.uniform(1e6, 1e9), rtt=rng.uniform(0.0, 5e-3),
            active_power=(1.2 - speed) * rng.uniform(5.0, 40.0),
            idle_power=rng.uniform(0.05, 2.0)))
    return out


def _random_case(rng: random.Random):
    n = rng.randint(2, 24)
    blocks, bytes_in = [], rng.uniform(1e3, 1e7)
    for i in range(n):
        bytes_out = rng.uniform(1e3, 1e7)
        blocks.append(Block(name=f"b{i}", flops=rng.uniform(1e6, 1e12),
                            param_bytes=rng.uniform(1e3, 1e8),
                            bytes_in=bytes_in, bytes_out=bytes_out,
                            halo_fraction=rng.uniform(0.0, 0.2)))
        bytes_in = bytes_out
    dag = ModelDAG(name="h", blocks=tuple(blocks),
                   input_bytes=blocks[0].bytes_in,
                   output_bytes=blocks[-1].bytes_out)
    return dag, _tradeoff_resources(rng, rng.randint(1, 6))


@st.composite
def cases(draw):
    n = draw(st.integers(2, 24))
    blocks, bytes_in = [], draw(st.floats(1e3, 1e7))
    for i in range(n):
        bytes_out = draw(st.floats(1e3, 1e7))
        blocks.append(Block(name=f"b{i}", flops=draw(st.floats(1e6, 1e12)),
                            param_bytes=draw(st.floats(1e3, 1e8)),
                            bytes_in=bytes_in, bytes_out=bytes_out,
                            halo_fraction=draw(st.floats(0, 0.2))))
        bytes_in = bytes_out
    dag = ModelDAG(name="h", blocks=tuple(blocks),
                   input_bytes=blocks[0].bytes_in,
                   output_bytes=blocks[-1].bytes_out)
    m = draw(st.integers(1, 6))
    resources = []
    for i in range(m):
        speed = draw(st.floats(0.1, 1.0))
        resources.append(Resource(
            name=f"r{i}", rate=speed * draw(st.floats(1e10, 1e12)),
            bw=draw(st.floats(1e6, 1e9)), rtt=draw(st.floats(0, 5e-3)),
            active_power=(1.2 - speed) * draw(st.floats(5.0, 40.0)),
            idle_power=draw(st.floats(0.05, 2.0))))
    wt = draw(st.booleans())
    radio = draw(st.sampled_from([0.0, 0.7, 2.5]))
    width = draw(st.sampled_from([2, 3, 4, 8]))
    return dag, resources, wt, radio, width


def _scalar_snapshot(p):
    return (type(p).__name__, getattr(p, "boundaries", None),
            getattr(p, "fractions", None), p.assignment,
            p.predicted_latency)


def _front_snapshot(front):
    return [(pt.latency, pt.energy, _scalar_snapshot(pt.plan))
            for pt in front]


def _check_engines_agree(dag, resources, wt, radio, width):
    with dp.planner_engine("reference"):
        ref_scalar = dp.partition(dag, resources)
        ref_front = _front_snapshot(dp.partition_front(
            dag, resources, weight_transfer=wt, radio_power=radio,
            width=width))
    with dp.planner_engine("fast"):
        reset_workspaces()
        fast_scalar = dp.partition(dag, resources)
        fast_front = _front_snapshot(dp.partition_front(
            dag, resources, weight_transfer=wt, radio_power=radio,
            width=width))
    assert _scalar_snapshot(ref_scalar) == _scalar_snapshot(fast_scalar)
    assert ref_front == fast_front


# --------------------------------------------------------------------------
# fast == reference, bit-identically
# --------------------------------------------------------------------------

def test_engines_bit_identical_seeded():
    rng = random.Random(11)
    for _ in range(30):
        dag, resources = _random_case(rng)
        wt = rng.random() < 0.5
        radio = rng.choice([0.0, 0.7, 2.5])
        width = rng.choice([2, 3, 4, 8])
        _check_engines_agree(dag, resources, wt, radio, width)


@settings(max_examples=40, deadline=None)
@given(cases())
def test_engines_bit_identical_property(case):
    _check_engines_agree(*case)


def test_hierarchical_front_bit_identical_on_paper_models():
    def snap(front):
        out = []
        for p in front:
            d = plan_to_dict(p.plan)
            d.pop("planning_seconds", None)
            out.append((p.latency, p.energy, d))
        return out

    for cluster in (paper_cluster(), battery_cluster()):
        for name, fn in EDGE_MODELS.items():
            dag = fn()
            cfg = PlannerConfig(delta=MODEL_DELTA[name])
            with dp.planner_engine("reference"):
                ref = snap(plan_front(dag, cluster, cfg))
            with dp.planner_engine("fast"):
                reset_workspaces()
                fast = snap(plan_front(dag, cluster, cfg))
            assert ref == fast, f"{name} diverged on {cluster!r}"


# --------------------------------------------------------------------------
# incremental epoch re-planning
# --------------------------------------------------------------------------

def test_incremental_replan_is_byte_identical_and_reuses_rows():
    cluster = paper_cluster()
    dag = EDGE_MODELS["resnet152"]()
    planner = HiDPPlanner()
    masks = single_departure_masks(cluster)
    assert len(masks) == len(cluster.nodes)

    def snap(front):
        out = []
        for p in front:
            d = plan_to_dict(p.plan)
            d.pop("planning_seconds", None)
            out.append((p.latency, p.energy, d))
        return out

    # cold per membership: a fresh workspace for every mask
    cold = {}
    for mask in masks:
        reset_workspaces()
        cold[mask] = snap(planner.front(
            dag, cluster.with_availability(list(mask))))

    # warm: one workspace survives the full pass + every departure + the
    # return — plans must be byte-identical to the cold ones throughout
    reset_workspaces()
    ws = workspace_for(None)
    planner.front(dag, cluster)                     # full membership
    rows_before = ws.rows_reused
    for mask in masks:                              # each departure...
        assert snap(planner.front(
            dag, cluster.with_availability(list(mask)))) == cold[mask]
    full_again = planner.front(dag, cluster)        # ...and the return
    assert ws.rows_reused > rows_before, \
        "epoch re-plans recomputed every DP row — nothing was incremental"
    reset_workspaces()
    assert snap(full_again) == snap(planner.front(dag, cluster))


def test_prewarmed_departure_epoch_needs_zero_demand_dp():
    cluster = paper_cluster()
    gone = cluster.nodes[1].name
    trace = ChurnTrace([ChurnEvent(time=5.0, node=gone, kind="leave"),
                        ChurnEvent(time=9.0, node=gone, kind="join")])
    ctrl = FleetController(cluster, trace)
    cache = PlanCache(HiDPPlanner(), cluster, membership_source=ctrl)
    pw = SpeculativePrewarmer(cache, ctrl)
    tenants = [(fn(), MODEL_DELTA[name]) for name, fn in EDGE_MODELS.items()]

    for dag, delta in tenants:
        cache.front(dag, delta=delta)               # demand, full membership
    assert cache.misses == len(tenants)
    assert pw.prime() == len(tenants) * len(cluster.nodes)

    misses0 = cache.misses
    ctrl.advance(5.0)                               # the departure epoch
    for dag, delta in tenants:
        cache.front(dag, delta=delta)
    assert cache.misses == misses0, "departure epoch paid a demand DP pass"
    assert cache.prewarm_hits == len(tenants)
    assert cache.prewarm_misses == 0

    ctrl.advance(9.0)                               # the return epoch
    for dag, delta in tenants:
        cache.front(dag, delta=delta)
    assert cache.misses == misses0, "returning membership was not warm"

    s = cache.stats()
    assert s["prewarm_hits"] == len(tenants)
    assert s["prewarmed"] == pw.fronts_built
    assert pw.epochs_seen == 2


def test_prewarm_emits_spans_and_promotion_counters():
    from repro.telemetry import TelemetryRecorder
    tel = TelemetryRecorder("t")
    cluster = paper_cluster()
    gone = cluster.nodes[0].name
    ctrl = FleetController(
        cluster, ChurnTrace([ChurnEvent(time=1.0, node=gone, kind="leave")]))
    cache = PlanCache(HiDPPlanner(), cluster, membership_source=ctrl,
                      telemetry=tel)
    SpeculativePrewarmer(cache, ctrl)
    dag = EDGE_MODELS["vgg19"]()
    cache.front(dag)
    cache.prewarm()
    ctrl.advance(1.0)
    cache.front(dag)
    names = [e.name for e in tel.events]
    assert names.count("plan.prewarm") == cache.prewarmed
    assert "plan_cache.prewarm_hit" in names
    # the departure epoch itself never triggered a demand frontier pass
    assert (names.count("plan.frontier_pass")
            == 1 + names.count("plan_cache.prewarm_miss"))


def test_prewarm_inserts_are_first_eviction_victims():
    from repro.serving import LRUEviction
    cluster = paper_cluster()
    cache = PlanCache(HiDPPlanner(), cluster,
                      eviction=LRUEviction(max_entries=3))
    dag_a, dag_b = EDGE_MODELS["vgg19"](), EDGE_MODELS["inceptionv3"]()
    cache.front(dag_a)
    cache.front(dag_b)
    cache.prewarm(dags=[dag_a, dag_b])       # 2 tenants x 5 masks, cap 3
    tenants_left = cache.tenants()
    assert len(tenants_left) == 3
    # both demand entries survived; only speculative fronts were dropped
    assert cache.front(dag_a) is not None and cache.misses == 2
    assert cache.front(dag_b) is not None and cache.misses == 2


# --------------------------------------------------------------------------
# engine flag + workspace invalidation
# --------------------------------------------------------------------------

def test_engine_flag_switches_and_validates():
    assert dp.get_engine() == "fast"
    prev = dp.set_engine("reference")
    assert prev == "fast" and dp.get_engine() == "reference"
    with dp.planner_engine("fast"):
        assert dp.get_engine() == "fast"
    assert dp.get_engine() == "reference"
    with pytest.raises(ValueError):
        dp.set_engine("warp")


def test_reference_engine_never_touches_workspaces():
    cluster = paper_cluster()
    dag = EDGE_MODELS["vgg19"]()
    ws = workspace_for(None)
    with dp.planner_engine("reference"):
        HiDPPlanner().front(dag, cluster)
    assert ws.stats()["rows_computed"] == 0
    assert len(ws.front_rows) == 0 and len(ws.results) == 0


def test_model_revision_bump_orphans_cached_rows():
    model = LearnedCostModel()
    model.observe("edge", "conv", 1e9, 1e6, 0.01)
    prov = CalibratedCostProvider(model)
    dag, resources = _random_case(random.Random(3))
    dp.partition_front(dag, resources, provider=prov)
    ws = workspace_for(prov)
    assert ws is not None and len(ws.front_rows) > 0
    rev0 = ws.revision
    model.observe("edge", "conv", 2e9, 1e6, 0.02)   # refit → revision bump
    ws2 = workspace_for(prov)
    assert ws2 is ws and ws2.revision != rev0
    assert len(ws2.front_rows) == 0, "stale rows survived a calibration move"


def test_single_departure_masks_shape():
    cluster = paper_cluster()
    masks = single_departure_masks(cluster)
    n = len(cluster.nodes)
    assert len(masks) == n
    for mask in masks:
        assert sum(mask) == n - 1
    # a one-node fleet has no single-departure neighbours (never empty it)
    lone = cluster.with_availability([True] + [False] * (n - 1))
    assert single_departure_masks(lone) == []


def test_workspace_lru_bounds_and_mask_cache():
    ws = PlannerWorkspace()
    for i in range(40):
        _ = ws.valid_mask(i)
    assert len(ws._masks) <= 33
    m = ws.valid_mask(4)
    assert m.shape == (5, 5) and m[0, 1] and not m[1, 1] and not m[1, 0]
