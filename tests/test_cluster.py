"""Direct unit tests for ``HeartbeatMonitor`` and ``ClusterManager`` —
previously only exercised indirectly through the simulator.

The availability machinery is the substrate ``repro.fleet`` builds on, so
its edges are pinned here: dead-node expiry at *exactly* the timeout
boundary, leader re-election when the leader dies, and the
``refresh_availability`` round-trip (a node that resumes beating comes
back)."""

import pytest

from repro.core import ClusterManager, HeartbeatMonitor
from repro.core.edge_models import paper_cluster


# --------------------------------------------------------------------------
# HeartbeatMonitor
# --------------------------------------------------------------------------

def test_expiry_at_exactly_the_timeout_boundary():
    """alive ⇔ (now - last_seen) <= interval * miss_threshold: the boundary
    instant itself still counts as alive; any later instant does not."""
    mon = HeartbeatMonitor(interval=0.5, miss_threshold=3)
    mon.beat("a", 10.0)
    deadline = 10.0 + 0.5 * 3
    assert mon.alive("a", deadline)                 # exactly at the boundary
    assert not mon.alive("a", deadline + 1e-9)      # one tick past it
    assert mon.alive("a", 10.0)                     # trivially fresh


def test_never_seen_node_is_dead_and_beat_revives():
    mon = HeartbeatMonitor(interval=0.5, miss_threshold=3)
    assert not mon.alive("ghost", 0.0)
    mon.beat("ghost", 5.0)
    assert mon.alive("ghost", 5.0)
    # a fresh beat fully resets the expiry window
    mon.beat("ghost", 100.0)
    assert mon.alive("ghost", 101.4)
    assert not mon.alive("ghost", 101.6)


# --------------------------------------------------------------------------
# ClusterManager: leadership
# --------------------------------------------------------------------------

def test_elect_leader_requires_availability():
    mgr = ClusterManager(paper_cluster())
    mgr.set_available("tx2", False)
    with pytest.raises(RuntimeError):
        mgr.elect_leader("tx2")
    with pytest.raises(KeyError):
        mgr.elect_leader("not-a-node")
    assert mgr.elect_leader("orin_nx").name == "orin_nx"
    assert mgr.leader == "orin_nx"


def test_reelection_when_the_leader_dies():
    """The fail-over path: the sitting leader goes away, a survivor is
    electable, and the old leader's self-availability privilege dies with
    its seat."""
    mgr = ClusterManager(paper_cluster())
    mgr.elect_leader("orin_nx")
    assert mgr.leader_available()
    mgr.set_available("orin_nx", False)
    assert not mgr.leader_available()
    # deterministic fail-over candidate: first available declared node
    assert mgr.first_available().name == "tx2"
    mgr.elect_leader("tx2")
    assert mgr.leader == "tx2" and mgr.leader_available()
    # the deposed leader is no longer "available to itself": with no beats
    # at all, refresh marks everyone but the new leader dead
    cluster = mgr.refresh_availability(now=100.0)
    av = dict(zip((n.name for n in cluster.nodes), cluster.availability()))
    assert av["tx2"] == 1 and av["orin_nx"] == 0


def test_first_available_none_when_fleet_empty():
    mgr = ClusterManager(paper_cluster(2))
    for n in mgr.nodes():
        mgr.set_available(n.name, False)
    assert mgr.first_available() is None
    assert mgr.available_count() == 0
    assert not mgr.leader_available()


# --------------------------------------------------------------------------
# ClusterManager: refresh_availability round-trip
# --------------------------------------------------------------------------

def test_refresh_availability_round_trip():
    """Stop beating → dead after the timeout; resume beating → alive again.
    The leader never needs its own beats."""
    mgr = ClusterManager(paper_cluster())
    mgr.elect_leader("orin_nx")
    for n in mgr.nodes():
        mgr.monitor.beat(n.name, 0.0)
    # everyone fresh at t=1.0
    av = dict(zip((n.name for n in mgr.nodes()),
                  mgr.refresh_availability(1.0).availability()))
    assert all(av.values())
    # rpi4 goes silent; at t=2.0 it has missed > 3 intervals
    for n in mgr.nodes():
        if n.name != "rpi4":
            mgr.monitor.beat(n.name, 2.0)
    av = dict(zip((n.name for n in mgr.nodes()),
                  mgr.refresh_availability(2.0).availability()))
    assert av["rpi4"] == 0
    assert av["orin_nx"] == 1 and av["tx2"] == 1
    # rpi4 resumes beating: the very next refresh restores it
    mgr.monitor.beat("rpi4", 2.5)
    av = dict(zip((n.name for n in mgr.nodes()),
                  mgr.refresh_availability(2.5).availability()))
    assert av["rpi4"] == 1


def test_set_available_round_trip_and_counts():
    mgr = ClusterManager(paper_cluster())
    assert mgr.available_count() == 5
    mgr.set_available("nano", False)
    assert mgr.available_count() == 4
    assert not mgr.node("nano").available
    mgr.set_available("nano", True)
    assert mgr.available_count() == 5
    assert mgr.node("nano").available
