"""repro.telemetry.regress — snapshot schema, diff semantics, exit codes.

The contract CI leans on: self-diff is clean (rc 0), a genuine regression
in a gated metric fails (rc 1), wall-clock metrics never gate unless
asked, and losing a baseline metric counts as a regression (coverage
loss), not a silent pass.
"""

import json

import pytest

from repro.telemetry.regress import (DEFAULT_TOLERANCE, diff,
                                     load_snapshot, main, render_diff,
                                     snapshot, write_snapshot)

BASE = {
    "lat/sim": {"value": 1000.0, "unit": "sim_us", "direction": "lower"},
    "tp/ratio": {"value": 0.9, "unit": "ratio", "direction": "higher"},
    "wall/us": {"value": 500.0, "unit": "us", "direction": "lower"},
}


def _snap(metrics=BASE):
    return snapshot(metrics, suites=["unit"])


def test_snapshot_round_trip(tmp_path):
    path = write_snapshot(tmp_path / "sub" / "BENCH_x.json", BASE,
                          ["tab1", "fig8"])
    d = load_snapshot(path)
    assert d["suites"] == ["tab1", "fig8"]
    assert d["metrics"]["lat/sim"] == BASE["lat/sim"]
    # metric order is canonical (sorted) so snapshots diff cleanly as text
    assert list(d["metrics"]) == sorted(BASE)


def test_load_snapshot_rejects_bad_schema(tmp_path):
    p = tmp_path / "bad.json"
    p.write_text(json.dumps({"schema": 99, "metrics": {}}))
    with pytest.raises(ValueError, match="schema"):
        load_snapshot(p)
    p.write_text(json.dumps({"schema": 1}))
    with pytest.raises(ValueError, match="metrics"):
        load_snapshot(p)


def test_self_diff_is_clean():
    r = diff(_snap(), _snap())
    assert r.ok
    assert {e.status for e in r.entries} <= {"ok", "info"}
    # wall metric is informational, the others gated
    by_name = {e.name: e for e in r.entries}
    assert by_name["wall/us"].status == "info"
    assert by_name["lat/sim"].status == "ok"


def test_lower_is_better_regression_gates():
    cur = {**BASE, "lat/sim": {**BASE["lat/sim"], "value": 2000.0}}
    r = diff(_snap(), _snap(cur))
    assert not r.ok
    e = {x.name: x for x in r.entries}["lat/sim"]
    assert e.status == "regressed"
    assert e.rel == pytest.approx(1.0)          # +100% in the bad direction
    assert "REGRESSION" in render_diff(r)


def test_lower_is_better_improvement_passes():
    cur = {**BASE, "lat/sim": {**BASE["lat/sim"], "value": 500.0}}
    r = diff(_snap(), _snap(cur))
    assert r.ok
    assert {x.name: x for x in r.entries}["lat/sim"].status == "improved"


def test_higher_is_better_direction_flips():
    worse = {**BASE, "tp/ratio": {**BASE["tp/ratio"], "value": 0.5}}
    better = {**BASE, "tp/ratio": {**BASE["tp/ratio"], "value": 1.4}}
    assert not diff(_snap(), _snap(worse)).ok
    r = diff(_snap(), _snap(better))
    assert r.ok
    assert {x.name: x for x in r.entries}["tp/ratio"].status == "improved"


def test_wall_metrics_report_but_never_gate_unless_asked():
    cur = {**BASE, "wall/us": {**BASE["wall/us"], "value": 50_000.0}}
    assert diff(_snap(), _snap(cur)).ok
    assert not diff(_snap(), _snap(cur), gate_wall=True).ok


def test_missing_gated_metric_is_a_regression():
    cur = {k: v for k, v in BASE.items() if k != "lat/sim"}
    r = diff(_snap(), _snap(cur))
    assert not r.ok
    assert {x.name: x for x in r.entries}["lat/sim"].status == "missing"
    # but a missing *wall* metric is only informational
    cur2 = {k: v for k, v in BASE.items() if k != "wall/us"}
    assert diff(_snap(), _snap(cur2)).ok


def test_new_metric_is_informational():
    cur = {**BASE, "fresh": {"value": 1.0, "unit": "count",
                             "direction": "lower"}}
    r = diff(_snap(), _snap(cur))
    assert r.ok
    assert {x.name: x for x in r.entries}["fresh"].status == "new"


def test_per_metric_tolerance_override():
    cur = {**BASE, "lat/sim": {**BASE["lat/sim"], "value": 1200.0}}
    # +20% passes the default 25% but fails a 10% override
    assert diff(_snap(), _snap(cur)).ok
    assert DEFAULT_TOLERANCE == 0.25
    assert not diff(_snap(), _snap(cur),
                    tolerances={"lat/sim": 0.10}).ok


def test_zero_baseline_edge():
    base = {"n": {"value": 0.0, "unit": "count", "direction": "lower"}}
    same = diff(_snap(base), _snap(base))
    assert same.ok
    grew = diff(_snap(base), _snap(
        {"n": {"value": 3.0, "unit": "count", "direction": "lower"}}))
    assert not grew.ok                         # 0 → 3 is infinitely worse


def test_cli_exit_codes(tmp_path, capsys):
    base = write_snapshot(tmp_path / "base.json", BASE)
    assert main([str(base), str(base)]) == 0
    cur = write_snapshot(tmp_path / "cur.json", {
        **BASE, "lat/sim": {**BASE["lat/sim"], "value": 9000.0}})
    assert main([str(base), str(cur)]) == 1
    out = capsys.readouterr().out
    assert "regressed" in out and "lat/sim" in out
    # widened tolerance lets the same diff pass
    assert main([str(base), str(cur), "--tolerance", "10.0"]) == 0
    # unusable inputs are rc 2 (distinct from "regressed")
    assert main([str(base)]) == 2
    assert main([str(base), str(tmp_path / "nope.json")]) == 2


def test_bench_emit_feeds_snapshots(tmp_path):
    from benchmarks import common
    saved = dict(common.METRICS)
    try:
        common.METRICS.clear()
        common.emit("unit/x", 42.0, unit="sim_us")
        common.emit("unit/y", 1.5, "note", unit="ratio",
                    direction="higher")
        path = write_snapshot(tmp_path / "b.json", common.METRICS,
                              ["unit"])
        d = load_snapshot(path)
        assert d["metrics"]["unit/x"] == {
            "value": 42.0, "unit": "sim_us", "direction": "lower"}
        assert d["metrics"]["unit/y"]["direction"] == "higher"
        assert diff(d, d).ok
    finally:
        common.METRICS.clear()
        common.METRICS.update(saved)
