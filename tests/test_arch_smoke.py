"""Per-architecture smoke tests: reduced same-family configs, one forward /
train / prefill+decode step on CPU, asserting shapes and finiteness —
the FULL configs are exercised only via the dry-run (ShapeDtypeStructs)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import build_model
from repro.training import optimizer as optim
from repro.training.train_loop import loss_fn, make_train_step
from repro.sharding.plan import ShardingPlan, SINGLE_POD

B, S = 2, 32


def _batch(cfg, key):
    ks = jax.random.split(key, 3)
    b = {"tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab),
         "targets": jax.random.randint(ks[1], (B, S), 0, cfg.vocab)}
    if cfg.family == "audio":
        b["frames"] = jax.random.normal(ks[2], (B, S // 2, cfg.d_model),
                                        jnp.bfloat16) * 0.1
    if cfg.family == "vlm":
        b["vision"] = jax.random.normal(ks[2], (B, cfg.n_vision_tokens,
                                                cfg.d_model),
                                        jnp.bfloat16) * 0.1
    return b


@pytest.fixture(scope="module", params=ARCH_IDS)
def arch(request):
    cfg = get_config(request.param).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return request.param, cfg, model, params


def test_forward_shapes_and_finite(arch, rng):
    aid, cfg, model, params = arch
    logits = model.apply_train(params, _batch(cfg, rng), remat=False)
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.isfinite(logits).all()), aid


@pytest.mark.slow
def test_train_step_decreases_loss(arch, rng):
    aid, cfg, model, params = arch
    plan = ShardingPlan(arch=aid, shape="smoke", mesh=SINGLE_POD,
                        global_mode="data", local_layout="dp_tp",
                        batch_axes=(), remat=False)
    step = make_train_step(model, optim.OptConfig(lr=5e-3, warmup_steps=1),
                           plan)
    opt = optim.init(params)
    batch = _batch(cfg, rng)
    losses = []
    for _ in range(5):
        params, opt, metrics = step(params, opt, batch)
        losses.append(float(metrics["loss"]))
        assert np.isfinite(losses[-1]), aid
    assert losses[-1] < losses[0], (aid, losses)


def test_prefill_then_decode_matches_full_forward(arch, rng):
    """Exactness of the serving path: prefill P tokens then decode one —
    logits must match the full-sequence forward at that position (the
    paper's accuracy-preservation claim, §IV-B)."""
    aid, cfg, model, params = arch
    batch = _batch(cfg, rng)
    toks = batch["tokens"]
    P = S - 1
    pre = {k: v for k, v in batch.items() if k != "targets"}
    pre["tokens"] = toks[:, :P]
    pre["lengths"] = jnp.full((B,), P, jnp.int32)
    if cfg.family == "audio":
        pre["frames"] = batch["frames"]
    logits_p, pcache = model.apply_prefill(params, pre)

    # pad prefill cache out to S and decode token P
    full_cache = model.init_cache(B, S, enc_len=(S // 2 if cfg.family ==
                                                 "audio" else None))
    padded = {}
    for k in full_cache:
        dst, src = full_cache[k], pcache[k]
        if k in ("k", "v"):
            padded[k] = dst.at[..., :P, :, :].set(src)
        elif k in ("xk", "xv"):
            padded[k] = src if src.shape == dst.shape else dst.at[
                ..., :src.shape[-3], :, :].set(src)
        else:
            padded[k] = src        # recurrent state carries over exactly
    dec = {"tokens": toks[:, P:P + 1],
           "lengths": jnp.full((B,), P + 1, jnp.int32)}
    logits_d, _ = model.apply_decode(params, padded, dec)

    full = {k: v for k, v in batch.items() if k != "targets"}
    logits_f = model.apply_train(params, full, remat=False)
    want = logits_f[:, P]
    got = logits_d[:, 0]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=5e-2, rtol=5e-2)


def test_param_specs_match_init_structure(arch):
    aid, cfg, model, params = arch
    specs = model.param_specs()
    s = {jax.tree_util.keystr(p): leaf
         for p, leaf in jax.tree_util.tree_leaves_with_path(specs)}
    p = {jax.tree_util.keystr(pa): leaf
         for pa, leaf in jax.tree_util.tree_leaves_with_path(params)}
    assert s.keys() == p.keys()
    for key in s:
        assert tuple(s[key].shape) == tuple(p[key].shape), key


def test_full_config_param_counts():
    """Exact configs from the brief hit their published parameter counts."""
    expect = {"mistral-large-123b": (118e9, 127e9),
              "mixtral-8x7b": (45e9, 48e9),
              "qwen3-moe-30b-a3b": (29e9, 32e9),
              "mamba2-780m": (0.7e9, 1.0e9),
              "hymba-1.5b": (1.3e9, 1.9e9),
              "gemma-2b": (2.2e9, 2.8e9),
              "gemma3-1b": (0.9e9, 1.3e9),
              "minicpm-2b": (2.2e9, 3.0e9),
              "llama-3.2-vision-11b": (9.5e9, 12.5e9),
              "whisper-tiny": (0.02e9, 0.08e9)}
    for aid, (lo, hi) in expect.items():
        n = get_config(aid).params_total()
        assert lo <= n <= hi, (aid, n)


def test_moe_active_params():
    qw = get_config("qwen3-moe-30b-a3b")
    assert qw.params_active() < 0.2 * qw.params_total()
    mx = get_config("mixtral-8x7b")
    assert 0.2 < mx.params_active() / mx.params_total() < 0.35
