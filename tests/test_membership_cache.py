"""Membership-keyed cache identity: leave→return serves the bit-identical
warm front with zero DP work, and distinct memberships never collide.

The guarantees membership-keyed caching rides on (docs/fleet.md):

* ``membership_fingerprint`` is a pure function of the availability mask —
  the same nodes away always hash the same (property-tested via hypothesis
  when installed, and over seeded random masks regardless), and any two
  distinct masks hash differently;
* a node leaving is *not* an invalidation: fronts for distinct memberships
  live side by side, and a leave→return lookup lands back on the original
  entry — the identical ``ParetoFront`` object, zero additional DP passes;
* persisted fronts carry their membership, so a restarted process serves
  *every* membership it ever planned — including degraded ones — warm;
* ``persist_every`` bounds the damage of a crash to one generation.
"""

import itertools
import random

import pytest

from _hypothesis_compat import given, settings, st

from repro.core import (Block, HiDPPlanner, ModelDAG, Objective,
                        PlannerConfig, membership_fingerprint)
from repro.core.cluster import ClusterManager
from repro.core.edge_models import battery_cluster, paper_cluster
from repro.core.objective import METRICS
from repro.profiling import CalibrationStore
from repro.serving import PlanCache


def toy_dag(name: str, n: int = 5, flops: float = 2e9) -> ModelDAG:
    blocks = tuple(Block(name=f"{name}{i}", flops=flops, param_bytes=1e6,
                         bytes_in=4e5, bytes_out=4e5, kind="conv")
                   for i in range(n))
    return ModelDAG(name=name, blocks=blocks, input_bytes=4e5,
                    output_bytes=4e5)


def make_cache(cluster, manager, **kwargs) -> PlanCache:
    planner = HiDPPlanner(PlannerConfig(
        objective=Objective("energy", radio_power=4.0)))
    return PlanCache(planner, cluster, membership_source=manager, **kwargs)


# --------------------------------------------------------------------------
# fingerprint identity (property)
# --------------------------------------------------------------------------

def _mask_fingerprint(cluster, mask):
    return membership_fingerprint(cluster.with_availability(mask))


@settings(max_examples=60, deadline=None)
@given(st.lists(st.booleans(), min_size=5, max_size=5),
       st.lists(st.booleans(), min_size=5, max_size=5))
def test_membership_fingerprint_is_mask_identity(mask_a, mask_b):
    cluster = paper_cluster()
    fa = _mask_fingerprint(cluster, mask_a)
    assert fa == _mask_fingerprint(cluster, list(mask_a))   # pure function
    assert (fa == _mask_fingerprint(cluster, mask_b)) == (mask_a == mask_b)


def test_membership_fingerprints_never_collide_exhaustive():
    """All 2^5 masks of the paper cluster hash distinctly — the seeded
    twin of the property test, so the invariant executes everywhere."""
    cluster = paper_cluster()
    masks = list(itertools.product([True, False], repeat=5))
    fps = {_mask_fingerprint(cluster, m) for m in masks}
    assert len(fps) == len(masks)
    # and a random replay is stable
    rng = random.Random(11)
    for _ in range(20):
        m = [rng.random() < 0.5 for _ in range(5)]
        assert _mask_fingerprint(cluster, m) == _mask_fingerprint(cluster, m)


def test_membership_is_orthogonal_to_topology():
    """Availability never leaks into the cluster fingerprint and topology
    never leaks into the membership fingerprint."""
    from repro.core import cluster_fingerprint

    full = paper_cluster()
    degraded = full.with_availability([True, False, True, True, False])
    assert cluster_fingerprint(full) == cluster_fingerprint(degraded)
    assert membership_fingerprint(full) != membership_fingerprint(degraded)


# --------------------------------------------------------------------------
# leave → return: zero DP, bit-identical
# --------------------------------------------------------------------------

def test_leave_return_serves_bit_identical_front_with_zero_dp():
    cluster = battery_cluster()
    mgr = ClusterManager(cluster)
    cache = make_cache(cluster, mgr)
    dag = toy_dag("a")

    full_front = cache.front(dag)                 # full membership: 1 pass
    built = {m: cache.get(dag, m) for m in METRICS}
    assert cache.misses == 1

    mgr.set_available("tx2", False)               # the node leaves
    away_front = cache.front(dag)                 # degraded membership: pass 2
    assert cache.misses == 2
    assert away_front is not full_front
    assert all(a.node.name != "tx2"
               for p in away_front
               for a in p.plan.global_plan.assignments)

    mgr.set_available("tx2", True)                # ... and returns
    misses = cache.misses
    back = cache.front(dag)
    assert cache.misses == misses                 # ZERO DP work
    assert back is full_front                     # the very same object
    for m in METRICS:
        warm = cache.get(dag, m)
        want = built[m]
        assert warm.predicted_latency == want.predicted_latency
        assert warm.predicted_energy == want.predicted_energy
        assert warm.global_plan.partition == want.global_plan.partition
        assert warm.local_plans == want.local_plans
    # and the degraded front is still resident for the next outage
    mgr.set_available("tx2", False)
    assert cache.front(dag) is away_front
    assert cache.misses == misses


def test_distinct_memberships_never_collide_in_the_table():
    """Fronts planned under different masks occupy different keys even for
    the same tenant and δ — flipping membership can never serve a plan
    that books a departed node."""
    cluster = battery_cluster()
    mgr = ClusterManager(cluster)
    cache = make_cache(cluster, mgr)
    dag = toy_dag("a")
    seen_keys = set()
    for mask in ([True] * 5, [True, False, True, True, True],
                 [True, True, False, False, True]):
        mgr.cluster = cluster.with_availability(mask)
        key = cache.key(dag)
        assert key not in seen_keys
        seen_keys.add(key)
        cache.front(dag)
    assert cache.misses == 3 and len(cache) == 3


# --------------------------------------------------------------------------
# persistence: memberships side by side
# --------------------------------------------------------------------------

def test_persisted_fronts_keep_membership_side_by_side(tmp_path):
    cluster = battery_cluster()
    mgr = ClusterManager(cluster)
    store = CalibrationStore(tmp_path)
    cache = make_cache(cluster, mgr)
    dag = toy_dag("a")
    built_full = {m: cache.get(dag, m) for m in METRICS}
    mgr.set_available("nano", False)
    built_away = {m: cache.get(dag, m) for m in METRICS}
    assert cache.persist(store) == 2              # both memberships written

    # the restarted process starts degraded, then the node returns
    mgr2 = ClusterManager(cluster.with_availability(
        [True, True, False, True, True]))
    fresh = make_cache(cluster, mgr2, store=store)
    assert fresh.loaded == 2
    for m in METRICS:                             # degraded membership warm
        got = fresh.get(dag, m)
        assert got.predicted_latency == built_away[m].predicted_latency
        assert got.local_plans == built_away[m].local_plans
    mgr2.set_available("nano", True)
    for m in METRICS:                             # full membership warm too
        got = fresh.get(dag, m)
        assert got.predicted_latency == built_full[m].predicted_latency
        assert got.global_plan.partition == \
            built_full[m].global_plan.partition
        assert got.local_plans == built_full[m].local_plans
    assert fresh.misses == 0                      # zero DP work, ever


def test_persist_every_autopersists_on_insert(tmp_path):
    cluster = battery_cluster()
    mgr = ClusterManager(cluster)
    store = CalibrationStore(tmp_path)
    cache = make_cache(cluster, mgr, store=store, persist_every=2)
    assert not store.fronts_path(cluster).is_file()
    cache.front(toy_dag("a"))                     # insert 1: below period
    assert not store.fronts_path(cluster).is_file()
    cache.front(toy_dag("b", 6))                  # insert 2: flushed
    assert store.fronts_path(cluster).is_file()
    assert len(store.load_fronts(cluster)) == 2
    cache.front(toy_dag("c", 7))                  # insert 3: not yet
    assert len(store.load_fronts(cluster)) == 2
    # "a crashed process loses at most one generation": a cold restart
    # still serves everything the last flush covered
    fresh = make_cache(cluster, ClusterManager(cluster), store=store)
    assert fresh.loaded == 2
    fresh.front(toy_dag("a"))
    fresh.front(toy_dag("b", 6))
    assert fresh.misses == 0
    fresh.front(toy_dag("c", 7))                  # the lost generation
    assert fresh.misses == 1


def test_persist_every_validation():
    cluster = battery_cluster()
    planner = HiDPPlanner(PlannerConfig())
    with pytest.raises(ValueError, match="persist_every"):
        PlanCache(planner, cluster, persist_every=0,
                  store=CalibrationStore("/tmp/unused"))
    with pytest.raises(ValueError, match="store"):
        PlanCache(planner, cluster, persist_every=2)
