"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) and blocked-jnp
implementations vs. the pure-jnp naive oracles in kernels/ref.py."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest  # noqa: F401

from _hypothesis_compat import given, settings, st

from repro.kernels import decode_attention as da
from repro.kernels import flash_attention as fa
from repro.kernels import ref, ssd_scan

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


def _mk_qkv(key, b, tq, tk, hq, hkv, d, dtype):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, tq, hq, d)).astype(dtype)
    k = jax.random.normal(ks[1], (b, tk, hkv, d)).astype(dtype)
    v = jax.random.normal(ks[2], (b, tk, hkv, d)).astype(dtype)
    return q, k, v


SHAPES = [
    # (b, tq, tk, hq, hkv, d, window, causal, bq, bk)
    (1, 128, 128, 4, 4, 64, None, True, 64, 64),
    (2, 64, 64, 8, 2, 32, None, True, 16, 32),
    (2, 37, 53, 6, 3, 16, 12, True, 16, 16),
    (1, 32, 32, 4, 1, 128, None, False, 32, 16),
    (3, 1, 96, 8, 4, 64, None, True, 16, 32),
    (2, 80, 80, 5, 5, 48, 24, True, 32, 32),
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", SHAPES)
def test_flash_attention_pallas_vs_oracle(shape, dtype, rng):
    b, tq, tk, hq, hkv, d, win, caus, bq, bk = shape
    q, k, v = _mk_qkv(rng, b, tq, tk, hq, hkv, d, dtype)
    lens = jnp.asarray([tk] + [max(tk * 2 // 3, 1)] * (b - 1))
    want = ref.attention_naive(q, k, v, causal=caus, window=win,
                               q_offset=tk - tq, lengths=lens)
    got = fa.flash_attention(q, k, v, causal=caus, window=win,
                             q_offset=tk - tq, lengths=lens,
                             block_q=bq, block_k=bk, interpret=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", SHAPES)
def test_flash_attention_blocked_vs_oracle(shape, dtype, rng):
    b, tq, tk, hq, hkv, d, win, caus, bq, bk = shape
    q, k, v = _mk_qkv(rng, b, tq, tk, hq, hkv, d, dtype)
    lens = jnp.asarray([tk] + [max(tk // 2, 1)] * (b - 1))
    want = ref.attention_naive(q, k, v, causal=caus, window=win,
                               q_offset=tk - tq, lengths=lens)
    got = ref.attention_blocked(q, k, v, causal=caus, window=win,
                                q_offset=tk - tq, lengths=lens,
                                block_q=bq, block_k=bk)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


DECODE_SHAPES = [
    (2, 128, 8, 2, 64, None, 32),
    (3, 96, 4, 4, 32, 24, 32),
    (1, 64, 8, 1, 128, None, 64),
    (4, 256, 12, 3, 64, 100, 128),
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", DECODE_SHAPES)
def test_decode_attention_pallas_vs_oracle(shape, dtype, rng):
    b, s, hq, hkv, d, win, bk = shape
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (b, 1, hq, d)).astype(dtype)
    kc = jax.random.normal(ks[1], (b, s, hkv, d)).astype(dtype)
    vc = jax.random.normal(ks[2], (b, s, hkv, d)).astype(dtype)
    lens = jnp.asarray([s] + [max(s // 3, 1)] * (b - 1))
    want = ref.decode_attention_naive(q, kc, vc, lens, window=win)
    got = da.decode_attention(q, kc, vc, lens, window=win, block_k=bk,
                              interpret=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


# --------------------------------------------------------------------------
# SSD
# --------------------------------------------------------------------------

def _mk_ssd(key, b, t, nh, hd, n):
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, t, nh, hd)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, t, nh))) * 0.1
    A = -jnp.exp(jax.random.normal(ks[2], (nh,)))
    B = jax.random.normal(ks[3], (b, t, n)) * 0.3
    C = jax.random.normal(ks[4], (b, t, n)) * 0.3
    D = jnp.full((nh,), 0.1)
    return x, dt, A, B, C, D


SSD_SHAPES = [(1, 64, 4, 8, 16, 16), (2, 48, 2, 16, 8, 8),
              (1, 33, 3, 8, 4, 16), (2, 128, 8, 16, 32, 32)]


@pytest.mark.parametrize("shape", SSD_SHAPES)
def test_ssd_chunked_vs_naive(shape, rng):
    b, t, nh, hd, n, chunk = shape
    args = _mk_ssd(rng, b, t, nh, hd, n)
    y0, h0 = ref.ssd_naive(*args)
    y1, h1 = ref.ssd_chunked(*args, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0), atol=1e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h0), atol=1e-4)


@pytest.mark.parametrize("shape", SSD_SHAPES)
def test_ssd_pallas_vs_naive(shape, rng):
    b, t, nh, hd, n, chunk = shape
    args = _mk_ssd(rng, b, t, nh, hd, n)
    y0, h0 = ref.ssd_naive(*args)
    y1, h1 = ssd_scan.ssd(*args, chunk=chunk, interpret=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0), atol=1e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h0), atol=1e-4)


def test_ssd_decode_matches_scan_tail(rng):
    b, t, nh, hd, n = 2, 48, 4, 8, 16
    x, dt, A, B, C, D = _mk_ssd(rng, b, t, nh, hd, n)
    y_full, h_full = ref.ssd_naive(x, dt, A, B, C, D)
    _, h_prefix = ref.ssd_naive(x[:, :-1], dt[:, :-1], A, B[:, :-1],
                                C[:, :-1], D)
    y_last, h_last = ref.ssd_decode_step(h_prefix, x[:, -1], dt[:, -1], A,
                                         B[:, -1], C[:, -1], D)
    np.testing.assert_allclose(np.asarray(y_last), np.asarray(y_full[:, -1]),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(h_last), np.asarray(h_full),
                               atol=1e-5)


def test_ssd_state_carry_composes(rng):
    """Chunked prefill of [0:t1] then [t1:t] == one pass (h0 handoff)."""
    b, t, nh, hd, n = 1, 64, 2, 8, 8
    x, dt, A, B, C, D = _mk_ssd(rng, b, t, nh, hd, n)
    y_full, h_full = ref.ssd_chunked(x, dt, A, B, C, D, chunk=16)
    t1 = 32
    y1, h1 = ref.ssd_chunked(x[:, :t1], dt[:, :t1], A, B[:, :t1], C[:, :t1],
                             D, chunk=16)
    y2, h2 = ref.ssd_chunked(x[:, t1:], dt[:, t1:], A, B[:, t1:], C[:, t1:],
                             D, chunk=16, h0=h1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), atol=1e-4)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h_full), atol=1e-4)


# --------------------------------------------------------------------------
# property sweep: random shapes through blocked vs naive
# --------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(st.integers(1, 3), st.integers(1, 72), st.integers(1, 72),
       st.sampled_from([(4, 4), (4, 2), (8, 1), (6, 3)]),
       st.sampled_from([16, 32, 64]),
       st.booleans())
def test_attention_property_sweep(b, tq, tk, heads, d, causal):
    tk = max(tk, tq)                     # decode-style or square
    hq, hkv = heads
    key = jax.random.PRNGKey(tq * 1000 + tk)
    q, k, v = _mk_qkv(key, b, tq, tk, hq, hkv, d, jnp.float32)
    want = ref.attention_naive(q, k, v, causal=causal, q_offset=tk - tq)
    got = ref.attention_blocked(q, k, v, causal=causal, q_offset=tk - tq,
                                block_q=16, block_k=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=3e-5)
