"""Unit tests for the dry-run tooling that doesn't need 512 devices:
the HLO collective-bytes parser and the roofline term arithmetic."""

import jax.numpy as jnp

from repro.launch.dryrun import collective_bytes
from repro.models import SHAPES, build_model
from repro.configs import get_config


HLO = """
HloModule test
  %x = f32[128,256]{1,0} parameter(0)
  %ag = f32[512,256]{1,0} all-gather(%x), replica_groups={{0,1,2,3}}
  %ar = bf16[64,64]{1,0} all-reduce(%y), to_apply=%add
  %rs = f32[32]{0} reduce-scatter(%z), to_apply=%add
  %a2a = (f32[4,8]{1,0}, f32[4,8]{1,0}) all-to-all(%p, %q)
  %cp = u32[16,2]{1,0} collective-permute(%w), source_target_pairs={{0,1}}
  %dot = f32[999,999]{1,0} dot(%a, %b)
"""


def test_collective_bytes_parser():
    out = collective_bytes(HLO)
    assert out["all-gather"] == 512 * 256 * 4
    assert out["all-reduce"] == 64 * 64 * 2
    assert out["reduce-scatter"] == 32 * 4
    assert out["all-to-all"] == 2 * 4 * 8 * 4
    assert out["collective-permute"] == 16 * 2 * 4
    assert out["total"] == sum(v for k, v in out.items() if k != "total")


def test_collective_bytes_empty():
    assert collective_bytes("%dot = f32[8,8] dot(%a, %b)")["total"] == 0


def test_executed_flops_overheads():
    """Executed-FLOPs model: train ≥ 4/3 × useful (remat); dense MoE adds
    the all-expert waste; EP adds only capacity padding."""
    from benchmarks.roofline import executed_flops
    cfg = get_config("qwen3-moe-30b-a3b")
    model = build_model(cfg)
    shape = SHAPES["train_4k"]
    useful = model.step_flops(shape)
    dense = executed_flops(model, shape, {"moe_impl": "dense"})
    ep = executed_flops(model, shape, {"moe_impl": "ep_a2a"})
    assert dense > 4 * useful          # 16× waste on the ffn share
    assert useful * 4 / 3 < ep < dense / 3
    # dense LM: only remat + attention masking overheads
    g = build_model(get_config("gemma-2b"))
    ge = executed_flops(g, shape, {"moe_impl": "dense"})
    assert 4 / 3 * g.step_flops(shape) <= ge <= 2.5 * g.step_flops(shape)


def test_step_flops_sanity():
    """6·N·D within 2× for a dense LM at train (attention/head extras)."""
    cfg = get_config("mistral-large-123b")
    model = build_model(cfg)
    shape = SHAPES["train_4k"]
    six_nd = 6.0 * cfg.params_total() * shape.global_batch * shape.seq_len
    got = model.step_flops(shape)
    assert 0.8 * six_nd < got < 2.0 * six_nd
