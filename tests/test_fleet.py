"""repro.fleet — churn traces, membership epochs, and mid-request fault
injection through the simulator.

The churn-aware guarantees, as tests (docs/fleet.md):

* traces are seeded and replayable: the same generator arguments always
  produce the same events, and generated schedules stay plausible (only
  present nodes leave, only absent nodes rejoin);
* a ``FleetController`` coalesces simultaneously-applied events into one
  membership epoch, re-elects the leader when it falls, and a
  leave-then-return flips the membership fingerprint back to its original
  value — the identity membership-keyed caching rides on;
* a ``crash`` mid-request fails its shards: the request re-plans on the
  survivors and retries to completion, the crashed node executes nothing
  past the crash instant, and ``SimReport`` accounts
  retries/migrations/SLO violations per request;
* with a membership-keyed ``PlanCache``, a churn stream costs exactly one
  frontier pass per (tenant, membership) — and a returning membership
  costs none at all.
"""

import pytest

from repro.core import (EdgeSimulator, HiDPPlanner, Objective,
                        PlannerConfig, SimRequest, membership_fingerprint,
                        simulate)
from repro.core.edge_models import (EDGE_MODELS, MODEL_DELTA, paper_cluster)
from repro.fleet import (DOWN_KINDS, UP_KINDS, ChurnEvent, ChurnTrace,
                         FleetController)
from repro.serving import PlanCache


def dag_delta(name="resnet152"):
    return EDGE_MODELS[name](), MODEL_DELTA[name]


# --------------------------------------------------------------------------
# traces
# --------------------------------------------------------------------------

def test_event_kinds_validated():
    with pytest.raises(ValueError):
        ChurnEvent(0.0, "a", "explode")
    assert ChurnEvent(0.0, "a", "crash").is_failure
    assert not ChurnEvent(0.0, "a", "leave").is_failure
    assert ChurnEvent(0.0, "a", "battery_drain").goes_down
    assert not ChurnEvent(0.0, "a", "battery_ok").goes_down


def test_scripted_trace_sorts_and_windows():
    tr = ChurnTrace.scripted([(2.0, "b", "join"), (1.0, "a", "crash")])
    assert [e.time for e in tr] == [1.0, 2.0]
    assert tr.window(0.0, 1.0) == (tr.events[0],)      # half-open: (t0, t1]
    assert tr.window(1.0, 5.0) == (tr.events[1],)


def test_poisson_trace_is_seeded_and_plausible():
    names = ["a", "b", "c"]
    t1 = ChurnTrace.poisson(names, rate=0.5, horizon=100.0, seed=7)
    t2 = ChurnTrace.poisson(names, rate=0.5, horizon=100.0, seed=7)
    t3 = ChurnTrace.poisson(names, rate=0.5, horizon=100.0, seed=8)
    assert t1.events == t2.events                       # replayable
    assert t1.events != t3.events                       # seed matters
    assert len(t1) > 0
    # plausibility: a node's events strictly alternate down/up
    for n in names:
        kinds = [e.kind in DOWN_KINDS for e in t1 if e.node == n]
        assert all(a != b for a, b in zip(kinds, kinds[1:]))
        if kinds:
            assert kinds[0]                             # starts present
    # protected nodes are never touched
    prot = ChurnTrace.poisson(names, rate=0.5, horizon=100.0, seed=7,
                              protect=["a"])
    assert all(e.node != "a" for e in prot)


def test_battery_and_thermal_duty_cycles_alternate():
    tr = ChurnTrace.battery(["a", "b"], drain_after=10.0,
                            recharge_after=5.0, horizon=40.0, stagger=1.0)
    for n in ("a", "b"):
        evs = [e for e in tr if e.node == n]
        assert [e.kind in DOWN_KINDS for e in evs][::2] == \
            [True] * len(evs[::2])
        assert all(e.kind in {"battery_drain", "battery_ok"} for e in evs)
    th = ChurnTrace.thermal(["a"], throttle_after=3.0, cool_after=2.0,
                            horizon=12.0)
    assert [e.kind for e in th] == ["thermal_throttle", "recover",
                                    "thermal_throttle", "recover"][:len(th)]
    assert all(k in (DOWN_KINDS | UP_KINDS) for k in
               {e.kind for e in tr.merge(th)})


def test_merge_keeps_time_order():
    a = ChurnTrace.scripted([(1.0, "a", "leave"), (3.0, "a", "join")])
    b = ChurnTrace.scripted([(2.0, "b", "crash")])
    assert [e.time for e in a.merge(b)] == [1.0, 2.0, 3.0]


# --------------------------------------------------------------------------
# controller: epochs, leadership, membership identity
# --------------------------------------------------------------------------

def test_controller_epochs_coalesce_and_fingerprint_returns():
    cluster = paper_cluster()
    fp0 = membership_fingerprint(cluster)
    trace = ChurnTrace.scripted([
        (1.0, "tx2", "leave"), (1.0, "nano", "leave"),   # same instant
        (5.0, "tx2", "join"), (6.0, "nano", "join"),
    ])
    seen = []
    fleet = FleetController(cluster, trace,
                            on_epoch=lambda ep: seen.append(ep))
    assert fleet.epoch == 0
    assert fleet.membership_fingerprint() == fp0
    applied = fleet.advance(2.0)
    assert len(applied) == 2
    assert fleet.epoch == 1                    # two events, ONE epoch
    assert fleet.membership_fingerprint() != fp0
    assert fleet.available_names() == ("orin_nx", "rpi5", "rpi4")
    fleet.advance(5.5)
    assert fleet.epoch == 2
    fleet.advance(10.0)
    assert fleet.epoch == 3
    # leave → return restores the exact membership identity
    assert fleet.membership_fingerprint() == fp0
    assert [ep.epoch for ep in seen] == [1, 2, 3]
    assert seen[0].events == applied
    # replayability: a fresh controller over the same trace, advanced
    # through the same instants, re-derives the same epoch history
    again = FleetController(paper_cluster(), trace)
    for t in (2.0, 5.5, 10.0):
        again.advance(t)
    assert [ep.fingerprint for ep in again.epochs] == \
        [ep.fingerprint for ep in [fleet.epochs[0]] + seen]
    # whereas one big advance coalesces the whole (net-zero) trace into
    # zero epochs — coalescing is per advance call, by design
    coalesced = FleetController(paper_cluster(), trace)
    coalesced.advance(10.0)
    assert coalesced.epoch == 0


def test_controller_reelects_fallen_leader_and_forgets_feedback():
    class SpyLoop:
        forgotten = []

        def forget_resource(self, node):
            self.forgotten.append(node)
            return 1

    cluster = paper_cluster()
    fleet = FleetController(cluster,
                            ChurnTrace.scripted([(1.0, "orin_nx", "crash")]),
                            feedback=SpyLoop())
    assert fleet.leader == "orin_nx"           # auto-elected at construction
    fleet.advance(2.0)
    assert fleet.leader == "tx2"               # first available survivor
    assert fleet.leader_elections == 1
    assert SpyLoop.forgotten == ["orin_nx"]


def test_controller_noop_epoch_when_events_cancel():
    """A leave+join of the same node inside one advance window nets out:
    no membership change, no epoch, no callback."""
    fired = []
    fleet = FleetController(
        paper_cluster(),
        ChurnTrace.scripted([(1.0, "nano", "leave"), (1.5, "nano", "join")]),
        on_epoch=lambda ep: fired.append(ep))
    applied = fleet.advance(2.0)
    assert len(applied) == 2
    assert fleet.epoch == 0 and not fired


def test_next_failure_peeks_without_consuming():
    fleet = FleetController(
        paper_cluster(),
        ChurnTrace.scripted([(1.0, "nano", "leave"),
                             (2.0, "tx2", "crash"),
                             (3.0, "rpi5", "crash")]))
    # peek ignores non-failures and off-plan nodes, honours the window
    assert fleet.next_failure(0.0, 5.0, {"tx2"}).time == 2.0
    assert fleet.next_failure(0.0, 5.0, {"rpi5"}).time == 3.0
    assert fleet.next_failure(0.0, 1.5, {"tx2", "rpi5"}) is None
    assert fleet.next_failure(2.0, 5.0, {"tx2"}) is None   # (start, end]
    # nothing was consumed: the graceful leave still applies at advance
    assert fleet.advance(1.0)[0].kind == "leave"


# --------------------------------------------------------------------------
# simulator fault injection
# --------------------------------------------------------------------------

def test_crash_mid_request_retries_to_completion():
    dag, delta = dag_delta()
    solo = simulate(paper_cluster(), "hidp", [(0.0, dag, delta)])
    clean_latency = solo.records[0].latency
    # crash a mid-tier node well inside the first request's window
    trace = ChurnTrace.scripted([(clean_latency * 0.4, "tx2", "crash")])
    fleet = FleetController(paper_cluster(), trace)
    sim = EdgeSimulator(paper_cluster(), "hidp", fleet=fleet)
    rep = sim.run([SimRequest(0, dag, 0.0, delta)])
    r = rep.records[0]
    assert r.retries == 1
    assert r.migrations >= 1                    # tx2's shards moved
    assert r.latency > clean_latency            # the retry costs real time
    # the casualty executes nothing past the crash instant
    crash_t = trace.events[0].time
    assert all(s.end <= crash_t + 1e-12 for s in rep.spans
               if s.node == "tx2")
    # survivors carry the retried attempt to completion
    assert {s.node for s in rep.spans if s.start > crash_t}
    assert rep.total_retries() == 1 and rep.total_migrations() >= 1


def test_leader_crash_reelects_and_completes():
    dag, delta = dag_delta()
    solo = simulate(paper_cluster(), "hidp", [(0.0, dag, delta)])
    trace = ChurnTrace.scripted(
        [(solo.records[0].latency * 0.5, "orin_nx", "crash")])
    fleet = FleetController(paper_cluster(), trace)
    sim = EdgeSimulator(paper_cluster(), "hidp", fleet=fleet)
    rep = sim.run([SimRequest(0, dag, 0.0, delta)])
    assert rep.records[0].retries == 1
    assert sim.leader != "orin_nx"
    assert sim.leader_elections == 1
    assert fleet.leader == sim.leader
    assert all(s.node != "orin_nx" for s in rep.spans
               if s.start > trace.events[0].time)


def test_graceful_leave_never_fails_in_flight_work():
    """A ``leave`` between requests re-plans the *next* request around the
    absent node; nothing retries."""
    dag, delta = dag_delta()
    trace = ChurnTrace.scripted([(0.01, "tx2", "leave")])
    fleet = FleetController(paper_cluster(), trace)
    sim = EdgeSimulator(paper_cluster(), "hidp", fleet=fleet)
    rep = sim.run([SimRequest(0, dag, 0.0, delta),
                   SimRequest(1, dag, 5.0, delta)])
    assert rep.total_retries() == 0
    # request 0 planned before the leave and may use tx2; request 1 not
    assert all(s.node != "tx2" for s in rep.spans if s.request_id == 1)


def test_slo_accounting_under_churn():
    dag, delta = dag_delta()
    solo = simulate(paper_cluster(), "hidp", [(0.0, dag, delta)])
    slo = solo.records[0].latency * 1.2         # clean run fits, retry won't
    trace = ChurnTrace.scripted([(slo * 0.5, "tx2", "crash"),
                                 (30.0, "tx2", "join")])
    fleet = FleetController(paper_cluster(), trace)
    sim = EdgeSimulator(paper_cluster(), "hidp", fleet=fleet)
    rep = sim.run([SimRequest(0, dag, 0.0, delta, slo=slo),
                   SimRequest(1, dag, 60.0, delta, slo=slo)])
    assert rep.records[0].slo_violated          # paid a retry
    assert not rep.records[1].slo_violated      # clean post-churn request
    assert rep.slo_violations() == 1


def test_all_nodes_dead_raises():
    dag, delta = dag_delta("efficientnet_b0")
    cluster = paper_cluster(2)
    trace = ChurnTrace.scripted([(0.05, "orin_nx", "crash"),
                                 (0.05, "tx2", "crash")])
    fleet = FleetController(cluster, trace)
    sim = EdgeSimulator(cluster, "hidp", fleet=fleet)
    with pytest.raises(RuntimeError, match="every node failed"):
        sim.run([SimRequest(0, dag, 0.0, delta)])


# --------------------------------------------------------------------------
# churn + membership-keyed cache, end to end
# --------------------------------------------------------------------------

def make_cache(cluster, fleet):
    planner = HiDPPlanner(PlannerConfig(
        objective=Objective("energy", radio_power=4.0)))
    return PlanCache(planner, cluster, membership_source=fleet)


def test_churn_stream_one_replan_per_tenant_per_membership():
    """The end-to-end gate: a node leaves and returns mid-stream.  Each
    (tenant, membership) pair pays exactly one frontier pass; the
    returning membership costs zero DP work."""
    names = ["resnet152", "vgg19"]
    dags = {n: EDGE_MODELS[n]() for n in names}
    cluster = paper_cluster()
    trace = ChurnTrace.scripted([(2.0, "nano", "leave"),
                                 (4.0, "nano", "join")])
    fleet = FleetController(cluster, trace)
    cache = make_cache(cluster, fleet)
    sim = EdgeSimulator(cluster, "hidp", plan_cache=cache, fleet=fleet)
    wl = [SimRequest(i, dags[names[i % 2]], 0.8 * i,
                     MODEL_DELTA[names[i % 2]]) for i in range(9)]
    rep = sim.run(wl)
    assert len(rep.records) == 9 and rep.total_retries() == 0
    # 2 tenants × 2 distinct memberships (full, no-nano) = 4 passes; the
    # return to full membership re-serves the original warm fronts
    assert cache.misses == 4
    assert cache.hits == len(wl) - 4
    assert fleet.epoch == 2


def test_crash_replan_goes_through_membership_keyed_cache():
    dag, delta = dag_delta()
    cluster = paper_cluster()
    solo = simulate(cluster, "hidp", [(0.0, dag, delta)])
    trace = ChurnTrace.scripted(
        [(solo.records[0].latency * 0.4, "tx2", "crash")])
    fleet = FleetController(cluster, trace)
    cache = make_cache(cluster, fleet)
    sim = EdgeSimulator(cluster, "hidp", plan_cache=cache, fleet=fleet)
    rep = sim.run([SimRequest(i, dag, 3.0 * i, delta) for i in range(3)])
    assert rep.total_retries() == 1
    # one pass for the full membership, one for the post-crash membership —
    # the retry's re-plan IS that second pass (exactly one per tenant per
    # epoch); both later requests resolve warm against it
    assert cache.misses == 2
    assert cache.hits == 2
    # the post-crash plan books nothing on the casualty
    post = trace.events[0].time
    assert all(s.node != "tx2" for s in rep.spans if s.start > post)


def test_membership_blind_cache_with_fleet_is_rejected():
    cluster = paper_cluster()
    fleet = FleetController(cluster, ChurnTrace())
    planner = HiDPPlanner(PlannerConfig())
    blind = PlanCache(planner, cluster)
    with pytest.raises(ValueError, match="membership"):
        EdgeSimulator(cluster, "hidp", plan_cache=blind, fleet=fleet)
