"""Degrade gracefully when ``hypothesis`` is absent.

With hypothesis installed this re-exports the real API.  Without it, the
property-based tests are skipped *individually* (``@given`` becomes a skip
marker and strategy constructors become inert), so the deterministic tests
in the same module still collect and run — instead of the whole module
erroring at import time.
"""

import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco

    class _InertStrategies:
        """Any ``st.<name>(...)`` returns None; ``st.composite`` returns a
        callable so ``@st.composite``-decorated strategies stay callable."""

        def __getattr__(self, name):
            if name == "composite":
                def composite(fn):
                    def strategy(*_a, **_k):
                        return None
                    return strategy
                return composite

            def strategy(*_a, **_k):
                return None
            return strategy

    st = _InertStrategies()
