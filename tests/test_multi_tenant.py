"""Multi-tenant PlanCache: bounded eviction, persistence, stale-version
hygiene.

The serving-layer guarantees, as tests (docs/serving.md):

* eviction never exceeds its entry/byte budget and never evicts the
  in-flight tenant — a request can always be served from the front it
  just built;
* persisted fronts round-trip bit-identically: selection on a loaded
  front equals selection on the freshly built one, for every objective;
* a fresh cache warmed from ``CalibrationStore`` serves every persisted
  tenant's first request with zero DP work (the restart-warm gate
  ``benchmarks/tab1_planner_overhead.py`` also enforces);
* entries persisted under an older calibration version are dropped on
  load — a stale front can never serve.
"""

import dataclasses

import pytest

from repro.core import (Block, HiDPPlanner, ModelDAG, Objective,
                        PlannerConfig, dag_fingerprint, simulate)
from repro.core.edge_models import EDGE_MODELS, MODEL_DELTA, battery_cluster
from repro.core.objective import METRICS
from repro.profiling import CalibrationStore
from repro.serving import LRUEviction, PlanCache


def toy_dag(name: str, n: int = 5, flops: float = 2e9) -> ModelDAG:
    blocks = tuple(Block(name=f"{name}{i}", flops=flops, param_bytes=1e6,
                         bytes_in=4e5, bytes_out=4e5, kind="conv")
                   for i in range(n))
    return ModelDAG(name=name, blocks=blocks, input_bytes=4e5,
                    output_bytes=4e5)


@pytest.fixture()
def cluster():
    return battery_cluster()


def make_cache(cluster, **kwargs) -> PlanCache:
    planner = HiDPPlanner(PlannerConfig(
        objective=Objective("energy", radio_power=4.0)))
    return PlanCache(planner, cluster, **kwargs)


# --------------------------------------------------------------------------
# eviction
# --------------------------------------------------------------------------

def test_entry_budget_never_exceeded_lru_order(cluster):
    cache = make_cache(cluster, eviction=LRUEviction(max_entries=2))
    a, b, c = toy_dag("a"), toy_dag("b", 6), toy_dag("c", 7)
    cache.front(a)
    cache.front(b)
    assert len(cache) == 2 and cache.evictions == 0
    cache.front(a)                       # refresh a's LRU position
    cache.front(c)                       # over budget: b is LRU → evicted
    assert len(cache) == 2
    assert cache.evictions == 1
    assert cache.tenants() == ("a", "c")
    # the evicted tenant is not an error — it re-plans and re-enters
    misses = cache.misses
    cache.front(b)
    assert cache.misses == misses + 1 and cache.tenants() == ("c", "b")


def test_byte_budget_never_evicts_in_flight_tenant(cluster):
    # a byte budget smaller than any single front: every insert overflows,
    # but the entry the current request just built must survive
    cache = make_cache(cluster, eviction=LRUEviction(max_bytes=1))
    a, b = toy_dag("a"), toy_dag("b", 6)
    cache.front(a)
    assert cache.tenants() == ("a",) and cache.nbytes() > 1
    cache.front(b)                       # a evicted, b (in-flight) kept
    assert cache.tenants() == ("b",)
    assert cache.evictions == 1
    # and b's request is served from the surviving front: a hit
    hits = cache.hits
    cache.get(b, "edp")
    assert cache.hits == hits + 1


def test_byte_budget_bounds_table(cluster):
    unbounded = make_cache(cluster)
    dags = [toy_dag(n, 5 + i) for i, n in enumerate("abcd")]
    for d in dags:
        unbounded.front(d)
    per_entry = unbounded.nbytes() // len(dags)
    budget = int(per_entry * 2.5)        # fits 2, not 3
    cache = make_cache(cluster, eviction=LRUEviction(max_bytes=budget))
    for d in dags:
        cache.front(d)
        assert cache.nbytes() <= budget
    assert len(cache) == 2 and cache.evictions == 2


def test_eviction_policy_validates():
    with pytest.raises(ValueError):
        LRUEviction(max_entries=0)
    with pytest.raises(ValueError):
        LRUEviction(max_bytes=0)


# --------------------------------------------------------------------------
# persistence: restart-warm serving
# --------------------------------------------------------------------------

def test_persisted_front_roundtrip_is_bit_identical(cluster, tmp_path):
    store = CalibrationStore(tmp_path)
    cache = make_cache(cluster)
    tenants = [("efficientnet_b0", EDGE_MODELS["efficientnet_b0"]()),
               ("vgg19", EDGE_MODELS["vgg19"]())]
    built = {}
    for name, dag in tenants:
        delta = MODEL_DELTA[name]
        for metric in METRICS:
            built[(name, metric)] = cache.get(dag, metric, delta=delta)
    assert cache.persist(store) == len(tenants)
    assert store.fronts_path(cluster).is_file()      # next to calibrations

    fresh = make_cache(cluster, store=store)         # "the restart"
    assert fresh.loaded == len(tenants)
    for name, dag in tenants:
        delta = MODEL_DELTA[name]
        for metric in METRICS:
            warm = fresh.get(dag, metric, delta=delta)
            want = built[(name, metric)]
            # selection off the loaded front == selection off the built
            # front, bit for bit
            assert warm.predicted_latency == want.predicted_latency
            assert warm.predicted_energy == want.predicted_energy
            assert warm.global_plan.partition == want.global_plan.partition
            assert warm.global_plan.assignments == \
                want.global_plan.assignments
            assert warm.local_plans == want.local_plans
    # every tenant's every request was served with zero DP work
    assert fresh.misses == 0
    assert fresh.hits == len(tenants) * len(METRICS)


def test_restart_warm_serves_simulated_stream_with_zero_dp(cluster,
                                                           tmp_path):
    store = CalibrationStore(tmp_path)
    cache = make_cache(cluster)
    dag = EDGE_MODELS["efficientnet_b0"]()
    delta = MODEL_DELTA["efficientnet_b0"]
    cache.front(dag, delta)
    cache.persist(store)
    fresh = make_cache(cluster, store=store)
    rep = simulate(cluster, "hidp", [(0.1 * i, dag, delta)
                                     for i in range(4)], plan_cache=fresh)
    assert len(rep.records) == 4
    assert fresh.misses == 0 and fresh.hits == 4
    # warm lookups report lookup time, not DP time
    assert all(r.completion - r.arrival < 60 for r in rep.records)


def test_stale_version_entries_dropped_on_load(cluster, tmp_path):
    store = CalibrationStore(tmp_path)
    cache = make_cache(cluster)
    dag = toy_dag("a")
    cache.front(dag)
    cache.persist(store)                  # persisted at version 0
    # the calibration moved on before the restart: version 1 ≠ 0
    stale = make_cache(cluster, version=1, store=store)
    assert stale.loaded == 0 and len(stale) == 0
    misses = stale.misses
    stale.front(dag)                      # must re-plan, never serve stale
    assert stale.misses == misses + 1


def test_reprofiled_store_invalidates_persisted_fronts(cluster, tmp_path):
    """The durable stale anchor: a new *on-disk* calibration between
    persist and restart drops the persisted fronts even though the
    in-memory version counters collide (both processes start at 0)."""
    from repro.profiling import LearnedCostModel

    store = CalibrationStore(tmp_path)
    cache = make_cache(cluster)
    dag = toy_dag("a")
    cache.front(dag)
    cache.persist(store)                  # counter 0, no calibration yet
    store.save(cluster, LearnedCostModel())   # the fleet re-profiles
    restarted = make_cache(cluster, store=store)  # counter 0 again
    assert restarted.loaded == 0, \
        "front persisted before a re-profiling must never serve after it"
    misses = restarted.misses
    restarted.front(dag)
    assert restarted.misses == misses + 1


def test_persist_requires_matching_generation_version(cluster, tmp_path):
    """Fronts persisted right before a bump carry the old version and are
    dropped by a loader living at the new one."""
    store = CalibrationStore(tmp_path)
    cache = make_cache(cluster)
    cache.front(toy_dag("a"))
    cache.persist(store)
    cache.bump_version()                  # drift after persisting
    reloaded = make_cache(cluster, version=cache.version, store=store)
    assert reloaded.loaded == 0


def test_warm_from_respects_eviction_budget(cluster, tmp_path):
    store = CalibrationStore(tmp_path)
    cache = make_cache(cluster)
    for i, n in enumerate("abc"):
        cache.front(toy_dag(n, 5 + i))
    assert cache.persist(store) == 3
    bounded = make_cache(cluster, store=store,
                         eviction=LRUEviction(max_entries=2))
    assert len(bounded) == 2
    assert bounded.evictions == 1


def test_persist_without_store_raises(cluster):
    cache = make_cache(cluster)
    with pytest.raises(ValueError):
        cache.persist()
    with pytest.raises(ValueError):
        cache.warm_from()


# --------------------------------------------------------------------------
# mixed-tenant streams through one shared cache
# --------------------------------------------------------------------------

def test_shared_cache_serves_mixed_tenant_stream(cluster):
    cache = make_cache(cluster)
    names = [n for n in list(EDGE_MODELS)[:2]]
    wl = [(0.05 * i, EDGE_MODELS[names[i % 2]](),
           MODEL_DELTA[names[i % 2]]) for i in range(8)]
    rep = simulate(cluster, "hidp", wl, plan_cache=cache)
    assert len(rep.records) == 8
    # one frontier pass per tenant, everything else a hit
    assert cache.misses == 2 and cache.hits == 6
    assert sorted(cache.tenants()) == sorted(names)


def test_dag_fingerprint_distinguishes_same_named_tenants(cluster):
    cache = make_cache(cluster)
    a = toy_dag("same", 5)
    b = dataclasses.replace(toy_dag("same", 5),
                            blocks=toy_dag("same", 5).blocks[:-1])
    assert dag_fingerprint(a) != dag_fingerprint(b)
    cache.front(a)
    cache.front(b)
    assert cache.misses == 2 and len(cache) == 2
