"""repro.profiling: Analyzer → LearnedCostModel → CalibratedCostProvider →
planner/simulator, and the drift-triggered feedback loop.

Also the refactor's regression guarantee: with the analytic CostProvider the
planner and simulator are numerically identical to planning without one.
"""

import math

import pytest

from repro.core import (Block, Cluster, ModelDAG, Node, Processor, chain,
                        plan, simulate, PlannerConfig)
from repro.core.cost_model import ANALYTIC
from repro.core.edge_models import EDGE_MODELS, MODEL_DELTA, paper_cluster
from repro.core.simulator import EdgeSimulator, SimRequest
from repro.profiling import (CalibratedCostProvider, CalibrationStore,
                             FeedbackLoop, LearnedCostModel, Profiler,
                             Sample, SyntheticGroundTruth, calibrate)


# --------------------------------------------------------------------------
# fixtures
# --------------------------------------------------------------------------

def three_node_cluster() -> Cluster:
    """Three *declared-identical* nodes — calibration must discover that one
    secretly underperforms."""
    def node(name: str) -> Node:
        return Node(name=name, processors=(
            Processor(name="cpu", kind="cpu", peak_flops=5e10,
                      local_bw=1e10, active_power=2.0, idle_power=0.5),
            Processor(name="gpu", kind="gpu", peak_flops=2e11,
                      local_bw=1e10, active_power=5.0, idle_power=1.0),
        ), net_bw=1e8, default_processor="gpu")
    return Cluster(nodes=(node("a"), node("b"), node("c")))


def toy_dag(n: int = 12, flops: float = 2e9) -> ModelDAG:
    blocks = [Block(name=f"b{i}", kind="conv", flops=flops,
                    param_bytes=1e5, bytes_in=4e4, bytes_out=4e4,
                    halo_fraction=0.02)
              for i in range(n)]
    return chain("toy", blocks, 4e4, 4e4)


def paper_samples(gt=None, seed=0):
    cluster = paper_cluster()
    dags = {k: f() for k, f in EDGE_MODELS.items()}
    return cluster, dags, Profiler(seed=seed).profile_cluster(
        cluster, dags, MODEL_DELTA, ground_truth=gt)


# --------------------------------------------------------------------------
# regression: analytic provider is the seed, bit for bit
# --------------------------------------------------------------------------

def test_analytic_provider_is_numerically_identical():
    cluster = paper_cluster()
    for name in ("resnet152", "efficientnet_b0"):
        dag = EDGE_MODELS[name]()
        base = plan(dag, cluster, PlannerConfig(delta=MODEL_DELTA[name]))
        prov = plan(dag, cluster, PlannerConfig(delta=MODEL_DELTA[name],
                                                provider=ANALYTIC))
        assert base.predicted_latency == prov.predicted_latency
        assert base.predicted_energy == prov.predicted_energy
        assert base.global_plan.partition == prov.global_plan.partition
        for lp0, lp1 in zip(base.local_plans, prov.local_plans):
            assert lp0.partition == lp1.partition


def test_simulator_spans_identical_with_explicit_analytic_provider():
    dag = EDGE_MODELS["resnet152"]()
    d = MODEL_DELTA["resnet152"]
    reqs = [SimRequest(0, dag, 0.0, d)]
    spans0 = EdgeSimulator(paper_cluster(), "hidp").run(list(reqs)).spans
    spans1 = EdgeSimulator(paper_cluster(), "hidp",
                           provider=ANALYTIC).run(list(reqs)).spans
    assert len(spans0) == len(spans1)
    for s0, s1 in zip(spans0, spans1):
        # absolute starts differ by wall-clock planning time only
        assert (s0.node, s0.processor, s0.flops) == (
            s1.node, s1.processor, s1.flops)
        assert s0.end - s0.start == pytest.approx(s1.end - s1.start,
                                                  rel=1e-12)


# --------------------------------------------------------------------------
# LearnedCostModel
# --------------------------------------------------------------------------

def test_round_trip_serialization():
    gt = SyntheticGroundTruth(paper_cluster(),
                              rate_scale={("orin_nx", "gpu"): 0.4},
                              noise=0.05)
    _, _, samples = paper_samples(gt)
    for mode in ("linear", "isotonic"):
        model = LearnedCostModel.fit(samples, mode=mode)
        clone = LearnedCostModel.from_json(model.to_json())
        assert clone.mode == model.mode
        assert clone.entries.keys() == model.entries.keys()
        for s in samples[::17]:
            assert clone.predict(s.key, s.kind, s.work, s.traffic) == \
                model.predict(s.key, s.kind, s.work, s.traffic)


def test_fitted_latency_monotone_in_flops():
    gt = SyntheticGroundTruth(paper_cluster(), noise=0.1)
    _, _, samples = paper_samples(gt)
    for mode in ("linear", "isotonic"):
        model = LearnedCostModel.fit(samples, mode=mode)
        for key, kind in [("orin_nx/gpu", "conv"), ("rpi4/cpu", "dense")]:
            works = [1e8 * (2 ** i) for i in range(12)]
            preds = [model.predict(key, kind, w, 1e5) for w in works]
            assert all(p is not None and p > 0 for p in preds)
            assert all(b >= a * (1 - 1e-9)
                       for a, b in zip(preds, preds[1:])), (mode, key)


def test_calibration_recovers_true_rates():
    """Measured-rate recovery: a 2× mis-declared processor is learned to
    within a few percent, and prediction MAPE beats the analytic model's."""
    cluster = paper_cluster()
    gt = SyntheticGroundTruth(cluster, rate_scale={("tx2", "gpu"): 0.5},
                              noise=0.02)
    _, dags, samples = paper_samples(gt)
    model = LearnedCostModel.fit(samples)
    # learned rate ≈ 0.5 × datasheet for the throttled GPU
    tx2_gpu = [p for n in cluster.nodes if n.name == "tx2"
               for p in n.processors if p.name == "gpu"][0]
    learned = model.rate("tx2/gpu", "conv")
    datasheet = tx2_gpu.rate(1.0, "conv")
    assert learned == pytest.approx(0.5 * datasheet, rel=0.1)
    assert model.mape_against(samples) < 0.1


def test_node_rate_aggregates_processors():
    samples = [
        Sample("n/cpu", "conv", 1e9, 1e5, 1.0),
        Sample("n/cpu", "conv", 2e9, 1e5, 2.0),
        Sample("n/gpu", "conv", 1e9, 1e5, 0.25),
        Sample("n/gpu", "conv", 2e9, 1e5, 0.5),
    ]
    model = LearnedCostModel.fit(samples)
    assert model.rate("n/cpu", "conv") == pytest.approx(1e9, rel=1e-6)
    assert model.rate("n/gpu", "conv") == pytest.approx(4e9, rel=1e-6)
    # Λ = Σλ (Eq. 2) with measured λ
    assert model.rate("n", "conv") == pytest.approx(5e9, rel=1e-6)


# --------------------------------------------------------------------------
# Profiler
# --------------------------------------------------------------------------

def test_profiler_deterministic_under_seed():
    gt = SyntheticGroundTruth(paper_cluster(), noise=0.1)
    _, _, s0 = paper_samples(gt, seed=7)
    _, _, s1 = paper_samples(gt, seed=7)
    assert s0 == s1
    _, _, s2 = paper_samples(gt, seed=8)
    assert s0 != s2


def test_profile_kernels_smoke():
    samples = Profiler(warmup=1, repeats=2, trim=0).profile_kernels()
    # full kernel set: attn, decode, ssd — 3 default shapes each
    assert len(samples) == 9
    assert {s.kind for s in samples} == {"attn", "decode", "ssd"}
    assert all(s.latency_s > 0 for s in samples)
    model = LearnedCostModel.fit(samples)
    for kind in ("attn", "decode", "ssd"):
        assert model.rate(samples[0].key, kind) > 0


def test_profile_kernels_subset_and_shapes():
    prof = Profiler(warmup=0, repeats=1, trim=0)
    samples = prof.profile_kernels(kinds=("attn",),
                                   shapes={"attn": ((1, 32, 2, 16),)})
    assert len(samples) == 1 and samples[0].kind == "attn"
    assert samples[0].work == 4.0 * 1 * 32 * 32 * 2 * 16
    import pytest
    with pytest.raises(KeyError):
        prof.profile_kernels(kinds=("conv",))


# --------------------------------------------------------------------------
# planner with calibration
# --------------------------------------------------------------------------

def test_calibrated_slow_node_gets_smaller_share():
    cluster = three_node_cluster()
    dag = toy_dag()
    gt = SyntheticGroundTruth(cluster, rate_scale={"b": 0.3})
    base = plan(dag, cluster, PlannerConfig(delta=1.0))
    prov = calibrate(cluster, {"toy": dag}, {"toy": 1.0}, ground_truth=gt)
    calibrated = plan(dag, cluster, PlannerConfig(delta=1.0, provider=prov))
    assert base.mode == calibrated.mode == "data"

    def share(p, node):
        return sum(a.fraction for a in p.global_plan.assignments
                   if a.node.name == node)

    # analytic sees three identical nodes → equal thirds; calibration sees
    # b at 30% → smaller share, and the fast nodes absorb the difference
    assert share(base, "b") == pytest.approx(1 / 3, rel=1e-6)
    assert share(calibrated, "b") < share(base, "b") * 0.6
    assert share(calibrated, "a") > share(base, "a")


def test_calibrated_plan_is_faster_on_true_hardware():
    """The acceptance scenario: rates diverge ≥2× from the datasheet; the
    calibrated plan simulates faster than the analytic plan on the same
    ground truth."""
    cluster = paper_cluster()
    dags = {k: f() for k, f in EDGE_MODELS.items()}
    gt = SyntheticGroundTruth(cluster, rate_scale={("orin_nx", "gpu"): 0.35,
                                                   ("tx2", "cpu"): 0.4})
    dag = dags["resnet152"]
    d = MODEL_DELTA["resnet152"]
    lat_analytic = simulate(cluster, "hidp", [(0.0, dag, d)],
                            ground_truth=gt).records[0].latency
    prov = calibrate(cluster, dags, MODEL_DELTA, ground_truth=gt)
    lat_calib = simulate(cluster, "hidp", [(0.0, dag, d)], provider=prov,
                         ground_truth=gt).records[0].latency
    assert lat_calib < lat_analytic


# --------------------------------------------------------------------------
# feedback loop
# --------------------------------------------------------------------------

def test_drift_triggers_exactly_one_replan():
    """Reality shifts 3× on one processor: the loop re-plans once, then the
    refitted model tracks reality and stays quiet."""
    model = LearnedCostModel.fit(
        [Sample("n/gpu", "conv", w, 0.0, w / 1e9)
         for w in (1e8, 2e8, 4e8, 8e8)])
    replans = []
    fb = FeedbackLoop(model, threshold=0.3,
                      on_drift=lambda: replans.append(fb.observations))
    for i in range(40):
        work = 1e8 * (1 + i % 5)
        fb.observe("n/gpu", "conv", work, 0.0, 3.0 * work / 1e9)
    assert fb.replans == 1
    assert replans == [fb.events[0].at_observation]
    assert fb.drift() < 0.05
    assert model.rate("n/gpu", "conv") == pytest.approx(1e9 / 3, rel=0.05)


def test_drift_detected_after_healthy_period():
    """The hard case: the model tracks reality for a long healthy stretch,
    *then* the hardware throttles 3×.  Detection is against a frozen
    reference, so the live EWMA adapting cannot mask the shift; the loop
    re-plans exactly once and the refit (from post-change observations
    only) then tracks the new regime."""
    model = LearnedCostModel.fit(
        [Sample("n/gpu", "conv", w, 0.0, w / 1e9)
         for w in (1e8, 2e8, 4e8, 8e8)])
    fb = FeedbackLoop(model, threshold=0.3)
    for i in range(30):                       # healthy: predictions hold
        work = 1e8 * (1 + i % 5)
        fb.observe("n/gpu", "conv", work, 0.0, work / 1e9)
    assert fb.replans == 0
    for i in range(30):                       # thermal throttle: 3× slower
        work = 1e8 * (1 + i % 5)
        fb.observe("n/gpu", "conv", work, 0.0, 3.0 * work / 1e9)
    assert fb.replans == 1
    assert model.rate("n/gpu", "conv") == pytest.approx(1e9 / 3, rel=0.05)


def test_calibrated_data_pricing_carries_block_overheads():
    """partition()'s min(Θ_ω, Θ_σ) must compare like with like: the data
    mode's predicted time includes the fitted per-block overheads that the
    model mode's segment costs carry."""
    from repro.core.cost_model import Resource
    from repro.core.dp_partitioner import partition_data

    overhead = 5e-3
    model = LearnedCostModel()
    model.fit_entry("r0", "conv",
                    [(w, 0.0, w / 1e9 + overhead)
                     for w in (1e6, 2e6, 4e6, 8e6)])
    prov = CalibratedCostProvider(model)
    dag = toy_dag(n=10, flops=1e6)
    r = Resource(name="r0", rate=1e9, bw=1e12)
    pd = partition_data(dag, [r], provider=prov)
    linear, fixed = prov.data_coeffs(dag, r)
    assert fixed == pytest.approx(10 * overhead, rel=1e-6)
    assert pd.predicted_latency > 10 * overhead
    # consistent with the model-mode view of the same whole-DAG segment
    assert prov.segment_coster(dag, r)(0, 10) == \
        pytest.approx(linear + fixed, rel=1e-9)


def test_no_replan_when_predictions_hold():
    model = LearnedCostModel.fit(
        [Sample("n/gpu", "conv", w, 0.0, w / 1e9)
         for w in (1e8, 2e8, 4e8, 8e8)])
    fb = FeedbackLoop(model, threshold=0.3)
    for i in range(40):
        work = 1e8 * (1 + i % 5)
        fb.observe("n/gpu", "conv", work, 0.0, 1.02 * work / 1e9)
    assert fb.replans == 0


def test_simulator_feeds_feedback_loop():
    cluster = paper_cluster()
    dags = {k: f() for k, f in EDGE_MODELS.items()}
    gt = SyntheticGroundTruth(cluster, rate_scale={("orin_nx", "gpu"): 0.35})
    clean = calibrate(cluster, dags, MODEL_DELTA)   # believes the datasheet
    fb = FeedbackLoop(clean.model, threshold=0.3)
    reqs = [(0.05 * i, dags["resnet152"], MODEL_DELTA["resnet152"])
            for i in range(4)]
    simulate(cluster, "hidp", reqs, ground_truth=gt, feedback=fb)
    assert fb.replans == 1
    # refitted: a second identical wave stays within tolerance
    simulate(cluster, "hidp", reqs, ground_truth=gt, feedback=fb)
    assert fb.replans == 1


def test_feedback_triggers_elastic_replan():
    pytest.importorskip("jax")
    from repro.configs import get_config
    from repro.models import build_model
    from repro.models.config import SHAPES
    from repro.runtime.elastic import ElasticController
    from repro.sharding.plan import MULTI_POD

    ctl = ElasticController(build_model(get_config("gemma-2b")),
                            SHAPES["train_4k"], MULTI_POD)
    p0 = ctl.initial_plan()
    model = LearnedCostModel.fit(
        [Sample("pod0", "generic", w, 0.0, w / 1e12)
         for w in (1e10, 2e10, 4e10)])
    fb = FeedbackLoop(model, threshold=0.3, on_drift=ctl.on_drift)
    for i in range(10):
        work = 1e10 * (1 + i % 3)
        fb.observe("pod0", "generic", work, 0.0, 4.0 * work / 1e12)
    assert ctl.replans == 1
    assert ctl.current_plan is not None
    assert ctl.current_plan.mesh == p0.mesh       # same fleet, fresh plan


# --------------------------------------------------------------------------
# calibration store
# --------------------------------------------------------------------------

def test_store_versions_per_fingerprint(tmp_path):
    cluster = three_node_cluster()
    store = CalibrationStore(tmp_path)
    model = LearnedCostModel.fit(
        [Sample("a/gpu", "conv", 1e9, 1e5, 0.01),
         Sample("a/gpu", "conv", 2e9, 1e5, 0.02)])
    assert store.versions(cluster) == []
    with pytest.raises(FileNotFoundError):
        store.load(cluster)
    v1 = store.save(cluster, model, note="first")
    v2 = store.save(cluster, model, note="re-profiled")
    assert (v1, v2) == (1, 2)
    assert store.versions(cluster) == [1, 2]
    loaded = store.load(cluster)
    assert loaded.to_dict() == model.to_dict()
    # a different fleet has a different fingerprint → no calibrations
    other = paper_cluster()
    assert CalibrationStore.fingerprint(other) != \
        CalibrationStore.fingerprint(cluster)
    assert store.versions(other) == []


def test_calibrated_provider_respects_capacity_view():
    """Global-only strategies probe the default runtime (P1): their node
    resources must resolve to the default processor's measured rate, not the
    Λ=Σλ aggregate only HiDP's local tier can realise."""
    from repro.core.cost_model import node_as_resource
    cluster = three_node_cluster()
    node = cluster.nodes[0]
    gt = SyntheticGroundTruth(cluster)
    prov = calibrate(cluster, {"toy": toy_dag()}, {"toy": 1.0},
                     ground_truth=gt)
    r_sum = node_as_resource(node, 1.0, capacity="sum")
    r_default = node_as_resource(node, 1.0, capacity="default")
    assert r_sum.profile_key == "a"
    assert r_default.profile_key == "a/gpu"
    rate_sum = prov.effective_rate(r_sum, "conv")
    rate_default = prov.effective_rate(r_default, "conv")
    gpu_only = prov.model.rate("a/gpu", "conv")
    assert rate_default == pytest.approx(gpu_only, rel=1e-9)
    assert rate_sum == pytest.approx(prov.model.rate("a", "conv"), rel=1e-9)
    assert rate_sum > rate_default                 # cpu+gpu > gpu alone


def test_calibrated_provider_falls_back_when_uncalibrated():
    model = LearnedCostModel.fit(
        [Sample("a/gpu", "conv", 1e9, 1e5, 0.01)])
    prov = CalibratedCostProvider(model)
    from repro.core.cost_model import Resource
    known = Resource(name="a/gpu", rate=1e11, bw=1e10)
    unknown = Resource(name="z/npu", rate=1e11, bw=1e10)
    assert prov.compute_time(1e9, known, "conv") == pytest.approx(0.01)
    assert prov.compute_time(1e9, unknown, "conv") == \
        ANALYTIC.compute_time(1e9, unknown, "conv")
    assert math.isfinite(prov.comm_time(1e6, unknown))
