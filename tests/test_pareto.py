"""Frontier + plan-cache invariants.

The refactor's guarantees, as tests:

* a :class:`ParetoFront` never returns a dominated plan (property-tested via
  hypothesis when installed, and over seeded random instances regardless);
* the front's latency-optimal endpoint is *bit-identical* to the seed's
  scalar latency DP, at every tier (``partition_front`` → ``plan_front``);
* ``Objective`` as a selector: feasible-first under the budget, then
  metric-optimal, deterministic ties;
* ``PlanCache`` serves mixed-objective traffic with zero DP work after one
  frontier pass, and invalidation on a calibration-version bump is atomic.
"""

import math
import random

import pytest

from _hypothesis_compat import given, settings, st

from repro.core import (Block, HiDPPlanner, ModelDAG, Objective, ParetoFront,
                        ParetoPoint, PlannerConfig, cluster_fingerprint,
                        partition, partition_front, plan, plan_front,
                        plan_local, plan_local_front, simulate)
from repro.core.cost_model import Resource, node_as_resource
from repro.core.edge_models import (EDGE_MODELS, MODEL_DELTA, battery_cluster,
                                    paper_cluster)
from repro.profiling import CalibrationStore, FeedbackLoop, LearnedCostModel
from repro.serving import PlanCache


# --------------------------------------------------------------------------
# instance generators (hypothesis strategies + a seeded fallback, so the
# invariants execute even where hypothesis is not installed)
# --------------------------------------------------------------------------

@st.composite
def dags(draw):
    n = draw(st.integers(2, 16))
    blocks = []
    bytes_in = draw(st.floats(1e3, 1e7))
    for i in range(n):
        bytes_out = draw(st.floats(1e3, 1e7))
        blocks.append(Block(
            name=f"b{i}", flops=draw(st.floats(1e6, 1e12)),
            param_bytes=draw(st.floats(1e3, 1e8)),
            bytes_in=bytes_in, bytes_out=bytes_out,
            halo_fraction=draw(st.floats(0, 0.2))))
        bytes_in = bytes_out
    return ModelDAG(name="h", blocks=tuple(blocks),
                    input_bytes=blocks[0].bytes_in,
                    output_bytes=blocks[-1].bytes_out)


@st.composite
def resource_lists(draw):
    m = draw(st.integers(1, 5))
    return [Resource(name=f"r{i}", rate=draw(st.floats(1e8, 1e13)),
                     bw=draw(st.floats(1e6, 1e10)),
                     rtt=draw(st.floats(0, 1e-2)),
                     active_power=draw(st.floats(1, 20)),
                     idle_power=draw(st.floats(0.1, 5)))
            for i in range(m)]


def _random_case(rng: random.Random):
    n = rng.randint(2, 16)
    blocks = []
    bytes_in = rng.uniform(1e3, 1e7)
    for i in range(n):
        bytes_out = rng.uniform(1e3, 1e7)
        blocks.append(Block(
            name=f"b{i}", flops=rng.uniform(1e6, 1e12),
            param_bytes=rng.uniform(1e3, 1e8),
            bytes_in=bytes_in, bytes_out=bytes_out,
            halo_fraction=rng.uniform(0.0, 0.2)))
        bytes_in = bytes_out
    dag = ModelDAG(name="h", blocks=tuple(blocks),
                   input_bytes=blocks[0].bytes_in,
                   output_bytes=blocks[-1].bytes_out)
    resources = [Resource(name=f"r{i}", rate=rng.uniform(1e8, 1e13),
                          bw=rng.uniform(1e6, 1e10),
                          rtt=rng.uniform(0.0, 1e-2),
                          active_power=rng.uniform(1.0, 20.0),
                          idle_power=rng.uniform(0.1, 5.0))
                 for i in range(rng.randint(1, 5))]
    return dag, resources


def _assert_front_invariants(front: ParetoFront):
    pts = front.points
    assert len(pts) >= 1
    for p in pts:
        assert math.isfinite(p.latency) and math.isfinite(p.energy)
        assert p.latency > 0 and p.energy >= 0
        assert not any(q.dominates(p) for q in pts if q is not p), \
            "front returned a dominated plan"
    for a, b in zip(pts, pts[1:]):
        assert a.latency < b.latency and a.energy > b.energy, \
            "front not strictly sorted"


def _check_partition_front(dag, resources):
    front = partition_front(dag, resources, radio_power=4.0)
    _assert_front_invariants(front)
    # the latency-optimal endpoint is bit-identical to the seed scalar DP
    seed = partition(dag, resources)
    assert front.latency_optimal.latency == seed.predicted_latency
    assert front.select(None).predicted_latency == seed.predicted_latency
    # objective-as-selector: feasible-first under the budget, then
    # metric-optimal — verified directly against the point set
    mid = (front.points[0].latency + front.points[-1].latency) / 2
    sel = front.select_point(Objective("energy", latency_budget=mid))
    feasible = [p for p in front.points if p.latency <= mid]
    assert feasible and sel.latency <= mid
    assert sel.energy == min(p.energy for p in feasible)
    # an unmeetable budget degrades to the fastest plan (drive toward
    # feasibility), never an exception
    tight = Objective("energy", latency_budget=front.points[0].latency / 2)
    assert front.select_point(tight) is front.latency_optimal


# --------------------------------------------------------------------------
# frontier invariants — tier-level (partition_front)
# --------------------------------------------------------------------------

def test_partition_front_invariants_seeded():
    rng = random.Random(7)
    for _ in range(25):
        dag, resources = _random_case(rng)
        _check_partition_front(dag, resources)


@settings(max_examples=40, deadline=None)
@given(dags(), resource_lists())
def test_partition_front_invariants_property(dag, resources):
    _check_partition_front(dag, resources)


@settings(max_examples=25, deadline=None)
@given(dags(), resource_lists())
def test_energy_selection_never_beats_frontier(dag, resources):
    """Any scalarized pick must lie on the front it was selected from."""
    front = partition_front(dag, resources, radio_power=4.0)
    for metric in ("energy", "edp"):
        sel = front.select_point(Objective(metric, radio_power=4.0))
        assert not front.dominated(sel.latency, sel.energy)


def test_partition_front_on_paper_models():
    cluster = paper_cluster()
    for name, fn in EDGE_MODELS.items():
        dag = fn()
        resources = [node_as_resource(n, MODEL_DELTA[name])
                     for n in cluster.nodes]
        front = partition_front(dag, resources)
        _assert_front_invariants(front)
        seed = partition(dag, resources)
        lo = front.latency_optimal
        assert lo.latency == seed.predicted_latency
        assert lo.plan == seed                   # same cuts, same assignment


def test_battery_cluster_front_has_real_tradeoff():
    """On the duty-cycled fleet the frontier is a curve, not a point."""
    cluster = battery_cluster()
    spread = 0
    for name, fn in EDGE_MODELS.items():
        resources = [node_as_resource(n, MODEL_DELTA[name])
                     for n in cluster.nodes]
        front = partition_front(fn(), resources, radio_power=4.0)
        if len(front) >= 3:
            spread += 1
        assert front.energy_optimal.energy <= front.latency_optimal.energy
    assert spread >= 2, "battery-cluster frontiers unexpectedly degenerate"


# --------------------------------------------------------------------------
# frontier invariants — hierarchical (plan_front / plan_local_front)
# --------------------------------------------------------------------------

def test_plan_front_latency_endpoint_is_seed_plan():
    """The hierarchical front's fastest point reproduces the seed two-tier
    pass bit-identically — partitions, assignments, and predictions."""
    for cluster in (paper_cluster(), battery_cluster()):
        for name in ("resnet152", "efficientnet_b0"):
            cfg = PlannerConfig(delta=MODEL_DELTA[name])
            dag = EDGE_MODELS[name]()
            seed = plan(dag, cluster, cfg)
            front = plan_front(dag, cluster, cfg)
            _assert_front_invariants(front)
            lo = front.latency_optimal.plan
            assert lo.predicted_latency == seed.predicted_latency
            assert lo.predicted_energy == seed.predicted_energy
            assert lo.global_plan.partition == seed.global_plan.partition
            for a, b in zip(lo.local_plans, seed.local_plans):
                assert a.partition == b.partition


def test_plan_local_front_endpoint_matches_plan_local():
    cluster = paper_cluster()
    dag = EDGE_MODELS["vgg19"]()
    delta = MODEL_DELTA["vgg19"]
    for node in cluster.nodes:
        front = plan_local_front(dag, node, delta=delta)
        _assert_front_invariants(front)
        seed = plan_local(dag, node, delta=delta)
        lo = front.latency_optimal.plan
        assert lo.predicted_latency == seed.predicted_latency
        assert lo.partition == seed.partition


def test_objective_selection_matches_scalarized_planning():
    """``plan(objective=o)`` is now *defined* as selection over the front;
    the selected plans keep the PR-2 scalarized guarantees: within budget,
    lower (or equal) energy than latency-only planning, and EDP sits
    between the endpoints (the frontier ordering)."""
    cluster = battery_cluster()
    improved = 0
    for name in EDGE_MODELS:
        dag = EDGE_MODELS[name]()
        cfg = PlannerConfig(delta=MODEL_DELTA[name])
        base = plan(dag, cluster, cfg)
        budget = base.predicted_latency * 1.35
        front = plan_front(dag, cluster, cfg)
        for metric in ("energy", "edp"):
            obj = Objective(metric, latency_budget=budget, radio_power=4.0)
            picked = plan(dag, cluster, PlannerConfig(
                delta=MODEL_DELTA[name], objective=obj))
            assert picked.predicted_latency <= budget * (1 + 1e-9)
            # selection cannot leave the frontier it selected from
            own_front = plan_front(dag, cluster, PlannerConfig(
                delta=MODEL_DELTA[name], objective=obj))
            assert not own_front.dominated(picked.predicted_latency,
                                           picked.predicted_energy)
        en_obj = Objective("energy", latency_budget=budget)
        aware = plan(dag, cluster, PlannerConfig(delta=MODEL_DELTA[name],
                                                 objective=en_obj))
        assert aware.predicted_energy <= base.predicted_energy * (1 + 1e-9)
        if aware.predicted_energy < base.predicted_energy:
            improved += 1
        assert front.select(en_obj).predicted_energy <= \
            front.latency_optimal.plan.predicted_energy * (1 + 1e-9)
    assert improved >= 2


# --------------------------------------------------------------------------
# PlanCache: zero-DP serving, atomic invalidation
# --------------------------------------------------------------------------

@pytest.fixture()
def warm_cache():
    cluster = battery_cluster()
    planner = HiDPPlanner(PlannerConfig(
        objective=Objective("energy", radio_power=4.0)))
    return PlanCache(planner, cluster), cluster


def test_cache_serves_mixed_objectives_with_one_dp_pass(warm_cache):
    cache, _ = warm_cache
    dag = EDGE_MODELS["efficientnet_b0"]()
    delta = MODEL_DELTA["efficientnet_b0"]
    plans = {}
    for obj in ("latency", "energy", "edp", "energy", "latency", "edp"):
        plans[obj] = cache.get(dag, obj, delta=delta)
    assert cache.misses == 1 and cache.hits == 5
    assert plans["energy"].predicted_energy <= \
        plans["latency"].predicted_energy
    assert plans["latency"].predicted_latency <= \
        plans["edp"].predicted_latency <= plans["energy"].predicted_latency
    # warm lookups report lookup time, not DP time
    assert plans["edp"].planning_seconds < 0.01


def test_cache_key_shape_and_shared_fingerprint(warm_cache):
    from repro.core import dag_fingerprint, membership_fingerprint

    cache, cluster = warm_cache
    dag = EDGE_MODELS["resnet152"]()
    key = cache.key(dag, 70.0)
    assert key == (cluster_fingerprint(cluster),
                   membership_fingerprint(cluster), cache.version,
                   dag_fingerprint(dag), 70.0)
    # the satellite guarantee: PlanCache keys and CalibrationStore paths
    # hash the cluster through the same helper
    assert cache.fingerprint == CalibrationStore.fingerprint(cluster)
    smaller = battery_cluster(n_nodes=3)
    assert cluster_fingerprint(smaller) != cache.fingerprint
    # tenant identity is the dag's full cost surface, not its name: a
    # same-named workload with different blocks keys differently
    import dataclasses as _dc
    reshaped = _dc.replace(dag, blocks=dag.blocks[:-1])
    assert dag_fingerprint(reshaped) != dag_fingerprint(dag)
    assert cache.key(reshaped, 70.0) != key


def test_cache_invalidation_on_version_bump_is_atomic(warm_cache):
    cache, _ = warm_cache
    dag = EDGE_MODELS["efficientnet_b0"]()
    delta = MODEL_DELTA["efficientnet_b0"]
    first = cache.get(dag, "energy", delta=delta)
    old_gen = cache._generation
    old_key = cache.key(dag, delta)
    v = cache.bump_version()
    # the swap is a single reference assignment: the old generation object
    # is untouched (a concurrent reader keeps a consistent view) and the
    # new one is empty at the new version
    assert old_gen[0] == v - 1 and old_key in old_gen[1]
    assert cache._generation[0] == v and not cache._generation[1]
    assert cache.key(dag, delta) != old_key
    # exactly one EXPLORE re-plan repopulates, then hits resume
    misses0 = cache.misses
    again = cache.get(dag, "energy", delta=delta)
    assert cache.misses == misses0 + 1
    cache.get(dag, "latency", delta=delta)
    cache.get(dag, "edp", delta=delta)
    assert cache.misses == misses0 + 1
    assert again.predicted_energy == pytest.approx(first.predicted_energy)


def test_feedback_drift_bumps_calibration_version_and_cache():
    """A FeedbackLoop wired as version_source: one drift event → version
    advance → stale fronts unreachable → one re-plan on next lookup."""
    model = LearnedCostModel()
    model.fit_entry("n/gpu", "conv", [(1e8, 0.0, 0.1), (2e8, 0.0, 0.2)])
    fb = FeedbackLoop(model, threshold=0.3, calibration_version=3)
    cluster = battery_cluster()
    cache = PlanCache(HiDPPlanner(), cluster, version_source=fb)
    assert cache.version == 3
    dag = EDGE_MODELS["efficientnet_b0"]()
    delta = MODEL_DELTA["efficientnet_b0"]
    cache.get(dag, "latency", delta=delta)
    cache.get(dag, "energy", delta=delta)
    assert (cache.misses, cache.hits) == (1, 1)
    # sustained 3x slowdown on the profiled resource → exactly one trip
    for i in range(10):
        work = 1e8 * (1 + i % 3)
        fb.observe("n/gpu", "conv", work, 0.0, 3.0 * work / 1e9)
    assert fb.replans == 1 and fb.calibration_version == 4
    assert cache.version == 4
    cache.get(dag, "latency", delta=delta)      # the single EXPLORE re-plan
    cache.get(dag, "edp", delta=delta)
    assert cache.misses == 2 and cache.invalidations == 1
    with pytest.raises(RuntimeError):
        cache.bump_version()                    # version_source owns it


def test_simulator_amortizes_planning_through_cache():
    cluster = battery_cluster()
    cache = PlanCache(HiDPPlanner(PlannerConfig(
        objective=Objective("energy", radio_power=4.0))), cluster)
    dag = EDGE_MODELS["efficientnet_b0"]()
    delta = MODEL_DELTA["efficientnet_b0"]
    reqs = [(0.05 * i, dag, delta) for i in range(6)]
    rep = simulate(cluster, "hidp", reqs, plan_cache=cache,
                   objective=Objective("energy", radio_power=4.0))
    assert len(rep.records) == 6
    assert cache.misses == 1 and cache.hits == 5
    assert cache.hit_rate() == pytest.approx(5 / 6)


def test_front_width_one_degrades_to_endpoints():
    """Degenerate caps (front_width=1) floor at the two endpoints instead
    of crashing the thinning loops."""
    dag = EDGE_MODELS["efficientnet_b0"]()
    cluster = battery_cluster()
    front = plan_front(dag, cluster, PlannerConfig(
        delta=MODEL_DELTA["efficientnet_b0"], front_width=1,
        objective=Objective("energy", radio_power=4.0)))
    _assert_front_invariants(front)
    assert 1 <= len(front) <= 2
    seed = plan(dag, cluster, PlannerConfig(
        delta=MODEL_DELTA["efficientnet_b0"]))
    assert front.latency_optimal.latency == seed.predicted_latency


def test_simulator_rejects_cache_with_baseline_strategy():
    """A plan cache owns planning; pairing it with a baseline strategy or a
    simulator-level provider would silently mislabel results."""
    from repro.core import EdgeSimulator
    from repro.core.cost_model import AnalyticCostProvider

    cluster = battery_cluster()
    cache = PlanCache(HiDPPlanner(), cluster)
    with pytest.raises(ValueError, match="modnn"):
        EdgeSimulator(cluster, "modnn", plan_cache=cache)
    with pytest.raises(ValueError, match="provider"):
        EdgeSimulator(cluster, "hidp", provider=AnalyticCostProvider(),
                      plan_cache=cache)
    EdgeSimulator(cluster, "hidp", plan_cache=cache)      # fine


def test_cache_warm_path_is_much_faster_than_cold():
    """Conservative in-test bound (the tab1 benchmark gates the real
    >=100x claim): warm selection beats the cold frontier pass by >=20x."""
    import time
    cluster = battery_cluster()
    cache = PlanCache(HiDPPlanner(), cluster)
    dag = EDGE_MODELS["resnet152"]()
    delta = MODEL_DELTA["resnet152"]
    cold = cache.get(dag, "latency", delta=delta)
    t0 = time.perf_counter()
    n = 30
    for i in range(n):
        cache.get(dag, ("latency", "energy", "edp")[i % 3], delta=delta)
    warm = (time.perf_counter() - t0) / n
    assert cache.misses == 1
    assert cold.planning_seconds > warm * 20
