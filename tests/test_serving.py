"""Serving engine: continuous batching, slot reuse, and greedy-decode
equivalence against a reference incremental loop."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.serving.engine import ServingEngine


@pytest.fixture(scope="module")
def small_lm():
    cfg = get_config("gemma-2b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(3))
    return cfg, model, params


def _reference_greedy(model, params, prompt, n_new):
    """Full-forward greedy decoding (no cache) — the exactness oracle."""
    toks = list(map(int, prompt))
    for _ in range(n_new):
        logits = model.apply_train(
            params, {"tokens": jnp.asarray([toks], jnp.int32)}, remat=False)
        toks.append(int(jnp.argmax(logits[0, -1])))
    return toks[len(prompt):]


def test_engine_single_request_matches_reference(small_lm):
    cfg, model, params = small_lm
    prompt = np.asarray([5, 9, 2, 7], np.int32)
    want = _reference_greedy(model, params, prompt, 6)

    eng = ServingEngine(model, params, max_batch=2, max_len=32)
    rid = eng.submit(prompt, max_new_tokens=6)
    done = eng.run_until_done()
    got = done[rid].generated[:6]
    assert got == want, (got, want)


def test_engine_batches_multiple_requests(small_lm):
    cfg, model, params = small_lm
    eng = ServingEngine(model, params, max_batch=2, max_len=32)
    prompts = [np.asarray(p, np.int32) for p in
               ([1, 2, 3], [9, 8, 7, 6], [4, 4], [11, 3, 5, 2, 1])]
    wants = [_reference_greedy(model, params, p, 4) for p in prompts]
    rids = [eng.submit(p, max_new_tokens=4) for p in prompts]
    done = eng.run_until_done()
    assert len(done) == 4                      # queue drained via slot reuse
    for rid, want in zip(rids, wants):
        assert done[rid].generated[:4] == want


def test_engine_respects_max_len(small_lm):
    cfg, model, params = small_lm
    eng = ServingEngine(model, params, max_batch=1, max_len=12)
    rid = eng.submit(np.asarray([1, 2, 3], np.int32), max_new_tokens=100)
    done = eng.run_until_done()
    assert done[rid].done
    assert 3 + len(done[rid].generated) <= 12 + 1


def test_engine_ssm_family():
    """Recurrent-state arch (mamba2) through the same engine path."""
    cfg = get_config("mamba2-780m").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(5))
    prompt = np.asarray([3, 1, 4], np.int32)
    want = _reference_greedy(model, params, prompt, 5)
    eng = ServingEngine(model, params, max_batch=2, max_len=24)
    rid = eng.submit(prompt, max_new_tokens=5)
    done = eng.run_until_done()
    assert done[rid].generated[:5] == want


def test_engine_feedback_reenters_explore_on_drift(small_lm):
    """Closed loop at serving time: a cost model that wildly underestimates
    decode latency drifts immediately; the engine re-enters EXPLORE (Fig. 4)
    and fires the re-plan hook, and the refitted model then tracks reality."""
    from repro.core.scheduler import State
    from repro.profiling import FeedbackLoop, LearnedCostModel

    cfg, model, params = small_lm
    beliefs = LearnedCostModel()
    # believes a decode step takes ~1 ns — off by many orders of magnitude
    beliefs.fit_entry("engine/decode", "decode",
                      [(1.0, 0.0, 1e-9), (2.0, 0.0, 2e-9)])
    replans = []
    fb = FeedbackLoop(beliefs, threshold=0.75,
                      on_drift=lambda: replans.append(fb.observations))
    eng = ServingEngine(model, params, max_batch=1, max_len=64,
                        feedback=fb, on_replan=lambda: None)
    rid = eng.submit(np.asarray([5, 9, 2], np.int32), max_new_tokens=40)
    done = eng.run_until_done()
    assert done[rid].done
    assert eng.replans >= 1 and replans
    assert State.EXPLORE in eng.trace
    # after the hard refit the model's belief is in the measured ballpark
    pred = beliefs.predict("engine/decode", "decode", 1.0, 0.0)
    assert pred is not None and pred > 1e-7


def test_dominant_objective_tie_break_is_deterministic(small_lm):
    """Ties resolve by the fixed METRICS order (latency > energy > edp),
    never by arrival or dict order — cache keys and re-plan objectives must
    be reproducible across runs."""
    cfg, model, params = small_lm
    eng = ServingEngine(model, params, max_batch=2, max_len=32)
    # 1 edp vs 1 energy (latency 0): energy wins — METRICS order
    eng.submit(np.asarray([1], np.int32), max_new_tokens=2, objective="edp")
    eng.submit(np.asarray([2], np.int32), max_new_tokens=2,
               objective="energy")
    assert eng.dominant_objective() == "energy"
    # 1 latency / 1 energy / 1 edp: latency wins the three-way tie
    eng.submit(np.asarray([3], np.int32), max_new_tokens=2,
               objective="latency")
    assert eng.dominant_objective() == "latency"
    # a clear majority still wins regardless of order
    eng.submit(np.asarray([4], np.int32), max_new_tokens=2, objective="edp")
    eng.submit(np.asarray([5], np.int32), max_new_tokens=2, objective="edp")
    assert eng.dominant_objective() == "edp"


def _toy_cache():
    """A PlanCache over the paper cluster for a small synthetic workload."""
    from repro.core import (Block, HiDPPlanner, ModelDAG, Objective,
                            PlannerConfig)
    from repro.core.edge_models import battery_cluster
    from repro.serving import PlanCache

    blocks = tuple(Block(name=f"b{i}", flops=2e9, param_bytes=1e6,
                         bytes_in=4e5, bytes_out=4e5, kind="conv")
                   for i in range(6))
    dag = ModelDAG(name="toy", blocks=blocks, input_bytes=4e5,
                   output_bytes=4e5)
    cluster = battery_cluster()
    planner = HiDPPlanner(PlannerConfig(
        objective=Objective("energy", radio_power=4.0)))
    return PlanCache(planner, cluster), dag


def test_engine_submit_resolves_objectives_from_plan_cache(small_lm):
    """Mixed-objective traffic is served from one cached frontier: the
    first submit pays the DP pass, every later submit is a hit."""
    cfg, model, params = small_lm
    cache, dag = _toy_cache()
    eng = ServingEngine(model, params, max_batch=2, max_len=32,
                        plan_cache=cache, default_dag=dag)
    from repro.core import Objective

    objectives = ("latency", "energy", "edp", "energy")
    for i, obj in enumerate(objectives):
        eng.submit(np.asarray([i + 1, 2], np.int32), max_new_tokens=2,
                   objective=obj)
    assert cache.misses == 1 and cache.hits == len(objectives) - 1
    # the engine's current plan is the last request's selection off the front
    want = cache.front(dag).select(Objective("energy"))
    assert eng.plan.global_plan.partition == want.global_plan.partition
    done = eng.run_until_done()
    assert len(done) == len(objectives)
    assert cache.misses == 1                    # execution never re-plans


def test_engine_drift_triggers_exactly_one_cache_replan(small_lm):
    """Drift while serving: the calibration version bumps, the cached
    frontier invalidates, and the engine re-enters EXPLORE with exactly one
    frontier re-plan at the dominant objective."""
    from repro.core.scheduler import State
    from repro.profiling import FeedbackLoop, LearnedCostModel

    cfg, model, params = small_lm
    cache, dag = _toy_cache()
    beliefs = LearnedCostModel()
    beliefs.fit_entry("engine/decode", "decode",
                      [(1.0, 0.0, 1e-9), (2.0, 0.0, 2e-9)])
    fb = FeedbackLoop(beliefs, threshold=0.75)
    eng = ServingEngine(model, params, max_batch=1, max_len=64,
                        feedback=fb, plan_cache=cache, default_dag=dag)
    rid = eng.submit(np.asarray([5, 9, 2], np.int32), max_new_tokens=40,
                     objective="energy")
    done = eng.run_until_done()
    assert done[rid].done
    assert eng.replans >= 1 and State.EXPLORE in eng.trace
    # one miss to warm the cache + one EXPLORE re-plan per drift event
    assert cache.misses == 1 + eng.replans
    assert cache.invalidations == eng.replans
    assert cache.version == eng.replans


def test_engine_drift_replans_each_tenant_exactly_once(small_lm):
    """Two tenants share one cache; a drift event re-enters EXPLORE with
    exactly one frontier re-plan *per in-flight tenant*, each at that
    tenant's own dominant objective."""
    import dataclasses

    from repro.core import dag_fingerprint
    from repro.core.scheduler import State
    from repro.profiling import FeedbackLoop, LearnedCostModel

    cfg, model, params = small_lm
    cache, dag_a = _toy_cache()
    dag_b = dataclasses.replace(dag_a, name="toy_b",
                                blocks=dag_a.blocks[:-1])
    beliefs = LearnedCostModel()
    beliefs.fit_entry("engine/decode", "decode",
                      [(1.0, 0.0, 1e-9), (2.0, 0.0, 2e-9)])
    fb = FeedbackLoop(beliefs, threshold=0.75)
    eng = ServingEngine(model, params, max_batch=2, max_len=64,
                        feedback=fb, plan_cache=cache)
    ra = eng.submit(np.asarray([5, 9, 2], np.int32), max_new_tokens=40,
                    objective="energy", dag=dag_a)
    rb = eng.submit(np.asarray([1, 4], np.int32), max_new_tokens=40,
                    objective="latency", dag=dag_b)
    done = eng.run_until_done()
    assert done[ra].done and done[rb].done
    assert eng.replans >= 1 and State.EXPLORE in eng.trace
    # one miss per tenant to warm the cache + one re-plan per tenant per
    # drift event — never more
    assert cache.misses == 2 + 2 * eng.replans
    assert cache.invalidations == eng.replans
    # each tenant's latest selection is tracked separately
    assert set(eng.tenant_plans) == {dag_fingerprint(dag_a),
                                     dag_fingerprint(dag_b)}
    assert eng.tenant_plans[dag_fingerprint(dag_a)].dag_name == "toy"
    assert eng.tenant_plans[dag_fingerprint(dag_b)].dag_name == "toy_b"


def test_engine_membership_epoch_replans_each_tenant_once(small_lm):
    """The churn path (docs/fleet.md): a FleetController membership epoch
    re-enters EXPLORE with exactly one plan resolution per in-flight
    tenant — a single frontier pass for the never-seen membership, and
    zero DP work when the departed node returns (the membership key flips
    back to its original value)."""
    from repro.core.scheduler import State
    from repro.fleet import ChurnTrace, FleetController

    cfg, model, params = small_lm
    cache, dag = _toy_cache()
    fleet = FleetController(cache.cluster, ChurnTrace.scripted(
        [(1.0, "tx2", "leave"), (2.0, "tx2", "join")]))
    cache.membership_source = fleet
    eng = ServingEngine(model, params, max_batch=2, max_len=32,
                        plan_cache=cache, default_dag=dag)
    fleet.on_epoch = lambda ep: eng.on_membership_change(ep)
    eng.submit(np.asarray([1, 2], np.int32), max_new_tokens=4)
    assert cache.misses == 1                 # cold pass, full membership
    fleet.advance(1.5)                       # tx2 leaves → epoch 1
    assert eng.replans == 1 and State.EXPLORE in eng.trace
    assert cache.misses == 2                 # one pass for the new mask
    assert all(a.node.name != "tx2"
               for a in eng.plan.global_plan.assignments)
    fleet.advance(2.5)                       # tx2 returns → epoch 2
    assert eng.replans == 2
    assert cache.misses == 2                 # warm return: zero DP work
    assert cache.hits >= 1
    done = eng.run_until_done()
    assert len(done) == 1


def test_engine_submit_requires_tenant_when_cache_wired(small_lm):
    """A plan_cache without a tenant (no dag= and no default_dag) cannot
    resolve a plan; naming a dag without a cache is equally a wiring
    error."""
    cfg, model, params = small_lm
    cache, dag = _toy_cache()
    eng = ServingEngine(model, params, max_batch=1, max_len=32,
                        plan_cache=cache)
    with pytest.raises(ValueError, match="tenant"):
        eng.submit(np.asarray([1], np.int32), max_new_tokens=2)
    eng.submit(np.asarray([1], np.int32), max_new_tokens=2, dag=dag)
    assert cache.misses == 1
    plain = ServingEngine(model, params, max_batch=1, max_len=32)
    with pytest.raises(ValueError, match="plan_cache"):
        plain.submit(np.asarray([1], np.int32), max_new_tokens=2, dag=dag)
    with pytest.raises(ValueError, match="plan_cache"):
        ServingEngine(model, params, default_dag=dag)


def test_engine_submit_delta_is_part_of_the_cache_key(small_lm):
    """δ rides the cache key: a submit at the delta that warmed the front
    hits; a different delta is a different tenant entry (one more pass)."""
    cfg, model, params = small_lm
    cache, dag = _toy_cache()
    cache.front(dag, 70.0)                         # warmed at δ=70
    eng = ServingEngine(model, params, max_batch=2, max_len=32,
                        plan_cache=cache, default_dag=dag)
    eng.submit(np.asarray([1, 2], np.int32), max_new_tokens=2, delta=70.0)
    assert (cache.misses, cache.hits) == (1, 1)    # warm front reused
    eng.submit(np.asarray([3], np.int32), max_new_tokens=2, delta=55.0)
    assert cache.misses == 2                       # new δ → new key
    eng.run_until_done()


def test_engine_per_request_objective(small_lm):
    """Requests carry a planning objective; the engine tracks the dominant
    one across queued + in-flight traffic and rejects unknown metrics."""
    cfg, model, params = small_lm
    eng = ServingEngine(model, params, max_batch=2, max_len=32)
    assert eng.dominant_objective() == "latency"      # empty engine default
    eng.submit(np.asarray([1, 2, 3], np.int32), max_new_tokens=2)
    eng.submit(np.asarray([4, 5], np.int32), max_new_tokens=2,
               objective="energy")
    eng.submit(np.asarray([6], np.int32), max_new_tokens=2,
               objective="energy")
    assert eng.dominant_objective() == "energy"
    with pytest.raises(ValueError):
        eng.submit(np.asarray([7], np.int32), objective="throughput")
    done = eng.run_until_done()
    assert len(done) == 3
    assert eng.dominant_objective() == "latency"      # drained → default


def test_fleet_epoch_resizes_elastic_world():
    """The fleet → runtime wiring (ISSUE 6): a FleetController membership
    epoch drives ElasticController.on_epoch end-to-end — a departed node
    shrinks the elastic world (the mesh loses its pod axis), the return
    grows it back, and telemetry records every transition."""
    from repro.configs import get_config
    from repro.core.edge_models import paper_cluster
    from repro.fleet import ChurnTrace, FleetController
    from repro.models import build_model
    from repro.models.config import SHAPES
    from repro.runtime.elastic import ElasticController
    from repro.sharding.plan import MULTI_POD
    from repro.telemetry import TelemetryRecorder

    rec = TelemetryRecorder("elastic")
    ctl = ElasticController(build_model(get_config("gemma-2b")),
                            SHAPES["train_4k"], MULTI_POD, telemetry=rec)
    assert ctl.initial_plan().mesh.n_pods == 2
    fleet = FleetController(
        paper_cluster(2),
        ChurnTrace.scripted([(1.0, "tx2", "leave"), (2.0, "tx2", "join")]),
        on_epoch=ctl.on_epoch, telemetry=rec)
    fleet.advance(1.5)                      # tx2 leaves → world of 1
    assert ctl.current_plan.mesh.n_pods == 1 and ctl.replans == 1
    fleet.advance(2.5)                      # tx2 returns → world of 2
    assert ctl.current_plan.mesh.n_pods == 2 and ctl.replans == 2
    worlds = [e for e in rec.events if e.name == "elastic.world"]
    assert [e.value for e in worlds] == [1.0, 2.0]
    assert [e.epoch for e in worlds] == [1, 2]
    members = [e for e in rec.events if e.name == "fleet.membership"]
    assert [(e.value, e.epoch) for e in members] == [(1.0, 1), (2.0, 2)]
    assert len([e for e in rec.events if e.name == "elastic.replan"]) == 2
