"""Distributed-execution equivalence on a multi-device CPU mesh: sharded
runs must match single-device runs bit-for-bit-ish; the GPipe pipeline must
match the flat stack; EP MoE must match dense MoE.

These tests spawn a subprocess with XLA_FLAGS=8 host devices so the main
test session keeps its single-device view (per the dry-run contract).
"""

import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_sharded_train_step_matches_single_device():
    print(_run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.configs import get_config
        from repro.models import build_model
        from repro.sharding.plan import ShardingPlan, MeshDesc
        from repro.sharding import specs, ctx as shard_ctx
        from repro.training import optimizer as optim
        from repro.training.train_loop import make_train_step

        cfg = get_config("gemma-2b").reduced()
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 32),
                                              0, cfg.vocab),
                 "targets": jax.random.randint(jax.random.PRNGKey(2), (8, 32),
                                               0, cfg.vocab)}
        mesh_desc = MeshDesc(("data", "model"), (4, 2))
        plan = ShardingPlan(arch="t", shape="s", mesh=mesh_desc,
                            global_mode="data", local_layout="dp_tp",
                            batch_axes=("data",), tp_axes=("model",),
                            remat=False)
        step = make_train_step(model, optim.OptConfig(lr=1e-3,
                                                      warmup_steps=1), plan)
        # single device
        p1, o1, m1 = step(params, optim.init(params), batch)

        # sharded
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        with mesh:
            p_sh = specs.param_shardings(mesh, params, plan)
            b_sh = specs.batch_shardings(mesh, batch, plan)
            params_s = jax.device_put(params, p_sh)
            batch_s = {k: jax.device_put(v, b_sh[k]) for k, v in batch.items()}
            with shard_ctx.plan_specs(P("data", None, None),
                                      P("data", None, "model"), mesh=mesh,
                                      ep_axis="model"):
                p2, o2, m2 = jax.jit(step)(params_s, optim.init(params_s),
                                           batch_s)
        np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                                   rtol=2e-3)
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32), atol=3e-3)
        print("SHARDED-EQUIV-OK")
    """))


def test_pipeline_matches_flat_stack():
    print(_run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.models import build_model, SHAPES
        from repro.models import transformer
        from repro.sharding import pipeline
        cfg = get_config("gemma-2b").reduced()   # 2 layers → 2 stages
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0,
                                    cfg.vocab)
        hidden_flat, _ = transformer.forward(cfg, params, tokens,
                                             mode="train",
                                             return_hidden=True)
        mesh = jax.make_mesh((2, 4), ("pod", "data"))
        staged = pipeline.stage_params(cfg, params, n_stages=2)
        with mesh:
            got = jax.jit(lambda s, t: pipeline.pipeline_hidden(
                cfg, s, t, mesh=mesh, n_stages=2, microbatches=2))(
                staged, tokens)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(hidden_flat, np.float32),
                                   atol=3e-2, rtol=3e-2)
        print("PIPELINE-OK")
    """))


def test_moe_ep_matches_dense_under_jit_mesh():
    print(_run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.models.config import ArchConfig, MoESpec
        from repro.models import layers as L, moe_ep
        from repro.sharding import ctx as shard_ctx
        cfg = ArchConfig(name="t", family="moe", n_layers=1, d_model=32,
                         n_heads=4, n_kv_heads=2, d_ff=64, vocab=128,
                         moe=MoESpec(num_experts=16, top_k=2, d_ff_expert=48,
                                     capacity_factor=8.0))
        p = L.moe_params(cfg, jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32)
                              ).astype(jnp.bfloat16)
        dense = L.moe_dense(cfg, p, x)
        mesh = jax.make_mesh((1, 8), ("data", "model"))
        with mesh:
            with shard_ctx.plan_specs(P("data", None, None), None, mesh=mesh,
                                      ep_axis="model"):
                got = jax.jit(lambda p, x: moe_ep.moe_ep_a2a(cfg, p, x))(p, x)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(dense, np.float32), atol=5e-2)
        print("MOE-EP-OK")
    """))


def test_moe_ep_grads_flow():
    print(_run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.models.config import ArchConfig, MoESpec
        from repro.models import layers as L, moe_ep
        from repro.sharding import ctx as shard_ctx
        cfg = ArchConfig(name="t", family="moe", n_layers=1, d_model=16,
                         n_heads=2, n_kv_heads=1, d_ff=32, vocab=64,
                         moe=MoESpec(num_experts=8, top_k=2, d_ff_expert=24,
                                     capacity_factor=8.0))
        p = L.moe_params(cfg, jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 16))
        mesh = jax.make_mesh((1, 8), ("data", "model"))
        with mesh:
            with shard_ctx.plan_specs(P("data", None, None), None, mesh=mesh,
                                      ep_axis="model"):
                g = jax.jit(jax.grad(lambda p, x: jnp.sum(
                    moe_ep.moe_ep_a2a(cfg, p, x).astype(jnp.float32) ** 2)))(
                    p, x)
        norms = [float(jnp.abs(l).sum()) for l in jax.tree.leaves(g)]
        assert sum(norms) > 0, norms
        assert all(np.isfinite(n) for n in norms)
        print("MOE-EP-GRAD-OK")
    """))
