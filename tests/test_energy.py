"""Energy-aware planning end-to-end: fitted energy predictors, the
latency/energy/EDP Objective through both DP tiers, ground-truth energy
metering in the simulator, and energy-drift detection in the feedback loop.

Also the regression guarantees: the analytic provider's energy queries
reproduce the seed's ``active_power × latency`` algebra, and the default
(latency) objective plans bit-identically to the seed.
"""

import math

import pytest

from repro.core import (LATENCY, Objective, PlannerConfig, plan,
                        resolve_objective, simulate)
from repro.core.cost_model import ANALYTIC, Resource, node_as_resource
from repro.core.dp_partitioner import (partition, partition_data,
                                       partition_model, predicted_energy)
from repro.core.edge_models import (EDGE_MODELS, MODEL_DELTA, battery_cluster,
                                    paper_cluster)
from repro.profiling import (CalibratedCostProvider, CalibrationStore,
                             FeedbackLoop, LearnedCostModel, Profiler,
                             Sample, SyntheticGroundTruth, calibrate)


def profiled_samples(gt=None, seed=0):
    cluster = paper_cluster()
    dags = {k: f() for k, f in EDGE_MODELS.items()}
    return cluster, Profiler(seed=seed).profile_cluster(
        cluster, dags, MODEL_DELTA, ground_truth=gt)


# --------------------------------------------------------------------------
# Objective semantics
# --------------------------------------------------------------------------

def test_objective_validation_and_parse():
    with pytest.raises(ValueError):
        Objective("throughput")
    with pytest.raises(ValueError):
        Objective("energy", latency_budget=-1.0)
    o = Objective.parse("edp@0.5")
    assert (o.metric, o.latency_budget) == ("edp", 0.5)
    assert Objective.parse("energy").latency_budget is None
    assert resolve_objective(None) is LATENCY
    assert LATENCY.is_latency and not Objective("energy").is_latency


def test_edp_tie_breaking():
    """Equal E×T products: lower energy wins, then lower latency."""
    edp = Objective("edp")
    # (lat, en) with identical products 1.0
    assert edp.better(2.0, 0.5, 0.5, 2.0)          # lower energy wins
    assert not edp.better(0.5, 2.0, 2.0, 0.5)
    # equal product *and* equal energy → lower latency breaks the tie
    assert edp.key(1.0, 1.0) < edp.key(1.0 + 1e-12, 1.0)
    assert edp.better(1.0, 1.0, 2.0, 1.0)           # lower E×T outright
    # feasibility dominates the metric entirely
    bounded = Objective("edp", latency_budget=1.0)
    assert bounded.better(1.0, 100.0, 1.1, 0.001)   # only a is within budget


def test_latency_objective_budget_feasibility():
    o = Objective("energy", latency_budget=1.0)
    # infeasible plans compare by latency (drive toward feasibility)
    assert o.better(1.5, 1.0, 2.0, 0.1)
    # feasible always beats infeasible
    assert o.better(0.9, 100.0, 1.01, 0.1)
    # local() keeps the budget but strips the radio term
    loc = Objective("energy", latency_budget=2.0, radio_power=4.0).local()
    assert loc.latency_budget == 2.0 and loc.radio_power == 0.0


# --------------------------------------------------------------------------
# Seed-numerics regressions
# --------------------------------------------------------------------------

def test_analytic_energy_is_power_times_latency():
    r = Resource(name="r", rate=1e11, bw=1e8, rtt=2e-3,
                 active_power=7.5, idle_power=2.0)
    flops, nbytes = 3.3e9, 4.7e6
    assert ANALYTIC.compute_energy(flops, r) == \
        r.active_power * ANALYTIC.compute_time(flops, r)
    assert ANALYTIC.comm_energy(nbytes, r) == \
        r.active_power * ANALYTIC.comm_time(nbytes, r)
    assert ANALYTIC.energy(flops, nbytes, r) == \
        ANALYTIC.compute_energy(flops, r) + ANALYTIC.comm_energy(nbytes, r)


def _seed_predicted_energy(dag, resources, plan_, provider=None):
    """The seed's predicted_energy algebra, inlined verbatim as the oracle."""
    from repro.core.cost_model import resolve_provider
    from repro.core.dag import ModelPartition
    prov = resolve_provider(provider)
    T = plan_.predicted_latency
    busy = {}
    if isinstance(plan_, ModelPartition):
        for si in range(plan_.num_stages):
            a, b = plan_.boundaries[si], plan_.boundaries[si + 1]
            r = resources[plan_.assignment[si]]
            seg = dag.segment(a, b)
            busy[plan_.assignment[si]] = busy.get(
                plan_.assignment[si], 0.0) + (
                prov.compute_time(seg.flops, r, seg.kind)
                + prov.comm_time(seg.bytes_in, r))
    else:
        for f, ri in zip(plan_.fractions, plan_.assignment):
            r = resources[ri]
            busy[ri] = (prov.compute_time(dag.total_flops * f, r,
                                          dag.dominant_kind())
                        + prov.comm_time(
                            (dag.input_bytes + dag.output_bytes) * f, r))
    e = 0.0
    for i, r in enumerate(resources):
        b = min(busy.get(i, 0.0), T)
        e += r.active_power * b + r.idle_power * max(T - b, 0.0)
    return e


def test_predicted_energy_matches_seed_numerics():
    """Both partition modes, all paper workloads: the provider-routed energy
    equals the seed's inlined active_power × busy algebra."""
    cluster = paper_cluster()
    for name in EDGE_MODELS:
        dag = EDGE_MODELS[name]()
        delta = MODEL_DELTA[name]
        resources = [node_as_resource(n, delta) for n in cluster.nodes]
        for plan_ in (partition_model(dag, resources),
                      partition_data(dag, resources)):
            assert predicted_energy(dag, resources, plan_) == pytest.approx(
                _seed_predicted_energy(dag, resources, plan_), rel=1e-12)


def test_default_objective_is_bit_identical_to_seed():
    """Passing the explicit latency Objective changes nothing at all."""
    cluster = paper_cluster()
    for name in ("resnet152", "efficientnet_b0"):
        dag = EDGE_MODELS[name]()
        cfg = PlannerConfig(delta=MODEL_DELTA[name])
        base = plan(dag, cluster, cfg)
        obj = plan(dag, cluster, PlannerConfig(delta=MODEL_DELTA[name],
                                               objective=LATENCY))
        assert base.predicted_latency == obj.predicted_latency
        assert base.predicted_energy == obj.predicted_energy
        assert base.global_plan.partition == obj.global_plan.partition
        for lp0, lp1 in zip(base.local_plans, obj.local_plans):
            assert lp0.partition == lp1.partition


# --------------------------------------------------------------------------
# Fitted energy predictors
# --------------------------------------------------------------------------

def test_energy_entries_fit_and_round_trip_through_store(tmp_path):
    gt = SyntheticGroundTruth(paper_cluster(),
                              power_scale={("orin_nx", "gpu"): 1.7},
                              noise=0.05)
    cluster, samples = profiled_samples(gt)
    store = CalibrationStore(tmp_path)
    for mode in ("linear", "isotonic"):
        model = LearnedCostModel.fit(samples, mode=mode)
        assert model.energy_entries, "energy predictors were not fitted"
        store.save(cluster, model, note=f"energy-{mode}")
        clone = store.load(cluster)
        assert clone.energy_entries.keys() == model.energy_entries.keys()
        for s in samples[::23]:
            assert clone.predict_energy(s.key, s.kind, s.work, s.traffic) == \
                model.predict_energy(s.key, s.kind, s.work, s.traffic)
        assert model.energy_mape_against(samples) < 0.1


def test_fitted_energy_monotone_in_work():
    gt = SyntheticGroundTruth(paper_cluster(), noise=0.1)
    _, samples = profiled_samples(gt)
    for mode in ("linear", "isotonic"):
        model = LearnedCostModel.fit(samples, mode=mode)
        for key, kind in [("orin_nx/gpu", "conv"), ("rpi4/cpu", "dense")]:
            works = [1e8 * (2 ** i) for i in range(12)]
            preds = [model.predict_energy(key, kind, w, 1e5) for w in works]
            assert all(p is not None and p > 0 for p in preds)
            assert all(b >= a * (1 - 1e-9)
                       for a, b in zip(preds, preds[1:])), (mode, key)


def test_energy_recovers_true_power():
    """A processor burning 2× its datasheet watts: the fitted marginal
    energy is ~2× the datasheet active_power / rate."""
    cluster = paper_cluster()
    gt = SyntheticGroundTruth(cluster,
                              power_scale={("tx2", "gpu"): 2.0},
                              noise=0.02)
    _, samples = profiled_samples(gt)
    model = LearnedCostModel.fit(samples)
    tx2_gpu = [p for n in cluster.nodes if n.name == "tx2"
               for p in n.processors if p.name == "gpu"][0]
    work = 5e9
    joules = model.predict_energy("tx2/gpu", "conv", work)
    # true energy ≈ 2 × active_power × (work / rate) plus overhead terms
    expect = 2.0 * tx2_gpu.active_power * work / tx2_gpu.rate(1.0, "conv")
    assert joules == pytest.approx(expect, rel=0.25)


def test_node_energy_aggregates_processors():
    samples = [
        Sample("n/cpu", "conv", 1e9, 1e5, 1.0, energy_j=2.0),
        Sample("n/cpu", "conv", 2e9, 1e5, 2.0, energy_j=4.0),
        Sample("n/gpu", "conv", 1e9, 1e5, 0.25, energy_j=1.0),
        Sample("n/gpu", "conv", 2e9, 1e5, 0.5, energy_j=2.0),
    ]
    model = LearnedCostModel.fit(samples)
    # node-level: work splits by measured rates (1e9 vs 4e9 → 1/5 vs 4/5);
    # energy = 0.2*w*2e-9 + 0.8*w*1e-9 J
    w = 5e9
    expect = 0.2 * w * 2e-9 + 0.8 * w * 1e-9
    assert model.predict_energy("n", "conv", w) == pytest.approx(expect,
                                                                 rel=1e-6)


def test_calibrated_provider_energy_falls_back():
    model = LearnedCostModel.fit(
        [Sample("a/gpu", "conv", 1e9, 1e5, 0.01, energy_j=0.05),
         Sample("a/gpu", "conv", 2e9, 1e5, 0.02, energy_j=0.10)])
    prov = CalibratedCostProvider(model)
    known = Resource(name="a/gpu", rate=1e11, bw=1e10, active_power=5.0)
    unknown = Resource(name="z/npu", rate=1e11, bw=1e10, active_power=3.0)
    assert prov.compute_energy(1e9, known, "conv") == pytest.approx(0.05)
    # unknown resource → datasheet power × (calibrated-or-analytic) time
    assert prov.compute_energy(1e9, unknown, "conv") == pytest.approx(
        3.0 * ANALYTIC.compute_time(1e9, unknown))
    assert math.isfinite(prov.comm_energy(1e6, unknown))


# --------------------------------------------------------------------------
# Energy-aware planning
# --------------------------------------------------------------------------

def test_energy_objective_picks_lower_energy_plan():
    """On the duty-cycled cluster the energy objective must find plans with
    strictly lower predicted *and* simulated energy than latency-only
    planning, within the latency budget, on at least two workloads."""
    cluster = battery_cluster()
    improved = 0
    for name in EDGE_MODELS:
        dag = EDGE_MODELS[name]()
        delta = MODEL_DELTA[name]
        base = plan(dag, cluster, PlannerConfig(delta=delta))
        budget = base.predicted_latency * 1.35
        obj = Objective("energy", latency_budget=budget, radio_power=4.0)
        aware = plan(dag, cluster, PlannerConfig(delta=delta, objective=obj))
        rep_l = simulate(cluster, "hidp", [(0.0, dag, delta)])
        rep_e = simulate(cluster, "hidp", [(0.0, dag, delta)], objective=obj)
        en_l = rep_l.energies()[name]
        en_e = rep_e.energies()[name]
        if (en_e < en_l and aware.predicted_latency <= budget * (1 + 1e-9)):
            improved += 1
    assert improved >= 2, f"energy objective improved only {improved} models"


def test_edp_objective_stays_closer_to_latency():
    """EDP trades less latency away than pure energy minimization."""
    cluster = battery_cluster()
    dag = EDGE_MODELS["resnet152"]()
    delta = MODEL_DELTA["resnet152"]
    base = plan(dag, cluster, PlannerConfig(delta=delta))
    budget = base.predicted_latency * 1.35
    p_en = plan(dag, cluster, PlannerConfig(
        delta=delta, objective=Objective("energy", latency_budget=budget)))
    p_edp = plan(dag, cluster, PlannerConfig(
        delta=delta, objective=Objective("edp", latency_budget=budget)))
    assert p_edp.predicted_latency <= p_en.predicted_latency * (1 + 1e-9)
    assert p_en.predicted_energy <= p_edp.predicted_energy * (1 + 1e-9)


def test_partition_respects_latency_budget():
    """The global DP under a tight budget returns a plan whose predicted
    latency does not exceed the latency-optimal plan's (budget-infeasible
    searches fall back toward the fastest plan)."""
    cluster = battery_cluster()
    dag = EDGE_MODELS["vgg19"]()
    delta = MODEL_DELTA["vgg19"]
    resources = [node_as_resource(n, delta) for n in cluster.nodes]
    fastest = partition(dag, resources)
    tight = Objective("energy", latency_budget=fastest.predicted_latency)
    p = partition(dag, resources, objective=tight)
    assert p.predicted_latency <= fastest.predicted_latency * (1 + 1e-9)


# --------------------------------------------------------------------------
# Runtime: ground-truth energy + drift
# --------------------------------------------------------------------------

def test_simulator_meters_ground_truth_energy():
    """Hardware burning 2× datasheet watts shows up in measured energy and
    in the prediction-error scoreboard; a faithful datasheet does not."""
    cluster = paper_cluster()
    dag = EDGE_MODELS["resnet152"]()
    delta = MODEL_DELTA["resnet152"]
    gt = SyntheticGroundTruth(cluster, power_scale={"orin_nx": 2.5})
    rep_clean = simulate(cluster, "hidp", [(0.0, dag, delta)])
    rep_hot = simulate(cluster, "hidp", [(0.0, dag, delta)], ground_truth=gt)
    assert rep_hot.energies()["resnet152"] > rep_clean.energies()["resnet152"]
    assert rep_clean.prediction_error()["energy"] < 0.05
    assert rep_hot.prediction_error()["energy"] > \
        rep_clean.prediction_error()["energy"]


def test_energy_drift_triggers_replan_when_latency_holds():
    """Power shifts 2.5×, timing stays faithful: only the energy window can
    catch it — and it re-plans exactly once."""
    model = LearnedCostModel.fit(
        [Sample("n/gpu", "conv", w, 0.0, w / 1e9, energy_j=5.0 * w / 1e9)
         for w in (1e8, 2e8, 4e8, 8e8)])
    fb = FeedbackLoop(model, threshold=0.3)
    for i in range(30):
        work = 1e8 * (1 + i % 5)
        fb.observe("n/gpu", "conv", work, 0.0, work / 1e9,
                   energy_j=5.0 * work / 1e9)
    assert fb.replans == 0
    for i in range(30):
        work = 1e8 * (1 + i % 5)
        fb.observe("n/gpu", "conv", work, 0.0, work / 1e9,
                   energy_j=2.5 * 5.0 * work / 1e9)
    assert fb.replans == 1
    assert fb.events[0].metric == "energy"
    # refit from post-change observations tracks the new power draw
    assert model.predict_energy("n/gpu", "conv", 4e8) == pytest.approx(
        2.5 * 5.0 * 4e8 / 1e9, rel=0.05)


def test_simulator_feeds_energy_observations():
    """Diverging power on true hardware reaches the feedback loop through
    the simulator's per-shard observations and trips an energy drift."""
    cluster = paper_cluster()
    dags = {k: f() for k, f in EDGE_MODELS.items()}
    gt = SyntheticGroundTruth(cluster, power_scale={("orin_nx", "gpu"): 3.0})
    clean = calibrate(cluster, dags, MODEL_DELTA)   # believes the datasheet
    fb = FeedbackLoop(clean.model, threshold=0.3)
    reqs = [(0.05 * i, dags["resnet152"], MODEL_DELTA["resnet152"])
            for i in range(4)]
    simulate(cluster, "hidp", reqs, ground_truth=gt, feedback=fb)
    assert fb.replans >= 1
    assert any(e.metric == "energy" for e in fb.events)
    # timing was faithful throughout — latency must not be what tripped
    assert all(e.metric == "energy" for e in fb.events)
