"""Unified decoder-only LM covering the dense / moe / ssm / hybrid families
(gemma-2b, gemma3-1b, minicpm-2b, mistral-large-123b, mixtral-8x7b,
qwen3-moe-30b-a3b, mamba2-780m, hymba-1.5b).

Design notes (DESIGN.md §7):

* **Scan-over-layers** — parameters are stacked along a leading L axis and the
  stack is applied with ``lax.scan``, so HLO size and compile time are O(1) in
  depth (88-layer/123 B-param configs lower in seconds on the CPU dry-run
  host).
* **Non-uniform attention patterns** (gemma3's 5 local : 1 global) ride the
  same uniform stack: a per-layer ``window`` array is scanned alongside the
  params and feeds the mask arithmetic as a traced scalar (global layers get
  window = seq_len, a no-op).
* Layer bodies are ``jax.checkpoint``-wrapped in training (policy chosen by
  the HiDP local plan — a §Perf knob).
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.sharding import ctx as shard_ctx

from . import layers as L
from .config import ArchConfig

CACHE_DTYPE = jnp.bfloat16


@jax.custom_vjp
def _pinned(x):
    """``optimization_barrier`` with a differentiation rule: the primitive
    itself has none, so grad tracing through the scan carry would raise —
    the VJP barriers the cotangent identically, keeping the backward
    residual stream pinned in bf16 too."""
    return jax.lax.optimization_barrier(x)


def _pinned_fwd(x):
    return jax.lax.optimization_barrier(x), None


def _pinned_bwd(_, g):
    return (jax.lax.optimization_barrier(g),)


_pinned.defvjp(_pinned_fwd, _pinned_bwd)


# --------------------------------------------------------------------------
# Parameter construction
# --------------------------------------------------------------------------

def layer_param_template(cfg: ArchConfig, key=None, dtype=jnp.float32) -> dict:
    """Parameters of ONE layer (unstacked)."""
    ks = iter(jax.random.split(key, 8)) if key is not None else iter([None] * 8)
    p: dict[str, Any] = {"ln1": L.norm_params(cfg, cfg.d_model)}
    if cfg.family == "ssm":
        p["ssm"] = L.ssm_params(cfg, next(ks), dtype)
        return p
    p["attn"] = L.attn_params(cfg, next(ks), dtype)
    if cfg.family == "hybrid":
        p["ssm"] = L.ssm_params(cfg, next(ks), dtype)
    p["ln2"] = L.norm_params(cfg, cfg.d_model)
    if cfg.family == "moe":
        p["moe"] = L.moe_params(cfg, next(ks), dtype)
    else:
        p["mlp"] = L.mlp_params(cfg, next(ks), dtype)
    return p


def _stack(template_fn, n: int, key=None):
    """Stack n parameter trees along a new leading axis."""
    if key is None:
        t = template_fn(None)
        return jax.tree.map(
            lambda s: (jax.ShapeDtypeStruct((n,) + tuple(s.shape), s.dtype)
                       if isinstance(s, jax.ShapeDtypeStruct)
                       else jax.ShapeDtypeStruct((n,) + s.shape, s.dtype)),
            t)
    keys = jax.random.split(key, n)
    trees = [template_fn(k) for k in keys]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def init_params(cfg: ArchConfig, key: jax.Array | None = None,
                dtype=jnp.float32) -> dict:
    """Full parameter tree.  key=None → ShapeDtypeStruct tree (dry-run)."""
    ks = jax.random.split(key, 3) if key is not None else [None] * 3
    params = {
        "embed": L.embed_params(cfg, ks[0], dtype),
        "layers": _stack(lambda k: layer_param_template(cfg, k, dtype),
                         cfg.n_layers, ks[1]),
        "final_norm": L.norm_params(cfg, cfg.d_model),
    }
    if key is None:
        params["final_norm"] = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
            params["final_norm"])
        params["embed"] = jax.tree.map(
            lambda x: (x if isinstance(x, jax.ShapeDtypeStruct)
                       else jax.ShapeDtypeStruct(x.shape, x.dtype)),
            params["embed"])
    return params


# --------------------------------------------------------------------------
# Per-layer window schedule (the 5:1 local:global pattern etc.)
# --------------------------------------------------------------------------

def window_schedule(cfg: ArchConfig, kv_len: int) -> jax.Array | None:
    """(L,) int32 of per-layer window sizes, or None if no layer is windowed.
    Global layers get kv_len (mask no-op)."""
    if cfg.sliding_window is None:
        return None
    full = jnp.full((cfg.n_layers,), cfg.sliding_window, jnp.int32)
    if cfg.local_global is not None:
        idx = jnp.arange(cfg.n_layers)
        is_global = (idx % (cfg.local_global + 1)) == cfg.local_global
        full = jnp.where(is_global, kv_len, full)
    return full


# --------------------------------------------------------------------------
# KV / SSM cache
# --------------------------------------------------------------------------

def init_cache(cfg: ArchConfig, batch: int, max_len: int,
               abstract: bool = False) -> dict:
    """Stacked (leading L) decode cache."""
    def mk(shape, dtype=CACHE_DTYPE):
        if abstract:
            return jax.ShapeDtypeStruct(shape, dtype)
        return jnp.zeros(shape, dtype)

    cache: dict[str, Any] = {}
    nl = cfg.n_layers
    if cfg.family != "ssm":
        cache["k"] = mk((nl, batch, max_len, cfg.n_kv_heads, cfg.hd))
        cache["v"] = mk((nl, batch, max_len, cfg.n_kv_heads, cfg.hd))
    if cfg.family in ("ssm", "hybrid"):
        s = cfg.ssm
        di, n, nh = s.d_inner(cfg.d_model), s.d_state, s.n_heads(cfg.d_model)
        cache["h"] = mk((nl, batch, nh, s.head_dim, n), jnp.float32)
        cache["conv"] = mk((nl, batch, s.conv_width - 1, di + 2 * n))
    return cache


# --------------------------------------------------------------------------
# Layer application
# --------------------------------------------------------------------------

def apply_layer(cfg: ArchConfig, p: dict, x: jax.Array, *, mode: str,
                positions: jax.Array, window, layer_cache: dict | None,
                lengths: jax.Array | None, moe_impl: str = "dense"
                ) -> tuple[jax.Array, dict]:
    new_cache: dict[str, Any] = {}
    h = L.apply_norm(cfg, p["ln1"], x)
    if cfg.family == "ssm":
        ssm_cache = (None if layer_cache is None else
                     {"h": layer_cache["h"], "conv": layer_cache["conv"]})
        y, sc = L.mamba_block(cfg, p["ssm"], h, mode=mode, cache=ssm_cache)
        new_cache.update(sc)
        return x + y, new_cache

    attn_cache = (None if layer_cache is None else
                  {"k": layer_cache["k"], "v": layer_cache["v"]})
    a, kv = L.attention(cfg, p["attn"], h, positions=positions, mode=mode,
                        causal=True, window=window, cache=attn_cache,
                        lengths=lengths)
    if kv is not None:
        new_cache.update(kv)
    if cfg.family == "hybrid":
        ssm_cache = (None if layer_cache is None else
                     {"h": layer_cache["h"], "conv": layer_cache["conv"]})
        s, sc = L.mamba_block(cfg, p["ssm"], h, mode=mode, cache=ssm_cache)
        new_cache.update(sc)
        a = (a + s) * 0.5                   # parallel heads, mean-fused
    x = x + a
    h2 = L.apply_norm(cfg, p["ln2"], x)
    if cfg.family == "moe":
        f = L.moe_apply(cfg, p["moe"], h2, impl=moe_impl)
    else:
        f = L.mlp(cfg, p["mlp"], h2)
    return x + f, new_cache


# --------------------------------------------------------------------------
# Full forward passes
# --------------------------------------------------------------------------

def forward(cfg: ArchConfig, params: dict, tokens: jax.Array, *,
            mode: str = "train",
            cache: dict | None = None,
            lengths: jax.Array | None = None,
            moe_impl: str = "dense",
            remat: bool = False,
            remat_group: int = 1,
            logits_tail: int | None = None,
            return_hidden: bool = False) -> tuple[jax.Array, dict | None]:
    """tokens: (B, T) int32.

    mode="train"/"prefill": full sequence; prefill returns the built cache.
    mode="decode": T==1, requires ``cache`` + ``lengths`` (new token position
    = lengths-1).
    ``logits_tail``: only unembed the last N positions (prefill: N=1).
    ``remat_group``: checkpoint every N layers instead of every layer —
    divides saved-activation memory by N at the cost of recomputing up to N
    layers per backward step (a HiDP plan knob for deep, memory-bound
    models).
    """
    b, t = tokens.shape
    x = shard_ctx.constrain_act(
        L.embed(params["embed"], tokens).astype(jnp.bfloat16))
    if mode == "decode":
        assert lengths is not None
        positions = (lengths - 1)[:, None]
        kv_len = cache["k"].shape[2] if "k" in (cache or {}) else t
    else:
        positions = jnp.broadcast_to(jnp.arange(t)[None], (b, t))
        kv_len = t
    wsched = window_schedule(cfg, kv_len)
    return_cache = mode in ("prefill", "decode")

    # window of -1 means "no window" — translate inside via where on mask:
    # the ref kernels accept traced windows; -1 disables via huge value.
    def body(carry, xs):
        x = carry
        p, w, lc = xs
        w_eff = None if wsched is None else jnp.where(w < 0,
                                                      jnp.int32(2 ** 30), w)
        y, nc = apply_layer(cfg, p, x, mode=mode, positions=positions,
                            window=w_eff, layer_cache=lc, lengths=lengths,
                            moe_impl=moe_impl)
        y = shard_ctx.constrain_act(y)
        return y, (nc if return_cache else None)

    xs = (params["layers"],
          (wsched if wsched is not None
           else jnp.zeros((cfg.n_layers,), jnp.int32) - 1),
          cache)
    g = remat_group if (remat and remat_group > 1
                        and cfg.n_layers % remat_group == 0) else 1

    def group_body(carry, xs_g):
        # the barrier pins the checkpointed carry in bf16: without it XLA
        # hoists the backward pass's f32 convert out of the loop and
        # materialises an f32 copy of the whole residual stack (§Perf B)
        carry = _pinned(carry)
        return jax.lax.scan(body, carry, xs_g)

    if remat:
        group_body = jax.checkpoint(
            group_body, policy=jax.checkpoint_policies.nothing_saveable)
    xs = jax.tree.map(
        lambda a: a.reshape((cfg.n_layers // g, g) + a.shape[1:]), xs)
    x, new_cache = jax.lax.scan(group_body, x, xs)
    if return_cache and new_cache is not None:
        new_cache = jax.tree.map(
            lambda a: a.reshape((cfg.n_layers,) + a.shape[2:]), new_cache)
    x = L.apply_norm(cfg, params["final_norm"], x)
    if logits_tail is not None:
        x = x[:, -logits_tail:]
    if return_hidden:
        return x, (new_cache if return_cache else None)
    logits = shard_ctx.constrain_logits(L.unembed(cfg, params["embed"], x))
    return logits, (new_cache if return_cache else None)
