"""Whisper-family encoder-decoder backbone.

The conv frontend is a STUB per the assignment brief: callers provide
precomputed frame embeddings (B, T_enc, d_model) — the shape the stride-2
conv stem would emit (T_enc = audio seq // 2).  Fidelity notes (DESIGN.md):
sinusoidal/learned positional embeddings are replaced with RoPE to share the
attention stack; LayerNorm + GELU are kept per the Whisper family.

Decode cache = {"k","v"} self-attn (stacked L) + static cross KV computed
once at prefill ({"xk","xv"}, stacked L over decoder layers).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.sharding import ctx as shard_ctx

from . import layers as L
from .config import ArchConfig
from .transformer import CACHE_DTYPE, _stack


# --------------------------------------------------------------------------
# Params
# --------------------------------------------------------------------------

def _enc_layer(cfg: ArchConfig, key=None, dtype=jnp.float32) -> dict:
    ks = iter(jax.random.split(key, 2)) if key is not None else iter([None] * 2)
    return {"ln1": L.norm_params(cfg, cfg.d_model),
            "attn": L.attn_params(cfg, next(ks), dtype),
            "ln2": L.norm_params(cfg, cfg.d_model),
            "mlp": L.mlp_params(cfg, next(ks), dtype)}


def _dec_layer(cfg: ArchConfig, key=None, dtype=jnp.float32) -> dict:
    ks = iter(jax.random.split(key, 3)) if key is not None else iter([None] * 3)
    return {"ln1": L.norm_params(cfg, cfg.d_model),
            "attn": L.attn_params(cfg, next(ks), dtype),
            "lnx": L.norm_params(cfg, cfg.d_model),
            "xattn": L.attn_params(cfg, next(ks), dtype),
            "ln2": L.norm_params(cfg, cfg.d_model),
            "mlp": L.mlp_params(cfg, next(ks), dtype)}


def init_params(cfg: ArchConfig, key=None, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 3) if key is not None else [None] * 3

    def norm_or_spec(p):
        if key is None:
            return jax.tree.map(
                lambda x: (x if isinstance(x, jax.ShapeDtypeStruct)
                           else jax.ShapeDtypeStruct(x.shape, x.dtype)), p)
        return p

    return {
        "embed": norm_or_spec(L.embed_params(cfg, ks[0], dtype)),
        "encoder": _stack(lambda k: _enc_layer(cfg, k, dtype),
                          cfg.encoder_layers, ks[1]),
        "decoder": _stack(lambda k: _dec_layer(cfg, k, dtype),
                          cfg.n_layers, ks[2]),
        "enc_norm": norm_or_spec(L.norm_params(cfg, cfg.d_model)),
        "final_norm": norm_or_spec(L.norm_params(cfg, cfg.d_model)),
    }


def init_cache(cfg: ArchConfig, batch: int, max_len: int, enc_len: int,
               abstract: bool = False) -> dict:
    def mk(shape, dtype=CACHE_DTYPE):
        return (jax.ShapeDtypeStruct(shape, dtype) if abstract
                else jnp.zeros(shape, dtype))
    nl, hkv, hd = cfg.n_layers, cfg.n_kv_heads, cfg.hd
    return {"k": mk((nl, batch, max_len, hkv, hd)),
            "v": mk((nl, batch, max_len, hkv, hd)),
            "xk": mk((nl, batch, enc_len, hkv, hd)),
            "xv": mk((nl, batch, enc_len, hkv, hd))}


# --------------------------------------------------------------------------
# Forward
# --------------------------------------------------------------------------

def encode(cfg: ArchConfig, params: dict, frames: jax.Array) -> jax.Array:
    """frames: (B, T_enc, d_model) stub embeddings → encoder states."""
    b, t, _ = frames.shape
    positions = jnp.broadcast_to(jnp.arange(t)[None], (b, t))
    x = frames.astype(jnp.bfloat16)

    def body(x, p):
        h = L.apply_norm(cfg, p["ln1"], x)
        a, _ = L.attention(cfg, p["attn"], h, positions=positions,
                           mode="full", causal=False)
        x = x + a
        x = x + L.mlp(cfg, p["mlp"], L.apply_norm(cfg, p["ln2"], x))
        return shard_ctx.constrain_act(x), None

    x, _ = jax.lax.scan(body, x, params["encoder"])
    return L.apply_norm(cfg, params["enc_norm"], x)


def _cross_kv(cfg: ArchConfig, p: dict, enc: jax.Array
              ) -> tuple[jax.Array, jax.Array]:
    b, te, _ = enc.shape
    hkv, hd = cfg.n_kv_heads, cfg.hd
    ec = enc.astype(jnp.bfloat16)
    k = (ec @ p["wk"].astype(jnp.bfloat16)).reshape(b, te, hkv, hd)
    v = (ec @ p["wv"].astype(jnp.bfloat16)).reshape(b, te, hkv, hd)
    return k, v


def decode(cfg: ArchConfig, params: dict, tokens: jax.Array, *,
           enc: jax.Array | None = None,
           mode: str = "train",
           cache: dict | None = None,
           lengths: jax.Array | None = None,
           logits_tail: int | None = None,
           remat: bool = False,
           return_hidden: bool = False) -> tuple[jax.Array, dict | None]:
    """Decoder pass.  mode="train"/"prefill" needs ``enc`` (encoder states);
    mode="decode" uses the cached cross KV."""
    b, t = tokens.shape
    x = shard_ctx.constrain_act(
        L.embed(params["embed"], tokens).astype(jnp.bfloat16))
    if mode == "decode":
        positions = (lengths - 1)[:, None]
    else:
        positions = jnp.broadcast_to(jnp.arange(t)[None], (b, t))
    return_cache = mode in ("prefill", "decode")

    def body(x, xs):
        p, lc = xs
        h = L.apply_norm(cfg, p["ln1"], x)
        attn_cache = None if lc is None else {"k": lc["k"], "v": lc["v"]}
        a, kv = L.attention(cfg, p["attn"], h, positions=positions,
                            mode=mode, causal=True, cache=attn_cache,
                            lengths=lengths)
        x = x + a
        hx = L.apply_norm(cfg, p["lnx"], x)
        if mode == "decode":
            xk, xv = lc["xk"], lc["xv"]
        else:
            xk, xv = _cross_kv(cfg, p["xattn"], enc)
        c, _ = L.attention(cfg, p["xattn"], hx, positions=positions,
                           mode=mode, causal=False, kv_override=(xk, xv))
        x = x + c
        x = x + L.mlp(cfg, p["mlp"], L.apply_norm(cfg, p["ln2"], x))
        x = shard_ctx.constrain_act(x)
        nc = None
        if return_cache:
            nc = {"k": kv["k"], "v": kv["v"], "xk": xk, "xv": xv}
        return x, nc

    if remat:
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    x, new_cache = jax.lax.scan(body, x, (params["decoder"], cache))
    x = L.apply_norm(cfg, params["final_norm"], x)
    if logits_tail is not None:
        x = x[:, -logits_tail:]
    if return_hidden:
        return x, (new_cache if return_cache else None)
    logits = shard_ctx.constrain_logits(L.unembed(cfg, params["embed"], x))
    return logits, (new_cache if return_cache else None)


def forward(cfg: ArchConfig, params: dict, frames: jax.Array,
            tokens: jax.Array, *, mode: str = "train",
            cache: dict | None = None, lengths: jax.Array | None = None,
            logits_tail: int | None = None,
            remat: bool = False,
            return_hidden: bool = False) -> tuple[jax.Array, dict | None]:
    """Full enc-dec pass (train / prefill).  Decode uses ``decode`` directly."""
    enc = encode(cfg, params, frames)
    return decode(cfg, params, tokens, enc=enc, mode=mode, cache=cache,
                  lengths=lengths, logits_tail=logits_tail, remat=remat,
                  return_hidden=return_hidden)
