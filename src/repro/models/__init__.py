from .config import ArchConfig, MoESpec, SSMSpec, SHAPES, ShapeConfig, \
    shape_applicable  # noqa: F401
from .model import Model, build_model  # noqa: F401
