"""Unified model API over the zoo + analytic cost model.

``build_model(cfg)`` returns a ``Model`` exposing:

* ``init / param_specs / init_cache``      — parameters & decode state
* ``apply_train / apply_prefill / apply_decode`` — the three step kinds
* ``input_specs(shape)``                   — ShapeDtypeStruct stand-ins for
                                             every input (dry-run contract)
* ``step_flops(shape)``                    — MODEL_FLOPS for §Roofline
* ``block_costs(shape)``                   — ModelDAG for the HiDP planner
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.dag import Block, ModelDAG
from . import encdec, transformer, vlm
from .config import ArchConfig, ShapeConfig

I32 = jnp.int32
BF16 = jnp.bfloat16


def _sds(shape, dtype=BF16):
    return jax.ShapeDtypeStruct(tuple(int(s) for s in shape), dtype)


# --------------------------------------------------------------------------
# Analytic per-layer FLOPs (fwd, per token)
# --------------------------------------------------------------------------

def _attn_proj_flops(cfg: ArchConfig) -> float:
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    return 2.0 * d * hq * hd + 2 * (2.0 * d * hkv * hd) + 2.0 * hq * hd * d


def _attn_ctx_flops(cfg: ArchConfig, ctx: float) -> float:
    """QK^T + PV flops per token at effective context ``ctx``."""
    return 4.0 * cfg.n_heads * cfg.hd * ctx


def _mlp_flops(cfg: ArchConfig, d_ff: int | None = None) -> float:
    ff = d_ff if d_ff is not None else cfg.d_ff
    mult = 3 if cfg.act in ("swiglu", "geglu") else 2
    return 2.0 * mult * cfg.d_model * ff


def _moe_flops(cfg: ArchConfig) -> float:
    m = cfg.moe
    router = 2.0 * cfg.d_model * m.num_experts
    expert = m.top_k * 2.0 * 3 * cfg.d_model * m.d_ff_expert
    return router + expert


def _ssm_flops(cfg: ArchConfig, decode: bool) -> float:
    s = cfg.ssm
    d = cfg.d_model
    di, n, nh, hd = s.d_inner(d), s.d_state, s.n_heads(d), s.head_dim
    proj = 2.0 * d * (2 * di + 2 * n + nh) + 2.0 * di * d
    conv = 2.0 * s.conv_width * (di + 2 * n)
    if decode:
        ssd = 2.0 * nh * hd * n * 2            # state update + readout
    else:
        c = s.chunk
        intra = 2.0 * c * n + 2.0 * c * nh * hd      # CB^T row + L·x̄ combine
        inter = 4.0 * nh * hd * n                    # states + y_off
        ssd = intra + inter
    return proj + conv + ssd


def _eff_ctx(T: float, window: float | None, causal: bool = True) -> float:
    base = T / 2 if causal else T
    if window is None:
        return base
    return min(float(window), base)


def layer_flops_per_token(cfg: ArchConfig, ctx: float, *,
                          decode: bool, window: int | None) -> float:
    """One layer, one token, forward."""
    if cfg.family == "ssm":
        return _ssm_flops(cfg, decode)
    f = _attn_proj_flops(cfg) + _attn_ctx_flops(cfg, ctx)
    if cfg.family == "hybrid":
        f += _ssm_flops(cfg, decode)
    if cfg.family == "moe":
        f += _moe_flops(cfg)
    else:
        f += _mlp_flops(cfg)
    return f


def _per_layer_windows(cfg: ArchConfig) -> list[int | None]:
    out: list[int | None] = []
    for i in range(cfg.n_layers):
        w = cfg.sliding_window
        if w is not None and cfg.local_global is not None:
            if (i % (cfg.local_global + 1)) == cfg.local_global:
                w = None                      # global layer
        out.append(w)
    return out


# --------------------------------------------------------------------------
# Model
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig

    # ------------------------------------------------------------------ params
    def init(self, key: jax.Array, dtype=jnp.float32) -> dict:
        if self.cfg.family == "audio":
            return encdec.init_params(self.cfg, key, dtype)
        if self.cfg.family == "vlm":
            return vlm.init_params(self.cfg, key, dtype)
        return transformer.init_params(self.cfg, key, dtype)

    def param_specs(self, dtype=jnp.float32) -> dict:
        if self.cfg.family == "audio":
            return encdec.init_params(self.cfg, None, dtype)
        if self.cfg.family == "vlm":
            return vlm.init_params(self.cfg, None, dtype)
        return transformer.init_params(self.cfg, None, dtype)

    def init_cache(self, batch: int, max_len: int, abstract: bool = False,
                   enc_len: int | None = None) -> dict:
        if self.cfg.family == "audio":
            return encdec.init_cache(self.cfg, batch, max_len,
                                     enc_len or max_len // 2, abstract)
        if self.cfg.family == "vlm":
            return vlm.init_cache(self.cfg, batch, max_len, abstract)
        return transformer.init_cache(self.cfg, batch, max_len, abstract)

    # ------------------------------------------------------------------- steps
    def apply_train(self, params: dict, batch: dict, *, remat: bool = True,
                    moe_impl: str = "dense", remat_group: int = 1,
                    return_hidden: bool = False) -> jax.Array:
        """Returns logits (B, T, V) fp32 — or the final-normed hidden states
        (B, T, d) when ``return_hidden`` (the chunked-CE path unembeds in
        slices to bound the fp32-logits working set)."""
        cfg = self.cfg
        if cfg.family == "audio":
            out, _ = encdec.forward(cfg, params, batch["frames"],
                                    batch["tokens"], mode="train",
                                    remat=remat, return_hidden=return_hidden)
        elif cfg.family == "vlm":
            out, _ = vlm.forward(cfg, params, batch["tokens"],
                                 vision=batch["vision"], mode="train",
                                 remat=remat, return_hidden=return_hidden)
        else:
            out, _ = transformer.forward(cfg, params, batch["tokens"],
                                         mode="train", remat=remat,
                                         remat_group=remat_group,
                                         moe_impl=moe_impl,
                                         return_hidden=return_hidden)
        return out

    def unembed_hidden(self, params: dict, x: jax.Array) -> jax.Array:
        """(B, T, d) → (B, T, V) fp32 logits (shared head)."""
        from . import layers as L
        return L.unembed(self.cfg, params["embed"], x)

    def apply_prefill(self, params: dict, batch: dict, *,
                      moe_impl: str = "dense") -> tuple[jax.Array, dict]:
        cfg = self.cfg
        lengths = batch.get("lengths")
        if cfg.family == "audio":
            return encdec.forward(cfg, params, batch["frames"],
                                  batch["tokens"], mode="prefill",
                                  lengths=lengths, logits_tail=1)
        if cfg.family == "vlm":
            return vlm.forward(cfg, params, batch["tokens"],
                               vision=batch["vision"], mode="prefill",
                               lengths=lengths, logits_tail=1)
        return transformer.forward(cfg, params, batch["tokens"],
                                   mode="prefill", lengths=lengths,
                                   moe_impl=moe_impl, logits_tail=1)

    def apply_decode(self, params: dict, cache: dict, batch: dict, *,
                     moe_impl: str = "dense") -> tuple[jax.Array, dict]:
        cfg = self.cfg
        lengths = batch["lengths"]
        if cfg.family == "audio":
            return encdec.decode(cfg, params, batch["tokens"], mode="decode",
                                 cache=cache, lengths=lengths)
        if cfg.family == "vlm":
            return vlm.forward(cfg, params, batch["tokens"], mode="decode",
                               cache=cache, lengths=lengths)
        return transformer.forward(cfg, params, batch["tokens"],
                                   mode="decode", cache=cache,
                                   lengths=lengths, moe_impl=moe_impl)

    # ----------------------------------------------------------- input specs
    def input_specs(self, shape: ShapeConfig) -> dict:
        """ShapeDtypeStruct stand-ins for every model input (dry-run)."""
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        if shape.kind == "train":
            specs = {"tokens": _sds((B, S), I32),
                     "targets": _sds((B, S), I32)}
            if cfg.family == "audio":
                specs["frames"] = _sds((B, S // 2, cfg.d_model))
            if cfg.family == "vlm":
                specs["vision"] = _sds((B, cfg.n_vision_tokens, cfg.d_model))
            return specs
        if shape.kind == "prefill":
            specs = {"tokens": _sds((B, S), I32), "lengths": _sds((B,), I32)}
            if cfg.family == "audio":
                specs["frames"] = _sds((B, S // 2, cfg.d_model))
            if cfg.family == "vlm":
                specs["vision"] = _sds((B, cfg.n_vision_tokens, cfg.d_model))
            return specs
        # decode: one new token against a cache of S
        return {"tokens": _sds((B, 1), I32), "lengths": _sds((B,), I32)}

    def cache_specs(self, shape: ShapeConfig) -> dict:
        B, S = shape.global_batch, shape.seq_len
        return self.init_cache(B, S, abstract=True,
                               enc_len=S // 2 if self.cfg.family == "audio"
                               else None)

    # ------------------------------------------------------------ cost model
    def step_flops(self, shape: ShapeConfig) -> float:
        """Analytic useful FLOPs for one step (MODEL_FLOPS in §Roofline).
        Train = 3× forward (6ND convention); remat overhead NOT included
        (it shows up in the HLO/MODEL ratio instead)."""
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        decode = shape.kind == "decode"
        T = 1 if decode else S
        tokens = B * T
        total = 0.0
        for w in _per_layer_windows(cfg):
            ctx = _eff_ctx(S if decode else S, w, causal=True)
            total += tokens * layer_flops_per_token(cfg, ctx, decode=decode,
                                                    window=w)
        if cfg.family == "audio":
            enc_tokens = B * (S // 2 if not decode else S // 2)
            enc_layer = (_attn_proj_flops(cfg)
                         + _attn_ctx_flops(cfg, (S // 2) if not decode
                                           else S // 2)
                         + _mlp_flops(cfg))
            if not decode:
                total += enc_tokens * enc_layer * cfg.encoder_layers
            # decoder cross-attention (per decoder layer, context = enc len)
            total += tokens * cfg.n_layers * (
                _attn_ctx_flops(cfg, S // 2) + _attn_proj_flops(cfg) / 2)
        if cfg.family == "vlm":
            ng = vlm.n_groups(cfg)
            total += tokens * ng * (
                _attn_ctx_flops(cfg, cfg.n_vision_tokens)
                + _attn_proj_flops(cfg) / 2 + _mlp_flops(cfg))
        # head (+ embed lookup is gather, ~0 flops)
        head_positions = tokens if shape.kind == "train" else B
        total += head_positions * 2.0 * cfg.d_model * cfg.vocab
        if shape.kind == "train":
            total *= 3.0
        return total

    def param_bytes(self, dtype_bytes: int = 2) -> float:
        return self.cfg.params_total() * dtype_bytes

    # -------------------------------------------------- HiDP planner bridge
    def block_costs(self, shape: ShapeConfig) -> ModelDAG:
        """The model as a partitionable block DAG (embed, L layers, head) for
        the HiDP global/local DP — the TPU-tier analogue of the paper's CNN
        layer DAGs."""
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        decode = shape.kind == "decode"
        T = 1 if decode else S
        tokens = B * T
        act_bytes = float(tokens * cfg.d_model * 2)          # bf16 edge
        mult = 3.0 if shape.kind == "train" else 1.0
        blocks: list[Block] = []
        blocks.append(Block(
            name="embed", kind="embed", flops=tokens * 1e3,  # gather ≈ free
            param_bytes=cfg.vocab * cfg.d_model * 2.0,
            bytes_in=float(tokens * 4), bytes_out=act_bytes,
            data_splittable=True))
        windows = _per_layer_windows(cfg)
        per_layer_params = ((cfg.params_total()
                             - (1 if cfg.tie_embeddings else 2)
                             * cfg.vocab * cfg.d_model)
                            / cfg.n_layers * 2.0)
        kinds = {"moe": "moe", "ssm": "ssm", "hybrid": "ssm"}
        # Decode-step data splitting = context parallelism over the KV cache:
        # legal when the per-layer state is a positional cache (attention),
        # illegal for recurrent SSM state (DESIGN.md §4 feasibility mask).
        decode_splittable = cfg.family not in ("ssm", "hybrid")
        for i, w in enumerate(windows):
            ctx = _eff_ctx(S, w)
            f = tokens * layer_flops_per_token(cfg, ctx, decode=decode,
                                               window=w) * mult
            blocks.append(Block(
                name=f"layer{i}", kind=kinds.get(cfg.family, "attn"),
                flops=f, param_bytes=per_layer_params,
                bytes_in=act_bytes, bytes_out=act_bytes,
                data_splittable=decode_splittable if decode else True))
        head_tokens = tokens if shape.kind == "train" else B
        blocks.append(Block(
            name="head", kind="dense",
            flops=head_tokens * 2.0 * cfg.d_model * cfg.vocab * mult,
            param_bytes=(0.0 if cfg.tie_embeddings
                         else cfg.vocab * cfg.d_model * 2.0),
            bytes_in=act_bytes, bytes_out=float(head_tokens * cfg.vocab * 4),
            data_splittable=True))
        return ModelDAG(name=f"{cfg.name}:{shape.name}", blocks=tuple(blocks),
                        input_bytes=float(tokens * 4),
                        output_bytes=blocks[-1].bytes_out)


def build_model(cfg: ArchConfig) -> Model:
    return Model(cfg)
