"""Expert-parallel MoE via shard_map + all_to_all + sort-based ragged matmul.

This is the HiDP local partitioner's "expert partitioning" sub-mode — the
beyond-P1 lowering that replaces the dense all-expert einsum (layers.moe_dense,
which burns num_experts/top_k× the useful FLOPs) with:

  1. per-chip routing (top-k over a replicated router),
  2. capacity-bounded all_to_all over the EP axis to the chips owning each
     expert (dispatch buffer: (ep, capacity, d)),
  3. sort-by-expert + ``jax.lax.ragged_dot`` grouped matmuls on each chip —
     executed FLOPs ≈ active FLOPs (modulo capacity padding),
  4. all_to_all back + weighted combine at the source chip.

Tokens over capacity are dropped (classic Switch semantics, capacity_factor
1.25 by default); correctness tests compare against moe_dense with a large
capacity factor so nothing drops.

The mesh and EP axis arrive via repro.sharding.ctx (published by the
launcher); without a published mesh the caller should use moe_dense.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.sharding import ctx as shard_ctx
from repro.sharding._compat import shard_map

from .config import ArchConfig


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _quant_i8(v: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-row symmetric int8 quantisation (rows = tokens)."""
    scale = jnp.max(jnp.abs(v.astype(jnp.float32)), axis=-1,
                    keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(v.astype(jnp.float32) / scale), -127, 127
                 ).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def _a2a_i8(v: jax.Array, axis: str) -> jax.Array:
    """all_to_all whose payload crosses the wire in int8 (+fp32 row scales);
    straight-through gradients, themselves int8-quantised on the reverse
    a2a (error stays bounded by the per-row scale)."""
    q, s = _quant_i8(v)
    rq = jax.lax.all_to_all(q, axis, 0, 0, tiled=False)
    rs = jax.lax.all_to_all(s, axis, 0, 0, tiled=False)
    return (rq.astype(jnp.float32) * rs).astype(v.dtype)


def _a2a_i8_fwd(v, axis):
    return _a2a_i8(v, axis), None


def _a2a_i8_bwd(axis, _, g):
    q, s = _quant_i8(g)
    rq = jax.lax.all_to_all(q, axis, 0, 0, tiled=False)
    rs = jax.lax.all_to_all(s, axis, 0, 0, tiled=False)
    return ((rq.astype(jnp.float32) * rs).astype(g.dtype),)


_a2a_i8.defvjp(_a2a_i8_fwd, _a2a_i8_bwd)


def moe_ep_a2a(cfg: ArchConfig, p: dict, x: jax.Array, *,
               axis: str | None = None,
               capacity_factor: float | None = None,
               a2a_dtype: str = "bfloat16") -> jax.Array:
    """x: (B, T, d) — batch/seq sharded per the activation spec, replicated
    over the EP axis.  p: one layer's MoE params (expert dim sharded over the
    EP axis).  Returns (B, T, d) like moe_dense."""
    mesh = shard_ctx.get_mesh()
    if mesh is None:
        from . import layers as L
        return L.moe_dense(cfg, p, x)
    ep_axis = axis or shard_ctx.get_ep_axis() or "model"
    act_spec = shard_ctx.get_act_spec() or P()
    spec = cfg.moe
    cf = capacity_factor or spec.capacity_factor
    ep = mesh.shape[ep_axis] if isinstance(ep_axis, str) else 1
    E = spec.num_experts
    if E % ep == 0:
        replicas = 1
        e_loc = E // ep
    elif ep % E == 0:
        # fewer experts than EP ranks (mixtral 8e over a 16-wide axis):
        # replicate each expert over r ranks and load-balance tokens across
        # replicas; the replicated weight view is a transient gather that
        # shards to one expert per chip (no per-chip memory waste).
        replicas = ep // E
        e_loc = 1
    else:
        from . import layers as L
        return L.moe_dense(cfg, p, x)

    # every rank must own an equal token slice — unless the seq dim is
    # already sharded over the EP axis (sequence-parallel layouts)
    total_tokens = x.shape[0] * x.shape[1]
    bsz_chk = dict(zip(mesh.axis_names, mesh.devices.shape))

    def _shard_chk(nm):
        if nm is None:
            return 1
        if isinstance(nm, tuple):
            o = 1
            for a in nm:
                o *= bsz_chk[a]
            return o
        return bsz_chk[nm]
    act_spec_chk = shard_ctx.get_act_spec() or P()
    seq_e = act_spec_chk[1] if len(act_spec_chk) > 1 else None
    seq_set = (set(seq_e) if isinstance(seq_e, tuple)
               else {seq_e} if seq_e else set())
    if ep_axis not in seq_set:
        div = 1
        for i in range(min(len(act_spec_chk), 2)):
            div *= _shard_chk(act_spec_chk[i])
        if (total_tokens // max(div, 1)) % ep:
            from . import layers as L
            return L.moe_dense(cfg, p, x)

    in_specs = (
        P(*act_spec),                           # x
        P(),                                    # router (replicated)
        P(ep_axis, None, None),                 # w_gate (E·r, d, ffe)
        P(ep_axis, None, None),                 # w_up
        P(ep_axis, None, None),                 # w_down
    )

    # when the activation seq dim is already sharded over the EP axis
    # (sequence-parallel layouts), each rank's block IS its token slice:
    # no slicing on entry and no all-gather on exit.
    seq_entry = act_spec[1] if len(act_spec) > 1 else None
    seq_axes_set = (set(seq_entry) if isinstance(seq_entry, tuple)
                    else {seq_entry} if seq_entry else set())
    tokens_pre_sharded = ep_axis in seq_axes_set

    def local(xb, router, w_gate, w_up, w_down):
        bl, tl, d = xb.shape
        t_full = bl * tl
        if tokens_pre_sharded:
            t = t_full
            x2 = xb.reshape(t, d)
        else:
            # activations are replicated over the EP axis — each rank owns a
            # 1/ep token slice (otherwise every rank would dispatch the same
            # assignments and the expert compute would duplicate ep×)
            t = t_full // ep
            rank = jax.lax.axis_index(ep_axis)
            x2 = jax.lax.dynamic_slice_in_dim(
                xb.reshape(t_full, d), rank * t, t, axis=0)
        cap = _round_up(max(int(t * spec.top_k * cf / ep), 8), 8)
        # 1. routing (fp32)
        logits = x2.astype(jnp.float32) @ router.astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        vals, idx = jax.lax.top_k(probs, spec.top_k)           # (t, k)
        vals = vals / jnp.maximum(vals.sum(-1, keepdims=True), 1e-9)
        flat_e = idx.reshape(-1)                               # (t*k,)
        flat_w = vals.reshape(-1)
        flat_tok = jnp.arange(t * spec.top_k) // spec.top_k
        if replicas == 1:
            dest = flat_e // e_loc                             # (t*k,)
            local_e = flat_e % e_loc
        else:
            dest = flat_e * replicas + (flat_tok % replicas)
            local_e = jnp.zeros_like(flat_e)
        # 2. capacity-bounded dispatch buffers
        onehot_dest = jax.nn.one_hot(dest, ep, dtype=jnp.int32)
        pos = jnp.cumsum(onehot_dest, axis=0) - onehot_dest    # pos within dest
        pos = (pos * onehot_dest).sum(-1)                      # (t*k,)
        keep = pos < cap
        send_x = jnp.zeros((ep, cap, d), xb.dtype)
        send_x = send_x.at[dest, pos].set(
            jnp.where(keep[:, None], x2[flat_tok], 0.0), mode="drop")
        send_el = jnp.zeros((ep, cap), jnp.int32)
        send_el = send_el.at[dest, pos].set(
            jnp.where(keep, local_e, 0), mode="drop")
        # 3. a2a to expert owners (optionally int8-quantised: the dispatch
        # payload is the dominant collective of EP training — §Perf A3)
        if a2a_dtype == "int8":
            recv_x = _a2a_i8(send_x, ep_axis)
        else:
            recv_x = jax.lax.all_to_all(send_x, ep_axis, 0, 0, tiled=False)
        recv_el = jax.lax.all_to_all(send_el[..., None], ep_axis, 0, 0,
                                     tiled=False)[..., 0]
        n = ep * cap
        rx = recv_x.reshape(n, d)
        rel = recv_el.reshape(n)
        # 4. sort by local expert, ragged grouped matmul, unsort
        order = jnp.argsort(rel)
        inv = jnp.argsort(order)
        xs = rx[order].astype(jnp.bfloat16)
        gs = jnp.bincount(rel, length=e_loc).astype(jnp.int32)
        gate = jax.lax.ragged_dot(xs, w_gate.astype(jnp.bfloat16), gs)
        up = jax.lax.ragged_dot(xs, w_up.astype(jnp.bfloat16), gs)
        h = (jax.nn.silu(gate.astype(jnp.float32)).astype(jnp.bfloat16)
             * up)
        out = jax.lax.ragged_dot(h, w_down.astype(jnp.bfloat16), gs)
        out = out[inv].reshape(ep, cap, d)
        # 5. a2a back + weighted combine at source
        if a2a_dtype == "int8":
            back = _a2a_i8(out.astype(jnp.float32), ep_axis)
        else:
            back = jax.lax.all_to_all(out, ep_axis, 0, 0, tiled=False)
        contrib = back[dest, pos].astype(jnp.float32)          # (t*k, d)
        contrib *= (flat_w * keep)[:, None]
        y = jnp.zeros((t, d), jnp.float32).at[flat_tok].add(contrib)
        if tokens_pre_sharded:
            return y.astype(xb.dtype).reshape(bl, tl, d)
        # restore replication over the EP axis (each rank computed its slice)
        y = jax.lax.all_gather(y.astype(xb.dtype), ep_axis, axis=0,
                               tiled=True)
        return y.reshape(bl, tl, d)

    w_gate, w_up, w_down = p["w_gate"], p["w_up"], p["w_down"]
    if replicas > 1:
        # transient replicated-expert view; shards to 1 expert per chip
        w_gate = jnp.repeat(w_gate, replicas, axis=0)
        w_up = jnp.repeat(w_up, replicas, axis=0)
        w_down = jnp.repeat(w_down, replicas, axis=0)
    fn = shard_map(local, mesh=mesh, in_specs=in_specs,
                   out_specs=P(*act_spec), check_vma=False)
    return fn(x, p["router"], w_gate, w_up, w_down)
