"""Architecture configuration system.

One ``ArchConfig`` per assigned architecture (``src/repro/configs/<id>.py``),
with exact figures from the assignment brief.  ``reduced()`` produces the
small-family config used by CPU smoke tests; the full config is exercised
only via the dry-run (ShapeDtypeStruct, no allocation).
"""

from __future__ import annotations

import dataclasses
from typing import Literal

Family = Literal["dense", "moe", "ssm", "hybrid", "audio", "vlm"]


@dataclasses.dataclass(frozen=True)
class MoESpec:
    num_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    router_dtype: str = "float32"


@dataclasses.dataclass(frozen=True)
class SSMSpec:
    d_state: int
    head_dim: int = 64
    expand: int = 2
    chunk: int = 128
    conv_width: int = 4

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None           # default d_model // n_heads
    act: str = "swiglu"                   # swiglu | geglu | gelu
    norm: str = "rmsnorm"                 # rmsnorm | layernorm
    norm_eps: float = 1e-6
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    # attention pattern
    sliding_window: int | None = None     # SWA width (mixtral, gemma3 local)
    local_global: int | None = None       # N local layers per 1 global (gemma3)
    # mixture-of-experts / state-space extensions
    moe: MoESpec | None = None
    ssm: SSMSpec | None = None
    # encoder-decoder (whisper): encoder layer count (decoder = n_layers)
    encoder_layers: int | None = None
    # vision-language (llama-3.2-vision): one cross-attn layer per group of
    # ``cross_attn_every`` self-attn layers; stub frontend supplies
    # ``n_vision_tokens`` precomputed patch embeddings.
    cross_attn_every: int | None = None
    n_vision_tokens: int = 1601
    # notes for DESIGN/EXPERIMENTS
    source: str = ""

    # ------------------------------------------------------------------ derived
    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else (
            self.d_model // max(self.n_heads, 1))

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for the long_500k shape (DESIGN.md §4)."""
        if self.family in ("ssm", "hybrid"):
            return True
        return self.sliding_window is not None

    def params_active(self) -> float:
        """Active parameters per token (MoE counts top_k experts only)."""
        return self._param_count(active_only=True)

    def params_total(self) -> float:
        return self._param_count(active_only=False)

    def _param_count(self, active_only: bool) -> float:
        d, hd = self.d_model, self.hd
        n_q, n_kv = self.n_heads, self.n_kv_heads
        attn = d * n_q * hd + 2 * d * n_kv * hd + n_q * hd * d
        if self.act in ("swiglu", "geglu"):
            ffn_dense = 3 * d * self.d_ff
        else:
            ffn_dense = 2 * d * self.d_ff
        per_layer = 0.0
        if self.family == "ssm":
            s = self.ssm
            di = s.d_inner(d)
            nh = s.n_heads(d)
            # in_proj (z,x,B,C,dt) + conv + out_proj (mamba2 fused projection)
            per_layer = d * (2 * di + 2 * s.d_state + nh) + \
                s.conv_width * (di + 2 * s.d_state) + di * d + nh
        elif self.family == "hybrid":
            s = self.ssm
            di = s.d_inner(d)
            nh = s.n_heads(d)
            ssm_p = d * (2 * di + 2 * s.d_state + nh) + \
                s.conv_width * (di + 2 * s.d_state) + di * d + nh
            per_layer = attn + ssm_p + ffn_dense
        elif self.moe is not None:
            e = self.moe.top_k if active_only else self.moe.num_experts
            moe_ffn = e * 3 * d * self.moe.d_ff_expert + d * self.moe.num_experts
            per_layer = attn + moe_ffn
        else:
            per_layer = attn + ffn_dense
        total = self.n_layers * per_layer
        if self.encoder_layers:
            # encoder self-attn+ffn, decoder already counted; add cross-attn
            total += self.encoder_layers * (attn + ffn_dense)
            total += self.n_layers * attn          # cross-attention blocks
        if self.cross_attn_every:
            n_cross = self.n_layers // self.cross_attn_every
            total += n_cross * (attn + ffn_dense)  # extra cross layers
        emb = self.vocab * d
        total += emb if self.tie_embeddings else 2 * emb
        return float(total)

    def reduced(self) -> "ArchConfig":
        """Small same-family config for CPU smoke tests."""
        kw: dict = dict(
            n_layers=min(self.n_layers, 2 if self.family != "vlm" else 4),
            d_model=64, n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) or 1,
            d_ff=128, vocab=256, head_dim=16,
        )
        if self.moe is not None:
            kw["moe"] = dataclasses.replace(self.moe, num_experts=4,
                                            top_k=min(self.moe.top_k, 2),
                                            d_ff_expert=64)
        if self.ssm is not None:
            kw["ssm"] = dataclasses.replace(self.ssm, d_state=8, head_dim=16,
                                            chunk=8)
        if self.encoder_layers:
            kw["encoder_layers"] = 2
        if self.cross_attn_every:
            kw["cross_attn_every"] = 2
            kw["n_vision_tokens"] = 16
        if self.local_global:
            kw["local_global"] = 2
            kw["n_layers"] = 6
        if self.sliding_window:
            kw["sliding_window"] = 16
        return dataclasses.replace(self, **kw)


# --------------------------------------------------------------------------
# Input shapes (the assigned 4-shape set for LM-family archs)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ArchConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """The 40-cell applicability matrix (skips recorded in DESIGN.md §4)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("pure full-attention arch: long_500k requires "
                       "sub-quadratic attention (DESIGN.md §4)")
    return True, ""
