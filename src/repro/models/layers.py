"""Building blocks shared by every architecture in the zoo.

All functions are pure: ``params`` pytrees in, arrays out.  Compute runs in
bf16 with fp32 softmax/norm accumulations (TPU-native mixed precision);
params are stored in the dtype the caller chooses (fp32 for training, bf16
for serving).

Attention/SSD hot loops dispatch through ``repro.kernels.ops`` so the same
model code lowers via Pallas on TPU and via the blocked-jnp reference on CPU.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.kernels import ops
from .config import ArchConfig, MoESpec, SSMSpec

COMPUTE_DTYPE = jnp.bfloat16


# --------------------------------------------------------------------------
# Norms
# --------------------------------------------------------------------------

def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return ((xf * jax.lax.rsqrt(var + eps)) * (1.0 + w)).astype(x.dtype)


def layernorm(x: jax.Array, w: jax.Array, b: jax.Array,
              eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = xf.mean(-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps) * w + b).astype(x.dtype)


def apply_norm(cfg: ArchConfig, p: Any, x: jax.Array) -> jax.Array:
    if cfg.norm == "layernorm":
        return layernorm(x, p["w"], p["b"], cfg.norm_eps)
    return rmsnorm(x, p["w"], cfg.norm_eps)


def norm_params(cfg: ArchConfig, d: int) -> dict:
    if cfg.norm == "layernorm":
        return {"w": jnp.ones((d,), jnp.float32),
                "b": jnp.zeros((d,), jnp.float32)}
    return {"w": jnp.zeros((d,), jnp.float32)}


# --------------------------------------------------------------------------
# Rotary position embedding
# --------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, T, H, D) with D even; positions: (B, T) absolute indices."""
    d = x.shape[-1]
    freqs = theta ** (-jnp.arange(0, d, 2, dtype=jnp.float32) / d)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B,T,D/2)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# Attention block (self / cross, with optional KV cache)
# --------------------------------------------------------------------------

def attn_params(cfg: ArchConfig, key: jax.Array | None = None,
                dtype=jnp.float32) -> dict:
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    shapes = {"wq": (d, hq * hd), "wk": (d, hkv * hd),
              "wv": (d, hkv * hd), "wo": (hq * hd, d)}
    if key is None:
        return {k: jax.ShapeDtypeStruct(s, dtype) for k, s in shapes.items()}
    ks = jax.random.split(key, len(shapes))
    return {k: (jax.random.normal(kk, s, dtype) / math.sqrt(s[0]))
            for kk, (k, s) in zip(ks, shapes.items())}


def attention(cfg: ArchConfig, p: dict, x: jax.Array, *,
              positions: jax.Array,
              mode: str,
              causal: bool = True,
              window: int | None = None,
              cache: dict | None = None,
              lengths: jax.Array | None = None,
              kv_override: tuple[jax.Array, jax.Array] | None = None,
              ) -> tuple[jax.Array, dict | None]:
    """Self- or cross-attention.

    mode: "full"   — train/prefill over the whole sequence (no cache read);
                     returns (out, new_cache_entry) where the cache entry is
                     the (k, v) computed here (prefill) — caller may discard.
          "decode" — T==1; reads ``cache`` {"k","v"} of shape (B,S,Hkv,hd),
                     writes the new token at ``lengths-1``.
    kv_override: (k, v) already in head layout — cross-attention (whisper
                 decoder / vlm image layers) supplies encoder/image KV.
    """
    b, t, d = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    xc = x.astype(COMPUTE_DTYPE)
    q = (xc @ p["wq"].astype(COMPUTE_DTYPE)).reshape(b, t, hq, hd)
    if kv_override is None:
        k = (xc @ p["wk"].astype(COMPUTE_DTYPE)).reshape(b, t, hkv, hd)
        v = (xc @ p["wv"].astype(COMPUTE_DTYPE)).reshape(b, t, hkv, hd)
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    else:
        k, v = kv_override
        q = rope(q, positions, cfg.rope_theta) if causal else q

    if mode == "decode" and kv_override is None:
        assert cache is not None and lengths is not None
        slot = lengths - 1                                    # (B,)
        bidx = jnp.arange(b)
        k_cache = cache["k"].at[bidx, slot].set(k[:, 0])
        v_cache = cache["v"].at[bidx, slot].set(v[:, 0])
        out = ops.decode_attention(q, k_cache, v_cache, lengths,
                                   window=window)
        new_cache = {"k": k_cache, "v": v_cache}
    elif mode == "decode":                                    # cross, static KV
        kv_len = k.shape[1]
        xl = jnp.full((b,), kv_len) if lengths is None else lengths
        out = ops.decode_attention(q, k, v, xl, window=None)
        new_cache = cache
    else:
        out = ops.flash_attention(q, k, v, causal=causal, window=window,
                                  lengths=lengths)
        new_cache = {"k": k, "v": v}
    out = out.reshape(b, t, hq * hd)
    return (out @ p["wo"].astype(COMPUTE_DTYPE)).astype(x.dtype), new_cache


# --------------------------------------------------------------------------
# MLP (gated / plain)
# --------------------------------------------------------------------------

def mlp_params(cfg: ArchConfig, key=None, dtype=jnp.float32,
               d_ff: int | None = None) -> dict:
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    gated = cfg.act in ("swiglu", "geglu")
    shapes = ({"w_gate": (d, ff), "w_up": (d, ff), "w_down": (ff, d)}
              if gated else {"w_up": (d, ff), "w_down": (ff, d)})
    if key is None:
        return {k: jax.ShapeDtypeStruct(s, dtype) for k, s in shapes.items()}
    ks = jax.random.split(key, len(shapes))
    return {k: jax.random.normal(kk, s, dtype) / math.sqrt(s[0])
            for kk, (k, s) in zip(ks, shapes.items())}


def _act(cfg: ArchConfig, x: jax.Array) -> jax.Array:
    if cfg.act == "swiglu":
        return jax.nn.silu(x)
    return jax.nn.gelu(x, approximate=True)          # geglu / gelu


def mlp(cfg: ArchConfig, p: dict, x: jax.Array) -> jax.Array:
    xc = x.astype(COMPUTE_DTYPE)
    if "w_gate" in p:
        h = _act(cfg, xc @ p["w_gate"].astype(COMPUTE_DTYPE)) * (
            xc @ p["w_up"].astype(COMPUTE_DTYPE))
    else:
        h = _act(cfg, xc @ p["w_up"].astype(COMPUTE_DTYPE))
    return (h @ p["w_down"].astype(COMPUTE_DTYPE)).astype(x.dtype)


# --------------------------------------------------------------------------
# Mixture-of-Experts FFN
# --------------------------------------------------------------------------

def moe_params(cfg: ArchConfig, key=None, dtype=jnp.float32) -> dict:
    spec = cfg.moe
    d, e, ffe = cfg.d_model, spec.num_experts, spec.d_ff_expert
    shapes = {"router": (d, e), "w_gate": (e, d, ffe),
              "w_up": (e, d, ffe), "w_down": (e, ffe, d)}
    if key is None:
        return {k: jax.ShapeDtypeStruct(s, dtype) for k, s in shapes.items()}
    ks = jax.random.split(key, len(shapes))
    return {k: jax.random.normal(kk, s, dtype) / math.sqrt(s[-2])
            for kk, (k, s) in zip(ks, shapes.items())}


def moe_router(spec: MoESpec, router_w: jax.Array, x2d: jax.Array
               ) -> tuple[jax.Array, jax.Array]:
    """Top-k routing. Returns (weights (T,k) fp32, indices (T,k) int32)."""
    logits = x2d.astype(jnp.float32) @ router_w.astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    vals, idx = jax.lax.top_k(probs, spec.top_k)
    vals = vals / jnp.maximum(vals.sum(-1, keepdims=True), 1e-9)
    return vals, idx


def moe_dense(cfg: ArchConfig, p: dict, x: jax.Array) -> jax.Array:
    """Baseline MoE: dense all-expert compute + routed combine.

    This is the "P1 / framework default" lowering: robust under any pjit
    sharding (expert axis shards over 'model'), but it computes every expert
    for every token — num_experts/top_k× more FLOPs than active.  The HiDP
    local partitioner replaces it with the EP-a2a path (models/moe_ep.py)
    when expert-partitioning is selected; see EXPERIMENTS.md §Perf.
    """
    spec = cfg.moe
    b, t, d = x.shape
    x2 = x.reshape(b * t, d)
    vals, idx = moe_router(spec, p["router"], x2)
    w = jnp.zeros((b * t, spec.num_experts), jnp.float32)
    w = w.at[jnp.arange(b * t)[:, None], idx].add(vals)     # (T,E)
    xc = x2.astype(COMPUTE_DTYPE)
    gate = jnp.einsum("td,edf->tef", xc, p["w_gate"].astype(COMPUTE_DTYPE))
    up = jnp.einsum("td,edf->tef", xc, p["w_up"].astype(COMPUTE_DTYPE))
    h = _act(cfg, gate) * up
    out_e = jnp.einsum("tef,efd->ted", h, p["w_down"].astype(COMPUTE_DTYPE))
    y = jnp.einsum("ted,te->td", out_e.astype(jnp.float32), w)
    return y.reshape(b, t, d).astype(x.dtype)


def moe_apply(cfg: ArchConfig, p: dict, x: jax.Array, *,
              impl: str = "dense", mesh=None, axis: str = "model"
              ) -> jax.Array:
    if impl == "dense":
        return moe_dense(cfg, p, x)
    from . import moe_ep
    return moe_ep.moe_ep_a2a(
        cfg, p, x, axis=axis,
        a2a_dtype="int8" if impl == "ep_a2a_q8" else "bfloat16")


# --------------------------------------------------------------------------
# Mamba-2 (SSD) block
# --------------------------------------------------------------------------

def ssm_params(cfg: ArchConfig, key=None, dtype=jnp.float32) -> dict:
    spec = cfg.ssm
    d = cfg.d_model
    di, n, nh, cw = (spec.d_inner(d), spec.d_state, spec.n_heads(d),
                     spec.conv_width)
    proj_out = 2 * di + 2 * n + nh                  # z, x, B, C, dt
    shapes = {"w_in": (d, proj_out), "conv": (cw, di + 2 * n),
              "A_log": (nh,), "D": (nh,), "dt_bias": (nh,),
              "norm": (di,), "w_out": (di, d)}
    if key is None:
        return {k: jax.ShapeDtypeStruct(s, dtype) for k, s in shapes.items()}
    ks = jax.random.split(key, len(shapes))
    out = {}
    for kk, (name, s) in zip(ks, shapes.items()):
        if name == "A_log":
            out[name] = jnp.log(jnp.linspace(1.0, 16.0, s[0])).astype(dtype)
        elif name == "D":
            out[name] = jnp.ones(s, dtype)
        elif name == "dt_bias":
            out[name] = jnp.zeros(s, dtype)
        elif name == "norm":
            out[name] = jnp.zeros(s, dtype)
        else:
            out[name] = jax.random.normal(kk, s, dtype) / math.sqrt(s[0])
    return out


def _causal_conv(xbc: jax.Array, w: jax.Array,
                 conv_state: jax.Array | None) -> tuple[jax.Array, jax.Array]:
    """Depthwise causal conv, width cw.  xbc: (B,T,C); w: (cw,C).
    conv_state: (B,cw-1,C) carried context (decode) or None (prefill).
    Returns (out (B,T,C), new_state (B,cw-1,C))."""
    cw = w.shape[0]
    if conv_state is None:
        conv_state = jnp.zeros((xbc.shape[0], cw - 1, xbc.shape[2]),
                               xbc.dtype)
    full = jnp.concatenate([conv_state, xbc], axis=1)        # (B,T+cw-1,C)
    out = sum(full[:, i:i + xbc.shape[1]] * w[i][None, None]
              for i in range(cw))
    new_state = full[:, -(cw - 1):]
    return jax.nn.silu(out), new_state


def mamba_block(cfg: ArchConfig, p: dict, x: jax.Array, *, mode: str,
                cache: dict | None = None
                ) -> tuple[jax.Array, dict]:
    """One Mamba-2 mixer.  cache = {"h": (B,nh,hd,n), "conv": (B,cw-1,C)}."""
    spec = cfg.ssm
    b, t, d = x.shape
    di, n, nh = spec.d_inner(d), spec.d_state, spec.n_heads(d)
    hd = spec.head_dim
    xc = x.astype(COMPUTE_DTYPE)
    zxbcdt = xc @ p["w_in"].astype(COMPUTE_DTYPE)
    z, xs, B, C, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + n, 2 * di + 2 * n], axis=-1)
    conv_in = jnp.concatenate([xs, B, C], axis=-1)
    conv_state = None if cache is None else cache["conv"]
    conv_out, new_conv = _causal_conv(conv_in, p["conv"].astype(COMPUTE_DTYPE),
                                      conv_state)
    xs, B, C = jnp.split(conv_out, [di, di + n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))   # (B,T,nh)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    xh = xs.reshape(b, t, nh, hd)
    h0 = None if cache is None else cache["h"]
    if mode == "decode":
        assert cache is not None
        y, h_new = ops.ssd_decode_step(cache["h"], xh[:, 0], dt[:, 0], A,
                                       B[:, 0], C[:, 0],
                                       p["D"].astype(jnp.float32))
        y = y[:, None]                                        # (B,1,nh,hd)
    else:
        y, h_new = ops.ssd(xh, dt, A, B, C, p["D"].astype(jnp.float32),
                           chunk=spec.chunk, h0=h0)
    y = y.reshape(b, t, di)
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                p["norm"])
    out = (y.astype(COMPUTE_DTYPE) @ p["w_out"].astype(COMPUTE_DTYPE))
    return out.astype(x.dtype), {"h": h_new, "conv": new_conv}


# --------------------------------------------------------------------------
# Embedding / head
# --------------------------------------------------------------------------

def embed_params(cfg: ArchConfig, key=None, dtype=jnp.float32) -> dict:
    shapes = {"embedding": (cfg.vocab, cfg.d_model)}
    if not cfg.tie_embeddings:
        shapes["head"] = (cfg.d_model, cfg.vocab)
    if key is None:
        return {k: jax.ShapeDtypeStruct(s, dtype) for k, s in shapes.items()}
    ks = jax.random.split(key, len(shapes))
    return {k: jax.random.normal(kk, s, dtype) * 0.02
            for kk, (k, s) in zip(ks, shapes.items())}


def embed(p: dict, tokens: jax.Array) -> jax.Array:
    return p["embedding"][tokens]


def unembed(cfg: ArchConfig, p: dict, x: jax.Array) -> jax.Array:
    w = p["embedding"].T if cfg.tie_embeddings else p["head"]
    return (x.astype(COMPUTE_DTYPE) @ w.astype(COMPUTE_DTYPE)
            ).astype(jnp.float32)
