"""Llama-3.2-Vision-class VLM backbone: groups of self-attention layers with
one image cross-attention layer per group (cross_attn_every).

The vision tower is a STUB per the brief: callers provide (B, n_vision_tokens,
d_model) precomputed patch embeddings.  Cross-attention KV over the image is
computed once (prefill) and is static during decode.

Parameter layout: two-level stack — outer axis = groups (n_layers //
cross_attn_every), inner axis = self layers per group (cross_attn_every − 1);
plus one cross layer per group.  Both levels are lax.scan'ed, keeping HLO
O(1) in depth.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.sharding import ctx as shard_ctx

from . import layers as L
from .config import ArchConfig
from .transformer import CACHE_DTYPE, _stack


def _self_layer(cfg: ArchConfig, key=None, dtype=jnp.float32) -> dict:
    ks = iter(jax.random.split(key, 2)) if key is not None else iter([None] * 2)
    return {"ln1": L.norm_params(cfg, cfg.d_model),
            "attn": L.attn_params(cfg, next(ks), dtype),
            "ln2": L.norm_params(cfg, cfg.d_model),
            "mlp": L.mlp_params(cfg, next(ks), dtype)}


def _cross_layer(cfg: ArchConfig, key=None, dtype=jnp.float32) -> dict:
    ks = iter(jax.random.split(key, 2)) if key is not None else iter([None] * 2)
    return {"ln1": L.norm_params(cfg, cfg.d_model),
            "xattn": L.attn_params(cfg, next(ks), dtype),
            "ln2": L.norm_params(cfg, cfg.d_model),
            "mlp": L.mlp_params(cfg, next(ks), dtype),
            # tanh gates (llama-3.2 cross layers start "closed")
            "gate_attn": jnp.zeros((), dtype),
            "gate_mlp": jnp.zeros((), dtype)}


def n_groups(cfg: ArchConfig) -> int:
    return cfg.n_layers // cfg.cross_attn_every


def self_per_group(cfg: ArchConfig) -> int:
    return cfg.cross_attn_every - 1


def init_params(cfg: ArchConfig, key=None, dtype=jnp.float32) -> dict:
    g, spg = n_groups(cfg), self_per_group(cfg)
    ks = jax.random.split(key, 3) if key is not None else [None] * 3

    def spec_of(p):
        if key is None:
            return jax.tree.map(
                lambda x: (x if isinstance(x, jax.ShapeDtypeStruct)
                           else jax.ShapeDtypeStruct(x.shape, x.dtype)), p)
        return p

    return {
        "embed": spec_of(L.embed_params(cfg, ks[0], dtype)),
        "self": _stack(lambda k: _stack(
            lambda k2: _self_layer(cfg, k2, dtype), spg, k), g, ks[1]),
        "cross": spec_of(_stack(lambda k: _cross_layer(cfg, k, dtype),
                                g, ks[2])),
        "final_norm": spec_of(L.norm_params(cfg, cfg.d_model)),
    }


def init_cache(cfg: ArchConfig, batch: int, max_len: int,
               abstract: bool = False) -> dict:
    def mk(shape, dtype=CACHE_DTYPE):
        return (jax.ShapeDtypeStruct(shape, dtype) if abstract
                else jnp.zeros(shape, dtype))
    g, spg = n_groups(cfg), self_per_group(cfg)
    hkv, hd, nv = cfg.n_kv_heads, cfg.hd, cfg.n_vision_tokens
    return {"k": mk((g, spg, batch, max_len, hkv, hd)),
            "v": mk((g, spg, batch, max_len, hkv, hd)),
            "xk": mk((g, batch, nv, hkv, hd)),
            "xv": mk((g, batch, nv, hkv, hd))}


def forward(cfg: ArchConfig, params: dict, tokens: jax.Array, *,
            vision: jax.Array | None = None,
            mode: str = "train",
            cache: dict | None = None,
            lengths: jax.Array | None = None,
            logits_tail: int | None = None,
            remat: bool = False,
            return_hidden: bool = False) -> tuple[jax.Array, dict | None]:
    """tokens: (B, T); vision: (B, Nv, d_model) stub patch embeddings
    (required for train/prefill; decode reads cached cross KV)."""
    b, t = tokens.shape
    x = shard_ctx.constrain_act(
        L.embed(params["embed"], tokens).astype(jnp.bfloat16))
    if mode == "decode":
        positions = (lengths - 1)[:, None]
    else:
        positions = jnp.broadcast_to(jnp.arange(t)[None], (b, t))
    return_cache = mode in ("prefill", "decode")
    vis = vision.astype(jnp.bfloat16) if vision is not None else None

    def self_body(x, xs):
        p, lc = xs
        h = L.apply_norm(cfg, p["ln1"], x)
        attn_cache = None if lc is None else {"k": lc["k"], "v": lc["v"]}
        a, kv = L.attention(cfg, p["attn"], h, positions=positions,
                            mode=mode, causal=True, cache=attn_cache,
                            lengths=lengths)
        x = x + a
        x = x + L.mlp(cfg, p["mlp"], L.apply_norm(cfg, p["ln2"], x))
        return shard_ctx.constrain_act(x), (kv if return_cache else None)

    def group_body(x, xs):
        gp_self, gp_cross, gc = xs
        sc = None if gc is None else {"k": gc["k"], "v": gc["v"]}
        x, kvs = jax.lax.scan(self_body, x, (gp_self, sc))
        # cross-attention layer
        h = L.apply_norm(cfg, gp_cross["ln1"], x)
        if mode == "decode":
            xk, xv = gc["xk"], gc["xv"]
        else:
            vc = vis
            hkv, hd = cfg.n_kv_heads, cfg.hd
            xk = (vc @ gp_cross["xattn"]["wk"].astype(jnp.bfloat16)
                  ).reshape(b, -1, hkv, hd)
            xv = (vc @ gp_cross["xattn"]["wv"].astype(jnp.bfloat16)
                  ).reshape(b, -1, hkv, hd)
        c, _ = L.attention(cfg, gp_cross["xattn"], h, positions=positions,
                           mode=mode, causal=False, kv_override=(xk, xv))
        gate_a = jnp.tanh(gp_cross["gate_attn"]).astype(x.dtype)
        x = x + gate_a * c
        m = L.mlp(cfg, gp_cross["mlp"], L.apply_norm(cfg, gp_cross["ln2"], x))
        gate_m = jnp.tanh(gp_cross["gate_mlp"]).astype(x.dtype)
        x = shard_ctx.constrain_act(x + gate_m * m)
        nc = None
        if return_cache:
            nc = {"k": kvs["k"], "v": kvs["v"], "xk": xk, "xv": xv}
        return x, nc

    if remat:
        group_body = jax.checkpoint(
            group_body, policy=jax.checkpoint_policies.nothing_saveable)

    x, new_cache = jax.lax.scan(group_body, x,
                                (params["self"], params["cross"], cache))
    x = L.apply_norm(cfg, params["final_norm"], x)
    if logits_tail is not None:
        x = x[:, -logits_tail:]
    if return_hidden:
        return x, (new_cache if return_cache else None)
    logits = shard_ctx.constrain_logits(L.unembed(cfg, params["embed"], x))
    return logits, (new_cache if return_cache else None)
