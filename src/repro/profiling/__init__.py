"""repro.profiling — the paper's DNN Model Analyzer as a subsystem.

Closed loop with the rest of the stack:

    Profiler  ──samples──▶  LearnedCostModel  ──versioned──▶  CalibrationStore
        ▲                        │
        │                 CalibratedCostProvider  ──▶  planner/baselines/sim
        │                        ▲
    measured shard latencies     │ EWMA blend + drift-triggered refit
    (simulator / serving) ──▶  FeedbackLoop  ──on_drift──▶  re-plan (elastic)

Samples carry both seconds and joules; the model fits latency *and* energy
predictors per (kind × processor), and the loop watches both for drift.
See docs/profiling.md for the mapping onto the paper's Fig. 4 FSM and
docs/energy.md for the energy objective built on the fitted predictors.
"""

from .learned import LearnedCostModel, Sample  # noqa: F401
from .profiler import (Profiler, SyntheticGroundTruth,  # noqa: F401
                       block_traffic)
from .provider import CalibratedCostProvider  # noqa: F401
from .store import CalibrationStore  # noqa: F401
from .feedback import DriftEvent, FeedbackLoop  # noqa: F401


def calibrate(cluster, dags, deltas, *, ground_truth=None,
              mode: str = "linear", profiler: "Profiler | None" = None
              ) -> "CalibratedCostProvider":
    """One-call convenience: profile → fit → wrap as a CostProvider."""
    prof = profiler or Profiler()
    samples = prof.profile_cluster(cluster, dags, deltas,
                                   ground_truth=ground_truth)
    model = LearnedCostModel.fit(samples, mode=mode)
    return CalibratedCostProvider(model)
