"""repro.profiling — the paper's DNN Model Analyzer as a subsystem.

Closed loop with the rest of the stack:

    Profiler  ──samples──▶  LearnedCostModel  ──versioned──▶  CalibrationStore
        ▲                        │
        │                 CalibratedCostProvider  ──▶  planner/baselines/sim
        │                        ▲
    measured shard latencies     │ EWMA blend + drift-triggered refit
    (simulator / serving) ──▶  FeedbackLoop  ──on_drift──▶  re-plan (elastic)

Samples carry both seconds and joules; the model fits latency *and* energy
predictors per (kind × processor), and the loop watches both for drift.
See docs/profiling.md for the mapping onto the paper's Fig. 4 FSM and
docs/energy.md for the energy objective built on the fitted predictors.
"""

from .learned import LearnedCostModel, Sample  # noqa: F401
from .profiler import (DEFAULT_KERNEL_SHAPES, Profiler,  # noqa: F401
                       SyntheticGroundTruth, block_traffic)
from .provider import CalibratedCostProvider  # noqa: F401
from .store import CalibrationStore  # noqa: F401
from .feedback import DriftEvent, FeedbackLoop  # noqa: F401


def calibrate(cluster, dags, deltas, *, ground_truth=None,
              mode: str = "linear", profiler: "Profiler | None" = None
              ) -> "CalibratedCostProvider":
    """One-call convenience: profile → fit → wrap as a CostProvider."""
    prof = profiler or Profiler()
    samples = prof.profile_cluster(cluster, dags, deltas,
                                   ground_truth=ground_truth)
    model = LearnedCostModel.fit(samples, mode=mode)
    return CalibratedCostProvider(model)


def calibrate_kernels(store: "CalibrationStore", cluster, *,
                      shapes=None, kinds=None, devices=None,
                      profiler: "Profiler | None" = None,
                      telemetry=None, mode: str = "linear",
                      note: str = "real-kernel sweep"
                      ) -> tuple["LearnedCostModel", int]:
    """Close the real-hardware calibration loop in one call: sweep the
    FULL ``repro.kernels`` set through :meth:`Profiler.profile_kernels`
    on **every visible jax device** (per-device Sample keys; pass
    ``devices=`` to restrict the sweep), fit a :class:`LearnedCostModel`
    from the pooled measurements, and persist it through ``store`` for
    ``cluster``.  Returns ``(model, version)`` — the saved
    ``CalibrationStore`` version a ``PlanCache`` keys on.

    With ``telemetry`` each measured point lands as a ``profile.kernel``
    span and the save as a ``profile.calibration`` counter (attrs:
    version, devices, samples).
    """
    import jax

    from repro.telemetry import active as _tel_active

    tel = _tel_active(telemetry)
    prof = profiler or Profiler()
    devices = list(devices) if devices is not None else jax.devices()
    samples: list[Sample] = []
    for dev in devices:
        samples.extend(prof.profile_kernels(
            shapes=shapes, kinds=kinds, device=dev,
            key=f"{dev.platform}:{dev.id}" if len(devices) > 1 else None,
            telemetry=telemetry))
    model = LearnedCostModel.fit(samples, mode=mode)
    version = store.save(cluster, model, note=note)
    if tel is not None:
        tel.counter("profile.calibration", version=version,
                    devices=len(devices), samples=len(samples),
                    note=note)
    return model, version
