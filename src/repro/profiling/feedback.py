"""FeedbackLoop — closing the Analyzer ↔ Scheduler cycle (paper Fig. 4).

The run-time scheduler measures every shard it executes.  The loop EWMA-
blends each observation into the *live* ``LearnedCostModel`` (so planning
keeps improving smoothly) but detects drift against a frozen **reference**
snapshot of each predictor, taken at fit/refit time.  Detection must not use
the live model: the EWMA adapts within a few observations, which would mask
exactly the sustained regime changes (thermal throttling, contention) the
loop exists to catch.

Per resource, the drift statistic is the mean relative error of the last
``min_observations`` measurements against the reference — recent
observations only, so a long healthy history cannot dilute a real shift.
Latency and energy drift are watched **independently**: an observation may
carry a measured ``energy_j`` alongside its seconds, and a processor whose
timing still tracks the model but whose power draw has shifted (DVFS
residency change, a rail browning out) trips the energy window on its own.
When a resource crosses ``threshold`` on either statistic, the loop

  1. hard-refits that resource's predictors from its most recent
     observations (the post-change regime, not the stale buffer),
  2. replaces their reference snapshots with the new fits,
  3. bumps ``calibration_version`` — the counter plan caches key on
     (``repro.serving.plan_cache.PlanCache`` wired as ``version_source``
     sees every cached frontier invalidate atomically at this instant),
  4. fires ``on_drift`` exactly once — the hook that re-enters EXPLORE:
     ``runtime.elastic.ElasticController.on_drift`` for the TPU runtime,
     or any re-planning callback for the edge simulator,
  5. resets the drift windows so the refitted model gets a clean slate.

A drift event therefore costs one re-plan, not one per observation.
With a ``telemetry=`` recorder wired, every trip also lands as a
``feedback.drift`` gauge (value = the drift magnitude, attrs = metric,
resource, new calibration version) — see docs/observability.md.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable

from .learned import LearnedCostModel


@dataclasses.dataclass(frozen=True)
class DriftEvent:
    at_observation: int
    mean_error: float
    metric: str = "latency"          # "latency" | "energy"


class FeedbackLoop:
    def __init__(self, model: LearnedCostModel, *,
                 threshold: float = 0.3,
                 alpha: float = 0.3,
                 window: int = 6,
                 min_observations: int = 3,
                 buffer_size: int = 64,
                 on_drift: Callable[[], object] | None = None,
                 calibration_version: int = 0,
                 telemetry=None):
        self.model = model
        from repro.telemetry import active as _tel_active
        self.telemetry = _tel_active(telemetry)
        self.threshold = threshold
        self.alpha = alpha
        self.min_observations = min_observations
        self.on_drift = on_drift
        self.observations = 0
        self.replans = 0
        # monotone counter a PlanCache keys cached frontiers on: seed it
        # with the CalibrationStore version the model was loaded at, and
        # every drift event advances it (invalidating those fronts)
        self.calibration_version = calibration_version
        self.events: list[DriftEvent] = []
        self._window = window
        self._errors: dict[str, deque[float]] = {}
        self._energy_errors: dict[str, deque[float]] = {}
        # rows are (work, traffic, measured_s, energy_j-or-0)
        self._buffers: dict[tuple[str, str],
                            deque[tuple[float, float, float, float]]] = {}
        self._buffer_size = buffer_size
        # frozen per-(key, kind) predictor snapshots drift is measured against
        self._reference: dict[tuple[str, str], object] = {}
        self._energy_reference: dict[tuple[str, str], object] = {}

    # ------------------------------------------------------------- ingest
    def _reference_for(self, key: str, kind: str):
        ek = (key, kind)
        if ek not in self._reference:
            live = (self.model.entries.get(ek)
                    or self.model.entries.get((key, "generic")))
            if live is None:
                return None
            self._reference[ek] = dataclasses.replace(live)
        return self._reference[ek]

    def _energy_reference_for(self, key: str, kind: str):
        ek = (key, kind)
        if ek not in self._energy_reference:
            live = (self.model.energy_entries.get(ek)
                    or self.model.energy_entries.get((key, "generic")))
            if live is None:
                return None
            self._energy_reference[ek] = dataclasses.replace(live)
        return self._energy_reference[ek]

    def observe(self, key: str, kind: str, work: float, traffic: float,
                measured_s: float, energy_j: float | None = None) -> bool:
        """One measured shard execution — seconds and, when the platform
        meters it, joules.  Returns True iff this observation tripped a
        drift threshold (latency or energy) and a re-plan was triggered."""
        if work <= 0 or measured_s <= 0:
            return False
        self.observations += 1
        joules = float(energy_j) if energy_j is not None and energy_j > 0 \
            else 0.0
        buf = self._buffers.setdefault(
            (key, kind), deque(maxlen=self._buffer_size))
        buf.append((work, traffic, measured_s, joules))

        ref = self._reference_for(key, kind)
        if ref is None:
            # first sight of this resource: seed predictors + references
            self.model.observe(key, kind, work, traffic, measured_s,
                               alpha=1.0)
            self._reference_for(key, kind)
            if joules > 0:
                self.model.observe_energy(key, kind, work, traffic, joules,
                                          alpha=1.0)
                self._energy_reference_for(key, kind)
            return False
        predicted = ref.linear(work, traffic)
        err = abs(predicted - measured_s) / max(measured_s, 1e-12)
        errs = self._errors.setdefault(key, deque(maxlen=self._window))
        errs.append(err)
        self.model.observe(key, kind, work, traffic, measured_s, self.alpha)

        if joules > 0:
            eref = self._energy_reference_for(key, kind)
            if eref is None:
                self.model.observe_energy(key, kind, work, traffic, joules,
                                          alpha=1.0)
                self._energy_reference_for(key, kind)
            else:
                epred = eref.linear(work, traffic)
                eerr = abs(epred - joules) / max(joules, 1e-12)
                eerrs = self._energy_errors.setdefault(
                    key, deque(maxlen=self._window))
                eerrs.append(eerr)
                self.model.observe_energy(key, kind, work, traffic, joules,
                                          self.alpha)

        # trigger only when the last min_observations errors *all* exceed
        # the threshold: a regime change sustains high error, noise does
        # not — and waiting for a full bad tail means the refit below sees
        # only post-change samples, so one change costs one re-plan
        if self._sustained(self._errors.get(key)):
            return self._trip(key, self.drift(key), "latency")
        if self._sustained(self._energy_errors.get(key)):
            return self._trip(key, self.energy_drift(key), "energy")
        return False

    def _sustained(self, errs: deque[float] | None) -> bool:
        if not errs:
            return False
        tail = list(errs)[-self.min_observations:]
        return (len(tail) >= self.min_observations
                and min(tail) > self.threshold)

    def _trip(self, key: str, drift_now: float, metric: str) -> bool:
        self._refit_key(key)
        self.replans += 1
        self.calibration_version += 1      # stale plan fronts die here
        self.events.append(DriftEvent(self.observations, drift_now, metric))
        if self.telemetry is not None:
            self.telemetry.gauge(
                "feedback.drift", float(drift_now), metric=metric,
                resource=key, calibration_version=self.calibration_version,
                at_observation=self.observations)
        self._errors.clear()          # fresh slate for the refitted model
        self._energy_errors.clear()
        if self.on_drift is not None:
            self.on_drift()
        return True

    def drift(self, key: str | None = None) -> float:
        """Mean relative latency error of the last ``min_observations``
        measurements against the reference — for one resource, or the worst
        when None."""
        return self._recent(self._errors, key)

    def energy_drift(self, key: str | None = None) -> float:
        """The energy twin of :meth:`drift`."""
        return self._recent(self._energy_errors, key)

    def _recent(self, table: dict[str, deque[float]],
                key: str | None) -> float:
        def recent_mean(errs: deque[float]) -> float:
            tail = list(errs)[-self.min_observations:]
            return sum(tail) / len(tail) if tail else 0.0
        if key is not None:
            errs = table.get(key)
            return recent_mean(errs) if errs else 0.0
        return max((recent_mean(e) for e in table.values() if e),
                   default=0.0)

    def _refit_key(self, key: str) -> None:
        """Hard-refit the drifted resource from its *recent* observations —
        the post-change regime — and re-snapshot its references.  Latency
        and energy predictors refit together: a drift event invalidates the
        whole picture of the resource, not one response variable."""
        for (k, kind), buf in self._buffers.items():
            if k != key or not buf:
                continue
            recent = list(buf)[-max(self.min_observations, 2):]
            self.model.fit_entry(k, kind, [r[:3] for r in recent])
            self._reference[(k, kind)] = dataclasses.replace(
                self.model.entries[(k, kind)])
            energy_rows = [(w, t, e) for w, t, _, e in recent if e > 0]
            if energy_rows:
                self.model.fit_energy_entry(k, kind, energy_rows)
                self._energy_reference[(k, kind)] = dataclasses.replace(
                    self.model.energy_entries[(k, kind)])

    # ------------------------------------------------------------- churn
    def forget_resource(self, node: str) -> int:
        """Drop every drift window, observation buffer, and reference
        snapshot for ``node`` (its node-level key and any ``node/proc``
        processor keys).  A ``repro.fleet.FleetController`` calls this when
        the node leaves the fleet: measurements from before an outage must
        not sit in the window that judges the node's first post-return
        shards — thermal state, DVFS residency, even the battery that
        caused the outage all reset across it.  The fitted predictors in
        the live model are *kept* (they are the best prior available);
        references re-snapshot from them on the next observation.  Returns
        the number of per-(key, kind) entries dropped."""
        def ours(key: str) -> bool:
            return key == node or key.startswith(f"{node}/")

        dropped = 0
        for table in (self._buffers, self._reference,
                      self._energy_reference):
            for k in [k for k in table if ours(k[0])]:
                del table[k]
                dropped += 1
        for table in (self._errors, self._energy_errors):
            for k in [k for k in table if ours(k)]:
                del table[k]
        return dropped

    # ---------------------------------------------------------- convenience
    def ingest_plan_execution(self, spans, plans: dict | None = None) -> int:
        """Feed a batch of simulator ExecutionSpans (duck-typed: .node,
        .processor, .flops, .start, .end, optional .watts).  Returns the
        number of drift triggers.  The span's flops are already δ-weighted by
        the caller's convention when delta==1; prefer the simulator's
        built-in feedback hook for per-shard accuracy."""
        triggers = 0
        for s in spans:
            dur = s.end - s.start
            if dur > 0 and s.flops > 0:
                watts = getattr(s, "watts", 0.0)
                if self.observe(f"{s.node}/{s.processor}", "generic",
                                s.flops, 0.0, dur,
                                energy_j=watts * dur if watts > 0 else None):
                    triggers += 1
        return triggers
