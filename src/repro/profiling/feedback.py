"""FeedbackLoop — closing the Analyzer ↔ Scheduler cycle (paper Fig. 4).

The run-time scheduler measures every shard it executes.  The loop EWMA-
blends each observation into the *live* ``LearnedCostModel`` (so planning
keeps improving smoothly) but detects drift against a frozen **reference**
snapshot of each predictor, taken at fit/refit time.  Detection must not use
the live model: the EWMA adapts within a few observations, which would mask
exactly the sustained regime changes (thermal throttling, contention) the
loop exists to catch.

Per resource, the drift statistic is the mean relative error of the last
``min_observations`` measurements against the reference — recent
observations only, so a long healthy history cannot dilute a real shift.
When a resource crosses ``threshold``, the loop

  1. hard-refits that resource's predictors from its most recent
     observations (the post-change regime, not the stale buffer),
  2. replaces their reference snapshots with the new fits,
  3. fires ``on_drift`` exactly once — the hook that re-enters EXPLORE:
     ``runtime.elastic.ElasticController.on_drift`` for the TPU runtime,
     or any re-planning callback for the edge simulator,
  4. resets the drift windows so the refitted model gets a clean slate.

A drift event therefore costs one re-plan, not one per observation.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable

from .learned import LearnedCostModel


@dataclasses.dataclass(frozen=True)
class DriftEvent:
    at_observation: int
    mean_error: float


class FeedbackLoop:
    def __init__(self, model: LearnedCostModel, *,
                 threshold: float = 0.3,
                 alpha: float = 0.3,
                 window: int = 6,
                 min_observations: int = 3,
                 buffer_size: int = 64,
                 on_drift: Callable[[], object] | None = None):
        self.model = model
        self.threshold = threshold
        self.alpha = alpha
        self.min_observations = min_observations
        self.on_drift = on_drift
        self.observations = 0
        self.replans = 0
        self.events: list[DriftEvent] = []
        self._window = window
        self._errors: dict[str, deque[float]] = {}
        self._buffers: dict[tuple[str, str],
                            deque[tuple[float, float, float]]] = {}
        self._buffer_size = buffer_size
        # frozen per-(key, kind) predictor snapshots drift is measured against
        self._reference: dict[tuple[str, str], object] = {}

    # ------------------------------------------------------------- ingest
    def _reference_for(self, key: str, kind: str):
        ek = (key, kind)
        if ek not in self._reference:
            live = (self.model.entries.get(ek)
                    or self.model.entries.get((key, "generic")))
            if live is None:
                return None
            self._reference[ek] = dataclasses.replace(live)
        return self._reference[ek]

    def observe(self, key: str, kind: str, work: float, traffic: float,
                measured_s: float) -> bool:
        """One measured shard execution.  Returns True iff this observation
        tripped the drift threshold (and a re-plan was triggered)."""
        if work <= 0 or measured_s <= 0:
            return False
        self.observations += 1
        buf = self._buffers.setdefault(
            (key, kind), deque(maxlen=self._buffer_size))
        buf.append((work, traffic, measured_s))

        ref = self._reference_for(key, kind)
        if ref is None:
            # first sight of this resource: seed predictor + reference
            self.model.observe(key, kind, work, traffic, measured_s,
                               alpha=1.0)
            self._reference_for(key, kind)
            return False
        predicted = ref.linear(work, traffic)
        err = abs(predicted - measured_s) / max(measured_s, 1e-12)
        errs = self._errors.setdefault(key, deque(maxlen=self._window))
        errs.append(err)
        self.model.observe(key, kind, work, traffic, measured_s, self.alpha)

        # trigger only when the last min_observations errors *all* exceed
        # the threshold: a regime change sustains high error, noise does
        # not — and waiting for a full bad tail means the refit below sees
        # only post-change samples, so one change costs one re-plan
        tail = list(errs)[-self.min_observations:]
        if (len(tail) >= self.min_observations
                and min(tail) > self.threshold):
            drift_now = self.drift(key)
            self._refit_key(key)
            self.replans += 1
            self.events.append(DriftEvent(self.observations, drift_now))
            self._errors.clear()       # fresh slate for the refitted model
            if self.on_drift is not None:
                self.on_drift()
            return True
        return False

    def drift(self, key: str | None = None) -> float:
        """Mean relative error of the last ``min_observations`` measurements
        against the reference — for one resource, or the worst when None."""
        def recent_mean(errs: deque[float]) -> float:
            tail = list(errs)[-self.min_observations:]
            return sum(tail) / len(tail) if tail else 0.0
        if key is not None:
            errs = self._errors.get(key)
            return recent_mean(errs) if errs else 0.0
        return max((recent_mean(e) for e in self._errors.values() if e),
                   default=0.0)

    def _refit_key(self, key: str) -> None:
        """Hard-refit the drifted resource from its *recent* observations —
        the post-change regime — and re-snapshot its references."""
        for (k, kind), buf in self._buffers.items():
            if k != key or not buf:
                continue
            recent = list(buf)[-max(self.min_observations, 2):]
            self.model.fit_entry(k, kind, recent)
            self._reference[(k, kind)] = dataclasses.replace(
                self.model.entries[(k, kind)])

    # ---------------------------------------------------------- convenience
    def ingest_plan_execution(self, spans, plans: dict | None = None) -> int:
        """Feed a batch of simulator ExecutionSpans (duck-typed: .node,
        .processor, .flops, .start, .end).  Returns the number of drift
        triggers.  The span's flops are already δ-weighted by the caller's
        convention when delta==1; prefer the simulator's built-in feedback
        hook for per-shard accuracy."""
        triggers = 0
        for s in spans:
            dur = s.end - s.start
            if dur > 0 and s.flops > 0:
                if self.observe(f"{s.node}/{s.processor}", "generic",
                                s.flops, 0.0, dur):
                    triggers += 1
        return triggers
