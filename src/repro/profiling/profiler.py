"""Profiler — the measurement half of the paper's DNN Model Analyzer.

Two measurement paths:

* ``profile_cluster`` micro-benchmarks the analytic block DAGs from
  ``core/edge_models.py`` against a ground truth — by default the datasheet
  itself, or a ``SyntheticGroundTruth`` whose per-processor rates diverge
  from it (thermal throttling, contention, a mis-declared board).  This is
  the deterministic testbed path: seeded jitter, warmup discards, trimmed
  means — the shape of real profiling without real hardware.

* ``profile_kernels`` wall-clock times the actual jax kernels in
  ``repro.kernels`` (blocked/Pallas-interpret lowering on CPU), producing
  real timing samples for the host — the path a physical deployment extends
  per device.

Both produce ``learned.Sample`` rows that ``LearnedCostModel.fit`` consumes.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Mapping, Sequence

import numpy as np

from repro.core.cost_model import Cluster, Node, Processor
from repro.core.dag import Block, ModelDAG

from .learned import Sample


def block_traffic(block: Block) -> float:
    """Bytes a block touches: weights plus in/out activations."""
    return block.param_bytes + block.bytes_in + block.bytes_out


# --------------------------------------------------------------------------
# Ground truth — what the hardware actually does
# --------------------------------------------------------------------------

@dataclasses.dataclass
class SyntheticGroundTruth:
    """True per-processor performance, possibly diverging from the datasheet.

    ``rate_scale`` maps ``(node_name, proc_name)`` (or ``node_name`` for the
    whole node) to a multiplier on the analytic rate: 0.4 means the processor
    sustains 40% of what the cost model believes.  ``power_scale`` does the
    same for active power draw: 1.5 means the processor really burns 1.5× its
    datasheet active watts (DVFS residency, rail losses, a mis-declared TDP)
    — the divergence the energy predictors exist to learn.  ``mem_bw`` and
    ``overhead_s`` add the memory-traffic and fixed-launch terms real
    measurements contain; ``noise`` is the relative jitter σ applied by
    ``sample_seconds`` (deterministic under a caller-provided rng).
    """

    cluster: Cluster
    rate_scale: Mapping[str, float] | Mapping[tuple[str, str], float] = \
        dataclasses.field(default_factory=dict)
    power_scale: Mapping[str, float] | Mapping[tuple[str, str], float] = \
        dataclasses.field(default_factory=dict)
    mem_bw: float = 12e9
    overhead_s: float = 2e-4
    noise: float = 0.0

    def _proc(self, node_name: str, proc_name: str) -> tuple[Node, Processor]:
        for n in self.cluster.nodes:
            if n.name == node_name:
                for p in n.processors:
                    if p.name == proc_name:
                        return n, p
        raise KeyError(f"{node_name}/{proc_name}")

    @staticmethod
    def _scale_from(table: Mapping, node_name: str, proc_name: str) -> float:
        rs = dict(table)
        return rs.get((node_name, proc_name),
                      rs.get(f"{node_name}/{proc_name}",
                             rs.get(node_name, 1.0)))

    def scale(self, node_name: str, proc_name: str) -> float:
        return self._scale_from(self.rate_scale, node_name, proc_name)

    def active_watts(self, node_name: str, proc_name: str) -> float:
        """The active power the hardware actually draws (W)."""
        _, p = self._proc(node_name, proc_name)
        return p.active_power * self._scale_from(self.power_scale,
                                                 node_name, proc_name)

    def rate(self, node_name: str, proc_name: str, kind: str,
             delta: float) -> float:
        """The rate the hardware actually sustains (flops/s at this δ)."""
        _, p = self._proc(node_name, proc_name)
        return p.rate(delta, kind) * self.scale(node_name, proc_name)

    def compute_seconds(self, node_name: str, proc_name: str, flops: float,
                        kind: str, delta: float) -> float:
        """Pure compute time of a shard — what the simulator's EXECUTE
        state charges when this ground truth replaces the datasheet."""
        return flops / max(self.rate(node_name, proc_name, kind, delta),
                           1e-12)

    def block_seconds(self, node_name: str, proc_name: str, block: Block,
                      delta: float) -> float:
        """Noise-free micro-benchmark latency of one block."""
        return (self.compute_seconds(node_name, proc_name, block.flops,
                                     block.kind, delta)
                + block_traffic(block) / self.mem_bw
                + self.overhead_s)

    def sample_seconds(self, node_name: str, proc_name: str, block: Block,
                       delta: float, rng: np.random.Generator) -> float:
        base = self.block_seconds(node_name, proc_name, block, delta)
        if self.noise <= 0:
            return base
        return base * float(np.clip(1.0 + self.noise * rng.standard_normal(),
                                    0.5, 2.0))


# --------------------------------------------------------------------------
# Profiler
# --------------------------------------------------------------------------

@dataclasses.dataclass
class Profiler:
    """Micro-benchmark driver: warmup, repeats, trimmed mean, fixed seed."""

    warmup: int = 2
    repeats: int = 5
    trim: int = 1                # drop the k fastest and k slowest repeats
    seed: int = 0

    def _trimmed_mean(self, xs: Sequence[float]) -> float:
        xs = sorted(xs)
        if len(xs) > 2 * self.trim:
            xs = xs[self.trim:len(xs) - self.trim]
        return float(np.mean(xs))

    def profile_cluster(self, cluster: Cluster,
                        dags: Mapping[str, ModelDAG],
                        deltas: Mapping[str, float],
                        ground_truth: SyntheticGroundTruth | None = None,
                        ) -> list[Sample]:
        """Per-(block × processor) timing/energy samples over every node.

        Deterministic: one seeded generator drives all jitter, and the
        iteration order is fixed (nodes → processors → dags → blocks).
        """
        gt = ground_truth or SyntheticGroundTruth(cluster)
        rng = np.random.default_rng(self.seed)
        samples: list[Sample] = []
        for node in cluster.nodes:
            for proc in node.processors:
                for name, dag in dags.items():
                    delta = deltas[name]
                    for block in dag.blocks:
                        for _ in range(self.warmup):   # cache/DVFS settle
                            gt.sample_seconds(node.name, proc.name, block,
                                              delta, rng)
                        reps = [gt.sample_seconds(node.name, proc.name,
                                                  block, delta, rng)
                                for _ in range(self.repeats)]
                        lat = self._trimmed_mean(reps)
                        samples.append(Sample(
                            key=f"{node.name}/{proc.name}",
                            kind=block.kind,
                            work=block.flops * delta,
                            traffic=block_traffic(block),
                            latency_s=lat,
                            energy_j=lat * gt.active_watts(node.name,
                                                           proc.name)))
        return samples

    # ------------------------------------------------------- real kernels
    def profile_kernels(self, *, block_q: int = 32,
                        block_k: int = 32) -> list[Sample]:
        """Wall-clock the repro.kernels attention/SSD ops on the host.

        Small shapes by design: this demonstrates the real-measurement path
        (warmup → repeats → trimmed mean) with the same Sample output as the
        synthetic path; a hardware deployment would sweep real shapes.
        """
        import jax
        import jax.numpy as jnp

        from repro.kernels import ops

        backend = jax.default_backend()
        key = f"host/{backend}"
        samples: list[Sample] = []
        rng = jax.random.PRNGKey(self.seed)

        def bench(fn, *args) -> float:
            for _ in range(self.warmup):
                jax.block_until_ready(fn(*args))
            reps = []
            for _ in range(self.repeats):
                t0 = time.perf_counter()
                jax.block_until_ready(fn(*args))
                reps.append(time.perf_counter() - t0)
            return self._trimmed_mean(reps)

        for b, t, h, d in ((1, 64, 4, 32), (1, 128, 4, 32), (2, 128, 4, 32)):
            ks = jax.random.split(rng, 3)
            q = jax.random.normal(ks[0], (b, t, h, d), jnp.float32)
            k = jax.random.normal(ks[1], (b, t, h, d), jnp.float32)
            v = jax.random.normal(ks[2], (b, t, h, d), jnp.float32)
            lat = bench(lambda q, k, v: ops.flash_attention(
                q, k, v, block_q=block_q, block_k=block_k), q, k, v)
            flops = 4.0 * b * t * t * h * d        # QK^T + AV
            traffic = 4.0 * (q.size + k.size + v.size + q.size)
            samples.append(Sample(key=key, kind="attn", work=flops,
                                  traffic=traffic, latency_s=lat))
        return samples
