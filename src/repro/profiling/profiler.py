"""Profiler — the measurement half of the paper's DNN Model Analyzer.

Two measurement paths:

* ``profile_cluster`` micro-benchmarks the analytic block DAGs from
  ``core/edge_models.py`` against a ground truth — by default the datasheet
  itself, or a ``SyntheticGroundTruth`` whose per-processor rates diverge
  from it (thermal throttling, contention, a mis-declared board).  This is
  the deterministic testbed path: seeded jitter, warmup discards, trimmed
  means — the shape of real profiling without real hardware.

* ``profile_kernels`` wall-clock times the actual jax kernels in
  ``repro.kernels`` — the FULL set (prefill flash attention, decode
  attention, Mamba-2 SSD; blocked/Pallas-interpret lowering on CPU) —
  per device, with per-kind shape sweeps (``DEFAULT_KERNEL_SHAPES``).
  ``repro.profiling.calibrate_kernels`` loops it over every visible jax
  device, fits a ``LearnedCostModel`` and persists it through the
  ``CalibrationStore`` — the real-hardware calibration loop.

Both produce ``learned.Sample`` rows that ``LearnedCostModel.fit`` consumes.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Mapping, Sequence

import numpy as np

from repro.core.cost_model import Cluster, Node, Processor
from repro.core.dag import Block, ModelDAG

from .learned import Sample


def block_traffic(block: Block) -> float:
    """Bytes a block touches: weights plus in/out activations."""
    return block.param_bytes + block.bytes_in + block.bytes_out


# --------------------------------------------------------------------------
# Ground truth — what the hardware actually does
# --------------------------------------------------------------------------

@dataclasses.dataclass
class SyntheticGroundTruth:
    """True per-processor performance, possibly diverging from the datasheet.

    ``rate_scale`` maps ``(node_name, proc_name)`` (or ``node_name`` for the
    whole node) to a multiplier on the analytic rate: 0.4 means the processor
    sustains 40% of what the cost model believes.  ``power_scale`` does the
    same for active power draw: 1.5 means the processor really burns 1.5× its
    datasheet active watts (DVFS residency, rail losses, a mis-declared TDP)
    — the divergence the energy predictors exist to learn.  ``mem_bw`` and
    ``overhead_s`` add the memory-traffic and fixed-launch terms real
    measurements contain; ``noise`` is the relative jitter σ applied by
    ``sample_seconds`` (deterministic under a caller-provided rng).
    """

    cluster: Cluster
    rate_scale: Mapping[str, float] | Mapping[tuple[str, str], float] = \
        dataclasses.field(default_factory=dict)
    power_scale: Mapping[str, float] | Mapping[tuple[str, str], float] = \
        dataclasses.field(default_factory=dict)
    mem_bw: float = 12e9
    overhead_s: float = 2e-4
    noise: float = 0.0

    def _proc(self, node_name: str, proc_name: str) -> tuple[Node, Processor]:
        for n in self.cluster.nodes:
            if n.name == node_name:
                for p in n.processors:
                    if p.name == proc_name:
                        return n, p
        raise KeyError(f"{node_name}/{proc_name}")

    @staticmethod
    def _scale_from(table: Mapping, node_name: str, proc_name: str) -> float:
        rs = dict(table)
        return rs.get((node_name, proc_name),
                      rs.get(f"{node_name}/{proc_name}",
                             rs.get(node_name, 1.0)))

    def scale(self, node_name: str, proc_name: str) -> float:
        return self._scale_from(self.rate_scale, node_name, proc_name)

    def active_watts(self, node_name: str, proc_name: str) -> float:
        """The active power the hardware actually draws (W)."""
        _, p = self._proc(node_name, proc_name)
        return p.active_power * self._scale_from(self.power_scale,
                                                 node_name, proc_name)

    def rate(self, node_name: str, proc_name: str, kind: str,
             delta: float) -> float:
        """The rate the hardware actually sustains (flops/s at this δ)."""
        _, p = self._proc(node_name, proc_name)
        return p.rate(delta, kind) * self.scale(node_name, proc_name)

    def compute_seconds(self, node_name: str, proc_name: str, flops: float,
                        kind: str, delta: float) -> float:
        """Pure compute time of a shard — what the simulator's EXECUTE
        state charges when this ground truth replaces the datasheet."""
        return flops / max(self.rate(node_name, proc_name, kind, delta),
                           1e-12)

    def block_seconds(self, node_name: str, proc_name: str, block: Block,
                      delta: float) -> float:
        """Noise-free micro-benchmark latency of one block."""
        return (self.compute_seconds(node_name, proc_name, block.flops,
                                     block.kind, delta)
                + block_traffic(block) / self.mem_bw
                + self.overhead_s)

    def sample_seconds(self, node_name: str, proc_name: str, block: Block,
                       delta: float, rng: np.random.Generator) -> float:
        base = self.block_seconds(node_name, proc_name, block, delta)
        if self.noise <= 0:
            return base
        return base * float(np.clip(1.0 + self.noise * rng.standard_normal(),
                                    0.5, 2.0))


# --------------------------------------------------------------------------
# Profiler
# --------------------------------------------------------------------------

# Default shape sweep for the real-kernel path, per kernel kind.  Small by
# design (CI runs these under Pallas-interpret on CPU); a hardware
# deployment passes its own per-device shapes to ``profile_kernels``.
DEFAULT_KERNEL_SHAPES: dict[str, tuple[tuple[int, ...], ...]] = {
    # (B, T, H, D) — prefill flash attention
    "attn": ((1, 64, 4, 32), (1, 128, 4, 32), (2, 128, 4, 32)),
    # (B, S, H, D) — one decode token against an S-long KV cache
    "decode": ((1, 128, 4, 32), (2, 128, 4, 32), (2, 256, 4, 32)),
    # (B, T, NH, HD, N) — Mamba-2 chunked SSD scan
    "ssd": ((1, 64, 4, 32, 16), (1, 128, 4, 32, 16), (2, 128, 4, 32, 16)),
}


@dataclasses.dataclass
class Profiler:
    """Micro-benchmark driver: warmup, repeats, trimmed mean, fixed seed."""

    warmup: int = 2
    repeats: int = 5
    trim: int = 1                # drop the k fastest and k slowest repeats
    seed: int = 0

    def _trimmed_mean(self, xs: Sequence[float]) -> float:
        xs = sorted(xs)
        if len(xs) > 2 * self.trim:
            xs = xs[self.trim:len(xs) - self.trim]
        return float(np.mean(xs))

    def profile_cluster(self, cluster: Cluster,
                        dags: Mapping[str, ModelDAG],
                        deltas: Mapping[str, float],
                        ground_truth: SyntheticGroundTruth | None = None,
                        ) -> list[Sample]:
        """Per-(block × processor) timing/energy samples over every node.

        Deterministic: one seeded generator drives all jitter, and the
        iteration order is fixed (nodes → processors → dags → blocks).
        """
        gt = ground_truth or SyntheticGroundTruth(cluster)
        rng = np.random.default_rng(self.seed)
        samples: list[Sample] = []
        for node in cluster.nodes:
            for proc in node.processors:
                for name, dag in dags.items():
                    delta = deltas[name]
                    for block in dag.blocks:
                        for _ in range(self.warmup):   # cache/DVFS settle
                            gt.sample_seconds(node.name, proc.name, block,
                                              delta, rng)
                        reps = [gt.sample_seconds(node.name, proc.name,
                                                  block, delta, rng)
                                for _ in range(self.repeats)]
                        lat = self._trimmed_mean(reps)
                        samples.append(Sample(
                            key=f"{node.name}/{proc.name}",
                            kind=block.kind,
                            work=block.flops * delta,
                            traffic=block_traffic(block),
                            latency_s=lat,
                            energy_j=lat * gt.active_watts(node.name,
                                                           proc.name)))
        return samples

    # ------------------------------------------------------- real kernels
    def profile_kernels(self, *, kinds: Sequence[str] | None = None,
                        shapes: Mapping[str, Sequence[tuple[int, ...]]]
                        | None = None,
                        block_q: int = 32, block_k: int = 32,
                        device=None, key: str | None = None,
                        telemetry=None) -> list[Sample]:
        """Wall-clock the FULL repro.kernels set on one device: prefill
        flash attention, single-token decode attention against a KV cache,
        and the Mamba-2 chunked SSD scan.

        ``shapes`` maps kernel kind → shape tuples (see
        ``DEFAULT_KERNEL_SHAPES`` for the per-kind layout); ``kinds``
        restricts the sweep.  ``device`` (a ``jax.Device``) places every
        input there before timing — the per-device path a hardware
        deployment loops over — and ``key`` overrides the Sample key
        (default ``host/<backend>`` for the host, ``<platform>:<id>`` for
        an explicit device).  With ``telemetry`` each measured point also
        lands as a ``profile.kernel`` span whose wall_s is the trimmed-mean
        latency.  Same discipline as the synthetic path throughout: warmup
        → repeats → trimmed mean, seeded inputs.
        """
        import jax
        import jax.numpy as jnp

        from repro.kernels import ops
        from repro.telemetry import active as _tel_active

        tel = _tel_active(telemetry)
        if key is None:
            key = (f"host/{jax.default_backend()}" if device is None
                   else f"{device.platform}:{device.id}")
        table = dict(DEFAULT_KERNEL_SHAPES)
        if shapes:
            table.update(shapes)
        sweep = tuple(kinds) if kinds is not None else tuple(table)
        unknown = [k for k in sweep if k not in table]
        if unknown:
            raise KeyError(f"unknown kernel kinds {unknown}; "
                           f"known: {sorted(table)}")
        samples: list[Sample] = []
        rng = jax.random.PRNGKey(self.seed)

        def put(x):
            return jax.device_put(x, device) if device is not None else x

        def bench(fn, *args) -> float:
            args = tuple(put(a) for a in args)
            for _ in range(self.warmup):
                jax.block_until_ready(fn(*args))
            reps = []
            for _ in range(self.repeats):
                t0 = time.perf_counter()
                jax.block_until_ready(fn(*args))
                reps.append(time.perf_counter() - t0)
            return self._trimmed_mean(reps)

        def record(kind: str, shape: tuple[int, ...], flops: float,
                   traffic: float, lat: float) -> None:
            samples.append(Sample(key=key, kind=kind, work=flops,
                                  traffic=traffic, latency_s=lat))
            if tel is not None:
                tel.span("profile.kernel", 0.0, wall_s=lat, kind=kind,
                         key=key, shape="x".join(map(str, shape)),
                         flops=flops)

        for kind in sweep:
            for shape in table[kind]:
                if kind == "attn":
                    b, t, h, d = shape
                    ks = jax.random.split(rng, 3)
                    q = jax.random.normal(ks[0], (b, t, h, d), jnp.float32)
                    k = jax.random.normal(ks[1], (b, t, h, d), jnp.float32)
                    v = jax.random.normal(ks[2], (b, t, h, d), jnp.float32)
                    lat = bench(lambda q, k, v: ops.flash_attention(
                        q, k, v, block_q=block_q, block_k=block_k), q, k, v)
                    flops = 4.0 * b * t * t * h * d       # QK^T + AV
                    traffic = 4.0 * (q.size + k.size + v.size + q.size)
                elif kind == "decode":
                    b, s, h, d = shape
                    ks = jax.random.split(rng, 3)
                    q = jax.random.normal(ks[0], (b, 1, h, d), jnp.float32)
                    kc = jax.random.normal(ks[1], (b, s, h, d), jnp.float32)
                    vc = jax.random.normal(ks[2], (b, s, h, d), jnp.float32)
                    lengths = jnp.full((b,), s, jnp.int32)
                    lat = bench(lambda q, kc, vc, ln: ops.decode_attention(
                        q, kc, vc, ln, block_k=block_k), q, kc, vc, lengths)
                    flops = 4.0 * b * s * h * d           # qK^T + aV
                    traffic = 4.0 * (q.size + kc.size + vc.size + q.size)
                else:                                     # ssd
                    b, t, nh, hd, n = shape
                    ks = jax.random.split(rng, 4)
                    x = jax.random.normal(ks[0], (b, t, nh, hd), jnp.float32)
                    dt = jax.random.uniform(ks[1], (b, t, nh), jnp.float32,
                                            0.001, 0.1)
                    A = -jnp.ones((nh,), jnp.float32)
                    B = jax.random.normal(ks[2], (b, t, n), jnp.float32)
                    C = jax.random.normal(ks[3], (b, t, n), jnp.float32)
                    D = jnp.ones((nh,), jnp.float32)
                    lat = bench(lambda x, dt, B, C: ops.ssd(
                        x, dt, A, B, C, D, chunk=min(64, t)), x, dt, B, C)
                    flops = 6.0 * b * t * nh * hd * n     # in/out proj + scan
                    traffic = 4.0 * (x.size + B.size + C.size + x.size)
                record(kind, shape, flops, traffic, lat)
        return samples
