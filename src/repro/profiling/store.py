"""CalibrationStore — versioned fitted models, keyed by cluster fingerprint.

A calibration is only valid for the hardware it was measured on, so models
are filed under a fingerprint of the cluster's declared topology (node and
processor names, datasheet rates, link bandwidths, affinity tables).  Any
change to the fleet — a board swapped, a link upgraded — changes the
fingerprint and cleanly invalidates old calibrations.  Within a fingerprint,
every ``save`` appends a new monotonically-numbered version; ``load``
returns the latest by default so re-profiling supersedes without deleting
history (the per-request plan cache can key on ``(fingerprint, version)``).
"""

from __future__ import annotations

import json
import pathlib
import time

from repro.core.cost_model import Cluster
from repro.core.fingerprint import cluster_fingerprint

from .learned import LearnedCostModel


class CalibrationStore:
    def __init__(self, root: str | pathlib.Path):
        self.root = pathlib.Path(root)
        self.root.mkdir(parents=True, exist_ok=True)

    # ----------------------------------------------------------- fingerprint
    # Shared with repro.serving.plan_cache.PlanCache so calibration paths and
    # plan-cache keys can never hash the cluster differently.
    fingerprint = staticmethod(cluster_fingerprint)

    def _dir(self, cluster: Cluster) -> pathlib.Path:
        return self.root / self.fingerprint(cluster)

    # ----------------------------------------------------------------- save
    def save(self, cluster: Cluster, model: LearnedCostModel,
             note: str = "") -> int:
        d = self._dir(cluster)
        d.mkdir(parents=True, exist_ok=True)
        version = (self.versions(cluster) or [0])[-1] + 1
        payload = {
            "fingerprint": self.fingerprint(cluster),
            "version": version,
            "note": note,
            "created_unix": time.time(),
            "model": model.to_dict(),
        }
        path = d / f"v{version:04d}.json"
        path.write_text(json.dumps(payload, sort_keys=True, indent=1))
        return version

    # ----------------------------------------------------------------- load
    def versions(self, cluster: Cluster) -> list[int]:
        d = self._dir(cluster)
        if not d.is_dir():
            return []
        return sorted(int(p.stem[1:]) for p in d.glob("v*.json"))

    def load(self, cluster: Cluster,
             version: int | None = None) -> LearnedCostModel:
        versions = self.versions(cluster)
        if not versions:
            raise FileNotFoundError(
                f"no calibration for fingerprint "
                f"{self.fingerprint(cluster)} under {self.root}")
        v = versions[-1] if version is None else version
        path = self._dir(cluster) / f"v{v:04d}.json"
        payload = json.loads(path.read_text())
        return LearnedCostModel.from_dict(payload["model"])
