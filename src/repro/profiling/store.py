"""CalibrationStore — versioned fitted models, keyed by cluster fingerprint.

A calibration is only valid for the hardware it was measured on, so models
are filed under a fingerprint of the cluster's declared topology (node and
processor names, datasheet rates, link bandwidths, affinity tables).  Any
change to the fleet — a board swapped, a link upgraded — changes the
fingerprint and cleanly invalidates old calibrations.  Within a fingerprint,
every ``save`` appends a new monotonically-numbered version; ``load``
returns the latest by default so re-profiling supersedes without deleting
history (the per-request plan cache can key on ``(fingerprint, version)``).

The store also files **warm plan frontiers** next to the calibrations they
were planned under (:meth:`save_fronts` / :meth:`load_fronts`): one
``fronts.json`` per cluster fingerprint, each entry stamped with the
``calibration_version`` it is valid for, the ``dag_fingerprint`` of the
tenant it serves, and the ``membership_fingerprint`` of the availability
mask it was planned over (fronts for distinct memberships persist side by
side, so a node that leaves and returns is served warm across restarts).  ``repro.serving.plan_cache.PlanCache`` persists its warm
table here so a restarted process serves every tenant without re-running
the cold frontier pass; entries whose version no longer matches the live
calibration are dropped on load, so a stale front can never be served.
The store itself treats entries as opaque JSON — encoding/decoding plan
payloads is the cache's job (``repro.core.plan_to_dict`` /
``plan_from_dict``), which keeps profiling free of serving imports.
"""

from __future__ import annotations

import contextlib
import json
import os
import pathlib
import time

from repro.core.cost_model import Cluster
from repro.core.fingerprint import cluster_fingerprint

from .learned import LearnedCostModel


@contextlib.contextmanager
def _advisory_lock(path: pathlib.Path):
    """Best-effort exclusive advisory lock on ``path``'s sidecar
    ``.lock`` file (``fcntl.flock``).  Two cooperating processes — a
    serving fleet sharing one ``fronts.json`` — serialize their writes;
    where ``fcntl`` is unavailable (non-POSIX) or the filesystem refuses
    (some network mounts), the lock degrades to a no-op and the atomic
    ``os.replace`` below still guarantees readers never see a torn file.
    """
    try:
        import fcntl
    except ImportError:                      # pragma: no cover - non-POSIX
        yield
        return
    lock_path = path.with_suffix(path.suffix + ".lock")
    with open(lock_path, "w") as lock:
        try:
            fcntl.flock(lock, fcntl.LOCK_EX)
        except OSError:                      # pragma: no cover - odd mounts
            yield
            return
        try:
            yield
        finally:
            fcntl.flock(lock, fcntl.LOCK_UN)


class CalibrationStore:
    def __init__(self, root: str | pathlib.Path):
        self.root = pathlib.Path(root)
        self.root.mkdir(parents=True, exist_ok=True)

    # ----------------------------------------------------------- fingerprint
    # Shared with repro.serving.plan_cache.PlanCache so calibration paths and
    # plan-cache keys can never hash the cluster differently.
    fingerprint = staticmethod(cluster_fingerprint)

    def _dir(self, cluster: Cluster) -> pathlib.Path:
        return self.root / self.fingerprint(cluster)

    # ----------------------------------------------------------------- save
    def save(self, cluster: Cluster, model: LearnedCostModel,
             note: str = "") -> int:
        d = self._dir(cluster)
        d.mkdir(parents=True, exist_ok=True)
        version = (self.versions(cluster) or [0])[-1] + 1
        payload = {
            "fingerprint": self.fingerprint(cluster),
            "version": version,
            "note": note,
            "created_unix": time.time(),
            "model": model.to_dict(),
        }
        path = d / f"v{version:04d}.json"
        path.write_text(json.dumps(payload, sort_keys=True, indent=1))
        return version

    # ----------------------------------------------------------------- load
    def versions(self, cluster: Cluster) -> list[int]:
        d = self._dir(cluster)
        if not d.is_dir():
            return []
        return sorted(int(p.stem[1:]) for p in d.glob("v*.json"))

    def load(self, cluster: Cluster,
             version: int | None = None) -> LearnedCostModel:
        versions = self.versions(cluster)
        if not versions:
            raise FileNotFoundError(
                f"no calibration for fingerprint "
                f"{self.fingerprint(cluster)} under {self.root}")
        v = versions[-1] if version is None else version
        path = self._dir(cluster) / f"v{v:04d}.json"
        payload = json.loads(path.read_text())
        return LearnedCostModel.from_dict(payload["model"])

    # ------------------------------------------------------- plan frontiers
    def fronts_path(self, cluster: Cluster) -> pathlib.Path:
        """Where warm plan frontiers live for this cluster — right next to
        its ``v*.json`` calibrations."""
        return self._dir(cluster) / "fronts.json"

    def save_fronts(self, cluster: Cluster, entries: list[dict]) -> int:
        """Persist warm plan frontiers for ``cluster``.

        Each entry is an opaque JSON dict the writer (``PlanCache``) built:
        at minimum ``dag_fingerprint``, ``dag_name``, ``delta``,
        ``calibration_version``, and a serialized ``front``.  The write is
        atomic (per-process temp file + ``os.replace``), mirroring the
        cache's in-memory generation swap: a concurrent reader sees either
        the old table or the new one, never a torn file.  Writers
        additionally serialize on a best-effort advisory ``.lock`` file,
        so two serving processes persisting to one shared store never
        interleave (last writer wins whole-file, not field-by-field).
        Returns the entry count.
        """
        d = self._dir(cluster)
        d.mkdir(parents=True, exist_ok=True)
        payload = {
            "fingerprint": self.fingerprint(cluster),
            "created_unix": time.time(),
            "entries": list(entries),
        }
        path = self.fronts_path(cluster)
        tmp = path.with_suffix(f".json.{os.getpid()}.tmp")
        with _advisory_lock(path):
            tmp.write_text(json.dumps(payload, sort_keys=True))
            os.replace(tmp, path)
        return len(entries)

    def load_fronts(self, cluster: Cluster) -> list[dict]:
        """The persisted frontier entries for ``cluster`` (raw dicts), or
        ``[]`` when none were ever saved.  Filtering stale
        ``calibration_version`` entries is the *loader's* contract
        (``PlanCache.warm_from``) — the store returns what is on disk."""
        path = self.fronts_path(cluster)
        if not path.is_file():
            return []
        payload = json.loads(path.read_text())
        if payload.get("fingerprint") != self.fingerprint(cluster):
            return []
        return payload.get("entries", [])
