"""LearnedCostModel — the regression half of the paper's DNN Model Analyzer.

The paper fits random-forest predictors mapping block features to per-block
latency **and energy** on each processor class.  We keep the *role* (measured
samples in, per-(block-kind × processor) predictions out) with two
dependency-free regressors:

* ``linear``   — non-negative least squares over (work, traffic, 1), where
                 ``work`` is δ-weighted FLOPs (device cycles) and ``traffic``
                 is bytes touched (params + activations).  The marginal
                 d latency/d work is the processor's *measured* inverse rate —
                 exactly the quantity the analytic model guesses from
                 datasheets.
* ``isotonic`` — pool-adjacent-violators over work → latency, for processors
                 whose latency curve is monotone but not affine (cache
                 cliffs, DVFS steps).  Predictions interpolate the fitted
                 step curve and extrapolate proportionally.

Energy predictors reuse the same machinery: every ``Sample`` carrying
``energy_j > 0`` contributes to a per-(key × kind) *energy* entry fitted
over (work, traffic, 1) exactly like latency — the marginal d energy/d work
is the processor's measured joules-per-flop, the quantity the analytic
model derives as ``active_power / rate``.  Latency and energy entries
serialize, EWMA-blend, and fall back identically.

Models serialize to/from JSON so a ``CalibrationStore`` can version them per
cluster fingerprint, and support EWMA blending of online observations (the
run-time scheduler feeding measurements back — paper Fig. 4's EXECUTE →
ANALYZE edge).

Keys are resource names: ``"orin_nx/gpu"`` for a processor, ``"orin_nx"``
for a node.  Node-level rates aggregate fitted processor rates, mirroring
Λ_j = Σ_k λ_k (Eq. 2) with measured λ.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Iterable, Mapping, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class Sample:
    """One measured (or micro-benchmarked) block execution."""

    key: str                     # "node/proc" (or "node")
    kind: str                    # block kind: conv/dwconv/dense/attn/...
    work: float                  # δ-weighted FLOPs (device cycles)
    traffic: float               # bytes touched: params + activations
    latency_s: float
    energy_j: float = 0.0


@dataclasses.dataclass
class _Entry:
    a: float                     # seconds per work unit (1/rate)
    b: float                     # seconds per byte of traffic
    c: float                     # fixed per-block overhead (s)
    n: int = 0                   # samples behind the fit
    mape: float = 0.0            # in-sample fit error
    iso_x: tuple[float, ...] = ()
    iso_y: tuple[float, ...] = ()

    def linear(self, work: float, traffic: float) -> float:
        return self.a * work + self.b * traffic + self.c


def _nnls(X: np.ndarray, y: np.ndarray) -> np.ndarray:
    """Tiny non-negative least squares: iteratively drop negative columns.

    Columns are norm-scaled first so the solve is well-conditioned despite
    work ~1e11 vs traffic ~1e6 vs the constant column."""
    norms = np.linalg.norm(X, axis=0)
    norms[norms == 0] = 1.0
    Xs = X / norms
    cols = list(range(X.shape[1]))
    coef = np.zeros(X.shape[1])
    for _ in range(X.shape[1] + 1):
        if not cols:
            break
        sol, *_ = np.linalg.lstsq(Xs[:, cols], y, rcond=None)
        if (sol >= 0).all():
            for ci, s in zip(cols, sol):
                coef[ci] = s
            break
        cols = [ci for ci, s in zip(cols, sol) if s > 0]
    return coef / norms


def _pava(x: np.ndarray, y: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Pool-adjacent-violators: isotonic (non-decreasing) fit of y over x."""
    order = np.argsort(x)
    xs, ys = x[order], y[order].astype(float)
    level_y = list(ys)
    level_w = [1.0] * len(ys)
    level_n = [1] * len(ys)
    i = 0
    while i < len(level_y) - 1:
        if level_y[i] > level_y[i + 1] + 1e-18:
            w = level_w[i] + level_w[i + 1]
            merged = (level_y[i] * level_w[i]
                      + level_y[i + 1] * level_w[i + 1]) / w
            level_y[i] = merged
            level_w[i] = w
            level_n[i] += level_n[i + 1]
            del level_y[i + 1], level_w[i + 1], level_n[i + 1]
            i = max(i - 1, 0)
        else:
            i += 1
    fit = np.concatenate([np.full(n, v) for v, n in zip(level_y, level_n)])
    return xs, fit


class LearnedCostModel:
    """Per-(key × kind) latency *and energy* predictors fitted from
    ProfileSamples.

    ``entries`` maps (key, kind) → latency predictor (seconds);
    ``energy_entries`` maps (key, kind) → energy predictor (joules).  Both
    are :class:`_Entry` instances fitted by the same NNLS/isotonic
    machinery, so everything said about latency fitting, fallback, EWMA
    blending, and serialization holds for energy too.
    """

    def __init__(self, mode: str = "linear"):
        if mode not in ("linear", "isotonic"):
            raise ValueError(mode)
        self.mode = mode
        self.entries: dict[tuple[str, str], _Entry] = {}
        self.energy_entries: dict[tuple[str, str], _Entry] = {}
        # Monotone mutation counter: every (re)fit or online observation
        # bumps it, so planner workspaces (repro.core.dp_cache) keyed on
        # this model can tell cached DP rows went stale.
        self.revision = 0

    # ------------------------------------------------------------------- fit
    @classmethod
    def fit(cls, samples: Iterable[Sample],
            mode: str = "linear") -> "LearnedCostModel":
        """Fit latency predictors for every (key × kind) group, and energy
        predictors for every group whose samples carry ``energy_j``."""
        model = cls(mode=mode)
        groups: dict[tuple[str, str], list[Sample]] = {}
        for s in samples:
            groups.setdefault((s.key, s.kind), []).append(s)
        for (key, kind), group in sorted(groups.items()):
            model.fit_entry(key, kind,
                            [(s.work, s.traffic, s.latency_s) for s in group])
            energy_rows = [(s.work, s.traffic, s.energy_j)
                           for s in group if s.energy_j > 0]
            if energy_rows:
                model.fit_energy_entry(key, kind, energy_rows)
        return model

    def fit_entry(self, key: str, kind: str,
                  rows: Sequence[tuple[float, float, float]]) -> None:
        """(Re)fit one latency predictor from (work, traffic, latency) rows."""
        self.entries[(key, kind)] = self._fit_rows(key, kind, rows)
        self.revision += 1

    def fit_energy_entry(self, key: str, kind: str,
                         rows: Sequence[tuple[float, float, float]]) -> None:
        """(Re)fit one energy predictor from (work, traffic, joules) rows —
        the same regression as latency with joules as the response."""
        self.energy_entries[(key, kind)] = self._fit_rows(key, kind, rows)
        self.revision += 1

    def _fit_rows(self, key: str, kind: str,
                  rows: Sequence[tuple[float, float, float]]) -> _Entry:
        arr = np.asarray(rows, dtype=float)
        if arr.ndim != 2 or arr.shape[0] == 0:
            raise ValueError(f"no samples for ({key}, {kind})")
        work, traffic, lat = arr[:, 0], arr[:, 1], arr[:, 2]
        # Only fit columns the samples can identify: with a single distinct
        # work value (or traffic collinear with work) the full design is
        # rank-deficient and minimum-norm lstsq splits latency arbitrarily
        # across coefficients — biasing the marginal rate 1/a.
        distinct_work = np.unique(work).size
        use_traffic = (np.ptp(traffic)
                       > 1e-9 * (np.mean(np.abs(traffic)) + 1e-12))
        if use_traffic and np.ptp(work) > 0:
            corr = np.corrcoef(work, traffic)[0, 1]
            if abs(corr) > 0.9999:
                use_traffic = False
        if distinct_work < 2:
            coef = np.array([float(np.mean(lat / np.maximum(work, 1e-12))),
                             0.0, 0.0])
        else:
            cols = [work]
            layout = [0]
            if use_traffic:
                cols.append(traffic)
                layout.append(1)
            cols.append(np.ones_like(work))
            layout.append(2)
            sol = _nnls(np.stack(cols, axis=1), lat)
            coef = np.zeros(3)
            coef[layout] = sol
            if coef[0] <= 0:          # degenerate: fall back to mean rate
                coef = np.array([float(np.mean(lat / np.maximum(work, 1e-12))),
                                 0.0, 0.0])
        pred = coef[0] * work + coef[1] * traffic + coef[2]
        mape = float(np.mean(np.abs(pred - lat) / np.maximum(lat, 1e-12)))
        entry = _Entry(a=float(coef[0]), b=float(coef[1]), c=float(coef[2]),
                       n=int(arr.shape[0]), mape=mape)
        if self.mode == "isotonic" and arr.shape[0] >= 2:
            xs, ys = _pava(work, lat)
            entry.iso_x, entry.iso_y = tuple(map(float, xs)), tuple(
                map(float, ys))
        return entry

    # --------------------------------------------------------------- queries
    @staticmethod
    def _lookup(table: dict[tuple[str, str], _Entry], key: str,
                kind: str) -> _Entry | None:
        e = table.get((key, kind))
        if e is None:
            e = table.get((key, "generic"))
        return e

    def _entry_for(self, key: str, kind: str) -> _Entry | None:
        return self._lookup(self.entries, key, kind)

    def entry(self, key: str, kind: str) -> _Entry | None:
        """The fitted latency predictor serving (key, kind), with generic
        fallback."""
        return self._entry_for(key, kind)

    def energy_entry(self, key: str, kind: str) -> _Entry | None:
        """The fitted energy predictor serving (key, kind), with generic
        fallback."""
        return self._lookup(self.energy_entries, key, kind)

    def rate(self, key: str, kind: str = "generic") -> float | None:
        """Measured work-units/s (δ=1 FLOP/s).  Node keys aggregate their
        processors' fitted rates: Λ_j = Σ_k λ_k with measured λ."""
        e = self._entry_for(key, kind)
        if e is not None and e.a > 0:
            return 1.0 / e.a
        prefix = key + "/"
        children = {k for (k, _) in self.entries if k.startswith(prefix)}
        rates = [r for r in (self.rate(c, kind) for c in sorted(children))
                 if r is not None]
        if rates:
            return sum(rates)
        return None

    def predict(self, key: str, kind: str, work: float,
                traffic: float = 0.0) -> float | None:
        """Predicted latency in seconds, or None when uncalibrated."""
        e = self._entry_for(key, kind)
        if e is None:
            r = self.rate(key, kind)      # node-level aggregation
            return None if r is None else work / max(r, 1e-300)
        return self._evaluate(e, work, traffic)

    def _evaluate(self, e: _Entry, work: float, traffic: float) -> float:
        if self.mode == "isotonic" and e.iso_x:
            x, y = e.iso_x, e.iso_y
            if work >= x[-1]:
                return y[-1] * (work / x[-1]) if x[-1] > 0 else y[-1]
            if work <= x[0]:
                return y[0] * (work / x[0]) if x[0] > 0 else y[0]
            return float(np.interp(work, x, y))
        return e.linear(work, traffic)

    def predict_energy(self, key: str, kind: str, work: float,
                       traffic: float = 0.0) -> float | None:
        """Predicted active energy in joules, or None when uncalibrated.

        Node keys aggregate their processors: the work splits across the
        fitted children in proportion to their measured rates (the share
        each realises under Λ_j = Σ_k λ_k) and each share is priced by the
        child's energy predictor."""
        e = self.energy_entry(key, kind)
        if e is not None:
            return self._evaluate(e, work, traffic)
        prefix = key + "/"
        children = sorted({k for (k, _) in self.energy_entries
                           if k.startswith(prefix)})
        shares = [(c, self.rate(c, kind)) for c in children]
        shares = [(c, r) for c, r in shares if r is not None]
        total = sum(r for _, r in shares)
        if not shares or total <= 0:
            return None
        joules = 0.0
        for c, r in shares:
            p = self.predict_energy(c, kind, work * r / total,
                                    traffic * r / total)
            if p is None:
                return None
            joules += p
        return joules

    # ------------------------------------------------------ online blending
    def observe(self, key: str, kind: str, work: float, traffic: float,
                latency_s: float, alpha: float = 0.3) -> None:
        """EWMA-blend one measured execution into the fitted rate."""
        if work <= 0 or latency_s <= 0:
            return
        self.revision += 1
        e = self.entries.get((key, kind))
        if e is None:
            self.entries[(key, kind)] = _Entry(
                a=latency_s / work, b=0.0, c=0.0, n=1)
            return
        resid = max(latency_s - e.b * traffic - e.c, 1e-12)
        implied_a = resid / work
        e.a = (1.0 - alpha) * e.a + alpha * implied_a
        e.n += 1
        if e.iso_x:
            # keep the isotonic curve consistent with the blended rate by
            # scaling it toward the observation
            scale = implied_a / max(e.a, 1e-300)
            blend = (1.0 - alpha) + alpha * scale
            e.iso_y = tuple(v * blend for v in e.iso_y)

    def observe_energy(self, key: str, kind: str, work: float, traffic: float,
                       energy_j: float, alpha: float = 0.3) -> None:
        """EWMA-blend one measured execution's joules into the fitted
        marginal energy — the energy twin of :meth:`observe`."""
        if work <= 0 or energy_j <= 0:
            return
        self.revision += 1
        e = self.energy_entries.get((key, kind))
        if e is None:
            self.energy_entries[(key, kind)] = _Entry(
                a=energy_j / work, b=0.0, c=0.0, n=1)
            return
        resid = max(energy_j - e.b * traffic - e.c, 1e-12)
        implied_a = resid / work
        e.a = (1.0 - alpha) * e.a + alpha * implied_a
        e.n += 1
        if e.iso_x:
            scale = implied_a / max(e.a, 1e-300)
            blend = (1.0 - alpha) + alpha * scale
            e.iso_y = tuple(v * blend for v in e.iso_y)

    # --------------------------------------------------------- serialization
    def to_json(self) -> str:
        return json.dumps(self.to_dict(), sort_keys=True)

    def to_dict(self) -> dict:
        def table(entries: dict[tuple[str, str], _Entry]) -> dict:
            return {f"{key}|{kind}": dataclasses.asdict(e)
                    for (key, kind), e in sorted(entries.items())}
        return {
            "mode": self.mode,
            "entries": table(self.entries),
            "energy_entries": table(self.energy_entries),
        }

    @classmethod
    def from_dict(cls, d: Mapping) -> "LearnedCostModel":
        model = cls(mode=d.get("mode", "linear"))

        def load(table: Mapping, into: dict) -> None:
            for joint, ed in table.items():
                key, _, kind = joint.rpartition("|")
                into[(key, kind)] = _Entry(
                    a=ed["a"], b=ed["b"], c=ed["c"], n=ed.get("n", 0),
                    mape=ed.get("mape", 0.0),
                    iso_x=tuple(ed.get("iso_x", ())),
                    iso_y=tuple(ed.get("iso_y", ())))
        load(d.get("entries", {}), model.entries)
        load(d.get("energy_entries", {}), model.energy_entries)
        return model

    @classmethod
    def from_json(cls, text: str) -> "LearnedCostModel":
        return cls.from_dict(json.loads(text))

    # ------------------------------------------------------------ diagnostics
    def mape_against(self, samples: Iterable[Sample]) -> float:
        """Mean absolute percentage latency error of this model over samples."""
        errs = []
        for s in samples:
            p = self.predict(s.key, s.kind, s.work, s.traffic)
            if p is not None:
                errs.append(abs(p - s.latency_s) / max(s.latency_s, 1e-12))
        return float(np.mean(errs)) if errs else float("nan")

    def energy_mape_against(self, samples: Iterable[Sample]) -> float:
        """Mean absolute percentage energy error over samples carrying
        ``energy_j``."""
        errs = []
        for s in samples:
            if s.energy_j <= 0:
                continue
            p = self.predict_energy(s.key, s.kind, s.work, s.traffic)
            if p is not None:
                errs.append(abs(p - s.energy_j) / max(s.energy_j, 1e-12))
        return float(np.mean(errs)) if errs else float("nan")
