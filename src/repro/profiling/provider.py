"""CalibratedCostProvider — measured cost predictions behind the planner's
``CostProvider`` protocol.

Drop-in for the analytic provider everywhere the DP partitioners price
compute *and energy*: segment costs come from per-block regressor
predictions (prefix summed, so the DP's inner loop stays O(1)); scalar
compute/rate/energy queries come from fitted marginals.  Communication stays
analytic — link bandwidths are declared, not discovered, in this
reproduction.

Any (resource × kind) the model has never seen falls back to the analytic
provider, so a partially-calibrated cluster still plans everywhere.

``delta`` handling: the model is fitted in work units (δ-weighted FLOPs),
making it model-agnostic; ``at_delta`` rebinds the provider to the
requesting model's compute intensity.  ``HiDPPlanner`` and the baseline
strategies call it automatically.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from repro.core.cost_model import (ANALYTIC, CostProvider, Resource)
from repro.core.dag import ModelDAG

from .learned import LearnedCostModel
from .profiler import block_traffic


@dataclasses.dataclass(frozen=True)
class CalibratedCostProvider:
    model: LearnedCostModel
    fallback: CostProvider = ANALYTIC
    delta: float = 1.0

    def at_delta(self, delta: float) -> "CalibratedCostProvider":
        return dataclasses.replace(self, delta=delta)

    # ------------------------------------------------------------- protocol
    @staticmethod
    def _key(resource: Resource) -> str:
        return getattr(resource, "profile_key", "") or resource.name

    def compute_time(self, flops: float, resource: Resource,
                     kind: str = "generic") -> float:
        rate = self.model.rate(self._key(resource), kind)
        if rate is None:
            return self.fallback.compute_time(flops, resource, kind)
        return flops * self.delta / max(rate, 1e-300)

    def comm_time(self, nbytes: float, resource: Resource,
                  rtt: float | None = None) -> float:
        return self.fallback.comm_time(nbytes, resource, rtt)

    def effective_rate(self, resource: Resource,
                       kind: str = "generic") -> float:
        """Measured flops/s at the bound δ (for heterogeneity ordering)."""
        rate = self.model.rate(self._key(resource), kind)
        if rate is None:
            return self.fallback.effective_rate(resource, kind)
        return rate / max(self.delta, 1e-300)

    def block_time(self, resource: Resource, block) -> float:
        p = self.model.predict(self._key(resource), block.kind,
                               block.flops * self.delta,
                               block_traffic(block))
        if p is None:
            return self.fallback.compute_time(block.flops, resource,
                                              block.kind)
        return p

    def segment_coster(self, dag: ModelDAG, resource: Resource
                       ) -> Callable[[int, int], float]:
        """Prefix sums of per-block predictions → O(1) segment costs."""
        pre = [0.0]
        for b in dag.blocks:
            pre.append(pre[-1] + self.block_time(resource, b))

        def cost(a: int, b: int) -> float:
            return pre[b] - pre[a]

        return cost

    # ------------------------------------------------- vectorized fast path
    # Matrix/array views of the closures above for the fast DP engine —
    # elementwise bit-identical (``pre[b] - pre[a]`` is the same float64
    # subtraction whether done by the closure or by numpy broadcasting).

    def segment_cost_matrix(self, dag: ModelDAG,
                            resource: Resource) -> np.ndarray:
        pre = [0.0]
        for b in dag.blocks:
            pre.append(pre[-1] + self.block_time(resource, b))
        p = np.asarray(pre, dtype=np.float64)
        return p[None, :] - p[:, None]

    def segment_energy_matrix(self, dag: ModelDAG,
                              resource: Resource) -> np.ndarray:
        pre = [0.0]
        for b in dag.blocks:
            pre.append(pre[-1] + self.block_energy(resource, b))
        p = np.asarray(pre, dtype=np.float64)
        return p[None, :] - p[:, None]

    def comm_time_array(self, nbytes, resource: Resource,
                        rtt: float | None = None):
        """Vectorized only when the fallback is (None → the caller loops)."""
        fn = getattr(self.fallback, "comm_time_array", None)
        return None if fn is None else fn(nbytes, resource, rtt)

    def comm_energy_array(self, nbytes, resource: Resource,
                          rtt: float | None = None):
        fn = getattr(self.fallback, "comm_energy_array", None)
        return None if fn is None else fn(nbytes, resource, rtt)

    # ------------------------------------------------------------- energy
    # Fitted energy predictors answer first; a (resource × kind) without one
    # degrades gracefully to datasheet power × *calibrated* seconds (better
    # than fully-analytic: the time half is still measured), and a fully
    # unknown resource bottoms out at the analytic provider.

    def energy(self, flops: float, nbytes: float, resource: Resource,
               kind: str = "generic") -> float:
        return (self.compute_energy(flops, resource, kind)
                + self.comm_energy(nbytes, resource))

    def compute_energy(self, flops: float, resource: Resource,
                       kind: str = "generic") -> float:
        p = self.model.predict_energy(self._key(resource), kind,
                                      flops * self.delta)
        if p is None:
            return resource.active_power * self.compute_time(flops, resource,
                                                             kind)
        return p

    def comm_energy(self, nbytes: float, resource: Resource,
                    rtt: float | None = None) -> float:
        """Link energy stays analytic, like the comm latencies it prices."""
        return self.fallback.comm_energy(nbytes, resource, rtt)

    def block_energy(self, resource: Resource, block) -> float:
        p = self.model.predict_energy(self._key(resource), block.kind,
                                      block.flops * self.delta,
                                      block_traffic(block))
        if p is None:
            return resource.active_power * self.block_time(resource, block)
        return p

    def segment_energy_coster(self, dag: ModelDAG, resource: Resource
                              ) -> Callable[[int, int], float]:
        """Prefix sums of per-block energy predictions → O(1) segment J."""
        pre = [0.0]
        for b in dag.blocks:
            pre.append(pre[-1] + self.block_energy(resource, b))

        def cost(a: int, b: int) -> float:
            return pre[b] - pre[a]

        return cost

    def data_coeffs(self, dag: ModelDAG, resource: Resource
                    ) -> tuple[float, float]:
        """Price a proportional data slice consistently with the per-block
        segment costs: a fraction f of the DAG costs f·linear + fixed, where
        the fixed part carries the fitted per-block overheads (c) and the
        weight-traffic term (params do not shrink with f).  Without this,
        data partitioning would be systematically under-priced relative to
        model partitioning under calibration."""
        key = self._key(resource)
        linear = fixed = 0.0
        for b in dag.blocks:
            e = self.model.entry(key, b.kind)
            if e is not None and e.a > 0:
                linear += e.a * (b.flops * self.delta) + e.b * (
                    b.bytes_in + b.bytes_out)
                fixed += e.c + e.b * b.param_bytes
                continue
            rate = self.model.rate(key, b.kind)
            if rate is not None:
                linear += b.flops * self.delta / max(rate, 1e-300)
            else:
                linear += self.fallback.compute_time(b.flops, resource,
                                                     b.kind)
        return linear, fixed
