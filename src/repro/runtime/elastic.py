"""Elastic scaling: re-plan and re-mesh when availability changes.

When a pod (or chip group) joins/leaves, the HiDP planner re-runs with the
new availability vector — the same Ψ/A machinery as the paper's leader node
probing the cluster (Alg. 1 line 3) — producing a new ShardingPlan for the
surviving mesh.  Parameters are resharded by round-tripping through the new
NamedShardings (jax handles device-to-device movement); training resumes
from the last checkpoint when the mesh change invalidates live buffers.

:meth:`ElasticController.on_epoch` closes the loop with the fleet layer:
wire it as (or from) a ``repro.fleet.FleetController``'s ``on_epoch``
callback and every membership epoch — a simulated edge node leaving, or a
real pod dropping out — resizes the elastic world to the epoch's
available-node count.  With a ``telemetry=`` recorder the controller emits
an ``elastic.world`` gauge per epoch and an ``elastic.replan`` counter per
actual re-plan (see docs/observability.md).
"""

from __future__ import annotations

import dataclasses

from repro.models.config import ShapeConfig
from repro.models.model import Model
from repro.sharding.plan import MeshDesc, ShardingPlan, plan_tpu


@dataclasses.dataclass
class ElasticController:
    model: Model
    shape: ShapeConfig
    base_mesh: MeshDesc
    current_plan: ShardingPlan | None = None
    replans: int = 0
    telemetry: object = None

    def __post_init__(self):
        from repro.telemetry import active as _tel_active
        self.telemetry = _tel_active(self.telemetry)

    def initial_plan(self) -> ShardingPlan:
        self.current_plan = plan_tpu(self.model, self.shape, self.base_mesh)
        return self.current_plan

    def shrunk_mesh(self, available_pods: int, *,
                    data_scale: float = 1.0) -> MeshDesc:
        """Mesh for a reduced fleet.  Pods leave whole (the DCN failure
        domain); intra-pod shrink rescales the data axis."""
        axes, shape = list(self.base_mesh.axes), list(self.base_mesh.shape)
        if "pod" in axes:
            shape[axes.index("pod")] = max(available_pods, 1)
            if available_pods <= 1:
                i = axes.index("pod")
                del axes[i], shape[i]
        if data_scale != 1.0 and "data" in axes:
            i = axes.index("data")
            shape[i] = max(int(shape[i] * data_scale), 1)
        return MeshDesc(tuple(axes), tuple(shape))

    def on_availability_change(self, available_pods: int) -> ShardingPlan:
        """Re-enter EXPLORE with the new A(N_φ): fresh plan for the
        surviving mesh.  A no-op (same plan object) when nothing changed."""
        mesh = self.shrunk_mesh(available_pods)
        if (self.current_plan is not None
                and mesh == self.current_plan.mesh):
            return self.current_plan
        self.replans += 1
        if self.telemetry is not None:
            self.telemetry.counter("elastic.replan", reason="availability",
                                   pods=available_pods,
                                   mesh="x".join(map(str, mesh.shape)))
        self.current_plan = plan_tpu(self.model, self.shape, mesh)
        return self.current_plan

    def on_epoch(self, epoch) -> ShardingPlan:
        """Membership-epoch adapter: wire this as (or from) a
        ``repro.fleet.FleetController.on_epoch`` callback.  The epoch's
        available-node count becomes the elastic world size — a departed
        node shrinks the mesh, a returned one grows it back — and the
        transition lands as an ``elastic.world`` gauge."""
        world = int(epoch.available())
        if self.telemetry is not None:
            self.telemetry.gauge(
                "elastic.world", float(world),
                t=getattr(epoch, "time", None) or 0.0,
                epoch=getattr(epoch, "epoch", None),
                fingerprint=getattr(epoch, "fingerprint", "")[:12])
        return self.on_availability_change(world)

    def on_drift(self) -> ShardingPlan:
        """Re-enter EXPLORE because the cost model drifted, not because the
        fleet changed: the mesh stays, the plan is recomputed.  This is the
        hook a ``repro.profiling.FeedbackLoop`` fires when predicted and
        measured shard latencies diverge past its threshold."""
        mesh = (self.current_plan.mesh if self.current_plan is not None
                else self.base_mesh)
        self.replans += 1
        if self.telemetry is not None:
            self.telemetry.counter("elastic.replan", reason="drift",
                                   mesh="x".join(map(str, mesh.shape)))
        self.current_plan = plan_tpu(self.model, self.shape, mesh)
        return self.current_plan
