"""Fault tolerance for long training runs: heartbeat-tracked availability
(the paper's A(N_φ), Eq. 4), periodic atomic checkpoints with resume, and
straggler mitigation.

The signals are injected (simulated clocks / per-step timings) so the policy
layer is fully testable without hardware; the launcher wires the same
interfaces to real step timings.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import numpy as np

from repro.core.cluster import ClusterManager, HeartbeatMonitor
from repro.training import checkpoint as ckpt


@dataclasses.dataclass
class CheckpointPolicy:
    directory: str
    every_steps: int = 50
    keep: int = 3

    def maybe_save(self, step: int, tree: Any) -> str | None:
        if step % self.every_steps:
            return None
        path = ckpt.step_path(self.directory, step)
        ckpt.save(path, tree, step)
        self._gc()
        return path

    def _gc(self) -> None:
        import os
        files = sorted(f for f in os.listdir(self.directory)
                       if f.startswith("ckpt_"))
        for f in files[:-self.keep]:
            os.remove(os.path.join(self.directory, f))

    def resume(self, like: Any) -> tuple[Any, int] | None:
        path = ckpt.latest(self.directory)
        if path is None:
            return None
        return ckpt.restore(path, like)


@dataclasses.dataclass
class StragglerPolicy:
    """Flag pods whose step time exceeds slack × p95 of the fleet.

    The mitigation (paper-faithful): the leader re-plans with the straggler's
    α_j = 0 — its share is redistributed by the same DP that placed it
    (runtime/elastic.py) — and restores it when it recovers."""

    slack: float = 1.5
    window: int = 20
    history: dict[str, list[float]] = dataclasses.field(default_factory=dict)

    def record(self, pod: str, step_seconds: float) -> None:
        h = self.history.setdefault(pod, [])
        h.append(step_seconds)
        del h[:-self.window]

    def stragglers(self) -> list[str]:
        if len(self.history) < 2:
            return []
        med = {p: float(np.median(h)) for p, h in self.history.items()
               if h}
        # fleet reference = median-of-medians (robust to the straggler
        # itself inflating a percentile reference)
        fleet = float(np.median(list(med.values())))
        return [p for p, m in med.items() if m > self.slack * fleet]


@dataclasses.dataclass
class FaultTolerantRunner:
    """Drives a train loop with checkpoint/restart + availability tracking.

    ``step_fn(state, batch) -> (state, metrics)`` is opaque; failures are
    signalled by exceptions from step_fn or by heartbeat loss, after which the
    runner restores the last checkpoint and continues (optionally on a
    re-planned, smaller cluster — see elastic.py)."""

    step_fn: Callable
    ckpt_policy: CheckpointPolicy
    manager: ClusterManager | None = None
    straggler: StragglerPolicy = dataclasses.field(
        default_factory=StragglerPolicy)
    restarts: int = 0

    def run(self, state: Any, batches, *, start_step: int = 0,
            max_failures: int = 3) -> tuple[Any, int, list[dict]]:
        metrics_log: list[dict] = []
        step = start_step
        resumed = self.ckpt_policy.resume(state)
        if resumed is not None:
            state, step = resumed
        failures = 0
        it = iter(batches)
        while True:
            try:
                batch = next(it)
            except StopIteration:
                break
            try:
                state, metrics = self.step_fn(state, batch)
            except Exception:
                failures += 1
                self.restarts += 1
                if failures > max_failures:
                    raise
                restored = self.ckpt_policy.resume(state)
                if restored is not None:
                    state, step = restored
                continue
            step += 1
            metrics["step"] = step
            metrics_log.append(metrics)
            self.ckpt_policy.maybe_save(step, state)
        return state, step, metrics_log
