"""Tier-2 (local) partitioner — Algorithm 1 lines 8-10.

Each node re-partitions its assigned sub-workload across its own processors
ρ_k using the *same* DP search, now driven by the local ratio vector
ψ = {λ_k/μ_k}.  This is the tier that the SoA strategies lack (Table I) and
the source of the "P1 is never optimal" observation of Fig. 1: on a Jetson,
running a whole block on the GPU alone loses to a tuned CPU+GPU split.

Block-kind affinity makes the split heterogeneity-aware: λ_k is modulated per
block kind (conv/attn/moe/ssm/...), the paper's "CPU-friendly layers" effect.
In the TPU guise, processors are sharding lanes and affinity encodes
per-(block-kind × axis) sharding efficiency (DESIGN.md §2).
"""

from __future__ import annotations

import dataclasses

from .cost_model import Node, Resource, processors_as_resources
from .dag import DataPartition, ModelDAG, ModelPartition, Partition
from . import dp_partitioner


@dataclasses.dataclass(frozen=True)
class LocalPlan:
    node_name: str
    mode: str                        # "model" | "data"
    partition: Partition
    predicted_latency: float
    predicted_energy: float


def dominant_kind(dag: ModelDAG) -> str:
    """The block kind carrying the most FLOPs — used to pick the affinity row
    when collapsing a sub-workload to a single scalar rate."""
    flops_by_kind: dict[str, float] = {}
    for b in dag.blocks:
        flops_by_kind[b.kind] = flops_by_kind.get(b.kind, 0.0) + b.flops
    return max(flops_by_kind, key=flops_by_kind.get) if flops_by_kind else "generic"


def plan_local(sub_dag: ModelDAG, node: Node, *, delta: float = 1.0) -> LocalPlan:
    kind = dominant_kind(sub_dag)
    resources = processors_as_resources(node, delta, kind)
    plan = dp_partitioner.partition(sub_dag, resources)
    energy = dp_partitioner.predicted_energy(sub_dag, resources, plan)
    mode = "model" if isinstance(plan, ModelPartition) else "data"
    return LocalPlan(node_name=node.name, mode=mode, partition=plan,
                     predicted_latency=plan.predicted_latency,
                     predicted_energy=energy)


def p1_plan(sub_dag: ModelDAG, node: Node, *, delta: float = 1.0,
            processor_kind: str | None = None) -> LocalPlan:
    """The SoA default (Fig. 1 config "P1"): run the whole block on a single
    processor — the framework-default device — with no local partitioning.
    Used by the MoDNN/OmniBoost/DisNet baselines and the Fig. 1 benchmark."""
    resources = processors_as_resources(node, delta, dominant_kind(sub_dag))
    # Prefer the requested processor kind; fall back to the fastest.
    if processor_kind is None:
        processor_kind = node.default_processor
    idx = next((i for i, p in enumerate(node.processors)
                if p.kind == processor_kind), None)
    if idx is None:
        idx = max(range(len(resources)), key=lambda i: resources[i].rate)
    r = resources[idx]
    lat = r.time_for(sub_dag.total_flops, sub_dag.input_bytes
                     + sub_dag.output_bytes)
    plan = DataPartition(fractions=(1.0,), assignment=(idx,),
                         predicted_latency=lat)
    energy = dp_partitioner.predicted_energy(sub_dag, resources, plan)
    return LocalPlan(node_name=node.name, mode="data", partition=plan,
                     predicted_latency=lat, predicted_energy=energy)
