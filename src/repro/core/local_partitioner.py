"""Tier-2 (local) partitioner — Algorithm 1 lines 8-10.

Each node re-partitions its assigned sub-workload across its own processors
ρ_k using the *same* DP search, now driven by the local ratio vector
ψ = {λ_k/μ_k}.  This is the tier that the SoA strategies lack (Table I) and
the source of the "P1 is never optimal" observation of Fig. 1: on a Jetson,
running a whole block on the GPU alone loses to a tuned CPU+GPU split.

Block-kind affinity makes the split heterogeneity-aware: λ_k is modulated per
block kind (conv/attn/moe/ssm/...), the paper's "CPU-friendly layers" effect.
In the TPU guise, processors are sharding lanes and affinity encodes
per-(block-kind × axis) sharding efficiency (DESIGN.md §2).
"""

from __future__ import annotations

import dataclasses

from .cost_model import (CostProvider, Node, Resource, resolve_provider,
                         processors_as_resources)
from .dag import DataPartition, ModelDAG, ModelPartition, Partition
from .dp_cache import workspace_for
from .fingerprint import dag_fingerprint
from .objective import Objective, resolve_objective
from .pareto import ParetoFront, ParetoPoint
from . import dp_partitioner


@dataclasses.dataclass(frozen=True)
class LocalPlan:
    node_name: str
    mode: str                        # "model" | "data"
    partition: Partition
    predicted_latency: float
    predicted_energy: float


def dominant_kind(dag: ModelDAG) -> str:
    """The block kind carrying the most FLOPs — used to pick the affinity row
    when collapsing a sub-workload to a single scalar rate."""
    return dag.dominant_kind()


def plan_local(sub_dag: ModelDAG, node: Node, *, delta: float = 1.0,
               provider: CostProvider | None = None,
               objective: Objective | None = None) -> LocalPlan:
    """Tier-2 planning pass: re-partition ``sub_dag`` over the node's own
    processors with the same DP, minimizing ``objective.local()`` — the same
    metric as the global tier, unconstrained and without the radio term
    (intra-node links are DRAM copies, not wireless)."""
    kind = dominant_kind(sub_dag)
    resources = processors_as_resources(node, delta, kind)
    obj = resolve_objective(objective).local()
    plan = dp_partitioner.partition(sub_dag, resources, provider=provider,
                                    objective=obj)
    energy = dp_partitioner.predicted_energy(sub_dag, resources, plan,
                                             provider)
    mode = "model" if isinstance(plan, ModelPartition) else "data"
    return LocalPlan(node_name=node.name, mode=mode, partition=plan,
                     predicted_latency=plan.predicted_latency,
                     predicted_energy=energy)


def plan_local_front(sub_dag: ModelDAG, node: Node, *, delta: float = 1.0,
                     provider: CostProvider | None = None,
                     width: int | None = None) -> ParetoFront:
    """Tier-2 frontier: the node's own latency–energy trade-offs for
    ``sub_dag`` over its processors.  No radio term — intra-node transfers
    are DRAM copies, not wireless.  The front's ``latency_optimal`` plan is
    exactly :func:`plan_local`'s answer under the default objective."""
    prov = resolve_provider(provider)
    ws = (workspace_for(prov)
          if dp_partitioner.get_engine() == "fast" else None)
    if ws is not None:
        # Node is a frozen dataclass, so the hierarchical hot path can memo
        # the *wrapped* front per (sub-workload, node, δ) — a warm pass skips
        # even the LocalPlan re-wrapping, not just the DP underneath.
        rkey = ("plf", dag_fingerprint(sub_dag), node, delta, width)
        memo = ws.results.get(rkey)
        if memo is not None:
            return memo
    kind = dominant_kind(sub_dag)
    resources = processors_as_resources(node, delta, kind)
    pf = dp_partitioner.partition_front(sub_dag, resources, provider=prov,
                                        width=width)
    points = []
    for p in pf:
        mode = "model" if isinstance(p.plan, ModelPartition) else "data"
        points.append(ParetoPoint(p.latency, p.energy, LocalPlan(
            node_name=node.name, mode=mode, partition=p.plan,
            predicted_latency=p.latency, predicted_energy=p.energy)))
    front = ParetoFront(points)
    if ws is not None:
        ws.results.put(rkey, front)
    return front


def p1_plan(sub_dag: ModelDAG, node: Node, *, delta: float = 1.0,
            processor_kind: str | None = None,
            provider: CostProvider | None = None) -> LocalPlan:
    """The SoA default (Fig. 1 config "P1"): run the whole block on a single
    processor — the framework-default device — with no local partitioning.
    Used by the MoDNN/OmniBoost/DisNet baselines and the Fig. 1 benchmark."""
    prov = resolve_provider(provider)
    kind = dominant_kind(sub_dag)
    resources = processors_as_resources(node, delta, kind)
    # Prefer the requested processor kind; fall back to the fastest.
    if processor_kind is None:
        processor_kind = node.default_processor
    idx = next((i for i, p in enumerate(node.processors)
                if p.kind == processor_kind), None)
    if idx is None:
        idx = max(range(len(resources)),
                  key=lambda i: prov.effective_rate(resources[i], kind))
    r = resources[idx]
    # per-block segment pricing (identical to total-FLOPs ÷ rate for the
    # analytic provider; carries fitted per-block overheads when calibrated)
    lat = (prov.segment_coster(sub_dag, r)(0, len(sub_dag.blocks))
           + prov.comm_time(sub_dag.input_bytes + sub_dag.output_bytes, r))
    plan = DataPartition(fractions=(1.0,), assignment=(idx,),
                         predicted_latency=lat)
    energy = dp_partitioner.predicted_energy(sub_dag, resources, plan, prov)
    return LocalPlan(node_name=node.name, mode="data", partition=plan,
                     predicted_latency=lat, predicted_energy=energy)
