"""Event-driven edge-cluster simulator — the faithful-reproduction testbed.

Replays the paper's experiments (Figs. 1, 5, 6, 7, 8) for any Strategy over
the Table II cluster.  The simulator owns time: processors and the shared
wireless medium are capacity-1 resources with busy-until reservations;
requests are planned on arrival (greedy list scheduling, like the paper's
run-time scheduler servicing a queue) and their shards reserve resources in
dependency order.

The wireless medium is shared and half-duplex (all transfers serialize at
80 MB/s), which is what makes fine-grained data partitioning of large inputs
expensive — one of the trade-offs HiDP's DP weighs.
"""

from __future__ import annotations

import contextlib
import dataclasses
from typing import Iterable, Sequence

from .baselines import STRATEGIES, Strategy
from .cost_model import Cluster, CostProvider, Node, comm_time, \
    compute_time, processors_as_resources
from .dag import DataPartition, ModelDAG, ModelPartition
from .hidp import HiDPPlan, sub_dag_for
from .local_partitioner import LocalPlan, dominant_kind
from .objective import Objective


@dataclasses.dataclass
class SimRequest:
    request_id: int
    dag: ModelDAG
    arrival: float
    delta: float = 1.0
    # Per-request planning objective; None inherits the simulator's default.
    objective: Objective | None = None
    # Latency SLO in seconds (None = unconstrained); violations are counted
    # per request in the report, which is how churn-induced retries show up
    # as a serving-quality figure and not just extra latency.
    slo: float | None = None


@dataclasses.dataclass
class ExecutionSpan:
    node: str
    processor: str
    start: float
    end: float
    flops: float
    watts: float
    request_id: int


@dataclasses.dataclass
class RequestRecord:
    request_id: int
    dag_name: str
    arrival: float
    completion: float
    active_energy: float
    mode: str
    # The plan's own predictions, kept so reports can hold the planner to
    # account against what the (possibly diverging) hardware actually did.
    predicted_latency: float = 0.0
    predicted_energy: float = 0.0
    # Churn accounting: how many times a mid-request node failure forced a
    # full re-plan-and-retry, and how many planned shards sat on nodes that
    # had to be abandoned (the work that migrated to survivors).
    retries: int = 0
    migrations: int = 0
    slo: float | None = None

    @property
    def latency(self) -> float:
        return self.completion - self.arrival

    @property
    def slo_violated(self) -> bool:
        return self.slo is not None and self.latency > self.slo


@dataclasses.dataclass
class SimReport:
    records: list[RequestRecord]
    spans: list[ExecutionSpan]
    cluster: Cluster

    # ------------------------------------------------------------- aggregates
    def latencies(self) -> dict[str, float]:
        out: dict[str, list[float]] = {}
        for r in self.records:
            out.setdefault(r.dag_name, []).append(r.latency)
        return {k: sum(v) / len(v) for k, v in out.items()}

    def energies(self) -> dict[str, float]:
        """Per-request energy: active shard energy + cluster idle power over
        the request's latency window (the paper's whole-cluster metering)."""
        idle_w = self._idle_watts()
        out: dict[str, list[float]] = {}
        for r in self.records:
            e = r.active_energy + idle_w * r.latency
            out.setdefault(r.dag_name, []).append(e)
        return {k: sum(v) / len(v) for k, v in out.items()}

    def _idle_watts(self) -> float:
        return sum(p.idle_power for n in self.cluster.nodes
                   for p in n.processors)

    def predicted_energies(self) -> dict[str, float]:
        """Planner-predicted per-request energy, normalized like
        :meth:`energies` (plan energy + cluster idle over the predicted
        latency window) so the two are directly comparable.  Empty dict
        for a run with zero completed requests (aggressive churn traces
        can drain a workload to nothing)."""
        if not self.records:
            return {}
        idle_w = self._idle_watts()
        out: dict[str, list[float]] = {}
        for r in self.records:
            e = r.predicted_energy + idle_w * r.predicted_latency
            out.setdefault(r.dag_name, []).append(e)
        return {k: sum(v) / len(v) for k, v in out.items()}

    def prediction_error(self) -> dict[str, float]:
        """Mean relative |predicted − measured| for latency and energy,
        across all requests — the ground-truth scoreboard a FeedbackLoop's
        drift detection acts on.  Approximate by construction (the plan's
        energy counts participating-node idle inside its own window; the
        measured side meters the whole cluster) but near zero whenever
        execution matches the cost model, and large when the hardware
        diverges.  Empty dict — never a raise, never a fake 0-error
        claim — when the run completed zero requests."""
        if not self.records:
            return {}
        idle_w = self._idle_watts()
        lat_errs, en_errs = [], []
        for r in self.records:
            if r.predicted_latency > 0:
                lat_errs.append(abs(r.predicted_latency - r.latency)
                                / max(r.latency, 1e-12))
            measured = r.active_energy + idle_w * r.latency
            predicted = r.predicted_energy + idle_w * r.predicted_latency
            if predicted > 0:
                en_errs.append(abs(predicted - measured)
                               / max(measured, 1e-12))
        mean = lambda xs: sum(xs) / len(xs) if xs else 0.0  # noqa: E731
        return {"latency": mean(lat_errs), "energy": mean(en_errs)}

    # ---------------------------------------------------- churn accounting
    def total_retries(self) -> int:
        """Mid-request failures retried to completion across all requests."""
        return sum(r.retries for r in self.records)

    def total_migrations(self) -> int:
        """Planned shards abandoned on a failed node and re-planned onto
        survivors."""
        return sum(r.migrations for r in self.records)

    def slo_violations(self) -> int:
        """Requests that finished past their declared SLO (requests with
        no SLO never count)."""
        return sum(1 for r in self.records if r.slo_violated)

    def makespan(self) -> float:
        return max((r.completion for r in self.records), default=0.0)

    def gflops_timeline(self, dt: float = 0.1) -> list[tuple[float, float]]:
        """(t, GFLOP/s) samples — Fig. 6."""
        horizon = self.makespan()
        out = []
        t = 0.0
        while t < horizon + dt:
            g = sum(s.flops / max(s.end - s.start, 1e-9)
                    for s in self.spans if s.start <= t < s.end)
            out.append((t, g / 1e9))
            t += dt
        return out

    def completed_by(self, horizon: float) -> int:
        return sum(1 for r in self.records if r.completion <= horizon)


class EdgeSimulator:
    """``provider`` feeds the *planner* (what the strategy believes about the
    hardware); ``ground_truth`` governs *execution* (what the hardware
    actually does — a ``repro.profiling.SyntheticGroundTruth``, whose
    ``rate_scale`` shifts timing and ``power_scale`` shifts measured watts).
    Leaving both None reproduces the seed behaviour exactly: planning and
    execution share the analytic datasheet model, so predictions are perfect.
    ``feedback`` (a ``repro.profiling.FeedbackLoop``) receives one
    observation per executed compute shard — the run-time scheduler's
    measured latencies *and joules*, so both latency and energy drift are
    caught.  ``objective`` sets the default planning objective for every
    request; a ``SimRequest.objective`` overrides it per request.
    ``plan_cache`` (a ``repro.serving.plan_cache.PlanCache`` over this
    cluster) replaces per-request strategy calls with cached-frontier
    selection — including **mixed-tenant request streams**: every request
    resolves its own ``SimRequest.dag`` against the one shared cache, so
    the first request per (dag fingerprint, δ, calibration version) pays
    the frontier pass and every later one selects in microseconds — each
    request's arrival-time planning overhead reflects whichever path it
    took, so planner amortization (and any eviction churn under a bounded
    cache) shows up in simulated completion times exactly as it would in
    serving.  The cache's *planner config* then owns planning
    (HiDP, and the provider baked into ``cache.planner.config``), so
    combining it with a baseline ``strategy`` or a simulator-level
    ``provider`` is rejected rather than silently mislabelling results.

    ``fleet`` (a ``repro.fleet.FleetController``) makes the cluster
    *churn*: the controller's trace is replayed as simulated time advances
    — graceful events (leave/join/battery/thermal) apply at each request's
    planning boundary, while a ``crash`` fails mid-request.  A failed
    request's doomed work is truncated at the crash instant (survivors'
    partial shards stay on the timeline as wasted-but-metered compute),
    the leader re-elects if it was the casualty
    (``ClusterManager.elect_leader`` via the controller), and the request
    re-plans on the survivors and retries from the crash time —
    ``RequestRecord.retries``/``migrations`` count the damage, and
    ``SimRequest.slo`` lets the report turn it into SLO violations.  With
    a ``plan_cache`` the cache must be membership-keyed
    (``membership_source=fleet``): each new membership costs one frontier
    pass per tenant and a *returning* membership serves warm.  Feedback
    observations from shards that completed before a crash are kept — the
    hardware really did execute them.

    ``telemetry`` (a ``repro.telemetry.TelemetryRecorder``) makes the run
    durable — and causal: each ``sim.request`` span is a trace-tree root
    over its ``sim.attempt`` children (fault-injection retries parent
    under the original request), and each attempt carries per-stage
    ``sim.plan`` / ``sim.queue_wait`` / ``sim.compute`` / ``sim.comm``
    child spans plus whatever the plan cache and fleet emitted while the
    attempt was open — so ``repro.telemetry.trace`` can answer where any
    request's latency went.  Retry/migration/SLO counters are stamped
    with the membership epoch in effect, and the logical clock advances
    with simulated time so every other instrumented subsystem (cache,
    fleet, feedback) timestamps consistently.  A disabled recorder is
    normalized away — the hot path pays a single ``is not None`` check
    (see docs/observability.md).

    ``planning_time`` controls how planner overhead enters *simulated*
    time: the default ``"wall"`` charges each attempt's measured
    ``planning_seconds`` (the paper-faithful accounting — DP overhead
    delays execution, which tab1 measures), while a float pins a fixed
    per-attempt overhead instead.  Pass ``planning_time=0.0`` for
    seeded-replay determinism: wall clocks are the only nondeterminism in
    the pipeline, so pinning this makes two replays byte-identical
    (telemetry's canonical-log contract is gated on exactly that)."""

    def __init__(self, cluster: Cluster, strategy: str | Strategy = "hidp",
                 leader: str | None = None,
                 provider: CostProvider | None = None,
                 ground_truth=None, feedback=None,
                 objective: Objective | None = None,
                 plan_cache=None, fleet=None, telemetry=None,
                 planning_time: float | str = "wall"):
        if fleet is not None and plan_cache is not None:
            ms = plan_cache.membership_source
            if not (ms is fleet or ms is fleet.manager):
                raise ValueError(
                    "a churning fleet with a membership-blind (or "
                    "differently-sourced) plan_cache would serve plans for "
                    "departed nodes; construct the cache with "
                    "membership_source=fleet (or fleet.manager, the same "
                    "object this simulator churns)")
        if plan_cache is not None:
            if not (strategy == "hidp" or strategy is STRATEGIES["hidp"]):
                raise ValueError(
                    "plan_cache replaces per-request planning with the "
                    "cache's own HiDPPlanner; it cannot simulate strategy "
                    f"{strategy!r} — drop plan_cache or use strategy='hidp'")
            if provider is not None:
                raise ValueError(
                    "plan_cache ignores the simulator-level provider; set "
                    "the provider on the cache's PlannerConfig instead")
        self.cluster = cluster
        self.strategy: Strategy = (STRATEGIES[strategy]
                                   if isinstance(strategy, str) else strategy)
        self.fleet = fleet
        if fleet is not None:
            self.leader = leader or fleet.manager.leader \
                or cluster.nodes[0].name
            fleet.elect_leader(self.leader)
        else:
            self.leader = leader or cluster.nodes[0].name
        self.provider = provider
        self.ground_truth = ground_truth
        self.feedback = feedback
        self.objective = objective
        self.plan_cache = plan_cache
        if planning_time != "wall":
            planning_time = float(planning_time)
            if planning_time < 0:
                raise ValueError("planning_time must be 'wall' or >= 0")
        self.planning_time = planning_time
        from repro.telemetry import active as _tel_active
        self.telemetry = _tel_active(telemetry)
        self.leader_elections = 0
        # capacity-1 resources
        self.proc_busy: dict[tuple[str, str], float] = {}
        self.medium_busy: float = 0.0
        self.medium_spans: list[tuple[float, float]] = []
        self.radio_energy: float = 0.0
        self.spans: list[ExecutionSpan] = []
        # measurements buffered per attempt; see _observe
        self._pending_obs: list[tuple] = []

    # ----------------------------------------------------------- reservations
    def _reserve_proc(self, node: str, proc: str, ready: float,
                      duration: float, flops: float, watts: float,
                      rid: int) -> float:
        key = (node, proc)
        start = max(ready, self.proc_busy.get(key, 0.0))
        end = start + duration
        self.proc_busy[key] = end
        self.spans.append(ExecutionSpan(node, proc, start, end, flops,
                                        watts, rid))
        return end

    RADIO_POWER = 4.0          # W burned at the endpoints during a transfer

    def _reserve_medium(self, ready: float, nbytes: float, bw: float,
                        rtt: float) -> float:
        start = max(ready, self.medium_busy)
        end = start + comm_time(nbytes, bw, rtt)
        self.medium_busy = end
        self.medium_spans.append((start, end))
        self.radio_energy += self.RADIO_POWER * (end - start)
        tel = self.telemetry
        if tel is not None:
            # children of the open sim.attempt context: contention on the
            # shared half-duplex medium, then the transfer itself
            if start - ready > 1e-12:
                tel.child_span("sim.queue_wait", start - ready, t=ready,
                               resource="medium")
            tel.child_span("sim.comm", end - start, t=start,
                           resource="medium", bytes=nbytes)
        return end

    # ------------------------------------------------------- local execution
    def _compute_seconds(self, node: Node, proc_idx: int, flops: float,
                         analytic_rate: float, kind: str, delta: float
                         ) -> float:
        """Seconds a shard actually takes: analytic (seed path) unless a
        ground truth overrides the datasheet."""
        if self.ground_truth is None:
            return compute_time(flops, analytic_rate)
        return self.ground_truth.compute_seconds(
            node.name, node.processors[proc_idx].name, flops, kind, delta)

    def _active_watts(self, node: Node, proc_idx: int) -> float:
        """Watts a shard actually draws: datasheet unless the ground truth
        declares a diverging power model."""
        proc = node.processors[proc_idx]
        gt = self.ground_truth
        if gt is not None and hasattr(gt, "active_watts"):
            return gt.active_watts(node.name, proc.name)
        return proc.active_power

    def _observe(self, node: Node, proc_idx: int, flops: float,
                 nbytes: float, kind: str, delta: float,
                 measured: float, joules: float, end: float) -> None:
        """Buffer one executed shard's measurement (run-time scheduler
        measurements re-entering the Model Analyzer).  Buffered rather
        than reported immediately because the attempt's fate decides what
        the loop may see: a crashed attempt only produced real
        measurements for shards that *completed* before the crash instant
        — everything later is unwound and must never become a phantom
        observation (or be double-counted by the retry)."""
        if self.feedback is not None and flops > 0:
            key = f"{node.name}/{node.processors[proc_idx].name}"
            self._pending_obs.append(
                (end, key, kind, flops * delta, nbytes, measured, joules))

    def _flush_observations(self, up_to: float | None = None) -> None:
        """Report buffered measurements to the feedback loop — all of
        them, or (after a crash) only shards that finished by ``up_to``."""
        for end, key, kind, work, nbytes, measured, joules \
                in self._pending_obs:
            if up_to is None or end <= up_to + 1e-12:
                self.feedback.observe(
                    key, kind, work, nbytes, measured,
                    energy_j=joules if joules > 0 else None)
        self._pending_obs = []

    def _run_local(self, sub: ModelDAG, node: Node, lp: LocalPlan,
                   ready: float, delta: float, rid: int
                   ) -> tuple[float, float]:
        """Execute a node's share per its local plan. Returns (done, energy)."""
        kind = dominant_kind(sub)
        resources = processors_as_resources(node, delta, kind)
        energy = 0.0
        part = lp.partition
        tel = self.telemetry
        if isinstance(part, ModelPartition):
            t = ready
            for si in range(part.num_stages):
                a, b = part.boundaries[si], part.boundaries[si + 1]
                seg = sub.segment(a, b)
                ri = part.assignment[si]
                r = resources[ri]
                compute = self._compute_seconds(node, ri, seg.flops, r.rate,
                                                kind, delta)
                watts = self._active_watts(node, ri)
                comm = comm_time(seg.bytes_in, r.bw, r.rtt)
                dur = comm + compute
                proc = node.processors[ri].name
                t0 = t
                t = self._reserve_proc(node.name, proc, t, dur, seg.flops,
                                       watts, rid)
                if tel is not None:
                    self._emit_stage(tel, node.name, proc, rid, t0, t - dur,
                                     comm, compute, seg.bytes_in)
                energy += watts * dur
                self._observe(node, ri, seg.flops, seg.bytes_in, kind, delta,
                              compute, watts * compute, end=t)
            return t, energy
        assert isinstance(part, DataPartition)
        done = ready
        for f, ri in zip(part.fractions, part.assignment):
            r = resources[ri]
            compute = self._compute_seconds(node, ri, sub.total_flops * f,
                                            r.rate, kind, delta)
            watts = self._active_watts(node, ri)
            comm = comm_time((sub.input_bytes + sub.output_bytes) * f,
                             r.bw, r.rtt)
            dur = comm + compute
            proc = node.processors[ri].name
            end = self._reserve_proc(node.name, proc, ready, dur,
                                     sub.total_flops * f, watts, rid)
            if tel is not None:
                self._emit_stage(tel, node.name, proc, rid, ready,
                                 end - dur, comm, compute,
                                 (sub.input_bytes + sub.output_bytes) * f)
            energy += watts * dur
            self._observe(node, ri, sub.total_flops * f,
                          (sub.input_bytes + sub.output_bytes) * f, kind,
                          delta, compute, watts * compute, end=end)
            done = max(done, end)
        return done, energy

    def _emit_stage(self, tel, node: str, proc: str, rid: int,
                    ready: float, start: float, comm: float,
                    compute: float, nbytes: float) -> None:
        """Per-stage trace children under the open ``sim.attempt``:
        processor contention (queue-wait), the intra-node input transfer
        (its bus shares the processor reservation), and the compute
        shard itself — the spans critical paths and per-node utilization
        are computed from."""
        if start - ready > 1e-12:
            tel.child_span("sim.queue_wait", start - ready, t=ready,
                           resource=f"{node}/{proc}", request=rid)
        if comm > 0:
            tel.child_span("sim.comm", comm, t=start, request=rid,
                           resource=f"{node}/{proc}", bytes=nbytes)
        tel.child_span("sim.compute", compute, t=start + comm,
                       node=node, proc=proc, request=rid)

    # ----------------------------------------------------------- one request
    def _plan_for(self, req: SimRequest,
                  objective: Objective | None) -> HiDPPlan:
        """One planning pass at the current membership: through the
        (membership-keyed) cache when wired, else a strategy call against
        the live cluster."""
        if self.plan_cache is not None:
            return self.plan_cache.get(req.dag, objective=objective,
                                       delta=req.delta)
        kwargs = {}
        if self.provider is not None:
            kwargs["provider"] = self.provider
        if objective is not None:
            kwargs["objective"] = objective
        cluster = (self.fleet.cluster if self.fleet is not None
                   else self.cluster)
        return self.strategy(req.dag, cluster, req.delta, **kwargs)

    # ------------------------------------------------- fault-injection state
    def _snapshot(self) -> tuple:
        return (dict(self.proc_busy), self.medium_busy, self.radio_energy,
                len(self.spans), len(self.medium_spans))

    def _rollback_to_crash(self, snap: tuple, crash_t: float) -> float:
        """Truncate a doomed attempt at the crash instant.  Work started
        before the crash stays on the timeline (survivors were genuinely
        busy executing shards that are now worthless — FLOPs pro-rated to
        the truncated window, watts metered in full, and transfers billed
        for their actual pre-crash airtime); everything scheduled past it
        is unwound so the retry sees the resources free.  Returns the
        wasted active energy, which the request still pays for."""
        proc_busy, medium_busy, radio_energy, nspans, nmedium = snap
        attempt = self.spans[nspans:]
        del self.spans[nspans:]
        self.proc_busy = proc_busy
        # radio: re-bill only the airtime the attempt actually burned
        # before the crash — per reservation, never idle gaps
        medium_attempt = self.medium_spans[nmedium:]
        del self.medium_spans[nmedium:]
        self.medium_busy = medium_busy
        self.radio_energy = radio_energy
        wasted = 0.0
        for m_start, m_end in medium_attempt:
            if m_start >= crash_t:
                continue
            m_end = min(m_end, crash_t)
            self.medium_spans.append((m_start, m_end))
            self.medium_busy = max(self.medium_busy, m_end)
            burned = self.RADIO_POWER * (m_end - m_start)
            self.radio_energy += burned
            wasted += burned
        for s in attempt:
            if s.start >= crash_t:
                continue
            end = min(s.end, crash_t)
            frac = (end - s.start) / max(s.end - s.start, 1e-12)
            self.spans.append(dataclasses.replace(s, end=end,
                                                  flops=s.flops * frac))
            key = (s.node, s.processor)
            self.proc_busy[key] = max(self.proc_busy.get(key, 0.0), end)
            wasted += s.watts * (end - s.start)
        return wasted

    def _sync_leader(self) -> None:
        """Adopt the controller's leader (it re-elects whenever the sitting
        leader goes unavailable — Alg. 1 line 2 under churn)."""
        if self.fleet is None:
            return
        leader = self.fleet.manager.leader
        if leader is not None and leader != self.leader:
            self.leader = leader
            self.leader_elections += 1

    def _epoch(self) -> int | None:
        """The membership epoch in effect (None for a static fleet) —
        what telemetry events are stamped with."""
        return self.fleet.epoch if self.fleet is not None else None

    def _run_request(self, req: SimRequest) -> RequestRecord:
        objective = req.objective or self.objective
        tel = self.telemetry
        if tel is not None:
            tel.advance(req.arrival)
        # the request's trace-tree root: attempts, per-stage shards, plan
        # cache activity, and fleet epochs it triggered all parent under it
        with (tel.trace("sim.request", t=req.arrival, tenant=req.dag.name,
                        request=req.request_id) if tel is not None
              else contextlib.nullcontext()) as req_h:
            if self.fleet is not None:
                # graceful events (leave/join/battery/thermal) land at the
                # planning boundary; crashes are handled mid-request below
                self.fleet.advance(req.arrival)
                self._sync_leader()
            start = req.arrival
            total_energy = 0.0
            retries = migrations = 0
            while True:
                crash = None
                with (tel.trace("sim.attempt", t=start,
                                tenant=req.dag.name,
                                request=req.request_id)
                      if tel is not None
                      else contextlib.nullcontext()) as att_h:
                    plan = self._plan_for(req, objective)
                    snap = self._snapshot()
                    overhead = (plan.planning_seconds
                                if self.planning_time == "wall"
                                else self.planning_time)
                    if tel is not None:
                        # planning overhead as charged into domain time
                        tel.child_span("sim.plan", overhead, t=start,
                                       tenant=req.dag.name,
                                       request=req.request_id)
                    t, energy = self._execute_plan(req, plan,
                                                   start + overhead)
                    if self.fleet is not None:
                        used = {a.node.name
                                for a in plan.global_plan.assignments}
                        used.add(self.leader)
                        crash = self.fleet.next_failure(start, t, used)
                    if crash is None:
                        total_energy += energy
                        self._flush_observations()
                        if tel is not None:
                            tel.advance(t)
                            att_h.set(t - start, epoch=self._epoch(),
                                      ok=True)
                    else:
                        # mid-request failure: truncate the doomed attempt,
                        # consume the trace through the crash (one
                        # coalesced membership epoch), re-elect if the
                        # leader fell, re-plan on survivors, retry; only
                        # shards that really finished before the crash
                        # reach the feedback loop
                        self._flush_observations(up_to=crash.time)
                        total_energy += self._rollback_to_crash(snap,
                                                                crash.time)
                        self.fleet.advance(crash.time)
                        migrated = sum(
                            1 for a in plan.global_plan.assignments
                            if not self.fleet.manager.node(
                                a.node.name).available)
                        migrations += migrated
                        retries += 1
                        self._sync_leader()
                        if tel is not None:
                            tel.advance(crash.time)
                            att_h.set(crash.time - start,
                                      epoch=self._epoch(), ok=False,
                                      crashed=crash.node)
                if crash is None:
                    break
                # retry accounting parents under the *request*, not the
                # closed attempt — a retry is the request's fate
                if tel is not None:
                    tel.counter("sim.retry", t=crash.time,
                                tenant=req.dag.name, epoch=self._epoch(),
                                request=req.request_id, crashed=crash.node)
                    if migrated:
                        tel.counter("sim.migration", migrated,
                                    t=crash.time, tenant=req.dag.name,
                                    epoch=self._epoch(),
                                    request=req.request_id)
                if self.fleet.manager.first_available() is None:
                    raise RuntimeError(
                        f"request {req.request_id}: every node failed; "
                        "nothing left to retry on")
                start = crash.time
            rec = RequestRecord(request_id=req.request_id,
                                dag_name=req.dag.name,
                                arrival=req.arrival, completion=t,
                                active_energy=total_energy,
                                mode=plan.global_plan.mode,
                                predicted_latency=plan.predicted_latency,
                                predicted_energy=plan.predicted_energy,
                                retries=retries, migrations=migrations,
                                slo=req.slo)
            if tel is not None:
                req_h.set(rec.latency, epoch=self._epoch(), mode=rec.mode,
                          retries=retries, migrations=migrations,
                          slo_violated=rec.slo_violated,
                          active_energy_j=rec.active_energy,
                          predicted_latency_s=rec.predicted_latency,
                          predicted_energy_j=rec.predicted_energy)
                if rec.slo_violated:
                    tel.counter("sim.slo_violation", t=rec.completion,
                                tenant=req.dag.name, epoch=self._epoch(),
                                request=req.request_id)
                tel.gauge("sim.energy", rec.active_energy,
                          t=rec.completion, tenant=req.dag.name,
                          epoch=self._epoch(), request=req.request_id)
        return rec

    def _execute_plan(self, req: SimRequest, plan: HiDPPlan,
                      t: float) -> tuple[float, float]:
        """Execute one planned attempt starting at ``t`` (post-planning).
        Returns (completion time, active energy incl. radio)."""
        gp = plan.global_plan
        energy = 0.0
        radio0 = self.radio_energy
        if gp.mode == "model":
            # sequential pipeline: activation hops over the shared medium
            for a, lp in zip(gp.assignments, plan.local_plans):
                sd = sub_dag_for(req.dag, a)
                if a.node.name != self.leader or a.stage_index > 0:
                    t = self._reserve_medium(t, sd.input_bytes,
                                             a.node.net_bw, 2e-3)
                t, e = self._run_local(sd, a.node, lp, t, req.delta,
                                       req.request_id)
                energy += e
            last = gp.assignments[-1].node
            if last.name != self.leader:
                t = self._reserve_medium(t, req.dag.output_bytes,
                                         last.net_bw, 2e-3)
        else:
            # scatter inputs → parallel local execution → gather outputs
            shards = [(a, lp, sub_dag_for(req.dag, a))
                      for a, lp in zip(gp.assignments, plan.local_plans)]
            readies = []
            for a, lp, sd in shards:                      # scatter phase
                if a.node.name != self.leader:
                    readies.append(self._reserve_medium(
                        t, sd.input_bytes, a.node.net_bw, 2e-3))
                else:
                    readies.append(t)
            ends = []
            for (a, lp, sd), ready in zip(shards, readies):   # compute phase
                end, e = self._run_local(sd, a.node, lp, ready, req.delta,
                                         req.request_id)
                ends.append(end)
                energy += e
            done_times = []                               # gather phase
            for (a, lp, sd), end in sorted(zip(shards, ends),
                                           key=lambda p: p[1]):
                if a.node.name != self.leader:
                    end = self._reserve_medium(end, sd.output_bytes,
                                               a.node.net_bw, 2e-3)
                done_times.append(end)
            t = max(done_times)
            if plan.extra_comm_bytes:
                # strategy-specific per-layer exchange (MoDNN halos) occupies
                # the medium during execution and gates completion
                t = max(t, self._reserve_medium(
                    max(readies), plan.extra_comm_bytes,
                    self.cluster.nodes[0].net_bw, 0.0))
            t += plan.extra_latency
        energy += self.radio_energy - radio0
        return t, energy

    # ------------------------------------------------------------------ drive
    def run(self, requests: Sequence[SimRequest]) -> SimReport:
        records = [self._run_request(r)
                   for r in sorted(requests, key=lambda r: r.arrival)]
        return SimReport(records=records, spans=self.spans,
                         cluster=self.cluster)


def simulate(cluster: Cluster, strategy: str | Strategy,
             workload: Iterable[tuple[float, ModelDAG, float]],
             *, provider: CostProvider | None = None,
             ground_truth=None, feedback=None,
             objective: Objective | None = None,
             plan_cache=None, fleet=None, telemetry=None,
             planning_time: float | str = "wall") -> SimReport:
    sim = EdgeSimulator(cluster, strategy, provider=provider,
                        ground_truth=ground_truth, feedback=feedback,
                        objective=objective, plan_cache=plan_cache,
                        fleet=fleet, telemetry=telemetry,
                        planning_time=planning_time)
    reqs = [SimRequest(i, dag, t, delta)
            for i, (t, dag, delta) in enumerate(workload)]
    return sim.run(reqs)
