"""HiDP cost model — §III "System Model" of the paper, verbatim algebra.

* processor compute rate        λ_k = f_k / δ           [flops/s]      (Eq. ρ)
* node compute rate             Λ_j = Σ_k λ_k           [flops/s]      (Eq. 2)
* local  comm rate              μ_k                     [bytes/s]
* local  ratio vector           ψ = {λ_k/μ_k}                          (Eq. 1)
* global comm rate              β_j                     [bytes/s]
* global ratio vector           Ψ = {Λ_j/β_j}                          (Eq. 3)
* availability vector           A(N_φ) = {α_j ∈ {0,1}}                 (Eq. 4)

The same classes describe (a) the paper's edge boards (Table II) for the
faithful reproduction and (b) TPU pods/chips for the production launcher —
only the numbers differ (see DESIGN.md §2).
"""

from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Callable, Protocol, Sequence, runtime_checkable

import numpy as np

if TYPE_CHECKING:
    from .dag import ModelDAG


# --------------------------------------------------------------------------
# Hardware descriptions
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Processor:
    """One processing unit ρ_k inside a node: CPU cluster, GPU, NPU — or, in
    the TPU guise, one intra-pod sharding *lane* (a group of chips reachable
    at ICI bandwidth)."""

    name: str
    kind: str                    # "cpu" | "gpu" | "npu" | "tpu"
    peak_flops: float            # f_k / δ at δ=1; per-model δ rescales this
    local_bw: float              # μ_k — bytes/s to peers inside the node
    idle_power: float = 0.0      # W
    active_power: float = 0.0    # W
    # Per-block-kind efficiency multipliers (the "CPU-friendly layer" effect;
    # §I: "CPU-friendly layers of DNN models"). 1.0 = peak.
    affinity: tuple[tuple[str, float], ...] = ()

    def rate(self, delta: float = 1.0, kind: str = "generic") -> float:
        """λ_k = f_k/δ, modulated by the layer-kind affinity."""
        eff = dict(self.affinity).get(kind, 1.0)
        return self.peak_flops * eff / max(delta, 1e-12)


@dataclasses.dataclass(frozen=True)
class Node:
    """Edge node φ_j (or TPU pod). ``net_bw`` is β_j in bytes/s.

    ``default_processor`` is the framework-default unit (the paper's "P1"
    behaviour: TensorFlow schedules on GPU unless told otherwise; on boards
    without a usable GPU delegate the default is the CPU)."""

    name: str
    processors: tuple[Processor, ...]
    net_bw: float                # β_j — bytes/s on the inter-node link
    available: bool = True       # α_j
    default_processor: str = "gpu"

    def compute_rate(self, delta: float = 1.0, kind: str = "generic") -> float:
        """Λ_j(ρ_k) = Σ_k λ_k   (Eq. 2)."""
        return sum(p.rate(delta, kind) for p in self.processors)

    def default_rate(self, delta: float = 1.0, kind: str = "generic") -> float:
        """Capacity as global-only strategies see it: they profile a node by
        timing inference with the default runtime, which exercises only the
        default processor (§I — "misrepresents the compute capacity")."""
        for p in self.processors:
            if p.kind == self.default_processor:
                return p.rate(delta, kind)
        return max(p.rate(delta, kind) for p in self.processors)

    def psi(self, delta: float = 1.0) -> tuple[float, ...]:
        """ψ{λ, μ} = {λ_k/μ_k}   (Eq. 1)."""
        return tuple(p.rate(delta) / p.local_bw for p in self.processors)


@dataclasses.dataclass(frozen=True)
class Cluster:
    """The edge cluster N(φ_j)."""

    nodes: tuple[Node, ...]

    def availability(self) -> tuple[int, ...]:
        """A(N_φ)   (Eq. 4)."""
        return tuple(1 if n.available else 0 for n in self.nodes)

    def available_nodes(self) -> tuple[Node, ...]:
        return tuple(n for n in self.nodes if n.available)

    def Psi(self, delta: float = 1.0) -> tuple[float, ...]:
        """Ψ{Λ, β} = {Λ_j/β_j}   (Eq. 3) over *available* nodes."""
        return tuple(n.compute_rate(delta) / n.net_bw
                     for n in self.available_nodes())

    def with_availability(self, alphas: Sequence[bool]) -> "Cluster":
        if len(alphas) != len(self.nodes):
            raise ValueError("availability vector length mismatch")
        return Cluster(tuple(
            dataclasses.replace(n, available=bool(a))
            for n, a in zip(self.nodes, alphas)))


# --------------------------------------------------------------------------
# Latency primitives used by the DP partitioners
# --------------------------------------------------------------------------

def compute_time(flops: float, rate: float) -> float:
    """Θ for a block on a resource at λ (or Λ) flops/s."""
    return flops / max(rate, 1e-12)


def comm_time(nbytes: float, bw: float, rtt: float = 0.0) -> float:
    return rtt + nbytes / max(bw, 1e-12)


@dataclasses.dataclass(frozen=True)
class Resource:
    """Uniform view the DP algorithm sees, at either tier (paper §III:
    "the function arguments are essentially the same in either case").

    Global tier: one Resource per available node  — rate Λ_j, bw β_j.
    Local  tier: one Resource per processor ρ_k   — rate λ_k, bw μ_k.
    """

    name: str
    rate: float                  # flops/s (already δ- and affinity-adjusted)
    bw: float                    # bytes/s toward the coordinator
    rtt: float = 0.0             # fixed per-transfer latency (s)
    active_power: float = 0.0    # W, for energy accounting
    idle_power: float = 0.0
    # Which calibration entries describe this resource ("" → use ``name``).
    # Distinguishes a node's Λ=Σλ view ("orin_nx") from the default-runtime
    # view global-only strategies probe ("orin_nx/gpu").
    profile_key: str = ""

    def time_for(self, block_flops: float, xfer_bytes: float) -> float:
        return compute_time(block_flops, self.rate) + comm_time(
            xfer_bytes, self.bw, self.rtt)


# --------------------------------------------------------------------------
# Cost providers — pluggable latency prediction
# --------------------------------------------------------------------------

@runtime_checkable
class CostProvider(Protocol):
    """How the planner prices compute, communication, and energy on a
    :class:`Resource`.

    The analytic provider reproduces the paper's closed-form algebra
    (the seed behaviour, bit-identical); a calibrated provider
    (``repro.profiling.CalibratedCostProvider``) answers from regressors
    fitted to measured samples — the paper's DNN Model Analyzer.

    Latency queries:

    * ``compute_time(flops, resource, kind)`` — seconds to execute
      ``flops`` on the resource.
    * ``comm_time(nbytes, resource, rtt)`` — seconds to move ``nbytes``
      over the resource's link (``rtt=None`` uses the resource's own).
    * ``effective_rate(resource, kind)`` — flops/s as the provider believes
      them; orders resources by heterogeneity.
    * ``segment_coster(dag, resource)`` — O(1) ``cost(a, b)`` for the
      compute seconds of ``dag.blocks[a:b]`` (prefix-summed).
    * ``data_coeffs(dag, resource)`` — ``(linear, fixed)`` seconds pricing a
      proportional data slice: fraction *f* costs ``f·linear + fixed``.

    Energy queries (J; the active-power draw while the resource works —
    idle power is accounted by the caller over the plan makespan):

    * ``energy(flops, nbytes, resource, kind)`` — joules to execute
      ``flops`` and move ``nbytes`` on the resource.
    * ``compute_energy(flops, resource, kind)`` / ``comm_energy(nbytes,
      resource, rtt)`` — the two terms of ``energy`` separately.
    * ``segment_energy_coster(dag, resource)`` — O(1) ``cost(a, b)`` for
      the compute joules of ``dag.blocks[a:b]``.

    ``at_delta(delta)`` rebinds the provider to a model's compute intensity
    (cycles/flop); the analytic provider is δ-invariant because its
    resources arrive already δ-adjusted.
    """

    def compute_time(self, flops: float, resource: Resource,
                     kind: str = "generic") -> float: ...

    def comm_time(self, nbytes: float, resource: Resource,
                  rtt: float | None = None) -> float: ...

    def effective_rate(self, resource: Resource,
                       kind: str = "generic") -> float: ...

    def segment_coster(self, dag: "ModelDAG", resource: Resource
                       ) -> Callable[[int, int], float]: ...

    def data_coeffs(self, dag: "ModelDAG", resource: Resource
                    ) -> tuple[float, float]: ...

    def energy(self, flops: float, nbytes: float, resource: Resource,
               kind: str = "generic") -> float: ...

    def compute_energy(self, flops: float, resource: Resource,
                       kind: str = "generic") -> float: ...

    def comm_energy(self, nbytes: float, resource: Resource,
                    rtt: float | None = None) -> float: ...

    def segment_energy_coster(self, dag: "ModelDAG", resource: Resource
                              ) -> Callable[[int, int], float]: ...

    def at_delta(self, delta: float) -> "CostProvider": ...


class AnalyticCostProvider:
    """Datasheet algebra: Θ = flops/rate, comm = rtt + bytes/bw.

    Every method reduces to exactly the arithmetic the seed modules inlined,
    so planning with this provider is bit-identical to planning without one.
    """

    def compute_time(self, flops: float, resource: Resource,
                     kind: str = "generic") -> float:
        return compute_time(flops, resource.rate)

    def comm_time(self, nbytes: float, resource: Resource,
                  rtt: float | None = None) -> float:
        return comm_time(nbytes, resource.bw,
                         resource.rtt if rtt is None else rtt)

    def effective_rate(self, resource: Resource,
                       kind: str = "generic") -> float:
        return resource.rate

    def segment_coster(self, dag: "ModelDAG", resource: Resource
                       ) -> Callable[[int, int], float]:
        """O(1) segment compute cost via the DAG's FLOP prefix sums."""
        cum = dag.cumulative_flops()
        rate = resource.rate

        def cost(a: int, b: int) -> float:
            return compute_time(cum[b] - cum[a], rate)

        return cost

    def data_coeffs(self, dag: "ModelDAG", resource: Resource
                    ) -> tuple[float, float]:
        """(seconds per unit data fraction, fixed per-slice seconds) for a
        proportional slice of the whole DAG.  The analytic model has no
        per-block overheads, so the fixed part is zero."""
        return (self.compute_time(dag.total_flops, resource,
                                  dag.dominant_kind()), 0.0)

    # ------------------------------------------------------------- energy
    # The datasheet energy model is P_active × time — exactly the algebra the
    # seed's ``predicted_energy`` inlined, now queryable per term so the DP
    # can minimize energy directly.

    def energy(self, flops: float, nbytes: float, resource: Resource,
               kind: str = "generic") -> float:
        """J to execute ``flops`` and move ``nbytes``: active_power × time."""
        return (self.compute_energy(flops, resource, kind)
                + self.comm_energy(nbytes, resource))

    def compute_energy(self, flops: float, resource: Resource,
                       kind: str = "generic") -> float:
        return resource.active_power * self.compute_time(flops, resource,
                                                         kind)

    def comm_energy(self, nbytes: float, resource: Resource,
                    rtt: float | None = None) -> float:
        return resource.active_power * self.comm_time(nbytes, resource, rtt)

    def segment_energy_coster(self, dag: "ModelDAG", resource: Resource
                              ) -> Callable[[int, int], float]:
        """O(1) segment compute energy: active_power × segment seconds."""
        coster = self.segment_coster(dag, resource)
        watts = resource.active_power

        def cost(a: int, b: int) -> float:
            return watts * coster(a, b)

        return cost

    # ------------------------------------------------- vectorized fast path
    # Array variants of the queries above, elementwise bit-identical to the
    # scalar ones (same operations in the same order, IEEE-754 float64
    # throughout) — the fast DP engine builds its transition matrices from
    # these instead of calling the scalar closures O(n²·m) times.

    def segment_cost_matrix(self, dag: "ModelDAG",
                            resource: Resource) -> np.ndarray:
        """``M[a, b] == segment_coster(dag, resource)(a, b)`` bit-exactly:
        (cum[b] − cum[a]) / max(rate, 1e-12), vectorized."""
        cum = np.asarray(dag.cumulative_flops(), dtype=np.float64)
        return (cum[None, :] - cum[:, None]) / max(resource.rate, 1e-12)

    def segment_energy_matrix(self, dag: "ModelDAG",
                              resource: Resource) -> np.ndarray:
        """``M[a, b] == segment_energy_coster(dag, resource)(a, b)``."""
        return resource.active_power * self.segment_cost_matrix(dag, resource)

    def comm_time_array(self, nbytes, resource: Resource,
                        rtt: float | None = None) -> np.ndarray:
        """Elementwise ``comm_time`` over an array of byte counts."""
        r = resource.rtt if rtt is None else rtt
        return r + np.asarray(nbytes, dtype=np.float64) / max(
            resource.bw, 1e-12)

    def comm_energy_array(self, nbytes, resource: Resource,
                          rtt: float | None = None) -> np.ndarray:
        return resource.active_power * self.comm_time_array(nbytes, resource,
                                                            rtt)

    def at_delta(self, delta: float) -> "AnalyticCostProvider":
        """Resources arrive already δ-adjusted; nothing to rebind."""
        return self


ANALYTIC = AnalyticCostProvider()


def resolve_provider(provider: CostProvider | None) -> CostProvider:
    return ANALYTIC if provider is None else provider


def node_as_resource(node: Node, delta: float = 1.0, kind: str = "generic",
                     capacity: str = "sum") -> Resource:
    """Global-tier view: collapse a node to (Λ_j, β_j).

    ``capacity="sum"`` is HiDP's Λ_j = Σλ_k (justified because its local tier
    actually realises it); ``capacity="default"`` is what global-only
    strategies measure when profiling the default runtime (P1)."""
    rate = (node.compute_rate(delta, kind) if capacity == "sum"
            else node.default_rate(delta, kind))
    if capacity == "sum":
        profile_key = node.name                     # Λ = Σλ over calibrations
    else:
        default = next((p.name for p in node.processors
                        if p.kind == node.default_processor), None)
        profile_key = f"{node.name}/{default}" if default else node.name
    return Resource(
        name=node.name,
        rate=rate,
        bw=node.net_bw,
        rtt=2e-3,  # wireless round-trip floor; overridden for TPU DCN
        active_power=sum(p.active_power for p in node.processors),
        idle_power=sum(p.idle_power for p in node.processors),
        profile_key=profile_key,
    )


def processors_as_resources(node: Node, delta: float = 1.0,
                            kind: str = "generic") -> tuple[Resource, ...]:
    """Local-tier view: each ρ_k as (λ_k, μ_k)."""
    return tuple(
        Resource(name=f"{node.name}/{p.name}", rate=p.rate(delta, kind),
                 bw=p.local_bw, rtt=2e-5,
                 active_power=p.active_power, idle_power=p.idle_power)
        for p in node.processors)


# --------------------------------------------------------------------------
# TPU production constants (v5e) — used by the roofline and the TPU-guise
# cost model.  Single source of truth for benchmarks/roofline.py.
# --------------------------------------------------------------------------

TPU_V5E_PEAK_FLOPS = 197e12        # bf16 FLOP/s per chip
TPU_V5E_HBM_BW = 819e9             # bytes/s per chip
TPU_V5E_ICI_BW = 50e9              # bytes/s per link (~intra-pod)
TPU_V5E_DCN_BW = 25e9              # bytes/s per pod-pair (inter-pod, approx)
TPU_V5E_TDP = 215.0                # W per chip (nameplate-ish, for energy est)


def tpu_chip(name: str = "v5e") -> Processor:
    return Processor(name=name, kind="tpu", peak_flops=TPU_V5E_PEAK_FLOPS,
                     local_bw=TPU_V5E_ICI_BW, idle_power=60.0,
                     active_power=TPU_V5E_TDP)


def tpu_pod(name: str, chips: int = 256) -> Node:
    return Node(name=name,
                processors=tuple(
                    dataclasses.replace(tpu_chip(), name=f"chip{i}")
                    for i in range(chips)),
                net_bw=TPU_V5E_DCN_BW)
