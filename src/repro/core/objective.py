"""Planning objectives — what the DP partitioners minimize.

The paper's headline is not only 38% lower latency but **46% lower energy**
(§IV, Fig. 5), and CoEdge-style formulations show that energy-aware workload
partitioning *under a latency constraint* is the right shape for
heterogeneous edge clusters.  This module makes that choice explicit: every
planner entry point accepts an :class:`Objective` describing the scalar the
search minimizes.

Three metrics:

* ``latency`` — the seed behaviour (and the default): minimize end-to-end
  inference latency.  Bit-identical to planning before objectives existed.
* ``energy``  — minimize predicted energy-to-solution (active while busy,
  idle for the rest of the makespan — the algebra of
  ``dp_partitioner.predicted_energy``), optionally subject to
  ``latency_budget``.
* ``edp``     — minimize the energy-delay product ``E × T`` (equal weight to
  both; the classic low-power systems scalarization), optionally subject to
  ``latency_budget``.

``latency_budget`` turns the search constrained: plans within the budget are
always preferred over plans outside it; among infeasible plans the fastest
wins (drive toward feasibility), among feasible ones the metric decides.

``radio_power`` lets the planner price what the edge testbed actually
measures: the simulator charges ``EdgeSimulator.RADIO_POWER`` watts at the
endpoints of every wireless transfer, an energy term the datasheet algebra
does not see.  It defaults to 0 so the default objective reproduces the seed
numerics exactly; energy-aware callers set it to the radio's transmit power
(4 W for the paper's testbed) so data-partitioning across many nodes pays
its true communication energy.
"""

from __future__ import annotations

import dataclasses

METRICS = ("latency", "energy", "edp")


@dataclasses.dataclass(frozen=True)
class Objective:
    """What a planning pass minimizes.

    Attributes:
        metric: ``"latency"`` | ``"energy"`` | ``"edp"``.
        latency_budget: optional hard latency cap in seconds.  Feasible
            plans (latency ≤ budget) always beat infeasible ones; among
            infeasible plans lower latency wins so the search converges
            toward feasibility.
        radio_power: watts charged on wireless transfer seconds when
            pricing a plan's energy (0 = seed algebra, no radio term).
    """

    metric: str = "latency"
    latency_budget: float | None = None
    radio_power: float = 0.0

    def __post_init__(self):
        if self.metric not in METRICS:
            raise ValueError(
                f"unknown objective metric {self.metric!r}; "
                f"expected one of {METRICS}")
        if self.latency_budget is not None and self.latency_budget <= 0:
            raise ValueError("latency_budget must be positive")

    # ------------------------------------------------------------ properties
    @property
    def is_latency(self) -> bool:
        """True when the search reduces to the seed's latency-only DP."""
        return self.metric == "latency" and self.latency_budget is None

    def unconstrained(self) -> "Objective":
        """The same metric without the latency budget."""
        if self.latency_budget is None:
            return self
        return dataclasses.replace(self, latency_budget=None)

    def local(self) -> "Objective":
        """The objective as the *local* tier should see it: the same metric
        and budget, but no radio term — intra-node transfers are DRAM
        copies, not wireless.  A latency budget is kept as-is; the
        hierarchical planner replaces it with the node's decomposed share
        (see ``hidp._local_objective``) before planning locally."""
        if self.radio_power == 0.0:
            return self
        return dataclasses.replace(self, radio_power=0.0)

    # ------------------------------------------------------------ comparison
    def key(self, latency: float, energy: float) -> tuple:
        """Total order over (latency, energy) plan outcomes — smaller wins.

        The leading element is budget feasibility; the trailing elements
        break ties deterministically (``edp`` ties fall to lower energy,
        then lower latency — saving joules at equal E×T is free).
        """
        feasible = (self.latency_budget is None
                    or latency <= self.latency_budget)
        if not feasible:
            return (1, latency, energy, 0.0)
        if self.metric == "latency":
            return (0, latency, energy, 0.0)
        if self.metric == "energy":
            return (0, energy, latency, 0.0)
        return (0, latency * energy, energy, latency)        # edp

    def better(self, lat_a: float, en_a: float,
               lat_b: float, en_b: float) -> bool:
        """True iff outcome *a* is strictly better than outcome *b*."""
        return self.key(lat_a, en_a) < self.key(lat_b, en_b)

    def at_least_as_good(self, lat_a: float, en_a: float,
                         lat_b: float, en_b: float) -> bool:
        """Non-strict comparison — preserves the seed's model-over-data
        tie-breaking in ``dp_partitioner.partition``."""
        return self.key(lat_a, en_a) <= self.key(lat_b, en_b)

    # --------------------------------------------------------------- parsing
    @classmethod
    def parse(cls, spec: str, *, latency_budget: float | None = None,
              radio_power: float = 0.0) -> "Objective":
        """Build from a CLI-style spec: ``"energy"``, ``"edp@0.5"`` (metric @
        latency budget in seconds)."""
        metric, _, budget = spec.partition("@")
        return cls(metric=metric.strip(),
                   latency_budget=float(budget) if budget else latency_budget,
                   radio_power=radio_power)


LATENCY = Objective()


def resolve_objective(objective: Objective | None) -> Objective:
    """None → the default latency objective (the seed behaviour)."""
    return LATENCY if objective is None else objective
