"""DNN-as-DAG representation used by the HiDP partitioners.

The paper models a DNN as a DAG ``D(L_i) = {L1, L2, ..., Li}`` whose nodes are
layers and whose edges are tensors (§III System Model).  Partitioning operates
on *blocks*: contiguous groups of layers (model partitioning, width ``ω``) or
replicated sub-models over split input data (data partitioning, ``σ``
sub-models).

Every block is annotated with the quantities the cost model needs:

* ``flops``        — forward FLOPs for one inference of the block
* ``param_bytes``  — weight bytes that must be resident/transferred to run it
* ``bytes_in``     — activation bytes entering the block (the tensor edge)
* ``bytes_out``    — activation bytes leaving the block

These are filled analytically — from layer hyper-parameters for the paper's
CNNs (``edge_models.py``) and from the LM configs for the TPU tier
(``models/model.py:block_costs``).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Iterable, Sequence


@dataclasses.dataclass(frozen=True)
class Block:
    """One partitionable unit (a layer or fused group of layers)."""

    name: str
    flops: float                 # forward FLOPs for one request through the block
    param_bytes: float           # resident weight bytes
    bytes_in: float              # input activation bytes
    bytes_out: float             # output activation bytes
    # Data partitioning metadata: can the block's *input* be split spatially /
    # batch-wise, and what fraction of bytes_out must be exchanged between
    # neighbouring data partitions to stay exact (halo / boundary rows for
    # convs, zero for pure batch splits, full for attention over shared ctx).
    data_splittable: bool = True
    halo_fraction: float = 0.0
    # Tags used by the local partitioner's affinity table (the TPU analogue of
    # "CPU-friendly layer"): e.g. "attn", "ffn", "moe", "ssm", "conv", "embed".
    kind: str = "generic"

    def scaled(self, fraction: float) -> "Block":
        """A proportional slice of this block (data partitioning)."""
        return dataclasses.replace(
            self,
            flops=self.flops * fraction,
            bytes_in=self.bytes_in * fraction,
            bytes_out=self.bytes_out * fraction,
        )


@dataclasses.dataclass(frozen=True)
class ModelDAG:
    """A linearised DAG: the paper's models (CNN chains and LM stacks) are
    sequential at block granularity, so topological order == list order.

    Residual/branchy interiors (Inception mixed blocks, MoE routers, parallel
    attn+SSM) are *fused into* one Block — partition points only exist at
    block boundaries, exactly as in the paper (blocks are "executable
    groups of layers").
    """

    name: str
    blocks: tuple[Block, ...]
    input_bytes: float           # bytes of one request's input
    output_bytes: float          # bytes of the final prediction

    # ------------------------------------------------------------------ totals
    @property
    def total_flops(self) -> float:
        return sum(b.flops for b in self.blocks)

    @property
    def total_param_bytes(self) -> float:
        return sum(b.param_bytes for b in self.blocks)

    def __len__(self) -> int:
        return len(self.blocks)

    # ------------------------------------------------------------- block maths
    def segment(self, start: int, stop: int) -> Block:
        """Fuse blocks[start:stop] into a single block (a model partition)."""
        if not 0 <= start < stop <= len(self.blocks):
            raise ValueError(f"bad segment [{start}, {stop}) of {len(self.blocks)}")
        seg = self.blocks[start:stop]
        return Block(
            name=f"{self.name}[{start}:{stop}]",
            flops=sum(b.flops for b in seg),
            param_bytes=sum(b.param_bytes for b in seg),
            bytes_in=seg[0].bytes_in,
            bytes_out=seg[-1].bytes_out,
            data_splittable=all(b.data_splittable for b in seg),
            halo_fraction=max(b.halo_fraction for b in seg),
            kind=seg[0].kind if len({b.kind for b in seg}) == 1 else "mixed",
        )

    def dominant_kind(self) -> str:
        """The block kind carrying the most FLOPs — picks the affinity row
        (and the calibration bucket) when collapsing the DAG to one rate."""
        flops_by_kind: dict[str, float] = {}
        for b in self.blocks:
            flops_by_kind[b.kind] = flops_by_kind.get(b.kind, 0.0) + b.flops
        return (max(flops_by_kind, key=flops_by_kind.get)
                if flops_by_kind else "generic")

    def cumulative_flops(self) -> list[float]:
        out, acc = [0.0], 0.0
        for b in self.blocks:
            acc += b.flops
            out.append(acc)
        return out

    def validate(self) -> None:
        """Edge-consistency: bytes_out of block i must equal bytes_in of i+1."""
        for a, b in zip(self.blocks, self.blocks[1:]):
            if not math.isclose(a.bytes_out, b.bytes_in, rel_tol=1e-6):
                raise ValueError(
                    f"DAG {self.name}: edge mismatch {a.name}.bytes_out="
                    f"{a.bytes_out} != {b.name}.bytes_in={b.bytes_in}"
                )


def chain(name: str, blocks: Iterable[Block], input_bytes: float,
          output_bytes: float, *, validate: bool = True) -> ModelDAG:
    dag = ModelDAG(name=name, blocks=tuple(blocks), input_bytes=input_bytes,
                   output_bytes=output_bytes)
    if validate:
        dag.validate()
    return dag


# --------------------------------------------------------------------------
# Partition descriptions (output of the DP partitioners, input to execution)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ModelPartition:
    """Model partitioning: contiguous stages, pipelined across resources.

    ``boundaries`` are cut points: stage i = blocks[boundaries[i]:boundaries[i+1]].
    ``assignment[i]`` is the index of the resource executing stage i.
    """
    mode: str = dataclasses.field(default="model", init=False)
    boundaries: tuple[int, ...] = ()
    assignment: tuple[int, ...] = ()
    predicted_latency: float = float("inf")

    @property
    def num_stages(self) -> int:
        return len(self.boundaries) - 1


@dataclasses.dataclass(frozen=True)
class DataPartition:
    """Data partitioning: σ parallel sub-models, fractions per resource.

    ``fractions[i]`` is the share of the request's data assigned to resource
    ``assignment[i]``; fractions sum to 1.
    """
    mode: str = dataclasses.field(default="data", init=False)
    fractions: tuple[float, ...] = ()
    assignment: tuple[int, ...] = ()
    predicted_latency: float = float("inf")

    @property
    def num_splits(self) -> int:
        return len(self.fractions)


Partition = ModelPartition | DataPartition
