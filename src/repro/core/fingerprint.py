"""Fingerprinting — the hashes calibration and plan caching key on.

A calibration (and therefore a cached plan frontier) is only valid for the
hardware it was computed against, so both ``CalibrationStore`` paths and
``PlanCache`` keys start with a fingerprint of the cluster's declared
topology: node and processor names, datasheet rates, link bandwidths, and
affinity tables.  Any change to the fleet — a board swapped, a link
upgraded, an affinity retuned — changes the fingerprint and cleanly
invalidates both stores at once.

A cached frontier is likewise only valid for the *workload* it was planned
for, so multi-tenant cache keys carry a :func:`dag_fingerprint` — a digest
of the block DAG's full cost surface (names, FLOPs, byte counts, kinds,
splittability) rather than just its name.  Two tenants that happen to share
a model name but differ in shape can never collide, and editing a model's
blocks orphans its persisted fronts exactly like a board swap orphans
calibrations.

Keeping both hashes here (rather than duplicated in each subsystem) is what
guarantees the key spaces cannot drift apart.
"""

from __future__ import annotations

import hashlib
import json
from typing import TYPE_CHECKING

from .cost_model import Cluster

if TYPE_CHECKING:
    from .dag import ModelDAG


def _digest(spec) -> str:
    return hashlib.sha256(
        json.dumps(spec, sort_keys=True).encode()).hexdigest()[:16]


def cluster_fingerprint(cluster: Cluster) -> str:
    """A 16-hex-digit digest of the cluster's declared topology."""
    spec = [
        (n.name, n.net_bw, n.default_processor,
         [(p.name, p.kind, p.peak_flops, p.local_bw, list(p.affinity))
          for p in n.processors])
        for n in cluster.nodes
    ]
    return _digest(spec)


def dag_fingerprint(dag: "ModelDAG") -> str:
    """A 16-hex-digit digest of a workload's identity: every field the cost
    model prices, so plans cached (or persisted) under this hash can only be
    served back to the exact same workload.

    Memoized per DAG instance (a direct ``__dict__`` write, which a frozen
    dataclass permits and its field-based ``__eq__``/``replace`` ignore) —
    the serving hot path fingerprints on every lookup and must stay at
    dict-access cost."""
    cached = dag.__dict__.get("_fingerprint")
    if cached is None:
        spec = (dag.name, dag.input_bytes, dag.output_bytes,
                [(b.name, b.flops, b.param_bytes, b.bytes_in, b.bytes_out,
                  b.data_splittable, b.halo_fraction, b.kind)
                 for b in dag.blocks])
        cached = _digest(spec)
        dag.__dict__["_fingerprint"] = cached
    return cached
