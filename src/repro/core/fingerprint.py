"""Fingerprinting — the hashes calibration and plan caching key on.

A calibration (and therefore a cached plan frontier) is only valid for the
hardware it was computed against, so both ``CalibrationStore`` paths and
``PlanCache`` keys start with a fingerprint of the cluster's declared
topology: node and processor names, datasheet rates, link bandwidths, and
affinity tables.  Any change to the fleet — a board swapped, a link
upgraded, an affinity retuned — changes the fingerprint and cleanly
invalidates both stores at once.

A cached frontier is likewise only valid for the *workload* it was planned
for, so multi-tenant cache keys carry a :func:`dag_fingerprint` — a digest
of the block DAG's full cost surface (names, FLOPs, byte counts, kinds,
splittability) rather than just its name.  Two tenants that happen to share
a model name but differ in shape can never collide, and editing a model's
blocks orphans its persisted fronts exactly like a board swap orphans
calibrations.

A cached frontier is finally only valid for the *membership* it was planned
over: the planner restricts itself to available nodes (Eq. 4's A(N_φ)), so
a plan computed while a node was away is a different plan than one computed
with it present, even though the declared topology — and therefore the
cluster fingerprint — is unchanged.  :func:`membership_fingerprint` digests
the availability mask over the declared node list, which lets caches file
fronts for distinct memberships *side by side*: a node that leaves and
later returns flips the mask back to a previously-seen value, and the warm
front for that membership serves again with zero DP work
(``repro.fleet`` drives this lifecycle).

Keeping all three hashes here (rather than duplicated in each subsystem) is
what guarantees the key spaces cannot drift apart.
"""

from __future__ import annotations

import hashlib
import json
from typing import TYPE_CHECKING

from .cost_model import Cluster

if TYPE_CHECKING:
    from .dag import ModelDAG


def _digest(spec) -> str:
    return hashlib.sha256(
        json.dumps(spec, sort_keys=True).encode()).hexdigest()[:16]


def cluster_fingerprint(cluster: Cluster) -> str:
    """A 16-hex-digit digest of the cluster's declared topology."""
    spec = [
        (n.name, n.net_bw, n.default_processor,
         [(p.name, p.kind, p.peak_flops, p.local_bw, list(p.affinity))
          for p in n.processors])
        for n in cluster.nodes
    ]
    return _digest(spec)


def membership_fingerprint(cluster: Cluster) -> str:
    """A 16-hex-digit digest of the cluster's availability mask A(N_φ),
    ordered by the declared node list.  Two clusters with the same declared
    topology hash equal under :func:`cluster_fingerprint` whatever their
    availability; this hash separates their *memberships* — the same set of
    nodes away always yields the same digest, so a leave-then-return
    membership maps back onto its original cache entries."""
    return _digest([(n.name, bool(n.available)) for n in cluster.nodes])


def dag_fingerprint(dag: "ModelDAG") -> str:
    """A 16-hex-digit digest of a workload's identity: every field the cost
    model prices, so plans cached (or persisted) under this hash can only be
    served back to the exact same workload.

    Memoized per DAG instance (a direct ``__dict__`` write, which a frozen
    dataclass permits and its field-based ``__eq__``/``replace`` ignore) —
    the serving hot path fingerprints on every lookup and must stay at
    dict-access cost."""
    cached = dag.__dict__.get("_fingerprint")
    if cached is None:
        spec = (dag.name, dag.input_bytes, dag.output_bytes,
                [(b.name, b.flops, b.param_bytes, b.bytes_in, b.bytes_out,
                  b.data_splittable, b.halo_fraction, b.kind)
                 for b in dag.blocks])
        cached = _digest(spec)
        dag.__dict__["_fingerprint"] = cached
    return cached
