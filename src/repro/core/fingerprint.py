"""Cluster fingerprinting — one hash shared by calibration and plan caching.

A calibration (and therefore a cached plan frontier) is only valid for the
hardware it was computed against, so both ``CalibrationStore`` paths and
``PlanCache`` keys start with a fingerprint of the cluster's declared
topology: node and processor names, datasheet rates, link bandwidths, and
affinity tables.  Any change to the fleet — a board swapped, a link
upgraded, an affinity retuned — changes the fingerprint and cleanly
invalidates both stores at once.  Keeping the hash here (rather than
duplicated in each subsystem) is what guarantees the two key spaces cannot
drift apart.
"""

from __future__ import annotations

import hashlib
import json

from .cost_model import Cluster


def cluster_fingerprint(cluster: Cluster) -> str:
    """A 16-hex-digit digest of the cluster's declared topology."""
    spec = [
        (n.name, n.net_bw, n.default_processor,
         [(p.name, p.kind, p.peak_flops, p.local_bw, list(p.affinity))
          for p in n.processors])
        for n in cluster.nodes
    ]
    digest = hashlib.sha256(
        json.dumps(spec, sort_keys=True).encode()).hexdigest()
    return digest[:16]
