"""HiDP core — the paper's contribution, hardware-agnostic.

Public API:
    plan(dag, cluster, config)            — two-tier HiDP planning
    Objective                             — latency | energy | edp (+ budget)
    STRATEGIES                            — hidp / modnn / omniboost / disnet
    EdgeSimulator / simulate              — faithful-reproduction testbed
    paper_cluster / EDGE_MODELS           — Table II devices, §IV workloads
"""

from .cost_model import (ANALYTIC, AnalyticCostProvider,  # noqa: F401
                         Cluster, CostProvider, Node, Processor, Resource,
                         node_as_resource, processors_as_resources,
                         resolve_provider, tpu_chip, tpu_pod)
from .dag import Block, DataPartition, ModelDAG, ModelPartition, chain  # noqa: F401
from .objective import LATENCY, Objective, resolve_objective  # noqa: F401
from .pareto import ParetoFront, ParetoPoint  # noqa: F401
from .fingerprint import (cluster_fingerprint, dag_fingerprint,  # noqa: F401
                          membership_fingerprint)
from .dp_partitioner import (partition, partition_data,  # noqa: F401
                             partition_data_front, partition_front,
                             partition_model, partition_model_front,
                             predicted_energy)
from .global_partitioner import (GlobalPlan, plan_global,  # noqa: F401
                                 plan_global_front)
from .local_partitioner import (LocalPlan, p1_plan, plan_local,  # noqa: F401
                                plan_local_front)
from .hidp import (HiDPPlan, HiDPPlanner, PlannerConfig, plan,  # noqa: F401
                   plan_from_dict, plan_front, plan_to_dict, sub_dag_for)
from .baselines import STRATEGIES, STRATEGY_FRONTS  # noqa: F401
from .scheduler import FollowerFSM, InferenceRequest, LeaderFSM, State  # noqa: F401
from .cluster import ClusterManager, HeartbeatMonitor  # noqa: F401
from .simulator import EdgeSimulator, SimReport, SimRequest, simulate  # noqa: F401
from .edge_models import (EDGE_MODELS, MODEL_DELTA,  # noqa: F401
                          battery_cluster, paper_cluster)
