"""Pareto-frontier plan sets — the planner's first-class output.

PR 2 made energy a planning objective, but every ``(objective, budget)``
variation still paid a full two-tier DP pass.  The pair-(latency, energy) DP
already tracks a frontier internally; this module surfaces it: a planning
pass now returns a :class:`ParetoFront` of plans covering the whole
latency–energy trade-off, and an :class:`~repro.core.objective.Objective`
becomes a *selector* over that front (feasible-first under
``latency_budget``, then metric-optimal) instead of a scalarizer baked into
the DP.  Plan the frontier once per ``(cluster, calibration, dag)``, then
serve any objective from cache (``repro.serving.plan_cache.PlanCache``)
until a drift event invalidates it — the CoEdge/DEFER amortization the
paper's ~15 ms per-request overhead otherwise forfeits.

Invariants every :class:`ParetoFront` maintains:

* points are sorted by latency ascending, energy strictly decreasing —
  no point is dominated by another (lower-or-equal latency *and* energy);
* on exact ``(latency, energy)`` ties the earliest-inserted candidate wins,
  so builders can splice a canonical plan (the seed scalar-DP latency
  optimum) ahead of DP-discovered duplicates and guarantee it survives;
* ``select`` is deterministic: ``Objective.key`` totally orders the points
  and ties fall to the lower-latency (earlier) point.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Iterable, Iterator, Sequence

from .objective import Objective, resolve_objective

# Builders cap per-cell DP frontiers (and composed fronts) at this many
# points; interior points with the smallest latency gap are thinned first,
# so the endpoints — latency-optimal and energy-optimal — always survive.
DEFAULT_FRONT_WIDTH = 16


@dataclasses.dataclass(frozen=True)
class ParetoPoint:
    """One non-dominated plan with its (latency, energy) price."""

    latency: float
    energy: float
    plan: Any

    def key(self, objective: Objective) -> tuple:
        return objective.key(self.latency, self.energy)

    def dominates(self, other: "ParetoPoint") -> bool:
        """Weak dominance: no worse on both axes, strictly better on one."""
        return (self.latency <= other.latency and self.energy <= other.energy
                and (self.latency < other.latency
                     or self.energy < other.energy))

    # -------------------------------------------------------- serialization
    def to_dict(self, encode_plan: Callable[[Any], Any] = lambda p: p
                ) -> dict:
        """A JSON-able view; ``encode_plan`` serializes the plan payload
        (e.g. ``repro.core.hidp.plan_to_dict`` for :class:`HiDPPlan`)."""
        return {"latency": self.latency, "energy": self.energy,
                "plan": encode_plan(self.plan)}

    @classmethod
    def from_dict(cls, d: dict,
                  decode_plan: Callable[[Any], Any] = lambda p: p
                  ) -> "ParetoPoint":
        return cls(latency=d["latency"], energy=d["energy"],
                   plan=decode_plan(d["plan"]))


class ParetoFront:
    """An immutable, sorted, non-dominated set of plans.

    Construct with :meth:`build` (which prunes dominated candidates) rather
    than the raw constructor; the constructor trusts its input.
    """

    __slots__ = ("points",)

    def __init__(self, points: Sequence[ParetoPoint]):
        if not points:
            raise ValueError("a ParetoFront needs at least one point")
        self.points = tuple(points)

    # ------------------------------------------------------------- building
    @classmethod
    def build(cls, candidates: Iterable[ParetoPoint | tuple],
              *, anchor: ParetoPoint | tuple | None = None,
              width: int | None = None) -> "ParetoFront":
        """Skyline-filter ``candidates`` (points or ``(lat, en, plan)``
        tuples) into a front.  Insertion order is the tie-break: the first
        candidate at an exact ``(latency, energy)`` tie is kept.  ``width``
        caps the front size (endpoints always survive thinning).

        ``anchor`` pins the latency endpoint to a canonical plan — the seed
        scalar-DP optimum: every candidate at or below the anchor's latency
        is discarded, deliberately including candidates whose latency is
        *strictly* lower.  Such candidates only arise when a downstream
        re-pricing (the hierarchical re-cost) disagrees with the tier the
        anchor was optimal in; the seed planner commits at that tier and
        never finds them, and the API contract — ``latency_optimal``
        reproduces the seed plan bit-identically, selection under the
        default objective is the seed pass — outranks an opportunistic
        re-costing win at the endpoint."""
        pts = [c if isinstance(c, ParetoPoint) else ParetoPoint(*c)
               for c in candidates]
        if anchor is not None:
            a = anchor if isinstance(anchor, ParetoPoint) \
                else ParetoPoint(*anchor)
            pts = [a] + [p for p in pts if p.latency > a.latency]
        if not pts:
            raise ValueError("no candidates to build a ParetoFront from")
        # stable sort: equal (lat, en) keeps the earlier candidate first
        pts.sort(key=lambda p: (p.latency, p.energy))
        front: list[ParetoPoint] = []
        best_en = float("inf")
        for p in pts:
            if p.energy < best_en:
                front.append(p)
                best_en = p.energy
        if width is not None:
            front = _thin(front, width)
        return cls(front)

    # ------------------------------------------------------------ accessors
    @property
    def latency_optimal(self) -> ParetoPoint:
        """The fastest plan — for frontier DPs built here, bit-identical to
        the seed's scalar latency DP (the builder splices it in first)."""
        return self.points[0]

    @property
    def energy_optimal(self) -> ParetoPoint:
        return self.points[-1]

    def __len__(self) -> int:
        return len(self.points)

    def __iter__(self) -> Iterator[ParetoPoint]:
        return iter(self.points)

    def plans(self) -> tuple:
        return tuple(p.plan for p in self.points)

    # ------------------------------------------------------------ selection
    def select_point(self, objective: Objective | None = None) -> ParetoPoint:
        """The objective as a selector: feasible-first under the budget,
        then metric-optimal — ``Objective.key`` encodes exactly that order,
        and among infeasible points lower latency wins, so a front whose
        fastest point misses the budget still returns its fastest plan."""
        obj = resolve_objective(objective)
        return min(self.points, key=lambda p: p.key(obj))

    def select(self, objective: Objective | None = None):
        return self.select_point(objective).plan

    # -------------------------------------------------------- serialization
    def to_dict(self, encode_plan: Callable[[Any], Any] = lambda p: p
                ) -> dict:
        """JSON round-trip out: the sorted point list, plans encoded by
        ``encode_plan``.  ``from_dict(to_dict(f))`` rebuilds a front whose
        selections are bit-identical to the original's — floats survive the
        trip exactly (JSON uses shortest round-trippable reprs) and order
        is preserved, so ``select`` walks the same points in the same
        order."""
        return {"points": [p.to_dict(encode_plan) for p in self.points]}

    @classmethod
    def from_dict(cls, d: dict,
                  decode_plan: Callable[[Any], Any] = lambda p: p
                  ) -> "ParetoFront":
        """Rebuild a persisted front.  Trusts the stored order (the writer
        held the invariants), like the raw constructor."""
        return cls([ParetoPoint.from_dict(p, decode_plan)
                    for p in d["points"]])

    # ----------------------------------------------------------- invariants
    def dominated(self, latency: float, energy: float) -> bool:
        """True iff some front point strictly beats ``(latency, energy)``
        on one axis and is no worse on the other."""
        probe = ParetoPoint(latency, energy, None)
        return any(p.dominates(probe) for p in self.points)

    def __repr__(self) -> str:
        lo, hi = self.points[0], self.points[-1]
        return (f"ParetoFront({len(self.points)} points, "
                f"lat [{lo.latency:.4g}, {hi.latency:.4g}] s, "
                f"en [{hi.energy:.4g}, {lo.energy:.4g}] J)")


def _thin(front: list[ParetoPoint], width: int) -> list[ParetoPoint]:
    """Cap a sorted front at ``width`` points, dropping interior points with
    the smallest latency gap to their predecessor (endpoints survive)."""
    while len(front) > max(width, 2):
        i = min(range(1, len(front) - 1),
                key=lambda k: front[k].latency - front[k - 1].latency)
        del front[i]
    return front


def pareto_filter(states: list[tuple], state: tuple,
                  cap: int = DEFAULT_FRONT_WIDTH) -> list[tuple]:
    """Insert ``state`` (``(lat, en, ...payload)``) into a sorted
    non-dominated state list — the per-cell frontier op of the DP searches.
    Existing points win ties (first-inserted preference).  Returns the
    original list unchanged when ``state`` is dominated.  Like
    :func:`_thin`, the cap floors at 2 so both endpoints always survive
    (``cap=1`` would otherwise leave no interior point to drop)."""
    lat, en = state[0], state[1]
    for s in states:
        if s[0] <= lat and s[1] <= en:
            return states                       # dominated (or an exact tie)
    out = [s for s in states if not (lat <= s[0] and en <= s[1])]
    out.append(state)
    out.sort(key=lambda s: (s[0], s[1]))
    if len(out) > max(cap, 2):
        i = min(range(1, len(out) - 1),
                key=lambda k: out[k][0] - out[k - 1][0])
        del out[i]
    return out
