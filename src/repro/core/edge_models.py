"""The paper's four evaluation workloads as analytic block DAGs, plus the
Table II edge-device specifications.

ResNet-152, VGG-19, InceptionNet-V3 and EfficientNet-B0 are built
programmatically from their published layer hyper-parameters; block FLOPs are
2·MACs, activations are float32.  Partitionable blocks follow the paper's
granularity ("layers are dynamically grouped into executable blocks"): one
block per residual/bottleneck block, VGG conv stage, Inception mixed block or
MBConv stage — 20–60 blocks per model, matching the DP's O(n·m) scale.

Device peak-FLOPs figures are sustained-CNN estimates for the boards in
Table II (not datasheet peaks): they reproduce the paper's qualitative
landscape — Orin ≫ TX2 > Nano ≫ RPi5 > RPi4, GPU:CPU ratios of 3–10×, and
GPU-unfriendly depthwise convolutions (the Fig. 1 "P1 is never optimal"
effect and EfficientNet's 50/50 optimal split).
"""

from __future__ import annotations

import dataclasses
import math

from .cost_model import Cluster, Node, Processor
from .dag import Block, ModelDAG, chain

BYTES = 4  # float32 activations


# --------------------------------------------------------------------------
# Block builders
# --------------------------------------------------------------------------

def conv_flops(h: int, w: int, cin: int, cout: int, k: int, stride: int = 1,
               groups: int = 1) -> tuple[float, int, int]:
    ho, wo = math.ceil(h / stride), math.ceil(w / stride)
    f = 2.0 * ho * wo * cout * (cin // groups) * k * k
    return f, ho, wo


def dense_flops(n_in: int, n_out: int) -> float:
    return 2.0 * n_in * n_out


def _block(name, kind, flops, params, h, w, cin, ho, wo, cout,
           halo=0.0, splittable=True) -> Block:
    return Block(name=name, kind=kind, flops=flops,
                 param_bytes=params * BYTES,
                 bytes_in=h * w * cin * BYTES,
                 bytes_out=ho * wo * cout * BYTES,
                 data_splittable=splittable, halo_fraction=halo)


# --------------------------------------------------------------------------
# ResNet-152  (224×224, bottleneck counts [3, 8, 36, 3])
# --------------------------------------------------------------------------

def resnet152() -> ModelDAG:
    blocks: list[Block] = []
    h = w = 224
    # stem: 7x7/2 conv 64 + 3x3/2 maxpool
    f, h, w = conv_flops(h, w, 3, 64, 7, 2)
    blocks.append(_block("stem", "conv", f, 3 * 64 * 49, 224, 224, 3,
                         h // 2, w // 2, 64, halo=0.06))
    h, w = h // 2, w // 2
    cin = 64
    stage_cfg = [(256, 3, 1), (512, 8, 2), (1024, 36, 2), (2048, 3, 2)]
    for si, (cout, reps, stride) in enumerate(stage_cfg):
        mid = cout // 4
        for r in range(reps):
            s = stride if r == 0 else 1
            f1, _, _ = conv_flops(h, w, cin, mid, 1)
            f2, ho, wo = conv_flops(h, w, mid, mid, 3, s)
            f3, _, _ = conv_flops(ho, wo, mid, cout, 1)
            fs = conv_flops(h, w, cin, cout, 1, s)[0] if (r == 0) else 0.0
            params = cin * mid + mid * mid * 9 + mid * cout + (
                cin * cout if r == 0 else 0)
            blocks.append(_block(f"res{si}_{r}", "conv", f1 + f2 + f3 + fs,
                                 params, h, w, cin, ho, wo, cout, halo=0.03))
            h, w, cin = ho, wo, cout
    # head: GAP + fc1000
    blocks.append(_block("head", "dense", dense_flops(2048, 1000),
                         2048 * 1000, h, w, 2048, 1, 1, 1000,
                         splittable=True))
    return chain("resnet152", blocks, 224 * 224 * 3 * BYTES, 1000 * BYTES)


# --------------------------------------------------------------------------
# VGG-19  (224×224, 16 conv + 3 FC)
# --------------------------------------------------------------------------

def vgg19() -> ModelDAG:
    cfg = [(64, 2), (128, 2), (256, 4), (512, 4), (512, 4)]
    blocks: list[Block] = []
    h = w = 224
    cin = 3
    for si, (cout, reps) in enumerate(cfg):
        f_total, params = 0.0, 0
        h_in, w_in, cin_in = h, w, cin
        for r in range(reps):
            f, _, _ = conv_flops(h, w, cin, cout, 3)
            f_total += f
            params += cin * cout * 9
            cin = cout
        h, w = h // 2, w // 2          # maxpool closes the stage
        blocks.append(_block(f"vgg{si}", "conv", f_total, params,
                             h_in, w_in, cin_in, h, w, cout, halo=0.05))
    blocks.append(_block("fc1", "dense", dense_flops(7 * 7 * 512, 4096),
                         7 * 7 * 512 * 4096, 7, 7, 512, 1, 1, 4096))
    blocks.append(_block("fc2", "dense", dense_flops(4096, 4096), 4096 * 4096,
                         1, 1, 4096, 1, 1, 4096))
    blocks.append(_block("fc3", "dense", dense_flops(4096, 1000), 4096 * 1000,
                         1, 1, 4096, 1, 1, 1000))
    return chain("vgg19", blocks, 224 * 224 * 3 * BYTES, 1000 * BYTES)


# --------------------------------------------------------------------------
# InceptionNet-V3  (299×299, simplified mixed blocks with published shapes)
# --------------------------------------------------------------------------

def inceptionv3() -> ModelDAG:
    blocks: list[Block] = []
    # stem: 3 convs + pool + 2 convs + pool → 35×35×192
    stem_f = 0.0
    f, h, w = conv_flops(299, 299, 3, 32, 3, 2); stem_f += f
    f, h, w = conv_flops(h, w, 32, 32, 3); stem_f += f
    f, h, w = conv_flops(h, w, 32, 64, 3); stem_f += f
    h, w = h // 2, w // 2
    f, _, _ = conv_flops(h, w, 64, 80, 1); stem_f += f
    f, h, w = conv_flops(h, w, 80, 192, 3); stem_f += f
    h, w = h // 2, w // 2
    blocks.append(_block("stem", "conv", stem_f, 9.2e5, 299, 299, 3,
                         h, w, 192, halo=0.04))
    # (h,w) now 35×35. Mixed blocks: (grid, cout, approx GMACs each)
    mixed = [("m35", 35, 288, 3, 0.30), ("m17", 17, 768, 5, 0.42),
             ("m8", 8, 2048, 2, 0.58)]
    cin = 192
    for name, grid, cout, reps, gmacs in mixed:
        for r in range(reps):
            c_in = cin if r == 0 else cout
            h_in = h if r == 0 else grid
            blocks.append(_block(f"{name}_{r}", "conv", gmacs * 2e9,
                                 gmacs * 2e9 / (2 * grid * grid) / 4,
                                 h_in, h_in, c_in, grid, grid, cout,
                                 halo=0.04))
        cin, h = cout, grid
    blocks.append(_block("head", "dense", dense_flops(2048, 1000),
                         2048 * 1000, 8, 8, 2048, 1, 1, 1000))
    return chain("inceptionv3", blocks, 299 * 299 * 3 * BYTES, 1000 * BYTES,
                 validate=False)  # mixed-block byte edges are approximations


# --------------------------------------------------------------------------
# EfficientNet-B0  (224×224, MBConv stages; heavy depthwise share)
# --------------------------------------------------------------------------

def efficientnet_b0() -> ModelDAG:
    # stage: (expansion, cout, reps, stride, k)
    cfg = [(1, 16, 1, 1, 3), (6, 24, 2, 2, 3), (6, 40, 2, 2, 5),
           (6, 80, 3, 2, 3), (6, 112, 3, 1, 5), (6, 192, 4, 2, 5),
           (6, 320, 1, 1, 3)]
    blocks: list[Block] = []
    f, h, w = conv_flops(224, 224, 3, 32, 3, 2)
    blocks.append(_block("stem", "conv", f, 3 * 32 * 9, 224, 224, 3,
                         h, w, 32, halo=0.05))
    cin = 32
    for si, (exp, cout, reps, stride, k) in enumerate(cfg):
        for r in range(reps):
            s = stride if r == 0 else 1
            mid = cin * exp
            fe = conv_flops(h, w, cin, mid, 1)[0] if exp != 1 else 0.0
            fd, ho, wo = conv_flops(h, w, mid, mid, k, s, groups=mid)
            fp, _, _ = conv_flops(ho, wo, mid, cout, 1)
            params = cin * mid + mid * k * k + mid * cout
            # depthwise FLOPs dominate runtime on GPU → mark the block dwconv
            blocks.append(_block(f"mb{si}_{r}", "dwconv", fe + fd + fp, params,
                                 h, w, cin, ho, wo, cout, halo=0.04))
            h, w, cin = ho, wo, cout
    f, _, _ = conv_flops(h, w, 320, 1280, 1)
    blocks.append(_block("headconv", "conv", f, 320 * 1280, h, w, 320,
                         h, w, 1280))
    blocks.append(_block("fc", "dense", dense_flops(1280, 1000), 1280 * 1000,
                         h, w, 1280, 1, 1, 1000))
    return chain("efficientnet_b0", blocks, 224 * 224 * 3 * BYTES,
                 1000 * BYTES)


EDGE_MODELS = {
    "resnet152": resnet152,
    "vgg19": vgg19,
    "inceptionv3": inceptionv3,
    "efficientnet_b0": efficientnet_b0,
}


# --------------------------------------------------------------------------
# Table II devices.  Affinity rows implement the paper's "CPU-friendly layer"
# effect: GPUs run depthwise convs at ~1/3 efficiency, dense layers at ~0.7.
# --------------------------------------------------------------------------

_GPU_AFF = (("dwconv", 0.35), ("dense", 0.7), ("mixed", 0.9))
_CPU_AFF = (("conv", 0.9), ("dwconv", 1.0), ("dense", 1.0), ("mixed", 0.9))
LOCAL_BW = 12e9            # CPU↔GPU shared-DRAM copy bandwidth (bytes/s)
WIRELESS_BW = 80e6         # paper: 80 MBps wireless


def _node(name: str, cpu_flops: float, gpu_flops: float, cpu_w: float,
          gpu_w: float, idle_w: float, default: str = "gpu") -> Node:
    return Node(name=name, processors=(
        Processor(name="cpu", kind="cpu", peak_flops=cpu_flops,
                  local_bw=LOCAL_BW, idle_power=idle_w / 2,
                  active_power=cpu_w, affinity=_CPU_AFF),
        Processor(name="gpu", kind="gpu", peak_flops=gpu_flops,
                  local_bw=LOCAL_BW, idle_power=idle_w / 2,
                  active_power=gpu_w, affinity=_GPU_AFF),
    ), net_bw=WIRELESS_BW, default_processor=default)


# Power model: whole-board static power dominates (SoC rails, DRAM, radio —
# what the on-board INA sensors meter), with modest per-processor deltas on
# top; this is what makes energy track latency in Fig. 5 (the paper: "lowest
# inference latency ... also reflects in the lowest energy consumption").

def jetson_orin_nx() -> Node:   # 8×A78 + 1024-core Ampere (CUDA default)
    return _node("orin_nx", 2.4e11, 1.1e12, 2.5, 8.0, 10.0)


def jetson_tx2() -> Node:       # 2×Denver2 + 4×A57 + 256-core Pascal
    return _node("tx2", 7.5e10, 3.2e11, 2.0, 5.0, 7.0)


def jetson_nano() -> Node:      # 4×A57 + 128-core Maxwell
    return _node("nano", 3.2e10, 1.2e11, 1.5, 3.5, 5.0)


def rpi5() -> Node:             # 2×A76 + VideoCore VII (no usable GPU default)
    return _node("rpi5", 3.2e10, 2.2e10, 2.0, 1.5, 4.0, default="cpu")


def rpi4() -> Node:             # 2×A72 + VideoCore VI (no usable GPU default)
    return _node("rpi4", 1.4e10, 1.1e10, 1.5, 1.2, 3.2, default="cpu")


def paper_cluster(n_nodes: int = 5) -> Cluster:
    """The paper's evaluation cluster, optionally truncated (Fig. 8 uses
    2–5 nodes, dropped slowest-first so the leader Orin always remains)."""
    all_nodes = (jetson_orin_nx(), jetson_tx2(), jetson_nano(), rpi5(), rpi4())
    return Cluster(nodes=all_nodes[:n_nodes])


def battery_cluster(n_nodes: int = 5, idle_scale: float = 0.05) -> Cluster:
    """The same boards deployed duty-cycled (battery/solar fleets with
    aggressive sleep states): idle draw shrinks to ``idle_scale`` of the
    wall-powered figures while active power is unchanged.

    On the wall-powered :func:`paper_cluster`, static power dominates and
    energy simply tracks latency (the paper: "lowest inference latency ...
    also reflects in the lowest energy consumption") — so the
    latency-optimal plan is already energy-optimal.  Duty-cycling breaks
    that degeneracy: active joules dominate, and roping slow helpers into a
    wide data split costs real energy for marginal speedup.  This is the
    regime where ``Objective("energy")`` / ``Objective("edp")`` planning
    pays off (see ``benchmarks/fig5_latency_energy.py --objective``)."""
    base = paper_cluster(n_nodes)
    return Cluster(nodes=tuple(
        dataclasses.replace(n, processors=tuple(
            dataclasses.replace(p, idle_power=p.idle_power * idle_scale)
            for p in n.processors))
        for n in base.nodes))


# Per-model compute intensity δ [cycles/flop] — calibrates absolute latency to
# the paper's Fig. 5 ranges (hundreds of ms).  Relative values follow each
# model's arithmetic-intensity profile (EffNet's depthwise convs have the
# worst locality; VGG's dense 3×3 convs the best).
MODEL_DELTA = {
    "resnet152": 70.0,
    "vgg19": 55.0,
    "inceptionv3": 80.0,
    "efficientnet_b0": 140.0,
}
