"""PlannerWorkspace — memoized setup and DP row reuse for the fast planner.

The DP partitioner's cold pass re-derives the same intermediate state over
and over: the heterogeneity order and per-resource prefix sums are rebuilt
on every ``partition_*`` call, the scalar seed DP inside a frontier pass
re-solves exactly the subproblems the frontier DP just visited, and a
membership epoch re-solves every survivor's rows from scratch.  This module
is the shared scratch space that stops all of that:

* **setup memos** — the heterogeneity order, per-resource segment-cost /
  energy matrices and comm vectors, keyed by ``(dag fingerprint,
  resources)``, so a frontier sweep builds each prefix sum once;
* **DP row caches** — the scalar DP's per-resource rows
  ``(dp, best, bestj, parent)`` and the frontier DP's per-resource cell
  rows, keyed by ``(dag fingerprint, flags, ordered-resource *prefix*)``.
  Row *j* of either DP depends only on the first *j* resources in
  heterogeneity order, so when a membership epoch removes a node at
  position *k*, every row before *k* is byte-for-byte reusable — the
  departure invalidates only the rows that used it.  ``rows_computed`` /
  ``rows_reused`` count exactly this (the tab1 incremental-replan gate
  reads them);
* **result memos** — whole ``partition_model`` / front-search /
  data-candidate / local-front results, so the duplicated sub-calls of a
  hierarchical pass (the seed anchor inside ``partition_model_front``, the
  scalar re-plan inside ``plan_local_front``, …) collapse to one solve.

Workspaces are keyed **per cost provider**: the analytic provider (a
stateless singleton) shares one process-wide workspace; any other provider
gets its own, anchored weakly on the provider's fitted ``model`` when it
has one (so ``at_delta`` rebinds — which create fresh provider objects
around the same model — keep hitting the same rows) and dropped when the
model is garbage-collected.  A provider whose model carries a ``revision``
counter (``repro.profiling.LearnedCostModel`` bumps it on every
``observe``/``fit``) invalidates its workspace automatically on refit:
stale rows can never price a plan after the calibration moved.

Everything cached here is immutable once inserted (numpy rows are never
written after publication; frontier states are tuples), so sharing across
calls — and across the ``PlanCache`` pre-warm path — is safe by
construction.  All caches are bounded LRU; ``reset_workspaces()`` clears
every workspace (benchmarks use it to measure genuinely cold passes).
"""

from __future__ import annotations

from collections import OrderedDict
from typing import Sequence
from weakref import WeakKeyDictionary

import numpy as np

# Per-table entry bound.  Entries are small (a few KB: one (n+1)² float64
# matrix per resource, n ≤ ~200 blocks), so this caps a workspace well
# under typical plan-cache budgets while keeping every live tenant warm.
MAX_ENTRIES = 1024


class _LRU:
    """A bounded, insertion-refreshing mapping (oldest evicted first)."""

    __slots__ = ("cap", "data")

    def __init__(self, cap: int = MAX_ENTRIES):
        self.cap = cap
        self.data: OrderedDict = OrderedDict()

    def get(self, key):
        val = self.data.get(key)
        if val is not None:
            self.data.move_to_end(key)
        return val

    def put(self, key, val) -> None:
        self.data[key] = val
        self.data.move_to_end(key)
        while len(self.data) > self.cap:
            self.data.popitem(last=False)

    def __len__(self) -> int:
        return len(self.data)

    def clear(self) -> None:
        self.data.clear()


class PlannerWorkspace:
    """One provider's memo space for the fast DP engine.

    Attributes:
        orders: ``(dag_fp, resources) → (ordered resources, index order)``.
        arrays: ``(dag_fp, resource, tag, …) → ndarray`` — comm vectors,
            segment cost/energy matrices, weight-transfer matrices.
        scalar_rows: ``(dag_fp, weight_transfer, prefix) → (dp, best,
            bestj, parent)`` numpy rows of the scalar latency DP.
        front_rows: ``(dag_fp, weight_transfer, radio, cap, prefix) →
            (dp_cells, best_cells)`` frontier DP rows.
        results: whole-call memo (partitions, fronts, data candidates).
        rows_computed / rows_reused: lifetime DP row counters — the
            incremental-replan currency (cold pass: all computed; epoch
            re-plan: only rows at/after the departed node's position).
        revision: the provider model revision these entries were built
            against (None for stateless providers).
    """

    __slots__ = ("orders", "arrays", "scalar_rows", "front_rows", "results",
                 "rows_computed", "rows_reused", "revision", "_masks")

    def __init__(self):
        self.orders = _LRU()
        self.arrays = _LRU()
        self.scalar_rows = _LRU()
        self.front_rows = _LRU()
        self.results = _LRU()
        self.rows_computed = 0
        self.rows_reused = 0
        self.revision = None
        self._masks: dict[int, np.ndarray] = {}

    # ------------------------------------------------------------- helpers
    def valid_mask(self, n: int) -> np.ndarray:
        """The strict upper-triangular (s < i) validity mask shared by every
        (n+1)×(n+1) DP transition matrix."""
        mask = self._masks.get(n)
        if mask is None:
            mask = np.triu(np.ones((n + 1, n + 1), dtype=bool), k=1)
            if len(self._masks) > 32:
                self._masks.clear()
            self._masks[n] = mask
        return mask

    def clear(self) -> None:
        self.orders.clear()
        self.arrays.clear()
        self.scalar_rows.clear()
        self.front_rows.clear()
        self.results.clear()
        self._masks.clear()

    def reset_counters(self) -> None:
        self.rows_computed = 0
        self.rows_reused = 0

    def stats(self) -> dict:
        return {"rows_computed": self.rows_computed,
                "rows_reused": self.rows_reused,
                "orders": len(self.orders), "arrays": len(self.arrays),
                "scalar_rows": len(self.scalar_rows),
                "front_rows": len(self.front_rows),
                "results": len(self.results)}


# The analytic provider is a stateless singleton — one shared workspace.
_ANALYTIC_WS = PlannerWorkspace()
# Other providers anchor weakly on their fitted model (or themselves):
# anchor → {sub-key → PlannerWorkspace}.
_PROVIDER_WS: "WeakKeyDictionary" = WeakKeyDictionary()
_MAX_PER_ANCHOR = 16


def workspace_for(provider) -> PlannerWorkspace | None:
    """The workspace serving ``provider`` — None when the provider cannot
    be safely cached against (unhashable / not weak-referenceable), which
    sends the caller down the uncached-but-still-vectorized path."""
    from .cost_model import ANALYTIC
    if provider is None or provider is ANALYTIC:
        return _ANALYTIC_WS
    anchor = getattr(provider, "model", None)
    if anchor is None:
        anchor = provider
    try:
        per = _PROVIDER_WS.get(anchor)
    except TypeError:
        return None
    if per is None:
        per = OrderedDict()
        try:
            _PROVIDER_WS[anchor] = per
        except TypeError:
            return None
    # δ-rebound providers around the same model each get their own rows
    # (rates differ per δ); the model anchor keeps them alive together
    sub = (type(provider).__name__, getattr(provider, "delta", None))
    ws = per.get(sub)
    if ws is None:
        ws = PlannerWorkspace()
        per[sub] = ws
        while len(per) > _MAX_PER_ANCHOR:
            per.popitem(last=False)
    # a refit model (revision bump) orphans every cached row
    rev = getattr(anchor, "revision", None)
    if rev != ws.revision:
        ws.clear()
        ws.revision = rev
    return ws


def reset_workspaces() -> None:
    """Drop every cached row/memo (cold-start; benchmarks and tests)."""
    _ANALYTIC_WS.clear()
    _ANALYTIC_WS.reset_counters()
    for per in list(_PROVIDER_WS.values()):
        for ws in per.values():
            ws.clear()
            ws.reset_counters()


def single_departure_masks(cluster) -> list[tuple[bool, ...]]:
    """The likely next memberships: the current availability mask with one
    available node flipped down (never emptying the fleet) — what
    ``PlanCache.prewarm`` speculates over, ordered by the declared node
    list so the speculation schedule is deterministic."""
    mask = tuple(bool(n.available) for n in cluster.nodes)
    if sum(mask) <= 1:
        return []
    out = []
    for i, up in enumerate(mask):
        if up:
            out.append(tuple(m if k != i else False
                             for k, m in enumerate(mask)))
    return out


def heterogeneity_order(ws: PlannerWorkspace | None, dag, resources, prov,
                        dag_fp: str | None = None):
    """Cached heterogeneity-descending resource order (the seed's
    ``_heterogeneity_order``), keyed by ``(dag fingerprint, resources)``."""
    from .dp_partitioner import _heterogeneity_order
    if ws is None:
        return _heterogeneity_order(dag, resources, prov)
    from .fingerprint import dag_fingerprint
    key = (dag_fp or dag_fingerprint(dag), tuple(resources))
    got = ws.orders.get(key)
    if got is None:
        got = _heterogeneity_order(dag, resources, prov)
        ws.orders.put(key, got)
    return got
