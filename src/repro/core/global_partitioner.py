"""Tier-1 (global) partitioner — Algorithm 1 lines 3-7.

The leader node collapses every *available* node to a Resource (Λ_j, β_j),
consults the DSE agent (the DP search in ``dp_partitioner``) for both modes,
and picks Θ = min(Θ_ω, Θ_σ).  The output maps sub-workloads to nodes; the
sub-workload that lands on a node is then re-partitioned locally (tier 2).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

from .cost_model import (Cluster, CostProvider, Node, Resource,
                         node_as_resource, resolve_provider)
from .dag import DataPartition, ModelDAG, ModelPartition, Partition
from .dp_cache import workspace_for
from .fingerprint import dag_fingerprint
from .objective import Objective
from .pareto import ParetoFront, ParetoPoint
from . import dp_partitioner


@dataclasses.dataclass(frozen=True)
class GlobalAssignment:
    """One node's share of the request after global partitioning."""

    node: Node
    # Model mode: the contiguous block range this node executes.
    block_range: tuple[int, int] | None = None
    # Data mode: the fraction of the request's data this node executes.
    fraction: float | None = None
    # Position in the pipeline (model mode) for ordering transfers.
    stage_index: int = 0


@dataclasses.dataclass(frozen=True)
class GlobalPlan:
    mode: str                            # "model" | "data"
    partition: Partition
    assignments: tuple[GlobalAssignment, ...]
    predicted_latency: float
    predicted_energy: float


def plan_global(dag: ModelDAG, cluster: Cluster, *, delta: float = 1.0,
                weight_transfer: bool = False,
                capacity: str = "sum",
                provider: CostProvider | None = None,
                objective: Objective | None = None) -> GlobalPlan:
    """Tier-1 planning pass: collapse available nodes to (Λ_j, β_j)
    Resources, run the DP at the given ``objective``, and map the winning
    partition back onto nodes."""
    nodes = cluster.available_nodes()
    if not nodes:
        raise RuntimeError("no available nodes in cluster (A(N_φ) all-zero)")
    resources = [node_as_resource(n, delta, capacity=capacity) for n in nodes]
    plan = dp_partitioner.partition(dag, resources,
                                    weight_transfer=weight_transfer,
                                    provider=provider, objective=objective)
    # report energy with the objective's radio term so the figure quoted in
    # GlobalPlan matches what the DP minimized (0 under the default
    # objective — the seed algebra)
    radio = objective.radio_power if objective is not None else 0.0
    energy = dp_partitioner.predicted_energy(dag, resources, plan, provider,
                                             radio_power=radio)
    return _as_global_plan(plan, nodes, energy)


def _as_global_plan(plan: Partition, nodes: Sequence[Node],
                    energy: float) -> GlobalPlan:
    """Map a winning Partition back onto cluster nodes."""
    assignments: list[GlobalAssignment] = []
    if isinstance(plan, ModelPartition):
        for si in range(plan.num_stages):
            a, b = plan.boundaries[si], plan.boundaries[si + 1]
            assignments.append(GlobalAssignment(
                node=nodes[plan.assignment[si]], block_range=(a, b),
                stage_index=si))
        mode = "model"
    else:
        assert isinstance(plan, DataPartition)
        for si, (f, ri) in enumerate(zip(plan.fractions, plan.assignment)):
            assignments.append(GlobalAssignment(
                node=nodes[ri], fraction=f, stage_index=si))
        mode = "data"
    return GlobalPlan(mode=mode, partition=plan,
                      assignments=tuple(assignments),
                      predicted_latency=plan.predicted_latency,
                      predicted_energy=energy)


def plan_global_front(dag: ModelDAG, cluster: Cluster, *, delta: float = 1.0,
                      weight_transfer: bool = False,
                      capacity: str = "sum",
                      provider: CostProvider | None = None,
                      radio_power: float = 0.0,
                      width: int | None = None) -> ParetoFront:
    """Tier-1 frontier: every non-dominated (latency, energy) trade-off over
    both partitioning modes, mapped onto nodes as :class:`GlobalPlan`\\ s.

    The front's ``latency_optimal`` plan is exactly what :func:`plan_global`
    returns under the default objective (the seed DP, bit-identical);
    ``radio_power`` prices wireless transfer seconds into every point's
    energy, matching what a scalarized pass would have minimized."""
    nodes = cluster.available_nodes()
    if not nodes:
        raise RuntimeError("no available nodes in cluster (A(N_φ) all-zero)")
    prov = resolve_provider(provider)
    ws = (workspace_for(prov)
          if dp_partitioner.get_engine() == "fast" else None)
    if ws is not None:
        # Keyed on the available-node tuple (frozen dataclasses), so distinct
        # membership masks memo side by side and a warm tier-1 pass skips the
        # Resource collapse and GlobalPlan mapping entirely.
        rkey = ("pgf", dag_fingerprint(dag), tuple(nodes), delta,
                weight_transfer, capacity, radio_power, width)
        memo = ws.results.get(rkey)
        if memo is not None:
            return memo
    resources = [node_as_resource(n, delta, capacity=capacity) for n in nodes]
    pf = dp_partitioner.partition_front(dag, resources,
                                        weight_transfer=weight_transfer,
                                        provider=prov,
                                        radio_power=radio_power, width=width)
    front = ParetoFront([
        ParetoPoint(p.latency, p.energy,
                    _as_global_plan(p.plan, nodes, p.energy))
        for p in pf])
    if ws is not None:
        ws.results.put(rkey, front)
    return front
