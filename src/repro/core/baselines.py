"""State-of-the-art baselines the paper compares against (§IV-A).

* **MoDNN** (Mao et al., DATE'17) — data partitioning only: input split
  proportionally to node compute capacity; no local tier (framework-default
  single-processor execution = config P1).  Implemented, per the paper, "using
  the data partitioning module of HiDP".  MoDNN partitions feature maps
  one-dimensionally *per layer*, so partitions exchange boundary rows at every
  layer over the wireless medium — its dominant overhead, modelled explicitly.

* **OmniBoost** (Karatzas et al., DAC'23) — model/pipeline partitioning with a
  Monte-Carlo tree search over cut points and a learned throughput estimator.
  We implement the MCTS over the same analytic cost model (our stand-in for
  their trained estimator) with a fixed rollout budget; it optimises pipeline
  *throughput* (max stage time), which is exactly why it cedes latency to
  HiDP.  Locally it pipelines over CPU+GPU (model-mode local split).

* **DisNet** (Samikwa et al., IoT-J'24) — hybrid partitioning (both modes,
  chosen heuristically at the *global* level only), no fine-grained local
  control: per the paper we reuse HiDP's global data+model partitioning and
  pin the local tier to P1.

All strategies share the HiDPPlan output type so the simulator and benchmarks
treat them uniformly.
"""

from __future__ import annotations

import dataclasses
import random
import time
from typing import Callable

from .cost_model import (Cluster, CostProvider, node_as_resource,
                         resolve_provider)
from .dag import DataPartition, ModelDAG, ModelPartition
from .dp_partitioner import partition_data, partition_model, predicted_energy
from .global_partitioner import GlobalAssignment, GlobalPlan
from .hidp import (HiDPPlan, PlannerConfig, _hierarchical_cost, plan,
                   plan_front, sub_dag_for)
from .local_partitioner import p1_plan, plan_local
from .objective import Objective, resolve_objective
from .pareto import ParetoFront, ParetoPoint

# Strategies optionally accept ``provider=`` (a CostProvider) so the whole
# comparison can be re-run against calibrated cost predictions, and
# ``objective=`` (an Objective) so it can be re-run minimizing energy or EDP
# wherever the strategy has a real degree of freedom (HiDP: both DP tiers;
# DisNet: its global mode choice; MoDNN's proportional split and OmniBoost's
# throughput-reward MCTS are fixed by their papers and ignore it).
Strategy = Callable[..., HiDPPlan]


def _resolve(provider: CostProvider | None, delta: float) -> CostProvider:
    return resolve_provider(provider).at_delta(delta)


# --------------------------------------------------------------------------
# HiDP itself, as a Strategy
# --------------------------------------------------------------------------

def hidp_strategy(dag: ModelDAG, cluster: Cluster, delta: float = 1.0,
                  provider: CostProvider | None = None,
                  objective: Objective | None = None) -> HiDPPlan:
    return plan(dag, cluster, PlannerConfig(delta=delta, provider=provider,
                                            objective=objective))


# --------------------------------------------------------------------------
# MoDNN — proportional data partitioning, P1 local
# --------------------------------------------------------------------------

def modnn_strategy(dag: ModelDAG, cluster: Cluster, delta: float = 1.0,
                   provider: CostProvider | None = None,
                   objective: Objective | None = None) -> HiDPPlan:
    t0 = time.perf_counter()
    prov = _resolve(provider, delta)
    kind = dag.dominant_kind()
    nodes = cluster.available_nodes()
    # MoDNN profiles nodes end-to-end with the default runtime, so it sees
    # default-processor capacity; it splits input proportionally to that
    # capacity (it does not drop slow helpers or model comm in the split).
    resources = [node_as_resource(n, delta, capacity="default")
                 for n in nodes]
    total = sum(prov.effective_rate(r, kind) for r in resources)
    fr = tuple(prov.effective_rate(r, kind) / total for r in resources)
    per_node = [prov.compute_time(dag.total_flops * f, r, kind)
                + prov.comm_time((dag.input_bytes + dag.output_bytes) * f, r)
                for f, r in zip(fr, resources)]
    # Per-layer 1-D feature-map partitioning ⇒ boundary-row exchange at every
    # block, between σ−1 neighbour pairs, all over the shared wireless medium,
    # plus a synchronisation barrier (one wireless round-trip) per block —
    # MoDNN's dominant overhead on multi-node clusters.
    sigma = len(nodes)
    halo_bytes = sum(b.bytes_out * b.halo_fraction for b in dag.blocks) * (
        sigma - 1)
    sync_latency = len(dag.blocks) * 2 * 2e-3
    part = DataPartition(fractions=fr,
                         assignment=tuple(range(len(nodes))),
                         predicted_latency=max(per_node))
    gp = GlobalPlan(
        mode="data", partition=part,
        assignments=tuple(GlobalAssignment(node=n, fraction=f, stage_index=i)
                          for i, (n, f) in enumerate(zip(nodes, fr))),
        predicted_latency=part.predicted_latency,
        predicted_energy=predicted_energy(dag, resources, part, prov))
    locals_ = tuple(p1_plan(sub_dag_for(dag, a), a.node, delta=delta,
                            provider=prov)
                    for a in gp.assignments)
    lat, en = _hierarchical_cost(dag, gp, locals_, prov, objective)
    lat += halo_bytes / nodes[0].net_bw + sync_latency
    return HiDPPlan(dag_name=dag.name, global_plan=gp, local_plans=locals_,
                    predicted_latency=lat, predicted_energy=en,
                    planning_seconds=time.perf_counter() - t0,
                    extra_comm_bytes=halo_bytes,
                    extra_latency=sync_latency)


# --------------------------------------------------------------------------
# OmniBoost — MCTS pipeline partitioning, throughput objective
# --------------------------------------------------------------------------

def _mcts_pipeline(dag: ModelDAG, resources, *, budget: int = 128,
                   seed: int = 0, max_stages: int = 2,
                   provider: CostProvider | None = None) -> ModelPartition:
    """Monte-Carlo search over cut points: states are partial boundary lists;
    rollouts complete them randomly; reward = −max stage time (throughput).
    Deliberately budget- and depth-limited (the paper's OmniBoost explores a
    learned estimator the same way, over small candidate pipelines)."""
    prov = resolve_provider(provider)
    rng = random.Random(seed)
    n, m = len(dag.blocks), len(resources)
    kind = dag.dominant_kind()
    order = sorted(range(m),
                   key=lambda i: -prov.effective_rate(resources[i], kind))

    def stage_time(a: int, b: int, ri: int) -> float:
        seg = dag.segment(a, b)
        r = resources[ri]
        return (prov.comm_time(seg.bytes_in, r)
                + prov.compute_time(seg.flops, r, seg.kind))

    def evaluate(cuts: list[int]) -> float:
        bounds = [0] + cuts + [n]
        return max(stage_time(bounds[i], bounds[i + 1], order[i % m])
                   for i in range(len(bounds) - 1))

    best_cuts, best_val = [], evaluate([])
    max_cuts = max(min(m, n, max_stages) - 1, 0)
    for _ in range(budget):
        k = rng.randint(1, max_cuts) if max_cuts else 0
        cuts = sorted(rng.sample(range(1, n), k)) if k else []
        v = evaluate(cuts)
        if v < best_val:
            best_val, best_cuts = v, cuts
    bounds = [0] + best_cuts + [n]
    assign = tuple(order[i % m] for i in range(len(bounds) - 1))
    # latency of the pipeline for a single request = sum of stage times
    latency = sum(stage_time(bounds[i], bounds[i + 1], assign[i])
                  for i in range(len(bounds) - 1))
    return ModelPartition(boundaries=tuple(bounds), assignment=assign,
                          predicted_latency=latency)


def omniboost_strategy(dag: ModelDAG, cluster: Cluster, delta: float = 1.0,
                       provider: CostProvider | None = None,
                       objective: Objective | None = None) -> HiDPPlan:
    t0 = time.perf_counter()
    prov = _resolve(provider, delta)
    nodes = cluster.available_nodes()
    resources = [node_as_resource(n, delta, capacity="default")
                 for n in nodes]
    part = _mcts_pipeline(dag, resources, provider=prov)
    assignments = []
    for si in range(part.num_stages):
        a, b = part.boundaries[si], part.boundaries[si + 1]
        assignments.append(GlobalAssignment(node=nodes[part.assignment[si]],
                                            block_range=(a, b),
                                            stage_index=si))
    gp = GlobalPlan(mode="model", partition=part,
                    assignments=tuple(assignments),
                    predicted_latency=part.predicted_latency,
                    predicted_energy=predicted_energy(dag, resources, part,
                                                      prov))
    # local: OmniBoost pipelines each stage over the node's CPU+GPU.
    locals_ = []
    for a in gp.assignments:
        sd = sub_dag_for(dag, a)
        from .cost_model import processors_as_resources
        lres = processors_as_resources(a.node, delta)
        lp_part = _mcts_pipeline(sd, lres, budget=64, seed=1, provider=prov)
        from .local_partitioner import LocalPlan
        locals_.append(LocalPlan(
            node_name=a.node.name, mode="model", partition=lp_part,
            predicted_latency=lp_part.predicted_latency,
            predicted_energy=predicted_energy(sd, lres, lp_part, prov)))
    lat, en = _hierarchical_cost(dag, gp, tuple(locals_), prov,
                                 objective)
    return HiDPPlan(dag_name=dag.name, global_plan=gp,
                    local_plans=tuple(locals_), predicted_latency=lat,
                    predicted_energy=en,
                    planning_seconds=time.perf_counter() - t0)


# --------------------------------------------------------------------------
# DisNet — heuristic hybrid global tier, P1 local
# --------------------------------------------------------------------------

def disnet_strategy(dag: ModelDAG, cluster: Cluster, delta: float = 1.0,
                    provider: CostProvider | None = None,
                    objective: Objective | None = None) -> HiDPPlan:
    """DisNet chooses between data and model partitioning *heuristically* at
    the global level (micro-split heuristics, not an exact DP): data fractions
    proportional to capacity, model cuts at equal-compute points; the better
    of the two estimates under the objective wins (the faster one for the
    default latency objective — the seed behaviour).  No local tier (P1)."""
    t0 = time.perf_counter()
    prov = _resolve(provider, delta)
    kind = dag.dominant_kind()
    nodes = cluster.available_nodes()
    resources = [node_as_resource(n, delta, capacity="default")
                 for n in nodes]
    order = sorted(range(len(nodes)),
                   key=lambda i: -prov.effective_rate(resources[i], kind))

    # Heuristic data split: proportional fractions over all nodes.
    total = sum(prov.effective_rate(r, kind) for r in resources)
    fr = tuple(prov.effective_rate(resources[i], kind) / total for i in order)
    per_node = [prov.compute_time(dag.total_flops * f, resources[i], kind)
                + prov.comm_time(
                    (dag.input_bytes + dag.output_bytes) * f, resources[i])
                for f, i in zip(fr, order)]
    data_part = DataPartition(fractions=fr, assignment=tuple(order),
                              predicted_latency=max(per_node))

    # Heuristic model split: equal-compute cuts over the 2 fastest nodes.
    k = min(2, len(order))
    cum = dag.cumulative_flops()
    target = dag.total_flops / k
    bounds, acc = [0], 0.0
    for i, b in enumerate(dag.blocks):
        acc += b.flops
        if acc >= target * len(bounds) and len(bounds) < k:
            bounds.append(i + 1)
    bounds.append(len(dag.blocks))
    bounds = sorted(set(bounds))
    assign = tuple(order[i % len(order)] for i in range(len(bounds) - 1))
    lat = 0.0
    for si in range(len(bounds) - 1):
        seg = dag.segment(bounds[si], bounds[si + 1])
        r = resources[assign[si]]
        lat += (prov.comm_time(seg.bytes_in, r)
                + prov.compute_time(seg.flops, r, seg.kind))
    model_part = ModelPartition(boundaries=tuple(bounds), assignment=assign,
                                predicted_latency=lat)

    obj = resolve_objective(objective)
    if obj.is_latency:
        part = (data_part if data_part.predicted_latency
                <= model_part.predicted_latency else model_part)
    else:
        en_d = predicted_energy(dag, resources, data_part, prov,
                                radio_power=obj.radio_power)
        en_m = predicted_energy(dag, resources, model_part, prov,
                                radio_power=obj.radio_power)
        part = (data_part
                if obj.at_least_as_good(data_part.predicted_latency, en_d,
                                        model_part.predicted_latency, en_m)
                else model_part)
    if isinstance(part, DataPartition):
        assignments = tuple(
            GlobalAssignment(node=nodes[ri], fraction=f, stage_index=i)
            for i, (f, ri) in enumerate(zip(part.fractions, part.assignment)))
        mode = "data"
    else:
        assignments = tuple(
            GlobalAssignment(node=nodes[part.assignment[si]],
                             block_range=(part.boundaries[si],
                                          part.boundaries[si + 1]),
                             stage_index=si)
            for si in range(part.num_stages))
        mode = "model"
    gp = GlobalPlan(mode=mode, partition=part, assignments=assignments,
                    predicted_latency=part.predicted_latency,
                    predicted_energy=predicted_energy(dag, resources, part,
                                                      prov))
    locals_ = tuple(p1_plan(sub_dag_for(dag, a), a.node, delta=delta,
                            provider=prov)
                    for a in gp.assignments)
    lat, en = _hierarchical_cost(dag, gp, locals_, prov, objective)
    return HiDPPlan(dag_name=dag.name, global_plan=gp, local_plans=locals_,
                    predicted_latency=lat, predicted_energy=en,
                    planning_seconds=time.perf_counter() - t0)


STRATEGIES: dict[str, Strategy] = {
    "hidp": hidp_strategy,
    "modnn": modnn_strategy,
    "omniboost": omniboost_strategy,
    "disnet": disnet_strategy,
}


# --------------------------------------------------------------------------
# Frontier views — every strategy as a ParetoFront, so figures comparing
# strategies can compare whole trade-off curves, not one scalarization.
# --------------------------------------------------------------------------

def hidp_front(dag: ModelDAG, cluster: Cluster, delta: float = 1.0,
               provider: CostProvider | None = None,
               objective: Objective | None = None) -> ParetoFront:
    """HiDP's full hierarchical frontier (``objective`` only contributes its
    radio-power pricing; selection happens at the caller)."""
    return plan_front(dag, cluster, PlannerConfig(delta=delta,
                                                  provider=provider,
                                                  objective=objective))


def _single_point_front(strategy: Strategy, dag: ModelDAG, cluster: Cluster,
                        delta: float, provider: CostProvider | None,
                        objective: Objective | None) -> ParetoFront:
    p = strategy(dag, cluster, delta, provider=provider, objective=objective)
    return ParetoFront([ParetoPoint(p.predicted_latency, p.predicted_energy,
                                    p)])


def modnn_front(dag: ModelDAG, cluster: Cluster, delta: float = 1.0,
                provider: CostProvider | None = None,
                objective: Objective | None = None) -> ParetoFront:
    """MoDNN's split is fixed by its paper (capacity-proportional, ignores
    the objective), so its "frontier" is one point."""
    return _single_point_front(modnn_strategy, dag, cluster, delta, provider,
                               objective)


def omniboost_front(dag: ModelDAG, cluster: Cluster, delta: float = 1.0,
                    provider: CostProvider | None = None,
                    objective: Objective | None = None) -> ParetoFront:
    """OmniBoost's MCTS rewards throughput only — one point."""
    return _single_point_front(omniboost_strategy, dag, cluster, delta,
                               provider, objective)


def disnet_front(dag: ModelDAG, cluster: Cluster, delta: float = 1.0,
                 provider: CostProvider | None = None,
                 objective: Objective | None = None) -> ParetoFront:
    """DisNet has one real degree of freedom — its heuristic global mode
    choice — so its frontier is the skyline of the latency-picked and
    energy-picked hybrids (one or two points)."""
    obj = resolve_objective(objective)
    p_lat = disnet_strategy(dag, cluster, delta, provider=provider)
    p_en = disnet_strategy(dag, cluster, delta, provider=provider,
                           objective=Objective("energy",
                                               radio_power=obj.radio_power))
    return ParetoFront.build([
        (p.predicted_latency, p.predicted_energy, p) for p in (p_lat, p_en)])


StrategyFront = Callable[..., ParetoFront]

STRATEGY_FRONTS: dict[str, StrategyFront] = {
    "hidp": hidp_front,
    "modnn": modnn_front,
    "omniboost": omniboost_front,
    "disnet": disnet_front,
}
