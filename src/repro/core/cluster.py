"""Cluster registry, availability tracking and leader election.

Paper semantics: the node that *receives* an inference request becomes the
leader (φ* — Alg. 1 line 2); availability A(N_φ) is probed by pseudo packets
(Eq. 4).  Here availability is maintained by a heartbeat monitor that both the
event-driven simulator and the TPU runtime drive; a node missing
``miss_threshold`` consecutive heartbeats flips α_j to 0 and triggers
re-planning (runtime/elastic.py).
"""

from __future__ import annotations

import dataclasses

from .cost_model import Cluster, Node


@dataclasses.dataclass
class HeartbeatMonitor:
    """Tracks last-seen times; the clock is injected (sim time or wall time)."""

    interval: float = 0.5              # seconds between expected beats
    miss_threshold: int = 3
    last_seen: dict[str, float] = dataclasses.field(default_factory=dict)

    def beat(self, node_name: str, now: float) -> None:
        self.last_seen[node_name] = now

    def alive(self, node_name: str, now: float) -> bool:
        t = self.last_seen.get(node_name)
        if t is None:
            return False
        return (now - t) <= self.interval * self.miss_threshold


@dataclasses.dataclass
class ClusterManager:
    """Mutable wrapper over the frozen Cluster: availability, leadership."""

    cluster: Cluster
    monitor: HeartbeatMonitor = dataclasses.field(
        default_factory=HeartbeatMonitor)
    leader: str | None = None

    def nodes(self) -> tuple[Node, ...]:
        return self.cluster.nodes

    def node(self, name: str) -> Node:
        for n in self.cluster.nodes:
            if n.name == name:
                return n
        raise KeyError(name)

    def first_available(self) -> Node | None:
        """The first declared node with α_j = 1 — the deterministic
        fail-over leader candidate when the current leader goes away."""
        for n in self.cluster.nodes:
            if n.available:
                return n
        return None

    def leader_available(self) -> bool:
        if self.leader is None:
            return False
        try:
            return self.node(self.leader).available
        except KeyError:
            return False

    def ensure_leader(self, preferred: str | None = None) -> str | None:
        """The one fail-over policy (Alg. 1 line 2 under churn), shared by
        the scheduler FSM and the fleet controller: elect ``preferred``
        when it names an available node; otherwise keep the sitting leader
        while it is available; otherwise the first available declared
        node.  Returns the leader's name, or None — clearing the seat —
        when no node is available."""
        if preferred is not None:
            try:
                if self.node(preferred).available:
                    return self.elect_leader(preferred).name
            except KeyError:
                pass
        if self.leader_available():
            return self.leader
        candidate = self.first_available()
        if candidate is None:
            self.leader = None
            return None
        return self.elect_leader(candidate.name).name

    def elect_leader(self, receiving_node: str) -> Node:
        """Alg. 1 line 2: leader = the node that received the request."""
        for n in self.cluster.nodes:
            if n.name == receiving_node:
                if not n.available:
                    raise RuntimeError(f"leader candidate {receiving_node} "
                                       "is unavailable")
                self.leader = n.name
                return n
        raise KeyError(receiving_node)

    def refresh_availability(self, now: float) -> Cluster:
        """Re-evaluate A(N_φ) from heartbeats (Alg. 1 line 3).  The leader is
        always considered available to itself."""
        alphas = []
        for n in self.cluster.nodes:
            if n.name == self.leader:
                alphas.append(True)
            else:
                alphas.append(self.monitor.alive(n.name, now))
        self.cluster = self.cluster.with_availability(alphas)
        return self.cluster

    def set_available(self, node_name: str, available: bool) -> Cluster:
        """Direct availability override (node join/leave/failure)."""
        alphas = [(n.available if n.name != node_name else available)
                  for n in self.cluster.nodes]
        self.cluster = self.cluster.with_availability(alphas)
        return self.cluster

    def available_count(self) -> int:
        return sum(self.cluster.availability())
