"""The HiDP dynamic-programming partitioner (paper §III, Algorithm 1 lines 4-6
and 8-10).

The paper uses one DP routine at both tiers ("the function arguments are
essentially the same in either case including the DNN and the
computation-communication ratio"):

* **model partitioning** — choose cut points turning the block chain into
  contiguous *stages* of heterogeneous width ω, each assigned to one resource;
  the request flows stage → stage, paying an activation transfer at every cut.
  ``Θ_ω = γ·ω`` with γ = Ψ (global) or ψ (local)  — Eq. 5.

* **data partitioning** — choose σ parallel sub-models and per-resource data
  fractions; all resources run concurrently and the slowest finishes last.
  ``Θ_σ = γ·σ``  — Eq. 6.

* **mode selection** — ``Θ = min(Θ_ω, Θ_σ)``  (Alg. 1 line 6 / 10).

The model-partitioning search is an exact DP over (prefix of blocks ×
resources-used):  DP[i][j] = best latency executing blocks[:i] on the first j
resources of a heterogeneity-ordered list.  The paper describes this as a
subset-sum-style O(n·m) recursion seeded "with the largest possible block
sizes following the resource heterogeneity" and back-propagating block by
block; we implement the exact O(n²·m) variant (n = #blocks is small: ≤ ~200)
and keep the paper's heterogeneity-descending resource order, which makes the
greedy seed the DP's first feasible path.
"""

from __future__ import annotations

from typing import Sequence

from .cost_model import CostProvider, Resource, resolve_provider
from .dag import DataPartition, ModelDAG, ModelPartition, Partition
from .objective import Objective, resolve_objective


# --------------------------------------------------------------------------
# Model partitioning (pipeline over stages of width ω)
# --------------------------------------------------------------------------

def partition_model(dag: ModelDAG, resources: Sequence[Resource],
                    *, weight_transfer: bool = False,
                    provider: CostProvider | None = None,
                    objective: Objective | None = None) -> ModelPartition:
    """Exact DP for heterogeneous contiguous pipeline partitioning.

    Latency objective (single request, sequential stage execution — the
    paper's "inherently temporal" model partitioning):

        T = Σ_stages [ xfer_in(stage) + compute(stage) ]  + xfer_out(last)

    Resources are ordered by descending rate ("following the resource
    heterogeneity"); the DP may leave later (slower) resources unused, so the
    result uses between 1 and m stages with variable block widths.

    ``weight_transfer``: when True, shipping a stage to a non-leader resource
    also pays its ``param_bytes`` over that resource's link (cold start —
    used by the simulator's first-request path; steady-state serving keeps
    weights resident, the paper's implicit assumption).

    ``objective``: what the recurrence minimizes.  The default (latency)
    runs the seed's scalar DP unchanged.  For ``energy``/``edp`` the DP
    tracks (latency, energy) pairs and compares states by
    ``Objective.key``; per-stage energy is additive because a pipeline busies
    one resource at a time — stage energy = active compute+comm joules plus
    the *other* resources' idle power over the stage's seconds (the
    idle-coupling that makes "slow but frugal" a real trade-off, not a free
    win).  EDP is not stage-separable, so for ``edp`` the prefix
    scalarization is a (well-behaved) heuristic rather than an exact DP.
    """
    n = len(dag.blocks)
    if n == 0:
        raise ValueError("empty DAG")
    prov = resolve_provider(provider)
    obj = resolve_objective(objective)
    # order by the provider's view of the DAG's dominant kind — for the
    # analytic provider this is exactly the seed's rate ordering, for a
    # calibrated one it follows measured rates
    kind = dag.dominant_kind()
    order = sorted(range(len(resources)),
                   key=lambda i: -prov.effective_rate(resources[i], kind))
    res = [resources[i] for i in order]
    m = len(res)

    # Per-resource segment costers (O(1) via prefix sums).
    costers = [prov.segment_coster(dag, r) for r in res]
    cum_params = [0.0]
    for b in dag.blocks:
        cum_params.append(cum_params[-1] + b.param_bytes)

    def seg_params(a: int, b: int) -> float:
        return cum_params[b] - cum_params[a]

    if not obj.is_latency:
        return _partition_model_objective(
            dag, resources, res, order, costers, seg_params,
            weight_transfer=weight_transfer, prov=prov, obj=obj)

    INF = float("inf")
    # dp[j][i]: best latency for blocks[:i] using a subset of the first j
    # resources where resource j-1 runs the last stage ending at i.
    # best[j][i]: min over j'<=j of dp, i.e. blocks[:i] done within first j res.
    # bestj[j][i]: the j' achieving best[j][i] — so the backtrack can follow
    # the exact state chain instead of guessing which dp row realised it.
    dp = [[INF] * (n + 1) for _ in range(m + 1)]
    best = [[INF] * (n + 1) for _ in range(m + 1)]
    bestj = [[0] * (n + 1) for _ in range(m + 1)]
    parent: dict[tuple[int, int], int] = {}      # (j, i) → stage start s
    for j in range(m + 1):
        dp[j][0] = 0.0
        best[j][0] = 0.0

    for j in range(1, m + 1):
        r = res[j - 1]
        coster = costers[j - 1]
        for i in range(1, n + 1):
            for s in range(i):
                prev = best[j - 1][s]
                if prev == INF:
                    continue
                xfer = dag.blocks[s].bytes_in if s > 0 else dag.input_bytes
                cost = (prev
                        + prov.comm_time(xfer, r)
                        + coster(s, i))
                if weight_transfer and j > 1:
                    cost += prov.comm_time(seg_params(s, i), r, rtt=0.0)
                if cost < dp[j][i]:
                    dp[j][i] = cost
                    parent[(j, i)] = s
            if dp[j][i] < best[j - 1][i]:
                best[j][i], bestj[j][i] = dp[j][i], j
            else:
                best[j][i], bestj[j][i] = best[j - 1][i], bestj[j - 1][i]

    # Final answer: best over how many resources considered; add result return.
    end_j, end_cost = 0, INF
    for j in range(1, m + 1):
        if dp[j][n] < INF:
            c = dp[j][n] + prov.comm_time(dag.output_bytes, res[j - 1])
            if c < end_cost:
                end_cost, end_j = c, j
    if end_cost == INF:
        raise RuntimeError("model-partition DP found no feasible plan")

    # Back-propagate block by block (paper's phrasing) to recover cuts:
    # stage (s, i) runs on res j-1; the prefix blocks[:s] was realised by
    # the dp row bestj[j-1][s] that achieved best[j-1][s].
    cuts: list[int] = [n]
    assign: list[int] = []
    j, i = end_j, n
    while i > 0:
        s = parent[(j, i)]
        assign.append(order[j - 1])
        cuts.append(s)
        j, i = bestj[j - 1][s], s
    cuts.reverse()
    assign.reverse()
    return ModelPartition(boundaries=tuple(cuts), assignment=tuple(assign),
                          predicted_latency=end_cost)


def _partition_model_objective(dag: ModelDAG, resources: Sequence[Resource],
                               res: list[Resource], order: list[int],
                               costers: list, seg_params,
                               *, weight_transfer: bool,
                               prov: CostProvider,
                               obj: Objective) -> ModelPartition:
    """The (latency, energy)-pair variant of the model-partitioning DP.

    Same state space and transitions as the scalar DP; each state carries
    the prefix's accumulated latency *and* energy and states compare by
    ``obj.key``.  Energy is stage-additive: while one pipeline stage runs,
    its resource draws active power and every *other* resource draws idle
    power, so stage energy = active J + (Σ idle − own idle) × stage seconds
    (identically the algebra of :func:`predicted_energy`, unrolled per
    stage), plus the objective's radio term on wireless transfer seconds.

    States are linked records ``(key, lat, en, j, s, prev)`` — each points
    at its exact predecessor, so reconstruction replays the very chain whose
    cost was reported.  Every cell keeps a small frontier: the best state by
    ``obj.key`` *and* the best by raw latency.  Scalarized single-state DPs
    can prune the only prefix that stays inside a ``latency_budget``; the
    latency variant preserves the seed's latency-optimal chain end to end,
    guaranteeing the search returns a within-budget plan whenever the
    latency-optimal pipeline over these resources fits the budget.  (EDP is
    additionally a prefix-scalarization heuristic — E×T is not
    stage-separable.)
    """
    n, m = len(dag.blocks), len(res)
    ecosters = [prov.segment_energy_coster(dag, r) for r in res]
    idle_total = sum(r.idle_power for r in resources)

    # state: (key, lat, en, j, s, prev_state); frontier per cell: state
    # minimizing key and state minimizing latency (often the same object).
    zero = (obj.key(0.0, 0.0), 0.0, 0.0, 0, 0, None)

    def merge(frontier, state):
        if frontier is None:
            return (state, state)
        by_key, by_lat = frontier
        if state[0] < by_key[0]:
            by_key = state
        if state[1] < by_lat[1]:
            by_lat = state
        return (by_key, by_lat)

    def states(frontier):
        if frontier is None:
            return ()
        return frontier if frontier[0] is not frontier[1] else frontier[:1]

    # dp[j][i]: frontier of states whose last stage ends at i on res j-1;
    # best[j][i]: frontier over all dp[j'][i], j' <= j.
    dp = [[None] * (n + 1) for _ in range(m + 1)]
    best = [[None] * (n + 1) for _ in range(m + 1)]
    for j in range(m + 1):
        dp[j][0] = (zero, zero)
        best[j][0] = (zero, zero)

    for j in range(1, m + 1):
        r = res[j - 1]
        coster, ecoster = costers[j - 1], ecosters[j - 1]
        idle_rest = idle_total - r.idle_power
        for i in range(1, n + 1):
            for s in range(i):
                for prev in states(best[j - 1][s]):
                    xfer = (dag.blocks[s].bytes_in if s > 0
                            else dag.input_bytes)
                    comm_s = prov.comm_time(xfer, r)
                    lat_stage = comm_s + coster(s, i)
                    en_stage = (prov.comm_energy(xfer, r) + ecoster(s, i)
                                + obj.radio_power * comm_s)
                    if weight_transfer and j > 1:
                        wt = prov.comm_time(seg_params(s, i), r, rtt=0.0)
                        lat_stage += wt
                        en_stage += (prov.comm_energy(seg_params(s, i), r,
                                                      rtt=0.0)
                                     + obj.radio_power * wt)
                    en_stage += idle_rest * lat_stage
                    lat = prev[1] + lat_stage
                    en = prev[2] + en_stage
                    state = (obj.key(lat, en), lat, en, j, s, prev)
                    dp[j][i] = merge(dp[j][i], state)
            best[j][i] = best[j - 1][i]
            for st in states(dp[j][i]):
                best[j][i] = merge(best[j][i], st)

    end_state, end_key = None, None
    for j in range(1, m + 1):
        r = res[j - 1]
        t_out = prov.comm_time(dag.output_bytes, r)
        e_out = (prov.comm_energy(dag.output_bytes, r)
                 + obj.radio_power * t_out
                 + (idle_total - r.idle_power) * t_out)
        for st in states(dp[j][n]):
            lat, en = st[1] + t_out, st[2] + e_out
            key = obj.key(lat, en)
            if end_key is None or key < end_key:
                end_state, end_key = (st, lat), key
    if end_state is None:
        raise RuntimeError("model-partition DP found no feasible plan")

    # Reconstruct by replaying the exact predecessor chain.
    st, final_lat = end_state
    cuts: list[int] = [n]
    assign: list[int] = []
    while st[5] is not None:                     # until the zero state
        assign.append(order[st[3] - 1])
        cuts.append(st[4])
        st = st[5]
    cuts.reverse()
    assign.reverse()
    return ModelPartition(boundaries=tuple(cuts), assignment=tuple(assign),
                          predicted_latency=final_lat)


# --------------------------------------------------------------------------
# Data partitioning (σ parallel sub-models)
# --------------------------------------------------------------------------

def _balanced_fractions(dag: ModelDAG, subset: Sequence[Resource],
                        provider: CostProvider | None = None
                        ) -> tuple[tuple[float, ...], float]:
    """Water-fill data fractions so every resource finishes simultaneously.

    Per-resource time for fraction f:  t_i = f·(F/r_i + B_io/bw_i) + rtt_i
    Setting t_i = t for all i and Σf = 1 gives a closed form.
    """
    prov = resolve_provider(provider)
    # bytes shipped per unit fraction: the input split + merged output + the
    # halo exchange along the deepest halo block.
    halo = max((b.bytes_out * b.halo_fraction for b in dag.blocks), default=0.0)
    bio = dag.input_bytes + dag.output_bytes + 2.0 * halo
    coeffs = [prov.data_coeffs(dag, r) for r in subset]
    k = [lin + prov.comm_time(bio, r, rtt=0.0)
         for (lin, _), r in zip(coeffs, subset)]           # seconds per unit f
    c = [r.rtt + fixed for (_, fixed), r in zip(coeffs, subset)]
    # t = (1 + Σ c_i/k_i) / Σ (1/k_i); f_i = (t - c_i)/k_i
    inv = sum(1.0 / ki for ki in k)
    t = (1.0 + sum(ci / ki for ci, ki in zip(c, k))) / inv
    fr = [(t - ci) / ki for ci, ki in zip(c, k)]
    if any(f <= 0 for f in fr):           # a resource too slow to help
        return tuple(), float("inf")
    s = sum(fr)
    return tuple(f / s for f in fr), t


def partition_data(dag: ModelDAG, resources: Sequence[Resource],
                   *, provider: CostProvider | None = None,
                   objective: Objective | None = None) -> DataPartition:
    """Explore σ = 1..m sub-models over heterogeneity-ordered resources and
    keep the best balanced split (Eq. 6).  Blocks that are not
    data-splittable force σ = 1 (feasibility mask — e.g. recurrent decode
    state, see DESIGN.md §4).

    Each σ's split is water-filled so every participant finishes together
    (the latency-optimal division for that subset); the *objective* then
    chooses between subsets — under ``energy``/``edp`` a smaller σ that
    keeps slow helpers idle (saving their active power and the shared
    medium's radio energy) can beat the latency-optimal wide split."""
    prov = resolve_provider(provider)
    obj = resolve_objective(objective)
    kind = dag.dominant_kind()
    order = sorted(range(len(resources)),
                   key=lambda i: -prov.effective_rate(resources[i], kind))
    if not all(b.data_splittable for b in dag.blocks):
        order = order[:1]
    best: DataPartition | None = None
    best_en = float("inf")
    for sigma in range(1, len(order) + 1):
        subset_idx = order[:sigma]
        subset = [resources[i] for i in subset_idx]
        fr, t = _balanced_fractions(dag, subset, prov)
        if not fr:
            continue
        cand = DataPartition(fractions=fr, assignment=tuple(subset_idx),
                             predicted_latency=t)
        if obj.is_latency:
            if best is None or t < best.predicted_latency:
                best = cand
            continue
        en = predicted_energy(dag, resources, cand, prov,
                              radio_power=obj.radio_power)
        if best is None or obj.better(t, en, best.predicted_latency, best_en):
            best, best_en = cand, en
    if best is None:
        raise RuntimeError("data-partition search found no feasible plan")
    return best


# --------------------------------------------------------------------------
# Mode selection — Algorithm 1 lines 4-6 / 8-10
# --------------------------------------------------------------------------

def partition(dag: ModelDAG, resources: Sequence[Resource],
              *, weight_transfer: bool = False,
              provider: CostProvider | None = None,
              objective: Objective | None = None) -> Partition:
    """Θ ← best(Θ_ω, Θ_σ): run both searches, return the better plan.

    With the default latency objective this is the paper's
    ``Θ = min(Θ_ω, Θ_σ)`` verbatim (model wins ties, as in the seed); under
    ``energy``/``edp`` both candidates are priced by
    :func:`predicted_energy` and ``Objective.key`` decides — respecting the
    latency budget when one is set."""
    obj = resolve_objective(objective)
    theta_w = partition_model(dag, resources, weight_transfer=weight_transfer,
                              provider=provider, objective=obj)
    theta_s = partition_data(dag, resources, provider=provider, objective=obj)
    if obj.is_latency:
        if theta_w.predicted_latency <= theta_s.predicted_latency:
            return theta_w
        return theta_s
    en_w = predicted_energy(dag, resources, theta_w, provider,
                            radio_power=obj.radio_power)
    en_s = predicted_energy(dag, resources, theta_s, provider,
                            radio_power=obj.radio_power)
    if obj.at_least_as_good(theta_w.predicted_latency, en_w,
                            theta_s.predicted_latency, en_s):
        return theta_w
    return theta_s


# --------------------------------------------------------------------------
# Energy prediction for a plan (used by the planners, simulator, benchmarks)
# --------------------------------------------------------------------------

def predicted_energy(dag: ModelDAG, resources: Sequence[Resource],
                     plan: Partition,
                     provider: CostProvider | None = None,
                     *, radio_power: float = 0.0) -> float:
    """∫P dt for one plan: active power while a resource computes or
    communicates, idle power for the rest of the plan's makespan.

    The active joules come from the provider's energy queries, so a
    calibrated provider prices them from *fitted* energy predictors while
    the analytic provider reproduces the seed's ``active_power × busy``
    algebra.  ``radio_power`` adds watts on total transfer seconds (the
    shared-medium transmit energy the simulator meters); it defaults to 0 so
    existing callers see the seed numerics unchanged."""
    prov = resolve_provider(provider)
    T = plan.predicted_latency
    busy: dict[int, float] = {}
    active: dict[int, float] = {}
    comm_s = 0.0
    if isinstance(plan, ModelPartition):
        for si in range(plan.num_stages):
            a, b = plan.boundaries[si], plan.boundaries[si + 1]
            ri = plan.assignment[si]
            r = resources[ri]
            seg = dag.segment(a, b)
            cm = prov.comm_time(seg.bytes_in, r)
            busy[ri] = busy.get(ri, 0.0) + (
                prov.compute_time(seg.flops, r, seg.kind) + cm)
            active[ri] = active.get(ri, 0.0) + (
                prov.compute_energy(seg.flops, r, seg.kind)
                + prov.comm_energy(seg.bytes_in, r))
            comm_s += cm
    else:
        kind = dag.dominant_kind()
        for f, ri in zip(plan.fractions, plan.assignment):
            r = resources[ri]
            nbytes = (dag.input_bytes + dag.output_bytes) * f
            cm = prov.comm_time(nbytes, r)
            busy[ri] = busy.get(ri, 0.0) + (
                prov.compute_time(dag.total_flops * f, r, kind) + cm)
            active[ri] = active.get(ri, 0.0) + (
                prov.compute_energy(dag.total_flops * f, r, kind)
                + prov.comm_energy(nbytes, r))
            comm_s += cm
    e = 0.0
    for i, r in enumerate(resources):
        b = busy.get(i, 0.0)
        ae = active.get(i, 0.0)
        if b > T and b > 0.0:
            ae *= T / b                   # clip active draw to the makespan
            b = T
        e += ae + r.idle_power * max(T - b, 0.0)
    return e + radio_power * comm_s
