"""The HiDP dynamic-programming partitioner (paper §III, Algorithm 1 lines 4-6
and 8-10).

The paper uses one DP routine at both tiers ("the function arguments are
essentially the same in either case including the DNN and the
computation-communication ratio"):

* **model partitioning** — choose cut points turning the block chain into
  contiguous *stages* of heterogeneous width ω, each assigned to one resource;
  the request flows stage → stage, paying an activation transfer at every cut.
  ``Θ_ω = γ·ω`` with γ = Ψ (global) or ψ (local)  — Eq. 5.

* **data partitioning** — choose σ parallel sub-models and per-resource data
  fractions; all resources run concurrently and the slowest finishes last.
  ``Θ_σ = γ·σ``  — Eq. 6.

* **mode selection** — ``Θ = min(Θ_ω, Θ_σ)``  (Alg. 1 line 6 / 10).

The model-partitioning search is an exact DP over (prefix of blocks ×
resources-used):  DP[i][j] = best latency executing blocks[:i] on the first j
resources of a heterogeneity-ordered list.  The paper describes this as a
subset-sum-style O(n·m) recursion seeded "with the largest possible block
sizes following the resource heterogeneity" and back-propagating block by
block; we implement the exact O(n²·m) variant (n = #blocks is small: ≤ ~200)
and keep the paper's heterogeneity-descending resource order, which makes the
greedy seed the DP's first feasible path.
"""

from __future__ import annotations

from typing import Sequence

from .cost_model import CostProvider, Resource, resolve_provider
from .dag import DataPartition, ModelDAG, ModelPartition, Partition
from .objective import Objective, resolve_objective
from .pareto import ParetoFront, pareto_filter

# Per-cell frontier cap for the (latency, energy) DP search — the *search
# breadth* knob, distinct from a front's output ``width`` (how many points
# callers get back, e.g. ``PlannerConfig.front_width``).  Endpoints always
# survive thinning, so the cap trades interior resolution for speed.
DP_FRONT_CAP = 8


def _heterogeneity_order(dag: ModelDAG, resources: Sequence[Resource],
                         prov: CostProvider
                         ) -> tuple[list[Resource], list[int]]:
    """Resources by descending effective rate for the DAG's dominant kind —
    the paper's "following the resource heterogeneity" seed order."""
    kind = dag.dominant_kind()
    order = sorted(range(len(resources)),
                   key=lambda i: -prov.effective_rate(resources[i], kind))
    return [resources[i] for i in order], order


# --------------------------------------------------------------------------
# Model partitioning (pipeline over stages of width ω)
# --------------------------------------------------------------------------

def partition_model(dag: ModelDAG, resources: Sequence[Resource],
                    *, weight_transfer: bool = False,
                    provider: CostProvider | None = None,
                    objective: Objective | None = None) -> ModelPartition:
    """Exact DP for heterogeneous contiguous pipeline partitioning.

    Latency objective (single request, sequential stage execution — the
    paper's "inherently temporal" model partitioning):

        T = Σ_stages [ xfer_in(stage) + compute(stage) ]  + xfer_out(last)

    Resources are ordered by descending rate ("following the resource
    heterogeneity"); the DP may leave later (slower) resources unused, so the
    result uses between 1 and m stages with variable block widths.

    ``weight_transfer``: when True, shipping a stage to a non-leader resource
    also pays its ``param_bytes`` over that resource's link (cold start —
    used by the simulator's first-request path; steady-state serving keeps
    weights resident, the paper's implicit assumption).

    ``objective``: how the winning plan is chosen.  The default (latency)
    runs the seed's scalar DP unchanged.  Any other objective selects over
    the plan frontier (:func:`partition_model_front`) — feasible-first under
    the latency budget, then metric-optimal — instead of scalarizing inside
    the recurrence.
    """
    n = len(dag.blocks)
    if n == 0:
        raise ValueError("empty DAG")
    prov = resolve_provider(provider)
    obj = resolve_objective(objective)
    if not obj.is_latency:
        return partition_model_front(
            dag, resources, weight_transfer=weight_transfer, provider=prov,
            radio_power=obj.radio_power).select(obj)
    # order by the provider's view of the DAG's dominant kind — for the
    # analytic provider this is exactly the seed's rate ordering, for a
    # calibrated one it follows measured rates
    res, order = _heterogeneity_order(dag, resources, prov)
    m = len(res)

    # Per-resource segment costers (O(1) via prefix sums).
    costers = [prov.segment_coster(dag, r) for r in res]
    cum_params = [0.0]
    for b in dag.blocks:
        cum_params.append(cum_params[-1] + b.param_bytes)

    def seg_params(a: int, b: int) -> float:
        return cum_params[b] - cum_params[a]

    INF = float("inf")
    # dp[j][i]: best latency for blocks[:i] using a subset of the first j
    # resources where resource j-1 runs the last stage ending at i.
    # best[j][i]: min over j'<=j of dp, i.e. blocks[:i] done within first j res.
    # bestj[j][i]: the j' achieving best[j][i] — so the backtrack can follow
    # the exact state chain instead of guessing which dp row realised it.
    dp = [[INF] * (n + 1) for _ in range(m + 1)]
    best = [[INF] * (n + 1) for _ in range(m + 1)]
    bestj = [[0] * (n + 1) for _ in range(m + 1)]
    parent: dict[tuple[int, int], int] = {}      # (j, i) → stage start s
    for j in range(m + 1):
        dp[j][0] = 0.0
        best[j][0] = 0.0

    for j in range(1, m + 1):
        r = res[j - 1]
        coster = costers[j - 1]
        for i in range(1, n + 1):
            for s in range(i):
                prev = best[j - 1][s]
                if prev == INF:
                    continue
                xfer = dag.blocks[s].bytes_in if s > 0 else dag.input_bytes
                cost = (prev
                        + prov.comm_time(xfer, r)
                        + coster(s, i))
                if weight_transfer and j > 1:
                    cost += prov.comm_time(seg_params(s, i), r, rtt=0.0)
                if cost < dp[j][i]:
                    dp[j][i] = cost
                    parent[(j, i)] = s
            if dp[j][i] < best[j - 1][i]:
                best[j][i], bestj[j][i] = dp[j][i], j
            else:
                best[j][i], bestj[j][i] = best[j - 1][i], bestj[j - 1][i]

    # Final answer: best over how many resources considered; add result return.
    end_j, end_cost = 0, INF
    for j in range(1, m + 1):
        if dp[j][n] < INF:
            c = dp[j][n] + prov.comm_time(dag.output_bytes, res[j - 1])
            if c < end_cost:
                end_cost, end_j = c, j
    if end_cost == INF:
        raise RuntimeError("model-partition DP found no feasible plan")

    # Back-propagate block by block (paper's phrasing) to recover cuts:
    # stage (s, i) runs on res j-1; the prefix blocks[:s] was realised by
    # the dp row bestj[j-1][s] that achieved best[j-1][s].
    cuts: list[int] = [n]
    assign: list[int] = []
    j, i = end_j, n
    while i > 0:
        s = parent[(j, i)]
        assign.append(order[j - 1])
        cuts.append(s)
        j, i = bestj[j - 1][s], s
    cuts.reverse()
    assign.reverse()
    return ModelPartition(boundaries=tuple(cuts), assignment=tuple(assign),
                          predicted_latency=end_cost)


def _model_front_search(dag: ModelDAG, resources: Sequence[Resource],
                        *, weight_transfer: bool, prov: CostProvider,
                        radio_power: float,
                        cap: int = DP_FRONT_CAP) -> list[ModelPartition]:
    """The (latency, energy)-pair DP, keeping a *frontier* per cell.

    Same state space and transitions as the scalar DP; each state carries
    the prefix's accumulated latency *and* energy, and every cell keeps a
    capped non-dominated set of states instead of one scalarized winner.
    Energy is stage-additive: while one pipeline stage runs, its resource
    draws active power and every *other* resource draws idle power, so
    stage energy = active J + (Σ idle − own idle) × stage seconds
    (identically the algebra of :func:`predicted_energy`, unrolled per
    stage), plus ``radio_power`` watts on wireless transfer seconds.

    States are linked records ``(lat, en, j, s, prev)`` — each points at
    its exact predecessor, so reconstruction replays the very chain whose
    cost was reported.  Latency accumulates with the same association as
    the scalar DP (``prev + comm + compute``), so the latency-minimal chain
    here is float-identical to the scalar DP's plan.  Returns the distinct
    partitions realising the final non-dominated states; callers re-price
    them uniformly and skyline-filter.
    """
    n, m = len(dag.blocks), len(resources)
    res, order = _heterogeneity_order(dag, resources, prov)
    costers = [prov.segment_coster(dag, r) for r in res]
    ecosters = [prov.segment_energy_coster(dag, r) for r in res]
    cum_params = [0.0]
    for b in dag.blocks:
        cum_params.append(cum_params[-1] + b.param_bytes)
    idle_total = sum(r.idle_power for r in resources)

    # dp[j][i]: frontier of states whose last stage ends at i on res j-1;
    # best[j][i]: frontier over all dp[j'][i], j' <= j.
    zero = (0.0, 0.0, 0, 0, None)
    dp: list[list[list]] = [[[] for _ in range(n + 1)] for _ in range(m + 1)]
    best: list[list[list]] = [[[] for _ in range(n + 1)]
                              for _ in range(m + 1)]
    for j in range(m + 1):
        dp[j][0] = [zero]
        best[j][0] = [zero]

    for j in range(1, m + 1):
        r = res[j - 1]
        coster, ecoster = costers[j - 1], ecosters[j - 1]
        idle_rest = idle_total - r.idle_power
        for i in range(1, n + 1):
            cell: list = []
            for s in range(i):
                prevs = best[j - 1][s]
                if not prevs:
                    continue
                xfer = dag.blocks[s].bytes_in if s > 0 else dag.input_bytes
                comm_s = prov.comm_time(xfer, r)
                cseg = coster(s, i)
                lat_stage = comm_s + cseg
                en_stage = (prov.comm_energy(xfer, r) + ecoster(s, i)
                            + radio_power * comm_s)
                wt = 0.0
                if weight_transfer and j > 1:
                    wt = prov.comm_time(cum_params[i] - cum_params[s], r,
                                        rtt=0.0)
                    en_stage += (prov.comm_energy(
                        cum_params[i] - cum_params[s], r, rtt=0.0)
                        + radio_power * wt)
                en_stage += idle_rest * (lat_stage + wt)
                for prev in prevs:
                    # associate exactly like the scalar DP: (((prev + comm)
                    # + compute) + weights) — keeps the latency-minimal
                    # chain bit-identical to partition_model's
                    lat = prev[0] + comm_s + cseg
                    if wt:
                        lat += wt
                    cell = pareto_filter(
                        cell, (lat, prev[1] + en_stage, j, s, prev), cap)
            dp[j][i] = cell
            merged = list(best[j - 1][i])
            for st in cell:
                merged = pareto_filter(merged, st, cap)
            best[j][i] = merged

    finals: list = []
    for j in range(1, m + 1):
        r = res[j - 1]
        t_out = prov.comm_time(dag.output_bytes, r)
        e_out = (prov.comm_energy(dag.output_bytes, r)
                 + radio_power * t_out
                 + (idle_total - r.idle_power) * t_out)
        for st in dp[j][n]:
            finals = pareto_filter(
                finals, (st[0] + t_out, st[1] + e_out, st), cap=4 * cap)
    if not finals:
        raise RuntimeError("model-partition DP found no feasible plan")

    plans: list[ModelPartition] = []
    for lat, _en, st in finals:
        cuts: list[int] = [n]
        assign: list[int] = []
        while st[4] is not None:                 # until the zero state
            assign.append(order[st[2] - 1])
            cuts.append(st[3])
            st = st[4]
        cuts.reverse()
        assign.reverse()
        plans.append(ModelPartition(boundaries=tuple(cuts),
                                    assignment=tuple(assign),
                                    predicted_latency=lat))
    return plans


def partition_model_front(dag: ModelDAG, resources: Sequence[Resource],
                          *, weight_transfer: bool = False,
                          provider: CostProvider | None = None,
                          radio_power: float = 0.0,
                          width: int | None = None) -> ParetoFront:
    """The latency–energy frontier of heterogeneous pipeline partitions.

    Candidates are the frontier DP's final non-dominated chains *plus* the
    seed scalar DP's latency optimum, spliced in first so the front's
    ``latency_optimal`` point is bit-identical to :func:`partition_model`
    under the default objective.  Every candidate is re-priced uniformly by
    :func:`predicted_energy` (with ``radio_power`` on transfer seconds) and
    skyline-filtered."""
    prov = resolve_provider(provider)
    seed = partition_model(dag, resources, weight_transfer=weight_transfer,
                           provider=prov)
    cands = [p for p in _model_front_search(
        dag, resources, weight_transfer=weight_transfer, prov=prov,
        radio_power=radio_power)
        if (p.boundaries, p.assignment) != (seed.boundaries, seed.assignment)]

    def price(p):
        return (p.predicted_latency,
                predicted_energy(dag, resources, p, prov,
                                 radio_power=radio_power), p)

    return ParetoFront.build([price(p) for p in cands], anchor=price(seed),
                             width=width)


# --------------------------------------------------------------------------
# Data partitioning (σ parallel sub-models)
# --------------------------------------------------------------------------

def _balanced_fractions(dag: ModelDAG, subset: Sequence[Resource],
                        provider: CostProvider | None = None
                        ) -> tuple[tuple[float, ...], float]:
    """Water-fill data fractions so every resource finishes simultaneously.

    Per-resource time for fraction f:  t_i = f·(F/r_i + B_io/bw_i) + rtt_i
    Setting t_i = t for all i and Σf = 1 gives a closed form.
    """
    prov = resolve_provider(provider)
    # bytes shipped per unit fraction: the input split + merged output + the
    # halo exchange along the deepest halo block.
    halo = max((b.bytes_out * b.halo_fraction for b in dag.blocks), default=0.0)
    bio = dag.input_bytes + dag.output_bytes + 2.0 * halo
    coeffs = [prov.data_coeffs(dag, r) for r in subset]
    k = [lin + prov.comm_time(bio, r, rtt=0.0)
         for (lin, _), r in zip(coeffs, subset)]           # seconds per unit f
    c = [r.rtt + fixed for (_, fixed), r in zip(coeffs, subset)]
    # t = (1 + Σ c_i/k_i) / Σ (1/k_i); f_i = (t - c_i)/k_i
    inv = sum(1.0 / ki for ki in k)
    t = (1.0 + sum(ci / ki for ci, ki in zip(c, k))) / inv
    fr = [(t - ci) / ki for ci, ki in zip(c, k)]
    if any(f <= 0 for f in fr):           # a resource too slow to help
        return tuple(), float("inf")
    s = sum(fr)
    return tuple(f / s for f in fr), t


def partition_data(dag: ModelDAG, resources: Sequence[Resource],
                   *, provider: CostProvider | None = None,
                   objective: Objective | None = None) -> DataPartition:
    """Explore σ = 1..m sub-models over heterogeneity-ordered resources and
    keep the best balanced split (Eq. 6).  Blocks that are not
    data-splittable force σ = 1 (feasibility mask — e.g. recurrent decode
    state, see DESIGN.md §4).

    Each σ's split is water-filled so every participant finishes together
    (the latency-optimal division for that subset); the *objective* then
    selects between subsets over their frontier — under ``energy``/``edp``
    a smaller σ that keeps slow helpers idle (saving their active power and
    the shared medium's radio energy) can beat the latency-optimal wide
    split."""
    prov = resolve_provider(provider)
    obj = resolve_objective(objective)
    if not obj.is_latency:
        return partition_data_front(
            dag, resources, provider=prov,
            radio_power=obj.radio_power).select(obj)
    best: DataPartition | None = None
    for cand in _data_candidates(dag, resources, prov):
        if best is None or cand.predicted_latency < best.predicted_latency:
            best = cand
    if best is None:
        raise RuntimeError("data-partition search found no feasible plan")
    return best


def _data_candidates(dag: ModelDAG, resources: Sequence[Resource],
                     prov: CostProvider) -> list[DataPartition]:
    """One balanced candidate per σ = 1..m over heterogeneity-ordered
    resources (the seed enumeration, every subset kept)."""
    _, order = _heterogeneity_order(dag, resources, prov)
    if not all(b.data_splittable for b in dag.blocks):
        order = order[:1]
    out: list[DataPartition] = []
    for sigma in range(1, len(order) + 1):
        subset_idx = order[:sigma]
        subset = [resources[i] for i in subset_idx]
        fr, t = _balanced_fractions(dag, subset, prov)
        if not fr:
            continue
        out.append(DataPartition(fractions=fr, assignment=tuple(subset_idx),
                                 predicted_latency=t))
    return out


def partition_data_front(dag: ModelDAG, resources: Sequence[Resource],
                         *, provider: CostProvider | None = None,
                         radio_power: float = 0.0,
                         width: int | None = None) -> ParetoFront:
    """The latency–energy frontier over the σ = 1..m balanced splits.
    σ = 1 on the fastest resource is always feasible, so the front is never
    empty; the seed's latency winner is its ``latency_optimal`` point."""
    prov = resolve_provider(provider)
    cands = _data_candidates(dag, resources, prov)
    if not cands:
        raise RuntimeError("data-partition search found no feasible plan")
    # the seed latency winner (first σ on ties, as in partition_data)
    # anchors the latency endpoint
    seed = min(cands, key=lambda p: p.predicted_latency)
    points = [(p.predicted_latency,
               predicted_energy(dag, resources, p, prov,
                                radio_power=radio_power), p)
              for p in cands if p is not seed]
    anchor = (seed.predicted_latency,
              predicted_energy(dag, resources, seed, prov,
                               radio_power=radio_power), seed)
    return ParetoFront.build(points, anchor=anchor, width=width)


# --------------------------------------------------------------------------
# Mode selection — Algorithm 1 lines 4-6 / 8-10
# --------------------------------------------------------------------------

def partition(dag: ModelDAG, resources: Sequence[Resource],
              *, weight_transfer: bool = False,
              provider: CostProvider | None = None,
              objective: Objective | None = None) -> Partition:
    """Θ ← best(Θ_ω, Θ_σ): run both searches, return the better plan.

    With the default latency objective this is the paper's
    ``Θ = min(Θ_ω, Θ_σ)`` verbatim (model wins ties, as in the seed); any
    other objective *selects* over the merged frontier
    (:func:`partition_front`) — feasible-first under the latency budget,
    then metric-optimal."""
    obj = resolve_objective(objective)
    if not obj.is_latency:
        return partition_front(dag, resources,
                               weight_transfer=weight_transfer,
                               provider=provider,
                               radio_power=obj.radio_power).select(obj)
    theta_w = partition_model(dag, resources, weight_transfer=weight_transfer,
                              provider=provider)
    theta_s = partition_data(dag, resources, provider=provider)
    if theta_w.predicted_latency <= theta_s.predicted_latency:
        return theta_w
    return theta_s


def partition_front(dag: ModelDAG, resources: Sequence[Resource],
                    *, weight_transfer: bool = False,
                    provider: CostProvider | None = None,
                    radio_power: float = 0.0,
                    width: int | None = None) -> ParetoFront:
    """The merged latency–energy frontier over *both* partitioning modes.

    Model-mode points are inserted first, so an exact (latency, energy) tie
    keeps the model plan — the seed's ``Θ = min(Θ_ω, Θ_σ)`` tie rule.  The
    front's ``latency_optimal`` plan is therefore exactly what
    :func:`partition` returns under the default objective."""
    mf = partition_model_front(dag, resources,
                               weight_transfer=weight_transfer,
                               provider=provider, radio_power=radio_power)
    df = partition_data_front(dag, resources, provider=provider,
                              radio_power=radio_power)
    # Θ = min(Θ_ω, Θ_σ), model on ties — the seed's mode pick is the anchor
    anchor = (mf.latency_optimal
              if mf.latency_optimal.latency <= df.latency_optimal.latency
              else df.latency_optimal)
    return ParetoFront.build(list(mf) + list(df), anchor=anchor, width=width)


# --------------------------------------------------------------------------
# Energy prediction for a plan (used by the planners, simulator, benchmarks)
# --------------------------------------------------------------------------

def predicted_energy(dag: ModelDAG, resources: Sequence[Resource],
                     plan: Partition,
                     provider: CostProvider | None = None,
                     *, radio_power: float = 0.0) -> float:
    """∫P dt for one plan: active power while a resource computes or
    communicates, idle power for the rest of the plan's makespan.

    The active joules come from the provider's energy queries, so a
    calibrated provider prices them from *fitted* energy predictors while
    the analytic provider reproduces the seed's ``active_power × busy``
    algebra.  ``radio_power`` adds watts on total transfer seconds (the
    shared-medium transmit energy the simulator meters); it defaults to 0 so
    existing callers see the seed numerics unchanged."""
    prov = resolve_provider(provider)
    T = plan.predicted_latency
    busy: dict[int, float] = {}
    active: dict[int, float] = {}
    comm_s = 0.0
    if isinstance(plan, ModelPartition):
        for si in range(plan.num_stages):
            a, b = plan.boundaries[si], plan.boundaries[si + 1]
            ri = plan.assignment[si]
            r = resources[ri]
            seg = dag.segment(a, b)
            cm = prov.comm_time(seg.bytes_in, r)
            busy[ri] = busy.get(ri, 0.0) + (
                prov.compute_time(seg.flops, r, seg.kind) + cm)
            active[ri] = active.get(ri, 0.0) + (
                prov.compute_energy(seg.flops, r, seg.kind)
                + prov.comm_energy(seg.bytes_in, r))
            comm_s += cm
    else:
        kind = dag.dominant_kind()
        for f, ri in zip(plan.fractions, plan.assignment):
            r = resources[ri]
            nbytes = (dag.input_bytes + dag.output_bytes) * f
            cm = prov.comm_time(nbytes, r)
            busy[ri] = busy.get(ri, 0.0) + (
                prov.compute_time(dag.total_flops * f, r, kind) + cm)
            active[ri] = active.get(ri, 0.0) + (
                prov.compute_energy(dag.total_flops * f, r, kind)
                + prov.comm_energy(nbytes, r))
            comm_s += cm
    e = 0.0
    for i, r in enumerate(resources):
        b = busy.get(i, 0.0)
        ae = active.get(i, 0.0)
        if b > T and b > 0.0:
            ae *= T / b                   # clip active draw to the makespan
            b = T
        e += ae + r.idle_power * max(T - b, 0.0)
    return e + radio_power * comm_s
