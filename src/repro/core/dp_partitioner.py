"""The HiDP dynamic-programming partitioner (paper §III, Algorithm 1 lines 4-6
and 8-10).

The paper uses one DP routine at both tiers ("the function arguments are
essentially the same in either case including the DNN and the
computation-communication ratio"):

* **model partitioning** — choose cut points turning the block chain into
  contiguous *stages* of heterogeneous width ω, each assigned to one resource;
  the request flows stage → stage, paying an activation transfer at every cut.
  ``Θ_ω = γ·ω`` with γ = Ψ (global) or ψ (local)  — Eq. 5.

* **data partitioning** — choose σ parallel sub-models and per-resource data
  fractions; all resources run concurrently and the slowest finishes last.
  ``Θ_σ = γ·σ``  — Eq. 6.

* **mode selection** — ``Θ = min(Θ_ω, Θ_σ)``  (Alg. 1 line 6 / 10).

The model-partitioning search is an exact DP over (prefix of blocks ×
resources-used):  DP[i][j] = best latency executing blocks[:i] on the first j
resources of a heterogeneity-ordered list.  The paper describes this as a
subset-sum-style O(n·m) recursion seeded "with the largest possible block
sizes following the resource heterogeneity" and back-propagating block by
block; we implement the exact O(n²·m) variant (n = #blocks is small: ≤ ~200)
and keep the paper's heterogeneity-descending resource order, which makes the
greedy seed the DP's first feasible path.
"""

from __future__ import annotations

from typing import Sequence

from .cost_model import CostProvider, Resource, resolve_provider
from .dag import DataPartition, ModelDAG, ModelPartition, Partition


# --------------------------------------------------------------------------
# Model partitioning (pipeline over stages of width ω)
# --------------------------------------------------------------------------

def partition_model(dag: ModelDAG, resources: Sequence[Resource],
                    *, weight_transfer: bool = False,
                    provider: CostProvider | None = None) -> ModelPartition:
    """Exact DP for heterogeneous contiguous pipeline partitioning.

    Latency objective (single request, sequential stage execution — the
    paper's "inherently temporal" model partitioning):

        T = Σ_stages [ xfer_in(stage) + compute(stage) ]  + xfer_out(last)

    Resources are ordered by descending rate ("following the resource
    heterogeneity"); the DP may leave later (slower) resources unused, so the
    result uses between 1 and m stages with variable block widths.

    ``weight_transfer``: when True, shipping a stage to a non-leader resource
    also pays its ``param_bytes`` over that resource's link (cold start —
    used by the simulator's first-request path; steady-state serving keeps
    weights resident, the paper's implicit assumption).
    """
    n = len(dag.blocks)
    if n == 0:
        raise ValueError("empty DAG")
    prov = resolve_provider(provider)
    # order by the provider's view of the DAG's dominant kind — for the
    # analytic provider this is exactly the seed's rate ordering, for a
    # calibrated one it follows measured rates
    kind = dag.dominant_kind()
    order = sorted(range(len(resources)),
                   key=lambda i: -prov.effective_rate(resources[i], kind))
    res = [resources[i] for i in order]
    m = len(res)

    # Per-resource segment costers (O(1) via prefix sums).
    costers = [prov.segment_coster(dag, r) for r in res]
    cum_params = [0.0]
    for b in dag.blocks:
        cum_params.append(cum_params[-1] + b.param_bytes)

    def seg_params(a: int, b: int) -> float:
        return cum_params[b] - cum_params[a]

    INF = float("inf")
    # dp[j][i]: best latency for blocks[:i] using a subset of the first j
    # resources where resource j-1 runs the last stage ending at i.
    # best[j][i]: min over j'<=j of dp, i.e. blocks[:i] done within first j res.
    dp = [[INF] * (n + 1) for _ in range(m + 1)]
    best = [[INF] * (n + 1) for _ in range(m + 1)]
    parent: dict[tuple[int, int], tuple[int, int]] = {}
    for j in range(m + 1):
        dp[j][0] = 0.0
        best[j][0] = 0.0

    for j in range(1, m + 1):
        r = res[j - 1]
        coster = costers[j - 1]
        for i in range(1, n + 1):
            for s in range(i):
                prev = best[j - 1][s]
                if prev == INF:
                    continue
                xfer = dag.blocks[s].bytes_in if s > 0 else dag.input_bytes
                cost = (prev
                        + prov.comm_time(xfer, r)
                        + coster(s, i))
                if weight_transfer and j > 1:
                    cost += prov.comm_time(seg_params(s, i), r, rtt=0.0)
                if cost < dp[j][i]:
                    dp[j][i] = cost
                    parent[(j, i)] = (j - 1, s)
            best[j][i] = min(best[j - 1][i], dp[j][i])

    # Final answer: best over how many resources considered; add result return.
    end_j, end_cost = 0, INF
    for j in range(1, m + 1):
        if dp[j][n] < INF:
            c = dp[j][n] + prov.comm_time(dag.output_bytes, res[j - 1])
            if c < end_cost:
                end_cost, end_j = c, j
    if end_cost == INF:
        raise RuntimeError("model-partition DP found no feasible plan")

    # Back-propagate block by block (paper's phrasing) to recover cuts.
    cuts: list[int] = [n]
    assign: list[int] = []
    j, i = end_j, n
    while i > 0:
        # Walk down to the j whose dp achieved best[j][i] on this path.
        while j > 0 and (j, i) not in parent:
            j -= 1
        pj, s = parent[(j, i)]
        assign.append(order[j - 1])
        cuts.append(s)
        j, i = pj, s
    cuts.reverse()
    assign.reverse()
    return ModelPartition(boundaries=tuple(cuts), assignment=tuple(assign),
                          predicted_latency=end_cost)


# --------------------------------------------------------------------------
# Data partitioning (σ parallel sub-models)
# --------------------------------------------------------------------------

def _balanced_fractions(dag: ModelDAG, subset: Sequence[Resource],
                        provider: CostProvider | None = None
                        ) -> tuple[tuple[float, ...], float]:
    """Water-fill data fractions so every resource finishes simultaneously.

    Per-resource time for fraction f:  t_i = f·(F/r_i + B_io/bw_i) + rtt_i
    Setting t_i = t for all i and Σf = 1 gives a closed form.
    """
    prov = resolve_provider(provider)
    # bytes shipped per unit fraction: the input split + merged output + the
    # halo exchange along the deepest halo block.
    halo = max((b.bytes_out * b.halo_fraction for b in dag.blocks), default=0.0)
    bio = dag.input_bytes + dag.output_bytes + 2.0 * halo
    coeffs = [prov.data_coeffs(dag, r) for r in subset]
    k = [lin + prov.comm_time(bio, r, rtt=0.0)
         for (lin, _), r in zip(coeffs, subset)]           # seconds per unit f
    c = [r.rtt + fixed for (_, fixed), r in zip(coeffs, subset)]
    # t = (1 + Σ c_i/k_i) / Σ (1/k_i); f_i = (t - c_i)/k_i
    inv = sum(1.0 / ki for ki in k)
    t = (1.0 + sum(ci / ki for ci, ki in zip(c, k))) / inv
    fr = [(t - ci) / ki for ci, ki in zip(c, k)]
    if any(f <= 0 for f in fr):           # a resource too slow to help
        return tuple(), float("inf")
    s = sum(fr)
    return tuple(f / s for f in fr), t


def partition_data(dag: ModelDAG, resources: Sequence[Resource],
                   *, provider: CostProvider | None = None
                   ) -> DataPartition:
    """Explore σ = 1..m sub-models over heterogeneity-ordered resources and
    keep the fastest balanced split (Eq. 6).  Blocks that are not
    data-splittable force σ = 1 (feasibility mask — e.g. recurrent decode
    state, see DESIGN.md §4)."""
    prov = resolve_provider(provider)
    kind = dag.dominant_kind()
    order = sorted(range(len(resources)),
                   key=lambda i: -prov.effective_rate(resources[i], kind))
    if not all(b.data_splittable for b in dag.blocks):
        order = order[:1]
    best: DataPartition | None = None
    for sigma in range(1, len(order) + 1):
        subset_idx = order[:sigma]
        subset = [resources[i] for i in subset_idx]
        fr, t = _balanced_fractions(dag, subset, prov)
        if not fr:
            continue
        if best is None or t < best.predicted_latency:
            best = DataPartition(fractions=fr, assignment=tuple(subset_idx),
                                 predicted_latency=t)
    if best is None:
        raise RuntimeError("data-partition search found no feasible plan")
    return best


# --------------------------------------------------------------------------
# Mode selection — Algorithm 1 lines 4-6 / 8-10
# --------------------------------------------------------------------------

def partition(dag: ModelDAG, resources: Sequence[Resource],
              *, weight_transfer: bool = False,
              provider: CostProvider | None = None) -> Partition:
    """Θ ← min(Θ_ω, Θ_σ): run both searches, return the faster plan."""
    theta_w = partition_model(dag, resources, weight_transfer=weight_transfer,
                              provider=provider)
    theta_s = partition_data(dag, resources, provider=provider)
    if theta_w.predicted_latency <= theta_s.predicted_latency:
        return theta_w
    return theta_s


# --------------------------------------------------------------------------
# Energy prediction for a plan (used by the simulator and benchmarks)
# --------------------------------------------------------------------------

def predicted_energy(dag: ModelDAG, resources: Sequence[Resource],
                     plan: Partition,
                     provider: CostProvider | None = None) -> float:
    """∫P dt with active power while a resource computes/communicates and idle
    power for the rest of the plan's makespan."""
    prov = resolve_provider(provider)
    T = plan.predicted_latency
    if isinstance(plan, ModelPartition):
        busy = {}
        for si in range(plan.num_stages):
            a, b = plan.boundaries[si], plan.boundaries[si + 1]
            r = resources[plan.assignment[si]]
            seg = dag.segment(a, b)
            busy[plan.assignment[si]] = busy.get(plan.assignment[si], 0.0) + (
                prov.compute_time(seg.flops, r, seg.kind)
                + prov.comm_time(seg.bytes_in, r))
    else:
        busy = {}
        kind = dag.dominant_kind()
        for f, ri in zip(plan.fractions, plan.assignment):
            r = resources[ri]
            busy[ri] = (prov.compute_time(dag.total_flops * f, r, kind)
                        + prov.comm_time(
                            (dag.input_bytes + dag.output_bytes) * f, r))
    e = 0.0
    for i, r in enumerate(resources):
        b = min(busy.get(i, 0.0), T)
        e += r.active_power * b + r.idle_power * max(T - b, 0.0)
    return e
