"""The HiDP dynamic-programming partitioner (paper §III, Algorithm 1 lines 4-6
and 8-10).

The paper uses one DP routine at both tiers ("the function arguments are
essentially the same in either case including the DNN and the
computation-communication ratio"):

* **model partitioning** — choose cut points turning the block chain into
  contiguous *stages* of heterogeneous width ω, each assigned to one resource;
  the request flows stage → stage, paying an activation transfer at every cut.
  ``Θ_ω = γ·ω`` with γ = Ψ (global) or ψ (local)  — Eq. 5.

* **data partitioning** — choose σ parallel sub-models and per-resource data
  fractions; all resources run concurrently and the slowest finishes last.
  ``Θ_σ = γ·σ``  — Eq. 6.

* **mode selection** — ``Θ = min(Θ_ω, Θ_σ)``  (Alg. 1 line 6 / 10).

The model-partitioning search is an exact DP over (prefix of blocks ×
resources-used):  DP[i][j] = best latency executing blocks[:i] on the first j
resources of a heterogeneity-ordered list.  The paper describes this as a
subset-sum-style O(n·m) recursion seeded "with the largest possible block
sizes following the resource heterogeneity" and back-propagating block by
block; we implement the exact O(n²·m) variant (n = #blocks is small: ≤ ~200)
and keep the paper's heterogeneity-descending resource order, which makes the
greedy seed the DP's first feasible path.

Two engines implement the search (`set_engine` / ``REPRO_PLANNER_ENGINE``):

* ``"reference"`` — the seed's triple-nested pure-Python loops, verbatim.
* ``"fast"`` (default) — the same recurrences over numpy transition
  matrices, with per-resource DP rows and whole-call results cached in a
  :class:`repro.core.dp_cache.PlannerWorkspace`.  Costs enter the inner
  loops pre-computed but every accumulation keeps the reference's exact
  float64 association (``(prev + comm) + compute``, first-minimum ties),
  so both engines return **bit-identical** plans and frontiers — the
  property tests in ``tests/test_fast_planner.py`` pin this.
"""

from __future__ import annotations

import contextlib
import os
from typing import Sequence

import numpy as np

from .cost_model import CostProvider, Resource, resolve_provider
from .dag import DataPartition, ModelDAG, ModelPartition, Partition
from .dp_cache import PlannerWorkspace, heterogeneity_order, workspace_for
from .fingerprint import dag_fingerprint
from .objective import Objective, resolve_objective
from .pareto import ParetoFront, pareto_filter

# Per-cell frontier cap for the (latency, energy) DP search — the *search
# breadth* knob, distinct from a front's output ``width`` (how many points
# callers get back, e.g. ``PlannerConfig.front_width``).  Endpoints always
# survive thinning, so the cap trades interior resolution for speed.
DP_FRONT_CAP = 8


# --------------------------------------------------------------------------
# Engine selection — vectorized fast path vs pure-Python reference
# --------------------------------------------------------------------------

_ENGINES = ("fast", "reference")
_ENGINE = os.environ.get("REPRO_PLANNER_ENGINE", "fast")
if _ENGINE not in _ENGINES:
    _ENGINE = "fast"


def get_engine() -> str:
    """The active DP engine: ``"fast"`` (vectorized + cached) or
    ``"reference"`` (the seed's pure-Python loops)."""
    return _ENGINE


def set_engine(name: str) -> str:
    """Switch engines; returns the previous one.  Both produce bit-identical
    plans — the reference exists for regression testing and benchmarking."""
    if name not in _ENGINES:
        raise ValueError(f"unknown planner engine {name!r}; "
                         f"expected one of {_ENGINES}")
    global _ENGINE
    prev = _ENGINE
    _ENGINE = name
    return prev


@contextlib.contextmanager
def planner_engine(name: str):
    """Scoped engine override: ``with planner_engine("reference"): ...``."""
    prev = set_engine(name)
    try:
        yield
    finally:
        set_engine(prev)


def _heterogeneity_order(dag: ModelDAG, resources: Sequence[Resource],
                         prov: CostProvider
                         ) -> tuple[list[Resource], list[int]]:
    """Resources by descending effective rate for the DAG's dominant kind —
    the paper's "following the resource heterogeneity" seed order."""
    kind = dag.dominant_kind()
    order = sorted(range(len(resources)),
                   key=lambda i: -prov.effective_rate(resources[i], kind))
    return [resources[i] for i in order], order


# --------------------------------------------------------------------------
# Model partitioning (pipeline over stages of width ω)
# --------------------------------------------------------------------------

def partition_model(dag: ModelDAG, resources: Sequence[Resource],
                    *, weight_transfer: bool = False,
                    provider: CostProvider | None = None,
                    objective: Objective | None = None) -> ModelPartition:
    """Exact DP for heterogeneous contiguous pipeline partitioning.

    Latency objective (single request, sequential stage execution — the
    paper's "inherently temporal" model partitioning):

        T = Σ_stages [ xfer_in(stage) + compute(stage) ]  + xfer_out(last)

    Resources are ordered by descending rate ("following the resource
    heterogeneity"); the DP may leave later (slower) resources unused, so the
    result uses between 1 and m stages with variable block widths.

    ``weight_transfer``: when True, shipping a stage to a non-leader resource
    also pays its ``param_bytes`` over that resource's link (cold start —
    used by the simulator's first-request path; steady-state serving keeps
    weights resident, the paper's implicit assumption).

    ``objective``: how the winning plan is chosen.  The default (latency)
    runs the seed's scalar DP unchanged.  Any other objective selects over
    the plan frontier (:func:`partition_model_front`) — feasible-first under
    the latency budget, then metric-optimal — instead of scalarizing inside
    the recurrence.
    """
    n = len(dag.blocks)
    if n == 0:
        raise ValueError("empty DAG")
    prov = resolve_provider(provider)
    obj = resolve_objective(objective)
    if not obj.is_latency:
        return partition_model_front(
            dag, resources, weight_transfer=weight_transfer, provider=prov,
            radio_power=obj.radio_power).select(obj)
    if _ENGINE == "reference":
        return _partition_model_reference(dag, resources,
                                          weight_transfer=weight_transfer,
                                          prov=prov)
    return _partition_model_fast(dag, resources,
                                 weight_transfer=weight_transfer, prov=prov,
                                 ws=workspace_for(prov))


def _partition_model_reference(dag: ModelDAG, resources: Sequence[Resource],
                               *, weight_transfer: bool, prov: CostProvider
                               ) -> ModelPartition:
    """The seed's scalar DP, verbatim — the bit-identity oracle for
    :func:`_partition_model_fast`."""
    n = len(dag.blocks)
    # order by the provider's view of the DAG's dominant kind — for the
    # analytic provider this is exactly the seed's rate ordering, for a
    # calibrated one it follows measured rates
    res, order = _heterogeneity_order(dag, resources, prov)
    m = len(res)

    # Per-resource segment costers (O(1) via prefix sums).
    costers = [prov.segment_coster(dag, r) for r in res]
    cum_params = [0.0]
    for b in dag.blocks:
        cum_params.append(cum_params[-1] + b.param_bytes)

    def seg_params(a: int, b: int) -> float:
        return cum_params[b] - cum_params[a]

    INF = float("inf")
    # dp[j][i]: best latency for blocks[:i] using a subset of the first j
    # resources where resource j-1 runs the last stage ending at i.
    # best[j][i]: min over j'<=j of dp, i.e. blocks[:i] done within first j res.
    # bestj[j][i]: the j' achieving best[j][i] — so the backtrack can follow
    # the exact state chain instead of guessing which dp row realised it.
    dp = [[INF] * (n + 1) for _ in range(m + 1)]
    best = [[INF] * (n + 1) for _ in range(m + 1)]
    bestj = [[0] * (n + 1) for _ in range(m + 1)]
    parent: dict[tuple[int, int], int] = {}      # (j, i) → stage start s
    for j in range(m + 1):
        dp[j][0] = 0.0
        best[j][0] = 0.0

    for j in range(1, m + 1):
        r = res[j - 1]
        coster = costers[j - 1]
        for i in range(1, n + 1):
            for s in range(i):
                prev = best[j - 1][s]
                if prev == INF:
                    continue
                xfer = dag.blocks[s].bytes_in if s > 0 else dag.input_bytes
                cost = (prev
                        + prov.comm_time(xfer, r)
                        + coster(s, i))
                if weight_transfer and j > 1:
                    cost += prov.comm_time(seg_params(s, i), r, rtt=0.0)
                if cost < dp[j][i]:
                    dp[j][i] = cost
                    parent[(j, i)] = s
            if dp[j][i] < best[j - 1][i]:
                best[j][i], bestj[j][i] = dp[j][i], j
            else:
                best[j][i], bestj[j][i] = best[j - 1][i], bestj[j - 1][i]

    # Final answer: best over how many resources considered; add result return.
    end_j, end_cost = 0, INF
    for j in range(1, m + 1):
        if dp[j][n] < INF:
            c = dp[j][n] + prov.comm_time(dag.output_bytes, res[j - 1])
            if c < end_cost:
                end_cost, end_j = c, j
    if end_cost == INF:
        raise RuntimeError("model-partition DP found no feasible plan")

    # Back-propagate block by block (paper's phrasing) to recover cuts:
    # stage (s, i) runs on res j-1; the prefix blocks[:s] was realised by
    # the dp row bestj[j-1][s] that achieved best[j-1][s].
    cuts: list[int] = [n]
    assign: list[int] = []
    j, i = end_j, n
    while i > 0:
        s = parent[(j, i)]
        assign.append(order[j - 1])
        cuts.append(s)
        j, i = bestj[j - 1][s], s
    cuts.reverse()
    assign.reverse()
    return ModelPartition(boundaries=tuple(cuts), assignment=tuple(assign),
                          predicted_latency=end_cost)


# ----------------------------------------------------- fast-engine plumbing

def _cached_array(ws: PlannerWorkspace | None, key, build):
    """Fetch a setup array from the workspace (or build it uncached)."""
    if ws is None:
        return build()
    v = ws.arrays.get(key)
    if v is None:
        v = build()
        ws.arrays.put(key, v)
    return v


def _segment_matrix(prov: CostProvider, dag: ModelDAG,
                    r: Resource) -> np.ndarray:
    """``M[s, i] == segment_coster(dag, r)(s, i)`` — via the provider's
    vectorized method when it has one, else by evaluating the closure over
    the (cached-once) upper triangle."""
    fn = getattr(prov, "segment_cost_matrix", None)
    if fn is not None:
        return np.ascontiguousarray(fn(dag, r), dtype=np.float64)
    return _matrix_from_coster(prov.segment_coster(dag, r), len(dag.blocks))


def _energy_matrix(prov: CostProvider, dag: ModelDAG,
                   r: Resource) -> np.ndarray:
    fn = getattr(prov, "segment_energy_matrix", None)
    if fn is not None:
        return np.ascontiguousarray(fn(dag, r), dtype=np.float64)
    return _matrix_from_coster(prov.segment_energy_coster(dag, r),
                               len(dag.blocks))


def _matrix_from_coster(coster, n: int) -> np.ndarray:
    M = np.zeros((n + 1, n + 1), dtype=np.float64)
    for a in range(n + 1):
        row = M[a]
        for b in range(a + 1, n + 1):
            row[b] = coster(a, b)
    return M


def _xfer_bytes(dag: ModelDAG) -> list[float]:
    """Activation bytes entering a stage that starts at block ``s`` (the
    scalar DP's ``xfer``); index n is padding for the masked diagonal."""
    n = len(dag.blocks)
    return ([dag.input_bytes]
            + [dag.blocks[s].bytes_in for s in range(1, n)] + [0.0])


def _comm_vector(prov: CostProvider, dag: ModelDAG,
                 r: Resource) -> np.ndarray:
    """``v[s] == prov.comm_time(xfer(s), r)`` for every stage start s."""
    xfer = _xfer_bytes(dag)
    fn = getattr(prov, "comm_time_array", None)
    v = fn(np.asarray(xfer, dtype=np.float64), r) if fn is not None else None
    if v is None:
        v = np.asarray([prov.comm_time(x, r) for x in xfer],
                       dtype=np.float64)
    return v


def _comm_energy_vector(prov: CostProvider, dag: ModelDAG,
                        r: Resource) -> np.ndarray:
    xfer = _xfer_bytes(dag)
    fn = getattr(prov, "comm_energy_array", None)
    v = fn(np.asarray(xfer, dtype=np.float64), r) if fn is not None else None
    if v is None:
        v = np.asarray([prov.comm_energy(x, r) for x in xfer],
                       dtype=np.float64)
    return v


def _cum_params(dag: ModelDAG) -> np.ndarray:
    pre = [0.0]
    for b in dag.blocks:
        pre.append(pre[-1] + b.param_bytes)
    return np.asarray(pre, dtype=np.float64)


def _weight_matrix(prov: CostProvider, dag: ModelDAG,
                   r: Resource) -> np.ndarray:
    """``W[s, i] == prov.comm_time(seg_params(s, i), r, rtt=0.0)``."""
    cp = _cum_params(dag)
    seg = cp[None, :] - cp[:, None]
    fn = getattr(prov, "comm_time_array", None)
    W = fn(seg, r, 0.0) if fn is not None else None
    if W is None:
        n = len(dag.blocks)
        W = np.zeros((n + 1, n + 1), dtype=np.float64)
        for a in range(n + 1):
            for b in range(a + 1, n + 1):
                W[a, b] = prov.comm_time(float(seg[a, b]), r, rtt=0.0)
    return W


def _weight_energy_matrix(prov: CostProvider, dag: ModelDAG,
                          r: Resource) -> np.ndarray:
    cp = _cum_params(dag)
    seg = cp[None, :] - cp[:, None]
    fn = getattr(prov, "comm_energy_array", None)
    WE = fn(seg, r, 0.0) if fn is not None else None
    if WE is None:
        n = len(dag.blocks)
        WE = np.zeros((n + 1, n + 1), dtype=np.float64)
        for a in range(n + 1):
            for b in range(a + 1, n + 1):
                WE[a, b] = prov.comm_energy(float(seg[a, b]), r, rtt=0.0)
    return WE


def _partition_model_fast(dag: ModelDAG, resources: Sequence[Resource],
                          *, weight_transfer: bool, prov: CostProvider,
                          ws: PlannerWorkspace | None) -> ModelPartition:
    """The scalar DP as a per-resource matrix recurrence.

    Row j over all cells at once: ``M[s, i] = (best[j-1][s] + comm[s]) +
    C[s, i] (+ W[s, i])`` masked to s < i; ``dp[j] = M.min(axis=0)`` and
    ``parent[j] = M.argmin(axis=0)`` (numpy's first-minimum matches the
    reference's strict-less replacement, so ties pick the same s).  Each
    addition keeps the reference's left-to-right association, so every cell
    — and the backtracked plan — is bit-identical to
    :func:`_partition_model_reference`.

    Rows are cached in the workspace keyed by the ordered resource
    *prefix*: row j depends only on ``res[:j]``, so a membership epoch that
    removes the node at order position k recomputes only rows ≥ k, and a
    repeated call recomputes nothing (the whole result is memoized too).
    """
    n = len(dag.blocks)
    dfp = dag_fingerprint(dag)
    rkey = ("pm", dfp, tuple(resources), weight_transfer)
    if ws is not None:
        memo = ws.results.get(rkey)
        if memo is not None:
            return memo
    res, order = heterogeneity_order(ws, dag, resources, prov, dfp)
    m = len(res)
    INF = float("inf")
    mask = (ws.valid_mask(n) if ws is not None
            else np.triu(np.ones((n + 1, n + 1), dtype=bool), k=1))

    best_row = np.full(n + 1, np.inf)
    best_row[0] = 0.0
    bestj_row = np.zeros(n + 1, dtype=np.int64)
    rows: list[tuple] = []
    prefix: tuple = ()
    for j in range(1, m + 1):
        r = res[j - 1]
        prefix = prefix + (r,)
        key = ("srow", dfp, weight_transfer, prefix)
        rec = ws.scalar_rows.get(key) if ws is not None else None
        if rec is None:
            C = _cached_array(ws, ("C", dfp, r),
                              lambda: _segment_matrix(prov, dag, r))
            comm = _cached_array(ws, ("comm", dfp, r),
                                 lambda: _comm_vector(prov, dag, r))
            M = (best_row + comm)[:, None] + C
            if weight_transfer and j > 1:
                W = _cached_array(ws, ("W", dfp, r),
                                  lambda: _weight_matrix(prov, dag, r))
                M = M + W
            M = np.where(mask, M, np.inf)
            dp_row = M.min(axis=0)
            parent_row = M.argmin(axis=0)
            dp_row[0] = 0.0
            better = dp_row < best_row
            rec = (dp_row, np.where(better, dp_row, best_row),
                   np.where(better, j, bestj_row).astype(np.int64),
                   parent_row)
            if ws is not None:
                ws.scalar_rows.put(key, rec)
                ws.rows_computed += 1
        elif ws is not None:
            ws.rows_reused += 1
        rows.append(rec)
        best_row, bestj_row = rec[1], rec[2]

    end_j, end_cost = 0, INF
    for j in range(1, m + 1):
        v = rows[j - 1][0][n]
        if v < INF:
            c = float(v) + prov.comm_time(dag.output_bytes, res[j - 1])
            if c < end_cost:
                end_cost, end_j = c, j
    if end_cost == INF:
        raise RuntimeError("model-partition DP found no feasible plan")

    cuts: list[int] = [n]
    assign: list[int] = []
    j, i = end_j, n
    while i > 0:
        s = int(rows[j - 1][3][i])
        assign.append(order[j - 1])
        cuts.append(s)
        j, i = (int(rows[j - 2][2][s]) if j >= 2 else 0), s
    cuts.reverse()
    assign.reverse()
    plan = ModelPartition(boundaries=tuple(cuts), assignment=tuple(assign),
                          predicted_latency=end_cost)
    if ws is not None:
        ws.results.put(rkey, plan)
    return plan


def _model_front_search(dag: ModelDAG, resources: Sequence[Resource],
                        *, weight_transfer: bool, prov: CostProvider,
                        radio_power: float,
                        cap: int = DP_FRONT_CAP) -> list[ModelPartition]:
    """The (latency, energy)-pair DP, keeping a *frontier* per cell.

    Same state space and transitions as the scalar DP; each state carries
    the prefix's accumulated latency *and* energy, and every cell keeps a
    capped non-dominated set of states instead of one scalarized winner.
    Energy is stage-additive: while one pipeline stage runs, its resource
    draws active power and every *other* resource draws idle power, so
    stage energy = active J + (Σ idle − own idle) × stage seconds
    (identically the algebra of :func:`predicted_energy`, unrolled per
    stage), plus ``radio_power`` watts on wireless transfer seconds.

    States are linked records ``(lat, en, j, s, prev)`` — each points at
    its exact predecessor, so reconstruction replays the very chain whose
    cost was reported.  Latency accumulates with the same association as
    the scalar DP (``prev + comm + compute``), so the latency-minimal chain
    here is float-identical to the scalar DP's plan.  Returns the distinct
    partitions realising the final non-dominated states; callers re-price
    them uniformly and skyline-filter.

    Dispatches on the active engine; both produce bit-identical results.
    """
    if _ENGINE == "reference":
        return _model_front_search_reference(
            dag, resources, weight_transfer=weight_transfer, prov=prov,
            radio_power=radio_power, cap=cap)
    return _model_front_search_fast(
        dag, resources, weight_transfer=weight_transfer, prov=prov,
        radio_power=radio_power, cap=cap, ws=workspace_for(prov))


def _model_front_search_reference(
        dag: ModelDAG, resources: Sequence[Resource],
        *, weight_transfer: bool, prov: CostProvider, radio_power: float,
        cap: int = DP_FRONT_CAP) -> list[ModelPartition]:
    """The seed's frontier DP, verbatim — the bit-identity oracle for
    :func:`_model_front_search_fast`."""
    n, m = len(dag.blocks), len(resources)
    res, order = _heterogeneity_order(dag, resources, prov)
    costers = [prov.segment_coster(dag, r) for r in res]
    ecosters = [prov.segment_energy_coster(dag, r) for r in res]
    cum_params = [0.0]
    for b in dag.blocks:
        cum_params.append(cum_params[-1] + b.param_bytes)
    idle_total = sum(r.idle_power for r in resources)

    # dp[j][i]: frontier of states whose last stage ends at i on res j-1;
    # best[j][i]: frontier over all dp[j'][i], j' <= j.
    zero = (0.0, 0.0, 0, 0, None)
    dp: list[list[list]] = [[[] for _ in range(n + 1)] for _ in range(m + 1)]
    best: list[list[list]] = [[[] for _ in range(n + 1)]
                              for _ in range(m + 1)]
    for j in range(m + 1):
        dp[j][0] = [zero]
        best[j][0] = [zero]

    for j in range(1, m + 1):
        r = res[j - 1]
        coster, ecoster = costers[j - 1], ecosters[j - 1]
        idle_rest = idle_total - r.idle_power
        for i in range(1, n + 1):
            cell: list = []
            for s in range(i):
                prevs = best[j - 1][s]
                if not prevs:
                    continue
                xfer = dag.blocks[s].bytes_in if s > 0 else dag.input_bytes
                comm_s = prov.comm_time(xfer, r)
                cseg = coster(s, i)
                lat_stage = comm_s + cseg
                en_stage = (prov.comm_energy(xfer, r) + ecoster(s, i)
                            + radio_power * comm_s)
                wt = 0.0
                if weight_transfer and j > 1:
                    wt = prov.comm_time(cum_params[i] - cum_params[s], r,
                                        rtt=0.0)
                    en_stage += (prov.comm_energy(
                        cum_params[i] - cum_params[s], r, rtt=0.0)
                        + radio_power * wt)
                en_stage += idle_rest * (lat_stage + wt)
                for prev in prevs:
                    # associate exactly like the scalar DP: (((prev + comm)
                    # + compute) + weights) — keeps the latency-minimal
                    # chain bit-identical to partition_model's
                    lat = prev[0] + comm_s + cseg
                    if wt:
                        lat += wt
                    cell = pareto_filter(
                        cell, (lat, prev[1] + en_stage, j, s, prev), cap)
            dp[j][i] = cell
            merged = list(best[j - 1][i])
            for st in cell:
                merged = pareto_filter(merged, st, cap)
            best[j][i] = merged

    finals: list = []
    for j in range(1, m + 1):
        r = res[j - 1]
        t_out = prov.comm_time(dag.output_bytes, r)
        e_out = (prov.comm_energy(dag.output_bytes, r)
                 + radio_power * t_out
                 + (idle_total - r.idle_power) * t_out)
        for st in dp[j][n]:
            finals = pareto_filter(
                finals, (st[0] + t_out, st[1] + e_out, st), cap=4 * cap)
    if not finals:
        raise RuntimeError("model-partition DP found no feasible plan")

    plans: list[ModelPartition] = []
    for lat, _en, st in finals:
        cuts: list[int] = [n]
        assign: list[int] = []
        while st[4] is not None:                 # until the zero state
            assign.append(order[st[2] - 1])
            cuts.append(st[3])
            st = st[4]
        cuts.reverse()
        assign.reverse()
        plans.append(ModelPartition(boundaries=tuple(cuts),
                                    assignment=tuple(assign),
                                    predicted_latency=lat))
    return plans


def _model_front_search_fast(
        dag: ModelDAG, resources: Sequence[Resource],
        *, weight_transfer: bool, prov: CostProvider, radio_power: float,
        cap: int, ws: PlannerWorkspace | None) -> list[ModelPartition]:
    """The frontier DP with pre-computed transition costs and cached rows.

    The capped per-cell insertion (``pareto_filter``) is *order-dependent*
    — latency-gap thinning at intermediate overflows depends on arrival
    order — so the cell update cannot be batch-vectorized without changing
    results.  Instead the fast path (1) pre-computes every per-(s, i)
    stage cost as numpy matrices converted once to Python-float lists
    (keeping the reference's exact association, e.g. stage energy is
    ``((comm_en + seg_en) + radio·comm) [+ (wt_en + radio·wt)] +
    idle_rest·((comm + seg) + wt)``), (2) screens whole predecessor groups
    with an exact corner test — the (min-lat, min-en) corner over a
    predecessor list lower-bounds every candidate it generates, and a cell
    point weakly dominating the corner rejects them all, exactly as the
    reference's per-candidate weak-dominance check would one by one — and
    (3) caches finished rows keyed by the ordered resource *prefix* (plus
    the flags and the cluster idle-power total the stage energies bake
    in), so repeated and incremental passes replay instead of re-search.

    States, insertion order, tie-breaks, and caps are identical to the
    reference, so the surviving skylines are bit-identical.
    """
    n, m = len(dag.blocks), len(resources)
    dfp = dag_fingerprint(dag)
    idle_total = sum(r.idle_power for r in resources)
    rkey = ("mfs", dfp, tuple(resources), weight_transfer, radio_power, cap)
    if ws is not None:
        memo = ws.results.get(rkey)
        if memo is not None:
            return memo
    res, order = heterogeneity_order(ws, dag, resources, prov, dfp)

    zero = (0.0, 0.0, 0, 0, None)
    best_prev: list[list] = [[zero]] + [[] for _ in range(n)]
    dp_rows: list[list[list]] = []
    prefix: tuple = ()
    for j in range(1, m + 1):
        r = res[j - 1]
        prefix = prefix + (r,)
        key = ("frow", dfp, weight_transfer, radio_power, cap, idle_total,
               prefix)
        rec = ws.front_rows.get(key) if ws is not None else None
        if rec is None:
            wt_active = weight_transfer and j > 1
            idle_rest = idle_total - r.idle_power
            if all(len(c) <= 1 for c in best_prev):
                rec = _front_row_singleton(
                    ws, dag, r, prov, dfp=dfp, j=j, n=n,
                    wt_active=wt_active, radio_power=radio_power,
                    idle_rest=idle_rest, best_prev=best_prev, cap=cap,
                    zero=zero)
            else:
                rec = _front_row_general(
                    ws, dag, r, prov, dfp=dfp, j=j, n=n,
                    wt_active=wt_active, radio_power=radio_power,
                    idle_rest=idle_rest, best_prev=best_prev, cap=cap,
                    zero=zero)
            if ws is not None:
                ws.front_rows.put(key, rec)
                ws.rows_computed += 1
        elif ws is not None:
            ws.rows_reused += 1
        dp_rows.append(rec[0])
        best_prev = rec[1]

    finals: list = []
    for j in range(1, m + 1):
        r = res[j - 1]
        t_out = prov.comm_time(dag.output_bytes, r)
        e_out = (prov.comm_energy(dag.output_bytes, r)
                 + radio_power * t_out
                 + (idle_total - r.idle_power) * t_out)
        for st in dp_rows[j - 1][n]:
            finals = pareto_filter(
                finals, (st[0] + t_out, st[1] + e_out, st), cap=4 * cap)
    if not finals:
        raise RuntimeError("model-partition DP found no feasible plan")

    plans: list[ModelPartition] = []
    for lat, _en, st in finals:
        cuts: list[int] = [n]
        assign: list[int] = []
        while st[4] is not None:                 # until the zero state
            assign.append(order[st[2] - 1])
            cuts.append(st[3])
            st = st[4]
        cuts.reverse()
        assign.reverse()
        plans.append(ModelPartition(boundaries=tuple(cuts),
                                    assignment=tuple(assign),
                                    predicted_latency=lat))
    if ws is not None:
        ws.results.put(rkey, plans)
    return plans


def _front_energy_array(ws: PlannerWorkspace | None, dag: ModelDAG,
                        r: Resource, prov: CostProvider, *, dfp: str,
                        radio_power: float, wt_active: bool,
                        idle_rest: float) -> np.ndarray:
    """Per-(s, i) stage energy for the frontier DP.

    Mirrors the reference's accumulation exactly:
    ``en = (comm_en + seg_en) + radio·comm``, then ``+ (wt_en + radio·wt)``
    under weight transfer, then ``+ idle_rest · ((comm + seg) + wt)``.
    The idle-independent part is cached per resource; the idle term
    depends on the cluster's total idle power, so it folds in per call
    (one fused numpy op) and the finished table is cached on the row key's
    ``idle_total`` via the front-row cache."""
    def build_pre():
        comm = _comm_vector(prov, dag, r)
        en = (_comm_energy_vector(prov, dag, r)[:, None]
              + _energy_matrix(prov, dag, r)) \
            + (radio_power * comm)[:, None]
        lat = comm[:, None] + _segment_matrix(prov, dag, r)
        if wt_active:
            W = _weight_matrix(prov, dag, r)
            en = en + (_weight_energy_matrix(prov, dag, r) + radio_power * W)
            lat = lat + W
        return en, lat
    en_pre, lat_tot = _cached_array(
        ws, ("ENpre", dfp, r, radio_power, wt_active), build_pre)
    return en_pre + idle_rest * lat_tot


def _front_row_singleton(ws: PlannerWorkspace | None, dag: ModelDAG,
                         r: Resource, prov: CostProvider, *, dfp: str,
                         j: int, n: int, wt_active: bool, radio_power: float,
                         idle_rest: float, best_prev: list, cap: int,
                         zero: tuple) -> tuple:
    """One frontier-DP row where every predecessor cell holds at most one
    state — the common shape (benchmark clusters never leave it), solved
    almost entirely in numpy.

    With singleton predecessors, cell (j, i) sees exactly one candidate per
    start s, so all candidate coordinates form two matrices computed with
    the reference's own per-element association (``(prev + comm) + seg
    [+ wt]`` for latency, ``prev + stage`` for energy — bit-identical
    float64 ops).  The sequential capped insertion then has a closed form
    for most columns.  While every arriving candidate stays *comparable*
    with the running occupant, the occupant after start s is exactly the
    pair of exclusive running minima ``(min lat, min en)`` over starts
    before s — a swap lowers both coordinates to the new joint minimum, a
    rejection lowers neither — so a column with no incomparable arrival
    (checked vectorized against those same exclusive cummins, which *are*
    the occupant up to the first violation) finishes as a single state:
    the joint coordinate-wise minimum, attributed to the earliest start
    achieving both.  Columns where a genuine latency–energy trade-off
    appears (rare) are replayed sequentially — the reference algorithm on
    the pre-tabulated candidate values — so caps, thinning, and tie
    preference behave identically in every regime."""
    p0 = np.array([c[0][0] if c else np.inf for c in best_prev])
    pE = np.array([c[0][1] if c else np.inf for c in best_prev])
    comm = _cached_array(ws, ("comm", dfp, r),
                         lambda: _comm_vector(prov, dag, r))
    LAT = (p0 + comm)[:, None] + _cached_array(
        ws, ("C", dfp, r), lambda: _segment_matrix(prov, dag, r))
    if wt_active:
        LAT = LAT + _cached_array(ws, ("W", dfp, r),
                                  lambda: _weight_matrix(prov, dag, r))
    EN = pE[:, None] + _front_energy_array(
        ws, dag, r, prov, dfp=dfp, radio_power=radio_power,
        wt_active=wt_active, idle_rest=idle_rest)
    mask = (ws.valid_mask(n) if ws is not None
            else np.triu(np.ones((n + 1, n + 1), dtype=bool), k=1))
    inf = np.inf
    LATm = np.where(mask, LAT, inf)
    ENm = np.where(mask, EN, inf)
    accL = np.minimum.accumulate(LATm, axis=0)
    accE = np.minimum.accumulate(ENm, axis=0)
    exL = np.empty_like(accL)
    exL[0] = inf
    exL[1:] = accL[:-1]
    exE = np.empty_like(accE)
    exE[0] = inf
    exE[1:] = accE[:-1]
    event = (((LATm < exL) & (ENm > exE))
             | ((LATm > exL) & (ENm < exE))).any(axis=0)
    minL = np.empty(n + 1)
    minL[0] = inf
    minL[1:] = np.diagonal(accL, offset=1)
    minE = np.empty(n + 1)
    minE[0] = inf
    minE[1:] = np.diagonal(accE, offset=1)
    src = (((LATm == minL) & (ENm == minE)).argmax(axis=0))
    eventl = event.tolist()
    minLl = minL.tolist()
    minEl = minE.tolist()
    srcl = src.tolist()
    prev0s = [c[0] if c else None for c in best_prev]
    valid = [s for s in range(n + 1) if best_prev[s]]
    tail = valid[1:]                       # s = 0 is always valid and first
    dp_cells: list[list] = [[zero]] + [None] * n
    best_cells: list[list] = [[zero]] + [None] * n
    pf = pareto_filter
    for i in range(1, n + 1):
        if not eventl[i]:
            s0 = srcl[i]
            cell = [(minLl[i], minEl[i], j, s0, prev0s[s0])]
        else:
            # a latency–energy trade-off appeared: replay this column's
            # sequential insertion exactly (O(1) while the cell is a
            # single state, pareto_filter once it widens)
            li = LAT[:i, i].tolist()
            ei = EN[:i, i].tolist()
            ol = li[0]
            oe = ei[0]
            osrc = 0
            cell = None
            for s in tail:
                if s >= i:
                    break
                lat = li[s]
                if cell is None:
                    en = ei[s]
                    if ol <= lat and oe <= en:
                        continue                   # dominated by occupant
                    if lat <= ol and en <= oe:
                        ol, oe, osrc = lat, en, s  # occupant replaced
                        continue
                    ost = (ol, oe, j, osrc, prev0s[osrc])
                    nst = (lat, en, j, s, prev0s[s])
                    cell = [ost, nst] if ol < lat else [nst, ost]
                else:
                    cell = pf(cell, (lat, ei[s], j, s, prev0s[s]), cap)
            if cell is None:
                cell = [(ol, oe, j, osrc, prev0s[osrc])]
        dp_cells[i] = cell
        bp = best_prev[i]
        if not bp:
            best_cells[i] = list(cell)
        elif len(bp) == 1 and len(cell) == 1:
            q, c = bp[0], cell[0]
            if q[0] <= c[0] and q[1] <= c[1]:
                best_cells[i] = list(bp)
            elif c[0] <= q[0] and c[1] <= q[1]:
                best_cells[i] = [c]
            else:
                best_cells[i] = [q, c] if q[0] < c[0] else [c, q]
        else:
            merged = list(bp)
            for st in cell:
                merged = pf(merged, st, cap)
            best_cells[i] = merged
    return dp_cells, best_cells


def _front_row_general(ws: PlannerWorkspace | None, dag: ModelDAG,
                       r: Resource, prov: CostProvider, *, dfp: str,
                       j: int, n: int, wt_active: bool, radio_power: float,
                       idle_rest: float, best_prev: list, cap: int,
                       zero: tuple) -> tuple:
    """One frontier-DP row with multi-state predecessor cells — the exact
    sequential insertion over pre-tabulated stage costs, plus the corner
    screen (evaluated at arrival time, so unaffected by later thinning)."""
    # stage-cost tables as Python floats (bit-exact float64 → float)
    CL = _cached_array(
        ws, ("commL", dfp, r),
        lambda: _comm_vector(prov, dag, r).tolist())
    C2 = _cached_array(
        ws, ("CL", dfp, r),
        lambda: _segment_matrix(prov, dag, r).tolist())
    WL = (_cached_array(
        ws, ("WL", dfp, r),
        lambda: _weight_matrix(prov, dag, r).tolist())
        if wt_active else None)
    EN = _front_energy_array(ws, dag, r, prov, dfp=dfp,
                             radio_power=radio_power,
                             wt_active=wt_active,
                             idle_rest=idle_rest).tolist()
    dp_cells: list[list] = [[zero]] + [[] for _ in range(n)]
    best_cells: list[list] = [[zero]] + [[] for _ in range(n)]
    for i in range(1, n + 1):
        cell: list = []
        for s in range(i):
            prevs = best_prev[s]
            if not prevs:
                continue
            cl = CL[s]
            cs = C2[s][i]
            wtv = WL[s][i] if wt_active else 0.0
            es = EN[s][i]
            if cell:
                # exact group screen: the corner lower-bounds every
                # candidate from prevs; a cell point weakly dominating it
                # rejects them all (what the reference's insert would do
                # candidate by candidate)
                lo_lat = prevs[0][0] + cl + cs
                if wtv:
                    lo_lat += wtv
                lo_en = prevs[-1][1] + es
                skip = False
                for q in cell:
                    if q[0] <= lo_lat and q[1] <= lo_en:
                        skip = True
                        break
                if skip:
                    continue
            for prev in prevs:
                lat = prev[0] + cl + cs
                if wtv:
                    lat += wtv
                cell = pareto_filter(
                    cell, (lat, prev[1] + es, j, s, prev), cap)
        dp_cells[i] = cell
        if cell:
            merged = list(best_prev[i])
            for st in cell:
                merged = pareto_filter(merged, st, cap)
            best_cells[i] = merged
        else:
            best_cells[i] = best_prev[i]
    return dp_cells, best_cells


def partition_model_front(dag: ModelDAG, resources: Sequence[Resource],
                          *, weight_transfer: bool = False,
                          provider: CostProvider | None = None,
                          radio_power: float = 0.0,
                          width: int | None = None) -> ParetoFront:
    """The latency–energy frontier of heterogeneous pipeline partitions.

    Candidates are the frontier DP's final non-dominated chains *plus* the
    seed scalar DP's latency optimum, spliced in first so the front's
    ``latency_optimal`` point is bit-identical to :func:`partition_model`
    under the default objective.  Every candidate is re-priced uniformly by
    :func:`predicted_energy` (with ``radio_power`` on transfer seconds) and
    skyline-filtered."""
    prov = resolve_provider(provider)
    ws = workspace_for(prov) if _ENGINE == "fast" else None
    if ws is not None:
        rkey = ("pmf", dag_fingerprint(dag), tuple(resources),
                weight_transfer, radio_power, width)
        memo = ws.results.get(rkey)
        if memo is not None:
            return memo
    seed = partition_model(dag, resources, weight_transfer=weight_transfer,
                           provider=prov)
    cands = [p for p in _model_front_search(
        dag, resources, weight_transfer=weight_transfer, prov=prov,
        radio_power=radio_power)
        if (p.boundaries, p.assignment) != (seed.boundaries, seed.assignment)]

    def price(p):
        return (p.predicted_latency,
                predicted_energy(dag, resources, p, prov,
                                 radio_power=radio_power), p)

    front = ParetoFront.build([price(p) for p in cands], anchor=price(seed),
                              width=width)
    if ws is not None:
        ws.results.put(rkey, front)
    return front


# --------------------------------------------------------------------------
# Data partitioning (σ parallel sub-models)
# --------------------------------------------------------------------------

def _balanced_fractions(dag: ModelDAG, subset: Sequence[Resource],
                        provider: CostProvider | None = None
                        ) -> tuple[tuple[float, ...], float]:
    """Water-fill data fractions so every resource finishes simultaneously.

    Per-resource time for fraction f:  t_i = f·(F/r_i + B_io/bw_i) + rtt_i
    Setting t_i = t for all i and Σf = 1 gives a closed form.
    """
    prov = resolve_provider(provider)
    # bytes shipped per unit fraction: the input split + merged output + the
    # halo exchange along the deepest halo block.
    halo = max((b.bytes_out * b.halo_fraction for b in dag.blocks), default=0.0)
    bio = dag.input_bytes + dag.output_bytes + 2.0 * halo
    coeffs = [prov.data_coeffs(dag, r) for r in subset]
    k = [lin + prov.comm_time(bio, r, rtt=0.0)
         for (lin, _), r in zip(coeffs, subset)]           # seconds per unit f
    c = [r.rtt + fixed for (_, fixed), r in zip(coeffs, subset)]
    # t = (1 + Σ c_i/k_i) / Σ (1/k_i); f_i = (t - c_i)/k_i
    inv = sum(1.0 / ki for ki in k)
    t = (1.0 + sum(ci / ki for ci, ki in zip(c, k))) / inv
    fr = [(t - ci) / ki for ci, ki in zip(c, k)]
    if any(f <= 0 for f in fr):           # a resource too slow to help
        return tuple(), float("inf")
    s = sum(fr)
    return tuple(f / s for f in fr), t


def partition_data(dag: ModelDAG, resources: Sequence[Resource],
                   *, provider: CostProvider | None = None,
                   objective: Objective | None = None) -> DataPartition:
    """Explore σ = 1..m sub-models over heterogeneity-ordered resources and
    keep the best balanced split (Eq. 6).  Blocks that are not
    data-splittable force σ = 1 (feasibility mask — e.g. recurrent decode
    state, see DESIGN.md §4).

    Each σ's split is water-filled so every participant finishes together
    (the latency-optimal division for that subset); the *objective* then
    selects between subsets over their frontier — under ``energy``/``edp``
    a smaller σ that keeps slow helpers idle (saving their active power and
    the shared medium's radio energy) can beat the latency-optimal wide
    split."""
    prov = resolve_provider(provider)
    obj = resolve_objective(objective)
    if not obj.is_latency:
        return partition_data_front(
            dag, resources, provider=prov,
            radio_power=obj.radio_power).select(obj)
    best: DataPartition | None = None
    for cand in _data_candidates(dag, resources, prov):
        if best is None or cand.predicted_latency < best.predicted_latency:
            best = cand
    if best is None:
        raise RuntimeError("data-partition search found no feasible plan")
    return best


def _data_candidates(dag: ModelDAG, resources: Sequence[Resource],
                     prov: CostProvider) -> list[DataPartition]:
    """One balanced candidate per σ = 1..m over heterogeneity-ordered
    resources (the seed enumeration, every subset kept)."""
    ws = workspace_for(prov) if _ENGINE == "fast" else None
    if ws is not None:
        rkey = ("dc", dag_fingerprint(dag), tuple(resources))
        memo = ws.results.get(rkey)
        if memo is not None:
            return memo
        _, order = heterogeneity_order(ws, dag, resources, prov)
    else:
        _, order = _heterogeneity_order(dag, resources, prov)
    if not all(b.data_splittable for b in dag.blocks):
        order = order[:1]
    out: list[DataPartition] = []
    for sigma in range(1, len(order) + 1):
        subset_idx = order[:sigma]
        subset = [resources[i] for i in subset_idx]
        fr, t = _balanced_fractions(dag, subset, prov)
        if not fr:
            continue
        out.append(DataPartition(fractions=fr, assignment=tuple(subset_idx),
                                 predicted_latency=t))
    if ws is not None:
        ws.results.put(rkey, out)
    return out


def partition_data_front(dag: ModelDAG, resources: Sequence[Resource],
                         *, provider: CostProvider | None = None,
                         radio_power: float = 0.0,
                         width: int | None = None) -> ParetoFront:
    """The latency–energy frontier over the σ = 1..m balanced splits.
    σ = 1 on the fastest resource is always feasible, so the front is never
    empty; the seed's latency winner is its ``latency_optimal`` point."""
    prov = resolve_provider(provider)
    ws = workspace_for(prov) if _ENGINE == "fast" else None
    if ws is not None:
        rkey = ("pdf", dag_fingerprint(dag), tuple(resources), radio_power,
                width)
        memo = ws.results.get(rkey)
        if memo is not None:
            return memo
    cands = _data_candidates(dag, resources, prov)
    if not cands:
        raise RuntimeError("data-partition search found no feasible plan")
    # the seed latency winner (first σ on ties, as in partition_data)
    # anchors the latency endpoint
    seed = min(cands, key=lambda p: p.predicted_latency)
    points = [(p.predicted_latency,
               predicted_energy(dag, resources, p, prov,
                                radio_power=radio_power), p)
              for p in cands if p is not seed]
    anchor = (seed.predicted_latency,
              predicted_energy(dag, resources, seed, prov,
                               radio_power=radio_power), seed)
    front = ParetoFront.build(points, anchor=anchor, width=width)
    if ws is not None:
        ws.results.put(rkey, front)
    return front


# --------------------------------------------------------------------------
# Mode selection — Algorithm 1 lines 4-6 / 8-10
# --------------------------------------------------------------------------

def partition(dag: ModelDAG, resources: Sequence[Resource],
              *, weight_transfer: bool = False,
              provider: CostProvider | None = None,
              objective: Objective | None = None) -> Partition:
    """Θ ← best(Θ_ω, Θ_σ): run both searches, return the better plan.

    With the default latency objective this is the paper's
    ``Θ = min(Θ_ω, Θ_σ)`` verbatim (model wins ties, as in the seed); any
    other objective *selects* over the merged frontier
    (:func:`partition_front`) — feasible-first under the latency budget,
    then metric-optimal."""
    obj = resolve_objective(objective)
    if not obj.is_latency:
        return partition_front(dag, resources,
                               weight_transfer=weight_transfer,
                               provider=provider,
                               radio_power=obj.radio_power).select(obj)
    theta_w = partition_model(dag, resources, weight_transfer=weight_transfer,
                              provider=provider)
    theta_s = partition_data(dag, resources, provider=provider)
    if theta_w.predicted_latency <= theta_s.predicted_latency:
        return theta_w
    return theta_s


def partition_front(dag: ModelDAG, resources: Sequence[Resource],
                    *, weight_transfer: bool = False,
                    provider: CostProvider | None = None,
                    radio_power: float = 0.0,
                    width: int | None = None) -> ParetoFront:
    """The merged latency–energy frontier over *both* partitioning modes.

    Model-mode points are inserted first, so an exact (latency, energy) tie
    keeps the model plan — the seed's ``Θ = min(Θ_ω, Θ_σ)`` tie rule.  The
    front's ``latency_optimal`` plan is therefore exactly what
    :func:`partition` returns under the default objective."""
    prov = resolve_provider(provider)
    ws = workspace_for(prov) if _ENGINE == "fast" else None
    if ws is not None:
        rkey = ("pf", dag_fingerprint(dag), tuple(resources),
                weight_transfer, radio_power, width)
        memo = ws.results.get(rkey)
        if memo is not None:
            return memo
    mf = partition_model_front(dag, resources,
                               weight_transfer=weight_transfer,
                               provider=prov, radio_power=radio_power)
    df = partition_data_front(dag, resources, provider=prov,
                              radio_power=radio_power)
    # Θ = min(Θ_ω, Θ_σ), model on ties — the seed's mode pick is the anchor
    anchor = (mf.latency_optimal
              if mf.latency_optimal.latency <= df.latency_optimal.latency
              else df.latency_optimal)
    front = ParetoFront.build(list(mf) + list(df), anchor=anchor, width=width)
    if ws is not None:
        ws.results.put(rkey, front)
    return front


# --------------------------------------------------------------------------
# Energy prediction for a plan (used by the planners, simulator, benchmarks)
# --------------------------------------------------------------------------

def predicted_energy(dag: ModelDAG, resources: Sequence[Resource],
                     plan: Partition,
                     provider: CostProvider | None = None,
                     *, radio_power: float = 0.0) -> float:
    """∫P dt for one plan: active power while a resource computes or
    communicates, idle power for the rest of the plan's makespan.

    The active joules come from the provider's energy queries, so a
    calibrated provider prices them from *fitted* energy predictors while
    the analytic provider reproduces the seed's ``active_power × busy``
    algebra.  ``radio_power`` adds watts on total transfer seconds (the
    shared-medium transmit energy the simulator meters); it defaults to 0 so
    existing callers see the seed numerics unchanged."""
    prov = resolve_provider(provider)
    T = plan.predicted_latency
    busy: dict[int, float] = {}
    active: dict[int, float] = {}
    comm_s = 0.0
    if isinstance(plan, ModelPartition):
        for si in range(plan.num_stages):
            a, b = plan.boundaries[si], plan.boundaries[si + 1]
            ri = plan.assignment[si]
            r = resources[ri]
            seg = dag.segment(a, b)
            cm = prov.comm_time(seg.bytes_in, r)
            busy[ri] = busy.get(ri, 0.0) + (
                prov.compute_time(seg.flops, r, seg.kind) + cm)
            active[ri] = active.get(ri, 0.0) + (
                prov.compute_energy(seg.flops, r, seg.kind)
                + prov.comm_energy(seg.bytes_in, r))
            comm_s += cm
    else:
        kind = dag.dominant_kind()
        for f, ri in zip(plan.fractions, plan.assignment):
            r = resources[ri]
            nbytes = (dag.input_bytes + dag.output_bytes) * f
            cm = prov.comm_time(nbytes, r)
            busy[ri] = busy.get(ri, 0.0) + (
                prov.compute_time(dag.total_flops * f, r, kind) + cm)
            active[ri] = active.get(ri, 0.0) + (
                prov.compute_energy(dag.total_flops * f, r, kind)
                + prov.comm_energy(nbytes, r))
            comm_s += cm
    e = 0.0
    for i, r in enumerate(resources):
        b = busy.get(i, 0.0)
        ae = active.get(i, 0.0)
        if b > T and b > 0.0:
            ae *= T / b                   # clip active draw to the makespan
            b = T
        e += ae + r.idle_power * max(T - b, 0.0)
    return e + radio_power * comm_s
