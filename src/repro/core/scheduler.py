"""Run-time Scheduler — the leader/follower finite-state machine (Fig. 4).

Leader:    ANALYZE → EXPLORE → GLOBAL_OFFLOAD → LOCAL_MAP → EXECUTE
                ▲                                   │
                └────────── merge & report ◄────────┘
Follower:  ANALYZE (receive) → LOCAL_MAP → EXECUTE → report

The FSM is transport-agnostic: ``Transport`` is injected (the simulator uses
simulated links; the TPU runtime uses in-process dispatch).  The FSM itself is
synchronous and step-driven so the event simulator can interleave many nodes;
``step()`` consumes/produces events.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Any, Callable, Protocol

from .cluster import ClusterManager
from .cost_model import Cluster, Node
from .dag import ModelDAG
from .hidp import HiDPPlan, PlannerConfig, plan, sub_dag_for
from .local_partitioner import LocalPlan, plan_local


class State(enum.Enum):
    ANALYZE = "analyze"
    EXPLORE = "explore"
    GLOBAL_OFFLOAD = "global_offload"
    LOCAL_MAP = "local_map"
    EXECUTE = "execute"


@dataclasses.dataclass
class InferenceRequest:
    request_id: int
    dag: ModelDAG
    arrival_time: float
    delta: float = 1.0


@dataclasses.dataclass
class ShardResult:
    request_id: int
    node_name: str
    stage_index: int
    payload: Any
    finish_time: float


class Transport(Protocol):
    """Inter-node communication abstraction (paper: Communication Module)."""

    def send(self, src: str, dst: str, nbytes: float, payload: Any,
             now: float) -> float:
        """Deliver payload; returns arrival time (src==dst → now)."""
        ...


@dataclasses.dataclass
class LeaderFSM:
    """One request's journey through the leader's scheduling policy."""

    manager: ClusterManager
    transport: Transport
    planner_config: PlannerConfig = dataclasses.field(
        default_factory=PlannerConfig)
    state: State = State.ANALYZE
    current: InferenceRequest | None = None
    plan_result: HiDPPlan | None = None
    pending_shards: set[int] = dataclasses.field(default_factory=set)
    results: list[ShardResult] = dataclasses.field(default_factory=list)
    trace: list[tuple[float, State]] = dataclasses.field(default_factory=list)

    # ------------------------------------------------------------- transitions
    def on_request(self, req: InferenceRequest, now: float) -> HiDPPlan:
        """ANALYZE: request arrives; leader elected; availability probed.
        EXPLORE: the DSE agent (DP) finds the partitioning mode and points."""
        assert self.state == State.ANALYZE, f"busy in {self.state}"
        self.current = req
        self.trace.append((now, State.ANALYZE))
        # churn-aware leadership: keep the sitting leader while it is alive,
        # otherwise fail over to the first available node (the request is
        # re-received there — Alg. 1 line 2 with a churned fleet)
        if self.manager.ensure_leader() is None:
            raise RuntimeError("no available node to lead the request")
        cluster = self.manager.refresh_availability(now)

        self.state = State.EXPLORE
        self.trace.append((now, State.EXPLORE))
        cfg = dataclasses.replace(self.planner_config, delta=req.delta)
        self.plan_result = plan(req.dag, cluster, cfg)

        self.state = State.GLOBAL_OFFLOAD
        self.trace.append((now, State.GLOBAL_OFFLOAD))
        return self.plan_result

    def offload(self, now: float) -> list[tuple[str, float, int]]:
        """GLOBAL_OFFLOAD: ship each non-leader assignment via the transport.
        Returns [(dst_node, arrival_time, stage_index)] for the simulator."""
        assert self.state == State.GLOBAL_OFFLOAD and self.plan_result
        leader = self.manager.leader
        sent = []
        gp = self.plan_result.global_plan
        for a in gp.assignments:
            self.pending_shards.add(a.stage_index)
            if a.node.name == leader:
                continue
            sd = sub_dag_for(self.current.dag, a)
            arrive = self.transport.send(leader, a.node.name, sd.input_bytes,
                                         ("shard", self.current.request_id,
                                          a.stage_index), now)
            sent.append((a.node.name, arrive, a.stage_index))
        self.state = State.LOCAL_MAP
        self.trace.append((now, State.LOCAL_MAP))
        return sent

    def local_map(self, now: float) -> LocalPlan:
        """LOCAL_MAP: tier-2 DP for the leader's own share."""
        assert self.state == State.LOCAL_MAP and self.plan_result
        leader = self.manager.leader
        idx = next(i for i, a in enumerate(
            self.plan_result.global_plan.assignments)
            if a.node.name == leader)
        lp = self.plan_result.local_plans[idx]
        self.state = State.EXECUTE
        self.trace.append((now, State.EXECUTE))
        return lp

    def on_shard_result(self, r: ShardResult, now: float) -> bool:
        """EXECUTE: gather local and global results (Alg. 1 line 12).
        Returns True when all shards have reported and the FSM merged."""
        assert self.state == State.EXECUTE
        self.results.append(r)
        self.pending_shards.discard(r.stage_index)
        if self.pending_shards:
            return False
        # merge & report (Alg. 1 line 13), back to ANALYZE
        self.state = State.ANALYZE
        self.trace.append((now, State.ANALYZE))
        self.current = None
        return True


@dataclasses.dataclass
class FollowerFSM:
    """Follower policy: receive → local map → execute → report (Fig. 4)."""

    node: Node
    transport: Transport
    state: State = State.ANALYZE

    def on_shard(self, sub: ModelDAG, delta: float, now: float) -> LocalPlan:
        assert self.state == State.ANALYZE
        self.state = State.LOCAL_MAP
        lp = plan_local(sub, self.node, delta=delta)
        self.state = State.EXECUTE
        return lp

    def report(self, leader: str, nbytes: float, payload: Any,
               now: float) -> float:
        arrive = self.transport.send(self.node.name, leader, nbytes, payload,
                                     now)
        self.state = State.ANALYZE
        return arrive
