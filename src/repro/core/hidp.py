"""HiDPPlanner — the end-to-end two-tier planner (the paper's contribution).

Given an inference request (a ModelDAG) and a Cluster, produce:

  tier 1: GlobalPlan  — mode (model|data) + node assignments     (Alg.1 l.3-7)
  tier 2: LocalPlan   — per node, mode + processor split         (Alg.1 l.8-10)

and the *hierarchical* latency/energy prediction, where each node's share is
costed by its own local plan instead of the optimistic Λ_j = Σλ_k global
collapse.  This refinement is exactly why HiDP beats global-only strategies:
the global tier books capacity a node cannot actually realise without a good
local split, and HiDP is the only strategy that then realises it.
"""

from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict
from typing import Sequence
from weakref import WeakKeyDictionary

from .cost_model import (Cluster, CostProvider, node_as_resource,
                         resolve_provider)
from .dag import DataPartition, ModelDAG, ModelPartition
from .global_partitioner import (GlobalAssignment, GlobalPlan, plan_global,
                                 plan_global_front)
from .local_partitioner import (LocalPlan, p1_plan, plan_local,
                                plan_local_front)
from .objective import Objective, resolve_objective
from .pareto import ParetoFront, ParetoPoint, pareto_filter
from . import dp_partitioner as _dp

# Sub-workload memo for the fast planner engine: a hierarchical pass (and
# every speculative pre-warm over N-1 memberships) re-derives the same
# ``dag.blocks[lo:hi]`` slices and σ-scaled copies many times over.  Keyed
# weakly on the parent DAG (a frozen dataclass — hashable, weakref-able) so
# entries die with the model; returning the *same* sub-DAG object also makes
# every downstream fingerprint/prefix-sum cache hit.  Bounded per DAG.
_SUBDAG_CACHE: "WeakKeyDictionary[ModelDAG, OrderedDict]" = (
    WeakKeyDictionary())
_SUBDAG_MAX = 512


def sub_dag_for(dag: ModelDAG, a: GlobalAssignment) -> ModelDAG:
    """Extract the sub-workload a global assignment hands to a node."""
    per = None
    if _dp.get_engine() == "fast":
        try:
            per = _SUBDAG_CACHE.get(dag)
            if per is None:
                per = OrderedDict()
                _SUBDAG_CACHE[dag] = per
        except TypeError:             # unhashable custom DAG subclass
            per = None
        else:
            key = (a.block_range, a.fraction)
            got = per.get(key)
            if got is not None:
                per.move_to_end(key)
                return got
    if a.block_range is not None:                        # model mode: ω blocks
        lo, hi = a.block_range
        blocks = dag.blocks[lo:hi]
        sub = ModelDAG(name=f"{dag.name}[{lo}:{hi}]", blocks=blocks,
                       input_bytes=blocks[0].bytes_in,
                       output_bytes=blocks[-1].bytes_out)
    else:
        assert a.fraction is not None                    # data mode: σ slice
        sub = ModelDAG(name=f"{dag.name}x{a.fraction:.3f}",
                       blocks=tuple(b.scaled(a.fraction)
                                    for b in dag.blocks),
                       input_bytes=dag.input_bytes * a.fraction,
                       output_bytes=dag.output_bytes * a.fraction)
    if per is not None:
        per[(a.block_range, a.fraction)] = sub
        while len(per) > _SUBDAG_MAX:
            per.popitem(last=False)
    return sub


@dataclasses.dataclass(frozen=True)
class HiDPPlan:
    dag_name: str
    global_plan: GlobalPlan
    local_plans: tuple[LocalPlan, ...]     # parallel to global_plan.assignments
    predicted_latency: float               # hierarchical (tier-2 refined)
    predicted_energy: float
    planning_seconds: float                # DP overhead (paper: ~15 ms)
    # strategy-specific extra traffic on the shared medium (MoDNN's per-layer
    # halo exchange); the simulator reserves the medium for it.
    extra_comm_bytes: float = 0.0
    # fixed serial overhead (MoDNN's per-layer barrier round-trips)
    extra_latency: float = 0.0

    @property
    def mode(self) -> str:
        return self.global_plan.mode


@dataclasses.dataclass(frozen=True)
class PlannerConfig:
    """Knobs for one :func:`plan` invocation.

    Attributes:
        delta: model compute-intensity [cycles/flop]; rescales datasheet
            rates to the model's arithmetic profile.
        weight_transfer: price cold-start weight shipping into model-mode
            stage costs (steady-state serving keeps weights resident).
        local_tier: False → global-only planning (the DisNet ablation).
        p1_local: True → pin the local tier to the framework-default
            single-processor behaviour (SoA config "P1").
        node_capacity: ``"sum"`` (HiDP's Λ_j = Σλ_k) or ``"default"``
            (what global-only strategies measure probing the default
            runtime).
        provider: cost predictions — None → the analytic datasheet model
            (seed behaviour); a ``CalibratedCostProvider`` answers from the
            profiling subsystem's fitted regressors (the paper's DNN Model
            Analyzer).
        objective: what both DP tiers minimize — None → latency (seed
            behaviour); ``Objective("energy", latency_budget=...)`` or
            ``Objective("edp")`` make energy a first-class planning goal.
            The budget and radio term apply at the global tier; the local
            tier minimizes the same metric via ``objective.local()``.
        front_width: cap on the composed :func:`plan_front` frontier (and
            on the global front it composes from).  Endpoints always
            survive thinning.
    """

    delta: float = 1.0                 # model compute-intensity [cycles/flop]
    weight_transfer: bool = False      # cold-start weight shipping
    local_tier: bool = True            # False → global-only (ablation/DisNet)
    p1_local: bool = False             # True → SoA default local behaviour
    node_capacity: str = "sum"         # "sum" (HiDP) | "default" (SoA probe)
    provider: CostProvider | None = None
    objective: Objective | None = None
    # max points kept when composing the hierarchical frontier (plan_front);
    # endpoints always survive, so this trades interior resolution for speed
    front_width: int = 8


def _hierarchical_cost(dag: ModelDAG, gp: GlobalPlan,
                       locals_: Sequence[LocalPlan],
                       provider: CostProvider | None = None,
                       objective: Objective | None = None
                       ) -> tuple[float, float]:
    """Re-cost the global plan with tier-2 refined per-node latencies.

    Energy is the sum of the local plans' predictions plus the objective's
    radio term on the inter-node transfer seconds priced here — keeping
    ``HiDPPlan.predicted_energy`` consistent with the figure the global DP
    minimized and with the simulator's radio-metered measurement (both
    terms are zero under the default objective, the seed behaviour)."""
    prov = resolve_provider(provider)
    radio = objective.radio_power if objective is not None else 0.0
    energy = sum(lp.predicted_energy for lp in locals_)
    if gp.mode == "model":
        total = 0.0
        for a, lp in zip(gp.assignments, locals_):
            r = node_as_resource(a.node)
            xfer = sub_dag_for(dag, a).input_bytes
            comm_s = prov.comm_time(xfer, r)
            total += comm_s + lp.predicted_latency
            energy += radio * comm_s
        out_s = prov.comm_time(dag.output_bytes,
                               node_as_resource(gp.assignments[-1].node),
                               rtt=0.0)
        total += out_s
        energy += radio * out_s
        return total, energy
    # data mode: concurrent, slowest node dominates
    per_node = []
    for a, lp in zip(gp.assignments, locals_):
        r = node_as_resource(a.node)
        sd = sub_dag_for(dag, a)
        comm_s = prov.comm_time(sd.input_bytes + sd.output_bytes, r)
        per_node.append(comm_s + lp.predicted_latency)
        energy += radio * comm_s
    return max(per_node), energy


def _local_objective(objective: Objective | None, gp: GlobalPlan,
                     a: GlobalAssignment, sub_dag: ModelDAG,
                     config: PlannerConfig,
                     provider: CostProvider | None) -> Objective | None:
    """Decompose a request-level latency budget into a per-node one.

    The global tier booked ``sub_dag`` on this node at the optimistic
    Λ_j = Σλ_k collapse; the local tier may spend that booking times the
    request's slack ratio (budget / global predicted latency), but no more —
    otherwise an unconstrained energy objective would happily pick a
    low-power local split that blows the request budget a tier above."""
    if objective is None:
        return None
    local = objective.local()
    if objective.latency_budget is None:
        return local
    kind = sub_dag.dominant_kind()
    r = node_as_resource(a.node, config.delta, kind,
                         capacity=config.node_capacity)
    prov = resolve_provider(provider)
    booked = prov.compute_time(sub_dag.total_flops, r, kind)
    slack = objective.latency_budget / max(gp.predicted_latency, 1e-12)
    return dataclasses.replace(local,
                               latency_budget=booked * max(slack, 1.0))


def plan(dag: ModelDAG, cluster: Cluster,
         config: PlannerConfig = PlannerConfig()) -> HiDPPlan:
    """Run the full two-tier HiDP planning pass for one request.

    Tier 1 (:func:`plan_global`) chooses the mode and node shares over the
    available cluster; tier 2 (:func:`plan_local`) re-partitions each node's
    sub-workload over its own processors, both priced by ``config.provider``
    (the analytic datasheet model by default).  Under the default latency
    objective this is the seed DP pass, bit-identical; any other
    ``config.objective`` *selects* from the plan frontier
    (:func:`plan_front`) — feasible-first under the latency budget, then
    metric-optimal.  The returned :class:`HiDPPlan` carries the
    tier-2-refined latency *and* energy predictions plus the planning
    overhead (paper: ~15 ms; the frontier pass costs a few times that and
    is amortized by ``repro.serving.plan_cache.PlanCache``).
    """
    objective = config.objective
    if not resolve_objective(objective).is_latency:
        t0 = time.perf_counter()
        selected = plan_front(dag, cluster, config).select(objective)
        return dataclasses.replace(
            selected, planning_seconds=time.perf_counter() - t0)
    t0 = time.perf_counter()
    provider = config.provider
    if provider is not None:
        provider = provider.at_delta(config.delta)
    gp = plan_global(dag, cluster, delta=config.delta,
                     weight_transfer=config.weight_transfer,
                     capacity=config.node_capacity, provider=provider,
                     objective=objective)
    locals_: list[LocalPlan] = []
    for a in gp.assignments:
        sd = sub_dag_for(dag, a)
        if not config.local_tier or config.p1_local:
            locals_.append(p1_plan(sd, a.node, delta=config.delta,
                                   provider=provider))
        else:
            locals_.append(plan_local(sd, a.node, delta=config.delta,
                                      provider=provider,
                                      objective=_local_objective(
                                          objective, gp, a, sd, config,
                                          provider)))
    latency, energy = _hierarchical_cost(dag, gp, locals_, provider,
                                         objective)
    dt = time.perf_counter() - t0
    return HiDPPlan(dag_name=dag.name, global_plan=gp,
                    local_plans=tuple(locals_), predicted_latency=latency,
                    predicted_energy=energy, planning_seconds=dt)


# --------------------------------------------------------------------------
# Frontier planning — one pass, every objective
# --------------------------------------------------------------------------

def _compose_front(dag: ModelDAG, gp: GlobalPlan,
                   lfronts: Sequence[ParetoFront], prov: CostProvider,
                   radio: float, cap: int) -> list[tuple]:
    """Compose per-node local fronts under one global plan into
    hierarchical (latency, energy, local-plan-choice) states — the
    node-separable unrolling of :func:`_hierarchical_cost`, so every
    composed state prices exactly as the scalar path would price that
    combination of local plans."""
    if gp.mode == "model":
        states: list[tuple] = [(0.0, 0.0, ())]
        for a, lf in zip(gp.assignments, lfronts):
            r = node_as_resource(a.node)
            xfer = sub_dag_for(dag, a).input_bytes
            comm_s = prov.comm_time(xfer, r)
            nxt: list[tuple] = []
            for lat, en, chosen in states:
                for p in lf:
                    nxt = pareto_filter(
                        nxt, (lat + comm_s + p.latency,
                              en + p.energy + radio * comm_s,
                              chosen + (p.plan,)), cap)
            states = nxt
        out_s = prov.comm_time(dag.output_bytes,
                               node_as_resource(gp.assignments[-1].node),
                               rtt=0.0)
        return [(lat + out_s, en + radio * out_s, chosen)
                for lat, en, chosen in states]
    # data mode: concurrent, slowest node dominates
    states = [(0.0, 0.0, ())]
    for a, lf in zip(gp.assignments, lfronts):
        r = node_as_resource(a.node)
        sd = sub_dag_for(dag, a)
        comm_s = prov.comm_time(sd.input_bytes + sd.output_bytes, r)
        nxt = []
        for lat, en, chosen in states:
            for p in lf:
                nxt = pareto_filter(
                    nxt, (max(lat, comm_s + p.latency),
                          en + p.energy + radio * comm_s,
                          chosen + (p.plan,)), cap)
        states = nxt
    return states


def plan_front(dag: ModelDAG, cluster: Cluster,
               config: PlannerConfig = PlannerConfig()) -> ParetoFront:
    """One planning pass, every objective: the hierarchical latency–energy
    frontier of two-tier HiDP plans.

    Tier 1 produces the global frontier; for each global plan on it, tier 2
    produces per-node local fronts, and the hierarchy composes them
    node-separably (pipeline: sums; data: max-latency/sum-energy) into
    non-dominated :class:`HiDPPlan` candidates.  The seed latency-optimal
    plan (the exact scalar two-tier pass) is spliced in first, so
    ``front.latency_optimal`` reproduces it bit-identically.  Select a plan
    for any request with ``front.select(objective)`` — zero DP work; that
    is what ``repro.serving.plan_cache.PlanCache`` serves from.

    Radio pricing comes from ``config.objective.radio_power`` (a pricing
    parameter, not a selector): every point's energy includes it, so the
    front is valid for any later selection objective with the same radio
    assumption."""
    t0 = time.perf_counter()
    provider = config.provider
    if provider is not None:
        provider = provider.at_delta(config.delta)
    prov = resolve_provider(provider)
    radio = resolve_objective(config.objective).radio_power
    width = config.front_width

    # the exact seed pass anchors the latency endpoint, bit-identically —
    # but its energy must be re-priced with the radio term (the scalar pass
    # ran radio-free) so the anchor skylines and selects against the
    # composed candidates on equal footing
    seed = plan(dag, cluster, dataclasses.replace(config, objective=None))
    if radio != 0.0:
        _, seed_energy = _hierarchical_cost(
            dag, seed.global_plan, seed.local_plans, provider,
            resolve_objective(config.objective))
        seed = dataclasses.replace(seed, predicted_energy=seed_energy)

    gfront = plan_global_front(dag, cluster, delta=config.delta,
                               weight_transfer=config.weight_transfer,
                               capacity=config.node_capacity,
                               provider=provider, radio_power=radio,
                               width=width)
    local_cache: dict[tuple, ParetoFront] = {}

    def local_front(a: GlobalAssignment) -> ParetoFront:
        key = (a.node.name, a.block_range, a.fraction)
        lf = local_cache.get(key)
        if lf is None:
            sd = sub_dag_for(dag, a)
            if not config.local_tier or config.p1_local:
                lp = p1_plan(sd, a.node, delta=config.delta, provider=prov)
                lf = ParetoFront([ParetoPoint(lp.predicted_latency,
                                              lp.predicted_energy, lp)])
            else:
                lf = plan_local_front(sd, a.node, delta=config.delta,
                                      provider=prov, width=width)
            local_cache[key] = lf
        return lf

    candidates: list[tuple[float, float, GlobalPlan, tuple]] = []
    for gpoint in gfront:
        gp = gpoint.plan
        lfronts = [local_front(a) for a in gp.assignments]
        for lat, en, chosen in _compose_front(dag, gp, lfronts, prov,
                                              radio, cap=width):
            candidates.append((lat, en, gp, chosen))

    dt = time.perf_counter() - t0
    anchor = ParetoPoint(seed.predicted_latency, seed.predicted_energy,
                         dataclasses.replace(seed, planning_seconds=dt))
    points: list[ParetoPoint] = []
    for lat, en, gp, chosen in candidates:
        points.append(ParetoPoint(lat, en, HiDPPlan(
            dag_name=dag.name, global_plan=gp, local_plans=tuple(chosen),
            predicted_latency=lat, predicted_energy=en,
            planning_seconds=dt)))
    return ParetoFront.build(points, anchor=anchor, width=width)


# --------------------------------------------------------------------------
# Plan serialization — the JSON round-trip persisted fronts ride on
# --------------------------------------------------------------------------

def _partition_to_dict(p: ModelPartition | DataPartition) -> dict:
    if isinstance(p, ModelPartition):
        return {"mode": "model", "boundaries": list(p.boundaries),
                "assignment": list(p.assignment),
                "predicted_latency": p.predicted_latency}
    return {"mode": "data", "fractions": list(p.fractions),
            "assignment": list(p.assignment),
            "predicted_latency": p.predicted_latency}


def _partition_from_dict(d: dict) -> ModelPartition | DataPartition:
    if d["mode"] == "model":
        return ModelPartition(boundaries=tuple(d["boundaries"]),
                              assignment=tuple(d["assignment"]),
                              predicted_latency=d["predicted_latency"])
    return DataPartition(fractions=tuple(d["fractions"]),
                         assignment=tuple(d["assignment"]),
                         predicted_latency=d["predicted_latency"])


def plan_to_dict(plan: HiDPPlan) -> dict:
    """A JSON-able view of a two-tier plan.  Nodes are stored by *name*
    only: a persisted plan is always filed under its cluster's fingerprint,
    so the loader (:func:`plan_from_dict`) reattaches the full ``Node``
    objects from a cluster guaranteed topology-identical to the writer's."""
    gp = plan.global_plan
    return {
        "dag_name": plan.dag_name,
        "predicted_latency": plan.predicted_latency,
        "predicted_energy": plan.predicted_energy,
        "planning_seconds": plan.planning_seconds,
        "extra_comm_bytes": plan.extra_comm_bytes,
        "extra_latency": plan.extra_latency,
        "global_plan": {
            "mode": gp.mode,
            "partition": _partition_to_dict(gp.partition),
            "predicted_latency": gp.predicted_latency,
            "predicted_energy": gp.predicted_energy,
            "assignments": [
                {"node": a.node.name, "block_range": list(a.block_range)
                 if a.block_range is not None else None,
                 "fraction": a.fraction, "stage_index": a.stage_index}
                for a in gp.assignments],
        },
        "local_plans": [
            {"node_name": lp.node_name, "mode": lp.mode,
             "partition": _partition_to_dict(lp.partition),
             "predicted_latency": lp.predicted_latency,
             "predicted_energy": lp.predicted_energy}
            for lp in plan.local_plans],
    }


def plan_from_dict(d: dict, cluster: Cluster) -> HiDPPlan:
    """Rebuild a persisted plan against ``cluster``; bit-identical to the
    plan :func:`plan_to_dict` serialized whenever the cluster's fingerprint
    matches the writer's (the persistence layer enforces that)."""
    nodes = {n.name: n for n in cluster.nodes}
    gd = d["global_plan"]
    assignments = tuple(
        GlobalAssignment(
            node=nodes[a["node"]],
            block_range=tuple(a["block_range"])
            if a["block_range"] is not None else None,
            fraction=a["fraction"], stage_index=a["stage_index"])
        for a in gd["assignments"])
    gp = GlobalPlan(mode=gd["mode"],
                    partition=_partition_from_dict(gd["partition"]),
                    assignments=assignments,
                    predicted_latency=gd["predicted_latency"],
                    predicted_energy=gd["predicted_energy"])
    locals_ = tuple(
        LocalPlan(node_name=ld["node_name"], mode=ld["mode"],
                  partition=_partition_from_dict(ld["partition"]),
                  predicted_latency=ld["predicted_latency"],
                  predicted_energy=ld["predicted_energy"])
        for ld in d["local_plans"])
    return HiDPPlan(dag_name=d["dag_name"], global_plan=gp,
                    local_plans=locals_,
                    predicted_latency=d["predicted_latency"],
                    predicted_energy=d["predicted_energy"],
                    planning_seconds=d["planning_seconds"],
                    extra_comm_bytes=d["extra_comm_bytes"],
                    extra_latency=d["extra_latency"])


class HiDPPlanner:
    """First-class two-tier planner: one configuration, frontier output.

    The object every consumer of planning should hold: ``front`` runs the
    (expensive, objective-independent) frontier pass once per
    ``(cluster, dag)``; ``plan`` selects a single plan for a concrete
    objective.  ``repro.serving.plan_cache.PlanCache`` wraps a planner to
    amortize ``front`` across requests."""

    def __init__(self, config: PlannerConfig = PlannerConfig()):
        self.config = config

    def at_delta(self, delta: float) -> "HiDPPlanner":
        """The same planner rebound to a model's compute intensity."""
        if delta == self.config.delta:
            return self
        return HiDPPlanner(dataclasses.replace(self.config, delta=delta))

    def front(self, dag: ModelDAG, cluster: Cluster) -> ParetoFront:
        return plan_front(dag, cluster, self.config)

    def plan(self, dag: ModelDAG, cluster: Cluster,
             objective: Objective | None = None) -> HiDPPlan:
        """A single plan: the configured objective unless overridden."""
        cfg = self.config
        if objective is not None:
            cfg = dataclasses.replace(cfg, objective=objective)
        return plan(dag, cluster, cfg)
