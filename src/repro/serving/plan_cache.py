"""PlanCache — one persistent, evicting plan-frontier cache per cluster.

HiDP's premise is a *shared* heterogeneous edge cluster serving many
concurrent DNN workloads (the paper's Fig. 7 request mixes; CoEdge,
arXiv:2012.03257, frames the same multi-workload scenario).  The paper pays
its ~15 ms two-tier DP on every request; this cache amortizes it across
requests *and tenants*: one (objective-independent) frontier pass per
``(cluster fingerprint, membership fingerprint, calibration version,
dag fingerprint, δ)``, then any request's objective — from any tenant — is
resolved against the cached :class:`~repro.core.pareto.ParetoFront` with
zero DP work: a dict lookup plus an O(front-width) ``select``.

Keys and invalidation:

* the **cluster fingerprint** comes from the shared
  :func:`repro.core.fingerprint.cluster_fingerprint` — the same hash that
  files calibrations in ``CalibrationStore``, so plan-cache keys and
  calibration paths can never drift apart.  A board swap or link upgrade
  changes the fingerprint and cleanly orphans every cached front.
* the **membership fingerprint**
  (:func:`repro.core.fingerprint.membership_fingerprint`) identifies *who
  is in the fleet right now* — the availability mask the planner restricts
  itself to.  Wire ``membership_source=`` (anything with a live
  ``.cluster`` attribute: a ``repro.core.ClusterManager`` or a
  ``repro.fleet.FleetController``) and every lookup keys on — and plans
  against — the current membership.  A node leaving is **not** an
  invalidation: fronts for distinct memberships live side by side in the
  same table (and in the same persisted ``fronts.json``), so a node that
  leaves and later *returns* flips the mask back to a seen value and the
  original warm front serves again with zero DP work, bit-identically.
  Without a ``membership_source`` the cache keys on the construction-time
  mask — the static-fleet behaviour, unchanged.
* the **dag fingerprint** (:func:`repro.core.fingerprint.dag_fingerprint`)
  identifies the tenant by its full cost surface, not its name — two
  workloads that share a model name but differ in shape can never collide,
  and editing a model's blocks orphans its fronts like a board swap
  orphans calibrations.
* the **calibration version** either lives in the cache
  (:meth:`bump_version`) or is read live from a ``version_source`` — any
  object with a ``calibration_version`` attribute, e.g. a
  ``repro.profiling.FeedbackLoop``, whose drift events increment it.
  Either way a bump is **atomic**: the version and the entry table swap in
  a single reference assignment, so a concurrent reader sees either the
  old generation (stale front, still internally consistent) or the new
  empty one — never a half-invalidated mix.
* after a bump, the next lookup *per tenant* misses exactly once and pays
  one EXPLORE re-plan (the frontier pass); every other objective variation
  for that tenant is a hit again.

Eviction (multi-tenant caches are bounded):

* ``eviction=LRUEviction(max_entries=..., max_bytes=...)`` caps the table;
  the least-recently-used tenant entry is dropped first when either budget
  overflows.  The entry the current request just touched (the in-flight
  tenant) is never evicted, even if it alone exceeds the byte budget — a
  request can always be served from the front it just built.
* an evicted tenant is not an error: its next request re-plans (a miss)
  and re-enters the table.  ``evictions`` counts drops.

Persistence (warm restarts):

* :meth:`persist` writes the current generation's fronts next to the
  calibrations in a ``repro.profiling.CalibrationStore`` (JSON round-trip
  via ``repro.core.plan_to_dict``); :meth:`warm_from` — or passing
  ``store=`` at construction — loads them back, **dropping any entry
  whose calibration version does not match the live one or whose
  on-disk calibration anchor moved** (a re-profiling between persist and
  restart invalidates even when in-memory counters collide), so a stale
  front can never serve.  A restarted process then serves every tenant's
  first request with zero DP work, and selections off loaded fronts are
  bit-identical to the freshly built ones (floats survive JSON exactly).
* ``persist_every=N`` auto-persists after every N-th insert (frontier
  pass), so a crashed process loses at most one generation of N-1 new
  fronts; the underlying ``save_fronts`` write is atomic and guarded by a
  best-effort advisory file lock, so two serving processes sharing one
  store never interleave a write.

``get`` stamps the returned plan's ``planning_seconds`` with what the
caller actually waited — the full frontier pass on a miss, the lookup
microseconds on a hit — so simulators and benchmarks measure the warm path
honestly.
"""

from __future__ import annotations

import dataclasses
import json
import time
from collections import OrderedDict

from repro.core.cost_model import Cluster
from repro.core.dag import ModelDAG
from repro.core.fingerprint import (cluster_fingerprint, dag_fingerprint,
                                    membership_fingerprint)
from repro.core.hidp import (HiDPPlan, HiDPPlanner, plan_from_dict,
                             plan_to_dict)
from repro.core.objective import Objective
from repro.core.pareto import ParetoFront


@dataclasses.dataclass
class CacheEntry:
    """One tenant's cached frontier, plus what persistence needs to file it
    (``nbytes`` is the JSON-serialized size — the byte-budget currency,
    computed lazily so misses pay for serialization only when a byte
    budget, a persist, or a stats call actually needs it)."""

    dag_name: str
    dag_fingerprint: str
    delta: float
    front: ParetoFront
    membership_fingerprint: str = ""
    # the live DAG object (when the front was planned in-process) — what
    # prewarming re-plans against other memberships; None for fronts loaded
    # from a store (they cannot be speculated over, only served)
    dag: ModelDAG | None = None
    # built by the pre-warmer, not yet demanded; promoted (and counted as a
    # prewarm hit) the first time a request lands on it
    speculative: bool = False
    _nbytes: int | None = None

    @property
    def nbytes(self) -> int:
        if self._nbytes is None:
            self._nbytes = len(json.dumps(self.front.to_dict(plan_to_dict)))
        return self._nbytes


class LRUEviction:
    """Bounded LRU over tenant entries.

    Attributes:
        max_entries: entry-count budget (None = unbounded).
        max_bytes: serialized-front byte budget (None = unbounded).

    ``victims`` returns the least-recently-used keys to drop so the table
    fits both budgets, never including ``protect`` — the in-flight tenant's
    entry survives even when it alone exceeds ``max_bytes``.
    """

    def __init__(self, max_entries: int | None = None,
                 max_bytes: int | None = None):
        if max_entries is not None and max_entries < 1:
            raise ValueError("max_entries must be >= 1 (the in-flight "
                             "tenant's entry is never evicted)")
        if max_bytes is not None and max_bytes <= 0:
            raise ValueError("max_bytes must be positive")
        self.max_entries = max_entries
        self.max_bytes = max_bytes

    def victims(self, entries: "OrderedDict[tuple, CacheEntry]",
                protect: tuple | None = None) -> list[tuple]:
        drop: list[tuple] = []
        n = len(entries)
        # entry sizes are only materialized when a byte budget exists
        nbytes = (sum(e.nbytes for e in entries.values())
                  if self.max_bytes is not None else 0)
        for key, entry in entries.items():          # LRU first
            over = ((self.max_entries is not None and n > self.max_entries)
                    or (self.max_bytes is not None and nbytes > self.max_bytes))
            if not over:
                break
            if key == protect:
                continue
            drop.append(key)
            n -= 1
            if self.max_bytes is not None:
                nbytes -= entry.nbytes
        return drop

    def __repr__(self) -> str:
        return (f"LRUEviction(max_entries={self.max_entries}, "
                f"max_bytes={self.max_bytes})")


class PlanCache:
    """Cached plan frontiers for one cluster, served to many tenants.

    Attributes:
        planner: the :class:`~repro.core.hidp.HiDPPlanner` that computes
            frontiers on a miss (its config fixes provider, radio pricing,
            and the default δ).
        fingerprint: the cluster's topology hash (shared with
            ``CalibrationStore``).
        eviction: the bounded-budget policy (:class:`LRUEviction`), or
            None for an unbounded table.
        persist_every: auto-persist period in inserts (None = only on
            demand); requires ``store=``.
        hits / misses / evictions / invalidations / loaded: lifetime
            counters; ``misses`` counts EXPLORE re-plans (full frontier
            passes), ``loaded`` counts fronts served warm from a store.
        telemetry: optional ``repro.telemetry.TelemetryRecorder`` — every
            hit/miss/eviction/invalidation/persist becomes a per-tenant
            counter and each DP frontier pass a wall-timed
            ``plan.frontier_pass`` span (docs/observability.md).
    """

    def __init__(self, planner: HiDPPlanner, cluster: Cluster, *,
                 version: int = 0, version_source=None,
                 eviction: LRUEviction | None = None, store=None,
                 membership_source=None, persist_every: int | None = None,
                 telemetry=None):
        self.planner = planner
        self.cluster = cluster
        self.fingerprint = cluster_fingerprint(cluster)
        self.eviction = eviction
        from repro.telemetry import active as _tel_active
        self.telemetry = _tel_active(telemetry)
        self._store = store
        self._version_source = version_source
        self.membership_source = membership_source
        if persist_every is not None:
            if persist_every < 1:
                raise ValueError("persist_every must be >= 1")
            if store is None:
                raise ValueError("persist_every needs a store to persist "
                                 "to: wire store= at construction")
        self.persist_every = persist_every
        self._inserts_since_persist = 0
        if version_source is not None:
            version = version_source.calibration_version
        # one atomically-swapped generation: (version, {key: CacheEntry}),
        # the table ordered least- to most-recently used
        self._generation: tuple[int, "OrderedDict[tuple, CacheEntry]"] = \
            (int(version), OrderedDict())
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.invalidations = 0
        self.loaded = 0
        self.prewarmed = 0
        self.prewarm_hits = 0
        self.prewarm_misses = 0
        self._prewarm_active = False
        if store is not None:
            self.warm_from(store)

    # -------------------------------------------------------------- keying
    @property
    def version(self) -> int:
        """The calibration version cached fronts are valid for — read live
        from ``version_source`` when one is wired, so a FeedbackLoop drift
        event invalidates without calling into the cache at all."""
        if self._version_source is not None:
            return int(self._version_source.calibration_version)
        return self._generation[0]

    def live_cluster(self) -> Cluster:
        """The cluster lookups plan against: the ``membership_source``'s
        current view when one is wired (live availability over the same
        declared topology), the construction-time cluster otherwise."""
        if self.membership_source is not None:
            return self.membership_source.cluster
        return self.cluster

    @property
    def membership_fingerprint(self) -> str:
        """The availability-mask hash of :meth:`live_cluster` — read live,
        so a ``FleetController`` membership epoch re-keys lookups without
        calling into the cache at all (a returning membership lands back
        on its original entries)."""
        return membership_fingerprint(self.live_cluster())

    def key(self, dag: ModelDAG, delta: float | None = None) -> tuple:
        """``(cluster fp, membership fp, calibration version,
        dag fingerprint, δ)``."""
        if delta is None:
            delta = self.planner.config.delta
        return (self.fingerprint, self.membership_fingerprint, self.version,
                dag_fingerprint(dag), delta)

    # ------------------------------------------------------------- lookups
    def _table(self, version: int) -> "OrderedDict[tuple, CacheEntry]":
        """The current generation's table, swapping in a fresh one
        atomically when ``version_source`` moved on."""
        gen_version, entries = self._generation
        if gen_version != version:
            entries = OrderedDict()
            self._generation = (version, entries)
            self.invalidations += 1
            if self.telemetry is not None:
                self.telemetry.counter("plan_cache.invalidation",
                                       version=version)
        return entries

    def front(self, dag: ModelDAG, delta: float | None = None) -> ParetoFront:
        """The cached frontier for ``dag`` — one DP pass per tenant per
        (membership, generation).  A hit refreshes the tenant's LRU
        position; a miss plans against the *live* membership, inserts, and
        then lets the eviction policy trim *other* tenants back under
        budget.  With ``persist_every`` wired, every N-th insert flushes
        the warm table to the store."""
        key = self.key(dag, delta)
        entries = self._table(key[2])
        entry = entries.get(key)
        tel = self.telemetry
        if entry is not None:
            self.hits += 1
            entries.move_to_end(key)
            if entry.speculative:
                # speculation paid off: the membership the pre-warmer bet
                # on arrived, and this epoch is served with zero DP work
                entry.speculative = False
                self.prewarm_hits += 1
                if tel is not None:
                    tel.counter("plan_cache.prewarm_hit", tenant=dag.name,
                                dag_fp=key[3][:12], membership=key[1][:12])
            if entry.dag is None:
                entry.dag = dag       # a loaded front becomes speculatable
            if tel is not None:
                tel.counter("plan_cache.hit", tenant=dag.name,
                            dag_fp=key[3][:12])
            return entry.front
        self.misses += 1
        if delta is None:
            delta = self.planner.config.delta
        if tel is not None:
            tel.counter("plan_cache.miss", tenant=dag.name,
                        dag_fp=key[3][:12])
        if self._prewarm_active:
            # a demand frontier pass the speculation schedule did not cover
            # (first-seen tenant, or a multi-node membership jump)
            self.prewarm_misses += 1
            if tel is not None:
                tel.counter("plan_cache.prewarm_miss", tenant=dag.name,
                            dag_fp=key[3][:12], membership=key[1][:12])
        t0 = time.perf_counter()
        front = self.planner.at_delta(delta).front(dag, self.live_cluster())
        if tel is not None:
            # the DP frontier pass — the EXPLORE cost the cache amortizes;
            # its duration is wall-measured, so it rides the wall_s field
            tel.span("plan.frontier_pass", 0.0, tenant=dag.name,
                     wall_s=time.perf_counter() - t0, dag_fp=key[3][:12],
                     membership=key[1][:12], version=key[2])
        entries[key] = CacheEntry(dag_name=dag.name,
                                  dag_fingerprint=key[3], delta=delta,
                                  front=front,
                                  membership_fingerprint=key[1], dag=dag)
        self._evict(entries, protect=key)
        self._inserts_since_persist += 1
        if (self.persist_every is not None
                and self._inserts_since_persist >= self.persist_every):
            self.persist()
        return front

    def get(self, dag: ModelDAG, objective: Objective | str | None = None,
            delta: float | None = None) -> HiDPPlan:
        """Resolve one request: select ``objective`` over the tenant's
        cached front.  Zero DP work on a hit.  ``objective`` may be an
        :class:`~repro.core.objective.Objective` or a metric name
        (``"latency"`` | ``"energy"`` | ``"edp"``)."""
        if isinstance(objective, str):
            objective = Objective(objective)
        t0 = time.perf_counter()
        misses = self.misses
        front = self.front(dag, delta)
        plan = front.select(objective)
        if misses != self.misses:
            return plan          # cold: keep the frontier pass's own timing
        return dataclasses.replace(
            plan, planning_seconds=time.perf_counter() - t0)

    # ---------------------------------------------------------- prewarming
    def prewarm(self, memberships=None, dags=None,
                delta: float | None = None) -> int:
        """Speculatively build fronts for the memberships likely to arrive
        next, so the epoch that realizes one is served with **zero**
        frontier passes.

        ``memberships`` is an iterable of availability masks (tuples of
        bool over the declared node list); by default the current live
        mask plus every single-departure neighbour
        (:func:`repro.core.dp_cache.single_departure_masks`) — the
        churn-trace-observed common case of one node dropping out.
        ``dags`` defaults to every tenant this cache has planned
        in-process (each at the δ it was planned at); pass DAGs explicitly
        to pre-warm tenants before their first request.

        Fronts that already exist (any earlier demand or speculative pass)
        are skipped, so re-running after every epoch costs only the truly
        new memberships.  Speculative entries are inserted **LRU-cold**:
        under an eviction budget they are the first victims, and a
        pre-warm sweep can never push a demanded tenant's front out of the
        table.  The fast DP engine's row caches make each speculative pass
        cheap — an N-1 membership shares every per-resource row with the
        full-membership pass that preceded it.

        Each front built emits a ``plan.prewarm`` telemetry span;
        ``prewarmed`` / ``prewarm_hits`` / ``prewarm_misses`` count the
        speculation economy in :meth:`stats`.  Returns the number of
        fronts built by this call."""
        self._prewarm_active = True
        base = self.live_cluster()
        if memberships is None:
            from repro.core.dp_cache import single_departure_masks
            live = tuple(bool(n.available) for n in base.nodes)
            memberships = [live] + single_departure_masks(base)
        version = self.version
        entries = self._table(version)
        if dags is None:
            targets_by_key: dict = {}
            for e in list(entries.values()):
                if e.dag is not None:
                    targets_by_key.setdefault((e.dag_fingerprint, e.delta),
                                              (e.dag, e.delta))
            targets = list(targets_by_key.values())
        else:
            d = self.planner.config.delta if delta is None else delta
            targets = [(dag, d) for dag in dags]
        tel = self.telemetry
        built = 0
        for mask in memberships:
            masked = base.with_availability(list(mask))
            if not any(mask):
                continue                       # never plan an empty fleet
            mfp = membership_fingerprint(masked)
            for dag, dg_delta in targets:
                key = (self.fingerprint, mfp, version,
                       dag_fingerprint(dag), dg_delta)
                if key in entries:
                    continue                   # already warm — free skip
                t0 = time.perf_counter()
                front = self.planner.at_delta(dg_delta).front(dag, masked)
                if tel is not None:
                    tel.span("plan.prewarm", 0.0, tenant=dag.name,
                             wall_s=time.perf_counter() - t0,
                             dag_fp=key[3][:12], membership=mfp[:12],
                             version=version)
                entries[key] = CacheEntry(
                    dag_name=dag.name, dag_fingerprint=key[3],
                    delta=dg_delta, front=front,
                    membership_fingerprint=mfp, dag=dag, speculative=True)
                entries.move_to_end(key, last=False)     # LRU-cold
                built += 1
                self.prewarmed += 1
        self._evict(entries)
        return built

    # ------------------------------------------------------------ eviction
    def _evict(self, entries: "OrderedDict[tuple, CacheEntry]",
               protect: tuple | None = None) -> None:
        if self.eviction is None:
            return
        for key in self.eviction.victims(entries, protect):
            if self.telemetry is not None:
                self.telemetry.counter("plan_cache.eviction",
                                       tenant=entries[key].dag_name,
                                       dag_fp=key[3][:12])
            del entries[key]
            self.evictions += 1

    # -------------------------------------------------------- invalidation
    def bump_version(self, version: int | None = None) -> int:
        """Atomically invalidate every cached front: the (version, table)
        pair swaps in one assignment.  Raises when a ``version_source``
        drives the version (bump it there — FeedbackLoop drift events do
        this automatically)."""
        if self._version_source is not None:
            raise RuntimeError(
                "version is driven by version_source; bump it there "
                "(FeedbackLoop drift events do this automatically)")
        new = self._generation[0] + 1 if version is None else int(version)
        self._generation = (new, OrderedDict())
        self.invalidations += 1
        if self.telemetry is not None:
            self.telemetry.counter("plan_cache.invalidation", version=new)
        return new

    def on_drift(self) -> None:
        """Hook for ``FeedbackLoop(on_drift=cache.on_drift)`` when no
        version_source is wired: one drift event → one atomic bump → the
        next lookup *per tenant* is that tenant's single EXPLORE re-plan."""
        if self._version_source is None:
            self.bump_version()

    # --------------------------------------------------------- persistence
    def _store_version(self, store) -> int:
        """The latest *on-disk* calibration version for this cluster — the
        durable stale-front anchor.  The in-memory counter resets with the
        process, but the store's ``v*.json`` history does not: a front
        persisted before a re-profiling (a new calibration file) can never
        be served after it, whatever the counters say."""
        versions = store.versions(self.cluster)
        return versions[-1] if versions else 0

    def persist(self, store=None) -> int:
        """Write the current generation's warm fronts next to the
        calibrations in ``store`` (a ``repro.profiling.CalibrationStore``;
        defaults to the one wired at construction).  Each entry is stamped
        with the generation's calibration version *and* the store's
        latest on-disk calibration version, so a loader under a newer
        calibration — counter bump or re-profiled store — drops it rather
        than serving a stale front.  Returns the number of fronts
        written."""
        store = self._store if store is None else store
        if store is None:
            raise ValueError("no CalibrationStore to persist to: pass one "
                             "here or wire store= at construction")
        version, entries = self._generation
        store_version = self._store_version(store)
        payload = [
            {"dag_fingerprint": e.dag_fingerprint, "dag_name": e.dag_name,
             "delta": e.delta, "calibration_version": version,
             "store_calibration_version": store_version,
             "membership_fingerprint": e.membership_fingerprint,
             "front": e.front.to_dict(plan_to_dict)}
            for e in entries.values()
        ]
        self._inserts_since_persist = 0
        n = store.save_fronts(self.cluster, payload)
        if self.telemetry is not None:
            self.telemetry.counter("plan_cache.persist", n,
                                   version=version)
        return n

    def warm_from(self, store=None) -> int:
        """Load persisted fronts into the current generation, skipping the
        cold frontier pass for every tenant they cover.  Stale entries are
        dropped, never served: an entry loads only if **both** its
        ``calibration_version`` matches the live version (restarting
        serving should seed its ``FeedbackLoop(calibration_version=...)``
        — and therefore this cache — with the same counter it persisted
        at) *and* its ``store_calibration_version`` matches the store's
        latest on-disk calibration, so a re-profiling between persist and
        restart invalidates even when the in-memory counters happen to
        collide.  A mismatch is conservative — the tenant re-plans cold —
        never wrong.  The eviction budget is enforced after loading.
        Returns the number of fronts loaded."""
        store = self._store if store is None else store
        if store is None:
            raise ValueError("no CalibrationStore to warm from: pass one "
                             "here or wire store= at construction")
        version = self.version
        store_version = self._store_version(store)
        entries = self._table(version)
        # entries written before membership keying existed carry no mask
        # hash; file them under the full-membership mask they were planned
        # over (every declared node available)
        full = membership_fingerprint(self.cluster.with_availability(
            [True] * len(self.cluster.nodes)))
        n = 0
        for raw in store.load_fronts(self.cluster):
            if (raw.get("calibration_version") != version
                    or raw.get("store_calibration_version")
                    != store_version):
                continue                      # stale: never serve it
            front = ParetoFront.from_dict(
                raw["front"], lambda d: plan_from_dict(d, self.cluster))
            mfp = raw.get("membership_fingerprint") or full
            # fronts for *every* membership load side by side: a returning
            # membership finds its entry warm even across a restart
            key = (self.fingerprint, mfp, version,
                   raw["dag_fingerprint"], raw["delta"])
            entries[key] = CacheEntry(
                dag_name=raw["dag_name"],
                dag_fingerprint=raw["dag_fingerprint"], delta=raw["delta"],
                front=front, membership_fingerprint=mfp,
                _nbytes=len(json.dumps(raw["front"])))
            n += 1
        self._evict(entries)
        self.loaded += n
        return n

    # --------------------------------------------------------------- stats
    def __len__(self) -> int:
        return len(self._generation[1])

    def nbytes(self) -> int:
        """Serialized size of every cached front — what ``max_bytes``
        budgets."""
        return sum(e.nbytes for e in self._generation[1].values())

    def tenants(self) -> tuple[str, ...]:
        """Dag names currently cached, least- to most-recently used."""
        return tuple(e.dag_name for e in self._generation[1].values())

    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def stats(self) -> dict:
        return {"hits": self.hits, "misses": self.misses,
                "evictions": self.evictions,
                "invalidations": self.invalidations, "loaded": self.loaded,
                "prewarmed": self.prewarmed,
                "prewarm_hits": self.prewarm_hits,
                "prewarm_misses": self.prewarm_misses,
                "speculative": sum(1 for e in self._generation[1].values()
                                   if e.speculative),
                "entries": len(self), "nbytes": self.nbytes(),
                "tenants": self.tenants(), "version": self.version,
                "fingerprint": self.fingerprint,
                "membership": self.membership_fingerprint,
                "hit_rate": self.hit_rate()}


class SpeculativePrewarmer:
    """Membership speculation driven by fleet epochs.

    Wires a :class:`PlanCache` to a ``repro.fleet.FleetController``: every
    membership epoch (and every explicit :meth:`prime` call — "idle time"
    in a serving loop) pre-builds fronts for the current membership and all
    single-departure neighbours, so the *next* departure is served entirely
    from cache — zero frontier passes, counter-verified via
    ``plan_cache.prewarm_hit`` and the absence of ``plan.frontier_pass``
    spans.  The fast DP engine makes each speculative pass share its rows
    with the pass that preceded it, which is what keeps idle-time
    speculation affordable (benchmarks/tab1_planner_overhead.py gates it).

    Attributes:
        cache: the plan cache speculated into.
        controller: the epoch source (its ``add_epoch_hook`` is used, so a
            serving engine's own ``on_epoch`` callback is untouched).
        epochs_seen: epochs observed via the hook.
        fronts_built: speculative fronts built by this prewarmer.
    """

    def __init__(self, cache: PlanCache, controller=None):
        self.cache = cache
        self.controller = controller
        self.epochs_seen = 0
        self.fronts_built = 0
        if controller is not None:
            if cache.membership_source is None:
                cache.membership_source = controller
            controller.add_epoch_hook(self._on_epoch)

    def prime(self, dags=None) -> int:
        """Run one speculation sweep now (idle-time trigger).  Returns the
        number of fronts built; already-warm memberships cost nothing."""
        built = self.cache.prewarm(dags=dags)
        self.fronts_built += built
        return built

    def _on_epoch(self, epoch) -> int:
        self.epochs_seen += 1
        return self.prime()

    def stats(self) -> dict:
        return {"epochs_seen": self.epochs_seen,
                "fronts_built": self.fronts_built}
