"""PlanCache — versioned plan frontiers on the serving hot path.

The paper pays its ~15 ms two-tier DP on *every* request; CoEdge
(arXiv:2012.03257) amortizes partition decisions across requests and DEFER
(arXiv:2201.06769) computes them once ahead of serving.  This cache gets
both: one (objective-independent) frontier pass per
``(cluster fingerprint, calibration version, dag name, δ)``, then any
request's objective is resolved against the cached
:class:`~repro.core.pareto.ParetoFront` with zero DP work — a dict lookup
plus an O(front-width) ``select``.

Keys and invalidation:

* the **cluster fingerprint** comes from the shared
  :func:`repro.core.fingerprint.cluster_fingerprint` — the same hash that
  files calibrations in ``CalibrationStore``, so plan-cache keys and
  calibration paths can never drift apart.  A board swap or link upgrade
  changes the fingerprint and cleanly orphans every cached front.
* the **calibration version** either lives in the cache
  (:meth:`bump_version`) or is read live from a ``version_source`` — any
  object with a ``calibration_version`` attribute, e.g. a
  ``repro.profiling.FeedbackLoop``, whose drift events increment it.
  Either way a bump is **atomic**: the version and the entry table swap in
  a single reference assignment, so a concurrent reader sees either the
  old generation (stale front, still internally consistent) or the new
  empty one — never a half-invalidated mix.
* after a bump, the next lookup per dag misses exactly once and pays one
  EXPLORE re-plan (the frontier pass); every other objective variation for
  that dag is a hit again.

``get`` stamps the returned plan's ``planning_seconds`` with what the
caller actually waited — the full frontier pass on a miss, the lookup
microseconds on a hit — so simulators and benchmarks measure the warm path
honestly.
"""

from __future__ import annotations

import dataclasses
import time

from repro.core.cost_model import Cluster
from repro.core.dag import ModelDAG
from repro.core.fingerprint import cluster_fingerprint
from repro.core.hidp import HiDPPlan, HiDPPlanner
from repro.core.objective import Objective
from repro.core.pareto import ParetoFront


class PlanCache:
    """Cached plan frontiers for one cluster, served by one planner.

    Attributes:
        planner: the :class:`~repro.core.hidp.HiDPPlanner` that computes
            frontiers on a miss (its config fixes provider, radio pricing,
            and the default δ).
        fingerprint: the cluster's topology hash (shared with
            ``CalibrationStore``).
        hits / misses / invalidations: lifetime counters; ``misses`` counts
            EXPLORE re-plans (full frontier passes).
    """

    def __init__(self, planner: HiDPPlanner, cluster: Cluster, *,
                 version: int = 0, version_source=None):
        self.planner = planner
        self.cluster = cluster
        self.fingerprint = cluster_fingerprint(cluster)
        self._version_source = version_source
        if version_source is not None:
            version = version_source.calibration_version
        # one atomically-swapped generation: (version, {key: front})
        self._generation: tuple[int, dict[tuple, ParetoFront]] = \
            (int(version), {})
        self.hits = 0
        self.misses = 0
        self.invalidations = 0

    # -------------------------------------------------------------- keying
    @property
    def version(self) -> int:
        """The calibration version cached fronts are valid for — read live
        from ``version_source`` when one is wired, so a FeedbackLoop drift
        event invalidates without calling into the cache at all."""
        if self._version_source is not None:
            return int(self._version_source.calibration_version)
        return self._generation[0]

    def key(self, dag_name: str, delta: float | None = None) -> tuple:
        """``(cluster fingerprint, calibration version, dag name, δ)``."""
        if delta is None:
            delta = self.planner.config.delta
        return (self.fingerprint, self.version, dag_name, delta)

    # ------------------------------------------------------------- lookups
    def front(self, dag: ModelDAG, delta: float | None = None) -> ParetoFront:
        """The cached frontier for ``dag`` — one DP pass per generation."""
        key = self.key(dag.name, delta)
        version, fronts = self._generation
        if version != key[1]:
            # version_source moved on: start a fresh generation atomically
            version, fronts = key[1], {}
            self._generation = (version, fronts)
            self.invalidations += 1
        front = fronts.get(key)
        if front is None:
            self.misses += 1
            planner = (self.planner if delta is None
                       else self.planner.at_delta(delta))
            front = planner.front(dag, self.cluster)
            fronts[key] = front
        else:
            self.hits += 1
        return front

    def get(self, dag: ModelDAG, objective: Objective | str | None = None,
            delta: float | None = None) -> HiDPPlan:
        """Resolve one request: select ``objective`` over the cached front.
        Zero DP work on a hit.  ``objective`` may be an
        :class:`~repro.core.objective.Objective` or a metric name
        (``"latency"`` | ``"energy"`` | ``"edp"``)."""
        if isinstance(objective, str):
            objective = Objective(objective)
        t0 = time.perf_counter()
        misses = self.misses
        front = self.front(dag, delta)
        plan = front.select(objective)
        if misses != self.misses:
            return plan          # cold: keep the frontier pass's own timing
        return dataclasses.replace(
            plan, planning_seconds=time.perf_counter() - t0)

    # -------------------------------------------------------- invalidation
    def bump_version(self, version: int | None = None) -> int:
        """Atomically invalidate every cached front: the (version, table)
        pair swaps in one assignment.  No-op counter-wise when a
        ``version_source`` drives the version (it already moved)."""
        if self._version_source is not None:
            raise RuntimeError(
                "version is driven by version_source; bump it there "
                "(FeedbackLoop drift events do this automatically)")
        new = self._generation[0] + 1 if version is None else int(version)
        self._generation = (new, {})
        self.invalidations += 1
        return new

    def on_drift(self) -> None:
        """Hook for ``FeedbackLoop(on_drift=cache.on_drift)`` when no
        version_source is wired: one drift event → one atomic bump → the
        next lookup per dag is the single EXPLORE re-plan."""
        if self._version_source is None:
            self.bump_version()

    # --------------------------------------------------------------- stats
    def __len__(self) -> int:
        return len(self._generation[1])

    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def stats(self) -> dict:
        return {"hits": self.hits, "misses": self.misses,
                "invalidations": self.invalidations,
                "entries": len(self), "version": self.version,
                "fingerprint": self.fingerprint,
                "hit_rate": self.hit_rate()}
