"""Serving engine: continuous batching over a slotted KV cache, driven by the
HiDP plan.

The engine is the TPU rendering of the paper's Run-time Scheduler FSM
(Fig. 4): ANALYZE admits queued requests into free slots, EXPLORE is the
HiDP planning pass (done once per (arch × shape × mesh), re-entered on
elasticity events), OFFLOAD/MAP dispatch the jitted prefill/decode
executables with plan-derived shardings, EXECUTE streams decode steps and
merges emitted tokens per request (Alg. 1 line 13).

Runs identically on a CPU test mesh (tiny configs) and the production mesh.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.objective import METRICS
from repro.core.scheduler import State
from repro.models.model import Model


@dataclasses.dataclass
class Request:
    request_id: int
    prompt: np.ndarray                  # (P,) int32
    max_new_tokens: int = 16
    eos_id: int | None = None
    # what this request asks the planner to minimize when (re-)planning:
    # "latency" | "energy" | "edp" (an Objective's metric name)
    objective: str = "latency"
    # filled during serving
    slot: int | None = None
    generated: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    """``feedback`` (a ``repro.profiling.FeedbackLoop``) closes the paper's
    ANALYZE↔EXECUTE loop at serving time: every decode step's wall-clock
    latency is reported as an observation keyed ``engine/decode``; when the
    loop flags drift the engine re-enters EXPLORE (traced, counted in
    ``replans``) and calls ``on_replan`` — typically
    ``ElasticController.on_drift`` or a fresh HiDP planning pass.

    Requests carry a per-request planning *objective* (``"latency"`` |
    ``"energy"`` | ``"edp"``, see ``repro.core.Objective``): the engine
    itself executes whatever plan it is given, but it tracks what the
    in-flight traffic asked for and exposes :meth:`dominant_objective` so an
    ``on_replan`` callback can hand the right ``Objective`` to the next
    planning pass (e.g. battery-saver clients requesting ``energy`` flip the
    fleet to energy-optimal plans once they dominate the batch).

    ``plan_cache`` (a ``repro.serving.plan_cache.PlanCache``) + ``plan_dag``
    (the ModelDAG describing the served workload) put planning on the cached
    frontier: every ``submit`` resolves its request's objective against the
    cached front — zero DP work after the first request — and a drift event
    re-enters EXPLORE with exactly one frontier re-plan, selected at the
    then-dominant objective.  Wire the same ``feedback`` loop as the cache's
    ``version_source`` and the bump is atomic with the refit."""

    def __init__(self, model: Model, params: dict, *, max_batch: int = 4,
                 max_len: int = 128, plan=None, donate: bool = True,
                 feedback=None, on_replan: Callable[[], Any] | None = None,
                 plan_cache=None, plan_dag=None):
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.plan = plan
        self.feedback = feedback
        self.on_replan = on_replan
        if (plan_cache is None) != (plan_dag is None):
            raise ValueError(
                "plan_cache and plan_dag go together: the cache needs the "
                "served workload's ModelDAG to resolve objectives against "
                "its frontier — pass both or neither")
        self.plan_cache = plan_cache
        self.plan_dag = plan_dag
        self.replans = 0
        self._decode_steps = 0
        self.cache = model.init_cache(max_batch, max_len)
        self.lengths = np.zeros((max_batch,), np.int32)
        self.slot_req: list[Request | None] = [None] * max_batch
        self.queue: deque[Request] = deque()
        self.completed: dict[int, Request] = {}
        self._next_id = 0
        self.state = State.ANALYZE
        self.trace: list[State] = []

        self._decode = jax.jit(
            lambda p, c, b: model.apply_decode(p, c, b),
            donate_argnums=(1,) if donate else ())
        self._prefill_cache: dict[int, Callable] = {}

    # ------------------------------------------------------------------ API
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 16,
               eos_id: int | None = None,
               objective: str = "latency") -> int:
        """Queue one request.  ``objective`` names the planning metric this
        request wants (``"latency"`` | ``"energy"`` | ``"edp"``).  With a
        ``plan_cache`` wired, the objective is resolved against the cached
        plan frontier right here — a lookup + select, no DP pass."""
        if objective not in METRICS:
            raise ValueError(f"unknown objective {objective!r}; "
                             f"expected one of {METRICS}")
        rid = self._next_id
        self._next_id += 1
        if self.plan_cache is not None and self.plan_dag is not None:
            self.plan = self.plan_cache.get(self.plan_dag,
                                            objective=objective)
        self.queue.append(Request(rid, np.asarray(prompt, np.int32),
                                  max_new_tokens, eos_id,
                                  objective=objective))
        return rid

    def active(self) -> int:
        return sum(r is not None for r in self.slot_req)

    def dominant_objective(self) -> str:
        """The most-requested objective among queued + in-flight requests —
        what an ``on_replan`` callback (and the post-drift cache re-plan)
        hands the next planning pass.  Tie-breaking is deterministic by the
        fixed ``METRICS`` order (latency > energy > edp; empty engine →
        "latency"), so re-plan objectives — and therefore cache behaviour —
        are reproducible across runs regardless of dict or arrival order."""
        counts = dict.fromkeys(METRICS, 0)
        for r in self.queue:
            counts[r.objective] += 1
        for r in self.slot_req:
            if r is not None:
                counts[r.objective] += 1
        return max(METRICS, key=counts.__getitem__)

    def run_until_done(self, max_steps: int = 10_000) -> dict[int, Request]:
        for _ in range(max_steps):
            if not self.queue and self.active() == 0:
                break
            self.step()
        return self.completed

    # ----------------------------------------------------------------- admit
    def _prefill_fn(self, plen: int) -> Callable:
        if plen not in self._prefill_cache:
            self._prefill_cache[plen] = jax.jit(
                lambda p, b: self.model.apply_prefill(p, b))
        return self._prefill_cache[plen]

    def _admit(self) -> None:
        self.state = State.ANALYZE
        self.trace.append(self.state)
        for slot in range(self.max_batch):
            if self.slot_req[slot] is not None or not self.queue:
                continue
            req = self.queue.popleft()
            plen = len(req.prompt)
            batch = {"tokens": jnp.asarray(req.prompt[None, :])}
            if self.model.cfg.family == "audio":
                batch["frames"] = jnp.zeros(
                    (1, max(plen // 2, 1), self.model.cfg.d_model),
                    jnp.bfloat16)
            if self.model.cfg.family == "vlm":
                batch["vision"] = jnp.zeros(
                    (1, self.model.cfg.n_vision_tokens,
                     self.model.cfg.d_model), jnp.bfloat16)
            batch["lengths"] = jnp.asarray([plen], jnp.int32)
            logits, pcache = self._prefill_fn(plen)(self.params, batch)
            self._write_slot(slot, pcache, plen)
            first = int(jnp.argmax(logits[0, -1]))
            req.slot = slot
            req.generated.append(first)
            self.slot_req[slot] = req
            self.lengths[slot] = plen + 1
            self._append_token(slot, first, plen)

    def _write_slot(self, slot: int, pcache: dict, plen: int) -> None:
        """Copy a (L, 1, P, ...) prefill cache into slot ``slot`` of the
        engine cache (padded to max_len)."""
        def write(dst, src):
            if dst.ndim >= 3 and src.shape[-1] == dst.shape[-1] \
                    and dst.shape[-3] == self.max_len:
                # (..., B, S, H, D) positional cache
                return dst.at[..., slot, :src.shape[-3], :, :].set(
                    src[..., 0, :, :, :])
            # recurrent state: (..., B, ...) — copy the batch slice
            return dst.at[..., slot:slot + 1, :, :].set(src) \
                if False else dst
        new = {}
        for k in self.cache:
            dst, src = self.cache[k], pcache[k]
            if k in ("k", "v", "xk", "xv"):
                # (..., 1, P, H, D) → slot write at seq prefix
                p = src.shape[-3]
                new[k] = dst.at[..., slot, :p, :, :].set(src[..., 0, :p, :, :])
            elif k == "h":
                new[k] = dst.at[..., slot, :, :, :].set(src[..., 0, :, :, :])
            elif k == "conv":
                new[k] = dst.at[..., slot, :, :].set(src[..., 0, :, :])
            else:
                new[k] = dst
        self.cache = new

    def _append_token(self, slot: int, token: int, pos: int) -> None:
        pass  # token history kept host-side in Request.generated

    # ---------------------------------------------------------------- decode
    def step(self) -> None:
        self._admit()
        if self.active() == 0:
            return
        self.state = State.EXECUTE
        self.trace.append(self.state)
        tokens = np.zeros((self.max_batch, 1), np.int32)
        for s, req in enumerate(self.slot_req):
            if req is not None:
                tokens[s, 0] = req.generated[-1]
        batch = {"tokens": jnp.asarray(tokens),
                 "lengths": jnp.asarray(np.maximum(self.lengths, 1))}
        t0 = time.perf_counter()
        logits, self.cache = self._decode(self.params, self.cache, batch)
        jax.block_until_ready(logits)
        step_s = time.perf_counter() - t0
        self._decode_steps += 1
        if self.feedback is not None and self._decode_steps > 1:
            # step 1 pays jit compilation — not a hardware signal
            # work = decoded tokens this step (batch-occupancy proxy for
            # FLOPs; the loop's regressor absorbs the per-token constant)
            drifted = self.feedback.observe(
                "engine/decode", "decode", float(self.active()), 0.0, step_s)
            if drifted:
                self.state = State.EXPLORE
                self.trace.append(self.state)
                self.replans += 1
                if self.plan_cache is not None and self.plan_dag is not None:
                    # the drift already bumped the calibration version (via
                    # version_source or this on_drift); re-plan exactly once,
                    # at the objective the in-flight traffic wants
                    self.plan_cache.on_drift()
                    self.plan = self.plan_cache.get(
                        self.plan_dag, objective=self.dominant_objective())
                if self.on_replan is not None:
                    self.on_replan()
        nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
        for s, req in enumerate(self.slot_req):
            if req is None:
                continue
            tok = int(nxt[s])
            req.generated.append(tok)
            self.lengths[s] += 1
            over = len(req.generated) >= req.max_new_tokens
            hit_eos = req.eos_id is not None and tok == req.eos_id
            full = self.lengths[s] >= self.max_len
            if over or hit_eos or full:
                req.done = True
                self.completed[req.request_id] = req
                self.slot_req[s] = None
                self.lengths[s] = 0
