"""Serving engine: continuous batching over a slotted KV cache, driven by the
HiDP plan.

The engine is the TPU rendering of the paper's Run-time Scheduler FSM
(Fig. 4): ANALYZE admits queued requests into free slots, EXPLORE is the
HiDP planning pass (amortized by the shared multi-tenant ``PlanCache`` —
one frontier pass per tenant, re-entered per tenant on drift/elasticity
events), OFFLOAD/MAP dispatch the jitted prefill/decode executables with
plan-derived shardings, EXECUTE streams decode steps and merges emitted
tokens per request (Alg. 1 line 13).

Each ``submit`` may name its tenant (``dag=``, a ModelDAG) and objective;
the request's plan is resolved from the cache's warm frontier — see
docs/serving.md for the full multi-tenant lifecycle.

Runs identically on a CPU test mesh (tiny configs) and the production mesh.
"""

from __future__ import annotations

import contextlib
import dataclasses
import time
from collections import deque
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fingerprint import dag_fingerprint
from repro.core.objective import METRICS
from repro.core.scheduler import State
from repro.models.model import Model


@dataclasses.dataclass
class Request:
    request_id: int
    prompt: np.ndarray                  # (P,) int32
    max_new_tokens: int = 16
    eos_id: int | None = None
    # what this request asks the planner to minimize when (re-)planning:
    # "latency" | "energy" | "edp" (an Objective's metric name)
    objective: str = "latency"
    # which tenant (ModelDAG) this request belongs to — resolved against
    # the shared PlanCache; None when the engine serves without a cache
    dag: Any = None
    # filled during serving
    slot: int | None = None
    generated: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    """``feedback`` (a ``repro.profiling.FeedbackLoop``) closes the paper's
    ANALYZE↔EXECUTE loop at serving time: every decode step's wall-clock
    latency is reported as an observation keyed ``engine/decode``; when the
    loop flags drift the engine re-enters EXPLORE (traced, counted in
    ``replans``) and calls ``on_replan`` — typically
    ``ElasticController.on_drift`` or a fresh HiDP planning pass.

    Requests carry a per-request planning *objective* (``"latency"`` |
    ``"energy"`` | ``"edp"``, see ``repro.core.Objective``): the engine
    itself executes whatever plan it is given, but it tracks what the
    in-flight traffic asked for and exposes :meth:`dominant_objective` so an
    ``on_replan`` callback can hand the right ``Objective`` to the next
    planning pass (e.g. battery-saver clients requesting ``energy`` flip the
    fleet to energy-optimal plans once they dominate the batch).

    ``plan_cache`` (a ``repro.serving.plan_cache.PlanCache``) puts planning
    on the shared multi-tenant frontier cache: every ``submit`` names its
    tenant with ``dag=`` (a ModelDAG; ``default_dag`` covers single-tenant
    deployments) and resolves its objective against that tenant's cached
    front — zero DP work after each tenant's first request.  A drift event
    re-enters EXPLORE with exactly **one frontier re-plan per in-flight
    tenant**, each selected at that tenant's dominant objective
    (:meth:`dominant_objective`); per-tenant selections land in
    ``tenant_plans`` keyed by dag fingerprint.  Wire the same ``feedback``
    loop as the cache's ``version_source`` and the bump is atomic with the
    refit.

    Under churn (``repro.fleet``), wire a ``FleetController``'s
    ``on_epoch`` to :meth:`on_membership_change` and give the cache the
    controller as its ``membership_source``: every membership epoch then
    re-enters EXPLORE with one plan resolution per in-flight tenant — a
    single frontier pass for a never-seen membership, a pure warm hit for
    a returning one (see docs/fleet.md).

    ``telemetry`` (a ``repro.telemetry.TelemetryRecorder``) records every
    submit's per-tenant cache resolution (hit | miss | none) and every
    EXPLORE re-entry (drift or membership epoch) as structured counters —
    see docs/observability.md."""

    def __init__(self, model: Model, params: dict, *, max_batch: int = 4,
                 max_len: int = 128, plan=None, donate: bool = True,
                 feedback=None, on_replan: Callable[[], Any] | None = None,
                 plan_cache=None, default_dag=None, telemetry=None):
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.plan = plan
        self.feedback = feedback
        self.on_replan = on_replan
        from repro.telemetry import active as _tel_active
        self.telemetry = _tel_active(telemetry)
        if plan_cache is None and default_dag is not None:
            raise ValueError(
                "default_dag names the tenant submits resolve against a "
                "plan_cache; without a cache there is nothing to resolve "
                "— pass plan_cache too")
        self.plan_cache = plan_cache
        self.default_dag = default_dag
        # most recent plan selection per tenant, keyed by dag fingerprint,
        # and each tenant's compute intensity (part of its cache key)
        self.tenant_plans: dict[str, Any] = {}
        self._tenant_deltas: dict[str, float | None] = {}
        self.replans = 0
        self._decode_steps = 0
        self.cache = model.init_cache(max_batch, max_len)
        self.lengths = np.zeros((max_batch,), np.int32)
        self.slot_req: list[Request | None] = [None] * max_batch
        self.queue: deque[Request] = deque()
        self.completed: dict[int, Request] = {}
        self._next_id = 0
        self.state = State.ANALYZE
        self.trace: list[State] = []

        self._decode = jax.jit(
            lambda p, c, b: model.apply_decode(p, c, b),
            donate_argnums=(1,) if donate else ())
        self._prefill_cache: dict[int, Callable] = {}

    # ------------------------------------------------------------------ API
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 16,
               eos_id: int | None = None, objective: str = "latency",
               dag=None, delta: float | None = None) -> int:
        """Queue one request.  ``objective`` names the planning metric this
        request wants (``"latency"`` | ``"energy"`` | ``"edp"``); ``dag``
        names its tenant (falling back to ``default_dag``) and ``delta``
        the tenant's compute intensity — part of the cache key, so it must
        match what warmed (or persisted) the tenant's front; None uses the
        cache planner's default.  With a ``plan_cache`` wired, the
        objective is resolved against that tenant's cached frontier right
        here — a lookup + select, no DP pass after the tenant's first
        request.  ``self.plan`` tracks the most recent resolution;
        per-tenant selections live in ``tenant_plans``."""
        if objective not in METRICS:
            raise ValueError(f"unknown objective {objective!r}; "
                             f"expected one of {METRICS}")
        dag = dag if dag is not None else self.default_dag
        if dag is not None and self.plan_cache is None:
            raise ValueError(
                "submit(dag=...) names a tenant to resolve against a "
                "plan_cache, but the engine has none — wire plan_cache=")
        rid = self._next_id
        self._next_id += 1
        if self.plan_cache is not None:
            if dag is None:
                raise ValueError(
                    "a plan_cache is wired but this submit names no "
                    "tenant: pass dag= here or default_dag= to the engine")
            misses0 = self.plan_cache.misses
            # the resolve context roots this submit's trace subtree: the
            # cache's hit/miss counters and any frontier-pass span it
            # triggers auto-parent under it
            with (self.telemetry.trace(
                      "engine.resolve", tenant=dag.name, request=rid,
                      objective=objective, wall=True)
                  if self.telemetry is not None
                  else contextlib.nullcontext()):
                self.plan = self.plan_cache.get(dag, objective=objective,
                                                delta=delta)
                fp = dag_fingerprint(dag)
                self.tenant_plans[fp] = self.plan
                self._tenant_deltas[fp] = delta
                if self.telemetry is not None:
                    # per-tenant cache resolution: was this submit served
                    # off the warm front, or did it pay the tenant's DP
                    # pass?
                    self.telemetry.counter(
                        "engine.submit", tenant=dag.name, request=rid,
                        objective=objective,
                        resolved="miss" if self.plan_cache.misses > misses0
                        else "hit")
        elif self.telemetry is not None:
            self.telemetry.counter("engine.submit", request=rid,
                                   objective=objective, resolved="none")
        self.queue.append(Request(rid, np.asarray(prompt, np.int32),
                                  max_new_tokens, eos_id,
                                  objective=objective, dag=dag))
        return rid

    def active(self) -> int:
        return sum(r is not None for r in self.slot_req)

    def _requests(self):
        """Queued + in-flight requests, queue first."""
        yield from self.queue
        for r in self.slot_req:
            if r is not None:
                yield r

    def _tenant_traffic(self) -> dict:
        """``{dag fingerprint: (dag, request count)}`` over queued +
        in-flight requests."""
        by_fp: dict[str, Any] = {}
        for r in self._requests():
            if r.dag is not None:
                fp = dag_fingerprint(r.dag)
                dag, n = by_fp.get(fp, (r.dag, 0))
                by_fp[fp] = (dag, n + 1)
        return by_fp

    def tenant_dags(self) -> list:
        """The distinct tenants with queued or in-flight traffic, ordered
        by dag fingerprint so per-tenant re-plans (and therefore cache
        behaviour) are deterministic regardless of arrival order."""
        traffic = self._tenant_traffic()
        return [traffic[fp][0] for fp in sorted(traffic)]

    def dominant_objective(self, dag=None) -> str:
        """The most-requested objective among queued + in-flight requests —
        what an ``on_replan`` callback (and the post-drift cache re-plan)
        hands the next planning pass.  ``dag`` restricts the count to one
        tenant's traffic (how each tenant's drift re-plan picks its own
        objective).  Tie-breaking is deterministic by the fixed ``METRICS``
        order (latency > energy > edp; empty engine → "latency"), so
        re-plan objectives — and therefore cache behaviour — are
        reproducible across runs regardless of dict or arrival order."""
        fp = None if dag is None else dag_fingerprint(dag)
        counts = dict.fromkeys(METRICS, 0)
        for r in self._requests():
            if fp is None or (r.dag is not None
                              and dag_fingerprint(r.dag) == fp):
                counts[r.objective] += 1
        return max(METRICS, key=counts.__getitem__)

    def _replan_in_flight_tenants(self) -> None:
        """One cache resolution per in-flight tenant, each at that tenant's
        dominant objective and keyed delta; the engine-level plan follows
        the busiest tenant (ties break low-fingerprint-first), never an
        arbitrary last writer."""
        traffic = self._tenant_traffic()
        for fp in sorted(traffic):
            dag = traffic[fp][0]
            self.tenant_plans[fp] = self.plan_cache.get(
                dag, objective=self.dominant_objective(dag),
                delta=self._tenant_deltas.get(fp))
        if traffic:
            busiest = max(sorted(traffic), key=lambda f: traffic[f][1])
            self.plan = self.tenant_plans[busiest]

    def on_membership_change(self, epoch=None) -> None:
        """The fleet's membership moved (a ``repro.fleet.FleetController``
        epoch — wire this as its ``on_epoch`` callback): re-enter EXPLORE
        with exactly one plan resolution per in-flight tenant.  Unlike
        drift, nothing is invalidated — the cache key's membership
        fingerprint changed under us, so a brand-new membership costs one
        frontier pass per affected tenant while a *returning* membership
        (a node that left and came back) resolves warm with zero DP work.
        ``epoch`` (the :class:`~repro.fleet.MembershipEpoch`) is accepted
        and ignored so the callback wires directly."""
        self.state = State.EXPLORE
        self.trace.append(self.state)
        self.replans += 1
        # one trace subtree per EXPLORE re-entry: the replan counter and
        # every per-tenant resolution (warm hit or frontier pass) parent
        # under it
        with (self.telemetry.trace(
                  "engine.replan_pass", reason="epoch",
                  epoch=getattr(epoch, "epoch", None), wall=True)
              if self.telemetry is not None
              else contextlib.nullcontext()):
            if self.telemetry is not None:
                self.telemetry.counter(
                    "engine.replan", reason="epoch",
                    epoch=getattr(epoch, "epoch", None),
                    tenants=len(self._tenant_traffic()))
            if self.plan_cache is not None:
                self._replan_in_flight_tenants()
            if self.on_replan is not None:
                self.on_replan()

    def run_until_done(self, max_steps: int = 10_000) -> dict[int, Request]:
        for _ in range(max_steps):
            if not self.queue and self.active() == 0:
                break
            self.step()
        return self.completed

    # ----------------------------------------------------------------- admit
    def _prefill_fn(self, plen: int) -> Callable:
        if plen not in self._prefill_cache:
            self._prefill_cache[plen] = jax.jit(
                lambda p, b: self.model.apply_prefill(p, b))
        return self._prefill_cache[plen]

    def _admit(self) -> None:
        self.state = State.ANALYZE
        self.trace.append(self.state)
        for slot in range(self.max_batch):
            if self.slot_req[slot] is not None or not self.queue:
                continue
            req = self.queue.popleft()
            plen = len(req.prompt)
            batch = {"tokens": jnp.asarray(req.prompt[None, :])}
            if self.model.cfg.family == "audio":
                batch["frames"] = jnp.zeros(
                    (1, max(plen // 2, 1), self.model.cfg.d_model),
                    jnp.bfloat16)
            if self.model.cfg.family == "vlm":
                batch["vision"] = jnp.zeros(
                    (1, self.model.cfg.n_vision_tokens,
                     self.model.cfg.d_model), jnp.bfloat16)
            batch["lengths"] = jnp.asarray([plen], jnp.int32)
            logits, pcache = self._prefill_fn(plen)(self.params, batch)
            self._write_slot(slot, pcache, plen)
            first = int(jnp.argmax(logits[0, -1]))
            req.slot = slot
            req.generated.append(first)
            self.slot_req[slot] = req
            self.lengths[slot] = plen + 1
            self._append_token(slot, first, plen)

    def _write_slot(self, slot: int, pcache: dict, plen: int) -> None:
        """Copy a (L, 1, P, ...) prefill cache into slot ``slot`` of the
        engine cache (padded to max_len)."""
        def write(dst, src):
            if dst.ndim >= 3 and src.shape[-1] == dst.shape[-1] \
                    and dst.shape[-3] == self.max_len:
                # (..., B, S, H, D) positional cache
                return dst.at[..., slot, :src.shape[-3], :, :].set(
                    src[..., 0, :, :, :])
            # recurrent state: (..., B, ...) — copy the batch slice
            return dst.at[..., slot:slot + 1, :, :].set(src) \
                if False else dst
        new = {}
        for k in self.cache:
            dst, src = self.cache[k], pcache[k]
            if k in ("k", "v", "xk", "xv"):
                # (..., 1, P, H, D) → slot write at seq prefix
                p = src.shape[-3]
                new[k] = dst.at[..., slot, :p, :, :].set(src[..., 0, :p, :, :])
            elif k == "h":
                new[k] = dst.at[..., slot, :, :, :].set(src[..., 0, :, :, :])
            elif k == "conv":
                new[k] = dst.at[..., slot, :, :].set(src[..., 0, :, :])
            else:
                new[k] = dst
        self.cache = new

    def _append_token(self, slot: int, token: int, pos: int) -> None:
        pass  # token history kept host-side in Request.generated

    # ---------------------------------------------------------------- decode
    def step(self) -> None:
        self._admit()
        if self.active() == 0:
            return
        self.state = State.EXECUTE
        self.trace.append(self.state)
        tokens = np.zeros((self.max_batch, 1), np.int32)
        for s, req in enumerate(self.slot_req):
            if req is not None:
                tokens[s, 0] = req.generated[-1]
        batch = {"tokens": jnp.asarray(tokens),
                 "lengths": jnp.asarray(np.maximum(self.lengths, 1))}
        t0 = time.perf_counter()
        logits, self.cache = self._decode(self.params, self.cache, batch)
        jax.block_until_ready(logits)
        step_s = time.perf_counter() - t0
        self._decode_steps += 1
        if self.feedback is not None and self._decode_steps > 1:
            # step 1 pays jit compilation — not a hardware signal
            # work = decoded tokens this step (batch-occupancy proxy for
            # FLOPs; the loop's regressor absorbs the per-token constant)
            drifted = self.feedback.observe(
                "engine/decode", "decode", float(self.active()), 0.0, step_s)
            if drifted:
                self.state = State.EXPLORE
                self.trace.append(self.state)
                self.replans += 1
                with (self.telemetry.trace("engine.replan_pass",
                                           reason="drift", wall=True)
                      if self.telemetry is not None
                      else contextlib.nullcontext()):
                    if self.telemetry is not None:
                        self.telemetry.counter(
                            "engine.replan", reason="drift",
                            tenants=len(self._tenant_traffic()))
                    if self.plan_cache is not None:
                        # the drift already bumped the calibration version
                        # (via version_source or this on_drift); re-plan
                        # exactly once *per in-flight tenant* — each
                        # tenant's first post-bump lookup is its single
                        # frontier pass — at the objective that tenant's
                        # traffic wants and the delta its front was keyed
                        # under
                        self.plan_cache.on_drift()
                        self._replan_in_flight_tenants()
                    if self.on_replan is not None:
                        self.on_replan()
        nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
        for s, req in enumerate(self.slot_req):
            if req is None:
                continue
            tok = int(nxt[s])
            req.generated.append(tok)
            self.lengths[s] += 1
            over = len(req.generated) >= req.max_new_tokens
            hit_eos = req.eos_id is not None and tok == req.eos_id
            full = self.lengths[s] >= self.max_len
            if over or hit_eos or full:
                req.done = True
                self.completed[req.request_id] = req
                self.slot_req[s] = None
                self.lengths[s] = 0
