"""repro.serving — the serving tier: continuous-batching engine + the
cluster's shared plan cache.

``ServingEngine`` executes (the paper's Run-time Scheduler FSM, Fig. 4);
``PlanCache`` keeps planning off the hot path for **every tenant sharing
the cluster**: one frontier pass per ``(cluster fingerprint, calibration
version, dag fingerprint, δ)``, every request objective served by
selection until a FeedbackLoop drift event bumps the version — then one
re-plan per tenant.  ``LRUEviction`` bounds the cache (entry/byte
budgets); ``PlanCache.persist``/``warm_from`` round-trip warm fronts
through ``CalibrationStore`` so restarts skip the cold pass.  See
docs/serving.md for the lifecycle.
"""

from .engine import Request, ServingEngine  # noqa: F401
from .plan_cache import (CacheEntry, LRUEviction, PlanCache,  # noqa: F401
                         SpeculativePrewarmer)
