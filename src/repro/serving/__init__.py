"""repro.serving — the serving tier: continuous-batching engine + plan cache.

``ServingEngine`` executes (the paper's Run-time Scheduler FSM, Fig. 4);
``PlanCache`` keeps planning off the hot path: one frontier pass per
``(cluster fingerprint, calibration version, dag)``, every request objective
served by selection until a FeedbackLoop drift event bumps the version.
See docs/planning.md for the cache lifecycle.
"""

from .engine import Request, ServingEngine  # noqa: F401
from .plan_cache import PlanCache  # noqa: F401
