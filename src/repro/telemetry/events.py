"""Typed, timestamped telemetry events — the fleet's structured record.

Every observable fact in the stack becomes one :class:`TelemetryEvent` of
exactly three kinds:

``span``
    Something with an extent: a request, a retry attempt, a DP frontier
    pass, a kernel micro-benchmark.  ``value`` is the span's duration in
    the *deterministic* time domain (simulated seconds); wall-clock-
    measured extents (planning passes, kernel timings) carry their
    measured seconds in ``wall_s`` instead, because wall time is not
    replayable.
``counter``
    Something that happened N times: a cache hit, a retry, an eviction,
    an SLO violation.  ``value`` is the increment (usually 1).
``gauge``
    A level sampled at an instant: fleet membership size, drift
    magnitude, elastic world size, joules.

Spans form **trace trees**: every span may carry a recorder-assigned
``span_id`` and any event a ``parent_id`` naming the span it happened
*inside* — a retry attempt under its request, a frontier pass under the
submit that triggered it, a per-stage compute shard under its attempt.
Ids come from the recorder's deterministic allocation counter (program
order, not wall clocks), so parentage is part of the replayable surface:
:meth:`TelemetryEvent.canonical` **keeps** both fields, and two seeded
replays must agree on the whole tree byte-for-byte.
:mod:`repro.telemetry.trace` reconstructs the trees and computes
critical paths from them.

Determinism is a schema contract, not an aspiration: every field except
the :data:`WALL_FIELDS` (``wall`` — the unix timestamp, ``wall_s`` — a
wall-clock-measured duration) must be reproducible under the repo's
seeded-replay idiom.  Two seeded runs of the same churn trace therefore
produce byte-identical logs once those fields are stripped —
:meth:`TelemetryEvent.canonical` is that projection, and the test suite
holds the whole pipeline to it.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Mapping

#: event kinds, fixed — queries and reports switch on these
KINDS = ("span", "counter", "gauge")

#: the only fields allowed to differ between two seeded replays of the
#: same run (wall-clock timestamp / wall-clock-measured duration)
WALL_FIELDS = ("wall", "wall_s")


@dataclasses.dataclass(frozen=True)
class TelemetryEvent:
    """One structured observation.

    Attributes:
        seq: recorder-assigned monotone sequence number — the total order
            events are replayed and compared in (deterministic, unlike
            wall time).
        kind: ``"span"`` | ``"counter"`` | ``"gauge"``.
        name: dotted event name, e.g. ``"sim.request"``,
            ``"plan_cache.hit"``, ``"fleet.membership"``.
        value: the deterministic payload — span duration (domain time),
            counter increment, or gauge level.
        t: logical time (simulated seconds for simulator-driven runs,
            the recorder's clock otherwise).
        tenant: the tenant (dag name) this event belongs to, ``""`` when
            not tenant-scoped.
        epoch: the fleet membership epoch in effect, None outside churn.
        attrs: free-form deterministic attributes (request id, node,
            metric, shape, ...).
        span_id: this span's identity in the trace tree (recorder-
            allocated, deterministic program order); None for events that
            are not themselves spans-with-children.
        parent_id: the ``span_id`` of the enclosing span — what makes
            flat logs reconstructable as causal trees; None for roots
            and for events emitted outside any span context.
        wall: unix timestamp at emission (nondeterministic, stripped by
            :meth:`canonical`).
        wall_s: wall-clock-measured duration for spans timed against
            real hardware (nondeterministic, stripped likewise).
    """

    seq: int
    kind: str
    name: str
    value: float
    t: float = 0.0
    tenant: str = ""
    epoch: int | None = None
    attrs: Mapping = dataclasses.field(default_factory=dict)
    span_id: int | None = None
    parent_id: int | None = None
    wall: float = 0.0
    wall_s: float | None = None

    def __post_init__(self) -> None:
        if self.kind not in KINDS:
            raise ValueError(f"unknown event kind {self.kind!r}; "
                             f"expected one of {KINDS}")

    # ------------------------------------------------------------- codecs
    def to_dict(self) -> dict:
        d = {"seq": self.seq, "kind": self.kind, "name": self.name,
             "value": self.value, "t": self.t}
        if self.tenant:
            d["tenant"] = self.tenant
        if self.epoch is not None:
            d["epoch"] = self.epoch
        if self.attrs:
            d["attrs"] = dict(self.attrs)
        if self.span_id is not None:
            d["span_id"] = self.span_id
        if self.parent_id is not None:
            d["parent_id"] = self.parent_id
        d["wall"] = self.wall
        if self.wall_s is not None:
            d["wall_s"] = self.wall_s
        return d

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), sort_keys=True,
                          separators=(",", ":"))

    @classmethod
    def from_dict(cls, d: Mapping) -> "TelemetryEvent":
        return cls(seq=int(d["seq"]), kind=d["kind"], name=d["name"],
                   value=float(d["value"]), t=float(d.get("t", 0.0)),
                   tenant=d.get("tenant", ""), epoch=d.get("epoch"),
                   attrs=dict(d.get("attrs", {})),
                   span_id=d.get("span_id"), parent_id=d.get("parent_id"),
                   wall=float(d.get("wall", 0.0)), wall_s=d.get("wall_s"))

    @classmethod
    def from_json(cls, line: str) -> "TelemetryEvent":
        return cls.from_dict(json.loads(line))

    # -------------------------------------------------------- determinism
    def canonical(self) -> str:
        """The event as JSON with the :data:`WALL_FIELDS` stripped — the
        byte string two seeded replays of the same run must agree on.
        ``span_id``/``parent_id`` are deliberately *kept*: trace-tree
        shape is deterministic and part of the replay contract."""
        d = self.to_dict()
        for f in WALL_FIELDS:
            d.pop(f, None)
        return json.dumps(d, sort_keys=True, separators=(",", ":"))
