"""repro.telemetry — structured fleet telemetry and the queryable run store.

The "observe" leg of the paper's closed loop, made durable: every
subsystem that matters at run time — the :class:`~repro.core.simulator.
EdgeSimulator` (request/attempt spans, retries, migrations, SLO,
joules), the :class:`~repro.serving.plan_cache.PlanCache` (per-tenant
hits/misses/evictions, DP frontier-pass spans), the
:class:`~repro.serving.engine.ServingEngine` (per-tenant cache
resolutions, EXPLORE re-entries), the :class:`~repro.fleet.
FleetController` (membership gauges, leader fail-overs), the
:class:`~repro.profiling.FeedbackLoop` (drift magnitude gauges), the
:class:`~repro.runtime.elastic.ElasticController` (world-size gauges),
and the :class:`~repro.profiling.Profiler` (kernel-profile spans) —
takes an optional ``telemetry=`` :class:`TelemetryRecorder` and emits
typed, timestamped events into it.

Events land in a :class:`RunStore` (JSONL log + atomic manifest, one
directory per run — the same filing idiom as ``CalibrationStore``) with
filtering and windowed-aggregation queries; :mod:`repro.telemetry.report`
turns a run into a p50/p99/energy/hit-rate summary and reconstructs the
simulator's ``SimReport`` aggregates *exactly* from the log.

Determinism and overhead are contracts, not hopes: seeded replays are
byte-identical modulo the designated wall-clock fields, and a disabled
recorder normalizes to no recorder at all (see :func:`active`), gated at
≤2 % in fig7.  See docs/observability.md.
"""

from .events import KINDS, WALL_FIELDS, TelemetryEvent  # noqa: F401
from .recorder import SpanHandle, TelemetryRecorder, active  # noqa: F401
from .report import run_summary, sim_aggregates  # noqa: F401
from .store import RunStore  # noqa: F401
from .trace import (SpanNode, critical_path,  # noqa: F401
                    node_utilization, overlap_headroom,
                    request_critical_paths, span_trees, tree_lines)
