"""Per-run summary reports over a :class:`~repro.telemetry.RunStore`.

Two layers:

* :func:`sim_aggregates` — the *exact* reconstruction surface: the run
  totals a :class:`~repro.core.simulator.SimReport` computes in memory
  (retries, migrations, SLO violations, per-tenant cache hits, total
  active joules), rebuilt purely from the durable event log.  The
  acceptance gate (fig7's telemetry section and
  ``tests/test_telemetry.py``) holds these equal to the in-memory report,
  so the log is a sufficient statistic for the run — not a lossy shadow.
* :func:`run_summary` / :func:`render` — the human table: request
  percentiles (p50/p99), energy, hit rates, retries per epoch, drift and
  membership history.

CLI (exit-code gated; CI smokes it)::

    python -m repro.telemetry.report <store-dir> [run]

exits nonzero when the store has no runs or the chosen run recorded no
events — an instrumented pipeline that produced nothing is a failure,
not an empty table.
"""

from __future__ import annotations

import sys

from .store import RunStore


def percentile(xs: list[float], q: float) -> float:
    """Nearest-rank percentile (q in [0, 100]); 0.0 for empty input."""
    if not xs:
        return 0.0
    xs = sorted(xs)
    i = max(0, min(len(xs) - 1, int(round(q / 100.0 * (len(xs) - 1)))))
    return xs[i]


def sim_aggregates(store: RunStore, run: str) -> dict:
    """Reconstruct a simulated run's ``SimReport`` totals from its event
    log alone.  Keys mirror the in-memory aggregates they must equal:
    ``total_retries`` / ``total_migrations`` / ``slo_violations``
    (``SimReport`` methods of the same name), ``total_active_joules``
    (sum of per-request active energy incl. radio), and
    ``cache_hits_by_tenant`` / ``cache_misses_by_tenant`` (the
    ``PlanCache`` counters, split per tenant — finer than the in-memory
    cache ever tracked)."""
    requests = store.events(run, kind="span", name="sim.request")
    return {
        "requests": len(requests),
        "latencies": [e.value for e in requests],
        "total_retries": int(sum(e.attrs.get("retries", 0)
                                 for e in requests)),
        "total_migrations": int(sum(e.attrs.get("migrations", 0)
                                    for e in requests)),
        "slo_violations": int(sum(1 for e in requests
                                  if e.attrs.get("slo_violated"))),
        "total_active_joules": float(sum(e.attrs.get("active_energy_j",
                                                     0.0)
                                         for e in requests)),
        "cache_hits_by_tenant": {
            t: int(v) for t, v in store.by_tenant(run,
                                                  "plan_cache.hit").items()},
        "cache_misses_by_tenant": {
            t: int(v)
            for t, v in store.by_tenant(run, "plan_cache.miss").items()},
        "retries_by_epoch": {
            int(k): int(v)
            for k, v in store.by_epoch(run, "sim.retry").items()},
    }


def run_summary(store: RunStore, run: str) -> dict:
    """The full per-run summary the CLI renders: :func:`sim_aggregates`
    plus latency percentiles, cache hit rate, frontier passes, membership
    epochs, leader elections, and drift events."""
    agg = sim_aggregates(store, run)
    lats = agg.pop("latencies")
    hits = sum(agg["cache_hits_by_tenant"].values())
    misses = sum(agg["cache_misses_by_tenant"].values())
    drift = store.events(run, kind="gauge", name="feedback.drift")
    membership = store.events(run, kind="gauge", name="fleet.membership")
    summary = {
        "run": run,
        **agg,
        "p50_latency_s": percentile(lats, 50),
        "p99_latency_s": percentile(lats, 99),
        "mean_latency_s": sum(lats) / len(lats) if lats else 0.0,
        "cache_hits": hits,
        "cache_misses": misses,
        "cache_hit_rate": hits / (hits + misses) if hits + misses else 0.0,
        "frontier_passes": len(store.events(run, kind="span",
                                            name="plan.frontier_pass")),
        "epochs": len(membership),
        "leader_elections": int(store.counter_total(
            run, "fleet.leader_election")),
        "drift_events": len(drift),
        "max_drift": max((e.value for e in drift), default=0.0),
        "events": len(store.events(run)),
    }
    return summary


def render(summary: dict) -> str:
    """One run, one table — fixed row order so reports diff cleanly."""
    rows = [
        ("requests", f"{summary['requests']}"),
        ("p50 latency", f"{summary['p50_latency_s'] * 1e3:10.1f} ms"),
        ("p99 latency", f"{summary['p99_latency_s'] * 1e3:10.1f} ms"),
        ("mean latency", f"{summary['mean_latency_s'] * 1e3:10.1f} ms"),
        ("active energy", f"{summary['total_active_joules']:10.2f} J"),
        ("retries", f"{summary['total_retries']}"),
        ("migrations", f"{summary['total_migrations']}"),
        ("SLO violations", f"{summary['slo_violations']}"),
        ("cache hits/misses",
         f"{summary['cache_hits']}/{summary['cache_misses']} "
         f"(rate {summary['cache_hit_rate']:.3f})"),
        ("frontier passes", f"{summary['frontier_passes']}"),
        ("membership epochs", f"{summary['epochs']}"),
        ("leader elections", f"{summary['leader_elections']}"),
        ("drift events", f"{summary['drift_events']} "
                         f"(max {summary['max_drift']:.3f})"),
        ("events", f"{summary['events']}"),
    ]
    width = max(len(k) for k, _ in rows)
    lines = [f"== telemetry report: run {summary['run']} =="]
    lines += [f"  {k:<{width}}  {v}" for k, v in rows]
    for tenant in sorted(set(summary["cache_hits_by_tenant"])
                         | set(summary["cache_misses_by_tenant"])):
        h = summary["cache_hits_by_tenant"].get(tenant, 0)
        m = summary["cache_misses_by_tenant"].get(tenant, 0)
        lines.append(f"  tenant {tenant or '<none>':<{width - 7}}  "
                     f"hits={h} misses={m}")
    for ep in sorted(summary["retries_by_epoch"]):
        lines.append(f"  epoch {ep:<{width - 6}}  "
                     f"retries={summary['retries_by_epoch'][ep]}")
    return "\n".join(lines)


def generate(store: RunStore, run: str | None = None) -> str:
    """Render the report for ``run`` (default: the latest).  Raises
    ``ValueError`` when the store has no runs or the run logged no
    events — the exit-code contract the CI smoke gates on."""
    if run is None:
        run = store.latest()
        if run is None:
            raise ValueError(f"no runs under {store.root}")
    if not store.events(run):
        raise ValueError(f"run {run!r} recorded no events")
    return render(run_summary(store, run))


def main(argv: list[str] | None = None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    if not argv or argv[0] in ("-h", "--help"):
        print(__doc__)
        return 0 if argv else 1
    store = RunStore(argv[0])
    run = argv[1] if len(argv) > 1 else None
    try:
        print(generate(store, run))
    except ValueError as e:
        print(f"telemetry report failed: {e}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
