"""Per-run summary reports over a :class:`~repro.telemetry.RunStore`.

Three layers:

* :func:`sim_aggregates` — the *exact* reconstruction surface: the run
  totals a :class:`~repro.core.simulator.SimReport` computes in memory
  (retries, migrations, SLO violations, per-tenant cache hits, total
  active joules), rebuilt purely from the durable event log.  The
  acceptance gate (fig7's telemetry section and
  ``tests/test_telemetry.py``) holds these equal to the in-memory report,
  so the log is a sufficient statistic for the run — not a lossy shadow.
* :func:`run_summary` / :func:`render` — the human table: request
  percentiles (p50/p99), energy, hit rates, retries per epoch, drift and
  membership history.
* :func:`render_trace` / :func:`render_timelines` — the causal layer
  (:mod:`repro.telemetry.trace`): where each request's latency went
  (plan/queue/compute/comm/retry-waste), per-resource utilization,
  overlap headroom, and ASCII latency/energy timelines drawn from
  :meth:`RunStore.aggregate` windows — no plotting dependencies.

CLI (exit-code gated; CI smokes it)::

    python -m repro.telemetry.report <store-dir> [run] [--window SECONDS]

exits nonzero when the store has no runs, the chosen run recorded no
events, or the run has a manifest but zero *span* events — an
instrumented pipeline that produced nothing (a disabled recorder wired
where an enabled one was meant) is a failure, not an empty table.
"""

from __future__ import annotations

import sys

from .store import RunStore
from .trace import CATEGORIES, trace_summary


def percentile(xs: list[float], q: float) -> float:
    """Nearest-rank percentile (q in [0, 100]); 0.0 for empty input."""
    if not xs:
        return 0.0
    xs = sorted(xs)
    i = max(0, min(len(xs) - 1, int(round(q / 100.0 * (len(xs) - 1)))))
    return xs[i]


def sim_aggregates(store: RunStore, run: str) -> dict:
    """Reconstruct a simulated run's ``SimReport`` totals from its event
    log alone.  Keys mirror the in-memory aggregates they must equal:
    ``total_retries`` / ``total_migrations`` / ``slo_violations``
    (``SimReport`` methods of the same name), ``total_active_joules``
    (sum of per-request active energy incl. radio), and
    ``cache_hits_by_tenant`` / ``cache_misses_by_tenant`` (the
    ``PlanCache`` counters, split per tenant — finer than the in-memory
    cache ever tracked)."""
    requests = store.events(run, kind="span", name="sim.request")
    return {
        "requests": len(requests),
        "latencies": [e.value for e in requests],
        "total_retries": int(sum(e.attrs.get("retries", 0)
                                 for e in requests)),
        "total_migrations": int(sum(e.attrs.get("migrations", 0)
                                    for e in requests)),
        "slo_violations": int(sum(1 for e in requests
                                  if e.attrs.get("slo_violated"))),
        "total_active_joules": float(sum(e.attrs.get("active_energy_j",
                                                     0.0)
                                         for e in requests)),
        "cache_hits_by_tenant": {
            t: int(v) for t, v in store.by_tenant(run,
                                                  "plan_cache.hit").items()},
        "cache_misses_by_tenant": {
            t: int(v)
            for t, v in store.by_tenant(run, "plan_cache.miss").items()},
        "retries_by_epoch": {
            int(k): int(v)
            for k, v in store.by_epoch(run, "sim.retry").items()},
    }


def run_summary(store: RunStore, run: str) -> dict:
    """The full per-run summary the CLI renders: :func:`sim_aggregates`
    plus latency percentiles, cache hit rate, frontier passes, membership
    epochs, leader elections, and drift events."""
    agg = sim_aggregates(store, run)
    lats = agg.pop("latencies")
    hits = sum(agg["cache_hits_by_tenant"].values())
    misses = sum(agg["cache_misses_by_tenant"].values())
    drift = store.events(run, kind="gauge", name="feedback.drift")
    membership = store.events(run, kind="gauge", name="fleet.membership")
    summary = {
        "run": run,
        **agg,
        "p50_latency_s": percentile(lats, 50),
        "p99_latency_s": percentile(lats, 99),
        "mean_latency_s": sum(lats) / len(lats) if lats else 0.0,
        "cache_hits": hits,
        "cache_misses": misses,
        "cache_hit_rate": hits / (hits + misses) if hits + misses else 0.0,
        "frontier_passes": len(store.events(run, kind="span",
                                            name="plan.frontier_pass")),
        "epochs": len(membership),
        "leader_elections": int(store.counter_total(
            run, "fleet.leader_election")),
        "drift_events": len(drift),
        "max_drift": max((e.value for e in drift), default=0.0),
        "events": len(store.events(run)),
    }
    return summary


def render(summary: dict) -> str:
    """One run, one table — fixed row order so reports diff cleanly."""
    rows = [
        ("requests", f"{summary['requests']}"),
        ("p50 latency", f"{summary['p50_latency_s'] * 1e3:10.1f} ms"),
        ("p99 latency", f"{summary['p99_latency_s'] * 1e3:10.1f} ms"),
        ("mean latency", f"{summary['mean_latency_s'] * 1e3:10.1f} ms"),
        ("active energy", f"{summary['total_active_joules']:10.2f} J"),
        ("retries", f"{summary['total_retries']}"),
        ("migrations", f"{summary['total_migrations']}"),
        ("SLO violations", f"{summary['slo_violations']}"),
        ("cache hits/misses",
         f"{summary['cache_hits']}/{summary['cache_misses']} "
         f"(rate {summary['cache_hit_rate']:.3f})"),
        ("frontier passes", f"{summary['frontier_passes']}"),
        ("membership epochs", f"{summary['epochs']}"),
        ("leader elections", f"{summary['leader_elections']}"),
        ("drift events", f"{summary['drift_events']} "
                         f"(max {summary['max_drift']:.3f})"),
        ("events", f"{summary['events']}"),
    ]
    width = max(len(k) for k, _ in rows)
    lines = [f"== telemetry report: run {summary['run']} =="]
    lines += [f"  {k:<{width}}  {v}" for k, v in rows]
    for tenant in sorted(set(summary["cache_hits_by_tenant"])
                         | set(summary["cache_misses_by_tenant"])):
        h = summary["cache_hits_by_tenant"].get(tenant, 0)
        m = summary["cache_misses_by_tenant"].get(tenant, 0)
        lines.append(f"  tenant {tenant or '<none>':<{width - 7}}  "
                     f"hits={h} misses={m}")
    for ep in sorted(summary["retries_by_epoch"]):
        lines.append(f"  epoch {ep:<{width - 6}}  "
                     f"retries={summary['retries_by_epoch'][ep]}")
    return "\n".join(lines)


def render_trace(tsum: dict) -> str:
    """The causal section: critical-path category breakdown (mean
    seconds and share of mean latency), per-resource utilization, and
    overlap headroom.  Empty string when the run has no request roots
    (pure benchmark runs) — the caller then skips the section."""
    if not tsum["requests"]:
        return ""
    lines = [f"  -- critical path ({tsum['requests']} requests, mean "
             f"latency {tsum['mean_latency_s'] * 1e3:.1f} ms) --"]
    width = max(len(c) for c in CATEGORIES)
    for cat in CATEGORIES:
        mean = tsum["category_means_s"][cat]
        frac = tsum["category_fractions"][cat]
        bar = "#" * int(round(frac * 30))
        lines.append(f"  {cat:<{width}}  {mean * 1e3:9.2f} ms "
                     f"{frac * 100:5.1f}%  {bar}")
    lines.append(f"  residual (max)  {tsum['max_residual_s']:.2e} s")
    util = tsum["utilization"]
    if util:
        lines.append("  -- utilization --")
        w = max(len(k) for k in util)
        for key, u in util.items():
            bar = "#" * int(round(u["utilization"] * 30))
            lines.append(f"  {key:<{w}}  busy {u['busy_s']:8.3f} s  "
                         f"util {u['utilization'] * 100:5.1f}%  {bar}")
    head = tsum["headroom"]
    total = head.get("total", {})
    if total.get("idle_while_peer_busy_s", 0.0) > 0:
        lines.append(
            f"  overlap headroom: "
            f"{total['idle_while_peer_busy_s']:.3f} s idle-while-peer-busy"
            f" ({total['fraction'] * 100:.1f}% of node-time) — "
            "reclaimable by pipelined execution")
    return "\n".join(lines)


def timeline(store: RunStore, run: str, name: str, *,
             kind: str | None = None, window: float = 1.0,
             reduce: str = "mean", width: int = 40,
             unit: str = "") -> list[str]:
    """One metric's :meth:`RunStore.aggregate` windows as ASCII bars —
    one line per non-empty window, bar length proportional to the
    window's value over the run maximum.  Empty list when the run never
    logged the metric."""
    buckets = store.aggregate(run, name, kind=kind, window=window,
                              reduce=reduce)
    if not buckets:
        return []
    peak = max(v for _, v in buckets) or 1.0
    lines = [f"  -- {name} per {window:g} s ({reduce}{', ' + unit if unit else ''}) --"]
    for t0, v in buckets:
        bar = "#" * max(1, int(round(v / peak * width)))
        lines.append(f"  [{t0:8.2f} s] {v:12.6g} {bar}")
    return lines


def render_timelines(store: RunStore, run: str,
                     window: float = 1.0) -> str:
    """Latency and energy over the run's logical time: mean request
    latency per window (``sim.request``, or ``load.request`` for
    queueing runs) and joules per window (``sim.energy`` gauges).
    Whatever the run did not log is skipped."""
    lines: list[str] = []
    for name in ("sim.request", "load.request"):
        lines += timeline(store, run, name, kind="span", window=window,
                          reduce="mean", unit="s latency")
    lines += timeline(store, run, "sim.energy", kind="gauge",
                      window=window, reduce="sum", unit="J")
    return "\n".join(lines)


def generate(store: RunStore, run: str | None = None, *,
             window: float = 1.0) -> str:
    """Render the report for ``run`` (default: the latest).  Raises
    ``ValueError`` when the store has no runs, the run logged no events,
    or the run has a manifest but zero span events — the exit-code
    contract the CI smoke gates on."""
    if run is None:
        run = store.latest()
        if run is None:
            raise ValueError(f"no runs under {store.root}")
    if not store.events(run):
        raise ValueError(f"run {run!r} recorded no events")
    if not store.events(run, kind="span"):
        raise ValueError(
            f"run {run!r} has a manifest but zero span events — nothing "
            "to report on; was a disabled recorder wired where an "
            "enabled one was meant?")
    parts = [render(run_summary(store, run))]
    tsec = render_trace(trace_summary(store, run))
    if tsec:
        parts.append(tsec)
    tl = render_timelines(store, run, window)
    if tl:
        parts.append(tl)
    return "\n".join(parts)


def main(argv: list[str] | None = None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    if not argv or argv[0] in ("-h", "--help"):
        print(__doc__)
        return 0 if argv else 1
    window = 1.0
    pos: list[str] = []
    i = 0
    while i < len(argv):
        if argv[i] == "--window":
            if i + 1 >= len(argv):
                print("--window needs a value (seconds)", file=sys.stderr)
                return 1
            window = float(argv[i + 1])
            i += 2
        else:
            pos.append(argv[i])
            i += 1
    store = RunStore(pos[0])
    run = pos[1] if len(pos) > 1 else None
    try:
        print(generate(store, run, window=window))
    except ValueError as e:
        print(f"telemetry report failed: {e}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
