"""Run-diffing perf-regression harness — snapshots, baselines, gates.

The missing half of a benchmark suite is *memory*: a number printed to a
terminal regresses silently.  This module distills a benchmark run (the
``benchmarks/common.emit`` surface) into a flat JSON **snapshot** and
diffs two snapshots with per-metric relative tolerances, exiting nonzero
on regression — the check CI runs against the committed baseline
(``BENCH_<n>.json`` at the repo root) so every later perf PR measures
itself against the trajectory.

Snapshot schema (version 1)::

    {"schema": 1, "suites": ["tab1", "fig8"],
     "metrics": {"fig8/4nodes/hidp": {"value": 523187.2, "unit":
                 "sim_us", "direction": "lower"}, ...}}

Units decide what is *gated* vs *informational*:

``us``
    Wall-clock microseconds — machine-dependent, so diffs report them
    but never fail on them (``--gate-wall`` opts in, e.g. for an A/A
    comparison on one box).
``sim_us`` / ``ratio`` / ``count`` / anything else
    Deterministic domain quantities (simulated latency, throughput
    ratios, event counts) — gated at the default relative tolerance
    (25 %) or a per-metric override.

``direction`` says which way is bad: ``lower`` (latency — regression =
value grew), ``higher`` (throughput ratio — regression = value fell).
A metric present in the baseline but missing from the current run is a
regression too (coverage loss), a brand-new metric is informational.

CLI (what CI runs)::

    python -m repro.telemetry.regress BASELINE.json CURRENT.json \
        [--tolerance 0.25] [--gate-wall]

exit 0 = no gated metric regressed; exit 1 = regression (the diff table
names every offender); exit 2 = unusable snapshot files.
"""

from __future__ import annotations

import dataclasses
import json
import pathlib
import sys
from typing import Mapping, Sequence

SCHEMA = 1

#: default relative tolerance for gated metrics
DEFAULT_TOLERANCE = 0.25

#: units that are machine-dependent wall time — reported, not gated
WALL_UNITS = ("us",)

#: diff entry statuses (fixed vocabulary, rendered in this order)
STATUSES = ("regressed", "missing", "improved", "ok", "info", "new")


def snapshot(metrics: Mapping[str, Mapping],
             suites: Sequence[str] = ()) -> dict:
    """A snapshot dict from ``{name: {value, unit, direction}}`` rows
    (``benchmarks/common.METRICS`` after a run)."""
    out = {}
    for name in sorted(metrics):
        m = metrics[name]
        out[name] = {"value": float(m["value"]),
                     "unit": str(m.get("unit", "us")),
                     "direction": str(m.get("direction", "lower"))}
    return {"schema": SCHEMA, "suites": list(suites), "metrics": out}


def write_snapshot(path: str | pathlib.Path,
                   metrics: Mapping[str, Mapping],
                   suites: Sequence[str] = ()) -> pathlib.Path:
    """Serialize :func:`snapshot` to ``path`` (parents created)."""
    path = pathlib.Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(snapshot(metrics, suites), indent=2,
                               sort_keys=True) + "\n")
    return path


def load_snapshot(path: str | pathlib.Path) -> dict:
    """Read and validate a snapshot file."""
    d = json.loads(pathlib.Path(path).read_text())
    if d.get("schema") != SCHEMA:
        raise ValueError(f"{path}: unsupported snapshot schema "
                         f"{d.get('schema')!r} (expected {SCHEMA})")
    if not isinstance(d.get("metrics"), dict):
        raise ValueError(f"{path}: snapshot has no metrics mapping")
    return d


@dataclasses.dataclass(frozen=True)
class DiffEntry:
    """One metric's verdict.  ``rel`` is the signed relative change in
    the *bad* direction (positive = worse), NaN when undefined."""

    name: str
    status: str          # one of STATUSES
    unit: str
    baseline: float | None
    current: float | None
    rel: float
    tolerance: float


@dataclasses.dataclass
class DiffResult:
    entries: list[DiffEntry]

    @property
    def regressions(self) -> list[DiffEntry]:
        return [e for e in self.entries
                if e.status in ("regressed", "missing")]

    @property
    def ok(self) -> bool:
        return not self.regressions


def _rel_worse(base: float, cur: float, direction: str) -> float:
    """Signed relative change in the bad direction: positive = worse.
    ``lower`` is better → growing is bad; ``higher`` → shrinking is."""
    if base == 0:
        return 0.0 if cur == base else float("inf")
    rel = (cur - base) / abs(base)
    return rel if direction == "lower" else -rel


def diff(baseline: Mapping, current: Mapping, *,
         tolerance: float = DEFAULT_TOLERANCE,
         tolerances: Mapping[str, float] | None = None,
         gate_wall: bool = False) -> DiffResult:
    """Compare two snapshots.  ``tolerances`` overrides the relative
    tolerance per metric name; wall-unit metrics are informational
    unless ``gate_wall``."""
    tolerances = tolerances or {}
    base_m, cur_m = baseline["metrics"], current["metrics"]
    entries: list[DiffEntry] = []
    for name in sorted(set(base_m) | set(cur_m)):
        b, c = base_m.get(name), cur_m.get(name)
        if b is None:
            entries.append(DiffEntry(name, "new", c["unit"], None,
                                     c["value"], float("nan"), 0.0))
            continue
        tol = float(tolerances.get(name, tolerance))
        gated = gate_wall or b.get("unit", "us") not in WALL_UNITS
        if c is None:
            entries.append(DiffEntry(
                name, "missing" if gated else "info", b.get("unit", "us"),
                b["value"], None, float("nan"), tol))
            continue
        rel = _rel_worse(b["value"], c["value"],
                         b.get("direction", "lower"))
        if not gated:
            status = "info"
        elif rel > tol:
            status = "regressed"
        elif rel < -tol:
            status = "improved"
        else:
            status = "ok"
        entries.append(DiffEntry(name, status, b.get("unit", "us"),
                                 b["value"], c["value"], rel, tol))
    order = {s: i for i, s in enumerate(STATUSES)}
    entries.sort(key=lambda e: (order[e.status], e.name))
    return DiffResult(entries)


def render_diff(result: DiffResult) -> str:
    """The diff as a fixed-order table plus a one-line verdict."""
    lines = []
    width = max((len(e.name) for e in result.entries), default=4)
    for e in result.entries:
        b = "-" if e.baseline is None else f"{e.baseline:.6g}"
        c = "-" if e.current is None else f"{e.current:.6g}"
        rel = "" if e.rel != e.rel else f"{e.rel * 100:+7.1f}%"
        lines.append(f"  {e.status:<9} {e.name:<{width}} "
                     f"{b:>12} -> {c:>12} {e.unit:<7} {rel}")
    n_reg = len(result.regressions)
    if n_reg:
        lines.append(f"REGRESSION: {n_reg} metric(s) worse than "
                     "tolerance (or missing) — see rows above")
    else:
        gated = sum(1 for e in result.entries
                    if e.status in ("ok", "improved"))
        lines.append(f"clean: {gated} gated metric(s) within tolerance, "
                     f"{len(result.entries) - gated} informational")
    return "\n".join(lines)


def main(argv: list[str] | None = None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    if not argv or argv[0] in ("-h", "--help"):
        print(__doc__)
        return 0 if argv else 2
    tolerance = DEFAULT_TOLERANCE
    gate_wall = False
    pos: list[str] = []
    i = 0
    while i < len(argv):
        if argv[i] == "--tolerance":
            if i + 1 >= len(argv):
                print("--tolerance needs a value", file=sys.stderr)
                return 2
            tolerance = float(argv[i + 1])
            i += 2
        elif argv[i] == "--gate-wall":
            gate_wall = True
            i += 1
        else:
            pos.append(argv[i])
            i += 1
    if len(pos) != 2:
        print("usage: python -m repro.telemetry.regress BASELINE CURRENT "
              "[--tolerance REL] [--gate-wall]", file=sys.stderr)
        return 2
    try:
        baseline = load_snapshot(pos[0])
        current = load_snapshot(pos[1])
    except (OSError, ValueError, json.JSONDecodeError) as e:
        print(f"regress: {e}", file=sys.stderr)
        return 2
    result = diff(baseline, current, tolerance=tolerance,
                  gate_wall=gate_wall)
    print(f"== regress: {pos[1]} vs baseline {pos[0]} "
          f"(tolerance {tolerance * 100:g}%) ==")
    print(render_diff(result))
    return 0 if result.ok else 1


if __name__ == "__main__":
    sys.exit(main())
