"""RunStore — a durable, queryable record of every instrumented run.

Layout mirrors ``CalibrationStore`` (one directory per run, atomic
manifest writes), and a store rooted next to calibration artifacts keeps
telemetry and calibrations in one place::

    <root>/<run>/events.jsonl     append-only event log
    <root>/<run>/manifest.json    atomic (tmp + os.replace) run metadata

The JSONL log is append-only so a crash loses at most the unflushed
buffer; the manifest is written whole-file-atomically so a reader never
sees a torn run description.  Queries (:meth:`events`) filter by kind,
name (exact, or prefix with a trailing ``*``), tenant, epoch, and time
range; :meth:`aggregate` buckets matching events into fixed windows of
logical time — the primitive reports build p50/p99 tables and
retries-per-epoch breakdowns from.

Round-trip contract: a fresh :class:`RunStore` pointed at the same root
(a process restart) returns byte-identical query results — events are
re-hydrated from JSONL, and :meth:`canonical_lines` (wall fields
stripped) is the determinism surface the tests compare.
"""

from __future__ import annotations

import json
import os
import pathlib
import statistics
import time
from typing import Callable, Iterable, Sequence

from .events import TelemetryEvent

_REDUCERS: dict[str, Callable[[Sequence[float]], float]] = {
    "sum": sum,
    "count": len,
    "mean": lambda xs: statistics.fmean(xs),
    "max": max,
    "min": min,
}


class RunStore:
    """Filesystem-backed event store: one subdirectory per run."""

    def __init__(self, root: str | pathlib.Path):
        self.root = pathlib.Path(root)
        self.root.mkdir(parents=True, exist_ok=True)

    # --------------------------------------------------------------- paths
    def run_dir(self, run: str) -> pathlib.Path:
        return self.root / run

    def events_path(self, run: str) -> pathlib.Path:
        return self.run_dir(run) / "events.jsonl"

    def manifest_path(self, run: str) -> pathlib.Path:
        return self.run_dir(run) / "manifest.json"

    # ---------------------------------------------------------------- runs
    def new_run(self, prefix: str = "run") -> str:
        """A fresh run id ``<prefix>-NNNN``, numbered after the highest
        existing one so re-runs never clobber earlier logs.  Counts every
        reserved run directory, including ones that have not recorded an
        event yet — two recorders created back-to-back must not collide."""
        n = 0
        if self.root.is_dir():
            for p in self.root.iterdir():
                if not p.is_dir():
                    continue
                head, _, tail = p.name.rpartition("-")
                if head == prefix and tail.isdigit():
                    n = max(n, int(tail))
        run = f"{prefix}-{n + 1:04d}"
        self.run_dir(run).mkdir(parents=True, exist_ok=True)
        return run

    def runs(self) -> list[str]:
        """Every run id under the root, sorted."""
        if not self.root.is_dir():
            return []
        return sorted(p.name for p in self.root.iterdir()
                      if p.is_dir() and (
                          (p / "events.jsonl").is_file()
                          or (p / "manifest.json").is_file()))

    def latest(self) -> str | None:
        """The most recently created run (manifest ``created_unix``,
        falling back to name order)."""
        runs = self.runs()
        if not runs:
            return None
        return max(runs, key=lambda r: (
            self.manifest(r).get("created_unix", 0.0), r))

    # --------------------------------------------------------------- write
    def append(self, run: str, events: Iterable[TelemetryEvent]) -> int:
        """Append events to the run's JSONL log.  Returns count."""
        d = self.run_dir(run)
        d.mkdir(parents=True, exist_ok=True)
        lines = [e.to_json() for e in events]
        if lines:
            with open(self.events_path(run), "a") as f:
                f.write("\n".join(lines) + "\n")
        return len(lines)

    def write_manifest(self, run: str, meta: dict) -> None:
        """Atomically (re)write the run's manifest; ``created_unix`` is
        preserved from an earlier manifest when present."""
        d = self.run_dir(run)
        d.mkdir(parents=True, exist_ok=True)
        old = self.manifest(run)
        payload = {"run": run,
                   "created_unix": old.get("created_unix", time.time()),
                   **meta}
        path = self.manifest_path(run)
        tmp = path.with_suffix(f".json.{os.getpid()}.tmp")
        tmp.write_text(json.dumps(payload, sort_keys=True, indent=1))
        os.replace(tmp, path)

    def manifest(self, run: str) -> dict:
        path = self.manifest_path(run)
        if not path.is_file():
            return {}
        return json.loads(path.read_text())

    # --------------------------------------------------------------- query
    def events(self, run: str, *, kind: str | None = None,
               name: str | None = None, tenant: str | None = None,
               epoch: int | None = None,
               t_range: tuple[float, float] | None = None
               ) -> list[TelemetryEvent]:
        """The run's events in ``seq`` order, filtered.

        ``name`` matches exactly, or as a prefix when it ends with ``*``
        (``"plan_cache.*"``).  ``t_range=(lo, hi)`` keeps events with
        ``lo <= t < hi``.  Filters compose conjunctively.
        """
        path = self.events_path(run)
        if not path.is_file():
            return []
        prefix = name[:-1] if name is not None and name.endswith("*") \
            else None
        out = []
        with open(path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                e = TelemetryEvent.from_json(line)
                if kind is not None and e.kind != kind:
                    continue
                if prefix is not None:
                    if not e.name.startswith(prefix):
                        continue
                elif name is not None and e.name != name:
                    continue
                if tenant is not None and e.tenant != tenant:
                    continue
                if epoch is not None and e.epoch != epoch:
                    continue
                if t_range is not None \
                        and not (t_range[0] <= e.t < t_range[1]):
                    continue
                out.append(e)
        out.sort(key=lambda e: e.seq)
        return out

    def counter_total(self, run: str, name: str, *,
                      tenant: str | None = None) -> float:
        """Sum of a counter's increments across the run."""
        return sum(e.value for e in self.events(run, kind="counter",
                                                name=name, tenant=tenant))

    def by_tenant(self, run: str, name: str,
                  kind: str = "counter") -> dict[str, float]:
        """``{tenant: total value}`` for one event name — e.g. per-tenant
        cache hit counts from ``plan_cache.hit``."""
        out: dict[str, float] = {}
        for e in self.events(run, kind=kind, name=name):
            out[e.tenant] = out.get(e.tenant, 0.0) + e.value
        return out

    def by_epoch(self, run: str, name: str,
                 kind: str = "counter") -> dict[int, float]:
        """``{epoch: total value}`` — e.g. retries per membership epoch
        (events with no epoch land under -1)."""
        out: dict[int, float] = {}
        for e in self.events(run, kind=kind, name=name):
            ep = -1 if e.epoch is None else e.epoch
            out[ep] = out.get(ep, 0.0) + e.value
        return out

    def aggregate(self, run: str, name: str, *, kind: str | None = None,
                  window: float = 1.0, reduce: str = "sum",
                  tenant: str | None = None
                  ) -> list[tuple[float, float]]:
        """Windowed aggregation over logical time: events matching
        ``name`` (prefix-``*`` allowed) bucketed into ``[k·window,
        (k+1)·window)`` and reduced by ``sum`` | ``count`` | ``mean`` |
        ``max`` | ``min``.  Returns ``[(window_start, value)]`` for
        non-empty windows, ascending."""
        if window <= 0:
            raise ValueError("window must be positive")
        try:
            fn = _REDUCERS[reduce]
        except KeyError:
            raise ValueError(f"unknown reducer {reduce!r}; expected one "
                             f"of {sorted(_REDUCERS)}") from None
        buckets: dict[int, list[float]] = {}
        for e in self.events(run, kind=kind, name=name, tenant=tenant):
            buckets.setdefault(int(e.t // window), []).append(e.value)
        return [(k * window, float(fn(buckets[k])))
                for k in sorted(buckets)]

    # --------------------------------------------------------- determinism
    def canonical_lines(self, run: str) -> list[str]:
        """The run's event log with wall-clock fields stripped — the byte
        surface two seeded replays must agree on (see
        :mod:`repro.telemetry.events`)."""
        return [e.canonical() for e in self.events(run)]
