"""TelemetryRecorder — the one emission point every subsystem shares.

A recorder is cheap enough to thread through hot paths: emission is a
dataclass construction and a list append; a *disabled* recorder
(``enabled=False``) is indistinguishable from no recorder at all, because
instrumented classes normalize it to ``None`` via :func:`active` at
construction time — the hot path then pays exactly one ``is not None``
check, which is why the fig7 overhead gate holds the disabled path to a
≤2 % regression.

Ordering is deterministic: every event gets the recorder's next ``seq``,
and logical time comes from the recorder's :attr:`clock`, which the
simulator advances as simulated time passes (subsystems with no time of
their own — the plan cache, the feedback loop — stamp events with the
clock as-is).  Wall-clock facts are confined to the schema's designated
``wall``/``wall_s`` fields, so seeded replays stay byte-identical modulo
those fields (see :mod:`repro.telemetry.events`).

Lifecycle::

    store = RunStore("artifacts/telemetry")
    tel = TelemetryRecorder(store.new_run("churn"), store=store)
    ... thread tel through EdgeSimulator / PlanCache / FleetController ...
    tel.close(cluster_fingerprint=...)     # flush events + write manifest

``flush_every=N`` bounds the in-memory buffer for long runs; ``close``
always flushes the tail and stamps the manifest with per-kind counts.
"""

from __future__ import annotations

import contextlib
import time
from typing import Iterator

from .events import TelemetryEvent


def active(telemetry: "TelemetryRecorder | None"
           ) -> "TelemetryRecorder | None":
    """Normalize a ``telemetry=`` constructor argument: a disabled
    recorder becomes ``None`` so instrumented hot paths pay only a single
    ``is not None`` check per event site.  Consequence: ``enabled`` is a
    construction-time decision — flipping it after wiring has no effect
    on classes that already normalized."""
    if telemetry is None or not telemetry.enabled:
        return None
    return telemetry


class TelemetryRecorder:
    """Buffers typed events for one run.

    Attributes:
        run: the run id events are filed under in the :class:`RunStore`.
        enabled: construction-time switch; a disabled recorder emits
            nothing and is normalized away by :func:`active`.
        clock: the logical clock (simulated seconds); events emitted
            without an explicit ``t`` are stamped with it.
        events: the in-memory buffer (flushed events are dropped from it
            only on ``flush`` when a store is wired).
    """

    def __init__(self, run: str = "run", *, enabled: bool = True,
                 store=None, flush_every: int | None = None):
        self.run = run
        self.enabled = enabled
        self.clock = 0.0
        self.events: list[TelemetryEvent] = []
        self._store = store
        if flush_every is not None and flush_every < 1:
            raise ValueError("flush_every must be >= 1")
        if flush_every is not None and store is None:
            raise ValueError("flush_every needs a store to flush to")
        self._flush_every = flush_every
        self._seq = 0
        self._counts = {"span": 0, "counter": 0, "gauge": 0}
        self._flushed = 0
        self._closed = False

    # ------------------------------------------------------------- clock
    def advance(self, t: float) -> None:
        """Move the logical clock forward (never backward) — the
        simulator calls this as simulated time passes so clock-stamped
        events from time-blind subsystems land at the right instant."""
        if t > self.clock:
            self.clock = t

    # ---------------------------------------------------------- emission
    def _emit(self, kind: str, name: str, value: float, t: float | None,
              tenant: str, epoch: int | None, wall_s: float | None,
              attrs: dict) -> None:
        if not self.enabled:
            return
        ev = TelemetryEvent(
            seq=self._seq, kind=kind, name=name, value=float(value),
            t=self.clock if t is None else float(t), tenant=tenant,
            epoch=epoch, attrs=attrs, wall=time.time(), wall_s=wall_s)
        self._seq += 1
        self._counts[kind] += 1
        self.events.append(ev)
        if (self._flush_every is not None
                and len(self.events) >= self._flush_every):
            self.flush()

    def counter(self, name: str, value: float = 1.0, *,
                t: float | None = None, tenant: str = "",
                epoch: int | None = None, **attrs) -> None:
        """Something happened ``value`` times (default 1)."""
        self._emit("counter", name, value, t, tenant, epoch, None, attrs)

    def gauge(self, name: str, value: float, *, t: float | None = None,
              tenant: str = "", epoch: int | None = None, **attrs) -> None:
        """A level sampled at an instant."""
        self._emit("gauge", name, value, t, tenant, epoch, None, attrs)

    def span(self, name: str, duration: float, *,
             t: float | None = None, tenant: str = "",
             epoch: int | None = None, wall_s: float | None = None,
             **attrs) -> None:
        """An extent: ``duration`` in deterministic domain time (pass 0.0
        and ``wall_s=`` for extents only wall clocks can measure)."""
        self._emit("span", name, duration, t, tenant, epoch, wall_s, attrs)

    @contextlib.contextmanager
    def timed(self, name: str, *, tenant: str = "",
              epoch: int | None = None, **attrs) -> Iterator[None]:
        """Wall-clock a block as a span: the measured seconds land in the
        nondeterministic ``wall_s`` field, ``value`` stays 0 — use for DP
        frontier passes, kernel profiles, benchmark suites."""
        t0 = time.perf_counter()
        try:
            yield
        finally:
            self.span(name, 0.0, tenant=tenant, epoch=epoch,
                      wall_s=time.perf_counter() - t0, **attrs)

    # -------------------------------------------------------- persistence
    def flush(self, store=None) -> int:
        """Append buffered-but-unflushed events to the store's JSONL log.
        Returns the number written (0 for a disabled/empty recorder)."""
        store = self._store if store is None else store
        pending = self.events[self._flushed:]
        if store is None or not pending:
            return 0
        n = store.append(self.run, pending)
        self._flushed += n
        return n

    def close(self, store=None, **manifest_extra) -> int:
        """Flush the tail and write the run manifest (per-kind counts,
        total events, plus any caller metadata).  Idempotent."""
        store = self._store if store is None else store
        n = self.flush(store)
        if store is not None and self.enabled and not self._closed:
            store.write_manifest(self.run, {
                "events": self._seq, "counts": dict(self._counts),
                "clock_end": self.clock, **manifest_extra})
            self._closed = True
        return n

    def __repr__(self) -> str:
        state = "enabled" if self.enabled else "disabled"
        return (f"TelemetryRecorder(run={self.run!r}, {state}, "
                f"{self._seq} events, clock={self.clock:.3f})")
