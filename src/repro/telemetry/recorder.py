"""TelemetryRecorder — the one emission point every subsystem shares.

A recorder is cheap enough to thread through hot paths: emission is a
dataclass construction and a list append; a *disabled* recorder
(``enabled=False``) is indistinguishable from no recorder at all, because
instrumented classes normalize it to ``None`` via :func:`active` at
construction time — the hot path then pays exactly one ``is not None``
check, which is why the fig7 overhead gate holds the disabled path to a
≤2 % regression.

Ordering is deterministic: every event gets the recorder's next ``seq``,
and logical time comes from the recorder's :attr:`clock`, which the
simulator advances as simulated time passes (subsystems with no time of
their own — the plan cache, the feedback loop — stamp events with the
clock as-is).  Wall-clock facts are confined to the schema's designated
``wall``/``wall_s`` fields, so seeded replays stay byte-identical modulo
those fields (see :mod:`repro.telemetry.events`).

Causal context rides the same determinism: :meth:`trace` opens a span
context (ids from a deterministic allocation counter, **not** the event
``seq`` — a parent span is emitted *after* its children, so its eventual
seq is unknowable at child-emission time), and every event emitted while
a context is open is auto-parented under it.  Subsystems that cannot
nest their control flow (the open-loop harness's event loop) allocate
ids explicitly with :meth:`allocate_span` and pass ``span_id=`` /
``parent_id=`` themselves.  :mod:`repro.telemetry.trace` rebuilds the
trees.

Lifecycle::

    store = RunStore("artifacts/telemetry")
    tel = TelemetryRecorder(store.new_run("churn"), store=store)
    ... thread tel through EdgeSimulator / PlanCache / FleetController ...
    tel.close(cluster_fingerprint=...)     # flush events + write manifest

``flush_every=N`` bounds the in-memory buffer for long runs; ``close``
always flushes the tail and stamps the manifest with per-kind counts.
"""

from __future__ import annotations

import contextlib
import time
from typing import Iterator

from .events import TelemetryEvent

#: sentinel for "parent under the innermost open trace() context"
_AUTO = object()


class SpanHandle:
    """The mutable face of an open :meth:`TelemetryRecorder.trace`
    context: callers fill in what is only known at exit (duration, final
    epoch, outcome attrs) via :meth:`set` before the context closes and
    the span event is emitted."""

    __slots__ = ("span_id", "name", "duration", "t", "tenant", "epoch",
                 "wall_s", "attrs")

    def __init__(self, span_id: int | None, name: str, t: float | None,
                 tenant: str, epoch: int | None, attrs: dict):
        self.span_id = span_id
        self.name = name
        self.duration = 0.0
        self.t = t
        self.tenant = tenant
        self.epoch = epoch
        self.wall_s: float | None = None
        self.attrs = attrs

    def set(self, duration: float | None = None, *,
            t: float | None = None, tenant: str | None = None,
            epoch: int | None = None, **attrs) -> "SpanHandle":
        """Update the span's fields before the context closes; extra
        keywords merge into its attrs.  Returns self for chaining."""
        if duration is not None:
            self.duration = float(duration)
        if t is not None:
            self.t = t
        if tenant is not None:
            self.tenant = tenant
        if epoch is not None:
            self.epoch = epoch
        self.attrs.update(attrs)
        return self


def active(telemetry: "TelemetryRecorder | None"
           ) -> "TelemetryRecorder | None":
    """Normalize a ``telemetry=`` constructor argument: a disabled
    recorder becomes ``None`` so instrumented hot paths pay only a single
    ``is not None`` check per event site.  Consequence: ``enabled`` is a
    construction-time decision — flipping it after wiring has no effect
    on classes that already normalized."""
    if telemetry is None or not telemetry.enabled:
        return None
    return telemetry


class TelemetryRecorder:
    """Buffers typed events for one run.

    Attributes:
        run: the run id events are filed under in the :class:`RunStore`.
        enabled: construction-time switch; a disabled recorder emits
            nothing and is normalized away by :func:`active`.
        clock: the logical clock (simulated seconds); events emitted
            without an explicit ``t`` are stamped with it.
        events: the in-memory buffer (flushed events are dropped from it
            only on ``flush`` when a store is wired).
    """

    def __init__(self, run: str = "run", *, enabled: bool = True,
                 store=None, flush_every: int | None = None):
        self.run = run
        self.enabled = enabled
        self.clock = 0.0
        self.events: list[TelemetryEvent] = []
        self._store = store
        if flush_every is not None and flush_every < 1:
            raise ValueError("flush_every must be >= 1")
        if flush_every is not None and store is None:
            raise ValueError("flush_every needs a store to flush to")
        self._flush_every = flush_every
        self._seq = 0
        self._counts = {"span": 0, "counter": 0, "gauge": 0}
        self._flushed = 0
        self._closed = False
        # trace-tree state: deterministic span-id allocation (program
        # order) and the stack of open trace() contexts
        self._next_span = 0
        self._stack: list[int] = []

    # ------------------------------------------------------------- clock
    def advance(self, t: float) -> None:
        """Move the logical clock forward (never backward) — the
        simulator calls this as simulated time passes so clock-stamped
        events from time-blind subsystems land at the right instant."""
        if t > self.clock:
            self.clock = t

    # ------------------------------------------------------- trace context
    def allocate_span(self) -> int:
        """Reserve the next deterministic span id without emitting
        anything — for callers whose control flow cannot nest (the
        open-loop harness allocates one per arrival at arrival time and
        emits the root span at the request's terminal event)."""
        sid = self._next_span
        self._next_span += 1
        return sid

    def current_span(self) -> int | None:
        """The innermost open :meth:`trace` context's span id (what an
        auto-parented event would attach to), or None."""
        return self._stack[-1] if self._stack else None

    @contextlib.contextmanager
    def trace(self, name: str, *, t: float | None = None,
              tenant: str = "", epoch: int | None = None,
              wall: bool = False, parent_id=_AUTO,
              **attrs) -> Iterator[SpanHandle]:
        """Open a span context: events emitted inside are auto-parented
        under it, and the span itself is emitted at exit (children first,
        parent last — trees are rebuilt from ids, not emission order).
        The yielded :class:`SpanHandle` takes exit-time facts
        (``handle.set(duration=..., ok=...)``); with ``wall=True`` the
        block is wall-clocked into ``wall_s`` like :meth:`timed`."""
        if not self.enabled:
            yield SpanHandle(None, name, t, tenant, epoch, dict(attrs))
            return
        h = SpanHandle(self.allocate_span(), name, t, tenant, epoch,
                       dict(attrs))
        if parent_id is _AUTO:
            parent_id = self.current_span()
        self._stack.append(h.span_id)
        t0 = time.perf_counter() if wall else None
        try:
            yield h
        finally:
            self._stack.pop()
            if t0 is not None and h.wall_s is None:
                h.wall_s = time.perf_counter() - t0
            self._emit("span", h.name, h.duration, h.t, h.tenant, h.epoch,
                       h.wall_s, h.attrs, span_id=h.span_id,
                       parent_id=parent_id)

    def child_span(self, name: str, duration: float, *,
                   t: float | None = None, tenant: str = "",
                   epoch: int | None = None, wall_s: float | None = None,
                   parent_id=_AUTO, **attrs) -> int | None:
        """Emit a leaf span with its own id, parented under the current
        context (or an explicit ``parent_id``).  Returns the allocated
        span id — the handle per-stage children (compute/comm/queue-wait
        shards) hang deeper structure from."""
        if not self.enabled:
            return None
        sid = self.allocate_span()
        self._emit("span", name, duration, t, tenant, epoch, wall_s,
                   attrs, span_id=sid, parent_id=parent_id)
        return sid

    # ---------------------------------------------------------- emission
    def _emit(self, kind: str, name: str, value: float, t: float | None,
              tenant: str, epoch: int | None, wall_s: float | None,
              attrs: dict, span_id: int | None = None,
              parent_id=_AUTO) -> None:
        if not self.enabled:
            return
        if parent_id is _AUTO:
            parent_id = self.current_span()
        ev = TelemetryEvent(
            seq=self._seq, kind=kind, name=name, value=float(value),
            t=self.clock if t is None else float(t), tenant=tenant,
            epoch=epoch, attrs=attrs, span_id=span_id,
            parent_id=parent_id, wall=time.time(), wall_s=wall_s)
        self._seq += 1
        self._counts[kind] += 1
        self.events.append(ev)
        if (self._flush_every is not None
                and len(self.events) >= self._flush_every):
            self.flush()

    def counter(self, name: str, value: float = 1.0, *,
                t: float | None = None, tenant: str = "",
                epoch: int | None = None, parent_id=_AUTO,
                **attrs) -> None:
        """Something happened ``value`` times (default 1)."""
        self._emit("counter", name, value, t, tenant, epoch, None, attrs,
                   parent_id=parent_id)

    def gauge(self, name: str, value: float, *, t: float | None = None,
              tenant: str = "", epoch: int | None = None,
              parent_id=_AUTO, **attrs) -> None:
        """A level sampled at an instant."""
        self._emit("gauge", name, value, t, tenant, epoch, None, attrs,
                   parent_id=parent_id)

    def span(self, name: str, duration: float, *,
             t: float | None = None, tenant: str = "",
             epoch: int | None = None, wall_s: float | None = None,
             span_id: int | None = None, parent_id=_AUTO,
             **attrs) -> None:
        """An extent: ``duration`` in deterministic domain time (pass 0.0
        and ``wall_s=`` for extents only wall clocks can measure).
        ``span_id`` attaches a pre-allocated identity (see
        :meth:`allocate_span`); without one the span is a leaf that
        children cannot reference."""
        self._emit("span", name, duration, t, tenant, epoch, wall_s,
                   attrs, span_id=span_id, parent_id=parent_id)

    @contextlib.contextmanager
    def timed(self, name: str, *, tenant: str = "",
              epoch: int | None = None, **attrs) -> Iterator[None]:
        """Wall-clock a block as a span: the measured seconds land in the
        nondeterministic ``wall_s`` field, ``value`` stays 0 — use for DP
        frontier passes, kernel profiles, benchmark suites.  The block is
        a full :meth:`trace` context, so events inside parent under it."""
        with self.trace(name, tenant=tenant, epoch=epoch, wall=True,
                        **attrs):
            yield

    # -------------------------------------------------------- persistence
    def flush(self, store=None) -> int:
        """Append buffered-but-unflushed events to the store's JSONL log.
        Returns the number written (0 for a disabled/empty recorder)."""
        store = self._store if store is None else store
        pending = self.events[self._flushed:]
        if store is None or not pending:
            return 0
        n = store.append(self.run, pending)
        self._flushed += n
        return n

    def close(self, store=None, **manifest_extra) -> int:
        """Flush the tail and write the run manifest (per-kind counts,
        total events, plus any caller metadata).  Idempotent."""
        store = self._store if store is None else store
        n = self.flush(store)
        if store is not None and self.enabled and not self._closed:
            store.write_manifest(self.run, {
                "events": self._seq, "counts": dict(self._counts),
                "clock_end": self.clock, **manifest_extra})
            self._closed = True
        return n

    def __repr__(self) -> str:
        state = "enabled" if self.enabled else "disabled"
        return (f"TelemetryRecorder(run={self.run!r}, {state}, "
                f"{self._seq} events, clock={self.clock:.3f})")
