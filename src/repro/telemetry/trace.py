"""Causal trace trees over a run's event log — where did the time go?

The recorder stamps every event with a deterministic ``span_id`` /
``parent_id`` (see :mod:`repro.telemetry.recorder`); this module turns
the flat log back into the causal structure the ids encode and answers
the questions flat spans cannot:

* :func:`span_trees` — the forest: ``sim.request`` roots over their
  ``sim.attempt`` children over per-stage ``sim.plan`` / ``sim.compute``
  / ``sim.comm`` / ``sim.queue_wait`` shards (and ``load.request`` roots
  over queue-wait/service in load runs); non-span events (retry
  counters, membership gauges) attach to the span they happened inside.
* :func:`tree_lines` — the forest rendered as canonical indented lines:
  the byte surface two seeded replays must agree on (the fig8 trace gate
  compares exactly this).
* :func:`critical_path` — one request's latency decomposed into
  **plan / queue / compute / comm / retry_waste / other** with the
  categories summing to the recorded latency (failed attempts are
  retry-waste wholesale; the final attempt is walked backward along its
  dependency chain, with uncovered gaps — e.g. a strategy's modeled
  ``extra_latency`` — landing in ``other``).
* :func:`node_utilization` / :func:`overlap_headroom` — per-node
  busy/idle timelines and idle-while-a-peer-computes seconds: the
  number a pipelined executor would reclaim, which the ROADMAP's
  pipelining item optimizes.

Everything here is pure post-processing: it reads a
:class:`~repro.telemetry.RunStore` (or a raw event list) and never
touches the hot path.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

from .events import TelemetryEvent

#: critical-path categories, fixed order (reports render in this order)
CATEGORIES = ("plan", "queue", "compute", "comm", "retry_waste", "other")

#: span name → critical-path category for per-stage children
_STAGE_CATEGORY = {
    "sim.plan": "plan",
    "sim.queue_wait": "queue",
    "load.queue_wait": "queue",
    "sim.compute": "compute",
    "load.service": "compute",
    "sim.comm": "comm",
}

#: span names that root a request's trace tree
REQUEST_ROOTS = ("sim.request", "load.request")


@dataclasses.dataclass
class SpanNode:
    """One span in the reconstructed tree.

    Attributes:
        event: the span's :class:`TelemetryEvent`.
        children: child *spans*, in emission (seq) order.
        events: non-span events (counters, gauges) parented here.
    """

    event: TelemetryEvent
    children: list["SpanNode"] = dataclasses.field(default_factory=list)
    events: list[TelemetryEvent] = dataclasses.field(default_factory=list)

    @property
    def name(self) -> str:
        return self.event.name

    @property
    def start(self) -> float:
        return self.event.t

    @property
    def end(self) -> float:
        return self.event.t + self.event.value

    def walk(self) -> Iterable["SpanNode"]:
        yield self
        for c in self.children:
            yield from c.walk()

    def __repr__(self) -> str:
        return (f"SpanNode({self.name!r}, id={self.event.span_id}, "
                f"{len(self.children)} children)")


def span_trees(events: Sequence[TelemetryEvent]) -> list[SpanNode]:
    """Rebuild the span forest from a flat event list.  Roots are spans
    with no parent (or whose parent id no span in the log claims — an
    orphan is surfaced, not dropped); non-span events attach to their
    parent's ``events`` list and are ignored when the parent is unknown.

    Children keep emission (seq) order, which is deterministic under the
    recorder's contract — so the forest, rendered via
    :func:`tree_lines`, is byte-identical across seeded replays."""
    nodes: dict[int, SpanNode] = {}
    spans: list[SpanNode] = []
    for e in events:
        if e.kind == "span":
            n = SpanNode(e)
            spans.append(n)
            if e.span_id is not None:
                nodes[e.span_id] = n
    roots: list[SpanNode] = []
    for n in spans:
        pid = n.event.parent_id
        parent = nodes.get(pid) if pid is not None else None
        if parent is None or parent is n:
            roots.append(n)
        else:
            parent.children.append(n)
    for e in events:
        if e.kind != "span" and e.parent_id is not None:
            parent = nodes.get(e.parent_id)
            if parent is not None:
                parent.events.append(e)
    return roots


def forest(store, run: str) -> list[SpanNode]:
    """:func:`span_trees` over everything a run logged."""
    return span_trees(store.events(run))


def tree_lines(roots: Sequence[SpanNode]) -> list[str]:
    """The forest as canonical indented lines — each node's
    wall-stripped JSON under its depth, children in order.  This is the
    replay-determinism surface for trace *shape*: two seeded replays
    must produce byte-identical lines (gated in fig8's trace gate and
    ``tests/test_trace.py``)."""
    out: list[str] = []

    def walk(node: SpanNode, depth: int) -> None:
        out.append("  " * depth + node.event.canonical())
        for e in node.events:
            out.append("  " * (depth + 1) + "· " + e.canonical())
        for c in node.children:
            walk(c, depth + 1)

    for r in roots:
        walk(r, 0)
    return out


# --------------------------------------------------------- critical path
@dataclasses.dataclass
class CriticalPath:
    """One request's latency, decomposed.  ``categories`` spans the full
    :data:`CATEGORIES` set and sums to ``latency`` (within float
    round-off — the trace gate holds the residual to ~1e-9)."""

    request: int | None
    tenant: str
    latency: float
    categories: dict[str, float]

    @property
    def total(self) -> float:
        return sum(self.categories.values())

    @property
    def residual(self) -> float:
        """latency − Σ categories: float dust, gated near zero."""
        return self.latency - self.total

    def fraction(self, category: str) -> float:
        return (self.categories[category] / self.latency
                if self.latency > 0 else 0.0)


def _walk_attempt(attempt: SpanNode, cats: dict[str, float],
                  eps: float = 1e-9) -> None:
    """Decompose one (successful) attempt: planning overhead first, then
    a backward walk from the attempt's completion along whichever stage
    span ends at the cursor — the dependency chain that actually gated
    completion.  Parallel shards off the chain are skipped (they
    overlapped the chain; their time is not additive latency), and
    uncovered gaps (modeled ``extra_latency``, inter-stage slack) land
    in ``other`` so the sum stays exact."""
    start, end = attempt.start, attempt.end
    stages: list[SpanNode] = []
    plan_total = 0.0
    for c in attempt.children:
        if c.name == "sim.plan":
            plan_total += c.event.value
            cats["plan"] += c.event.value
        elif c.name in _STAGE_CATEGORY:
            stages.append(c)
    exec_start = start + plan_total
    remaining = sorted(stages, key=lambda s: (s.end, s.event.value))
    cursor = end
    while cursor > exec_start + eps:
        pick = None
        for i in range(len(remaining) - 1, -1, -1):
            if remaining[i].end <= cursor + eps:
                pick = remaining.pop(i)
                break
        if pick is None:
            break
        if pick.end < cursor - eps:
            cats["other"] += cursor - pick.end
            cursor = pick.end
        seg_start = max(pick.start, exec_start)
        cats[_STAGE_CATEGORY[pick.name]] += cursor - seg_start
        cursor = seg_start
    if cursor > exec_start:
        cats["other"] += cursor - exec_start


def critical_path(root: SpanNode) -> CriticalPath:
    """Decompose one request root (``sim.request`` or ``load.request``)
    into :data:`CATEGORIES`.  Failed attempts (``ok=False`` — a crash
    killed their shards) are charged wholesale to ``retry_waste``: every
    second of a doomed attempt delayed the request, whatever that
    attempt was doing when the node died."""
    e = root.event
    if e.name not in REQUEST_ROOTS:
        raise ValueError(f"not a request root: {e.name!r} "
                         f"(expected one of {REQUEST_ROOTS})")
    cats = dict.fromkeys(CATEGORIES, 0.0)
    if e.name == "load.request":
        for c in root.children:
            cat = _STAGE_CATEGORY.get(c.name)
            if cat is not None:
                cats[cat] += c.event.value
        cats["other"] += e.value - sum(cats.values())
    else:
        attempts = [c for c in root.children if c.name == "sim.attempt"]
        for a in attempts:
            if a.event.attrs.get("ok", True):
                _walk_attempt(a, cats)
            else:
                cats["retry_waste"] += a.event.value
        cats["other"] += e.value - sum(cats.values())
    # flush float dust (including -0.0) so reports never print "-0.0000"
    cats = {k: (0.0 if abs(v) < 1e-12 else v) for k, v in cats.items()}
    return CriticalPath(request=e.attrs.get("request"), tenant=e.tenant,
                        latency=e.value, categories=cats)


def request_critical_paths(store, run: str) -> list[CriticalPath]:
    """Every request root in the run, decomposed, in emission order."""
    return [critical_path(r) for r in forest(store, run)
            if r.name in REQUEST_ROOTS]


def category_totals(paths: Sequence[CriticalPath]) -> dict[str, float]:
    """Summed seconds per category across requests (the report's
    where-did-the-time-go row)."""
    out = dict.fromkeys(CATEGORIES, 0.0)
    for p in paths:
        for k, v in p.categories.items():
            out[k] += v
    return out


# ------------------------------------------------- utilization & headroom
def _merged(intervals: Iterable[tuple[float, float]]
            ) -> list[tuple[float, float]]:
    out: list[list[float]] = []
    for s, e in sorted(intervals):
        if e <= s:
            continue
        if out and s <= out[-1][1] + 1e-12:
            out[-1][1] = max(out[-1][1], e)
        else:
            out.append([s, e])
    return [(s, e) for s, e in out]


def _subtract(a: Sequence[tuple[float, float]],
              b: Sequence[tuple[float, float]]
              ) -> list[tuple[float, float]]:
    """Interval difference a − b (both merged & sorted)."""
    out: list[tuple[float, float]] = []
    j = 0
    for s, e in a:
        cur = s
        while j < len(b) and b[j][1] <= cur:
            j += 1
        k = j
        while k < len(b) and b[k][0] < e:
            bs, be = b[k]
            if bs > cur:
                out.append((cur, bs))
            cur = max(cur, be)
            if be >= e:
                break
            k += 1
        if cur < e:
            out.append((cur, e))
    return out


def _span_len(intervals: Sequence[tuple[float, float]]) -> float:
    return sum(e - s for s, e in intervals)


def busy_intervals(events: Sequence[TelemetryEvent]
                   ) -> dict[str, list[tuple[float, float]]]:
    """Merged busy windows per execution resource: ``sim.compute`` spans
    keyed by their ``node`` attr, ``sim.comm`` spans by their
    ``resource`` (the shared ``medium``, or a node's internal bus)."""
    raw: dict[str, list[tuple[float, float]]] = {}
    for e in events:
        if e.kind != "span" or e.value <= 0:
            continue
        if e.name == "sim.compute":
            key = str(e.attrs.get("node", "?"))
        elif e.name == "sim.comm":
            key = str(e.attrs.get("resource", "medium"))
        else:
            continue
        raw.setdefault(key, []).append((e.t, e.t + e.value))
    return {k: _merged(v) for k, v in raw.items()}


def node_utilization(store, run: str,
                     horizon: float | None = None) -> dict[str, dict]:
    """Per-resource busy/idle over ``[0, horizon]`` (default: the last
    busy instant or request completion).  Returns
    ``{resource: {busy_s, idle_s, utilization, intervals}}`` — the
    timeline the report's ASCII renderer draws, and the saturation
    evidence the throughput-maximization line needs (which resource
    fills first)."""
    events = store.events(run, kind="span")
    busy = busy_intervals(events)
    if horizon is None:
        ends = [iv[-1][1] for iv in busy.values() if iv]
        ends += [e.t + e.value for e in events if e.name in REQUEST_ROOTS]
        horizon = max(ends, default=0.0)
    out = {}
    for key in sorted(busy):
        b = _span_len(busy[key])
        out[key] = {"busy_s": b, "idle_s": max(horizon - b, 0.0),
                    "utilization": b / horizon if horizon > 0 else 0.0,
                    "intervals": busy[key]}
    return out


def overlap_headroom(store, run: str,
                     horizon: float | None = None) -> dict[str, dict]:
    """Idle-while-a-peer-computes, per node — the compute/comm overlap a
    pipelined executor could reclaim (ROADMAP's pipelining item; PAPERS
    arxiv 2201.06769).  For each compute node: seconds it sat idle while
    at least one *other* node was computing.  The ``"total"`` entry sums
    the per-node headroom and normalizes by nodes × any-busy time."""
    events = store.events(run, kind="span")
    busy = {k: v for k, v in busy_intervals(events).items()
            if k != "medium" and "/" not in k}      # compute nodes only
    any_busy = _merged(iv for v in busy.values() for iv in v)
    out: dict[str, dict] = {}
    total = 0.0
    for key in sorted(busy):
        others = _merged(iv for k, v in busy.items() if k != key
                         for iv in v)
        idle_while_peer = _span_len(_subtract(others, busy[key]))
        total += idle_while_peer
        out[key] = {"idle_while_peer_busy_s": idle_while_peer,
                    "busy_s": _span_len(busy[key])}
    denom = len(busy) * _span_len(any_busy)
    out["total"] = {"idle_while_peer_busy_s": total,
                    "fraction": total / denom if denom > 0 else 0.0}
    return out


# ------------------------------------------------------------- summaries
def trace_summary(store, run: str) -> dict:
    """Everything the report renders from the trace layer: per-category
    mean seconds (and fractions of mean latency), per-node utilization,
    and overlap headroom.  ``requests`` is 0 for runs with no request
    roots (pure benchmark runs) — the report then skips the section."""
    paths = request_critical_paths(store, run)
    totals = category_totals(paths)
    n = len(paths)
    mean_latency = (sum(p.latency for p in paths) / n) if n else 0.0
    return {
        "requests": n,
        "mean_latency_s": mean_latency,
        "category_means_s": {k: (v / n if n else 0.0)
                             for k, v in totals.items()},
        "category_fractions": {
            k: (v / n / mean_latency if n and mean_latency > 0 else 0.0)
            for k, v in totals.items()},
        "max_residual_s": max((abs(p.residual) for p in paths),
                              default=0.0),
        "utilization": node_utilization(store, run),
        "headroom": overlap_headroom(store, run),
    }
