"""Production mesh construction.

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state (the dry-run must set XLA_FLAGS before first init).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) chips over ("data", "model").
    Multi-pod: 2 pods × 256 chips over ("pod", "data", "model")."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_smoke_mesh(shape=(1, 1), axes=("data", "model")):
    """Tiny mesh over however many devices exist (CPU tests)."""
    return jax.make_mesh(shape, axes)


def mesh_axis_sizes(mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))
