import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count on first init).

"""Multi-pod dry-run: lower + compile every (architecture × input shape) on
the production meshes, prove memory fit, and extract the roofline terms.

For each cell:
  1. HiDP plans the cell (tier-1 global DP over pods, tier-2 layout DSE).
  2. The step function (train / prefill / decode per the shape's kind) is
     jit'd with plan-derived in/out shardings and lowered with
     ShapeDtypeStruct stand-ins — no real allocation anywhere.
  3. ``compiled.memory_analysis()`` proves per-device fit;
     ``compiled.cost_analysis()`` provides HLO FLOPs/bytes; collective
     traffic is parsed from the post-SPMD HLO (per-device shapes).
  4. Everything lands in a JSON record consumed by benchmarks/roofline.py
     and EXPERIMENTS.md.

Usage:
  python -m repro.launch.dryrun --arch gemma-2b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out experiments/dryrun]
"""

import argparse
import json
import re
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.kernels import ops as kernel_ops
from repro.launch.mesh import make_production_mesh
from repro.models import SHAPES, build_model, shape_applicable
from repro.sharding import ctx as shard_ctx
from repro.sharding import specs
from repro.sharding.plan import MULTI_POD, MeshDesc, SINGLE_POD, plan_tpu
from repro.training import optimizer as optim
from repro.training.train_loop import make_train_step

COLLECTIVE_RE = re.compile(
    r"^\s*%?\S+\s*=\s*(\([^)]*\)|\S+)\s*(all-gather|all-reduce|"
    r"reduce-scatter|all-to-all|collective-permute)", re.M)
SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|s32|u32|s64|u64|s16|u16|s8|u8|pred)"
                      r"\[([0-9,]*)\]")
DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
               "s64": 8, "u64": 8, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
               "pred": 1}


def collective_bytes(hlo_text: str) -> dict:
    """Sum result-shape bytes of every collective op in the (post-SPMD,
    per-device) HLO.  Returns totals per op kind."""
    out: dict[str, float] = {}
    for m in COLLECTIVE_RE.finditer(hlo_text):
        shapes_blob, kind = m.group(1), m.group(2)
        nbytes = 0.0
        for sm in SHAPE_RE.finditer(shapes_blob):
            dt, dims = sm.group(1), sm.group(2)
            numel = 1
            for d in dims.split(","):
                if d:
                    numel *= int(d)
            nbytes += numel * DTYPE_BYTES[dt]
        out[kind] = out.get(kind, 0.0) + nbytes
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return out


def build_cell(arch: str, shape_name: str, mesh_desc: MeshDesc,
               force_layout=None, moe_impl=None, force_global=None):
    cfg = get_config(arch)
    model = build_model(cfg)
    shape = SHAPES[shape_name]
    plan = plan_tpu(model, shape, mesh_desc, force_layout=force_layout,
                    moe_impl=moe_impl, force_global=force_global)
    return cfg, model, shape, plan


def _plan_act_specs(plan):
    from jax.sharding import PartitionSpec as P

    def ax(axes):
        return (None if not axes
                else axes[0] if len(axes) == 1 else tuple(axes))
    act = P(ax(plan.batch_axes), ax(plan.seq_axes), None)
    logits = P(ax(plan.batch_axes), None, ax(plan.tp_axes))
    return act, logits


def lower_cell(model, shape, plan, mesh):
    """Returns the lowered computation for the cell's step function.  The
    plan's activation/logits layouts are published to the sharding context so
    the model pins them with with_sharding_constraint at layer boundaries."""
    act_spec, logits_spec = _plan_act_specs(plan)
    ep_axis = "model" if "model" in mesh.axis_names else (
        plan.tp_axes[0] if plan.tp_axes else mesh.axis_names[-1])
    with shard_ctx.plan_specs(act_spec, logits_spec, mesh=mesh,
                              ep_axis=ep_axis):
        return _lower_cell_inner(model, shape, plan, mesh)


def _lower_pipeline_train(model, shape, plan, mesh, in_specs):
    """GPipe rendering of global model-mode for training shapes: stacked
    layer params reshaped (S, L/S, ...) and sharded over 'pod'; microbatches
    stream through ppermute ticks (sharding/pipeline.py).  Reference
    implementation: stage-resident weights (no FSDP composition)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.sharding import pipeline as pp

    cfg = model.cfg
    S = plan.pipeline_stages
    params = model.param_specs(jnp.float32)
    per = cfg.n_layers // S
    staged = dict(params)
    staged["layers"] = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((S, per) + tuple(s.shape[1:]),
                                       s.dtype), params["layers"])
    p_sh = pp.stage_param_shardings(mesh, staged, axis="pod")
    sd = jnp.bfloat16 if plan.opt_dtype == "bfloat16" else jnp.float32
    opt = optim.init_abstract(staged, sd)
    o_sh = optim.OptState(step=specs.replicated(mesh), m=p_sh, v=p_sh)
    step = pp.make_pipeline_train_step(
        model, optim.OptConfig(state_dtype=plan.opt_dtype), plan, mesh)
    batch_sh = {k: specs.replicated(mesh) for k in in_specs}
    metric_sh = {k: specs.replicated(mesh)
                 for k in ("grad_norm", "lr", "loss")}
    fn = jax.jit(step, in_shardings=(p_sh, o_sh, batch_sh),
                 out_shardings=(p_sh, o_sh, metric_sh),
                 donate_argnums=(0, 1))
    return fn.lower(staged, opt, in_specs)


def _lower_cell_inner(model, shape, plan, mesh):
    cfg = model.cfg
    in_specs = model.input_specs(shape)
    batch_sh = specs.batch_shardings(mesh, in_specs, plan)
    if (shape.kind == "train" and plan.pipeline_stages > 1
            and cfg.family in ("dense", "moe", "ssm", "hybrid")):
        return _lower_pipeline_train(model, shape, plan, mesh, in_specs)
    if shape.kind == "train":
        master = plan.param_dtype == "bfloat16"
        params = model.param_specs(
            jnp.bfloat16 if master else jnp.float32)
        p_sh = specs.param_shardings(mesh, params, plan)
        sd = jnp.bfloat16 if plan.opt_dtype == "bfloat16" else jnp.float32
        opt = optim.init_abstract(params, sd, master=master)
        o_sh = optim.OptState(step=specs.replicated(mesh),
                              m=p_sh, v=p_sh,
                              master=p_sh if master else None)
        step = make_train_step(
            model, optim.OptConfig(state_dtype=plan.opt_dtype), plan)
        metric_sh = {"grad_norm": specs.replicated(mesh),
                     "lr": specs.replicated(mesh),
                     "loss": specs.replicated(mesh)}
        fn = jax.jit(step,
                     in_shardings=(p_sh, o_sh, batch_sh),
                     out_shardings=(p_sh, o_sh, metric_sh),
                     donate_argnums=(0, 1))
        return fn.lower(params, opt, in_specs)
    params = model.param_specs(jnp.bfloat16)
    p_sh = specs.param_shardings(mesh, params, plan)
    if shape.kind == "prefill":
        def prefill(p, b):
            return model.apply_prefill(p, b, moe_impl=plan.moe_impl)
        cache_like = model.cache_specs(shape)
        c_sh = specs.cache_shardings(mesh, cache_like, plan)
        lsh = specs.logits_sharding(
            mesh, plan, (shape.global_batch, 1, cfg.vocab))
        # prefill's returned cache has seq = input length
        fn = jax.jit(prefill, in_shardings=(p_sh, batch_sh),
                     out_shardings=(lsh, c_sh))
        return fn.lower(params, in_specs)
    # decode
    cache = model.cache_specs(shape)
    c_sh = specs.cache_shardings(mesh, cache, plan)

    def decode(p, c, b):
        return model.apply_decode(p, c, b, moe_impl=plan.moe_impl)
    lsh = specs.logits_sharding(mesh, plan,
                                (shape.global_batch, 1, cfg.vocab))
    fn = jax.jit(decode, in_shardings=(p_sh, c_sh, batch_sh),
                 out_shardings=(lsh, c_sh),
                 donate_argnums=(1,))
    return fn.lower(params, cache, in_specs)


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             force_layout=None, moe_impl=None, force_global=None,
             out_dir: str = "experiments/dryrun") -> dict:
    mesh_desc = MULTI_POD if multi_pod else SINGLE_POD
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "x".join(map(str, mesh_desc.shape)),
           "multi_pod": multi_pod}
    if not ok:
        rec.update(status="skipped", reason=why)
        return rec
    t0 = time.time()
    cfg, model, shape, plan = build_cell(arch, shape_name, mesh_desc,
                                         force_layout, moe_impl, force_global)
    mesh = make_production_mesh(multi_pod=multi_pod)
    with mesh:
        lowered = lower_cell(model, shape, plan, mesh)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        coll = collective_bytes(compiled.as_text())
    rec.update(
        status="ok",
        plan=dict(global_mode=plan.global_mode, layout=plan.local_layout,
                  batch_axes=plan.batch_axes, seq_axes=plan.seq_axes,
                  tp_axes=plan.tp_axes, fsdp_axes=plan.fsdp_axes,
                  microbatches=plan.microbatches, moe_impl=plan.moe_impl,
                  remat_group=plan.remat_group, opt_dtype=plan.opt_dtype,
                  param_dtype=plan.param_dtype,
                  pipeline_stages=plan.pipeline_stages,
                  predicted={k: v for k, v in plan.predicted.items()
                             if k != "fits"},
                  planning_ms=plan.planning_seconds * 1e3),
        memory=dict(
            argument_bytes=mem.argument_size_in_bytes,
            output_bytes=mem.output_size_in_bytes,
            temp_bytes=mem.temp_size_in_bytes,
            alias_bytes=mem.alias_size_in_bytes,
            peak_per_device=(mem.argument_size_in_bytes
                             + mem.output_size_in_bytes
                             + mem.temp_size_in_bytes
                             - mem.alias_size_in_bytes)),
        cost=dict(flops=cost.get("flops", -1.0),
                  bytes_accessed=cost.get("bytes accessed", -1.0),
                  transcendentals=cost.get("transcendentals", -1.0)),
        collectives=coll,
        model_flops=model.step_flops(shape),
        seconds=dict(lower=t_lower, compile=t_compile),
    )
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        tag = f"{arch}_{shape_name}_{'mp' if multi_pod else 'sp'}"
        if force_layout:
            tag += f"_{force_layout}"
        if moe_impl:
            tag += f"_{moe_impl}"
        with open(os.path.join(out_dir, tag + ".json"), "w") as f:
            json.dump(rec, f, indent=2)
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="every (arch × shape) on the selected mesh")
    ap.add_argument("--layout", default=None,
                    help="force a tier-2 layout candidate (hillclimb)")
    ap.add_argument("--moe-impl", default=None,
                    choices=["dense", "ep_a2a", "ep_a2a_q8"])
    ap.add_argument("--force-global", default=None,
                    choices=["data", "model"])
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args(argv)
    kernel_ops.set_backend("blocked")

    cells = []
    if args.all:
        for a in ARCH_IDS:
            for s in SHAPES:
                cells.append((a, s))
    else:
        if not (args.arch and args.shape):
            ap.error("--arch and --shape (or --all) required")
        cells = [(args.arch, args.shape)]

    failures = 0
    for arch, shape in cells:
        try:
            rec = run_cell(arch, shape, args.multi_pod,
                           force_layout=args.layout, moe_impl=args.moe_impl,
                           force_global=args.force_global, out_dir=args.out)
            if rec["status"] == "ok":
                m = rec["memory"]["peak_per_device"] / 1e9
                print(f"[OK] {arch:22s} {shape:12s} "
                      f"{rec['mesh']:9s} layout={rec['plan']['layout']:12s} "
                      f"peak={m:6.2f}GB flops={rec['cost']['flops']:.3e} "
                      f"coll={rec['collectives'].get('total', 0)/1e9:.2f}GB "
                      f"compile={rec['seconds']['compile']:.0f}s",
                      flush=True)
            else:
                print(f"[SKIP] {arch:22s} {shape:12s} — {rec['reason']}",
                      flush=True)
        except Exception as e:
            failures += 1
            print(f"[FAIL] {arch:22s} {shape:12s}: "
                  f"{type(e).__name__}: {e}", flush=True)
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
