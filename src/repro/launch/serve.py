"""End-to-end serving driver: continuous batching over the HiDP-planned
engine with a mixed stream of requests.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --requests 16
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.models import build_model
from repro.serving.engine import ServingEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b", choices=ARCH_IDS)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=96)
    ap.add_argument("--max-new", type=int, default=24)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServingEngine(model, params, max_batch=args.max_batch,
                        max_len=args.max_len)
    rng = np.random.default_rng(0)
    t0 = time.time()
    rids = []
    for i in range(args.requests):
        plen = int(rng.integers(4, 24))
        prompt = rng.integers(0, cfg.vocab, size=plen).astype(np.int32)
        rids.append(eng.submit(prompt, max_new_tokens=args.max_new))
    done = eng.run_until_done()
    dt = time.time() - t0
    toks = sum(len(r.generated) for r in done.values())
    print(f"arch={cfg.name}: served {len(done)}/{args.requests} requests, "
          f"{toks} tokens in {dt:.1f}s ({toks / dt:.1f} tok/s) with "
          f"{args.max_batch} slots")
    for rid in rids[:3]:
        print(f"  req{rid}: {done[rid].generated[:10]} ...")
    assert len(done) == args.requests


if __name__ == "__main__":
    main()
