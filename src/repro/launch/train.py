"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch gemma-2b --steps 200 \
        --d-model 256 --layers 8 --batch 8 --seq 256

Runs a real training loop (synthetic data, HiDP-planned step, fault-tolerant
runner with periodic checkpoints) sized to the host.  On the production mesh
the same code path runs with the full config; on this CPU host use reduced
dims (defaults give a ~20M-param model).
"""

from __future__ import annotations

import argparse
import dataclasses
import itertools
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.models import build_model
from repro.runtime.fault_tolerance import CheckpointPolicy, \
    FaultTolerantRunner
from repro.sharding.plan import SINGLE_POD, ShardingPlan
from repro.training import optimizer as optim
from repro.training.data import SyntheticDataset
from repro.training.train_loop import make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b", choices=ARCH_IDS)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch).reduced()
    cfg = dataclasses.replace(
        cfg, d_model=args.d_model, n_layers=args.layers,
        d_ff=args.d_model * 4, n_heads=max(args.d_model // 64, 1),
        n_kv_heads=max(min(cfg.n_kv_heads or 1, args.d_model // 64), 1),
        head_dim=64, vocab=4096)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"arch={cfg.name} reduced to {n_params / 1e6:.1f}M params; "
          f"{args.steps} steps of {args.batch}x{args.seq}")

    schedule = "wsd" if args.arch == "minicpm-2b" else "cosine"
    opt_cfg = optim.OptConfig(lr=args.lr, warmup_steps=20,
                              total_steps=args.steps, schedule=schedule)
    plan = ShardingPlan(arch=cfg.name, shape="train", mesh=SINGLE_POD,
                        global_mode="data", local_layout="host",
                        batch_axes=(), remat=True)
    raw_step = jax.jit(make_train_step(model, opt_cfg, plan),
                       donate_argnums=(0, 1))

    def step_fn(state, batch):
        params, opt = state
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt, metrics = raw_step(params, opt, batch)
        return (params, opt), metrics

    runner = FaultTolerantRunner(
        step_fn=step_fn,
        ckpt_policy=CheckpointPolicy(args.ckpt_dir,
                                     every_steps=args.ckpt_every))
    data = itertools.islice(
        iter(SyntheticDataset(cfg, args.batch, args.seq)), args.steps)
    t0 = time.time()
    state, step, log = runner.run((params, optim.init(params)), data)
    dt = time.time() - t0
    first = [m["loss"] for m in log[:5]]
    last = [m["loss"] for m in log[-5:]]
    print(f"done: {step} steps in {dt:.1f}s "
          f"({args.batch * args.seq * step / dt:.0f} tok/s)")
    print(f"loss: first5={[f'{float(l):.3f}' for l in first]} "
          f"last5={[f'{float(l):.3f}' for l in last]}")
    assert float(sum(last) / len(last)) < float(sum(first) / len(first)), \
        "training did not reduce the loss"
    print("loss decreased ✓")


if __name__ == "__main__":
    main()
