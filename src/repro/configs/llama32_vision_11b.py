"""llama-3.2-vision-11b — VLM backbone with cross-attention image layers
[hf:meta-llama/Llama-3.2-11B-Vision; unverified].

40L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256; one cross-attention
layer per 5 self-attention layers (8 cross layers).  The vision tower is a
STUB: ``input_specs()`` supplies (batch, 1601, d_model) precomputed patch
embeddings; their KV is computed once at prefill and static during decode.
"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=128256,
    head_dim=128,
    act="swiglu",
    cross_attn_every=5,
    n_vision_tokens=1601,
    rope_theta=500_000.0,
    source="hf:meta-llama/Llama-3.2-11B-Vision; unverified",
)
