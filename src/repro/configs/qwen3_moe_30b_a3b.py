"""qwen3-moe-30b-a3b — fine-grained MoE, 128 experts top-8
[hf:Qwen/Qwen3-30B-A3B; hf].  48L d_model=2048 32H (GQA kv=4) expert
d_ff=768 vocab=151936."""

from repro.models.config import ArchConfig, MoESpec

CONFIG = ArchConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    d_ff=768,
    vocab=151936,
    head_dim=128,
    act="swiglu",
    moe=MoESpec(num_experts=128, top_k=8, d_ff_expert=768),
    rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen3-30B-A3B; hf",
)
