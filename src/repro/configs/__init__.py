"""Architecture registry: ``--arch <id>`` resolution for every launcher."""

from __future__ import annotations

import importlib

from repro.models.config import ArchConfig, SHAPES, ShapeConfig, \
    shape_applicable  # noqa: F401

_MODULES = {
    "hymba-1.5b": "hymba_1_5b",
    "gemma3-1b": "gemma3_1b",
    "mistral-large-123b": "mistral_large_123b",
    "minicpm-2b": "minicpm_2b",
    "gemma-2b": "gemma_2b",
    "whisper-tiny": "whisper_tiny",
    "llama-3.2-vision-11b": "llama32_vision_11b",
    "mixtral-8x7b": "mixtral_8x7b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "mamba2-780m": "mamba2_780m",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch_id: str) -> ArchConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.CONFIG


def all_configs() -> dict[str, ArchConfig]:
    return {aid: get_config(aid) for aid in ARCH_IDS}
