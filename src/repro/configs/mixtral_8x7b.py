"""mixtral-8x7b — MoE, 8 experts top-2, sliding-window attention
[arXiv:2401.04088; hf].  32L d_model=4096 32H (GQA kv=8) expert d_ff=14336
vocab=32000, window 4096."""

from repro.models.config import ArchConfig, MoESpec

CONFIG = ArchConfig(
    name="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=32000,
    head_dim=128,
    act="swiglu",
    sliding_window=4096,
    moe=MoESpec(num_experts=8, top_k=2, d_ff_expert=14336),
    rope_theta=1_000_000.0,
    source="arXiv:2401.04088; hf",
)
