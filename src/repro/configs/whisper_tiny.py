"""whisper-tiny — audio encoder-decoder backbone [arXiv:2212.04356;
unverified].  4L (enc) + 4L (dec) d_model=384 6H d_ff=1536 vocab=51865.

The conv frontend is a STUB per the assignment: ``input_specs()`` supplies
precomputed frame embeddings of shape (batch, seq//2, d_model) — the shape the
stride-2 conv stem would produce.  LayerNorm + GELU per the Whisper family.
"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="whisper-tiny",
    family="audio",
    n_layers=4,                  # decoder layers
    encoder_layers=4,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab=51865,
    head_dim=64,
    act="gelu",
    norm="layernorm",
    source="arXiv:2212.04356; unverified",
)
