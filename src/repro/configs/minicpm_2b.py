"""minicpm-2b — dense llama-like, trained with the WSD schedule
[arXiv:2404.06395; hf].  40L d_model=2304 36H (full MHA kv=36) d_ff=5760
vocab=122753.  The WSD (warmup-stable-decay) schedule is implemented in
training/optimizer.py and selected by this config."""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="minicpm-2b",
    family="dense",
    n_layers=40,
    d_model=2304,
    n_heads=36,
    n_kv_heads=36,
    d_ff=5760,
    vocab=122753,
    head_dim=64,
    act="swiglu",
    tie_embeddings=True,
    source="arXiv:2404.06395; hf",
)

# training-schedule marker consumed by training/optimizer.py
LR_SCHEDULE = "wsd"
