"""mamba2-780m — attention-free SSM with SSD (state-space duality)
[arXiv:2405.21060; unverified].  48L d_model=1536 vocab=50280, d_state=128,
expand=2 (d_inner=3072, 48 SSD heads of head_dim 64)."""

from repro.models.config import ArchConfig, SSMSpec

CONFIG = ArchConfig(
    name="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=0,                  # attention-free
    n_kv_heads=0,
    d_ff=0,
    vocab=50280,
    ssm=SSMSpec(d_state=128, head_dim=64, expand=2, chunk=128),
    source="arXiv:2405.21060; unverified",
)
