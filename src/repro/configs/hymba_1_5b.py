"""hymba-1.5b — hybrid parallel attention+Mamba heads [arXiv:2411.13676; hf].

32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16.
Simplifications recorded in DESIGN.md: meta-tokens and the mixed
local/global attention schedule of the released model are not modelled; every
layer runs full attention in parallel with an SSD head (outputs mean-fused),
which is the architectural contribution the assignment exercises.
"""

from repro.models.config import ArchConfig, SSMSpec

CONFIG = ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_ff=5504,
    vocab=32001,
    head_dim=64,
    act="swiglu",
    sliding_window=1024,          # hybrid: SWA attention branch + SSM branch
    ssm=SSMSpec(d_state=16, head_dim=64, expand=2, chunk=128),
    source="arXiv:2411.13676; hf",
)
