"""gemma3-1b — dense, 5:1 local:global attention, 128k-class context
[hf:google/gemma-3-1b-pt; unverified].

26L d_model=1152 4H (GQA kv=1) d_ff=6912 vocab=262144. head_dim=256 (Gemma
family uses wide heads decoupled from d_model); local layers are 512-token
sliding-window, every 6th layer is global.
"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-1b",
    family="dense",
    n_layers=26,
    d_model=1152,
    n_heads=4,
    n_kv_heads=1,
    d_ff=6912,
    vocab=262144,
    head_dim=256,
    act="geglu",
    tie_embeddings=True,
    sliding_window=512,
    local_global=5,               # 5 local layers per 1 global
    rope_theta=1_000_000.0,
    source="hf:google/gemma-3-1b-pt; unverified",
)
